"""Dynamic packet router tests (paper §4.2–§4.3): runtime-reconfigurable
routing over a fixed compiled link schedule."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import Communicator, Topology, make_test_mesh
from repro.core.router import (
    RouterConfig,
    make_links,
    make_router_tables,
    run_router,
    snake_bus,
)

DIMS = (2, 4)
N = 8


@pytest.fixture(scope="module")
def env():
    mesh = make_test_mesh(DIMS, ("x", "y"))
    comm = Communicator.create(("x", "y"), DIMS)
    return mesh, comm


def _build(cfg, comm, mesh):
    fn = functools.partial(run_router, cfg, comm)

    def wrapped(tbl, pay, dst, ln):
        out_pay, out_cnt, ovf, _ = fn(tbl, pay[0], dst[0], ln[0], n_steps=64)
        return out_pay[None], out_cnt[None], ovf[None]

    spec = P(("x", "y"))
    return jax.jit(
        jax.shard_map(
            wrapped,
            mesh=mesh,
            in_specs=(P(), spec, spec, spec),
            out_specs=(spec, spec, spec),
        )
    )


def _stage(cfg, msgs):
    """msgs: list of (src, port, dst, value). Returns staged arrays."""
    pay = np.zeros((N, cfg.n_ports, cfg.fifo_cap, cfg.pkt_elems), np.float32)
    dst = np.zeros((N, cfg.n_ports, cfg.fifo_cap), np.int32)
    ln = np.zeros((N, cfg.n_ports), np.int32)
    for s, p, d, val in msgs:
        i = ln[s, p]
        pay[s, p, i] = val
        dst[s, p, i] = d
        ln[s, p] += 1
    return jnp.asarray(pay), jnp.asarray(dst), jnp.asarray(ln)


def test_make_links_2x4():
    links = make_links(DIMS)
    # dim0 size 2 -> one link; dim1 size 4 -> two links
    ids = [lid for lid, _ in links]
    assert ids == [0, 2, 3]


def test_router_delivers_torus(env):
    mesh, comm = env
    cfg = RouterConfig(dims=DIMS)
    tbl = jnp.asarray(make_router_tables(Topology.torus(DIMS), DIMS))
    runner = _build(cfg, comm, mesh)

    msgs = [
        (0, 0, 5, 1.0),
        (0, 1, 7, 2.0),
        (3, 0, 4, 3.0),
        (6, 1, 1, 4.0),
    ]
    pay, dst, ln = _stage(cfg, msgs)
    out_pay, out_cnt, ovf = runner(tbl, pay, dst, ln)
    out_pay, out_cnt, ovf = map(np.asarray, (out_pay, out_cnt, ovf))
    assert ovf.sum() == 0
    for s, p, d, val in msgs:
        assert out_cnt[d, p] >= 1, f"msg {s}->{d} port {p} not delivered"
        assert np.any(np.isclose(out_pay[d, p, : out_cnt[d, p]], val)), (
            f"payload {val} missing at rank {d} port {p}"
        )


def test_router_reroute_without_recompile(env):
    """THE paper claim: same compiled executable, different routing tables."""
    mesh, comm = env
    cfg = RouterConfig(dims=DIMS)
    runner = _build(cfg, comm, mesh)

    tbl_torus = jnp.asarray(make_router_tables(Topology.torus(DIMS), DIMS))
    tbl_bus = jnp.asarray(make_router_tables(snake_bus(DIMS), DIMS))

    msgs = [(0, 0, 5, 9.0), (2, 1, 6, 8.0)]
    pay, dst, ln = _stage(cfg, msgs)

    for tbl in (tbl_torus, tbl_bus):
        out_pay, out_cnt, ovf = map(np.asarray, runner(tbl, pay, dst, ln))
        assert ovf.sum() == 0
        for s, p, d, val in msgs:
            assert out_cnt[d, p] >= 1
            assert np.any(np.isclose(out_pay[d, p, : out_cnt[d, p]], val))

    # one executable served both tables
    assert runner._cache_size() == 1


def test_router_fifo_order(env):
    """Same (src, dst, port): elements delivered in push order (§3.1.1 i)."""
    mesh, comm = env
    cfg = RouterConfig(dims=DIMS)
    tbl = jnp.asarray(make_router_tables(Topology.torus(DIMS), DIMS))
    runner = _build(cfg, comm, mesh)

    msgs = [(1, 0, 6, float(10 + i)) for i in range(5)]
    pay, dst, ln = _stage(cfg, msgs)
    out_pay, out_cnt, ovf = map(np.asarray, runner(tbl, pay, dst, ln))
    assert ovf.sum() == 0
    assert out_cnt[6, 0] == 5
    got = out_pay[6, 0, :5, 0]
    np.testing.assert_allclose(got, [10, 11, 12, 13, 14])


def test_router_all_pairs_flood(env):
    """Every rank sends to every other rank; all delivered, none lost."""
    mesh, comm = env
    cfg = RouterConfig(dims=DIMS, fifo_cap=8, transit_cap=32, out_cap=16)
    tbl = jnp.asarray(make_router_tables(Topology.torus(DIMS), DIMS))
    runner = _build(cfg, comm, mesh)

    msgs = []
    for s in range(N):
        for d in range(N):
            if s != d:
                msgs.append((s, 0, d, float(100 * s + d)))
    pay, dst, ln = _stage(cfg, msgs)
    out_pay, out_cnt, ovf = map(np.asarray, runner(tbl, pay, dst, ln))
    assert ovf.sum() == 0
    for s, p, d, val in msgs:
        assert np.any(np.isclose(out_pay[d, p, : out_cnt[d, p]], val)), (
            f"lost {s}->{d}"
        )
    assert out_cnt[:, 0].sum() == len(msgs)
