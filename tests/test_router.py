"""Dynamic packet router tests (paper §4.2–§4.3): runtime-reconfigurable
routing over a fixed compiled link schedule."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import Communicator, Topology, make_test_mesh
from repro.core.router import (
    RouterConfig,
    make_links,
    make_router_tables,
    run_router,
    snake_bus,
)

DIMS = (2, 4)
N = 8


@pytest.fixture(scope="module")
def env():
    mesh = make_test_mesh(DIMS, ("x", "y"))
    comm = Communicator.create(("x", "y"), DIMS)
    return mesh, comm


def _build(cfg, comm, mesh):
    fn = functools.partial(run_router, cfg, comm)

    def wrapped(tbl, pay, dst, ln):
        out_pay, out_cnt, ovf, _ = fn(tbl, pay[0], dst[0], ln[0], n_steps=64)
        return out_pay[None], out_cnt[None], ovf[None]

    spec = P(("x", "y"))
    return jax.jit(
        jax.shard_map(
            wrapped,
            mesh=mesh,
            in_specs=(P(), spec, spec, spec),
            out_specs=(spec, spec, spec),
        )
    )


def _stage(cfg, msgs):
    """msgs: list of (src, port, dst, value). Returns staged arrays."""
    pay = np.zeros((N, cfg.n_ports, cfg.fifo_cap, cfg.pkt_elems), np.float32)
    dst = np.zeros((N, cfg.n_ports, cfg.fifo_cap), np.int32)
    ln = np.zeros((N, cfg.n_ports), np.int32)
    for s, p, d, val in msgs:
        i = ln[s, p]
        pay[s, p, i] = val
        dst[s, p, i] = d
        ln[s, p] += 1
    return jnp.asarray(pay), jnp.asarray(dst), jnp.asarray(ln)


def test_make_links_2x4():
    links = make_links(DIMS)
    # dim0 size 2 -> one link; dim1 size 4 -> two links
    ids = [lid for lid, _ in links]
    assert ids == [0, 2, 3]


def test_router_delivers_torus(env):
    mesh, comm = env
    cfg = RouterConfig(dims=DIMS)
    tbl = jnp.asarray(make_router_tables(Topology.torus(DIMS), DIMS))
    runner = _build(cfg, comm, mesh)

    msgs = [
        (0, 0, 5, 1.0),
        (0, 1, 7, 2.0),
        (3, 0, 4, 3.0),
        (6, 1, 1, 4.0),
    ]
    pay, dst, ln = _stage(cfg, msgs)
    out_pay, out_cnt, ovf = runner(tbl, pay, dst, ln)
    out_pay, out_cnt, ovf = map(np.asarray, (out_pay, out_cnt, ovf))
    assert ovf.sum() == 0
    for s, p, d, val in msgs:
        assert out_cnt[d, p] >= 1, f"msg {s}->{d} port {p} not delivered"
        assert np.any(np.isclose(out_pay[d, p, : out_cnt[d, p]], val)), (
            f"payload {val} missing at rank {d} port {p}"
        )


def test_router_reroute_without_recompile(env):
    """THE paper claim: same compiled executable, different routing tables."""
    mesh, comm = env
    cfg = RouterConfig(dims=DIMS)
    runner = _build(cfg, comm, mesh)

    tbl_torus = jnp.asarray(make_router_tables(Topology.torus(DIMS), DIMS))
    tbl_bus = jnp.asarray(make_router_tables(snake_bus(DIMS), DIMS))

    msgs = [(0, 0, 5, 9.0), (2, 1, 6, 8.0)]
    pay, dst, ln = _stage(cfg, msgs)

    for tbl in (tbl_torus, tbl_bus):
        out_pay, out_cnt, ovf = map(np.asarray, runner(tbl, pay, dst, ln))
        assert ovf.sum() == 0
        for s, p, d, val in msgs:
            assert out_cnt[d, p] >= 1
            assert np.any(np.isclose(out_pay[d, p, : out_cnt[d, p]], val))

    # one executable served both tables
    assert runner._cache_size() == 1


def test_router_fifo_order(env):
    """Same (src, dst, port): elements delivered in push order (§3.1.1 i)."""
    mesh, comm = env
    cfg = RouterConfig(dims=DIMS)
    tbl = jnp.asarray(make_router_tables(Topology.torus(DIMS), DIMS))
    runner = _build(cfg, comm, mesh)

    msgs = [(1, 0, 6, float(10 + i)) for i in range(5)]
    pay, dst, ln = _stage(cfg, msgs)
    out_pay, out_cnt, ovf = map(np.asarray, runner(tbl, pay, dst, ln))
    assert ovf.sum() == 0
    assert out_cnt[6, 0] == 5
    got = out_pay[6, 0, :5, 0]
    np.testing.assert_allclose(got, [10, 11, 12, 13, 14])


def test_router_all_pairs_flood(env):
    """Every rank sends to every other rank; all delivered, none lost."""
    mesh, comm = env
    cfg = RouterConfig(dims=DIMS, fifo_cap=8, transit_cap=32, out_cap=16)
    tbl = jnp.asarray(make_router_tables(Topology.torus(DIMS), DIMS))
    runner = _build(cfg, comm, mesh)

    msgs = []
    for s in range(N):
        for d in range(N):
            if s != d:
                msgs.append((s, 0, d, float(100 * s + d)))
    pay, dst, ln = _stage(cfg, msgs)
    out_pay, out_cnt, ovf = map(np.asarray, runner(tbl, pay, dst, ln))
    assert ovf.sum() == 0
    for s, p, d, val in msgs:
        assert np.any(np.isclose(out_pay[d, p, : out_cnt[d, p]], val)), (
            f"lost {s}->{d}"
        )
    assert out_cnt[:, 0].sum() == len(msgs)


# ---------------------------------------------------------------------------
# Vectorized / Pallas datapath equivalence (DESIGN.md §10)
# ---------------------------------------------------------------------------


def _build_impl(cfg, comm, mesh, impl, n_steps=64):
    """All four router outputs (incl. overflow and t_done) under ``impl``."""

    def wrapped(tbl, pay, dst, ln):
        op, oc, ov, td = run_router(
            cfg, comm, tbl, pay[0], dst[0], ln[0], n_steps, impl=impl
        )
        return op[None], oc[None], ov[None], td[None]

    spec = P(("x", "y"))
    return jax.jit(
        jax.shard_map(
            wrapped, mesh=mesh, in_specs=(P(),) + (spec,) * 3,
            out_specs=(spec,) * 4,
        )
    )


def _rand_msgs(cfg, rng, load=4):
    msgs = []
    for s in range(N):
        for p in range(cfg.n_ports):
            for _ in range(rng.randint(0, load + 1)):
                msgs.append((s, p, rng.randint(0, N), float(rng.randint(1, 99))))
    return msgs


_EQ_CFGS = {
    "r1": dict(n_ports=1, R=1, switch_bubble=False, tick_batch=1),
    "r4_bubble": dict(n_ports=1, R=4, switch_bubble=True, tick_batch=2),
    "ports2_r8": dict(n_ports=2, R=8, switch_bubble=False, tick_batch=4),
    "ports2_bubble_r16": dict(n_ports=2, R=16, switch_bubble=True,
                              tick_batch=3),
}


@pytest.mark.parametrize("impl", ["vector", "pallas"])
@pytest.mark.parametrize("cfg_name", sorted(_EQ_CFGS))
@pytest.mark.parametrize("topo", ["torus", "snake_bus"])
def test_router_impls_tick_identical(env, impl, cfg_name, topo):
    """The vectorized and Pallas datapaths must be *tick-for-tick* equal to
    the scalar reference: same delivery buffers, same counts, same overflow
    tally and the same t_done stamp — R-stickiness, switch-bubble,
    multi-port contention and batched ticks included."""
    mesh, comm = env
    cfg = RouterConfig(dims=DIMS, fifo_cap=6, transit_cap=8, out_cap=16,
                       pkt_elems=4, **_EQ_CFGS[cfg_name])
    topo_obj = Topology.torus(DIMS) if topo == "torus" else snake_bus(DIMS)
    tbl = jnp.asarray(make_router_tables(topo_obj, DIMS))
    rng = np.random.RandomState(sum(map(ord, cfg_name)) % 1000)
    args = (tbl,) + _stage(cfg, _rand_msgs(cfg, rng))

    ref = [np.asarray(v)
           for v in _build_impl(cfg, comm, mesh, "scalar")(*args)]
    got = [np.asarray(v) for v in _build_impl(cfg, comm, mesh, impl)(*args)]
    for a, b, nm in zip(ref, got, ("out_pay", "out_cnt", "overflow",
                                   "t_done")):
        np.testing.assert_array_equal(
            a, b, err_msg=f"{impl} != scalar on {nm} ({cfg_name}/{topo})"
        )


@pytest.mark.parametrize("impl", ["scalar", "vector", "pallas"])
def test_router_out_cap_overrun_counts_overflow(env, impl):
    """A delivery past ``out_cap`` must DROP and COUNT, never silently
    overwrite a slot (mirrors the transit_cap drop test): the first
    ``out_cap`` packets survive intact, the surplus lands in ``overflow``."""
    mesh, comm = env
    cfg = RouterConfig(dims=DIMS, n_ports=1, fifo_cap=8, transit_cap=16,
                       out_cap=2, pkt_elems=4)
    tbl = jnp.asarray(make_router_tables(Topology.torus(DIMS), DIMS))
    # four ranks send one packet each to rank 0 / port 0: out_cap=2 holds
    # the first two arrivals, the other two must drop-and-count
    msgs = [(s, 0, 0, float(10 + s)) for s in (1, 2, 4, 5)]
    args = (tbl,) + _stage(cfg, msgs)
    out_pay, out_cnt, ovf, _ = (
        np.asarray(v) for v in _build_impl(cfg, comm, mesh, impl)(*args)
    )
    assert out_cnt[0, 0] == cfg.out_cap
    assert ovf.sum() == len(msgs) - cfg.out_cap
    # the slots that did land are real payloads, not overwritten garbage
    assert set(out_pay[0, 0, :, 0][: cfg.out_cap]) <= {
        float(v) for _, _, _, v in msgs
    }


# ---------------------------------------------------------------------------
# Property: tick-for-tick equivalence on random partial permutations
# ---------------------------------------------------------------------------

import sys as _sys  # noqa: E402

_sys.path.insert(0, str(__import__("pathlib").Path(__file__).parent))
from _hyp import given, settings, st  # noqa: E402


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**16 - 1),
    topo=st.sampled_from(["torus", "snake_bus"]),
    R=st.sampled_from([1, 4, 16]),
    bubble=st.booleans(),
    batch=st.integers(1, 4),
)
def test_router_impls_equivalent_property(seed, topo, R, bubble, batch):
    """Random partial permutations over random configs: the vectorized and
    Pallas arbiters must reproduce the scalar reference's full 4-tuple
    (out_pay, out_cnt, overflow, t_done) exactly."""
    mesh = make_test_mesh(DIMS, ("x", "y"))
    comm = Communicator.create(("x", "y"), DIMS)
    cfg = RouterConfig(dims=DIMS, n_ports=2, fifo_cap=4, transit_cap=6,
                       out_cap=8, pkt_elems=4, R=R, switch_bubble=bubble,
                       tick_batch=batch)
    topo_obj = Topology.torus(DIMS) if topo == "torus" else snake_bus(DIMS)
    tbl = jnp.asarray(make_router_tables(topo_obj, DIMS))
    rng = np.random.RandomState(seed)
    # a random partial permutation per port: unique srcs, unique dsts
    msgs = []
    for p in range(cfg.n_ports):
        srcs = rng.permutation(N)[: rng.randint(1, N + 1)]
        dsts = rng.permutation(N)[: len(srcs)]
        for s, d in zip(srcs, dsts):
            if s != d:
                msgs.append((int(s), p, int(d), float(rng.randint(1, 99))))
    if not msgs:
        return
    args = (tbl,) + _stage(cfg, msgs)
    outs = {
        impl: [np.asarray(v)
               for v in _build_impl(cfg, comm, mesh, impl)(*args)]
        for impl in ("scalar", "vector", "pallas")
    }
    for impl in ("vector", "pallas"):
        for a, b, nm in zip(outs["scalar"], outs[impl],
                            ("out_pay", "out_cnt", "overflow", "t_done")):
            np.testing.assert_array_equal(
                a, b, err_msg=f"{impl} != scalar on {nm} (seed={seed})"
            )


@pytest.mark.parametrize("impl", ["vector", "pallas"])
def test_router_batch_respects_step_budget(env, impl):
    """A tick batch must never carry a still-live network past ``n_steps``:
    with a flood that cannot drain in the budget and a tick_batch that does
    not divide it, the batched datapaths must stop delivering exactly where
    the scalar reference stops."""
    mesh, comm = env
    cfg = RouterConfig(dims=DIMS, n_ports=1, fifo_cap=8, transit_cap=8,
                       out_cap=8, pkt_elems=4, tick_batch=4)
    tbl = jnp.asarray(make_router_tables(Topology.torus(DIMS), DIMS))
    msgs = []
    for s in range(N):
        for k in range(4):
            msgs.append((s, 0, (s + 1 + k) % N, float(10 * s + k)))
    args = (tbl,) + _stage(cfg, msgs)
    ref = [np.asarray(v)
           for v in _build_impl(cfg, comm, mesh, "scalar", n_steps=5)(*args)]
    got = [np.asarray(v)
           for v in _build_impl(cfg, comm, mesh, impl, n_steps=5)(*args)]
    for a, b, nm in zip(ref, got, ("out_pay", "out_cnt", "overflow",
                                   "t_done")):
        np.testing.assert_array_equal(a, b, err_msg=f"{impl}: {nm}")
