"""repro/apps contract tests (DESIGN.md §8).

Three pillars:

* **overlap exactness** — the pipelined stencil step (interior update
  while halos fly) is bit-identical to the non-overlapped reference on
  ring and torus grids under every transport backend, including the lossy
  compressed wire (both schedules quantise identical slabs);
* **end-to-end correctness** — the distributed run reassembles to the
  single-rank sweep exactly on exact wires, within the codec bound on
  ``smi:compressed``;
* **costing exactness** — the halo exchange's traced, *tagged* transport
  counters equal the netsim prediction to the step and the byte, and the
  tuner's ``halo`` cells obey the never-worse-than-static invariant the
  other ops already carry (tests/test_netsim.py sweeps them since "halo"
  is in OPS).
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import HALO_TAG, DistributedStencil, HaloExchange
from repro.core.overlap import halo_perm
from repro.netsim import Plan, halo_pairs, predict_halo_time
from repro.transport import get_transport

BACKENDS = ["static", "packet", "fused", "compressed"]

GRIDS = {"ring": (1, 8), "torus": (2, 4)}


def _make(grid_name, **kw):
    app = DistributedStencil.create(GRIDS[grid_name], **kw)
    return app, app.make_mesh()


@pytest.fixture(scope="module")
def world():
    return np.random.RandomState(0).randn(32, 32).astype(np.float32)


@pytest.mark.parametrize("grid_name", sorted(GRIDS))
@pytest.mark.parametrize("backend", BACKENDS)
def test_overlapped_matches_reference_bit_exact(grid_name, backend, world,
                                                devices8):
    app, mesh = _make(grid_name)
    tiles = jnp.asarray(app.scatter(world))
    # fresh instances per traced function: runtime-stats backends (packet)
    # may not be reused across traces
    ref = np.asarray(app.jitted(
        mesh, n_steps=2, overlapped=False, transport=get_transport(backend)
    )(tiles))
    ovl = np.asarray(app.jitted(
        mesh, n_steps=2, overlapped=True, transport=get_transport(backend)
    )(tiles))
    np.testing.assert_array_equal(ref, ovl)

    want = app.single_rank_reference(world, 2)
    got = app.gather(ovl)
    if backend == "compressed":
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)
    else:
        np.testing.assert_array_equal(got, want)


def test_multistep_rolled_equals_iterated(world, devices8):
    """fori_loop'd run == repeated single-step calls (double-buffer carry
    correctness across timesteps)."""
    app, mesh = _make("torus")
    tiles = jnp.asarray(app.scatter(world))
    rolled = np.asarray(app.jitted(mesh, n_steps=3, overlapped=True)(tiles))
    one = app.jitted(mesh, n_steps=1, overlapped=True)
    stepped = tiles
    for _ in range(3):
        stepped = one(stepped)
    np.testing.assert_array_equal(rolled, np.asarray(stepped))


def test_pallas_interpret_interior_bit_exact(world, devices8):
    """The Pallas row-streaming kernel as the interior update (interpreter
    off-TPU) stays bit-identical to the jnp reference schedule."""
    app, mesh = _make("torus")
    tiles = jnp.asarray(app.scatter(world))
    ref = np.asarray(app.jitted(mesh, n_steps=2, overlapped=False)(tiles))
    app_p = dataclasses.replace(app, interpret=True)
    ovl = np.asarray(app_p.jitted(mesh, n_steps=2, overlapped=True)(tiles))
    np.testing.assert_array_equal(ref, ovl)


# ---------------------------------------------------------------------------
# costing: traced tagged stats == netsim prediction, exactly
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("grid_name", sorted(GRIDS))
@pytest.mark.parametrize("backend", BACKENDS)
def test_halo_tagged_stats_match_prediction(grid_name, backend, world,
                                            devices8):
    app, mesh = _make(grid_name)
    tiles = jnp.asarray(app.scatter(world))
    t = get_transport(backend)
    np.asarray(app.jitted(mesh, n_steps=2, overlapped=True, transport=t)(tiles))
    nx, ny = world.shape[0] // app.grid[0], world.shape[1] // app.grid[1]
    pred_key = "compressed" if backend == "compressed" else backend
    steps, nbytes = app.halo_schedule.predicted_stats(
        (nx, ny), transport=pred_key
    )
    got = t.stats.tag_counts(HALO_TAG)
    assert got == (2 * steps, 2 * nbytes), (
        f"{backend}@{grid_name}: traced {got} != 2x predicted "
        f"({steps}, {nbytes})"
    )
    # the tag accounts everything this run moved: no untagged residue
    assert t.stats.steps == got[0]
    assert t.stats.bytes_moved == got[1]


def test_halo_pairs_single_source_of_truth():
    """netsim's pure-python pair builder == the traced halo_perm wiring."""
    for grid in [(1, 8), (2, 4), (3, 3)]:
        for drx, dry in [(-1, 0), (1, 0), (0, -1), (0, 1)]:
            assert halo_pairs(grid, drx, dry) == halo_perm(grid, drx, dry)


def test_halo_plan_auto_and_tuner_cells(world, devices8):
    app, mesh = _make("torus")
    plan = app.comm.plan("halo", 4096)
    assert isinstance(plan, Plan)
    assert plan.wire == "raw", "lossy halos must never be a tuned choice"
    assert plan.n_chunks == 1
    # plan="auto" runs and matches the static schedule bit for bit
    app_auto = dataclasses.replace(app, plan="auto")
    tiles = jnp.asarray(app.scatter(world))
    got = np.asarray(app_auto.jitted(mesh, n_steps=2, overlapped=True)(tiles))
    ref = np.asarray(app.jitted(mesh, n_steps=2, overlapped=False)(tiles))
    np.testing.assert_array_equal(got, ref)


def test_predicted_time_model_shapes(devices8):
    """LinkModel halo predictions behave physically: positive, monotone in
    slab size, and the int8 wire only wins once serialisation dominates."""
    app, _ = _make("torus")
    small = predict_halo_time(app.comm, grid=app.grid, shape=(16, 16))
    big = predict_halo_time(app.comm, grid=app.grid, shape=(1024, 1024))
    assert 0 < small < big
    from repro.netsim import LinkModel

    m = LinkModel.default_v5e()
    assert m.overlapped_step_time(3.0, 2.0) == 3.0
    assert m.serial_step_time(3.0, 2.0) == 5.0
    # a tiny slab is latency-bound: the compressed wire pays the codec
    small_i8 = predict_halo_time(
        app.comm, grid=app.grid, shape=(16, 16), wire="int8"
    )
    assert small_i8 > small
    # a huge slab is serialisation-bound: the compressed wire wins
    big_i8 = predict_halo_time(
        app.comm, grid=app.grid, shape=(65536, 65536), wire="int8"
    )
    big_raw = predict_halo_time(
        app.comm, grid=app.grid, shape=(65536, 65536)
    )
    assert big_i8 < big_raw


def test_halo_exchange_invalid_grid():
    from repro.core import Communicator

    comm = Communicator.create("x", (8,))
    with pytest.raises(AssertionError):
        HaloExchange(comm=comm, grid=(3, 3))
