"""smilint: the static channel-program verifier (DESIGN.md §14).

Covers both passes end to end — capture-mode abstract interpretation
(ledger recording, zero real comm, the SMI10x rules) and the AST source
lints (SMI00x, suppression comments, the check_no_stream_shims shim) —
plus the claims-introspection surfaces (PortAllocator / ChannelPool) and
the golden-rule corpus gate that CI enforces.
"""

import gc
import pathlib
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis import CATALOG, Diagnostic, ProgramBuilder, verify_program
from repro.analysis import capture as cap
from repro.analysis.corpus import corpus
from repro.analysis.rules import (
    ALL_RULES,
    NoStreamShims,
    lint_paths,
    lint_source,
)
from repro.analysis.verify import verify_ledger
from repro.channels import (
    ChannelPool,
    open_allreduce_channel,
    open_channel,
)
from repro.core import Communicator, PortAllocator, make_test_mesh, run_spmd
from repro.obs import trace as obs
from repro.transport import get_transport

ROOT = pathlib.Path(__file__).resolve().parent.parent


@pytest.fixture(scope="module")
def ring8():
    mesh = make_test_mesh((8,), ("x",))
    comm = Communicator.create("x", (8,))
    return mesh, comm


# ---------------------------------------------------------------------------
# capture mode: abstract interpretation of real channel programs
# ---------------------------------------------------------------------------


def _pipeline_prog(comm, mesh, *, count=4, port=0):
    """A claimed p2p push/pop pipeline + an anonymous bcast transfer."""

    def fn(v):
        with open_channel(comm, count=count, src=0, dst=3, port=port,
                          elem_shape=(), dtype=jnp.float32) as ch:
            acc = jnp.float32(0)

            def body(i, carry):
                ch, acc = carry
                ch = ch.push(v[0, 0] + i.astype(jnp.float32))
                ch, val, ok = ch.pop()
                return ch, acc + jnp.where(ok, val, 0.0)

            ch, acc = jax.lax.fori_loop(0, count + 2, body, (ch, acc))
        y = open_allreduce_channel(comm, port=None).transfer(
            acc[None] + v[0])
        return y[None]

    return jax.jit(jax.shard_map(
        fn, mesh=mesh, in_specs=P("x"), out_specs=P("x")))


def test_capture_records_ops_and_moves_no_bytes(ring8):
    mesh, comm = ring8
    f = _pipeline_prog(comm, mesh)
    with cap.capture() as led:
        f.lower(jax.ShapeDtypeStruct((8, 4), jnp.float32))
    assert not cap.ACTIVE and cap.LEDGER is None  # scope restored
    counts = led.counts()
    # fori_loop bodies trace once: one push + one pop in the ledger
    assert counts["open"] == 2
    assert counts["push"] == 1 and counts["pop"] == 1
    assert counts["close"] == 1 and counts["transfer"] == 1
    # the acceptance bar: abstract interpretation executes no collective
    assert led.real_steps == 0
    assert led.transport_steps  # ...but the abstract tallies accrued
    assert all(v["steps"] > 0 for v in led.transport_steps.values())
    opens = [o for o in led.ops if o.op == "open"]
    assert [(o.kind, o.port) for o in opens] == [("p2p", 0),
                                                ("allreduce", None)]
    xfer = next(o for o in led.ops if o.op == "transfer")
    assert xfer.kind == "allreduce" and xfer.port is None
    pushed = next(o for o in led.ops if o.op == "push")
    assert pushed.location and ":" in pushed.location
    assert verify_ledger(led, name="pipeline") == []


def test_capture_is_invisible_to_real_execution(ring8):
    """The same program runs for real before and after a capture — the
    spec's transport cache must never leak the abstract backend out (or a
    real one in)."""
    mesh, comm = ring8
    t = get_transport("static")
    before = t.stats.steps

    def fn(v):
        return open_channel(comm, src=0, dst=3, port=None, transport=t,
                            n_chunks=2).transfer(v[0])[None]

    x = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4)
    y0 = run_spmd(fn, mesh, P("x"), P("x"), x)
    real_steps_per_run = t.stats.steps - before
    assert real_steps_per_run > 0
    with cap.capture() as led:
        jax.jit(jax.shard_map(
            fn, mesh=mesh, in_specs=P("x"), out_specs=P("x"))).lower(
            jax.ShapeDtypeStruct((8, 4), jnp.float32))
    assert led.real_steps == 0
    # fresh jit entry post-capture: must resolve the REAL backend again
    y1 = jax.jit(jax.shard_map(
        lambda v: fn(v), mesh=mesh, in_specs=P("x"), out_specs=P("x")))(x)
    np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))
    np.testing.assert_array_equal(np.asarray(y1[3]), np.asarray(x[0]))


def test_capture_flags_port_collision_in_one_trace(ring8):
    """Two live claims on one (comm, port) inside a single traced program
    — the paper's one-port-one-FIFO rule — surfaces as SMI101."""
    mesh, comm = ring8
    pa = PortAllocator()

    def fn(v):
        a = open_channel(comm, src=0, dst=1, port=3, allocator=pa)
        b = open_channel(comm, src=0, dst=2, port=3, allocator=pa)
        return (v + 0 * (a.pipe + b.pipe))[:1]

    with pytest.raises(ValueError, match="already claimed"):
        with cap.capture():
            jax.jit(jax.shard_map(
                fn, mesh=mesh, in_specs=P("x"), out_specs=P("x"))).lower(
                jax.ShapeDtypeStruct((8,), jnp.float32))


def test_verifier_reports_seeded_collision():
    b = ProgramBuilder(size=2)
    s = b.spmd()
    s.open(kind="p2p", port=3, src=0, dst=1, count=1, dtype="float32")
    s.open(kind="p2p", port=3, src=0, dst=1, count=1, dtype="float32")
    diags = verify_program(b.build("seeded"))
    assert any(d.rule == "SMI101" for d in diags)
    d = next(d for d in diags if d.rule == "SMI101")
    row = d.to_dict()
    assert row["port"] == 3 and row["severity"] == CATALOG["SMI101"][0]


# ---------------------------------------------------------------------------
# the in-repo program sweep (the CI capture gate, acceptance criteria)
# ---------------------------------------------------------------------------


def test_capture_train_program_is_clean_and_executes_no_collective():
    from repro.analysis.programs import capture_train

    led = capture_train()
    assert led.real_steps == 0, "capture-mode train lowering moved bytes"
    assert led.transport_steps, "train lowered without any channel traffic"
    assert verify_ledger(led, name="launch.train") == []


def test_capture_serve_program_is_clean_and_executes_no_collective():
    from repro.analysis.programs import capture_serve

    led = capture_serve()
    assert led.real_steps == 0, "capture-mode serve lowering moved bytes"
    counts = led.counts()
    # the pool's persistent claims balance: opened AND closed in-capture
    assert counts.get("pool.open", 0) >= 1
    assert counts.get("pool.open") == counts.get("pool.close")
    assert verify_ledger(led, name="launch.serve") == []


# ---------------------------------------------------------------------------
# corpus: every seeded defect must report exactly its golden rules
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("case", corpus(), ids=lambda c: c.name)
def test_corpus_case_reports_exact_golden_rules(case):
    reported = case.reported()
    assert reported == case.golden, (
        f"{case.name}: reported {sorted(reported)} != "
        f"golden {sorted(case.golden)} ({case.note})")


def test_catalog_covers_every_golden_rule():
    for case in corpus():
        for rule in case.golden:
            assert rule in CATALOG
    assert {r.rule_id for r in ALL_RULES} == {
        r for r in CATALOG if r.startswith("SMI0")}


# ---------------------------------------------------------------------------
# AST pass: repo hygiene + suppression + the legacy shim entry point
# ---------------------------------------------------------------------------


def test_repo_is_smilint_clean():
    assert lint_paths(str(ROOT)) == []


def test_suppression_comment_silences_exactly_the_named_rule():
    src = "y = stream_bcast(x, comm)  # smilint: ignore[SMI001]\n"
    assert lint_source(src, relpath="src/repro/seeded.py") == []
    noisy = lint_source("y = stream_bcast(x, comm)\n",
                        relpath="src/repro/seeded.py")
    assert [d.rule for d in noisy] == ["SMI001"]
    # suppressing a DIFFERENT rule must not silence SMI001
    other = lint_source(
        "y = stream_bcast(x, comm)  # smilint: ignore[SMI004]\n",
        relpath="src/repro/seeded.py")
    assert [d.rule for d in other] == ["SMI001"]


def test_close_discipline_accepts_escapes_and_with():
    clean = (
        "def mk(comm):\n"
        "    ch = open_channel(comm, port=1)\n"
        "    return ch\n"
        "def use(comm, x):\n"
        "    with open_channel(comm, port=2) as ch:\n"
        "        pass\n"
        "    anon = open_channel(comm, port=None)\n"
        "    ch2 = open_channel(comm, port=3)\n"
        "    ch2.close()\n"
    )
    assert lint_source(clean, relpath="src/repro/seeded.py") == []


def test_shim_script_regression(tmp_path):
    """scripts/check_no_stream_shims.py now fronts rule SMI001: clean on
    the repo, exit 1 (naming the file) on a seeded violation."""
    env_ok = subprocess.run(
        [sys.executable, str(ROOT / "scripts/check_no_stream_shims.py")],
        capture_output=True, text=True)
    assert env_ok.returncode == 0, env_ok.stdout + env_ok.stderr
    bad = tmp_path / "src" / "repro"
    bad.mkdir(parents=True)
    (bad / "bad.py").write_text("y = stream_bcast(x, comm, root=0)\n")
    env_bad = subprocess.run(
        [sys.executable, str(ROOT / "scripts/check_no_stream_shims.py"),
         str(tmp_path)],
        capture_output=True, text=True)
    assert env_bad.returncode == 1
    assert "SMI001" in env_bad.stdout and "bad.py" in env_bad.stdout


# ---------------------------------------------------------------------------
# claims introspection (PortAllocator / ChannelPool)
# ---------------------------------------------------------------------------


def test_port_allocator_claims_rows(ring8):
    _, comm = ring8
    pa = PortAllocator()
    ch = open_channel(comm, src=0, dst=1, port=5, tag="t.claimed",
                      allocator=pa)
    anon = open_channel(comm, src=0, dst=2, port=None, allocator=pa)
    rows = pa.claims(comm)
    assert [r["port"] for r in rows] == [5, None]
    named, anon_row = rows
    assert named["tag"] == "t.claimed" and named["kind"] == "p2p"
    assert not named["anonymous"] and not named["persistent"]
    assert anon_row["anonymous"] and anon_row["kind"] == "p2p"
    ch.close()
    assert [r["port"] for r in pa.claims(comm)] == [None]
    del anon, rows, named, anon_row  # rows hold the owner spec strongly
    gc.collect()
    assert pa.claims(comm) == ()


def test_channel_pool_claims_and_idempotent_close(ring8):
    _, comm = ring8
    pa = PortAllocator()
    pool = ChannelPool(comm, allocator=pa)
    pool.spec("decode.mlp")
    pool.spec("decode.attn", kind="allreduce")
    rows = pool.claims()
    assert [r["port"] for r in rows] == [100, 101]
    assert all(r["persistent"] for r in rows)
    assert rows[0]["tag"] == "serve.decode.mlp"
    # another client's claim on the same allocator stays out of the view
    other = open_channel(comm, src=0, dst=1, port=7, allocator=pa)
    assert [r["port"] for r in pool.claims()] == [100, 101]
    pool.close()
    assert pool.claims() == ()
    pool.close()  # idempotent: a second close is a no-op, not an error
    assert pa.in_use(comm) == (7,)
    other.close()


def test_leaked_pool_emits_ft_leak_and_recovers_ports(ring8):
    _, comm = ring8
    pa = PortAllocator()
    pool = ChannelPool(comm, allocator=pa)
    pool.spec("decode.mlp")
    pool.spec("decode.attn")
    with obs.enabled(capacity=256) as tracer:
        del pool
        gc.collect()
        leaks = [e for e in tracer.events() if e["kind"] == "ft.leak"]
    assert len(leaks) == 1
    assert leaks[0]["attrs"]["ports"] == [100, 101]
    assert leaks[0]["attrs"]["n_claims"] == 2
    assert pa.in_use(comm) == ()  # __del__ recovered the claims
    # a CLOSED pool going out of scope is not a leak
    pool2 = ChannelPool(comm, allocator=pa)
    pool2.spec("decode.mlp")
    pool2.close()
    with obs.enabled(capacity=256) as tracer:
        del pool2
        gc.collect()
        assert [e for e in tracer.events() if e["kind"] == "ft.leak"] == []


# ---------------------------------------------------------------------------
# persistent claims: survival across del + gc (the serving lifecycle)
# ---------------------------------------------------------------------------


def test_persistent_claim_survives_del_and_gc(ring8):
    _, comm = ring8
    pa = PortAllocator()
    pool = ChannelPool(comm, allocator=pa)
    spec = pool.spec("decode.mlp")
    assert pa.in_use(comm) == (100,)
    # every compiled step that used the spec dies; the claim must not
    del spec
    gc.collect()
    assert pa.in_use(comm) == (100,)
    with pytest.raises(ValueError):
        pa.claim(comm, 100)
    pool.close()
    assert pa.in_use(comm) == ()


def test_diagnostic_str_carries_machine_fields():
    d = Diagnostic(rule="SMI104", message="window overrun",
                   rank=1, port=3, tag="tp.col", location="src/x.py:9")
    s = str(d)
    assert "SMI104" in s and "src/x.py:9" in s
    row = d.to_dict()
    assert row["rank"] == 1 and row["port"] == 3 and row["tag"] == "tp.col"
