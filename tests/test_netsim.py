"""netsim contract tests (DESIGN.md §6).

Three pillars:

* **exactness** — the simulator / stats predictor reproduces the *exact*
  step and byte counters a real traced transport tallies, for the static
  and packet backends, on the ring, the 2x4 torus and the snake-bus, with
  zero packet loss;
* **model sanity** — latency is nondecreasing in hops and effective
  bandwidth is nonincreasing in chunk-count overhead (the paper's Tab. 3 /
  Fig. 9 shapes), and contention/backpressure behave physically;
* **autotuner invariant** — across the swept (topology x size) grid the
  tuner never selects a plan the simulator scores worse than the static
  default, and the tuned dispatchers stay bit-identical to the reference
  schedules.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _chan import chan_bcast, chan_reduce
from jax.sharding import PartitionSpec as P

from repro.core import (
    Communicator,
    Topology,
    bcast,
    make_test_mesh,
    reduce,
    stream_allgather,
    stream_p2p,
)
from repro.core.router import snake_bus
from repro.netsim import (
    DEFAULT_PLAN,
    LinkModel,
    Message,
    Plan,
    TuningTable,
    autotune,
    collective_rounds,
    p2p_messages,
    predict_transport_stats,
    score_plan,
    simulate,
    simulate_rounds,
)
from repro.transport import get_transport

TOPOLOGIES = {
    "ring": lambda: (
        make_test_mesh((8,), ("x",)),
        Communicator.create("x", (8,), topology=Topology.ring(8)),
        P("x"),
    ),
    "torus": lambda: (
        make_test_mesh((2, 4), ("x", "y")),
        Communicator.create(("x", "y"), (2, 4)),
        P(("x", "y")),
    ),
    "snake_bus": lambda: (
        make_test_mesh((2, 4), ("x", "y")),
        Communicator.create(("x", "y"), (2, 4), topology=snake_bus((2, 4))),
        P(("x", "y")),
    ),
}


# ---------------------------------------------------------------------------
# exactness: simulator == TransportStats
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topo", sorted(TOPOLOGIES))
@pytest.mark.parametrize("backend", ["static", "packet"])
def test_sim_reproduces_transport_stats_p2p(topo, backend, devices8):
    mesh, comm, spec = TOPOLOGIES[topo]()
    shape, n_chunks, dst = (8, 16), 4, 5
    x = jnp.asarray(np.random.RandomState(0).randn(8, *shape), jnp.float32)
    t = get_transport(backend)

    def fn(v):
        y = stream_p2p(v[0], src=0, dst=dst, comm=comm, n_chunks=n_chunks,
                       transport=t)
        ovf = t.stats.overflow
        if ovf is None:
            ovf = jnp.zeros((), jnp.int32)
        return y[None], ovf[None]

    y, ovf = jax.jit(
        jax.shard_map(fn, mesh=mesh, in_specs=spec, out_specs=(spec, spec))
    )(x)
    assert int(np.asarray(ovf).sum()) == 0, "not a zero-loss run"
    np.testing.assert_array_equal(np.asarray(y)[dst], np.asarray(x)[0])

    steps, nbytes = predict_transport_stats(
        comm, "p2p", shape=shape, src=0, dst=dst, n_chunks=n_chunks,
        transport=backend,
    )
    assert t.stats.steps == steps, (
        f"{backend}@{topo}: simulated steps {steps} != traced {t.stats.steps}"
    )
    assert t.stats.bytes_moved == nbytes, (
        f"{backend}@{topo}: simulated bytes {nbytes} != "
        f"traced {t.stats.bytes_moved}"
    )
    # the stats -> calibration hook carries exactly these counters
    from repro.netsim import record_from_stats

    rec = record_from_stats(t.stats, 1e-3, "probe")
    assert rec["steps"] == steps and rec["bytes"] == nbytes
    assert rec["seconds"] == 1e-3 and rec["name"] == "probe"


@pytest.mark.parametrize("topo", ["ring"])
def test_sim_reproduces_transport_stats_allgather(topo, devices8):
    # ring only: on other topologies the simulator honestly charges the
    # linearised shift's wrap/cross edges their multi-hop routed cost,
    # while the static backend's trace-time counter is one step per
    # ppermute regardless — p2p exactness covers those topologies above
    mesh, comm, spec = TOPOLOGIES[topo]()
    shape = (4, 8)
    x = jnp.asarray(np.random.RandomState(1).randn(8, *shape), jnp.float32)
    t = get_transport("static")

    def fn(v):
        return stream_allgather(v[0], comm, transport=t)[None]

    jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec))(x)
    steps, nbytes = predict_transport_stats(
        comm, "allgather", shape=shape, transport="static"
    )
    assert t.stats.steps == steps
    assert t.stats.bytes_moved == nbytes


def test_sim_reproduces_transport_stats_packet_shift(devices8):
    mesh, comm, spec = TOPOLOGIES["ring"]()
    shape = (8, 8)
    x = jnp.asarray(np.random.RandomState(2).randn(8, *shape), jnp.float32)
    t = get_transport("packet")

    def fn(v):
        y = t.shift(v[0], comm)
        return y[None], t.stats.overflow[None]

    _, ovf = jax.jit(
        jax.shard_map(fn, mesh=mesh, in_specs=spec, out_specs=(spec, spec))
    )(x)
    assert int(np.asarray(ovf).sum()) == 0
    steps, nbytes = predict_transport_stats(
        comm, "shift", shape=shape, transport="packet"
    )
    assert t.stats.steps == steps
    assert t.stats.bytes_moved == nbytes


# ---------------------------------------------------------------------------
# simulator mechanics
# ---------------------------------------------------------------------------


def test_pipeline_ticks_and_byte_hops():
    topo = Topology.bus(8)
    from repro.core.routing import compute_route_table

    rt = compute_route_table(topo)
    for n_chunks in (1, 2, 8):
        for dst in (1, 4, 7):
            hops = rt.n_hops(0, dst)
            rep = simulate(topo, rt, p2p_messages(rt, 0, dst, 4096.0, n_chunks))
            assert rep.ticks == n_chunks + hops - 1
            assert rep.byte_hops == pytest.approx(4096.0 * hops)
            # a smoothly pipelining single flow parks at most the one
            # in-flight flit per hop (the paper's 1-deep pipe register)
            assert rep.congestion() <= 1


def test_contention_queues_and_backpressure():
    topo = Topology.bus(8)
    from repro.core.routing import compute_route_table

    rt = compute_route_table(topo)
    msgs = [
        Message(0, 4, n_flits=6, flit_bytes=64.0),
        Message(1, 4, n_flits=6, flit_bytes=64.0),
    ]
    solo = simulate(topo, rt, msgs[:1])
    both = simulate(topo, rt, msgs)
    assert both.ticks > solo.ticks          # shared links serialize
    assert both.ticks >= 12                 # bottleneck link moves 12 flits
    assert both.congestion() >= 1           # flits parked in transit
    tight = simulate(topo, rt, msgs, fifo_depth=1)
    assert tight.stalls > 0                 # backpressure engaged
    assert tight.ticks >= both.ticks        # and it can't be faster
    # occupancy: the shared edge (1, 2) carries both flows' flits
    assert both.link_busy[(1, 2)] == 12


def test_sticky_arbitration_and_switch_bubble():
    topo = Topology.bus(4)
    from repro.core.routing import compute_route_table

    rt = compute_route_table(topo)
    msgs = [
        Message(0, 3, n_flits=8, flit_bytes=32.0, port=0, pipelined=False),
        Message(0, 3, n_flits=8, flit_bytes=32.0, port=1, pipelined=False),
    ]
    free = simulate(topo, rt, msgs)
    r1 = simulate(topo, rt, msgs, R=1, switch_bubble=True)
    r16 = simulate(topo, rt, msgs, R=16, switch_bubble=True)
    # R=1 alternates sources every cycle and pays the bubble each time;
    # R=16 latches one FIFO and drains it — the paper's Tab. 4 trade-off
    assert r1.ticks > r16.ticks >= free.ticks


def test_model_monotonicity():
    m = LinkModel.default_v5e()
    # Tab. 3: latency nondecreasing in hops
    for nbytes in (1 << 10, 1 << 24):
        for n_chunks in (1, 8):
            times = [m.p2p_time(nbytes, h, n_chunks) for h in range(1, 9)]
            assert all(b >= a for a, b in zip(times, times[1:]))
    # chunk-count overhead: in the latency-bound regime every extra chunk
    # adds a tick, so effective bandwidth is nonincreasing in n_chunks
    bw = [m.bandwidth(1 << 10, 4, n) for n in (1, 2, 4, 8, 16, 32)]
    assert all(b <= a for a, b in zip(bw, bw[1:]))
    # and no chunking choice may beat the pure serialization bound
    for n in (1, 2, 4, 8, 16, 32):
        assert m.p2p_time(1 << 24, 4, n) >= m.serialization(1 << 24)
    # Tab. 4: injection cost falls with stickiness R
    cyc = [m.injection_cycles(R) for R in (1, 4, 8, 16)]
    assert all(b <= a for a, b in zip(cyc, cyc[1:]))
    assert cyc[0] > 1.0


def test_calibration_recovers_model():
    true = LinkModel(hop_latency=2e-6, link_bw=10e9, injection_base=5e-6)
    recs = []
    rng = np.random.RandomState(0)
    for steps, nbytes in [(1, 32), (4, 1 << 12), (7, 1 << 16), (19, 1 << 20)]:
        t = true.predict({"steps": steps, "bytes": nbytes})
        recs.append({"steps": steps, "bytes": float(nbytes),
                     "seconds": t * (1 + 0.05 * rng.randn())})
    fitted = LinkModel.fit(recs)
    for r in recs:
        ratio = fitted.predict(r) / true.predict(r)
        assert 0.5 < ratio < 2.0


# ---------------------------------------------------------------------------
# autotuner
# ---------------------------------------------------------------------------


TUNE_TOPOS = {
    "ring8": lambda: Topology.ring(8),
    "torus2x4": lambda: Topology.torus((2, 4)),
    "snake_bus": lambda: snake_bus((2, 4)),
}


@pytest.mark.parametrize("name", sorted(TUNE_TOPOS))
def test_autotuner_never_worse_than_static_default(name):
    """Acceptance invariant: across topology in {ring(8), torus(2,4),
    snake-bus} x size in {1KiB..16MiB}, the tuned plan's simulator score is
    never worse than the static default's."""
    topo = TUNE_TOPOS[name]()
    from repro.core.routing import compute_route_table

    rt = compute_route_table(topo)
    table = autotune(topo, rt)
    model = table.model
    for (op, size), e in table.entries.items():
        assert e["score"] <= e["static_score"] + 1e-18, (op, size, e)
        # re-score independently: the recorded numbers are reproducible
        plan = Plan(e["transport"], e["n_chunks"], e["algo"], e["wire"])
        assert score_plan(topo, rt, op, size, plan, model) == \
            pytest.approx(e["score"])
        default = DEFAULT_PLAN if op != "p2p" else Plan("static", 1, "routed")
        assert score_plan(topo, rt, op, size, default, model) == \
            pytest.approx(e["static_score"])


def test_autotuner_prefers_chunked_pipeline_for_large_messages():
    topo = Topology.ring(8)
    table = autotune(topo)
    small = table.lookup("bcast", 1 << 10)
    large = table.lookup("bcast", 16 << 20)
    assert small.n_chunks <= large.n_chunks
    assert large.n_chunks > 1  # pipelining must win when serialization-bound
    assert large.transport == "static"


def test_autotuner_selects_compressed_for_bandwidth_bound_only():
    """Acceptance invariant for the wire dimension (1x8 ring, default
    LinkModel): bcast and allreduce each get at least one bandwidth-bound
    cell on the int8 compressed wire, the smallest (latency-bound) cell
    never does, and every compressed pick realises a valid transport key."""
    table = autotune(Topology.ring(8))
    for op in ("bcast", "allreduce"):
        sizes = sorted({s for (o, s) in table.entries if o == op})
        wires = {s: table.entries[(op, s)]["wire"] for s in sizes}
        assert wires[sizes[0]] == "raw", (op, wires)
        assert "int8" in wires.values(), (op, wires)
        # compression must win a suffix of the size grid, not scattered
        # latency-bound cells: once int8 wins, larger sizes stay int8
        seen_int8 = False
        for s in sizes:
            if wires[s] == "int8":
                seen_int8 = True
            elif seen_int8:
                pytest.fail(f"{op}: raw cell {s} above a compressed cell")
        plan = table.lookup(op, sizes[-1])
        assert plan.wire == "int8"
        assert plan.transport_key.startswith("compressed:")
        from repro.transport import is_transport_key

        assert is_transport_key(plan.transport_key)
    # the rooted reduce re-quantises its travelling partial every hop (no
    # once-quantised schedule exists for it), so its cells must stay raw
    for (op, size), e in table.entries.items():
        if op == "reduce":
            assert e["wire"] == "raw", (size, e)


def test_tuning_table_json_roundtrip(tmp_path):
    table = autotune(Topology.ring(8), sizes=(1 << 10, 1 << 20))
    p = tmp_path / "tuning.json"
    table.save(str(p))
    back = TuningTable.load(str(p))
    assert back.topo_sig == table.topo_sig
    assert back.entries == table.entries
    assert back.lookup("p2p", 1 << 19).to_dict() == \
        table.lookup("p2p", 1 << 19).to_dict()


def test_tuned_dispatchers_bit_identical(devices8):
    """bcast()/reduce() with the tuned plan produce exactly what the
    reference schedules produce (plans change cost, never values)."""
    mesh, comm, spec = TOPOLOGIES["ring"]()
    x = jnp.asarray(np.random.RandomState(3).randn(8, 16, 4), jnp.float32)

    def tuned(v):
        return bcast(v[0], comm, root=0)[None], \
            reduce(v[0], comm, root=0)[None]

    def ref(v):
        return chan_bcast(v[0], comm, root=0)[None], \
            chan_reduce(v[0], comm, root=0)[None]

    got = jax.jit(jax.shard_map(
        tuned, mesh=mesh, in_specs=spec, out_specs=(spec, spec)))(x)
    want = jax.jit(jax.shard_map(
        ref, mesh=mesh, in_specs=spec, out_specs=(spec, spec)))(x)
    for g, w, nm in zip(got, want, ["bcast", "reduce"]):
        np.testing.assert_allclose(
            np.asarray(g), np.asarray(w), rtol=1e-6, atol=1e-6,
            err_msg=f"tuned {nm} diverged from reference")


def test_stream_p2p_auto_plan(devices8):
    mesh, comm, spec = TOPOLOGIES["snake_bus"]()
    x = jnp.asarray(np.random.RandomState(4).randn(8, 16, 4), jnp.float32)

    def fn(v):
        return stream_p2p(v[0], src=0, dst=5, comm=comm, plan="auto")[None]

    y = np.asarray(jax.jit(
        jax.shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec))(x))
    want = np.zeros_like(np.asarray(x))
    want[5] = np.asarray(x)[0]
    np.testing.assert_array_equal(y, want)


def test_communicator_plan_cached():
    comm = Communicator.create("x", (8,), topology=Topology.ring(8))
    p1 = comm.plan("allreduce", 1 << 20)
    p2 = comm.plan("allreduce", 1 << 20)
    assert p1 == p2
    assert isinstance(p1, Plan)


def test_tuning_cache_distinguishes_route_tables():
    """Same topology, different routing scheme -> different cache entries
    (plans are scored against routes, not just the connection graph)."""
    from repro.netsim.tune import tuning_table_for

    dor = Communicator.create("x", (8,))
    bfs = Communicator.create("x", (8,), routing_scheme="bfs")
    t_dor = tuning_table_for(dor.topology, dor.route_table)
    t_bfs = tuning_table_for(bfs.topology, bfs.route_table)
    assert t_dor.topo_sig != t_bfs.topo_sig
    assert t_dor is tuning_table_for(dor.topology, dor.route_table)  # cached


# ---------------------------------------------------------------------------
# collective schedule shapes (tick counts mirror core/collectives.py)
# ---------------------------------------------------------------------------


def test_collective_round_tick_counts():
    topo = Topology.ring(8)
    from repro.core.routing import compute_route_table

    rt = compute_route_table(topo)
    # chain bcast: n_chunks + P - 2 (the streamed-bcast schedule's steps)
    for nc in (1, 4, 16):
        ticks, _, _ = simulate_rounds(
            topo, rt, collective_rounds(topo, rt, "bcast", "ring", 4096.0,
                                        n_chunks=nc))
        assert ticks == nc + 8 - 2
    # ring allreduce: 2(P-1) single-tick permute rounds
    ticks, _, _ = simulate_rounds(
        topo, rt, collective_rounds(topo, rt, "allreduce", "ring", 4096.0))
    assert ticks == 2 * 7
    # binomial tree: ceil(log2 P) rounds, each >= 1 tick
    rounds = collective_rounds(topo, rt, "bcast", "tree", 4096.0)
    assert len(rounds) == 3


# ---------------------------------------------------------------------------
# fused fast path in the tuner + delivery-buffer bound in the simulator
# ---------------------------------------------------------------------------


def test_autotuner_selects_fused_for_reducing_ops_only():
    """The fused backend runs the identical static schedules minus the
    per-tick unfused-add cost, so it must win every raw reduce/allreduce
    cell — and must NOT displace static on ops with no accumulate (ties
    keep the default via the strict-< argmin)."""
    table = autotune(Topology.ring(8))
    for (op, size), e in table.entries.items():
        if op in ("reduce", "allreduce") and e["wire"] == "raw":
            assert e["transport"] == "fused", (op, size, e)
        if op in ("p2p", "bcast", "halo"):
            assert e["transport"] != "fused", (op, size, e)


def test_tuning_table_json_carries_unfused_add_latency(tmp_path):
    table = autotune(Topology.ring(8), sizes=(1 << 12,))
    p = tmp_path / "t.json"
    table.save(str(p))
    back = TuningTable.load(str(p))
    assert back.model.unfused_add_latency == table.model.unfused_add_latency
    # older tables without the key still load (field default applies)
    import json as _json

    spec = _json.loads(table.to_json())
    del spec["model"]["unfused_add_latency"]
    legacy = TuningTable.from_json(_json.dumps(spec))
    assert legacy.model.unfused_add_latency == LinkModel().unfused_add_latency


def test_sim_out_cap_counts_delivery_drops():
    """An undersized (rank, port) delivery buffer drops the surplus flits
    and reports them — the simulator-side mirror of the device router's
    out_cap overrun semantics."""
    topo = Topology.ring(8)
    from repro.core.routing import compute_route_table

    rt = compute_route_table(topo)
    # three senders, one flit each, all delivering to rank 0 / port 0
    msgs = [Message(src=s, dst=0, n_flits=1, flit_bytes=64.0)
            for s in (1, 2, 3)]
    free = simulate(topo, rt, msgs)
    assert free.dropped == 0
    tight = simulate(topo, rt, msgs, out_cap=1)
    assert tight.dropped == 2
    # drops never stall completion: every message still reports done
    assert all(d >= 0 for d in tight.msg_done)
