"""Observability layer tests (DESIGN.md §11): tracer schema + export
roundtrip, the disabled tracer's zero-cost hot path, metrics snapshots
matching live TransportStats to the byte, the netsim predicted-overlay
adapter, drift gauges agreeing with ``calibrate.validate``, and the
producer instrumentation across channels / router / tuner / ft."""

import gc
import json
import tracemalloc

import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.channels import ChannelSpec
from repro.core import (
    Communicator,
    Topology,
    make_test_mesh,
    open_channel,
    run_spmd,
)
from repro.netsim import calibrate, predict_channel_stats
from repro.netsim.schedule import halo_rounds
from repro.netsim.sim import simulate
from repro.obs import trace as obs
from repro.obs.export import (
    PID_SIM_LINKS,
    directed_links,
    lane_count,
    parse_chrome_trace,
    sim_report_events,
    to_chrome_trace,
)
from repro.obs.metrics import MetricsRegistry
from repro.transport import get_transport


@pytest.fixture(autouse=True)
def _no_leaked_tracer():
    """Every test leaves the process-wide tracer disabled."""
    yield
    obs.disable()


@pytest.fixture(scope="module")
def ring8():
    mesh = make_test_mesh((8,), ("x",))
    comm = Communicator.create("x", (8,))
    return mesh, comm


@pytest.fixture(scope="module")
def torus24():
    mesh = make_test_mesh((2, 4), ("x", "y"))
    comm = Communicator.create(("x", "y"), (2, 4))
    return mesh, comm


# ---------------------------------------------------------------------------
# tracer + chrome export
# ---------------------------------------------------------------------------


def _fake_clock():
    t = [0.0]

    def clock():
        t[0] += 0.5
        return t[0]

    return clock


def test_event_schema_roundtrip():
    """export -> json -> parse recovers the schema events identically."""
    tr = obs.Tracer(capacity=64, clock=_fake_clock())
    tr.event("channel.open", tag="halo", port=3, src=0, dst=5)
    tr.event("run.step", rank=2, step=1, dur=0.25)
    tr.event("sim.flit", ts=1.5, link=[0, 1], dur=0.1, msg=0)
    tr.event("router.overflow", tag=None, counter="stats.overflow")
    events = tr.events()
    assert all(tuple(e.keys()) == obs.EVENT_KEYS for e in events)
    doc = json.loads(json.dumps(to_chrome_trace(events)))
    assert parse_chrome_trace(doc) == events
    # viewer records carry the expected phases: dur -> "X", else instant
    body = [r for r in doc["traceEvents"] if r["ph"] != "M"]
    assert [r["ph"] for r in body] == ["i", "X", "X", "i"]


def test_tracer_ring_buffer_bounded():
    tr = obs.Tracer(capacity=4, clock=_fake_clock())
    for i in range(10):
        tr.event("k", i=i)
    assert len(tr) == 4
    assert [e["attrs"]["i"] for e in tr.events()] == [6, 7, 8, 9]


def test_enabled_context_restores_previous():
    assert obs.get() is None and obs.TRACING is False
    with obs.enabled(capacity=16) as tr:
        assert obs.get() is tr and obs.TRACING is True
        obs.emit("k")
    assert obs.get() is None and obs.TRACING is False
    assert len(tr) == 1  # events stay readable after the block


def test_disabled_tracer_records_nothing_and_allocates_nothing():
    """The hot-path contract: with tracing off, the guarded call-site
    pattern records no events and allocates no objects per call."""
    assert obs.TRACING is False

    def hot(n):
        for _ in range(n):
            if obs.TRACING:
                obs.emit("channel.push", tag="t", port=0, src=1)

    hot(1000)  # warm everything (bytecode caches, the range type)
    gc.collect()
    tracemalloc.start()
    try:
        snap1 = tracemalloc.take_snapshot()
        hot(10_000)
        snap2 = tracemalloc.take_snapshot()
    finally:
        tracemalloc.stop()
    flt = [tracemalloc.Filter(True, __file__)]
    grown = sum(
        d.size_diff
        for d in snap2.filter_traces(flt).compare_to(
            snap1.filter_traces(flt), "lineno")
        if d.size_diff > 0
    )
    # zero per-call allocations: 10k guarded calls must not grow this
    # file's traced allocations beyond interpreter noise
    assert grown < 512, f"disabled tracer leaked {grown}B over 10k calls"
    with obs.enabled() as tr:
        pass
    assert len(tr) == 0


# ---------------------------------------------------------------------------
# metrics registry vs live TransportStats
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["static", "packet", "compressed"])
@pytest.mark.parametrize("fix", ["ring8", "torus24"])
def test_metrics_snapshot_matches_transport_stats(request, fix, backend):
    """The snapshot's per-tag counters equal netsim's prediction to the
    byte — the same oracle the channel tests gate on, read through the
    metrics registry."""
    mesh, comm = request.getfixturevalue(fix)
    spec_in = P("x") if fix == "ring8" else P(("x", "y"))
    t = get_transport(backend)
    shape, n_chunks, dst = (32,), 4, comm.size - 1
    x = jnp.asarray(
        np.random.RandomState(0).randn(comm.size, *shape), jnp.float32
    )

    def fn(v):
        ch = open_channel(comm, src=0, dst=dst, port=None, transport=t,
                          n_chunks=n_chunks, tag="obs")
        return ch.transfer(v[0])[None]

    run_spmd(fn, mesh, spec_in, spec_in, x)

    reg = MetricsRegistry()
    reg.track("p2p", t)
    snap = reg.snapshot()["transports"]["p2p"]
    spec = ChannelSpec(comm=comm, kind="p2p", src=0, dst=dst, port=None,
                       transport=backend, n_chunks=n_chunks, tag="obs")
    steps, nbytes = predict_channel_stats(spec, shape=shape)
    assert snap["by_tag"]["obs"] == {"steps": steps, "bytes": nbytes}
    assert snap["steps"] == int(t.stats.steps)
    assert snap["bytes"] == int(t.stats.bytes_moved)
    # the snapshot is JSON-safe as-is (traced overflow reads as None)
    json.dumps(reg.snapshot())


def test_metrics_counters_and_gauges():
    reg = MetricsRegistry()
    reg.inc("runs")
    reg.inc("runs", 2)
    reg.gauge("wall_s", 0.125)
    snap = reg.snapshot()
    assert snap["counters"] == {"runs": 3}
    assert snap["gauges"] == {"wall_s": 0.125}
    reg.clear()
    assert reg.snapshot() == {"counters": {}, "gauges": {}, "transports": {}}


# ---------------------------------------------------------------------------
# netsim adapter: the predicted overlay
# ---------------------------------------------------------------------------


def test_sim_adapter_lane_count_equals_link_count(torus24):
    """One viewer lane per directed topology link — idle links included."""
    _, comm = torus24
    topo = comm.topology
    reports = [
        simulate(topo, comm.route_table, msgs, trace=True)
        for msgs in halo_rounds((2, 4), 256.0, 256.0)
    ]
    assert all(rep.moves for rep in reports)
    events = sim_report_events(topo, reports)
    doc = to_chrome_trace(events)
    assert lane_count(doc, PID_SIM_LINKS) == len(directed_links(topo))
    # flits land on link lanes, deliveries on sim rank lanes
    kinds = {e["kind"] for e in events}
    assert {"sim.lane", "sim.flit", "sim.deliver"} <= kinds


def test_simulate_trace_off_records_no_moves():
    topo = Topology.ring(4)
    comm = Communicator.create("x", (4,), topology=topo)
    msgs = halo_rounds((1, 4), 64.0, 64.0)[0]
    assert simulate(topo, comm.route_table, msgs).moves == []
    rep = simulate(topo, comm.route_table, msgs, trace=True)
    # every flit-hop is logged exactly once
    assert len(rep.moves) == rep.flit_hops


def test_sim_rounds_laid_out_back_to_back(torus24):
    _, comm = torus24
    reports = [
        simulate(comm.topology, comm.route_table, msgs, trace=True)
        for msgs in halo_rounds((2, 4), 128.0, 128.0)
    ]
    events = sim_report_events(comm.topology, reports)
    flits = [e for e in events if e["kind"] == "sim.flit"]
    dt = flits[0]["attrs"]["dur"]
    # the last round's flits start after the earlier rounds' tick spans
    offset = sum(r.ticks for r in reports[:-1]) * dt
    assert max(e["ts"] for e in flits) >= offset


# ---------------------------------------------------------------------------
# drift gauges vs calibrate.validate
# ---------------------------------------------------------------------------


def test_drift_gauge_matches_validate_ratio():
    records = [
        calibrate.record(4, 1024.0, 1.0e-5, "a"),
        calibrate.record(8, 4096.0, 5.0e-5, "b"),
        calibrate.record(16, 65536.0, 3.0e-4, "c"),
    ]
    m, worst = calibrate.validate(records, tol=1e9, label="obs_test")
    reg = MetricsRegistry()
    got = reg.drift_from_records("obs_test", records, model=m)
    # identical formula (calibrate.drift_ratio), so exact equality holds
    assert got == worst
    assert reg.gauges["drift/obs_test"] == worst
    for r in records:
        ratio = reg.gauges[f"drift/obs_test/{r['name']}"]
        assert ratio == calibrate.drift_ratio(m.predict(r), r["seconds"])


def test_drift_gauge_symmetric():
    reg = MetricsRegistry()
    assert reg.drift("x", predicted=2.0, measured=1.0) == 2.0
    assert reg.drift("y", predicted=1.0, measured=2.0) == 2.0
    assert reg.drift("z", predicted=0.5, measured=0.5) == 1.0


# ---------------------------------------------------------------------------
# producer instrumentation
# ---------------------------------------------------------------------------


def test_channel_events_emitted(ring8):
    mesh, comm = ring8
    x = jnp.ones((8, 16), jnp.float32)

    with obs.enabled() as tr:
        # fresh lambda: a fresh jit cache entry, so the channel re-traces
        run_spmd(
            lambda v: open_channel(comm, src=0, dst=3, port=None, tag="qq",
                                   n_chunks=2).transfer(v[0])[None],
            mesh, P("x"), P("x"), x,
        )
        kinds = tr.kinds()
        tagged = {e["tag"] for e in tr.events()}
    assert {"channel.open", "channel.transfer.start",
            "channel.transfer.finish"} <= kinds
    assert "qq" in tagged


def test_router_events_emitted(ring8):
    mesh, comm = ring8
    t = get_transport("packet")
    x = jnp.ones((8, 16), jnp.float32)

    with obs.enabled() as tr:
        run_spmd(
            lambda v: t.p2p(v[0], src=0, dst=3, comm=comm)[None],
            mesh, P("x"), P("x"), x,
        )
        kinds = tr.kinds()
    assert {"router.run", "router.overflow"} <= kinds


def test_tuner_plan_events_emitted():
    from repro.netsim.tune import autotune

    with obs.enabled() as tr:
        autotune(Topology.ring(4), ops=("bcast",), sizes=(1024,))
        plans = [e for e in tr.events() if e["kind"] == "tuner.plan"]
    assert len(plans) == 1
    ev = plans[0]
    assert ev["tag"] == "bcast" and ev["attrs"]["nbytes"] == 1024
    assert "transport" in ev["attrs"] and "score" in ev["attrs"]


def test_ft_events_emitted(monkeypatch):
    from repro.ft.watchdog import StepWatchdog, run_with_restarts

    now = [100.0]
    monkeypatch.setattr("repro.ft.watchdog.time.monotonic", lambda: now[0])
    with obs.enabled() as tr:
        wd = StepWatchdog(threshold=3.0, alpha=0.1)
        wd.start()
        for i, dt in enumerate([1.0] * 3 + [10.0]):
            now[0] += dt
            wd.lap(step=i)

        class _Ckpt:
            def restore(self, state_like):
                return {"w": 1}, {"step": 5}

        calls = []

        def loop(state, step):
            calls.append(step)
            if len(calls) == 1:
                raise RuntimeError("boom")
            return state

        run_with_restarts(loop, _Ckpt(), {"w": 0}, max_restarts=1)
        events = tr.events()
    stragglers = [e for e in events if e["kind"] == "ft.straggler"]
    restarts = [e for e in events if e["kind"] == "ft.restart"]
    assert len(stragglers) == 1 and stragglers[0]["attrs"]["step"] == 3
    assert len(restarts) == 1 and restarts[0]["attrs"]["resume_step"] == 5
