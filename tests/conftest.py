"""Test configuration.

Multi-device SMI tests need >1 device, so the suite runs with 8 host
placeholder devices (the paper's 8-FPGA testbed size).  This is deliberately
NOT the 512-device production mesh — that count is reserved for
``launch/dryrun.py`` per its contract; smoke tests and reference checks here
only assume ``jax.device_count() >= 1`` and build small meshes explicitly.
"""

import os

# Must run before jax initializes its backends (first jax import in-session).
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402

import pytest  # noqa: E402


@pytest.fixture(scope="session")
def devices8():
    if jax.device_count() < 8:
        pytest.skip("needs 8 host devices")
    return jax.devices()[:8]
