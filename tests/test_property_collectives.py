"""Hypothesis property tests on system invariants (deliverable c):
algebraic laws the streamed collectives must satisfy for any data."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _chan import chan_allreduce, chan_gather, chan_scatter
from _hyp import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.core import (
    Communicator,
    make_test_mesh,
    run_spmd,
    stream_allgather,
    stream_alltoall,
    stream_p2p,
    stream_reduce_scatter,
)

PP = 8


@pytest.fixture(scope="module")
def ring8():
    return make_test_mesh((PP,), ("x",)), Communicator.create("x", (PP,))


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), m=st.integers(1, 4))
def test_property_rs_then_ag_is_allreduce(seed, m):
    """reduce_scatter ∘ all_gather == all_reduce (the ring identity)."""
    mesh, comm = make_test_mesh((PP,), ("x",)), Communicator.create("x", (PP,))
    rng = np.random.RandomState(seed)
    x = rng.randn(PP, PP * m, 3).astype(np.float32)

    def fn(v):
        rs = stream_reduce_scatter(v[0], comm)
        ag = stream_allgather(rs, comm)
        ar = chan_allreduce(v[0], comm)
        return ag[None], ar[None]

    ag, ar = run_spmd(fn, mesh, P("x"), (P("x"), P("x")), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(ag), np.asarray(ar), rtol=1e-5,
                               atol=1e-5)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000))
def test_property_alltoall_involution(seed):
    """alltoall(alltoall(x)) == x (transpose is an involution)."""
    mesh, comm = make_test_mesh((PP,), ("x",)), Communicator.create("x", (PP,))
    rng = np.random.RandomState(seed)
    x = rng.randn(PP, PP, 2, 2).astype(np.float32)

    def fn(v):
        return stream_alltoall(stream_alltoall(v[0], comm), comm)[None]

    y = run_spmd(fn, mesh, P("x"), P("x"), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(y), x, rtol=1e-6)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 1000), root=st.integers(0, PP - 1))
def test_property_scatter_gather_roundtrip(seed, root):
    """gather(scatter(x)) == x at the root, for any root."""
    mesh, comm = make_test_mesh((PP,), ("x",)), Communicator.create("x", (PP,))
    rng = np.random.RandomState(seed)
    full = rng.randn(PP * 3, 2).astype(np.float32)

    def fn(v):
        mine = chan_scatter(v, comm, root=root)
        back = chan_gather(mine, comm, root=root)
        return back[None]

    y = run_spmd(fn, mesh, P(None), P("x"), jnp.asarray(full))
    got = np.asarray(y).reshape(PP, PP * 3, 2)[root]
    np.testing.assert_allclose(got, full, rtol=1e-6)


@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(0, 1000),
    src=st.integers(0, PP - 1),
    dst=st.integers(0, PP - 1),
    n_chunks=st.sampled_from([1, 2, 4]),
)
def test_property_p2p_chunk_invariance(seed, src, dst, n_chunks):
    """Chunk count is an optimisation parameter: it never changes payload
    (the paper's buffer-size correctness rule, §3.3/§4.2)."""
    mesh, comm = make_test_mesh((PP,), ("x",)), Communicator.create("x", (PP,))
    rng = np.random.RandomState(seed)
    x = rng.randn(PP, 8, 2).astype(np.float32)

    def fn(v):
        return stream_p2p(v[0], src=src, dst=dst, comm=comm,
                          n_chunks=n_chunks)[None]

    y = run_spmd(fn, mesh, P("x"), P("x"), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(y)[dst], x[src], rtol=1e-6)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 1000))
def test_property_allreduce_linearity(seed):
    """AR(a + b) == AR(a) + AR(b) (reduction is linear)."""
    mesh, comm = make_test_mesh((PP,), ("x",)), Communicator.create("x", (PP,))
    rng = np.random.RandomState(seed)
    a = rng.randn(PP, 6).astype(np.float32)
    b = rng.randn(PP, 6).astype(np.float32)

    def fn(u, v):
        lhs = chan_allreduce(u[0] + v[0], comm)
        rhs = chan_allreduce(u[0], comm) + chan_allreduce(v[0], comm)
        return lhs[None], rhs[None]

    lhs, rhs = run_spmd(fn, mesh, (P("x"), P("x")), (P("x"), P("x")),
                        jnp.asarray(a), jnp.asarray(b))
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-5,
                               atol=1e-5)
