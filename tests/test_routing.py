"""Topology + route-generator unit and property tests (paper §4.3)."""

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core import (
    Topology,
    compute_route_table,
    channel_dependency_acyclic,
    physical_link_map,
)


def test_torus_2d_links():
    t = Topology.torus((2, 4))
    assert t.n_ranks == 8
    # rank 0 = (0,0): +x -> (1,0)=4, +y -> (0,1)=1, -y -> (0,3)=3
    assert set(t.neighbors(0)) == {4, 1, 3}
    assert t.is_connected()
    # paper setup: every FPGA wired to 4 distinct others in the 8-node torus
    t8 = Topology.torus((2, 4))
    assert all(len(t8.neighbors(r)) <= 4 for r in range(8))


def test_bus_topology():
    b = Topology.bus(8)
    assert b.neighbors(0) == (1,)
    assert b.neighbors(7) == (6,)
    assert b.neighbors(3) == (4, 2)
    assert b.is_connected()
    assert b.diameter() == 7


def test_ring_vs_bus_diameter():
    assert Topology.ring(8).diameter() == 4
    assert Topology.bus(8).diameter() == 7


def test_json_roundtrip():
    t = Topology.torus((2, 4))
    s = t.to_json()
    t2 = Topology.from_json(s)
    assert t2.n_ranks == t.n_ranks
    for r in range(t.n_ranks):
        assert set(t2.neighbors(r)) == set(t.neighbors(r))


def test_dor_paths_valid_torus():
    t = Topology.torus((4, 4))
    rt = compute_route_table(t)
    for s in range(16):
        for d in range(16):
            p = rt.path(s, d)
            assert p[0] == s and p[-1] == d
            for a, b in zip(p[:-1], p[1:]):
                assert b in t.neighbors(a), f"hop {a}->{b} not a link"
            assert len(p) - 1 <= t.diameter()


def test_dor_is_shortest_on_torus():
    from repro.core.routing import bfs_dists

    t = Topology.torus((2, 4))
    rt = compute_route_table(t)
    for s in range(8):
        dist = bfs_dists(t, s)
        for d in range(8):
            assert rt.n_hops(s, d) == dist[d]


def test_deadlock_analysis():
    """Dally–Seitz CDG analysis.

    Wrap-around DOR on a torus has *cyclic* channel dependencies (the classic
    result — wormhole routers need virtual channels/datelines); the checker
    must detect that.  Acyclic cases (bus, no-wrap paths) must pass.  Our
    static ppermute schedules are globally synchronous (TDM over links), so
    they are deadlock-free regardless — the CDG check guards the *dynamic*
    router when given non-torus custom tables (see core/router.py docs).
    """
    rt_torus = compute_route_table(Topology.torus((4, 4)))
    assert not channel_dependency_acyclic(rt_torus)  # wrap cycles detected
    rt_bus = compute_route_table(Topology.bus(8))
    assert channel_dependency_acyclic(rt_bus)


def test_bfs_routes_on_bus():
    b = Topology.bus(8)
    rt = compute_route_table(b)
    assert rt.path(0, 7) == list(range(8))
    assert rt.path(5, 2) == [5, 4, 3, 2]
    assert channel_dependency_acyclic(rt)


def test_route_recompute_without_rebuild():
    """Paper: change topology => recompute tables only."""
    t = Topology.torus((2, 4))
    rt_torus = compute_route_table(t)
    rt_bus = compute_route_table(Topology.bus(8))
    # 0 -> 5: short on torus, long on bus
    assert rt_torus.n_hops(0, 5) < rt_bus.n_hops(0, 5)


def test_physical_link_map():
    m = physical_link_map((2, 4))
    # (0,0)->(0,1) is +1 in dim 1 => link id 2
    assert m[(0, 1)] == 2
    # (0,1)->(0,0) is -1 in dim 1 => link id 3
    assert m[(1, 0)] == 3
    # dim 0 has size 2: +1 and -1 coincide; entry exists
    assert (0, 4) in m


@settings(max_examples=25, deadline=None)
@given(
    dx=st.sampled_from([2, 3, 4]),
    dy=st.sampled_from([2, 3, 4, 5]),
    data=st.data(),
)
def test_property_dor_valid_and_minimal(dx, dy, data):
    from repro.core.routing import bfs_dists

    t = Topology.torus((dx, dy))
    rt = compute_route_table(t)
    s = data.draw(st.integers(0, t.n_ranks - 1))
    d = data.draw(st.integers(0, t.n_ranks - 1))
    p = rt.path(s, d)
    assert p[0] == s and p[-1] == d
    assert len(set(p)) == len(p), "path revisits a rank"
    for a, b in zip(p[:-1], p[1:]):
        assert b in t.neighbors(a)
    assert len(p) - 1 == bfs_dists(t, s)[d]


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 12), data=st.data())
def test_property_random_graph_routes(n, data):
    # random connected graph: start from a path, add random extra edges
    extra = data.draw(
        st.lists(
            st.tuples(st.integers(0, n - 1), st.integers(0, n - 1)),
            max_size=8,
        )
    )
    edges = [(i, i + 1) for i in range(n - 1)]
    edges += [(a, b) for a, b in extra if a != b]
    t = Topology.from_edges(n, edges)
    rt = compute_route_table(t)
    for s in range(n):
        for d in range(n):
            p = rt.path(s, d)
            assert p[0] == s and p[-1] == d
            for a, b in zip(p[:-1], p[1:]):
                assert b in t.neighbors(a)
