"""Collective-compute overlap engine vs oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import Communicator, make_test_mesh, run_spmd
from repro.core.overlap import (
    halo_exchange_2d,
    stream_allgather_matmul,
    stream_matmul_reducescatter,
    stream_ring_attention,
)

PP = 8


@pytest.fixture(scope="module")
def ring8():
    mesh = make_test_mesh((PP,), ("x",))
    comm = Communicator.create("x", (PP,))
    return mesh, comm


@pytest.mark.parametrize("bidir", [False, True])
def test_allgather_matmul(ring8, bidir):
    mesh, comm = ring8
    rng = np.random.RandomState(0)
    x = rng.randn(PP * 4, 16).astype(np.float32)     # rows sharded
    w = rng.randn(PP, 16, 8).astype(np.float32)      # per-rank column shard

    def fn(xs, ws):
        y = stream_allgather_matmul(xs, ws[0], comm, bidir=bidir)
        return y[None]

    y = run_spmd(fn, mesh, (P("x"), P("x")), P("x"), jnp.asarray(x), jnp.asarray(w))
    # rank r computes full_x @ w[r]
    for r in range(PP):
        want = x @ w[r]
        np.testing.assert_allclose(np.asarray(y[r]), want, rtol=2e-4, atol=1e-4)


def test_matmul_reducescatter(ring8):
    mesh, comm = ring8
    rng = np.random.RandomState(1)
    # global X: (M, K) with K sharded; W: (K, N) row-sharded to match
    M, K, N = PP * 3, PP * 4, 5
    X = rng.randn(M, K).astype(np.float32)
    W = rng.randn(K, N).astype(np.float32)
    want = X @ W  # (M, N); rank r should get rows [3r:3r+3]

    Xs = X.reshape(M, PP, 4).transpose(1, 0, 2)  # (P, M, K_local)
    Ws = W.reshape(PP, 4, N)

    def fn(xs, ws):
        y = stream_matmul_reducescatter(xs[0], ws[0], comm)
        return y[None]

    y = run_spmd(
        fn, mesh, (P("x"), P("x")), P("x"),
        jnp.asarray(Xs), jnp.asarray(Ws),
    )
    np.testing.assert_allclose(np.asarray(y).reshape(M, N), want, rtol=2e-4, atol=1e-4)


@pytest.mark.parametrize("causal", [True, False])
def test_ring_attention_matches_full(ring8, causal):
    mesh, comm = ring8
    rng = np.random.RandomState(2)
    B, S, H, Hkv, D = 2, PP * 4, 4, 2, 8
    q = rng.randn(B, S, H, D).astype(np.float32) * 0.3
    k = rng.randn(B, S, Hkv, D).astype(np.float32) * 0.3
    v = rng.randn(B, S, Hkv, D).astype(np.float32) * 0.3

    # oracle: full attention
    g = H // Hkv
    kf = np.repeat(k, g, axis=2)
    vf = np.repeat(v, g, axis=2)
    scale = D ** -0.5
    s = np.einsum("bqhd,bkhd->bhqk", q * scale, kf)
    if causal:
        mask = np.tril(np.ones((S, S), bool))
        s = np.where(mask[None, None], s, -np.inf)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    want = np.einsum("bhqk,bkhd->bqhd", p, vf)

    qs = q.reshape(B, PP, 4, H, D).transpose(1, 0, 2, 3, 4)  # (P, B, Sq, H, D)
    ks = k.reshape(B, PP, 4, Hkv, D).transpose(1, 0, 2, 3, 4)
    vs = v.reshape(B, PP, 4, Hkv, D).transpose(1, 0, 2, 3, 4)

    def fn(qq, kk, vv):
        o = stream_ring_attention(qq[0], kk[0], vv[0], comm, causal=causal)
        return o[None]

    o = run_spmd(
        fn, mesh, (P("x"), P("x"), P("x")), P("x"),
        jnp.asarray(qs), jnp.asarray(ks), jnp.asarray(vs),
    )
    got = np.asarray(o).transpose(1, 0, 2, 3, 4).reshape(B, S, H, D)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_ring_attention_local_window(ring8):
    mesh, comm = ring8
    rng = np.random.RandomState(3)
    B, S, H, D = 1, PP * 4, 2, 4
    W = 8  # window
    q = rng.randn(B, S, H, D).astype(np.float32) * 0.3
    k = rng.randn(B, S, H, D).astype(np.float32) * 0.3
    v = rng.randn(B, S, H, D).astype(np.float32) * 0.3

    scale = D ** -0.5
    s = np.einsum("bqhd,bkhd->bhqk", q * scale, k)
    qpos = np.arange(S)[:, None]
    kpos = np.arange(S)[None, :]
    mask = (qpos >= kpos) & (qpos - kpos < W)
    s = np.where(mask[None, None], s, -np.inf)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    want = np.einsum("bhqk,bkhd->bqhd", p, v)

    rs = lambda a: a.reshape(B, PP, 4, H, D).transpose(1, 0, 2, 3, 4)

    def fn(qq, kk, vv):
        o = stream_ring_attention(qq[0], kk[0], vv[0], comm, causal=True, local_window=W)
        return o[None]

    o = run_spmd(
        fn, mesh, (P("x"), P("x"), P("x")), P("x"),
        jnp.asarray(rs(q)), jnp.asarray(rs(k)), jnp.asarray(rs(v)),
    )
    got = np.asarray(o).transpose(1, 0, 2, 3, 4).reshape(B, S, H, D)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_halo_exchange_2d():
    mesh = make_test_mesh((2, 4), ("gx", "gy"))
    comm = Communicator.create(("gx", "gy"), (2, 4))
    RX, RY = 2, 4
    nx, ny = 4, 4
    rng = np.random.RandomState(4)
    world = rng.randn(RX * nx, RY * ny).astype(np.float32)

    tiles = np.zeros((RX * RY, nx, ny), np.float32)
    for rx in range(RX):
        for ry in range(RY):
            tiles[rx * RY + ry] = world[rx * nx:(rx + 1) * nx, ry * ny:(ry + 1) * ny]

    def fn(t):
        return halo_exchange_2d(t[0], comm, grid=(RX, RY), halo=(1, 1))[None]

    out = run_spmd(fn, mesh, P(("gx", "gy")), P(("gx", "gy")), jnp.asarray(tiles))
    out = np.asarray(out)
    for rx in range(RX):
        for ry in range(RY):
            o = out[rx * RY + ry]
            np.testing.assert_allclose(o[1:-1, 1:-1], tiles[rx * RY + ry])
            # interior halos match the neighbouring tile rows/cols
            if rx > 0:
                np.testing.assert_allclose(o[0, 1:-1], world[rx * nx - 1, ry * ny:(ry + 1) * ny])
            else:
                assert np.all(o[0] == 0)
            if rx < RX - 1:
                np.testing.assert_allclose(o[-1, 1:-1], world[(rx + 1) * nx, ry * ny:(ry + 1) * ny])
            if ry > 0:
                np.testing.assert_allclose(o[1:-1, 0], world[rx * nx:(rx + 1) * nx, ry * ny - 1])
            if ry < RY - 1:
                np.testing.assert_allclose(o[1:-1, -1], world[rx * nx:(rx + 1) * nx, (ry + 1) * ny])
