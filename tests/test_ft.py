"""Fault-tolerance layer tests: straggler watchdog, restart driver, elastic
re-meshing (`repro/ft` was previously untested)."""

import jax
import numpy as np
import pytest

from repro.ft.elastic import (
    best_mesh_shape,
    elastic_restart_plan,
    reshard_state,
)
from repro.ft.watchdog import StepWatchdog, run_with_restarts


# ---------------------------------------------------------------------------
# StepWatchdog
# ---------------------------------------------------------------------------


def _drive(watchdog, durations, monkeypatch):
    """Feed a deterministic step-time sequence through lap()."""
    now = [100.0]

    def fake_monotonic():
        return now[0]

    monkeypatch.setattr("repro.ft.watchdog.time.monotonic", fake_monotonic)
    watchdog.start()
    flags = []
    for i, dt in enumerate(durations):
        now[0] += dt
        flags.append(watchdog.lap(step=i))
    return flags


def test_watchdog_flags_straggler(monkeypatch):
    wd = StepWatchdog(threshold=3.0, alpha=0.1)
    # steady 1s steps, then a 10s straggler, then recovery
    flags = _drive(wd, [1.0] * 5 + [10.0] + [1.0] * 3, monkeypatch)
    assert flags[:5] == [False] * 5
    assert flags[5] is True
    assert len(wd.events) == 1
    ev = wd.events[0]
    assert ev["step"] == 5 and ev["dt"] == pytest.approx(10.0)
    # ema keeps tracking after the event (no permanent poisoning)
    assert wd.ema is not None and wd.ema < 10.0


def test_watchdog_first_step_never_flags(monkeypatch):
    wd = StepWatchdog()
    flags = _drive(wd, [100.0], monkeypatch)
    assert flags == [False]  # no ema yet -> nothing to compare against
    assert wd.ema == pytest.approx(100.0)


def test_watchdog_ema_update(monkeypatch):
    wd = StepWatchdog(alpha=0.5)
    _drive(wd, [2.0, 4.0], monkeypatch)
    # ema = 2.0 then 0.5*2 + 0.5*4 = 3.0
    assert wd.ema == pytest.approx(3.0)


def test_watchdog_lap_before_start(monkeypatch):
    """lap() before start() must not seed the EMA with a zero interval.

    Regression: the first lap used to compute dt against an unset timer,
    seeding ema=0.0 — after which *every* subsequent step exceeded
    threshold*ema and was flagged a straggler."""
    now = [100.0]
    monkeypatch.setattr("repro.ft.watchdog.time.monotonic", lambda: now[0])
    wd = StepWatchdog(threshold=3.0, alpha=0.1)
    flags = []
    for i in range(5):  # note: no start() call
        now[0] += 1.0
        flags.append(wd.lap(step=i))
    assert flags == [False] * 5
    assert wd.events == []
    # the un-started first lap arms the timer; the ema seeds from the
    # first *real* interval, not from dt=0
    assert wd.ema == pytest.approx(1.0)


# ---------------------------------------------------------------------------
# run_with_restarts
# ---------------------------------------------------------------------------


class _FakeCheckpointer:
    """Restores a fixed (state, manifest) pair and counts restores."""

    def __init__(self, state, step):
        self.state, self.step = state, step
        self.restores = 0

    def restore(self, state_like):
        self.restores += 1
        return self.state, {"step": self.step}


def test_restart_driver_resumes_from_checkpoint():
    ckpt = _FakeCheckpointer(state={"w": 7}, step=42)
    attempts = []

    def make_loop(state, step):
        attempts.append((dict(state), step))
        if len(attempts) < 3:
            raise RuntimeError("simulated node failure")
        return {"w": state["w"] + step}

    final, restarts = run_with_restarts(
        make_loop, ckpt, {"w": 0}, max_restarts=2
    )
    assert restarts == 2 and ckpt.restores == 2
    # first attempt starts cold; retries resume from the checkpoint
    assert attempts[0] == ({"w": 0}, 0)
    assert attempts[1] == ({"w": 7}, 42) and attempts[2] == ({"w": 7}, 42)
    assert final == {"w": 49}


def test_restart_driver_gives_up_past_max_restarts():
    ckpt = _FakeCheckpointer(state={"w": 1}, step=1)

    def always_fails(state, step):
        raise RuntimeError("persistent failure")

    with pytest.raises(RuntimeError, match="persistent failure"):
        run_with_restarts(always_fails, ckpt, {"w": 0}, max_restarts=2)
    assert ckpt.restores == 2  # restored twice, third failure propagates


def test_restart_driver_no_failure_no_restore():
    ckpt = _FakeCheckpointer(state={}, step=0)
    final, restarts = run_with_restarts(
        lambda state, step: "done", ckpt, {}, max_restarts=2
    )
    assert final == "done" and restarts == 0 and ckpt.restores == 0


# ---------------------------------------------------------------------------
# elastic re-meshing
# ---------------------------------------------------------------------------


def test_best_mesh_shape():
    assert best_mesh_shape(8, prefer_model=4) == (2, 4)
    assert best_mesh_shape(6, prefer_model=4) == (2, 3)
    assert best_mesh_shape(7, prefer_model=4) == (7, 1)  # prime: model=1
    assert best_mesh_shape(2, prefer_model=4) == (1, 2)


@pytest.mark.parametrize("survivors", [8, 6, 4])
def test_elastic_restart_plan(survivors):
    plan = elastic_restart_plan(8, survivors, prefer_model=4)
    d, m = plan["mesh_shape"]
    assert d * m == survivors
    topo = plan["topology"]
    assert topo.n_ranks == survivors and topo.is_connected()
    # the regenerated tables route every surviving pair
    rt = plan["route_table"]
    for s in range(survivors):
        for t in range(survivors):
            assert rt.n_hops(s, t) <= topo.diameter()


def test_reshard_state_roundtrip():
    dev = jax.devices()[0]
    sharding = jax.sharding.SingleDeviceSharding(dev)
    host = {"a": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": np.ones((4,), np.int32)}
    out = reshard_state(host, {"a": sharding, "b": sharding})
    for k in host:
        np.testing.assert_array_equal(np.asarray(out[k]), host[k])
        assert out[k].sharding == sharding
