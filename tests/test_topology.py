"""Topology serialization + graph queries on irregular `from_edges` graphs.

The paper's route generator consumes JSON topology descriptions; these
tests pin the untested edge of ``core/topology.py``: the
``to_json``/``from_json`` roundtrip, ``diameter`` and ``is_connected`` on
graphs that are neither tori nor buses.
"""

import json

import pytest

from repro.core import Topology

# an irregular connected graph: a star (0-1..0-4) with a tail 4-5-6
IRREGULAR_EDGES = [(0, 1), (0, 2), (0, 3), (0, 4), (4, 5), (5, 6)]


def test_to_json_from_json_roundtrip():
    topo = Topology.from_edges(7, IRREGULAR_EDGES, name="star_tail")
    s = topo.to_json()
    spec = json.loads(s)
    assert spec["n_ranks"] == 7
    assert spec["name"] == "star_tail"
    assert sorted(tuple(e) for e in spec["edges"]) == sorted(IRREGULAR_EDGES)

    back = Topology.from_json(s)
    assert back.n_ranks == topo.n_ranks
    assert back.name == topo.name
    # adjacency *sets* survive (neighbour order is construction order and
    # may legitimately differ after the sorted-edge serialisation)
    for r in range(7):
        assert set(back.links[r]) == set(topo.links[r])
    # the serialisation is a fixed point
    assert Topology.from_json(back.to_json()).to_json() == back.to_json()


def test_from_json_accepts_file(tmp_path):
    topo = Topology.from_edges(4, [(0, 1), (1, 2), (2, 3)], name="p4")
    p = tmp_path / "topo.json"
    p.write_text(topo.to_json())
    back = Topology.from_json(str(p))
    assert back.n_ranks == 4 and back.name == "p4"
    assert back.diameter() == 3


def test_roundtrip_drops_torus_coords_but_keeps_routes_working():
    """dims (DOR coordinates) are not serialised; a roundtripped torus must
    still route (BFS fallback) and keep its metric structure."""
    from repro.core import compute_route_table

    torus = Topology.torus((2, 4))
    back = Topology.from_json(torus.to_json())
    assert back.dims is None
    assert back.diameter() == torus.diameter()
    rt = compute_route_table(back)  # auto -> bfs on dims=None
    for s in range(8):
        for d in range(8):
            assert rt.n_hops(s, d) <= back.diameter()


def test_diameter_irregular():
    topo = Topology.from_edges(7, IRREGULAR_EDGES)
    # farthest pair: tail end 6 to any other star leaf (6-5-4-0-1) = 4
    assert topo.diameter() == 4
    assert Topology.ring(8).diameter() == 4
    assert Topology.bus(8).diameter() == 7


def test_is_connected():
    assert Topology.from_edges(7, IRREGULAR_EDGES).is_connected()
    # two components: triangle + isolated edge
    split = Topology.from_edges(5, [(0, 1), (1, 2), (2, 0), (3, 4)])
    assert not split.is_connected()
    # a lone rank with no links at all
    assert not Topology.from_edges(2, []).is_connected()
    assert Topology.from_edges(1, []).is_connected()


def test_degree_and_ports_on_irregular_graph():
    topo = Topology.from_edges(7, IRREGULAR_EDGES)
    assert topo.degree(0) == 4
    assert topo.degree(6) == 1
    for r in range(7):
        for i, n in enumerate(topo.neighbors(r)):
            assert topo.port_of(r, n) == i


def test_from_edges_validates_symmetry_and_bounds():
    with pytest.raises(AssertionError):
        Topology(2, ((1,), ()))  # asymmetric link
    with pytest.raises((AssertionError, IndexError)):
        Topology.from_edges(2, [(0, 5)])  # out-of-range neighbour
