"""Per-kernel validation: interpret=True Pallas vs pure-jnp oracles,
sweeping shapes and dtypes (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import (
    attention_ref,
    flash_attention,
    matmul,
    matmul_ref,
    ssd_decode_step,
    ssd_ref,
    ssd_scan,
    stencil_ref,
    stencil_step,
)
from repro.kernels.ssd.ops import _ssd_chunked_jnp

RNG = np.random.RandomState


# ---------------------------------------------------------------- matmul


@pytest.mark.parametrize("M,K,N", [
    (128, 128, 128),
    (256, 384, 128),
    (100, 70, 50),      # ragged -> padding path
    (8, 512, 8),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_shapes_dtypes(M, K, N, dtype):
    rng = RNG(0)
    x = jnp.asarray(rng.randn(M, K), dtype)
    w = jnp.asarray(rng.randn(K, N), dtype)
    got = matmul(x, w, interpret=True)
    want = matmul_ref(x, w)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol,
    )


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 3), k=st.integers(1, 3), n=st.integers(1, 3),
    seed=st.integers(0, 99),
)
def test_matmul_property_blocked(m, k, n, seed):
    rng = RNG(seed)
    M, K, N = 64 * m, 64 * k, 64 * n
    x = jnp.asarray(rng.randn(M, K), jnp.float32)
    w = jnp.asarray(rng.randn(K, N), jnp.float32)
    got = matmul(x, w, block_m=64, block_n=64, block_k=64, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(matmul_ref(x, w)), rtol=3e-5, atol=3e-5
    )


# ------------------------------------------------------- flash attention


@pytest.mark.parametrize("B,S,H,Hkv,D", [
    (1, 128, 2, 2, 64),     # MHA
    (2, 256, 4, 2, 64),     # GQA
    (1, 200, 4, 1, 32),     # MQA + ragged seq (padding path)
])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_vs_ref(B, S, H, Hkv, D, causal):
    rng = RNG(1)
    q = jnp.asarray(rng.randn(B, S, H, D) * 0.3, jnp.float32)
    k = jnp.asarray(rng.randn(B, S, Hkv, D) * 0.3, jnp.float32)
    v = jnp.asarray(rng.randn(B, S, Hkv, D) * 0.3, jnp.float32)
    got = flash_attention(q, k, v, causal=causal, interpret=True,
                          block_q=64, block_k=64)
    want = attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_local_window():
    rng = RNG(2)
    B, S, H, D, W = 1, 256, 2, 32, 64
    q = jnp.asarray(rng.randn(B, S, H, D) * 0.3, jnp.float32)
    k = jnp.asarray(rng.randn(B, S, H, D) * 0.3, jnp.float32)
    v = jnp.asarray(rng.randn(B, S, H, D) * 0.3, jnp.float32)
    got = flash_attention(q, k, v, causal=True, window=W, interpret=True,
                          block_q=64, block_k=64)
    want = attention_ref(q, k, v, causal=True, window=W)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    rng = RNG(3)
    B, S, H, D = 1, 128, 2, 64
    q = jnp.asarray(rng.randn(B, S, H, D) * 0.3, jnp.bfloat16)
    k = jnp.asarray(rng.randn(B, S, H, D) * 0.3, jnp.bfloat16)
    v = jnp.asarray(rng.randn(B, S, H, D) * 0.3, jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, interpret=True,
                          block_q=64, block_k=64)
    want = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=3e-2, atol=3e-2,
    )


# --------------------------------------------------------------- stencil


@pytest.mark.parametrize("M,N", [(128, 128), (256, 128), (100, 130)])
def test_stencil_vs_ref(M, N):
    rng = RNG(4)
    x = jnp.asarray(rng.randn(M, N), jnp.float32)
    got = stencil_step(x, interpret=True, block_m=64)
    want = stencil_ref(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 99), m=st.sampled_from([64, 96, 128]))
def test_stencil_property_mean_preserving_bound(seed, m):
    """Property: max|stencil(x)| <= max|x| (averaging operator)."""
    rng = RNG(seed)
    x = jnp.asarray(rng.randn(m, 128), jnp.float32)
    y = stencil_step(x, interpret=True, block_m=64)
    assert np.abs(np.asarray(y)).max() <= np.abs(np.asarray(x)).max() + 1e-6


# ------------------------------------------------------------------- ssd


@pytest.mark.parametrize("S,chunk", [(128, 64), (256, 128), (200, 64)])
def test_ssd_kernel_vs_sequential_ref(S, chunk):
    rng = RNG(5)
    BH, Dh, Dst = 4, 16, 8
    x = jnp.asarray(rng.randn(BH, S, Dh) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.rand(BH, S) * 0.5 + 0.05, jnp.float32)
    B = jnp.asarray(rng.randn(BH, S, Dst) * 0.5, jnp.float32)
    C = jnp.asarray(rng.randn(BH, S, Dst) * 0.5, jnp.float32)
    A = jnp.asarray(-np.exp(rng.randn(BH, 1) * 0.3), jnp.float32)  # negative

    got = ssd_scan(x, dt, B, C, A, chunk=chunk, interpret=True)
    want = ssd_ref(x, dt, B, C, A)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_ssd_jnp_dispatch_matches_kernel():
    """The CPU dispatch path (chunked jnp) must equal the kernel's math."""
    rng = RNG(6)
    BH, S, Dh, Dst = 2, 192, 8, 4
    x = jnp.asarray(rng.randn(BH, S, Dh) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.rand(BH, S) * 0.5 + 0.05, jnp.float32)
    B = jnp.asarray(rng.randn(BH, S, Dst) * 0.5, jnp.float32)
    C = jnp.asarray(rng.randn(BH, S, Dst) * 0.5, jnp.float32)
    A = jnp.asarray(-np.ones((BH, 1)), jnp.float32)
    a = ssd_scan(x, dt, B, C, A, chunk=64, interpret=True)
    b = _ssd_chunked_jnp(x, dt, B, C, A, chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5, atol=2e-5)


def test_ssd_decode_matches_scan_tail():
    """Decoding token-by-token reproduces the scan output (state carry)."""
    rng = RNG(7)
    BH, S, Dh, Dst = 2, 32, 8, 4
    x = jnp.asarray(rng.randn(BH, S, Dh) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.rand(BH, S) * 0.5 + 0.05, jnp.float32)
    B = jnp.asarray(rng.randn(BH, S, Dst) * 0.5, jnp.float32)
    C = jnp.asarray(rng.randn(BH, S, Dst) * 0.5, jnp.float32)
    A = jnp.asarray(-np.ones((BH, 1)), jnp.float32)

    want = ssd_ref(x, dt, B, C, A)
    h = jnp.zeros((BH, Dst, Dh), jnp.float32)
    ys = []
    for t in range(S):
        h, y = ssd_decode_step(h, x[:, t], dt[:, t], B[:, t], C[:, t], A)
        ys.append(y)
    got = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


# ------------------------------------------------- chunked attention ref


@pytest.mark.parametrize("Sq,Skv,window", [
    (128, 128, None), (128, 128, 32), (64, 192, None),  # decode-ish right-align
])
def test_attention_chunked_matches_dense(Sq, Skv, window):
    from repro.kernels import attention_chunked_ref

    rng = RNG(8)
    B, H, Hkv, D = 2, 4, 2, 16
    q = jnp.asarray(rng.randn(B, Sq, H, D) * 0.3, jnp.float32)
    k = jnp.asarray(rng.randn(B, Skv, Hkv, D) * 0.3, jnp.float32)
    v = jnp.asarray(rng.randn(B, Skv, Hkv, D) * 0.3, jnp.float32)
    got = attention_chunked_ref(q, k, v, causal=True, window=window, block_k=32)
    want = attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)
