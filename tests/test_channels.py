"""First-class SMI channels: p2p + transient collective channels (§2.2–§2.4).

Covers the channel API of ``repro/channels``: port claims through the
PortAllocator, push/pop pipeline semantics (arrival latency = route hops,
``valid`` gating of pipeline bubbles, pushed/popped counters), p2p channels
over every transport backend with per-channel tagged TransportStats matching
``netsim.predict_channel_stats`` to the byte, transient collective channels
(bit-identical to their ``stream_*`` equivalents on every backend), and the
deprecation shims the legacy kwarg call sites keep working through.
"""

import gc
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.channels import (
    ChannelSpec,
    default_channel_spec,
    open_allreduce_channel,
    open_bcast_channel,
    open_gather_channel,
    open_reduce_channel,
    open_scatter_channel,
)
from repro.core import (
    Communicator,
    Topology,
    open_channel,
    push,
    pop,
    stream_allreduce,
    stream_bcast,
    stream_gather,
    stream_p2p,
    stream_reduce,
    stream_scatter,
    make_test_mesh,
    pvary,
    run_spmd,
    PortAllocator,
)
from repro.netsim import predict_channel_stats
from repro.transport import get_transport


@pytest.fixture(scope="module")
def ring8():
    mesh = make_test_mesh((8,), ("x",))
    comm = Communicator.create("x", (8,))
    return mesh, comm


@pytest.fixture(scope="module")
def torus24():
    mesh = make_test_mesh((2, 4), ("x", "y"))
    comm = Communicator.create(("x", "y"), (2, 4))
    return mesh, comm


def test_stream_p2p_ring(ring8):
    mesh, comm = ring8
    x = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16)

    def fn(xs):
        return stream_p2p(xs[0], src=0, dst=5, comm=comm, n_chunks=4)[None]

    y = run_spmd(fn, mesh, P("x"), P("x"), x)
    # destination shard (rank 5) holds source's shard (rank 0)
    np.testing.assert_allclose(np.asarray(y[5]), np.asarray(x[0]))
    # all other ranks zero
    for r in range(8):
        if r != 5:
            assert np.all(np.asarray(y[r]) == 0)


def test_stream_p2p_multihop_torus(torus24):
    mesh, comm = torus24
    # 0=(0,0) -> 7=(1,3): 2 hops under DOR (x then y, wrap)
    assert comm.route_table.n_hops(0, 7) == 2
    x = jnp.arange(8 * 12, dtype=jnp.float32).reshape(8, 12) + 1.0

    def fn(xs):
        return stream_p2p(xs[0], src=0, dst=7, comm=comm, n_chunks=3)[None]

    y = run_spmd(fn, mesh, P(("x", "y")), P(("x", "y")), x)
    np.testing.assert_allclose(np.asarray(y[7]), np.asarray(x[0]))


def test_stream_p2p_all_pairs(ring8):
    """Every (src, dst) pair delivers — MPI-style flexible addressing."""
    mesh, comm = ring8
    x = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4) + 3.0
    for src in [0, 3]:
        for dst in range(8):
            def fn(xs):
                return stream_p2p(xs[0], src=src, dst=dst, comm=comm, n_chunks=2)[None]

            y = run_spmd(fn, mesh, P("x"), P("x"), x)
            np.testing.assert_allclose(np.asarray(y[dst]), np.asarray(x[src]))


def test_channel_push_pop_pipeline(ring8):
    """Paper Listing 1: rank0 pushes N elements, rank1 pops them, pipelined.

    The pop'd stream arrives with latency = hops; validity gates the tail.
    """
    mesh, comm = ring8
    N = 10
    hops = comm.route_table.n_hops(0, 3)

    def fn(dummy):
        chan = open_channel(comm, count=N, src=0, dst=3, elem_shape=(), dtype=jnp.float32)
        acc0 = pvary(jnp.zeros((N,), jnp.float32), comm)

        def body(i, carry):
            chan, acc = carry
            data = (i * 2).astype(jnp.float32)  # "compute interesting data"
            chan = push(chan, data)
            chan, val, valid = pop(chan)
            slot = i - (hops - 1)
            upd = acc.at[jnp.maximum(slot, 0)].set(val)
            acc = jnp.where(valid, upd, acc)
            return chan, acc

        chan, acc = jax.lax.fori_loop(0, N + hops - 1, body, (chan, acc0))
        return acc[None] + 0 * dummy[:, :1], chan.popped[None]

    d = jnp.zeros((8, 1))
    acc, popped = run_spmd(fn, mesh, P("x"), (P("x"), P("x")), d)
    got = np.asarray(acc[3]).ravel()[:N]
    np.testing.assert_allclose(got, 2.0 * np.arange(N))
    assert int(popped[3]) == N
    # non-destination ranks never pop valid data
    assert int(popped[0]) == 0


def test_stream_p2p_latency_model(ring8):
    """Latency grows linearly with hops (Tab. 3), bandwidth does not (Fig. 9):
    check schedule step counts, the structural analogue."""
    mesh, comm = ring8
    n_chunks = 16
    # ring wraps: 0->7 is one hop; use the bus for the long-haul case
    for dst, hops in [(1, 1), (4, 4), (7, 1)]:
        assert comm.route_table.n_hops(0, dst) == hops
    bus = Communicator.create("x", (8,), topology=Topology.bus(8))
    for dst, hops in [(1, 1), (4, 4), (7, 7)]:
        assert bus.route_table.n_hops(0, dst) == hops
        steps = n_chunks + hops - 1
        # pipelined: steps grow additively with hops, not multiplicatively
        assert steps < n_chunks * hops + 1


def test_port_allocator(ring8):
    _, comm = ring8
    pa = PortAllocator()
    pa.claim(comm, 0)
    pa.claim(comm, 1)
    with pytest.raises(ValueError):
        pa.claim(comm, 0)
    pa.release_all(comm)
    pa.claim(comm, 0)


def test_channel_dtype_preserved(ring8):
    mesh, comm = ring8
    x = (jnp.arange(8 * 8).reshape(8, 8) % 127).astype(jnp.int8)

    def fn(xs):
        return stream_p2p(xs[0], src=2, dst=6, comm=comm, n_chunks=2)[None]

    y = run_spmd(fn, mesh, P("x"), P("x"), x)
    assert y.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(y[6]), np.asarray(x[2]))


# ---------------------------------------------------------------------------
# port claims: open_channel enforces the PortAllocator
# ---------------------------------------------------------------------------


def test_open_channel_enforces_port_claim(ring8):
    _, comm = ring8
    pa = PortAllocator()
    ch = open_channel(comm, src=0, dst=1, port=0, allocator=pa)
    assert pa.in_use(comm) == (0,)
    with pytest.raises(ValueError, match="port 0 already claimed"):
        open_channel(comm, src=0, dst=2, port=0, allocator=pa)
    # a different port coexists; close releases and the port is reusable
    other = open_channel(comm, src=0, dst=2, port=1, allocator=pa)
    assert pa.in_use(comm) == (0, 1)
    ch.close()
    assert pa.in_use(comm) == (1,)
    reopened = open_channel(comm, src=3, dst=4, port=0, allocator=pa)
    reopened.close()
    other.close()


def test_channel_scope_releases_port(ring8):
    _, comm = ring8
    pa = PortAllocator()
    with open_bcast_channel(comm, root=0, port=7, allocator=pa):
        assert pa.in_use(comm) == (7,)
        with pytest.raises(ValueError):
            open_reduce_channel(comm, root=0, port=7, allocator=pa)
    assert pa.in_use(comm) == ()


def test_anonymous_channels_claim_nothing(ring8):
    _, comm = ring8
    pa = PortAllocator()
    a = open_channel(comm, src=0, dst=1, port=None, allocator=pa)
    b = open_channel(comm, src=0, dst=2, port=None, allocator=pa)
    assert pa.in_use(comm) == ()
    a.close(), b.close()


def test_stale_double_close_cannot_free_other_claim(ring8):
    """close() is idempotent per channel: a stale second close must not
    release a later channel's live claim on the same port."""
    _, comm = ring8
    pa = PortAllocator()
    a = open_channel(comm, src=0, dst=1, port=2, allocator=pa)
    a.close()
    b = open_channel(comm, src=0, dst=1, port=2, allocator=pa)
    a.close()  # stale: port 2 now belongs to b
    assert pa.in_use(comm) == (2,)
    with pytest.raises(ValueError):
        open_channel(comm, src=0, dst=1, port=2, allocator=pa)
    # nor may a stale spec release free an ownerless (bare-claim) port
    c = open_channel(comm, src=0, dst=1, port=7, allocator=pa)
    c.close()
    pa.claim(comm, 7)  # ownerless claim takes the freed port
    c.close()  # stale: must not free the bare claim
    assert 7 in pa.in_use(comm)
    pa.release(comm, 7)  # an unowned release does free it
    b.close()
    assert pa.in_use(comm) == ()


def test_garbage_collected_channel_claim_lapses(ring8):
    """A claim owned by a dead spec (its opening trace is gone) must not
    poison the allocator — re-tracing functions that never close."""
    _, comm = ring8
    pa = PortAllocator()
    ch = open_channel(comm, src=0, dst=1, port=2, allocator=pa)
    del ch
    gc.collect()
    assert pa.in_use(comm) == ()
    again = open_channel(comm, src=0, dst=1, port=2, allocator=pa)
    again.close()


def test_double_claim_inside_one_trace_raises(ring8):
    """Two opens of one (comm, port) inside a single traced program must
    collide at trace time — the second open happens while the first claim
    is live in the very same abstract execution."""
    mesh, comm = ring8
    pa = PortAllocator()

    def fn(v):
        a = open_channel(comm, src=0, dst=1, port=4, allocator=pa)
        b = open_channel(comm, src=0, dst=2, port=4, allocator=pa)
        return (v + 0 * (a.pipe + b.pipe))[:1]

    with pytest.raises(ValueError, match="port 4 already claimed"):
        run_spmd(fn, mesh, P("x"), P("x"), jnp.zeros((8,), jnp.float32))


def test_stale_double_close_after_later_claimant_keeps_claims_view(ring8):
    """The claims() snapshot mirrors the stale-close rule: after a later
    claimant takes the port, the stale closer's second close leaves the
    live row (and its owner) untouched."""
    _, comm = ring8
    pa = PortAllocator()
    a = open_channel(comm, src=0, dst=1, port=2, tag="first", allocator=pa)
    a.close()
    b = open_channel(comm, src=0, dst=1, port=2, tag="second", allocator=pa)
    a.close()  # stale
    rows = pa.claims(comm)
    assert [r["port"] for r in rows] == [2]
    assert rows[0]["tag"] == "second" and not rows[0]["persistent"]
    b.close()
    assert pa.claims(comm) == ()


def test_persistent_claim_survives_del_and_gc_of_every_user(ring8):
    """claim(persistent=True) is the serving lifecycle: the port stays
    claimed after every channel (and local spec ref) dies, until an
    explicit release — the opposite of the transient lapse above."""
    _, comm = ring8
    from repro.channels import ChannelPool

    pa = PortAllocator()
    pool = ChannelPool(comm, allocator=pa)
    spec = pool.spec("decode.mlp")
    port = spec.port
    del spec
    gc.collect()
    assert pa.in_use(comm) == (port,)
    assert [r["persistent"] for r in pa.claims(comm)] == [True]
    with pytest.raises(ValueError):
        pa.claim(comm, port)
    pool.close()
    assert pa.in_use(comm) == ()


# ---------------------------------------------------------------------------
# ChannelSpec: the single config carrier
# ---------------------------------------------------------------------------


def test_default_channel_spec_maps_comm_modes(ring8):
    _, comm = ring8
    assert default_channel_spec(comm, "smi:packet").transport == "packet"
    assert default_channel_spec(comm, "smi").transport == "static"
    spec = default_channel_spec(comm, "smi:compressed:packet")
    assert spec.transport == "compressed:packet"
    assert spec.transport_key == "compressed:packet"
    with pytest.raises(AssertionError):
        default_channel_spec(comm, "bulk")


def test_channel_spec_wire_composes_transport_key(ring8):
    _, comm = ring8
    spec = ChannelSpec(comm=comm, transport="packet", wire="int8")
    assert spec.transport_key == "compressed:packet"
    assert type(spec.resolve()).__name__ == "CompressedTransport"
    raw = ChannelSpec(comm=comm, transport="static")
    assert raw.transport_key == "static"
    # stats tag defaults to the claimed port, explicit tag wins
    assert ChannelSpec(comm=comm, port=4).stats_tag == "port4"
    assert ChannelSpec(comm=comm, port=4, tag="h").stats_tag == "h"
    assert ChannelSpec(comm=comm, port=None).stats_tag is None


def test_parallel_ctx_channel_spec():
    """The launch layer's comm_mode lands on a ChannelSpec: model code can
    open channels on the TP communicator without re-threading the backend."""
    from repro.mesh import make_ctx

    mesh = make_test_mesh((8,), ("model",))
    ctx = make_ctx(mesh, model_axis="model", batch_axes=(),
                   comm_mode="smi:packet")
    spec = ctx.channel_spec(kind="p2p", src=0, dst=3, port=None)
    assert spec.comm is ctx.model_comm
    assert spec.transport == "packet"
    assert spec.transport_key == "packet"


def test_channel_transfer_carries_port_and_transport(ring8):
    """Regression (ISSUE 5 satellite): the pre-redesign channel_transfer
    dropped the channel's port and dispatched to the communicator-default
    transport.  A transfer must move through the channel's own backend and
    account under its port tag."""
    mesh, comm = ring8
    t = get_transport("packet")
    x = jnp.arange(8 * 32, dtype=jnp.float32).reshape(8, 32)

    def fn(v):
        ch = open_channel(comm, src=0, dst=5, port=3, transport=t,
                          n_chunks=4, allocator=PortAllocator())
        return ch.transfer(v[0])[None]

    y = run_spmd(fn, mesh, P("x"), P("x"), x)
    np.testing.assert_array_equal(np.asarray(y[5]), np.asarray(x[0]))
    # the packet backend (not the static default) moved the bytes...
    assert t.stats.steps > 0
    # ...and every step of them is accounted under the channel's port tag
    assert t.stats.tag_counts("port3") == (t.stats.steps, t.stats.bytes_moved)


# ---------------------------------------------------------------------------
# p2p channels over every backend: push/pop latency, counters, netsim stats
# ---------------------------------------------------------------------------

BACKENDS = ("static", "packet", "fused", "compressed")


@pytest.mark.parametrize("backend", BACKENDS)
def test_push_pop_over_backend(ring8, backend):
    """The element pipeline moves through the channel's transport backend:
    arrival latency == routed hops (paper Tab. 3) on every backend, and the
    pushed/popped counters track the roles."""
    mesh, comm = ring8
    N, SRC, DST = 3, 0, 3
    hops = comm.route_table.n_hops(SRC, DST)
    iters = N + hops + 2  # trailing pops = pipeline bubbles
    lossy = backend == "compressed"

    def fn(dummy):
        chan = open_channel(comm, count=N, src=SRC, dst=DST, port=None,
                            transport=backend, dtype=jnp.float32)
        acc = pvary(jnp.zeros((iters,), jnp.float32), comm)
        arrived = pvary(jnp.zeros((iters,), jnp.float32), comm)
        for i in range(iters):  # unrolled: the packet router threads
            if i < N:           # runtime counters (no fori_loop)
                chan = push(chan, jnp.float32(i + 1))
            chan, val, valid = pop(chan)
            acc = jnp.where(valid, acc.at[i].set(val), acc)
            arrived = jnp.where(valid, arrived.at[i].set(1.0), arrived)
        return (acc[None], arrived[None], chan.pushed[None],
                chan.popped[None])

    acc, arr, pushed, popped = run_spmd(
        fn, mesh, P("x"), (P("x"), P("x"), P("x"), P("x")),
        jnp.zeros((8, 1)),
    )
    arr_dst = np.asarray(arr[DST])
    # element j pushed at iteration j arrives after `hops` hop-steps
    want_arrival = np.zeros((iters,))
    want_arrival[hops - 1:hops - 1 + N] = 1.0
    np.testing.assert_array_equal(arr_dst, want_arrival)
    got = np.asarray(acc[DST])[hops - 1:hops - 1 + N]
    if lossy:
        np.testing.assert_allclose(got, 1.0 + np.arange(N), rtol=0.02)
    else:
        np.testing.assert_array_equal(got, 1.0 + np.arange(N))
    # counters: src counted N pushes, dst N valid pops, bubbles ignored
    assert int(pushed[SRC]) == N and int(popped[DST]) == N
    assert int(popped[SRC]) == 0 and int(pushed[DST]) == 0
    # no other rank ever popped valid data
    for r in range(8):
        if r != DST:
            assert int(popped[r]) == 0


@pytest.mark.parametrize("backend", ["packet", "compressed"])
def test_p2p_channel_stats_match_netsim(ring8, backend):
    """Acceptance: a packet-/compressed-backed p2p channel's *tagged*
    TransportStats match netsim.predict_channel_stats to the byte."""
    mesh, comm = ring8
    t = get_transport(backend)
    shape, n_chunks, dst = (32,), 4, 5
    x = jnp.asarray(
        np.random.RandomState(3).randn(8, *shape), jnp.float32
    )

    def fn(v):
        ch = open_channel(comm, src=0, dst=dst, port=6, transport=t,
                          n_chunks=n_chunks, allocator=PortAllocator())
        return ch.transfer(v[0])[None]

    y = run_spmd(fn, mesh, P("x"), P("x"), x)
    if backend != "compressed":
        np.testing.assert_array_equal(np.asarray(y[dst]), np.asarray(x[0]))

    spec = ChannelSpec(comm=comm, kind="p2p", src=0, dst=dst, port=6,
                       transport=backend, n_chunks=n_chunks)
    steps, nbytes = predict_channel_stats(spec, shape=shape)
    assert spec.stats_tag == "port6"
    assert t.stats.tag_counts("port6") == (steps, nbytes), (
        f"{backend}: tagged stats {t.stats.tag_counts('port6')} != "
        f"predicted {(steps, nbytes)}"
    )


def test_predict_channel_stats_fused_aliases_static(ring8):
    _, comm = ring8
    fused = ChannelSpec(comm=comm, src=0, dst=4, transport="fused",
                        n_chunks=2)
    static = ChannelSpec(comm=comm, src=0, dst=4, transport="static",
                         n_chunks=2)
    assert (predict_channel_stats(fused, shape=(16,))
            == predict_channel_stats(static, shape=(16,)))


# ---------------------------------------------------------------------------
# transient collective channels: element-level push/pop semantics
# ---------------------------------------------------------------------------


def test_bcast_channel_push_pop_ring(ring8):
    """§2.4: the root pushes, every rank pops — pipelined chain with
    per-rank latency = ring distance, bubbles gated by ``valid``."""
    mesh, comm = ring8
    N, ROOT, PP = 4, 0, 8
    iters = N + PP  # enough to drain the farthest rank + bubbles

    def fn(v):
        chan = open_bcast_channel(comm, count=N, root=ROOT, port=None,
                                  dtype=jnp.float32)
        acc = pvary(jnp.zeros((iters,), jnp.float32), comm)
        hit = pvary(jnp.zeros((iters,), jnp.float32), comm)

        def body(i, carry):
            chan, acc, hit = carry
            chan = chan.push(jax.lax.dynamic_index_in_dim(
                v[0], jnp.minimum(i, N - 1), 0, keepdims=False))
            chan, val, valid = chan.pop()
            acc = jnp.where(valid, acc.at[i].set(val), acc)
            hit = jnp.where(valid, hit.at[i].set(1.0), hit)
            return chan, acc, hit

        chan, acc, hit = jax.lax.fori_loop(
            0, iters, body, (chan, acc, hit))
        return acc[None], hit[None], chan.popped[None]

    x = jnp.asarray(np.random.RandomState(0).randn(8, N), jnp.float32)
    acc, hit, popped = run_spmd(
        fn, mesh, P("x"), (P("x"), P("x"), P("x")), x)
    root_seq = np.asarray(x[ROOT])
    for r in range(8):
        dist = (r - ROOT) % 8
        # pop i advances one hop-step: a rank d hops downstream first
        # delivers at pop d-1 (the root delivers its injection at pop 0)
        off = max(dist - 1, 0)
        got_hits = np.asarray(hit[r])
        want_hits = np.zeros((iters,))
        want_hits[off:off + N] = 1.0  # latency = ring distance
        np.testing.assert_array_equal(got_hits, want_hits, err_msg=f"r={r}")
        np.testing.assert_allclose(
            np.asarray(acc[r])[off:off + N], root_seq, rtol=1e-6,
            err_msg=f"rank {r}",
        )
        assert int(popped[r]) == N  # every rank delivers N, bubbles gated


def test_bcast_channel_push_pop_line_mid_root(ring8):
    """On a line (bus) topology the chain splits at the root: latency =
    |r - root| in both directions."""
    del ring8
    mesh = make_test_mesh((8,), ("x",))
    comm = Communicator.create("x", (8,), topology=Topology.bus(8))
    N, ROOT = 3, 3
    iters = N + 5  # farthest distance on the line is 4 (rank 7)

    def fn(v):
        chan = open_bcast_channel(comm, count=N, root=ROOT, port=None,
                                  dtype=jnp.float32)
        acc = pvary(jnp.zeros((iters,), jnp.float32), comm)
        hit = pvary(jnp.zeros((iters,), jnp.float32), comm)

        def body(i, carry):
            chan, acc, hit = carry
            chan = chan.push(jax.lax.dynamic_index_in_dim(
                v[0], jnp.minimum(i, N - 1), 0, keepdims=False))
            chan, val, valid = chan.pop()
            acc = jnp.where(valid, acc.at[i].set(val), acc)
            hit = jnp.where(valid, hit.at[i].set(1.0), hit)
            return chan, acc, hit

        chan, acc, hit = jax.lax.fori_loop(0, iters, body, (chan, acc, hit))
        return acc[None], hit[None]

    x = jnp.asarray(np.random.RandomState(1).randn(8, N), jnp.float32)
    acc, hit = run_spmd(fn, mesh, P("x"), (P("x"), P("x")), x)
    for r in range(8):
        dist = abs(r - ROOT)
        off = max(dist - 1, 0)
        want_hits = np.zeros((iters,))
        want_hits[off:off + N] = 1.0
        np.testing.assert_array_equal(np.asarray(hit[r]), want_hits,
                                      err_msg=f"r={r}")
        np.testing.assert_allclose(
            np.asarray(acc[r])[off:off + N], np.asarray(x[ROOT]),
            rtol=1e-6, err_msg=f"rank {r}",
        )


def test_reduce_channel_push_pop(ring8):
    """Every rank pushes contributions; the root pops the reduced stream
    after the chain latency, element order preserved."""
    mesh, comm = ring8
    N, ROOT, PP = 3, 0, 8
    iters = N + PP

    def fn(v):
        chan = open_reduce_channel(comm, count=N, root=ROOT, port=None,
                                   dtype=jnp.float32)
        acc = pvary(jnp.zeros((iters,), jnp.float32), comm)
        hit = pvary(jnp.zeros((iters,), jnp.float32), comm)

        def body(i, carry):
            chan, acc, hit = carry
            chan = chan.push(jax.lax.dynamic_index_in_dim(
                v[0], jnp.minimum(i, N - 1), 0, keepdims=False))
            chan, val, valid = chan.pop()
            acc = jnp.where(valid, acc.at[i].set(val), acc)
            hit = jnp.where(valid, hit.at[i].set(1.0), hit)
            return chan, acc, hit

        chan, acc, hit = jax.lax.fori_loop(0, iters, body, (chan, acc, hit))
        return acc[None], hit[None], chan.popped[None]

    x = jnp.asarray(np.random.RandomState(2).randn(8, N), jnp.float32)
    acc, hit, popped = run_spmd(
        fn, mesh, P("x"), (P("x"), P("x"), P("x")), x)
    want = np.asarray(x).sum(axis=0)  # elementwise sum over ranks
    hits_root = np.asarray(hit[ROOT])
    first = int(np.argmax(hits_root))
    assert hits_root[first:first + N].all() and hits_root.sum() == N
    np.testing.assert_allclose(
        np.asarray(acc[ROOT])[first:first + N], want, rtol=1e-5)
    assert int(popped[ROOT]) == N
    for r in range(1, 8):
        assert int(popped[r]) == 0


def test_round_channels_push_pop(ring8):
    """scatter/gather/allreduce channels: one schedule round per pop, the
    count cap gates extra pops invalid."""
    mesh, comm = ring8
    PP, N = 8, 2
    rng = np.random.RandomState(4)
    rows = jnp.asarray(rng.randn(N, PP), jnp.float32)  # scatter payloads
    mine = jnp.asarray(rng.randn(8, N), jnp.float32)   # per-rank elements

    def fn(v):
        sc = open_scatter_channel(comm, count=N, root=0, port=None,
                                  elem_shape=(), dtype=jnp.float32)
        ar = open_allreduce_channel(comm, count=N, port=None,
                                    elem_shape=(), dtype=jnp.float32)
        outs, oks = [], []
        for i in range(N + 1):  # one extra round: must pop invalid
            j = min(i, N - 1)
            sc = sc.push(rows[j])  # root's row: one element per rank
            ar = ar.push(v[0][j])
            sc, s_val, s_ok = sc.pop()
            ar, a_val, a_ok = ar.pop()
            outs.append((s_val, a_val))
            oks.append((jnp.asarray(s_ok).astype(jnp.float32),
                        jnp.asarray(a_ok).astype(jnp.float32)))
        return (jnp.stack([s for s, _ in outs])[None],
                jnp.stack([a for _, a in outs])[None],
                jnp.stack([jnp.stack(o) for o in oks])[None])

    s_out, a_out, oks = run_spmd(
        fn, mesh, P("x"), (P("x"), P("x"), P("x")), mine)
    for r in range(8):
        ok = np.asarray(oks[r])
        assert ok[:N].all() and not ok[N].any()  # count gates round N
        np.testing.assert_allclose(  # scatter: rank r gets column r
            np.asarray(s_out[r])[:N], np.asarray(rows)[:, r], rtol=1e-6)
        np.testing.assert_allclose(  # allreduce: every rank the sum
            np.asarray(a_out[r])[:N], np.asarray(mine).sum(axis=0).T[:N],
            rtol=1e-5)


def test_gather_channel_push_pop(ring8):
    mesh, comm = ring8
    PP, N = 8, 2
    mine = jnp.asarray(np.random.RandomState(5).randn(8, N), jnp.float32)

    def fn(v):
        ga = open_gather_channel(comm, count=N, root=0, port=None,
                                 elem_shape=(), dtype=jnp.float32)
        outs, oks = [], []
        for i in range(N):
            ga = ga.push(v[0][i])
            ga, rows, ok = ga.pop()
            outs.append(rows)
            oks.append(jnp.asarray(ok).astype(jnp.float32))
        return jnp.stack(outs)[None], jnp.stack(oks)[None]

    rows, oks = run_spmd(fn, mesh, P("x"), (P("x"), P("x")), mine)
    assert np.asarray(oks[0]).all()  # root pops valid rows
    for r in range(1, 8):
        assert not np.asarray(oks[r]).any()  # gather delivers only at root
    np.testing.assert_allclose(  # round i: the (P,)-row of element i
        np.asarray(rows[0]), np.asarray(mine).T[:N], rtol=1e-6)


def test_collective_channel_plan_path_keeps_tag(ring8):
    """A planned collective transfer still moves through the channel's
    backend and accounts under its stats tag (the per-channel accounting
    contract must not depend on whether a plan rides the spec)."""
    mesh, comm = ring8
    from repro.netsim.tune import Plan

    t = get_transport("static")
    plan = Plan(transport="static", n_chunks=2, algo="ring", wire="raw")
    x = jnp.asarray(np.random.RandomState(11).randn(8, 4, 3), jnp.float32)

    def fn(v):
        ch = open_bcast_channel(comm, root=0, port=4, transport=t,
                                plan=plan, allocator=PortAllocator())
        return ch.transfer(v[0])[None]

    y = run_spmd(fn, mesh, P("x"), P("x"), x)
    np.testing.assert_array_equal(np.asarray(y[3]), np.asarray(x[0]))
    assert t.stats.steps > 0
    assert t.stats.tag_counts("port4") == (t.stats.steps,
                                           t.stats.bytes_moved)


def test_p2p_channel_count_caps_validity(ring8):
    """A bounded p2p channel delivers at most ``count`` valid elements —
    the documented min(count, pushed) validity gate."""
    mesh, comm = ring8
    COUNT, SRC, DST = 2, 0, 2
    hops = comm.route_table.n_hops(SRC, DST)
    iters = 4 + hops

    def fn(v):
        chan = open_channel(comm, count=COUNT, src=SRC, dst=DST, port=None,
                            dtype=jnp.float32)
        acc = pvary(jnp.zeros((iters,), jnp.float32), comm)
        for i in range(iters):
            if i < 4:  # push twice as many elements as the channel's count
                chan = push(chan, jnp.float32(i + 1))
            chan, val, valid = pop(chan)
            acc = jnp.where(valid, acc.at[i].set(val), acc)
        return acc[None], chan.popped[None]

    acc, popped = run_spmd(fn, mesh, P("x"), (P("x"), P("x")),
                           jnp.zeros((8, 1)))
    assert int(popped[DST]) == COUNT
    got = np.asarray(acc[DST])
    np.testing.assert_array_equal(got[got != 0], [1.0, 2.0])


def test_collective_push_overrun_refused_not_corrupted(ring8):
    """Pushes beyond the P-deep credit window are refused (SMI_Push
    backpressure), never silently overwriting undelivered elements."""
    mesh, comm = ring8
    PP, N = 8, 10  # two more pushes than the FIFO holds

    def fn(v):
        chan = open_bcast_channel(comm, count=N, root=0, port=None,
                                  dtype=jnp.float32)
        for i in range(N):  # burst: all pushes before any pop
            chan = chan.push(v[0][i])
        accepted = chan.pushed
        acc = pvary(jnp.zeros((N,), jnp.float32), comm)

        def body(i, carry):
            chan, acc = carry
            chan, val, valid = chan.pop()
            acc = jnp.where(valid, acc.at[jnp.minimum(i, N - 1)].set(val),
                            acc)
            return chan, acc

        chan, acc = jax.lax.fori_loop(0, N + PP, body, (chan, acc))
        return acc[None], accepted[None], chan.popped[None]

    x = jnp.asarray(np.random.RandomState(6).randn(8, N), jnp.float32)
    acc, accepted, popped = run_spmd(
        fn, mesh, P("x"), (P("x"), P("x"), P("x")), x)
    assert int(accepted[0]) == PP  # the window refused the 2 overrun pushes
    assert int(popped[0]) == PP    # and delivery stops at the accepted count
    got = np.asarray(acc[0])       # drain is pop-only: acc slots 0..PP-1
    np.testing.assert_allclose(     # ...hold the first PP pushes unmangled
        got[:PP], np.asarray(x[0])[:PP], rtol=1e-6)


# ---------------------------------------------------------------------------
# transient collective channels == stream_* on every backend & topology
# ---------------------------------------------------------------------------

COLLECTIVE_TOPOLOGIES = {
    "ring1x8": lambda: (
        make_test_mesh((8,), ("x",)),
        Communicator.create("x", (8,)),
        P("x"),
    ),
    "torus2x4": lambda: (
        make_test_mesh((2, 4), ("x", "y")),
        Communicator.create(("x", "y"), (2, 4)),
        P(("x", "y")),
    ),
}


@pytest.mark.parametrize("topo", sorted(COLLECTIVE_TOPOLOGIES))
@pytest.mark.parametrize("backend", BACKENDS)
def test_collective_channels_bitexact_vs_stream(topo, backend):
    """Acceptance: bcast/reduce/scatter/gather/allreduce over transient
    collective channels produce bit-identical results to the corresponding
    ``stream_*`` calls on all four transport backends, ring + torus."""
    mesh, comm, spec = COLLECTIVE_TOPOLOGIES[topo]()
    PP = comm.size
    rng = np.random.RandomState(7)
    x = jnp.asarray(rng.randn(PP, 4, 3), jnp.float32)   # bcast/reduce/ar
    g = jnp.asarray(rng.randn(PP, 2, 3), jnp.float32)   # gather shards
    full = jnp.asarray(rng.randn(PP * 2, 3), jnp.float32)  # scatter rows

    def chan_fn(v, gv, fv):
        b = open_bcast_channel(comm, root=1, port=None, transport=backend,
                               n_chunks=2).transfer(v[0])
        r = open_reduce_channel(comm, root=0, port=None, transport=backend,
                                n_chunks=2).transfer(v[0])
        gt = open_gather_channel(comm, root=0, port=None,
                                 transport=backend).transfer(gv[0])
        s = open_scatter_channel(comm, root=0, port=None,
                                 transport=backend).transfer(fv)
        a = open_allreduce_channel(comm, port=None,
                                   transport=backend).transfer(v[0])
        return b[None], r[None], gt[None], s[None], a[None]

    def stream_fn(v, gv, fv):
        b = stream_bcast(v[0], comm, root=1, n_chunks=2, transport=backend)
        r = stream_reduce(v[0], comm, root=0, n_chunks=2, transport=backend)
        gt = stream_gather(gv[0], comm, root=0, transport=backend)
        s = stream_scatter(fv, comm, root=0, transport=backend)
        a = stream_allreduce(v[0], comm, transport=backend)
        return b[None], r[None], gt[None], s[None], a[None]

    outs = {}
    for label, fn in (("channel", chan_fn), ("stream", stream_fn)):
        outs[label] = run_spmd(
            fn, mesh, (spec, spec, P(None)),
            (spec, spec, spec, spec, spec), x, g, full,
        )
    for kind, got, want in zip(
        ("bcast", "reduce", "gather", "scatter", "allreduce"),
        outs["channel"], outs["stream"],
    ):
        np.testing.assert_array_equal(
            np.asarray(got), np.asarray(want),
            err_msg=f"{kind} channel != stream_* on {backend}@{topo}",
        )
    if backend != "compressed":  # ground truth on exact wires
        b, r, gt, s, a = (np.asarray(o) for o in outs["channel"])
        xs = np.asarray(x)
        for rr in range(PP):
            np.testing.assert_allclose(b[rr], xs[1], rtol=1e-6)
            np.testing.assert_allclose(a[rr], xs.sum(0), rtol=1e-5)
        np.testing.assert_allclose(r[0], xs.sum(0), rtol=1e-5)
        np.testing.assert_allclose(
            gt[0].reshape(PP, 2, 3), np.asarray(g), rtol=1e-6)
        np.testing.assert_allclose(
            s.reshape(PP * 2, 3), np.asarray(full), rtol=1e-6)


# ---------------------------------------------------------------------------
# deprecation shims: the legacy kwarg call sites keep working
# ---------------------------------------------------------------------------


def test_stream_p2p_transport_kwarg_deprecated_but_identical(ring8):
    mesh, comm = ring8
    x = jnp.asarray(np.random.RandomState(8).randn(8, 16), jnp.float32)

    with pytest.warns(DeprecationWarning, match="open a channel"):
        legacy = run_spmd(
            lambda v: stream_p2p(v[0], src=0, dst=4, comm=comm, n_chunks=2,
                                 transport="packet")[None],
            mesh, P("x"), P("x"), x,
        )
    channel = run_spmd(
        lambda v: open_channel(comm, src=0, dst=4, port=None, n_chunks=2,
                               transport="packet").transfer(v[0])[None],
        mesh, P("x"), P("x"), x,
    )
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(channel))


def test_stream_p2p_plan_kwarg_deprecated_but_identical(ring8):
    mesh, comm = ring8
    x = jnp.asarray(np.random.RandomState(9).randn(8, 16), jnp.float32)

    with pytest.warns(DeprecationWarning, match="DESIGN.md"):
        legacy = run_spmd(
            lambda v: stream_p2p(v[0], src=0, dst=5, comm=comm,
                                 plan="auto")[None],
            mesh, P("x"), P("x"), x,
        )
    channel = run_spmd(
        lambda v: open_channel(comm, src=0, dst=5, port=None,
                               plan="auto").transfer(v[0])[None],
        mesh, P("x"), P("x"), x,
    )
    np.testing.assert_array_equal(np.asarray(legacy), np.asarray(channel))


def test_stream_p2p_plain_call_does_not_warn(ring8):
    mesh, comm = ring8
    x = jnp.ones((8, 8), jnp.float32)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        y = run_spmd(
            lambda v: stream_p2p(v[0], src=0, dst=2, comm=comm,
                                 n_chunks=2)[None],
            mesh, P("x"), P("x"), x,
        )
    np.testing.assert_array_equal(np.asarray(y[2]), np.asarray(x[0]))
