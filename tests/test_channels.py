"""Transient channels + streamed p2p engine tests (paper §3.1)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import (
    Communicator,
    Topology,
    open_channel,
    push,
    pop,
    stream_p2p,
    make_test_mesh,
    pvary,
    run_spmd,
    PortAllocator,
)


@pytest.fixture(scope="module")
def ring8():
    mesh = make_test_mesh((8,), ("x",))
    comm = Communicator.create("x", (8,))
    return mesh, comm


@pytest.fixture(scope="module")
def torus24():
    mesh = make_test_mesh((2, 4), ("x", "y"))
    comm = Communicator.create(("x", "y"), (2, 4))
    return mesh, comm


def test_stream_p2p_ring(ring8):
    mesh, comm = ring8
    x = jnp.arange(8 * 16, dtype=jnp.float32).reshape(8, 16)

    def fn(xs):
        return stream_p2p(xs[0], src=0, dst=5, comm=comm, n_chunks=4)[None]

    y = run_spmd(fn, mesh, P("x"), P("x"), x)
    # destination shard (rank 5) holds source's shard (rank 0)
    np.testing.assert_allclose(np.asarray(y[5]), np.asarray(x[0]))
    # all other ranks zero
    for r in range(8):
        if r != 5:
            assert np.all(np.asarray(y[r]) == 0)


def test_stream_p2p_multihop_torus(torus24):
    mesh, comm = torus24
    # 0=(0,0) -> 7=(1,3): 2 hops under DOR (x then y, wrap)
    assert comm.route_table.n_hops(0, 7) == 2
    x = jnp.arange(8 * 12, dtype=jnp.float32).reshape(8, 12) + 1.0

    def fn(xs):
        return stream_p2p(xs[0], src=0, dst=7, comm=comm, n_chunks=3)[None]

    y = run_spmd(fn, mesh, P(("x", "y")), P(("x", "y")), x)
    np.testing.assert_allclose(np.asarray(y[7]), np.asarray(x[0]))


def test_stream_p2p_all_pairs(ring8):
    """Every (src, dst) pair delivers — MPI-style flexible addressing."""
    mesh, comm = ring8
    x = jnp.arange(8 * 4, dtype=jnp.float32).reshape(8, 4) + 3.0
    for src in [0, 3]:
        for dst in range(8):
            def fn(xs):
                return stream_p2p(xs[0], src=src, dst=dst, comm=comm, n_chunks=2)[None]

            y = run_spmd(fn, mesh, P("x"), P("x"), x)
            np.testing.assert_allclose(np.asarray(y[dst]), np.asarray(x[src]))


def test_channel_push_pop_pipeline(ring8):
    """Paper Listing 1: rank0 pushes N elements, rank1 pops them, pipelined.

    The pop'd stream arrives with latency = hops; validity gates the tail.
    """
    mesh, comm = ring8
    N = 10
    hops = comm.route_table.n_hops(0, 3)

    def fn(dummy):
        chan = open_channel(comm, count=N, src=0, dst=3, elem_shape=(), dtype=jnp.float32)
        acc0 = pvary(jnp.zeros((N,), jnp.float32), comm)

        def body(i, carry):
            chan, acc = carry
            data = (i * 2).astype(jnp.float32)  # "compute interesting data"
            chan = push(chan, data)
            chan, val, valid = pop(chan)
            slot = i - (hops - 1)
            upd = acc.at[jnp.maximum(slot, 0)].set(val)
            acc = jnp.where(valid, upd, acc)
            return chan, acc

        chan, acc = jax.lax.fori_loop(0, N + hops - 1, body, (chan, acc0))
        return acc[None] + 0 * dummy[:, :1], chan.popped[None]

    d = jnp.zeros((8, 1))
    acc, popped = run_spmd(fn, mesh, P("x"), (P("x"), P("x")), d)
    got = np.asarray(acc[3]).ravel()[:N]
    np.testing.assert_allclose(got, 2.0 * np.arange(N))
    assert int(popped[3]) == N
    # non-destination ranks never pop valid data
    assert int(popped[0]) == 0


def test_stream_p2p_latency_model(ring8):
    """Latency grows linearly with hops (Tab. 3), bandwidth does not (Fig. 9):
    check schedule step counts, the structural analogue."""
    mesh, comm = ring8
    n_chunks = 16
    # ring wraps: 0->7 is one hop; use the bus for the long-haul case
    for dst, hops in [(1, 1), (4, 4), (7, 1)]:
        assert comm.route_table.n_hops(0, dst) == hops
    bus = Communicator.create("x", (8,), topology=Topology.bus(8))
    for dst, hops in [(1, 1), (4, 4), (7, 7)]:
        assert bus.route_table.n_hops(0, dst) == hops
        steps = n_chunks + hops - 1
        # pipelined: steps grow additively with hops, not multiplicatively
        assert steps < n_chunks * hops + 1


def test_port_allocator(ring8):
    _, comm = ring8
    pa = PortAllocator()
    pa.claim(comm, 0)
    pa.claim(comm, 1)
    with pytest.raises(ValueError):
        pa.claim(comm, 0)
    pa.release_all(comm)
    pa.claim(comm, 0)


def test_channel_dtype_preserved(ring8):
    mesh, comm = ring8
    x = (jnp.arange(8 * 8).reshape(8, 8) % 127).astype(jnp.int8)

    def fn(xs):
        return stream_p2p(xs[0], src=2, dst=6, comm=comm, n_chunks=2)[None]

    y = run_spmd(fn, mesh, P("x"), P("x"), x)
    assert y.dtype == jnp.int8
    np.testing.assert_array_equal(np.asarray(y[6]), np.asarray(x[2]))
