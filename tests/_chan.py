"""Channels-API equivalents of the retired ``stream_*`` shim calls.

The deprecated ``repro.core.stream_*`` wrappers survive only for the
shim-equivalence test (test_channels) and the deprecation-warning sweep
(test_parallel_layers); every other test calls the supported surface — a
transient anonymous-port collective channel
(``repro.channels.open_*_channel``) — through these helpers, which keep
the old call-site shape."""

from repro.channels import (
    open_allreduce_channel,
    open_bcast_channel,
    open_gather_channel,
    open_reduce_channel,
    open_scatter_channel,
)


def chan_bcast(x, comm, *, root=0, n_chunks=1, transport=None):
    return open_bcast_channel(
        comm, root=root, port=None, transport=transport, n_chunks=n_chunks
    ).transfer(x)


def chan_reduce(x, comm, *, root=0, n_chunks=1, op=None, transport=None):
    return open_reduce_channel(
        comm, root=root, port=None, op=op, transport=transport,
        n_chunks=n_chunks,
    ).transfer(x)


def chan_gather(x, comm, *, root=0, transport=None):
    return open_gather_channel(
        comm, root=root, port=None, transport=transport
    ).transfer(x)


def chan_scatter(x, comm, *, root=0, transport=None):
    return open_scatter_channel(
        comm, root=root, port=None, transport=transport
    ).transfer(x)


def chan_allreduce(x, comm, *, quantize=None, dequantize=None, bidir=False,
                   transport=None):
    return open_allreduce_channel(
        comm, port=None, transport=transport
    ).transfer(x, quantize=quantize, dequantize=dequantize, bidir=bidir)
