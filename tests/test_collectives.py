"""Streamed collectives vs numpy oracles (paper §3.2 / §4.4)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _chan import (
    chan_allreduce,
    chan_bcast,
    chan_gather,
    chan_reduce,
    chan_scatter,
)
from _hyp import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.core import (
    Communicator,
    Topology,
    make_test_mesh,
    run_spmd,
    stream_allgather,
    stream_alltoall,
    stream_reduce_scatter,
    tree_bcast,
    tree_reduce,
    staged_bcast,
    staged_reduce,
    make_int8_codec,
)

PP = 8


@pytest.fixture(scope="module")
def ring8():
    mesh = make_test_mesh((PP,), ("x",))
    comm = Communicator.create("x", (PP,))
    return mesh, comm


@pytest.fixture(scope="module")
def bus8():
    mesh = make_test_mesh((PP,), ("x",))
    comm = Communicator.create("x", (PP,), topology=Topology.bus(PP))
    return mesh, comm


def _x(m=4, k=3, seed=0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(PP * m, k).astype(np.float32))


def test_allgather(ring8):
    mesh, comm = ring8
    x = _x()
    y = run_spmd(lambda v: stream_allgather(v, comm)[None], mesh, P("x"), P("x"), x)
    for r in range(PP):
        np.testing.assert_allclose(np.asarray(y[r]), np.asarray(x), rtol=1e-6)


def test_allgather_bidir(ring8):
    mesh, comm = ring8
    x = _x(seed=1)
    y = run_spmd(lambda v: stream_allgather(v, comm, bidir=True)[None], mesh, P("x"), P("x"), x)
    for r in range(PP):
        np.testing.assert_allclose(np.asarray(y[r]), np.asarray(x), rtol=1e-6)


def test_reduce_scatter(ring8):
    mesh, comm = ring8
    # every rank holds a full (P*m, k) partial; result: rank r gets sum over
    # ranks of block r.
    rng = np.random.RandomState(2)
    full = rng.randn(PP, PP * 2, 3).astype(np.float32)  # [rank, rows, k]
    want = full.sum(axis=0)  # (P*2, 3); block r = want[2r:2r+2]

    def fn(v):  # v: (P*2, 3) this rank's partials (shard over leading? no)
        return stream_reduce_scatter(v, comm)

    x = jnp.asarray(full.reshape(PP * PP * 2, 3))  # shard over ranks: (P, P*2, 3)
    y = run_spmd(fn, mesh, P("x"), P("x"), x)
    # y is (P * 2, 3): rank r's (2,3) block stacked
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-5)


def test_allreduce(ring8):
    mesh, comm = ring8
    rng = np.random.RandomState(3)
    per_rank = rng.randn(PP, 5, 7).astype(np.float32)
    want = per_rank.sum(axis=0)

    def fn(v):
        return chan_allreduce(v[0], comm)[None]

    x = jnp.asarray(per_rank)
    y = run_spmd(fn, mesh, P("x"), P("x"), x)
    for r in range(PP):
        np.testing.assert_allclose(np.asarray(y[r]), want, rtol=1e-5)


def test_allreduce_int8_compressed(ring8):
    mesh, comm = ring8
    rng = np.random.RandomState(4)
    per_rank = rng.randn(PP, 64).astype(np.float32)
    want = per_rank.sum(axis=0)
    q, dq = make_int8_codec()

    def fn(v):
        return chan_allreduce(v[0], comm, quantize=q, dequantize=dq)[None]

    y = run_spmd(fn, mesh, P("x"), P("x"), jnp.asarray(per_rank))
    # int8 ring: loose tolerance; error-feedback at the optimizer recovers it
    np.testing.assert_allclose(np.asarray(y[0]), want, atol=0.35)


def test_alltoall(ring8):
    mesh, comm = ring8
    rng = np.random.RandomState(5)
    blocks = rng.randn(PP, PP, 2, 3).astype(np.float32)  # [rank, dst, m, k]
    want = blocks.transpose(1, 0, 2, 3)  # [rank, src, m, k]

    def fn(v):
        return stream_alltoall(v[0], comm)[None]

    y = run_spmd(fn, mesh, P("x"), P("x"), jnp.asarray(blocks))
    np.testing.assert_allclose(np.asarray(y), want, rtol=1e-6)


@pytest.mark.parametrize("root", [0, 3])
@pytest.mark.parametrize("n_chunks", [1, 4])
def test_bcast_ring(ring8, root, n_chunks):
    mesh, comm = ring8
    rng = np.random.RandomState(6)
    per_rank = rng.randn(PP, 8, 3).astype(np.float32)

    def fn(v):
        return chan_bcast(v[0], comm, root=root, n_chunks=n_chunks)[None]

    y = run_spmd(fn, mesh, P("x"), P("x"), jnp.asarray(per_rank))
    for r in range(PP):
        np.testing.assert_allclose(np.asarray(y[r]), per_rank[root], rtol=1e-6)


@pytest.mark.parametrize("root", [0, 3, 7])
def test_bcast_bus(bus8, root):
    """Same API, bus topology: the paper's topology-flexibility claim."""
    mesh, comm = bus8
    rng = np.random.RandomState(7)
    per_rank = rng.randn(PP, 4, 2).astype(np.float32)

    def fn(v):
        return chan_bcast(v[0], comm, root=root, n_chunks=2)[None]

    y = run_spmd(fn, mesh, P("x"), P("x"), jnp.asarray(per_rank))
    for r in range(PP):
        np.testing.assert_allclose(np.asarray(y[r]), per_rank[root], rtol=1e-6)


@pytest.mark.parametrize("root", [0, 2])
@pytest.mark.parametrize("n_chunks", [1, 4])
def test_reduce(ring8, root, n_chunks):
    mesh, comm = ring8
    rng = np.random.RandomState(8)
    per_rank = rng.randn(PP, 8, 2).astype(np.float32)
    want = per_rank.sum(axis=0)

    def fn(v):
        return chan_reduce(v[0], comm, root=root, n_chunks=n_chunks)[None]

    y = run_spmd(fn, mesh, P("x"), P("x"), jnp.asarray(per_rank))
    np.testing.assert_allclose(np.asarray(y[root]), want, rtol=1e-5)
    for r in range(PP):
        if r != root:
            assert np.all(np.asarray(y[r]) == 0)


@pytest.mark.parametrize("root", [0, 5])
def test_gather(ring8, root):
    mesh, comm = ring8
    rng = np.random.RandomState(9)
    shards = rng.randn(PP, 3, 2).astype(np.float32)

    def fn(v):
        return chan_gather(v[0], comm, root=root)[None]

    y = run_spmd(fn, mesh, P("x"), P("x"), jnp.asarray(shards))
    got = np.asarray(y[root]).reshape(PP, 3, 2)
    np.testing.assert_allclose(got, shards, rtol=1e-6)


@pytest.mark.parametrize("root", [0, 5])
def test_scatter(ring8, root):
    mesh, comm = ring8
    rng = np.random.RandomState(10)
    full = rng.randn(PP * 3, 2).astype(np.float32)

    def fn(v):
        # all ranks pass the same buffer; only root's content matters
        return chan_scatter(v, comm, root=root)

    x = jnp.asarray(np.broadcast_to(full, (PP * 3, 2)).copy())
    y = run_spmd(lambda v: fn(v)[None], mesh, P(None), P("x"),
                 jnp.asarray(full))
    got = np.asarray(y)  # (P, 3, 2)
    np.testing.assert_allclose(got.reshape(PP * 3, 2), full, rtol=1e-6)


@pytest.mark.parametrize("root", [0, 3])
def test_tree_bcast_reduce(ring8, root):
    mesh, comm = ring8
    rng = np.random.RandomState(11)
    per_rank = rng.randn(PP, 6).astype(np.float32)

    def fb(v):
        return tree_bcast(v[0], comm, root=root)[None]

    y = run_spmd(fb, mesh, P("x"), P("x"), jnp.asarray(per_rank))
    for r in range(PP):
        np.testing.assert_allclose(np.asarray(y[r]), per_rank[root], rtol=1e-6)

    def fr(v):
        return tree_reduce(v[0], comm, root=root)[None]

    z = run_spmd(fr, mesh, P("x"), P("x"), jnp.asarray(per_rank))
    np.testing.assert_allclose(np.asarray(z[root]), per_rank.sum(0), rtol=1e-5)


def test_staged_baselines(ring8):
    mesh, comm = ring8
    rng = np.random.RandomState(12)
    per_rank = rng.randn(PP, 4).astype(np.float32)

    y = run_spmd(lambda v: staged_bcast(v[0], comm, root=0)[None],
                 mesh, P("x"), P("x"), jnp.asarray(per_rank))
    for r in range(PP):
        np.testing.assert_allclose(np.asarray(y[r]), per_rank[0], rtol=1e-6)

    z = run_spmd(lambda v: staged_reduce(v[0], comm, root=0)[None],
                 mesh, P("x"), P("x"), jnp.asarray(per_rank))
    np.testing.assert_allclose(np.asarray(z[0]), per_rank.sum(0), rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 6),
    seed=st.integers(0, 100),
    root=st.integers(0, PP - 1),
)
def test_property_bcast_reduce_duality(m, seed, root):
    """Property: reduce(bcast(x)) == P * x at root, for any shapes/root."""
    mesh = make_test_mesh((PP,), ("x",))
    comm = Communicator.create("x", (PP,))
    rng = np.random.RandomState(seed)
    x = rng.randn(PP, m * 2, 2).astype(np.float32)

    def fn(v):
        b = chan_bcast(v[0], comm, root=root, n_chunks=1)
        rduced = chan_reduce(b, comm, root=root, n_chunks=2)
        return rduced[None]

    y = run_spmd(fn, mesh, P("x"), P("x"), jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(y[root]), PP * x[root], rtol=1e-4)
