"""Gradient-equivalence matrix for the channel-native parallel layers.

Every ``repro/parallel`` layer — column/row-parallel linear, parallel
embedding, vocab-parallel cross entropy, MoE dispatch/combine — must
reproduce a replicated single-rank reference in BOTH the forward value and
``jax.grad``, on a ring (1x8) and a torus (2x4) mesh, across all four
transport backends.  Raw-wire backends (static / packet / fused) are held
to bit-identity where the schedule moves data without re-associating a
reduction, and to f32-tight tolerance where ring partial-sum order differs
from the oracle's single contraction.

The compressed backend is lossy by design: forwards (and gradient paths
that only *use* quantized forward values, like the column layer's weight
gradient) must land within the int8 codec's error bound, while gradient
paths that differentiate *through* the codec are the gradient of the
quantized function — ``round`` has zero derivative almost everywhere — and
are checked finite, not value-matched.  (Training never relies on those
paths for exactness; end-to-end lossy-grad behaviour is owned by the
``ErrorFeedback`` tests and the train-smoke bit-identity gate.)

Also here: the ``"grad"`` channel-tag observability contract
(``grad_sync`` / ``grad_sync_fsdp`` traffic shows up in
``metrics.track()`` snapshots), the ``clip_by_global_norm`` regressions,
the shim deprecation sweep, and a byte-exactness regression for
``netsim.predict_train_step_stats`` against the channel ledger.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import make_test_mesh, run_spmd
from repro.mesh.api import make_ctx
from repro.parallel import (
    column_parallel_linear,
    moe_combine,
    moe_dispatch,
    parallel_embedding,
    row_parallel_linear,
    vocab_parallel_cross_entropy,
)

BACKENDS = ["static", "packet", "fused", "compressed"]
MESHES = {"ring": (1, 8), "torus": (2, 4)}

ROWS_LOC = 2   # sequence rows per device
K, N, D, V, S = 8, 16, 8, 16, 4

_mesh_cache = {}


def _mesh(dims):
    if dims not in _mesh_cache:
        _mesh_cache[dims] = make_test_mesh(dims, ("data", "model"))
    return _mesh_cache[dims]


def _ctx(dims, backend):
    return make_ctx(_mesh(dims), model_axis="model", batch_axes=("data",),
                    comm_mode=f"smi:{backend}")


def _check(got, want, backend, *, exact: bool, lossy: str = "codec"):
    """``exact``: raw-wire backends must be bit-identical (vs f32-tight).

    ``lossy`` picks the compressed-backend policy: "codec" = within the
    int8 wire's error bound; "raw" = the op never touches a lossy wire
    (tagged psum/pmax), hold it to the raw-backend bar; "finite" = the
    value differentiates through the quantizer and is only sanity-checked.
    """
    got, want = np.asarray(got), np.asarray(want)
    if backend == "compressed" and lossy != "raw":
        if lossy == "finite":
            assert got.shape == want.shape
            assert np.all(np.isfinite(got))
            return
        np.testing.assert_allclose(got, want, rtol=2e-2, atol=1e-1)
    elif exact:
        np.testing.assert_array_equal(got, want)
    else:
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def _rng(seed):
    return np.random.RandomState(seed)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("dims", list(MESHES.values()), ids=list(MESHES))
def test_column_parallel_linear(dims, backend):
    dp, tp = dims
    ctx = _ctx(dims, backend)
    rows = dp * tp * ROWS_LOC
    x = jnp.asarray(_rng(0).randn(rows, K).astype(np.float32))
    w = jnp.asarray(_rng(1).randn(K, N).astype(np.float32))
    cot = jnp.asarray(_rng(2).randn(rows, N).astype(np.float32))

    def fn(xl, wl, cl):
        out, pull = jax.vjp(
            lambda a, b: column_parallel_linear(a, b, ctx), xl, wl)
        gx, gw = pull(cl)
        return out, gx, gw[None]

    out, gx, gw = run_spmd(
        fn, _mesh(dims),
        (P(("data", "model"), None), P(None, "model"), P("data", "model")),
        (P("data", "model"), P(("data", "model"), None),
         P("data", None, "model")),
        x, w, cot,
    )
    want = x @ w
    # the gather moves shards verbatim and the per-chunk GEMM contracts the
    # same full-K rows the oracle does: raw wires are bit-identical
    _check(out, want, backend, exact=True)
    # gx transposes the gather (through the codec when compressed)
    _check(gx, cot @ w.T, backend, exact=False, lossy="finite")
    # gw = gathered_x.T @ cot uses quantized *values* only: codec-bounded
    _check(np.asarray(gw).sum(0), x.T @ cot, backend, exact=False)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("dims", list(MESHES.values()), ids=list(MESHES))
def test_row_parallel_linear(dims, backend):
    dp, tp = dims
    ctx = _ctx(dims, backend)
    g_rows = tp * ROWS_LOC                 # full rows per data group
    x = jnp.asarray(_rng(3).randn(dp * g_rows, K).astype(np.float32))
    w = jnp.asarray(_rng(4).randn(K, N).astype(np.float32))
    cot = jnp.asarray(_rng(5).randn(dp * g_rows, N).astype(np.float32))

    def fn(xl, wl, cl):
        out, pull = jax.vjp(
            lambda a, b: row_parallel_linear(a, b, ctx), xl, wl)
        gx, gw = pull(cl)
        return out, gx, gw[None]

    out, gx, gw = run_spmd(
        fn, _mesh(dims),
        (P("data", "model"), P("model", None), P(("data", "model"), None)),
        (P(("data", "model"), None), P("data", "model"),
         P("data", "model", None)),
        x, w, cot,
    )
    # ring accumulation re-associates the K-contraction: f32-tight, not bitwise
    _check(out, x @ w, backend, exact=False)
    # both gradients transpose the reduce-scatter: lossy path when compressed
    _check(gx, cot @ w.T, backend, exact=False, lossy="finite")
    _check(np.asarray(gw).sum(0).reshape(K, N), x.T @ cot, backend,
           exact=False, lossy="finite")


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("dims", list(MESHES.values()), ids=list(MESHES))
def test_parallel_embedding(dims, backend):
    dp, tp = dims
    ctx = _ctx(dims, backend)
    B = 2 * dp
    table = jnp.asarray(_rng(6).randn(V, D).astype(np.float32))
    ids = jnp.asarray(_rng(7).randint(0, V, (B, S)), jnp.int32)
    cot = jnp.asarray(_rng(8).randn(B, S, D).astype(np.float32))

    def fn(tl, il, cl):
        out, pull = jax.vjp(
            lambda t: parallel_embedding(t, il, ctx), tl)
        (gt,) = pull(cl)
        return out, gt[None]

    out, gt = run_spmd(
        fn, _mesh(dims),
        (P("model", None), P("data", None), P("data", None, None)),
        (P("data", None, None), P("data", "model", None)),
        table, ids, cot,
    )
    want = np.asarray(table)[np.asarray(ids)]
    # exactly one vocab shard contributes per id; the psum adds zeros, and
    # no transport is involved: bit-exact on every backend
    _check(out, want, backend, exact=True, lossy="raw")
    gt_ref = np.zeros((V, D), np.float32)
    np.add.at(gt_ref, np.asarray(ids).reshape(-1),
              np.asarray(cot).reshape(-1, D))
    # the output is model-replicated, so the per-rank pullback feeds each
    # replica's cotangent into the psum transpose: the assembled table
    # gradient carries an exact factor of tp — normalize it out (tp is a
    # power of two here, so the division is lossless)
    _check(np.asarray(gt).sum(0) / tp, gt_ref, backend, exact=False,
           lossy="raw")


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("dims", list(MESHES.values()), ids=list(MESHES))
def test_vocab_parallel_cross_entropy(dims, backend):
    dp, tp = dims
    ctx = _ctx(dims, backend)
    B = 2 * dp
    logits = jnp.asarray(_rng(9).randn(B, S, V).astype(np.float32))
    labels = jnp.asarray(_rng(10).randint(0, V, (B, S)), jnp.int32)
    cot = jnp.asarray(_rng(11).randn(B, S).astype(np.float32))

    def fn(ll, yl, cl):
        out, pull = jax.vjp(
            lambda l: vocab_parallel_cross_entropy(l, yl, ctx), ll)
        (gl,) = pull(cl)
        return out, gl

    out, gl = run_spmd(
        fn, _mesh(dims),
        (P("data", None, "model"), P("data", None), P("data", None)),
        (P("data", None), P("data", None, "model")),
        logits, labels, cot,
    )
    lf = np.asarray(logits, np.float64).astype(np.float32)
    m = lf.max(-1)
    zs = np.exp(lf - m[..., None]).sum(-1)
    picked = np.take_along_axis(
        lf, np.asarray(labels)[..., None], axis=-1)[..., 0]
    want = np.log(zs) + m - picked
    # raw tagged psums on every backend; partial sum-exp order differs
    # from the single-rank sum: f32-tight
    _check(out, want, backend, exact=False, lossy="raw")
    sm = np.exp(lf - m[..., None]) / zs[..., None]
    onehot = np.eye(V, dtype=np.float32)[np.asarray(labels)]
    # model-replicated output -> psum-transpose tp factor (see the
    # embedding test); normalize before comparing
    _check(np.asarray(gl) / tp, (sm - onehot) * np.asarray(cot)[..., None],
           backend, exact=False, lossy="raw")


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("dims", list(MESHES.values()), ids=list(MESHES))
def test_moe_dispatch_combine(dims, backend):
    dp, tp = dims
    ctx = _ctx(dims, backend)
    rows = dp * tp * ROWS_LOC
    x = jnp.asarray(_rng(12).randn(rows, D).astype(np.float32))
    w = jnp.asarray(_rng(13).randn(tp, D).astype(np.float32))
    cot = jnp.asarray(_rng(14).randn(rows, D).astype(np.float32))

    def layer(xl, wl):
        xf = moe_dispatch(xl, ctx)          # (tp*ROWS_LOC, D) full tokens
        y_part = xf * wl                    # this expert group's partial
        return moe_combine(y_part, ctx)     # back to sequence shards

    def fn(xl, wl, cl):
        out, pull = jax.vjp(layer, xl, wl)
        gx, gw = pull(cl)
        return out, gx, gw[None]

    out, gx, gw = run_spmd(
        fn, _mesh(dims),
        (P(("data", "model"), None), P("model", None),
         P(("data", "model"), None)),
        (P(("data", "model"), None), P(("data", "model"), None),
         P("data", "model", None)),
        x, w, cot,
    )
    wsum = np.asarray(w).sum(0)
    _check(out, np.asarray(x) * wsum, backend, exact=False)
    # dispatch/combine transposes ride the same (lossy when compressed) wires
    _check(gx, np.asarray(cot) * wsum, backend, exact=False, lossy="finite")
    gw_ref = (np.asarray(x) * np.asarray(cot)).reshape(dp, tp * ROWS_LOC, D)
    gw_got = np.asarray(gw).sum(0).reshape(tp, D)
    _check(gw_got, np.broadcast_to(
        gw_ref.sum(1).sum(0), (tp, D)), backend, exact=False, lossy="finite")


# ------------------------------------------------------- grad channel tag


def test_grad_sync_tag_in_metrics_snapshot():
    """grad_sync traffic is attributable: the ``"grad"`` tag lands in the
    tracked transport's stats and therefore in metrics snapshots."""
    from repro.mesh.api import grad_sync
    from repro.obs.metrics import MetricsRegistry
    from repro.transport import get_transport

    dims = (2, 4)
    ctx = _ctx(dims, "static")
    t = get_transport("static")
    reg = MetricsRegistry()
    reg.track("grad_sync", t)

    def fn(g):
        return jax.tree.map(
            lambda x: x[None], grad_sync(g, ctx, transport=t))

    grads = {"a": jnp.ones((8, 4)), "b": jnp.ones((6,))}
    run_spmd(fn, _mesh(dims), (P(),), P(("data", "model")), grads)
    snap = reg.snapshot()["transports"]["grad_sync"]
    assert "grad" in snap["by_tag"]
    assert snap["by_tag"]["grad"]["bytes"] > 0


def test_grad_sync_fsdp_tag_in_ledger():
    """Replicated (dim<0) FSDP leaves ring under the same ``"grad"`` tag;
    with no live transport handle the ledger carries the attribution."""
    from repro.mesh.api import grad_sync_fsdp
    from repro.parallel import ledger

    dims = (2, 4)
    ctx = _ctx(dims, "static")
    plan = {"a": -1, "b": 0}

    def fn(g):
        out = grad_sync_fsdp(g, plan, ctx)
        return jax.tree.map(lambda x: jnp.sum(x)[None], out)

    grads = {"a": jnp.ones((6,)), "b": jnp.ones((8, 4))}
    with ledger.capture() as led:
        run_spmd(fn, _mesh(dims), (P(),), P(("data", "model")), grads)
    assert "grad" in led.tag_bytes()
    assert led.tag_bytes()["grad"] > 0


# -------------------------------------------------- clip_by_global_norm


def test_clip_empty_pytree():
    from repro.optim.grad import clip_by_global_norm

    clipped, norm = clip_by_global_norm({}, 1.0)
    assert clipped == {}
    assert float(norm) == 0.0


def test_clip_preserves_leaf_dtypes():
    from repro.optim.grad import clip_by_global_norm

    grads = {
        "bf16": jnp.full((4,), 3.0, jnp.bfloat16),
        "f32": jnp.full((4,), 4.0, jnp.float32),
    }
    clipped, norm = clip_by_global_norm(grads, 1.0)
    assert clipped["bf16"].dtype == jnp.bfloat16
    assert clipped["f32"].dtype == jnp.float32
    np.testing.assert_allclose(float(norm), 10.0, rtol=1e-6)
    # scale applied in f32, cast back: values match the f32 computation
    np.testing.assert_allclose(
        np.asarray(clipped["f32"]), np.full((4,), 0.4), rtol=1e-6)


# ------------------------------------------------------ deprecation sweep


@pytest.mark.parametrize("shim", ["stream_bcast", "stream_reduce",
                                  "stream_gather", "stream_scatter",
                                  "stream_allreduce"])
def test_legacy_shims_warn(shim):
    import repro.core as core
    from repro.core import Communicator

    mesh = make_test_mesh((8,), ("x",))
    comm = Communicator.create("x", (8,))
    fn = getattr(core, shim)
    x = jnp.ones((64, 2))

    def run(v):
        with pytest.warns(DeprecationWarning):
            if shim == "stream_allreduce":
                fn(v, comm)
            else:
                fn(v, comm, root=0)
        return jnp.zeros((1,))

    run_spmd(run, mesh, P("x"), P("x"), x)


# --------------------------------------- predicted-vs-measured regression


def test_plan_auto_choice_recorded_in_ledger():
    """A ``plan="auto"`` layer consults the tuner at trace time and the
    capture ledger records WHICH backend it chose, keyed by layer tag —
    the observability contract the per-tag config defaults rely on."""
    from repro.parallel import ledger
    from repro.transport import is_transport_key

    dims = (1, 8)
    ctx = make_ctx(_mesh(dims), model_axis="model", batch_axes=("data",),
                   comm_mode="smi", plan="auto")
    x = jnp.asarray(_rng(20).randn(8 * ROWS_LOC, K).astype(np.float32))
    w = jnp.asarray(_rng(21).randn(K, N).astype(np.float32))

    def fn(xl, wl):
        return column_parallel_linear(xl, wl, ctx)

    with ledger.capture() as led:
        run_spmd(fn, _mesh(dims),
                 (P(("data", "model"), None), P(None, "model")),
                 P("data", "model"), x, w)
    assert "tp.col" in led.plans, led.plans
    assert is_transport_key(led.plans["tp.col"])
    # the tuned traffic still tallies under the layer's tag
    steps, nbytes = led.tag_counts("tp.col")
    assert steps > 0 and nbytes > 0


def test_predict_train_step_stats_matches_ledger():
    """The full-train-step predictor equals the traced channel ledger to
    the byte per tag (the --validate-comm contract, DESIGN.md §12)."""
    from repro.configs import get_arch, smoke
    from repro.configs.base import ShapeConfig
    from repro.launch.mesh import make_mesh
    from repro.launch.steps import TrainSettings, build_train
    from repro.netsim import predict_train_step_stats
    from repro.parallel import ledger

    cfg = smoke(get_arch("yi-6b"))
    shape = ShapeConfig("t", seq_len=128, global_batch=8, kind="train")
    st = TrainSettings(comm_mode="smi:static", remat="nothing",
                       loss_chunks=1, total_steps=10, warmup_steps=1)
    mesh = make_mesh((2, 4), ("data", "model"))
    art = build_train(cfg, mesh, shape, st)
    batch = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
             for k, v in art["input_specs"].items()}
    with ledger.capture() as led:
        art["step"].lower(art["state_shape"], batch)
    measured = {t: dict(e) for t, e in led.by_tag.items()}
    predicted = predict_train_step_stats(cfg, (2, 4), shape, st)
    assert predicted == measured
