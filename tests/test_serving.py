"""ServeEngine batching-path tests (serving/engine.py).

The engine decodes a fixed-width wave of slots in lock-step; these tests
pin the properties the dry-run shapes rely on: slot independence (a
request's tokens don't depend on its wave-mates), prompt replay across
different prompt lengths, eos early-exit, and queue draining over
multiple waves.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_arch, smoke
from repro.mesh.api import ParallelCtx
from repro.models import init_lm
from repro.serving import Request, ServeEngine


@pytest.fixture(scope="module")
def engine_setup():
    cfg = smoke(get_arch("yi-6b"))
    params = init_lm(jax.random.PRNGKey(0), cfg, ParallelCtx())
    return cfg, params


def _run(cfg, params, prompts, *, batch_slots, max_new=4, eos=None,
         max_steps=200):
    eng = ServeEngine(cfg, params, batch_slots=batch_slots, capacity=64,
                      eos=eos)
    for uid, prompt in enumerate(prompts):
        eng.submit(Request(uid=uid, prompt=list(prompt), max_new=max_new))
    done = eng.run(max_steps=max_steps)
    return {r.uid: r for r in done}


def test_batched_slots_are_independent(engine_setup):
    """A request's output is the same whether it decodes alone or batched
    with a different wave-mate — the batching path must not leak state
    across slots (per-slot caches, per-slot prompt cursors)."""
    cfg, params = engine_setup
    pa, pb = [5, 7, 9], [11, 3]
    solo = _run(cfg, params, [pa], batch_slots=1)
    duo = _run(cfg, params, [pa, pb], batch_slots=2)
    assert duo[0].out == solo[0].out
    solo_b = _run(cfg, params, [pb], batch_slots=1)
    assert duo[1].out == solo_b[0].out


def test_unequal_prompt_lengths_replay_correctly(engine_setup):
    """Wave-mates with different prompt lengths: the shorter one starts
    sampling while the longer one is still replaying its prompt."""
    cfg, params = engine_setup
    short, long = [4], [4, 8, 15, 16, 23]
    duo = _run(cfg, params, [short, long], batch_slots=2, max_new=3)
    assert len(duo[0].out) == 3 and len(duo[1].out) == 3
    solo = _run(cfg, params, [long], batch_slots=1, max_new=3)
    assert duo[1].out == solo[0].out


def test_queue_drains_over_multiple_waves(engine_setup):
    cfg, params = engine_setup
    prompts = [[i + 1, i + 2] for i in range(5)]  # 3 waves of <= 2 slots
    done = _run(cfg, params, prompts, batch_slots=2, max_new=2,
                max_steps=400)
    assert sorted(done) == [0, 1, 2, 3, 4]
    for r in done.values():
        assert r.done and len(r.out) == 2
        assert all(0 <= t < cfg.padded_vocab for t in r.out)
    # wave admission resets position: identical prompts in different waves
    # produce identical continuations
    same = _run(cfg, params, [[9, 9], [9, 9], [9, 9]], batch_slots=1,
                max_new=2, max_steps=400)
    assert same[0].out == same[1].out == same[2].out


def test_wave_position_is_run_local(engine_setup):
    """Regression: the engine once carried a dead ``self.pos`` instance
    attribute shadowing the run-local wave position — a stale value there
    would corrupt the greedy path of any wave after the first.  Position
    is wave-local state now: identical prompts in back-to-back ``run()``
    calls decode identically, and the attribute stays gone."""
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, batch_slots=1, capacity=64)
    assert not hasattr(eng, "pos")
    eng.submit(Request(uid=0, prompt=[5, 7], max_new=3))
    first = eng.run(max_steps=50)
    assert not hasattr(eng, "pos")
    eng.submit(Request(uid=1, prompt=[5, 7], max_new=3))
    second = eng.run(max_steps=50)
    assert first[0].out == second[0].out


def test_arrival_schedule_and_latency_bookkeeping(engine_setup):
    """``run(arrivals=...)`` replays a timed trace: requests join the
    queue at their tick (idle ticks pass while nothing is resident) and
    admit/finish ticks land in ``admit_step``/``finish_step`` — the
    counters the serving benchmark's latency percentiles come from."""
    cfg, params = engine_setup
    eng = ServeEngine(cfg, params, batch_slots=2, capacity=64)
    arrivals = [(0, Request(uid=0, prompt=[5, 7], max_new=2)),
                (6, Request(uid=1, prompt=[3], max_new=2))]
    done = {r.uid: r for r in eng.run(max_steps=100, arrivals=arrivals)}
    assert sorted(done) == [0, 1] and all(r.done for r in done.values())
    assert eng.admit_step[0] == 0
    assert eng.admit_step[1] >= 6          # not admitted before it arrived
    for uid in (0, 1):
        assert eng.finish_step[uid] > eng.admit_step[uid]
    # an all-upfront submission decodes identically to the no-arrivals path
    ref = _run(cfg, params, [[5, 7]], batch_slots=2, max_new=2)
    assert done[0].out == ref[0].out


def test_eos_early_exit(engine_setup):
    cfg, params = engine_setup
    probe = _run(cfg, params, [[5, 7]], batch_slots=1, max_new=4)
    toks = probe[0].out
    assert len(toks) == 4
    # stop at the first occurrence of the chosen eos token instead of
    # decoding out to max_new
    eos = int(toks[1])
    done = _run(cfg, params, [[5, 7]], batch_slots=1, max_new=4, eos=eos)
    assert done[0].out == toks[:toks.index(eos) + 1]
    assert done[0].done
