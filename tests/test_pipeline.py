"""Pipeline parallelism over SMI channels: forward correctness + AD."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import Communicator, make_test_mesh
from repro.core.pipeline import pipeline_apply, pipeline_loss

PP = 4


@pytest.fixture(scope="module")
def chain4():
    mesh = make_test_mesh((PP,), ("pp",))
    comm = Communicator.create("pp", (PP,))
    return mesh, comm


def _stage(params, x):
    w, b = params
    return jnp.tanh(x @ w + b)


def test_pipeline_forward_matches_sequential(chain4):
    mesh, comm = chain4
    rng = np.random.RandomState(0)
    D, M, mb = 6, 5, 3
    Ws = rng.randn(PP, D, D).astype(np.float32) * 0.4
    Bs = rng.randn(PP, D).astype(np.float32) * 0.1
    X = rng.randn(M, mb, D).astype(np.float32)

    # oracle: sequential application of all 4 stages
    want = X.copy()
    for s in range(PP):
        want = np.tanh(want @ Ws[s] + Bs[s])

    def fn(w, b, x):
        out = pipeline_apply(_stage, (w[0], b[0]), x, comm)
        return out[None]

    out = jax.jit(
        jax.shard_map(
            fn, mesh=mesh,
            in_specs=(P("pp"), P("pp"), P()),
            out_specs=P("pp"),
        )
    )(jnp.asarray(Ws), jnp.asarray(Bs), jnp.asarray(X))
    got = np.asarray(out[PP - 1])  # delivered at the last stage
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_pipeline_grad_flows_to_all_stages(chain4):
    """AD transposes the channel hops into the reverse pipeline: every
    stage's parameters must receive a nonzero gradient."""
    mesh, comm = chain4
    rng = np.random.RandomState(1)
    D, M, mb = 4, 4, 2
    Ws = rng.randn(PP, D, D).astype(np.float32) * 0.4
    Bs = rng.randn(PP, D).astype(np.float32) * 0.1
    X = rng.randn(M, mb, D).astype(np.float32)
    Y = rng.randn(M, mb, D).astype(np.float32)

    def loss_rankwise(w, b, x, y):
        return pipeline_loss(
            _stage,
            lambda p, t: jnp.mean((p - t) ** 2),
            (w[0], b[0]),
            x, y, comm,
        )

    def value_and_grads(w, b, x, y):
        def f(wb):
            return loss_rankwise(wb[0], wb[1], x, y)

        l, g = jax.value_and_grad(f)((w, b))
        return l[None], g[0], g[1]

    l, gw, gb = jax.jit(
        jax.shard_map(
            value_and_grads, mesh=mesh,
            in_specs=(P("pp"), P("pp"), P(), P()),
            out_specs=(P("pp"), P("pp"), P("pp")),
        )
    )(jnp.asarray(Ws), jnp.asarray(Bs), jnp.asarray(X), jnp.asarray(Y))

    # loss identical on every stage (psum'd)
    lv = np.asarray(l)
    np.testing.assert_allclose(lv, lv[0], rtol=1e-6)
    gw = np.asarray(gw)
    for s in range(PP):
        assert np.abs(gw[s]).max() > 0, f"stage {s} got zero gradient"

    # gradient oracle: plain sequential model
    def seq_loss(wb):
        w, b = wb
        h = jnp.asarray(X)
        for s in range(PP):
            h = jnp.tanh(h @ w[s] + b[s])
        return jnp.mean(jnp.mean((h - Y) ** 2, axis=(1, 2)))

    l0, (gw0, gb0) = jax.value_and_grad(seq_loss)((jnp.asarray(Ws), jnp.asarray(Bs)))
    np.testing.assert_allclose(lv[0], np.asarray(l0), rtol=1e-5)
    np.testing.assert_allclose(gw, np.asarray(gw0), rtol=1e-4, atol=1e-5)
