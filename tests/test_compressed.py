"""Compressed-link transport contract tests (DESIGN.md §7).

Four pillars:

* **tolerance** — the same collective call sites produce results within
  the codec error bound across the compressed backend and the raw static
  reference, on the torus and the snake-bus, including the packet router
  as the inner backend;
* **wire accounting** — the traced backend's `TransportStats` byte counter
  equals the netsim prediction *exactly* (int8 payload + scale sidecar,
  not f32), and `_schedule_loop`'s rolled stat scaling matches an
  unrolled run on both raw and compressed wires;
* **reduce-scatter regression** — the once-quantised contribution
  schedule's error is bounded independent of P, while the seed's
  re-round-the-accumulator loop (kept reachable via the generic
  ``shift_accumulate``) demonstrably grows with P;
* **plumbing** — registry wrapper keys, comm_mode forms, deprecated
  ``quantize=``/``dequantize=`` shims, lossy-dtype errors, and the
  runtime-stats cross-trace reuse guard.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from _chan import chan_allreduce, chan_bcast
from repro.core import (
    Communicator,
    Topology,
    bcast,
    make_int8_codec,
    make_test_mesh,
    stream_allgather,
    stream_p2p,
)
from repro.core.collectives import stream_reduce_scatter
from repro.core.router import snake_bus
from repro.netsim import int8_wire_nbytes, predict_transport_stats
from repro.transport import (
    get_transport,
    is_transport_key,
    resolve_comm_mode,
)
from repro.transport.compressed import (
    CompressedTransport,
    dequantize_int8,
    quantize_int8,
)

TOPOLOGIES = {
    "ring": lambda: (
        make_test_mesh((8,), ("x",)),
        Communicator.create("x", (8,), topology=Topology.ring(8)),
        P("x"),
    ),
    "torus": lambda: (
        make_test_mesh((2, 4), ("x", "y")),
        Communicator.create(("x", "y"), (2, 4)),
        P(("x", "y")),
    ),
    "snake_bus": lambda: (
        make_test_mesh((2, 4), ("x", "y")),
        Communicator.create(("x", "y"), (2, 4), topology=snake_bus((2, 4))),
        P(("x", "y")),
    ),
}


def _codec_atol(x, hops_quantised=1):
    """Worst-case absolute error of ``hops_quantised`` independent int8
    quantisations of data bounded by max|x| (scale = max/127, error <=
    scale/2 each)."""
    return hops_quantised * float(np.max(np.abs(x))) / 254.0 * 1.05 + 1e-6


# ---------------------------------------------------------------------------
# codec units
# ---------------------------------------------------------------------------


def test_codec_blockwise_scales_and_bound():
    rng = np.random.RandomState(0)
    v = jnp.asarray(rng.randn(100, 7).astype(np.float32))
    q, scales = quantize_int8(v, 64)
    assert q.shape == v.shape and q.dtype == jnp.int8
    assert scales.shape == (-(-700 // 64),)
    err = np.abs(np.asarray(dequantize_int8((q, scales), 64)) - np.asarray(v))
    # per-element error bounded by its own block's scale
    per_elem = np.repeat(np.asarray(scales), 64)[:700].reshape(100, 7)
    assert np.all(err <= per_elem / 2 * 1.01 + 1e-8)


def test_codec_axis_elems_localises_scales():
    """Blockwise scales must beat a per-tensor scale on heterogeneous
    magnitudes — the whole point of honouring axis_elems."""
    rng = np.random.RandomState(1)
    v = np.concatenate([rng.randn(256) * 1e3, rng.randn(256) * 1e-2])
    v = jnp.asarray(v.astype(np.float32))
    small = np.asarray(v)[256:]

    def err(axis_elems):
        q, s = quantize_int8(v, axis_elems)
        back = np.asarray(dequantize_int8((q, s), axis_elems))
        return np.max(np.abs(back[256:] - small))

    assert err(256) < err(None) / 100  # per-tensor scale flattens the tail


def test_codec_requantisation_idempotent():
    rng = np.random.RandomState(2)
    v = jnp.asarray(rng.randn(1000).astype(np.float32))
    q, s = quantize_int8(v, 128)
    dq = dequantize_int8((q, s), 128)
    q2, s2 = quantize_int8(dq, 128)
    np.testing.assert_array_equal(np.asarray(q2), np.asarray(q))


def test_make_int8_codec_honours_axis_elems():
    """The historic bug: axis_elems was accepted and ignored."""
    rng = np.random.RandomState(3)
    v = jnp.asarray(rng.randn(512).astype(np.float32))
    q, dq = make_int8_codec(axis_elems=64)
    wire = q(v)
    assert wire[1].shape == (8,), "one scale per 64-element block"
    qt, dqt = make_int8_codec()  # None -> per-tensor scale (legacy)
    assert qt(v)[1].shape == (1,)
    np.testing.assert_allclose(
        np.asarray(dq(wire)), np.asarray(v), atol=_codec_atol(np.asarray(v))
    )


def test_codec_rejects_integer_payloads():
    with pytest.raises(TypeError, match="floating"):
        quantize_int8(jnp.arange(8, dtype=jnp.int32))


# ---------------------------------------------------------------------------
# cross-backend tolerance suite
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topo", sorted(TOPOLOGIES))
@pytest.mark.parametrize("backend", ["compressed", "compressed:fused"])
def test_collectives_within_codec_bound(topo, backend, devices8):
    """bcast / allgather / allreduce over the compressed wire agree with
    the raw static reference within the codec error bound."""
    mesh, comm, spec = TOPOLOGIES[topo]()
    x = jnp.asarray(np.random.RandomState(4).randn(8, 64), jnp.float32)

    def run(tkey):
        def fn(v):
            t = get_transport(tkey)
            bc = chan_bcast(v[0], comm, root=0, n_chunks=4, transport=t)
            ag = stream_allgather(v[0], comm, transport=t)
            ar = chan_allreduce(v[0], comm, transport=t)
            return bc[None], ag[None], ar[None]

        out = jax.jit(jax.shard_map(
            fn, mesh=mesh, in_specs=spec, out_specs=(spec,) * 3))(x)
        return jax.tree.map(np.asarray, out)

    ref = run("static")
    got = run(backend)
    xa = np.asarray(x)
    # bcast/allgather: values quantised once (requantisation idempotent)
    np.testing.assert_allclose(got[0], ref[0], atol=_codec_atol(xa))
    np.testing.assert_allclose(got[1], ref[1], atol=_codec_atol(xa))
    # allreduce: P once-quantised contributions + compressed allgather of
    # the reduced block
    atol = _codec_atol(xa, hops_quantised=8) + _codec_atol(ref[2])
    np.testing.assert_allclose(got[2], ref[2], atol=atol)


def test_compressed_over_packet_router(devices8):
    """The int8 wire rides the packet router end to end (int8 codes are
    exact on its f32 wire) with zero loss."""
    mesh, comm, spec = TOPOLOGIES["ring"]()
    x = jnp.asarray(np.random.RandomState(5).randn(8, 64), jnp.float32)

    def fn(v):
        t = get_transport("compressed:packet")
        y = chan_allreduce(v[0], comm, transport=t)
        ovf = t.stats.overflow
        return y[None], jnp.asarray(ovf, jnp.int32)[None]

    y, ovf = jax.jit(jax.shard_map(
        fn, mesh=mesh, in_specs=spec, out_specs=(spec, spec)))(x)
    assert int(np.asarray(ovf).sum()) == 0, "not a zero-loss run"
    want = np.asarray(x).sum(axis=0)
    atol = _codec_atol(np.asarray(x), 8) + _codec_atol(want)
    np.testing.assert_allclose(np.asarray(y)[0], want, atol=atol)


@pytest.mark.parametrize("topo", sorted(TOPOLOGIES))
def test_p2p_within_codec_bound(topo, devices8):
    mesh, comm, spec = TOPOLOGIES[topo]()
    x = jnp.asarray(np.random.RandomState(6).randn(8, 16, 4), jnp.float32)

    def fn(v):
        y = stream_p2p(v[0], src=0, dst=5, comm=comm, n_chunks=2,
                       transport=get_transport("compressed"))
        return y[None]

    y = np.asarray(jax.jit(jax.shard_map(
        fn, mesh=mesh, in_specs=spec, out_specs=spec))(x))
    xa = np.asarray(x)
    np.testing.assert_allclose(y[5], xa[0], atol=_codec_atol(xa))
    others = np.delete(y, 5, axis=0)
    np.testing.assert_array_equal(others, np.zeros_like(others))


def test_model_layer_helper_compressed_mode(devices8):
    """colparallel_matmul under comm_mode='smi:compressed' tracks bulk
    within the codec tolerance (the mesh-api plumbing end to end)."""
    from repro.mesh.api import colparallel_matmul, make_ctx

    mesh = make_test_mesh((2, 4), ("data", "model"))
    rng = np.random.RandomState(7)
    x = jnp.asarray(rng.randn(8, 16), jnp.float32)
    w = jnp.asarray(rng.randn(16, 12), jnp.float32)
    spec_x = P(("data", "model"))
    out = {}
    for m in ["bulk", "smi:compressed"]:
        ctx = make_ctx(mesh, model_axis="model", batch_axes=("data",),
                       comm_mode=m)
        f = jax.jit(jax.shard_map(
            lambda xv, wv, c=ctx: colparallel_matmul(xv, wv, c),
            mesh=mesh, in_specs=(spec_x, P(None, "model")),
            out_specs=spec_x))
        out[m] = np.asarray(f(x, w))
    # the gathered activations are quantised once; the GEMM amplifies by
    # at most the contraction's L1 mass
    atol = _codec_atol(np.asarray(x)) * float(
        np.max(np.sum(np.abs(np.asarray(w)), axis=0))) + 1e-4
    np.testing.assert_allclose(out["smi:compressed"], out["bulk"], atol=atol)


# ---------------------------------------------------------------------------
# reduce-scatter regression: error bounded independent of P
# ---------------------------------------------------------------------------


def _rs_rel_error(Pn, path, m=256, seed=0):
    """Max relative error of a quantized ring reduce-scatter at size Pn.

    ``path="contribution"`` is the fixed schedule (stream_reduce_scatter
    over the compressed transport); ``path="accumulator"`` reconstructs
    the seed's buggy loop — re-round the travelling partial every hop —
    via the generic lossy ``shift_accumulate``.
    """
    mesh = make_test_mesh((Pn,), ("x",))
    comm = Communicator.create("x", (Pn,), topology=Topology.ring(Pn))
    rng = np.random.RandomState(seed)
    x = rng.randn(Pn, Pn * m).astype(np.float32)

    def fn(v):
        t = get_transport("compressed")
        if path == "contribution":
            return stream_reduce_scatter(v[0], comm, transport=t)[None]
        xb = v[0].reshape(Pn, m)
        r = comm.rank()

        def cc(i):
            return jax.lax.dynamic_index_in_dim(xb, i, 0, keepdims=False)

        acc = cc((r - 1) % Pn)
        for s in range(1, Pn):
            acc = t.shift_accumulate(acc, cc((r - s - 1) % Pn), comm, +1)
        return acc[None]

    y = np.asarray(jax.jit(jax.shard_map(
        fn, mesh=mesh, in_specs=P("x"), out_specs=P("x")))(jnp.asarray(x)))
    want = x.sum(axis=0).reshape(Pn, m)
    err = max(np.max(np.abs(y[r] - want[r])) for r in range(Pn))
    return err / np.max(np.abs(want))


def test_reduce_scatter_error_bounded_in_P(devices8):
    """The regression: the once-quantised contribution schedule's error
    saturates as P grows, while the old quantize-the-accumulator loop's
    keeps growing — and the new path beats the old at P=8."""
    new = {Pn: _rs_rel_error(Pn, "contribution") for Pn in (2, 4, 8)}
    old = {Pn: _rs_rel_error(Pn, "accumulator") for Pn in (2, 4, 8)}
    # bounded independent of P: doubling P=4 -> P=8 moves the error by
    # at most 15% (measured ~3%), and everything stays within a few
    # quantisation steps of the codec bound
    assert new[8] <= new[4] * 1.15, new
    assert new[8] <= 4.0 / 254.0, new
    # the seed's accumulator path compounds: clearly growing at each
    # doubling, and strictly worse than the fix at P=8
    assert old[8] >= old[4] * 1.3, old
    assert old[4] >= old[2] * 1.3, old
    assert new[8] < old[8]


def test_error_feedback_residual_carries_and_resets(devices8):
    """EF residuals persist across hops inside one trace and silently
    reset (no tracer leak) when the instance is reused in a new trace."""
    mesh, comm, spec = TOPOLOGIES["ring"]()
    t = get_transport("compressed")
    x1 = jnp.asarray(np.random.RandomState(8).randn(8, 64), jnp.float32)
    x2 = jnp.asarray(np.random.RandomState(9).randn(8, 32), jnp.float32)

    def fn(v):
        return stream_reduce_scatter(v[0], comm, transport=t)[None]

    for x in (x1, x2):  # second shape forces a fresh trace
        y = np.asarray(jax.jit(jax.shard_map(
            fn, mesh=mesh, in_specs=spec, out_specs=spec))(x))
        want = np.asarray(x).sum(axis=0).reshape(8, -1)
        atol = _codec_atol(np.asarray(x), 8)
        for r in range(8):
            np.testing.assert_allclose(y[r], want[r], atol=atol)


# ---------------------------------------------------------------------------
# wire-byte accounting: traced stats == netsim prediction, exactly
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("topo", sorted(TOPOLOGIES))
def test_wire_bytes_exact_p2p(topo, devices8):
    mesh, comm, spec = TOPOLOGIES[topo]()
    shape, n_chunks, dst = (8, 16), 4, 5
    x = jnp.asarray(np.random.RandomState(10).randn(8, *shape), jnp.float32)
    t = get_transport("compressed")

    def fn(v):
        return stream_p2p(v[0], src=0, dst=dst, comm=comm,
                          n_chunks=n_chunks, transport=t)[None]

    jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec))(x)
    steps, nbytes = predict_transport_stats(
        comm, "p2p", shape=shape, src=0, dst=dst, n_chunks=n_chunks,
        transport="compressed",
    )
    assert t.stats.steps == steps
    assert t.stats.bytes_moved == nbytes
    # and it really is the compressed byte count, not the f32 one
    assert nbytes < 128 * 4 * steps


def test_wire_bytes_exact_shift_and_allgather(devices8):
    mesh, comm, spec = TOPOLOGIES["ring"]()
    shape = (4, 8)
    x = jnp.asarray(np.random.RandomState(11).randn(8, *shape), jnp.float32)

    t = get_transport("compressed")

    def fn(v):
        return t.shift(v[0], comm)[None]

    jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec))(x)
    steps, nbytes = predict_transport_stats(
        comm, "shift", shape=shape, transport="compressed")
    assert (t.stats.steps, t.stats.bytes_moved) == (steps, nbytes)
    assert nbytes == int8_wire_nbytes(32)

    t2 = get_transport("compressed")

    def fn2(v):
        return stream_allgather(v[0], comm, transport=t2)[None]

    jax.jit(jax.shard_map(fn2, mesh=mesh, in_specs=spec, out_specs=spec))(x)
    steps, nbytes = predict_transport_stats(
        comm, "allgather", shape=shape, transport="compressed")
    assert (t2.stats.steps, t2.stats.bytes_moved) == (steps, nbytes)


def test_schedule_loop_rolled_scaling_matches_unrolled(devices8):
    """Satellite audit of `_schedule_loop`'s one-iteration stat scaling:
    the rolled fori_loop path and a forced-unrolled run tally identical
    steps/bytes for the chunked chain schedule, on both the raw and the
    compressed wire (per-step bytes are constant by construction)."""
    mesh, comm, spec = TOPOLOGIES["ring"]()
    x = jnp.asarray(np.random.RandomState(12).randn(8, 16), jnp.float32)

    def stats_for(tkey, unroll):
        t = get_transport(tkey)
        if unroll:
            t.runtime_stats = True  # force _schedule_loop's unrolled path
        def fn(v):
            return chan_bcast(v[0], comm, root=0, n_chunks=4,
                              transport=t)[None]
        jax.jit(jax.shard_map(
            fn, mesh=mesh, in_specs=spec, out_specs=spec))(x)
        return t.stats.steps, t.stats.bytes_moved

    for tkey in ("static", "compressed"):
        rolled = stats_for(tkey, unroll=False)
        unrolled = stats_for(tkey, unroll=True)
        assert rolled == unrolled, (tkey, rolled, unrolled)


# ---------------------------------------------------------------------------
# autotuned dispatch over the enlarged plan space
# ---------------------------------------------------------------------------


def test_auto_plan_runs_compressed_cell(devices8):
    """bcast(plan="auto") at a bandwidth-bound size (the tuner's int8
    cell) runs the compressed wire and stays within the codec bound."""
    mesh = make_test_mesh((8,), ("x",))
    comm = Communicator.create("x", (8,), topology=Topology.ring(8))
    spec = P("x")
    plan = comm.plan("bcast", 1 << 20)
    assert plan.wire == "int8", plan  # acceptance: 1 MiB is compressed
    elems = (1 << 20) // 4
    x = jnp.asarray(
        np.random.RandomState(13).randn(8, elems // 128, 128), jnp.float32)

    def fn(v):
        return bcast(v[0], comm, root=0)[None]

    y = np.asarray(jax.jit(jax.shard_map(
        fn, mesh=mesh, in_specs=spec, out_specs=spec))(x))
    xa = np.asarray(x)
    for r in range(8):
        np.testing.assert_allclose(y[r], xa[0], atol=_codec_atol(xa))


def test_auto_plan_integer_payload_falls_back_to_raw(devices8):
    """An int8-wire cell must not apply to integer payloads: the plan
    falls back to the raw wire and the result stays exact (bcast and
    stream_p2p, both on the compressed 1 MiB cell)."""
    mesh = make_test_mesh((8,), ("x",))
    comm = Communicator.create("x", (8,), topology=Topology.ring(8))
    spec = P("x")
    assert comm.plan("bcast", 1 << 20).wire == "int8"  # the tempting cell
    elems = (1 << 20) // 4
    x = jnp.asarray(
        np.random.RandomState(15).randint(-1000, 1000, (8, elems)),
        jnp.int32)

    def fn(v):
        b = bcast(v[0], comm, root=0)
        p = stream_p2p(v[0], src=0, dst=5, comm=comm, plan="auto")
        return b[None], p[None]

    b, p = jax.jit(jax.shard_map(
        fn, mesh=mesh, in_specs=spec, out_specs=(spec, spec)))(x)
    xa = np.asarray(x)
    for r in range(8):
        np.testing.assert_array_equal(np.asarray(b)[r], xa[0])
    np.testing.assert_array_equal(np.asarray(p)[5], xa[0])


# ---------------------------------------------------------------------------
# plumbing: registry / comm_mode / shims / guards / dtypes
# ---------------------------------------------------------------------------


def test_registry_wrapper_keys():
    t = get_transport("compressed")
    assert isinstance(t, CompressedTransport)
    assert t.inner.name == "static"
    assert t.stats is t.inner.stats  # shared counters: wire-byte accurate
    tp = get_transport("compressed:packet")
    assert tp.inner.name == "packet"
    assert tp.runtime_stats  # inherited from the packet inner
    assert is_transport_key("compressed:fused")
    assert not is_transport_key("compressed:warp-drive")
    with pytest.raises(KeyError):
        get_transport("compressed:warp-drive")
    assert resolve_comm_mode("smi:compressed") == ("smi", "compressed")
    assert resolve_comm_mode("smi:compressed:packet") == \
        ("smi", "compressed:packet")
    from repro.configs.registry import COMM_MODES

    assert "smi:compressed" in COMM_MODES


def test_deprecated_quantize_kwargs_shim(devices8):
    """The legacy kwargs warn and route through the compressed transport
    (same once-quantised schedule, custom codec)."""
    mesh, comm, spec = TOPOLOGIES["ring"]()
    x = jnp.asarray(np.random.RandomState(14).randn(8, 64), jnp.float32)
    q, dq = make_int8_codec(axis_elems=64)

    def fn(v):
        return chan_allreduce(v[0], comm, quantize=q, dequantize=dq)[None]

    with pytest.warns(DeprecationWarning, match="transport='compressed'"):
        y = np.asarray(jax.jit(jax.shard_map(
            fn, mesh=mesh, in_specs=spec, out_specs=spec))(x))
    want = np.asarray(x).sum(axis=0)
    np.testing.assert_allclose(
        y[0], want, atol=_codec_atol(np.asarray(x), 8))


def test_compressed_integer_allreduce_raises(devices8):
    mesh, comm, spec = TOPOLOGIES["ring"]()
    x = jnp.ones((8, 16), jnp.int32)

    def fn(v):
        return chan_allreduce(
            v[0], comm, transport=get_transport("compressed"))[None]

    with pytest.raises(TypeError, match="lossy"):
        jax.jit(jax.shard_map(
            fn, mesh=mesh, in_specs=spec, out_specs=spec))(x)


def test_runtime_stats_reuse_across_traces_raises(devices8):
    """The documented packet-backend footgun now fails loudly: reusing a
    runtime_stats instance across separately-traced functions raises
    instead of silently corrupting `stats`."""
    mesh, comm, spec = TOPOLOGIES["ring"]()
    t = get_transport("packet")

    def fn(v):
        return t.shift(v[0], comm)[None]

    x1 = jnp.ones((8, 32), jnp.float32)
    jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec))(x1)
    x2 = jnp.ones((8, 64), jnp.float32)  # new shape -> new trace
    with pytest.raises(RuntimeError, match="reused across"):
        jax.jit(jax.shard_map(
            fn, mesh=mesh, in_specs=spec, out_specs=spec))(x2)
    # reset_stats() is the sanctioned way to reuse
    t.reset_stats()
    jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec))(x2)


def test_error_feedback_sync_hook():
    """optim.grad.ErrorFeedback.sync: residual = sent - delivered."""
    from repro.optim import ErrorFeedback

    g = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    ef = ErrorFeedback.init(g)
    lossy = lambda t: jax.tree.map(lambda v: jnp.round(v * 2) / 2, t)
    synced, ef = ErrorFeedback.sync(ef, g, lossy)
    res = np.asarray(ef["w"])
    np.testing.assert_allclose(
        res, np.asarray(g["w"]) - np.asarray(synced["w"]), atol=1e-7)
    # a second step re-injects the residual
    synced2, _ = ErrorFeedback.sync(ef, g, lossy)
    assert np.all(np.abs(np.asarray(synced2["w"]) +
                         np.asarray(synced["w"]) -
                         2 * np.asarray(g["w"])) <= 0.25 + 1e-7)
