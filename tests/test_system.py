"""End-to-end system behaviour tests (deliverable c, integration level)."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, cells
from repro.core import Communicator, Topology, make_test_mesh


def test_public_api_surface():
    """The composable public API the README documents must exist."""
    import repro.core as core
    import repro.kernels as kernels
    import repro.models as models

    for name in ["Communicator", "Topology", "stream_p2p", "stream_allgather",
                  "stream_bcast", "open_channel", "push", "pop"]:
        assert hasattr(core, name), name
    for name in ["matmul", "flash_attention", "stencil_step", "ssd_scan"]:
        assert hasattr(kernels, name), name
    for name in ["init_lm", "lm_loss", "lm_decode_step"]:
        assert hasattr(models, name), name


def test_cells_cover_assignment():
    """40 (arch x shape) cells; long_500k runs only for sub-quadratic archs."""
    cs = cells()
    assert len(cs) == 40
    skips = [(a, s) for a, s, skip in cs if skip]
    assert all(s == "long_500k" for _, s in skips)
    ran_long = {a for a, s, skip in cs if s == "long_500k" and not skip}
    assert ran_long == {"mamba2-2.7b", "recurrentgemma-9b"}


def test_dryrun_artifacts_if_present():
    """When the dry-run sweep has been run, every recorded cell must be OK
    on both meshes (the runnability contract)."""
    path = os.path.join(os.path.dirname(__file__), "..", "dryrun_results.jsonl")
    if not os.path.exists(path):
        pytest.skip("dry-run sweep not run in this checkout")
    recs = {}
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            recs[(r["arch"], r["shape"], r["mesh"])] = r
    meshes = {m for _, _, m in recs}
    assert {"16x16", "2x16x16"} <= meshes
    bad = [k for k, r in recs.items() if not r["ok"]]
    assert not bad, f"failed dry-run cells: {bad}"


def test_route_tables_regenerate_for_any_world_size():
    """Elasticity invariant: the route generator covers every world size the
    rescue path can produce (paper: re-route without rebuild)."""
    for n in range(2, 17):
        comm = Communicator.create("x", (n,), topology=Topology.bus(n))
        assert comm.route_table.n_hops(0, n - 1) == n - 1


def test_smi_and_bulk_modes_agree_numerically():
    """One tiny forward under both comm modes: identical activations."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_arch, smoke
    from repro.mesh.api import make_ctx, ParallelCtx
    from repro.models import init_lm, lm_specs, lm_loss
    from repro.data import make_inputs
    from repro.configs.base import ShapeConfig

    cfg = smoke(get_arch("minitron-4b"))
    shape = ShapeConfig("t", 32, 4, "train")
    inp = make_inputs(cfg, shape, seed=9)
    params = init_lm(jax.random.PRNGKey(0), cfg, ParallelCtx())
    mesh = make_test_mesh((2, 4), ("data", "model"))
    losses = {}
    for mode in ["smi", "bulk"]:
        ctx = make_ctx(mesh, model_axis="model", batch_axes=("data",), comm_mode=mode)
        specs = lm_specs(cfg, ctx)
        psh = jax.tree.map(
            lambda v, s: jax.device_put(v, NamedSharding(mesh, s)),
            params, specs, is_leaf=lambda x: hasattr(x, "shape"),
        )

        def fn(p, t, l):
            loss, _ = lm_loss(p, t, l, cfg, ctx, remat="none")
            return jnp.broadcast_to(loss, (1,))

        out = jax.jit(jax.shard_map(
            fn, mesh=mesh, in_specs=(specs, P("data"), P("data")),
            out_specs=P(("data", "model"))))(psh, inp["tokens"], inp["labels"])
        losses[mode] = np.asarray(out)
    np.testing.assert_allclose(losses["smi"], losses["bulk"], rtol=2e-5, atol=2e-5)
