"""Per-architecture smoke tests (deliverable f): reduced configs, one
forward/train step + one decode step on CPU; shape + finite checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SHAPES, get_arch, smoke
from repro.configs.base import ShapeConfig
from repro.data import make_inputs
from repro.mesh.api import ParallelCtx
from repro.models import (
    init_lm,
    lm_caches,
    lm_decode_step,
    lm_loss,
    lm_specs,
)

CTX = ParallelCtx()  # single-device
SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")
DECODE_SHAPE = ShapeConfig("smoke_dec", seq_len=32, global_batch=2, kind="decode")


@pytest.fixture(scope="module", params=sorted(ARCHS))
def arch(request):
    return request.param


def test_smoke_train_step(arch):
    cfg = smoke(get_arch(arch))
    key = jax.random.PRNGKey(0)
    params = init_lm(key, cfg, CTX)
    # spec tree must mirror the param tree exactly
    specs = lm_specs(cfg, CTX)
    assert jax.tree.structure(
        jax.tree.map(lambda _: 0, params)
    ) == jax.tree.structure(
        jax.tree.map(lambda _: 0, specs, is_leaf=lambda s: not isinstance(s, (dict, tuple)))
    )

    inp = make_inputs(cfg, SMOKE_SHAPE, seed=1)

    def loss_fn(p):
        loss, (ce, aux) = lm_loss(
            p, inp["tokens"], inp["labels"], cfg, CTX,
            extra_embeds=inp.get("pixel_embeds"), remat="none",
        )
        return loss, (ce, aux)

    (loss, (ce, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
    assert np.isfinite(float(loss)), f"{arch}: loss not finite"
    assert float(ce) > 0, f"{arch}: CE should be positive at init"
    # CE near ln(V) at init (uniform) — sanity of the vocab-parallel CE
    assert float(ce) < np.log(cfg.padded_vocab) + 2.0
    gnorm = jax.tree.reduce(
        lambda a, b: a + b, jax.tree.map(lambda g: jnp.sum(g * g), grads)
    )
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0, f"{arch}: bad grads"


def test_smoke_decode_step(arch):
    cfg = smoke(get_arch(arch))
    key = jax.random.PRNGKey(0)
    params = init_lm(key, cfg, CTX)
    B = 2
    caches = lm_caches(cfg, B, capacity=32, ctx=CTX)
    inp = make_inputs(cfg, DECODE_SHAPE, seed=2, batch_override=B)
    tok = inp["token"]
    logits, caches = lm_decode_step(params, caches, tok, jnp.asarray(5), cfg, CTX)
    V = cfg.padded_vocab
    want = (B, V, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B, V)
    assert logits.shape == want, f"{arch}: {logits.shape} != {want}"
    assert np.all(np.isfinite(np.asarray(logits))), f"{arch}: non-finite logits"
    # second step reuses the cache
    logits2, _ = lm_decode_step(params, caches, tok, jnp.asarray(6), cfg, CTX)
    assert np.all(np.isfinite(np.asarray(logits2)))


def test_all_archs_present():
    assert len(ARCHS) == 10
    fams = {c.family for c in ARCHS.values()}
    assert fams == {"dense", "moe", "ssm", "hybrid", "vlm", "audio"}


def test_param_counts_in_band():
    """Analytic param counts should be within ~25% of the advertised sizes
    (they're approximations; catches transposed-dim config bugs)."""
    expect = {
        "glm4-9b": 9e9, "yi-6b": 6e9, "minitron-4b": 4.2e9,
        "command-r-plus-104b": 104e9, "mamba2-2.7b": 2.7e9,
        "recurrentgemma-9b": 9e9, "qwen3-moe-30b-a3b": 30e9,
        "llama4-scout-17b-a16e": 109e9,  # total (active 17b)
        "internvl2-1b": 0.6e9,  # LLM backbone only (vit excluded)
        "musicgen-medium": 1.5e9,
    }
    for name, want in expect.items():
        got = get_arch(name).param_count()
        assert 0.5 * want < got < 1.8 * want, f"{name}: {got:.2e} vs {want:.2e}"
