"""Optional-hypothesis shim for the property tests.

The container image may not ship ``hypothesis``.  Property-based tests are
a bonus tier: when the library is missing they individually skip, while the
example-based tests in the same modules keep running.  Import from here
instead of ``hypothesis`` directly:

    from _hyp import given, settings, st
"""

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - depends on image contents
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_a, **_k):
        def deco(fn):
            return pytest.mark.skip(reason="hypothesis not installed")(fn)

        return deco

    def settings(*_a, **_k):
        def deco(fn):
            return fn

        return deco

    class _NullStrategies:
        """Accepts any strategy construction; values are never drawn
        because ``given`` skips the test."""

        def __getattr__(self, _name):
            def _strategy(*_a, **_k):
                return None

            return _strategy

    st = _NullStrategies()
