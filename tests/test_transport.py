"""Cross-backend transport equivalence (the transport-refactor contract).

The same collective call site, selected only by a string key, must produce
*bit-identical* results under every transport backend — static trace-time
schedules, the dynamic packet router run end-to-end, and the Pallas-fused
hot path — on both the physical torus and the snake-bus logical topology,
with zero packet overflow (lossless routing) asserted for every router run.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _chan import chan_allreduce, chan_bcast
from jax.sharding import PartitionSpec as P

from repro.core import (
    Communicator,
    Topology,
    make_test_mesh,
    stream_allgather,
    stream_p2p,
)
from repro.core.router import snake_bus
from repro.mesh.api import colparallel_matmul, make_ctx
from repro.transport import (
    Transport,
    available_transports,
    get_transport,
    resolve_comm_mode,
    resolve_transport,
)

BACKENDS = ("static", "packet", "fused")


def _transport(name):
    # fused: force the Pallas kernel through the interpreter on CPU so the
    # fused code path (not just its jnp fallback) is what gets verified
    if name == "fused":
        return get_transport(name, interpret=jax.default_backend() != "tpu")
    return get_transport(name)


def _run_collectives(comm, mesh, spec, x, backend):
    """One traced fn running Bcast + AllGather + AllReduce over ``backend``,
    returning the packet-overflow count as a regular output."""

    def fn(v):
        t = _transport(backend)
        bc = chan_bcast(v[0], comm, root=0, n_chunks=4, transport=t)
        ag = stream_allgather(v[0], comm, transport=t)
        ar = chan_allreduce(v[0], comm, transport=t)
        ovf = t.stats.overflow
        if ovf is None:
            ovf = jnp.zeros((), jnp.int32)
        return bc[None], ag[None], ar[None], ovf[None]

    out = jax.jit(
        jax.shard_map(fn, mesh=mesh, in_specs=spec, out_specs=(spec,) * 4)
    )(x)
    return jax.tree.map(np.asarray, out)


TOPOLOGIES = {
    "torus": lambda: (
        make_test_mesh((8,), ("x",)),
        Communicator.create("x", (8,)),
        P("x"),
    ),
    "snake_bus": lambda: (
        make_test_mesh((2, 4), ("x", "y")),
        Communicator.create(("x", "y"), (2, 4), topology=snake_bus((2, 4))),
        P(("x", "y")),
    ),
    # non-default routing scheme: the packet router must follow the
    # communicator's own (BFS) routes, not recompute DOR ones
    "torus_bfs": lambda: (
        make_test_mesh((8,), ("x",)),
        Communicator.create("x", (8,), routing_scheme="bfs"),
        P("x"),
    ),
}


@pytest.mark.parametrize("topo", sorted(TOPOLOGIES))
def test_collectives_bit_identical_across_backends(topo, devices8):
    mesh, comm, spec = TOPOLOGIES[topo]()
    x = jnp.asarray(np.random.RandomState(0).randn(8, 16), jnp.float32)

    results = {b: _run_collectives(comm, mesh, spec, x, b) for b in BACKENDS}
    for b in BACKENDS:
        ovf = results[b][3]
        assert int(ovf.sum()) == 0, f"{b} on {topo}: packet overflow {ovf}"
    for b in BACKENDS[1:]:
        for k, name in enumerate(["bcast", "allgather", "allreduce"]):
            np.testing.assert_array_equal(
                results[BACKENDS[0]][k], results[b][k],
                err_msg=f"{name}: {b} != {BACKENDS[0]} on {topo}",
            )


@pytest.mark.parametrize("topo", sorted(TOPOLOGIES))
@pytest.mark.parametrize("backend", BACKENDS)
def test_p2p_multihop_matches_static(topo, backend, devices8):
    mesh, comm, spec = TOPOLOGIES[topo]()
    x = jnp.asarray(np.random.RandomState(1).randn(8, 8), jnp.float32)

    def fn(v):
        y = stream_p2p(
            v[0], src=0, dst=5, comm=comm, n_chunks=2,
            transport=_transport(backend),
        )
        return y[None]

    got = np.asarray(
        jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec))(x)
    )
    want = np.zeros_like(np.asarray(x))
    want[5] = np.asarray(x)[0]
    np.testing.assert_array_equal(got, want)


def test_packet_overflow_counter_reports_drops(devices8):
    """An under-provisioned transit queue must lose packets AND say so —
    the counter is the lossless-run oracle of the equivalence tests, so
    prove it can fire (no silent truncation)."""
    mesh = make_test_mesh((2, 4), ("x", "y"))
    comm = Communicator.create(("x", "y"), (2, 4))
    spec = P(("x", "y"))

    def fn(v):
        # Two DOR routes (4->2 and 7->1) converge on rank 0 and both leave
        # via its +y link: arrivals outpace the drain, and a 1-deep transit
        # queue must drop and count.
        t = get_transport("packet", pkt_elems=4, transit_cap=1)
        y = t.permute(v[0], comm, [(4, 2), (7, 1)])
        return y[None], jnp.asarray(t.stats.overflow, jnp.int32)[None]

    x = jnp.ones((8, 64), jnp.float32)
    _y, ovf = jax.jit(
        jax.shard_map(fn, mesh=mesh, in_specs=spec, out_specs=(spec, spec))
    )(x)
    assert int(np.asarray(ovf).sum()) > 0


def test_registry_keys_and_resolution():
    assert set(BACKENDS) <= set(available_transports())
    t = get_transport("packet")
    assert isinstance(t, Transport) and t.name == "packet"
    with pytest.raises(KeyError):
        get_transport("carrier-pigeon")
    # per-communicator default + per-call override
    comm = Communicator.create("x", (4,), transport="fused")
    assert resolve_transport(None, comm).name == "fused"
    assert resolve_transport("static", comm).name == "static"
    assert resolve_transport(t, comm) is t
    assert comm.with_transport("packet").transport == "packet"


def test_resolve_comm_mode():
    assert resolve_comm_mode("smi") == ("smi", "static")
    assert resolve_comm_mode("smi:packet") == ("smi", "packet")
    assert resolve_comm_mode("bulk") == ("bulk", "static")
    assert resolve_comm_mode(None)[0] == "none"
    with pytest.raises(ValueError):
        resolve_comm_mode("smi:warp-drive")
    with pytest.raises(ValueError):
        resolve_comm_mode("bulk:static")


def test_fused_accumulate_matches_jnp():
    from repro.transport.fused import fused_accumulate

    rng = np.random.RandomState(2)
    for shape in [(5,), (33, 7), (4, 128), (1000,)]:
        a = jnp.asarray(rng.randn(*shape), jnp.float32)
        b = jnp.asarray(rng.randn(*shape), jnp.float32)
        got = fused_accumulate(a, b, interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(a + b))


@pytest.mark.parametrize("mode", ["smi:static", "smi:packet", "smi:fused"])
def test_model_layer_helper_over_backends(mode, devices8):
    """The mesh-api helper the model layers call (colparallel_matmul) runs
    unmodified under every smi:<backend> comm_mode and agrees with bulk."""
    mesh = make_test_mesh((2, 4), ("data", "model"))
    rng = np.random.RandomState(3)
    x = jnp.asarray(rng.randn(8, 16), jnp.float32)   # (t, K) seq-sharded
    w = jnp.asarray(rng.randn(16, 12), jnp.float32)  # (K, N) col-sharded

    def make_fn(m):
        # off TPU the fused backend falls back to ppermute + jnp add — the
        # documented CPU path; the kernel itself is covered above
        ctx = make_ctx(mesh, model_axis="model", batch_axes=("data",),
                       comm_mode=m)

        def fn(xv, wv):
            return colparallel_matmul(xv, wv, ctx)

        return fn

    spec_x = P(("data", "model"))
    out = {}
    for m in ["bulk", mode]:
        f = jax.jit(jax.shard_map(
            make_fn(m), mesh=mesh,
            in_specs=(spec_x, P(None, "model")), out_specs=spec_x,
        ))
        out[m] = np.asarray(f(x, w))
    np.testing.assert_allclose(out[mode], out["bulk"], rtol=1e-5, atol=1e-5)


def test_tbl_cache_is_bounded_lru():
    from repro.transport.packet import TBL_CACHE_MAX, lru_get

    cache: dict = {}
    calls = []
    for i in range(TBL_CACHE_MAX + 4):
        lru_get(cache, i, lambda i=i: calls.append(i) or i * 10)
    assert len(cache) == TBL_CACHE_MAX
    assert 0 not in cache and 3 not in cache  # oldest evicted
    # a hit refreshes recency instead of rebuilding
    n_calls = len(calls)
    oldest = next(iter(cache))
    assert lru_get(cache, oldest, lambda: None) == oldest * 10
    assert len(calls) == n_calls
    # ...so the refreshed key survives the next eviction round
    lru_get(cache, "new", lambda: "v")
    assert oldest in cache


def test_packet_pallas_registry_and_equivalence(devices8):
    """"packet:pallas" pins the router to the Pallas tick kernel; it must
    resolve as a first-class transport key (comm modes included) and move
    the exact bytes the scalar-reference packet backend moves."""
    from repro.transport.packet import PallasPacketTransport

    t = get_transport("packet:pallas")
    assert isinstance(t, PallasPacketTransport)
    assert t.router_impl == "pallas"
    assert resolve_comm_mode("smi:packet:pallas") == ("smi", "packet:pallas")

    mesh, comm, spec = TOPOLOGIES["torus"]()
    x = jnp.asarray(np.random.RandomState(7).randn(8, 12), jnp.float32)
    pairs = [(i, (i + 3) % 8) for i in range(8)]

    def run(key):
        def fn(v):
            tp = get_transport(key, pkt_elems=8)
            y = tp.permute(v[0], comm, pairs)
            return y[None], jnp.asarray(tp.stats.overflow, jnp.int32)[None]

        return jax.tree.map(np.asarray, jax.jit(jax.shard_map(
            fn, mesh=mesh, in_specs=spec, out_specs=(spec, spec)))(x))

    ref, ovf_ref = run("packet")
    got, ovf = run("packet:pallas")
    assert int(ovf_ref.sum()) == 0 and int(ovf.sum()) == 0
    np.testing.assert_array_equal(ref, got)
