"""TP/SP model parallelism: parallel loss/decode == single-device oracle.

The strongest integration test in the suite: the full LM (all four block
families) runs inside shard_map over a (data=2, model=4) mesh in both
``smi`` (streamed ring collectives) and ``bulk`` (XLA collectives) modes and
must reproduce the single-device loss and decode logits.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch, smoke
from repro.configs.base import ShapeConfig
from repro.data import make_inputs
from repro.core import make_test_mesh
from repro.mesh.api import ParallelCtx, make_ctx
from repro.models import (
    init_lm,
    lm_caches,
    lm_cache_specs,
    lm_decode_step,
    lm_loss,
    lm_specs,
)

TP = 4
DP = 2
SHAPE = ShapeConfig("par", seq_len=32, global_batch=4, kind="train")

# archs chosen to cover all block families; dims divisible by TP
PAR_ARCHS = ["glm4-9b", "qwen3-moe-30b-a3b", "mamba2-2.7b", "recurrentgemma-9b"]


def _cfg(name):
    c = smoke(get_arch(name))
    return c


@pytest.fixture(scope="module")
def mesh():
    return make_test_mesh((DP, TP), ("data", "model"))


def _single_device_loss(cfg, inp):
    """Oracle: per-DP-shard single-device losses (MoE capacity is a
    per-dispatch-group quantity, so the comparison must shard-match)."""
    ctx = ParallelCtx()
    params = init_lm(jax.random.PRNGKey(0), cfg, ctx)
    B = inp["tokens"].shape[0]
    per = B // DP
    losses = []
    for d in range(DP):
        sl = slice(d * per, (d + 1) * per)
        loss, _ = lm_loss(
            params, inp["tokens"][sl], inp["labels"][sl], cfg, ctx,
            extra_embeds=None if "pixel_embeds" not in inp
            else inp["pixel_embeds"][sl],
            remat="none",
        )
        losses.append(float(loss))
    return params, np.asarray(losses)


@pytest.mark.parametrize("mode", ["bulk", "smi"])
@pytest.mark.parametrize("arch", PAR_ARCHS)
def test_parallel_loss_matches_single(arch, mode, mesh):
    cfg = _cfg(arch)
    inp = make_inputs(cfg, SHAPE, seed=3)
    params_full, want = _single_device_loss(cfg, inp)

    ctx = make_ctx(mesh, model_axis="model", batch_axes=("data",), comm_mode=mode)
    specs = lm_specs(cfg, ctx)
    # shard the oracle's full params onto the mesh per the spec tree
    params_sh = jax.tree.map(
        lambda v, s: jax.device_put(v, NamedSharding(mesh, s)),
        params_full, specs,
        is_leaf=lambda x: isinstance(x, jnp.ndarray) or hasattr(x, "shape"),
    )

    def fn(p, tokens, labels):
        loss, (ce, aux) = lm_loss(
            p, tokens, labels, cfg, ctx, remat="none",
        )
        # identical on every device; emit one scalar per device for checking
        return jnp.broadcast_to(loss, (1,))

    tok_spec = P("data") if cfg.n_codebooks == 1 else P("data")
    out = jax.jit(
        jax.shard_map(
            fn, mesh=mesh,
            in_specs=(specs, tok_spec, tok_spec),
            out_specs=P(("data", "model")),
        )
    )(params_sh, inp["tokens"], inp["labels"])
    got = np.asarray(out).reshape(DP, TP)
    # every device within a data group agrees (TP is exact)
    for d in range(DP):
        np.testing.assert_allclose(got[d], got[d, 0], rtol=1e-5)
    # each data group matches its single-device oracle
    np.testing.assert_allclose(got[:, 0], want, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("arch", PAR_ARCHS)
def test_parallel_decode_matches_single(arch, mesh):
    cfg = _cfg(arch)
    B = 2
    ctx1 = ParallelCtx()
    params_full = init_lm(jax.random.PRNGKey(0), cfg, ctx1)
    caches1 = lm_caches(cfg, B, capacity=32, ctx=ctx1)
    tok = jnp.asarray(
        np.random.RandomState(4).randint(
            0, cfg.vocab_size,
            (B, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B,),
        ),
        jnp.int32,
    )
    want, _ = lm_decode_step(params_full, caches1, tok, jnp.asarray(3), cfg, ctx1)

    ctx = make_ctx(mesh, model_axis="model", batch_axes=("data",), comm_mode="bulk")
    specs = lm_specs(cfg, ctx)
    params_sh = jax.tree.map(
        lambda v, s: jax.device_put(v, NamedSharding(mesh, s)),
        params_full, specs,
        is_leaf=lambda x: hasattr(x, "shape"),
    )

    def fn(p, t):
        caches = lm_caches(cfg, B // DP, capacity=32, ctx=ctx)
        logits, _ = lm_decode_step(
            p, caches, t, jnp.asarray(3), cfg, ctx, gather_logits=False
        )
        return logits

    out_spec = (
        P("data", "model", None) if cfg.n_codebooks > 1 else P("data", "model")
    )
    out = jax.jit(
        jax.shard_map(
            fn, mesh=mesh,
            in_specs=(specs, P("data")),
            out_specs=out_spec,
        )
    )(params_sh, tok)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(want), rtol=3e-4, atol=3e-4
    )


@pytest.mark.parametrize("arch", ["glm4-9b", "mamba2-2.7b", "recurrentgemma-9b"])
def test_shared_gather_opt_matches(arch, mesh):
    """Beyond-paper shared-gather layout must not change the math."""
    cfg = _cfg(arch)
    inp = make_inputs(cfg, SHAPE, seed=5)
    params_full, want = _single_device_loss(cfg, inp)

    ctx = make_ctx(mesh, model_axis="model", batch_axes=("data",),
                   comm_mode="smi", opt_shared_gather=True)
    specs = lm_specs(cfg, ctx)
    params_sh = jax.tree.map(
        lambda v, s: jax.device_put(v, NamedSharding(mesh, s)),
        params_full, specs, is_leaf=lambda x: hasattr(x, "shape"),
    )

    def fn(p, tokens, labels):
        loss, _ = lm_loss(p, tokens, labels, cfg, ctx, remat="none")
        return jnp.broadcast_to(loss, (1,))

    out = jax.jit(
        jax.shard_map(fn, mesh=mesh, in_specs=(specs, P("data"), P("data")),
                      out_specs=P(("data", "model")))
    )(params_sh, inp["tokens"], inp["labels"])
    got = np.asarray(out).reshape(DP, TP)
    np.testing.assert_allclose(got[:, 0], want, rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("arch", ["glm4-9b", "recurrentgemma-9b"])
def test_ring_attn_opt_matches(arch, mesh):
    """Ring-attention layout must reproduce the baseline loss."""
    cfg = _cfg(arch)
    inp = make_inputs(cfg, SHAPE, seed=6)
    params_full, want = _single_device_loss(cfg, inp)

    ctx = make_ctx(mesh, model_axis="model", batch_axes=("data",),
                   comm_mode="smi", opt_ring_attn=True)
    specs = lm_specs(cfg, ctx)
    params_sh = jax.tree.map(
        lambda v, s: jax.device_put(v, NamedSharding(mesh, s)),
        params_full, specs, is_leaf=lambda x: hasattr(x, "shape"),
    )

    def fn(p, tokens, labels):
        loss, _ = lm_loss(p, tokens, labels, cfg, ctx, remat="none")
        return jnp.broadcast_to(loss, (1,))

    out = jax.jit(
        jax.shard_map(fn, mesh=mesh, in_specs=(specs, P("data"), P("data")),
                      out_specs=P(("data", "model")))
    )(params_sh, inp["tokens"], inp["labels"])
    got = np.asarray(out).reshape(DP, TP)
    np.testing.assert_allclose(got[:, 0], want, rtol=3e-4, atol=3e-4)
