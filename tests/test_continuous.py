"""Continuous-batching engine tests (serving/continuous.py, DESIGN.md §13).

Four contracts:

* **wave-oracle bit-identity** — a request's greedy tokens are identical
  to the wave engine's (and to a solo run) no matter which slot it lands
  in, when it was admitted, or who its batch-mates are: per-slot
  positions, per-slot cache invalidation and per-slot prompt cursors must
  never leak state.  Checked single-device and tensor-parallel (ring and
  torus meshes, static and packet backends).
* **slot churn** — randomized staggered arrivals through a small slot
  pool drain completely and every output still equals its solo oracle
  (no cache-row leaks across admission/eviction churn).
* **migration exactness** — the packed byte image round-trip
  (``pack_slot`` -> ``unpack_slot``) equals the local ``copy_slot``
  oracle leaf-for-leaf, and a mid-decode slot migration never changes
  the request's remaining tokens.
* **persistent-channel lifecycle** — the serving pool's port claims
  survive trace exits and garbage collection, and are released only by
  engine shutdown / ``pool.close()``.

Plus the serving twin of the train-step accounting regression:
``netsim.predict_decode_step_stats`` equals the traced channel ledger to
the byte per ``serve.*`` tag (the ``launch/serve --validate-comm``
contract).
"""

import gc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, smoke
from repro.mesh.api import ParallelCtx
from repro.models import init_lm, lm_caches
from repro.serving import ContinuousEngine, Request, ServeEngine
from repro.serving.continuous import copy_slot, pack_slot, unpack_slot


@pytest.fixture(scope="module")
def engine_setup():
    cfg = smoke(get_arch("yi-6b"))
    params = init_lm(jax.random.PRNGKey(0), cfg, ParallelCtx())
    return cfg, params


def _reqs(prompts, max_new=4):
    return [Request(uid=i, prompt=list(p), max_new=max_new)
            for i, p in enumerate(prompts)]


def _solo_outs(cfg, params, prompts, *, max_new=4, engine_cls=ServeEngine):
    """{uid: tokens} with every request decoded alone — the oracle."""
    outs = {}
    for uid, p in enumerate(prompts):
        eng = engine_cls(cfg, params, batch_slots=1, capacity=64)
        eng.submit(Request(uid=uid, prompt=list(p), max_new=max_new))
        done = eng.run(max_steps=200)
        outs[uid] = done[0].out
    return outs


# ------------------------------------------------------ wave bit-identity


def test_continuous_matches_wave_engine(engine_setup):
    """Same prompts, same params: the continuous engine's greedy outputs
    are bit-identical to the wave engine's, slot-for-slot."""
    cfg, params = engine_setup
    prompts = [[5, 7, 9], [11, 3], [4], [8, 2, 6, 1]]

    wave = ServeEngine(cfg, params, batch_slots=2, capacity=64)
    for r in _reqs(prompts):
        wave.submit(r)
    wave_done = {r.uid: r.out for r in wave.run(max_steps=300)}

    cont = ContinuousEngine(cfg, params, batch_slots=2, capacity=64)
    for r in _reqs(prompts):
        cont.submit(r)
    cont_done = {r.uid: r.out for r in cont.run(max_steps=300)}

    assert sorted(cont_done) == sorted(wave_done) == [0, 1, 2, 3]
    for uid in wave_done:
        assert cont_done[uid] == wave_done[uid], f"uid {uid} diverged"


def test_mid_stream_admission_does_not_perturb_residents(engine_setup):
    """A request admitted into a freed slot mid-decode leaves its
    still-running batch-mates' outputs untouched — and its own output
    equals its solo run (the whole point of continuous batching)."""
    cfg, params = engine_setup
    prompts = [[5, 7, 9, 2], [11, 3], [6, 1, 4]]
    solo = _solo_outs(cfg, params, prompts, max_new=5)

    eng = ContinuousEngine(cfg, params, batch_slots=2, capacity=64)
    # slots=2, three requests: uid 2 is admitted into whichever slot
    # frees first, while the other resident keeps decoding
    for r in _reqs(prompts, max_new=5):
        eng.submit(r)
    done = {r.uid: r.out for r in eng.run(max_steps=300)}
    assert done == solo


def test_slot_churn_no_cache_row_leaks(engine_setup):
    """Property sweep: randomized prompts and Poisson-ish staggered
    arrivals through 3 slots — every request's output equals its solo
    oracle, so no admission/eviction sequence leaks cache rows."""
    cfg, params = engine_setup
    rng = np.random.RandomState(0)
    prompts = [list(rng.randint(1, cfg.padded_vocab, rng.randint(1, 5)))
               for _ in range(8)]
    ticks = np.cumsum(rng.randint(0, 4, len(prompts)))
    solo = _solo_outs(cfg, params, prompts, max_new=3)

    eng = ContinuousEngine(cfg, params, batch_slots=3, capacity=64)
    arrivals = [(int(t), r) for t, r in zip(ticks, _reqs(prompts, max_new=3))]
    done = {r.uid: r.out for r in eng.run(max_steps=400, arrivals=arrivals)}
    assert done == solo
    assert all(r is None for r in eng.slot_req)  # fully drained
    # bookkeeping: every request has admit/finish ticks, in order
    for uid in solo:
        assert eng.admit_step[uid] < eng.finish_step[uid]


# ------------------------------------------------------------- migration


def test_pack_unpack_matches_copy_slot_oracle(engine_setup):
    """unpack(pack(src), dst) == copy_slot(src, dst) leaf-for-leaf: the
    byte image is exact for every cache leaf dtype (bf16 KV, int32
    slot_pos, f32 state)."""
    cfg, params = engine_setup
    caches = lm_caches(cfg, 3, capacity=16, ctx=ParallelCtx())
    # make rows distinguishable: run two decode steps on real data
    eng = ContinuousEngine(cfg, params, batch_slots=3, capacity=16)
    for r in _reqs([[5, 7], [11, 3], [9]], max_new=2):
        eng.submit(r)
    eng.tick()
    eng.tick()
    caches = eng.caches

    want = copy_slot(caches, 0, 2)
    got = unpack_slot(caches, pack_slot(caches, 0), 2)
    for w, g in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(w), np.asarray(g))


def test_migration_preserves_output(engine_setup):
    """Migrating a request to a different slot mid-decode changes nothing
    about its remaining tokens (the image carries cache rows exactly;
    pos/cursor/last-token travel with it)."""
    cfg, params = engine_setup
    prompts = [[5, 7, 9], [11, 3]]
    solo = _solo_outs(cfg, params, prompts, max_new=6)

    eng = ContinuousEngine(cfg, params, batch_slots=3, capacity=64)
    for r in _reqs(prompts, max_new=6):
        eng.submit(r)
    for _ in range(4):
        eng.tick()
    moved = eng.migrate(0, 2)           # uid 0's cache image: slot 0 -> 2
    assert eng.slot_req[2] is moved and eng.slot_req[0] is None
    done = {r.uid: r.out for r in eng.run(max_steps=200)}
    done.update({r.uid: r.out for r in [moved] if r.done})
    assert done == solo


# ---------------------------------------------- tensor-parallel engines


TP_MESHES = {"ring": (1, 8), "torus": (2, 4)}


def _tp_cfg():
    # n_heads=8 divides both tp=8 and tp=4 evenly, so init_lm needs no
    # head padding and single-device params equal the TP layout exactly
    return smoke(get_arch("glm4-9b")).scaled(n_heads=8, d_model=128,
                                             d_ff=128)


@pytest.mark.parametrize("backend", ["static", "packet"])
@pytest.mark.parametrize("dims", list(TP_MESHES.values()),
                         ids=list(TP_MESHES))
def test_tp_continuous_matches_wave_oracle(dims, backend, devices8):
    """The tensor-parallel continuous engine on persistent channels
    produces the same greedy tokens as the single-device wave engine, on
    ring and torus meshes, static and packet backends."""
    from repro.launch.mesh import make_mesh
    from repro.launch.steps import build_continuous_serve

    cfg = _tp_cfg()
    params = init_lm(jax.random.PRNGKey(0), cfg, ParallelCtx())
    prompts = [[5, 7, 9], [11, 3], [4, 8]]

    wave = ServeEngine(cfg, params, batch_slots=2, capacity=32)
    for r in _reqs(prompts, max_new=3):
        wave.submit(r)
    want = {r.uid: r.out for r in wave.run(max_steps=200)}

    mesh = make_mesh(dims, ("data", "model"))
    rt = build_continuous_serve(cfg, mesh, comm_mode=f"smi:{backend}",
                                batch_slots=2, capacity=32)
    with ContinuousEngine(
        cfg, jax.device_put(params, rt["param_sharding"]), runtime=rt,
    ) as eng:
        for r in _reqs(prompts, max_new=3):
            eng.submit(r)
        got = {r.uid: r.out for r in eng.run(max_steps=200)}
    assert got == want, f"{backend} on {dims} diverged from wave oracle"


def test_persistent_pool_lifecycle(devices8):
    """The pool's port claims are strong: they survive trace exits and
    gc of the compiled step, and come back ONLY at pool close (engine
    shutdown) — the ChannelSpec(persistent=True) contract."""
    from repro.channels import PORTS
    from repro.launch.mesh import make_mesh
    from repro.launch.steps import build_continuous_serve

    cfg = _tp_cfg()
    mesh = make_mesh((1, 8), ("data", "model"))
    rt = build_continuous_serve(cfg, mesh, comm_mode="smi:static",
                                batch_slots=2, capacity=32)
    pool, comm = rt["pool"], rt["ctx"].model_comm
    assert pool is not None and not pool.closed

    # trace the decode step: every layer tag claims its persistent port
    pshapes = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg,
                                             rt["ctx"]))
    cshapes = jax.eval_shape(rt["init_caches"])
    tok = jax.ShapeDtypeStruct((2,), jnp.int32)
    pos = jax.ShapeDtypeStruct((2,), jnp.int32)
    lowered = rt["step"].lower(pshapes, cshapes, tok, pos)
    ports = pool.ports()
    assert len(ports) > 2  # layer channels + the migration pair
    assert all(tag.startswith("serve.") for tag in ports)
    assert set(ports.values()) <= set(PORTS.in_use(comm))

    # the claim outlives the trace: drop the lowered step, collect, and
    # re-trace — same specs, same ports, nothing lapsed in between
    del lowered
    gc.collect()
    assert set(ports.values()) <= set(PORTS.in_use(comm))
    rt["step"].lower(pshapes, cshapes, tok, pos)
    assert pool.ports() == ports

    pool.close()
    assert pool.closed
    assert not set(ports.values()) & set(PORTS.in_use(comm))


# ------------------------------------- predicted-vs-measured regression


def test_predict_decode_step_stats_matches_ledger(devices8):
    """The serving decode-step predictor equals the traced channel
    ledger to the byte per serve.* tag, migration legs included (the
    ``launch/serve --validate-comm`` contract, DESIGN.md §13)."""
    from repro.launch.mesh import make_mesh
    from repro.launch.steps import build_continuous_serve
    from repro.netsim import predict_decode_step_stats
    from repro.parallel import ledger

    class St:
        comm_mode = "smi:static"

    cfg = smoke(get_arch("yi-6b"))
    B, cap = 2, 32
    mesh = make_mesh((2, 4), ("data", "model"))
    rt = build_continuous_serve(cfg, mesh, comm_mode=St.comm_mode,
                                batch_slots=B, capacity=cap)
    pshapes = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg,
                                             rt["ctx"]))
    cshapes = jax.eval_shape(rt["init_caches"])
    tok = jax.ShapeDtypeStruct((B,), jnp.int32)
    pos = jax.ShapeDtypeStruct((B,), jnp.int32)
    slot = jax.ShapeDtypeStruct((), jnp.int32)
    with ledger.capture() as led:
        rt["step"].lower(pshapes, cshapes, tok, pos)
        infl = jax.eval_shape(rt["migrate_start"], cshapes, slot)
        rt["migrate_start"].lower(cshapes, slot)
        rt["migrate_finish"].lower(cshapes, infl, slot)
    rt["pool"].close()
    measured = {t: dict(e) for t, e in led.by_tag.items()}
    predicted = predict_decode_step_stats(cfg, (2, 4), B, St,
                                          capacity=cap, migrations=1)
    assert predicted == measured
