"""Substrate tests: optimizer, data pipeline, checkpoint, FT, serving,
and a short end-to-end training run (loss must decrease)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_arch, smoke
from repro.configs.base import ShapeConfig
from repro.core import make_test_mesh
from repro.data.pipeline import SyntheticTokenPipeline
from repro.ft import StepWatchdog, best_mesh_shape, elastic_restart_plan, run_with_restarts
from repro.launch.steps import TrainSettings, build_train
from repro.launch.train import train_loop
from repro.mesh.api import ParallelCtx
from repro.models import init_lm
from repro.optim import adamw_init, adamw_update, clip_by_global_norm, cosine_warmup
from repro.serving import Request, ServeEngine


def test_adamw_descends_quadratic():
    p = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(p)
    for _ in range(200):
        g = jax.grad(lambda q: jnp.sum(q["w"] ** 2))(p)
        p, opt = adamw_update(p, g, opt, lr=0.1, weight_decay=0.0)
    assert float(jnp.abs(p["w"]).max()) < 0.05


def test_clip_and_schedule():
    g = {"a": jnp.full((4,), 10.0)}
    gc, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    _, n2 = clip_by_global_norm(gc, 1.0)
    assert float(n2) == pytest.approx(1.0, rel=1e-5)
    lr0 = cosine_warmup(jnp.asarray(0), base_lr=1.0, warmup_steps=10, total_steps=100)
    lr5 = cosine_warmup(jnp.asarray(5), base_lr=1.0, warmup_steps=10, total_steps=100)
    lr100 = cosine_warmup(jnp.asarray(100), base_lr=1.0, warmup_steps=10, total_steps=100)
    assert float(lr0) == 0.0 and float(lr5) == pytest.approx(0.5)
    assert float(lr100) == pytest.approx(0.1, rel=1e-3)


def test_pipeline_deterministic_and_shifted():
    p1 = SyntheticTokenPipeline(100, 16, 4, seed=7)
    p2 = SyntheticTokenPipeline(100, 16, 4, seed=7)
    a, b = p1.next(), p2.next()
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
    p1.close()
    p2.close()


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    state = {"w": jnp.arange(6.0).reshape(2, 3), "n": jnp.asarray(3)}
    ck.save(state, 10)
    ck.save(state, 20, async_=True)
    ck.wait()
    assert ck.steps() == [10, 20]
    restored, manifest = ck.restore(state)
    np.testing.assert_allclose(np.asarray(restored["w"]), np.asarray(state["w"]))
    assert manifest["step"] == 20
    ck.save(state, 30)
    assert ck.steps() == [20, 30]  # keep=2 GC'd step 10


def test_checkpoint_restart_on_failure(tmp_path):
    """Injected failure -> driver restores latest checkpoint and resumes."""
    ck = Checkpointer(str(tmp_path))
    calls = []

    def make_loop(state, start):
        calls.append(start)
        for step in range(start, 10):
            state = {"x": state["x"] + 1}
            if step == 4 and len(calls) == 1:
                raise RuntimeError("simulated node loss")
            if step % 2 == 0:
                ck.save(state, step)
        return state

    final, restarts = run_with_restarts(make_loop, ck, {"x": jnp.asarray(0)})
    assert restarts == 1
    assert calls == [0, 2]  # resumed from the last completed checkpoint
    assert int(final["x"]) >= 6


def test_watchdog_flags_straggler():
    import time

    wd = StepWatchdog(threshold=5.0, alpha=0.5)
    wd.start()
    for s in range(3):
        time.sleep(0.01)
        assert not wd.lap(s)
    time.sleep(0.3)  # 30x slower
    assert wd.lap(3)
    assert wd.events and wd.events[0]["step"] == 3


def test_elastic_plan():
    plan = elastic_restart_plan(8, 6, prefer_model=4)
    assert plan["mesh_shape"] == (2, 3)  # (data, model), model=3 divides 6
    assert plan["topology"].n_ranks == 6
    assert best_mesh_shape(8) == (2, 4)
    assert best_mesh_shape(7) == (7, 1)


def test_serve_engine_waves():
    cfg = smoke(get_arch("yi-6b"))
    params = init_lm(jax.random.PRNGKey(0), cfg, ParallelCtx())
    eng = ServeEngine(cfg, params, batch_slots=2, capacity=64)
    for uid in range(4):  # 2 waves of 2
        eng.submit(Request(uid=uid, prompt=[5, 7, 9], max_new=4))
    done = eng.run(max_steps=200)
    assert len(done) == 4
    for r in done:
        assert len(r.out) == 4
        assert all(0 <= t < cfg.padded_vocab for t in r.out)
    # determinism: same engine config reproduces wave-1 outputs
    eng2 = ServeEngine(cfg, params, batch_slots=2, capacity=64)
    for uid in range(2):
        eng2.submit(Request(uid=uid, prompt=[5, 7, 9], max_new=4))
    done2 = eng2.run(max_steps=100)
    assert done2[0].out == done[0].out


@pytest.mark.parametrize("comm_mode", ["bulk", "smi"])
def test_train_loop_loss_decreases(tmp_path, comm_mode):
    """End-to-end: 16 steps of the full driver on a (2,4) mesh; CE drops."""
    cfg = smoke(get_arch("yi-6b"))
    mesh = make_test_mesh((2, 4), ("data", "model"))
    shape = ShapeConfig("t", seq_len=32, global_batch=8, kind="train")
    st = TrainSettings(comm_mode=comm_mode, remat="nothing", loss_chunks=1,
                       base_lr=3e-2, warmup_steps=3, total_steps=200)
    _, hist = train_loop(
        cfg, mesh, shape, st, steps=32, ckpt_dir=str(tmp_path),
        ckpt_every=10, log_every=4,
    )
    first = hist[0]["ce"]
    last = min(h["ce"] for h in hist[-3:])
    assert last < first - 0.1, f"CE did not decrease: {first} -> {last}"
    ck = Checkpointer(str(tmp_path))
    assert ck.latest_step() == 32


def test_train_restart_resumes(tmp_path):
    """Injected failure mid-train -> restart from checkpoint continues."""
    cfg = smoke(get_arch("yi-6b"))
    mesh = make_test_mesh((2, 4), ("data", "model"))
    shape = ShapeConfig("t", seq_len=32, global_batch=4, kind="train")
    st = TrainSettings(comm_mode="bulk", remat="nothing", loss_chunks=1,
                       base_lr=5e-3, warmup_steps=2, total_steps=12)
    ck = Checkpointer(str(tmp_path))

    art_state = {"attempts": 0}

    def make_loop(state, start):
        art_state["attempts"] += 1
        fail = 7 if art_state["attempts"] == 1 else None
        s, _ = train_loop(
            cfg, mesh, shape, st, steps=12, ckpt_dir=str(tmp_path),
            ckpt_every=4, log_every=100, state=state, start_step=start,
            fail_at=fail,
        )
        return s

    # state_like for restore structure: fresh init
    art = build_train(cfg, mesh, shape, st)
    state0 = art["init_state"](0)
    final, restarts = run_with_restarts(make_loop, ck, state0)
    assert restarts == 1
    assert ck.latest_step() == 12


def test_compressed_grad_training_step():
    """int8-compressed SMI gradient rings still train (loss finite+drops)."""
    cfg = smoke(get_arch("yi-6b"))
    mesh = make_test_mesh((2, 4), ("data", "model"))
    shape = ShapeConfig("t", seq_len=32, global_batch=4, kind="train")
    st = TrainSettings(comm_mode="smi", remat="nothing", loss_chunks=1,
                       base_lr=1e-2, warmup_steps=1, total_steps=8,
                       compressed_grads=True)
    _, hist = train_loop(cfg, mesh, shape, st, steps=8, log_every=7)
    assert np.isfinite(hist[-1]["ce"])
    assert hist[-1]["ce"] < hist[0]["ce"] + 0.1
