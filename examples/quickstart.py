"""Quickstart: the paper's Listing 1, on a JAX device mesh.

Rank 0 opens a send channel and pushes N elements from inside its pipelined
loop; rank 3 pops them as they arrive (pipeline latency = network hops).
Then the same message moves with a whole-message channel transfer, a
transient broadcast channel shares it with every rank, and the last section
opens the same channels over the int8 compressed-link backend — the
channel's spec carries the transport, so no call site changes.

    PYTHONPATH=src python examples/quickstart.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.channels import (
    default_channel_spec,
    open_bcast_channel,
    open_channel,
)
from repro.core import (
    Communicator,
    Topology,
    bcast,
    make_test_mesh,
    pvary,
)


def main():
    mesh = make_test_mesh((8,), ("x",))
    # the 8-FPGA bus of the paper's latency experiment
    comm = Communicator.create("x", (8,), topology=Topology.bus(8))
    N, SRC, DST = 12, 0, 3
    hops = comm.route_table.n_hops(SRC, DST)
    print(f"channel {SRC} -> {DST}: {hops} hops over {comm.topology.name}")

    # ---- element-level: SMI_Open_channel / SMI_Push / SMI_Pop ----------
    # Opening claims port 0 on the communicator's allocator; leaving the
    # `with` scope releases it (two live channels cannot share a port).
    def spmd(dummy):
        with open_channel(comm, count=N, src=SRC, dst=DST, port=0,
                          elem_shape=(), dtype=jnp.float32) as chan:
            acc = pvary(jnp.zeros((N,), jnp.float32), comm)

            def body(i, carry):
                chan, acc = carry
                data = jnp.sin(i.astype(jnp.float32))   # "compute" (Listing 1)
                chan = chan.push(data)                  # SMI_Push at rank 0
                chan, val, valid = chan.pop()           # SMI_Pop at rank 3
                slot = jnp.maximum(i - (hops - 1), 0)
                acc = jnp.where(valid, acc.at[slot].set(val), acc)
                return chan, acc

            chan, acc = jax.lax.fori_loop(0, N + hops - 1, body, (chan, acc))
        return acc[None] + 0 * dummy[:, :1]

    out = jax.jit(jax.shard_map(
        spmd, mesh=mesh, in_specs=P("x"), out_specs=P("x")))(jnp.zeros((8, 1)))
    got = np.asarray(out[DST]).ravel()
    want = np.sin(np.arange(N, dtype=np.float32))
    np.testing.assert_allclose(got, want, rtol=1e-6)
    print(f"push/pop pipeline delivered {N} elements:", got[:5], "...")

    # ---- transfer-level: whole messages over transient channels ---------
    msg = jnp.arange(8 * 64, dtype=jnp.float32).reshape(8, 64)

    def transfer(v):
        y = open_channel(comm, src=SRC, dst=DST, port=None,
                         n_chunks=8).transfer(v[0])
        b = open_bcast_channel(comm, root=DST, port=None,
                               n_chunks=4).transfer(y)
        return b[None]

    out = jax.jit(jax.shard_map(
        transfer, mesh=mesh, in_specs=P("x"), out_specs=P("x")))(msg)
    for r in range(8):
        np.testing.assert_allclose(np.asarray(out[r]), np.asarray(msg[SRC]))
    print("channel transfer + broadcast channel: all 8 ranks hold "
          "rank-0's message ✓")

    # ---- one-line autotuned collective ---------------------------------
    # bcast() consults the netsim tuning table (DESIGN.md §6): the link
    # simulator picks the schedule shape, chunk count and transport backend
    # for this topology and message size — no manual n_chunks to get wrong.
    out = jax.jit(jax.shard_map(
        lambda v: bcast(v[0], comm, root=SRC)[None],
        mesh=mesh, in_specs=P("x"), out_specs=P("x")))(msg)
    for r in range(8):
        np.testing.assert_allclose(np.asarray(out[r]), np.asarray(msg[SRC]))
    plan = comm.plan("bcast", msg[SRC].size * 4)
    print(f"autotuned bcast ✓ (netsim chose {plan})")

    # ---- compressed links: comm_mode="smi:compressed" -------------------
    # The launch-layer comm_mode strings map onto channel specs: the spec
    # carries the int8 compressed-link backend (blockwise scales + error
    # feedback, DESIGN.md §7), and the same broadcast-channel call site
    # moves over it unchanged.
    spec = default_channel_spec(comm, "smi:compressed")
    out = jax.jit(jax.shard_map(
        lambda v: open_bcast_channel(
            comm, root=SRC, port=None, transport=spec.transport, n_chunks=4,
        ).transfer(v[0])[None],
        mesh=mesh, in_specs=P("x"), out_specs=P("x")))(msg)
    bound = float(np.max(np.abs(np.asarray(msg[SRC])))) / 254 * 1.05
    for r in range(8):
        np.testing.assert_allclose(np.asarray(out[r]), np.asarray(msg[SRC]),
                                   atol=bound)
    print("compressed-link broadcast ✓ (int8 wire, within codec bound)")

    # ---- parallel layers: model comm as tagged channels (DESIGN.md §12) -
    # Two lines make a linear layer column-parallel: a ParallelCtx over
    # the mesh, then the layer call.  plan="auto" lets the netsim tuning
    # table pick the transport backend + wire for this payload size; the
    # layer's "tp.col" tag makes its traffic attributable in metrics
    # snapshots, Chrome traces, and the --validate-comm byte accounting.
    from repro.mesh.api import make_ctx
    from repro.parallel import column_parallel_linear

    pmesh = make_test_mesh((1, 8), ("data", "model"))
    ctx = make_ctx(pmesh, model_axis="model", batch_axes=("data",),
                   comm_mode="smi")
    K, NCOL = 64, 32
    xs = jnp.asarray(np.random.RandomState(0).randn(16, K), jnp.float32)
    ws = jnp.asarray(np.random.RandomState(1).randn(K, NCOL), jnp.float32)
    y = jax.jit(jax.shard_map(
        lambda a, b: column_parallel_linear(a, b, ctx, plan="auto"),
        mesh=pmesh,
        in_specs=(P(("data", "model")), P(None, "model")),
        out_specs=P("data", "model")))(xs, ws)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(xs @ ws))
    print('column-parallel linear over a tagged "tp.col" channel ✓ '
          "(plan='auto', bit-identical to x @ w)")

    # ---- tracing a channel (DESIGN.md §11) ------------------------------
    # The obs tracer records channel open/transfer/close events while the
    # program traces; repro.obs.export renders them (plus netsim-predicted
    # link timelines) as a Chrome trace that loads in Perfetto.  Off by
    # default — a disabled tracer costs one attribute load per call site.
    from repro.obs import trace as obs_trace
    from repro.obs.export import to_chrome_trace

    with obs_trace.enabled(capacity=4096) as tracer:
        # a fresh lambda is a fresh jit cache entry, so the channel traces
        # again and the tracer sees its events
        jax.jit(jax.shard_map(
            lambda v: open_channel(comm, src=SRC, dst=DST, port=None,
                                   n_chunks=8).transfer(v[0])[None],
            mesh=mesh, in_specs=P("x"), out_specs=P("x")))(msg)
        events = tracer.events()
    doc = to_chrome_trace(events)
    print(f"traced {len(events)} channel events {sorted(tracer.kinds())} "
          f"-> {len(doc['traceEvents'])} viewer records")


if __name__ == "__main__":
    main()
