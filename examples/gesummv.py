"""GESUMMV (paper §5.4.1): MPMD functional decomposition over 2 ranks.

y = alpha*A@x + beta*B@x.  Rank 0 computes the A-GEMV and streams its
result into rank 1, which computes the B-GEMV from its own memory and
combines — the paper's 8-line-diff distribution, doubling aggregate
memory bandwidth for this memory-bound routine.

    PYTHONPATH=src python examples/gesummv.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.gesummv import run  # noqa: E402


if __name__ == "__main__":
    rows = run()
    for N, t1, t2 in rows:
        print(f"N={N}: single {t1*1e3:.2f} ms | 2-rank SMI {t2*1e3:.2f} ms "
              f"(host devices share one memory system; the v5e model column "
              f"carries the paper's 2x)")
