"""Serve a small model with batched requests through the ServeEngine.

    PYTHONPATH=src python examples/serve_lm.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

from repro.launch.serve import main

if __name__ == "__main__":
    main(["--arch", "yi-6b", "--smoke", "--requests", "6",
          "--max-new", "10", "--slots", "3"])
