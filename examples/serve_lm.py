"""Serve a small model with continuous batching (DESIGN.md §13).

Default: the single-device ContinuousEngine — requests admit into any
free slot mid-decode, prompts replay through the same step their
batch-mates generate in.  Uncomment the mesh/comm-mode args to decode
tensor-parallel over persistent SMI channels (one port claim per layer
tag, held until engine shutdown); add ``--validate-comm`` to byte-check
the ``serve.*`` channel ledger against the netsim prediction instead.

    PYTHONPATH=src python examples/serve_lm.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

from repro.launch.serve import main

if __name__ == "__main__":
    main(["--arch", "yi-6b", "--smoke", "--requests", "6",
          "--max-new", "10", "--slots", "3",
          # "--mesh", "1,8", "--comm-mode", "smi:static",
          ])
