"""End-to-end driver (deliverable b): train a ~100M-param llama-family model
with the full production stack — SMI streamed collectives (TP+SP over the
model axis, FSDP/ZeRO over data), AdamW, synthetic data pipeline with
prefetch, async checkpointing, watchdog.

Default runs a ~25M config for a quick demonstration; pass --full-100m for
the 100M variant (slower on CPU).

    PYTHONPATH=src python examples/train_lm.py --steps 40
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse

from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_mesh
from repro.launch.steps import TrainSettings
from repro.launch.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--comm-mode", default="smi",
                    help="smi | smi:static | smi:packet | smi:fused | bulk")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    base = get_arch("yi-6b")  # llama-family
    if args.full_100m:
        cfg = base.scaled(n_layers=8, d_model=768, n_heads=8, n_kv_heads=4,
                          head_dim=96, d_ff=2048, vocab_size=32_000,
                          dtype="float32")
        shape = ShapeConfig("e2e", seq_len=256, global_batch=8, kind="train")
    else:
        cfg = base.scaled(n_layers=6, d_model=384, n_heads=8, n_kv_heads=4,
                          head_dim=48, d_ff=1024, vocab_size=8_192,
                          dtype="float32")
        shape = ShapeConfig("e2e", seq_len=128, global_batch=8, kind="train")
    n = cfg.param_count()
    print(f"[train_lm] {cfg.name}-derived config: {n/1e6:.1f}M params, "
          f"seq={shape.seq_len}, batch={shape.global_batch}, "
          f"mode={args.comm_mode}")

    mesh = make_mesh((2, 4), ("data", "model"))
    st = TrainSettings(
        comm_mode=args.comm_mode, remat="nothing", loss_chunks=1,
        base_lr=3e-3, warmup_steps=max(args.steps // 5, 4),
        total_steps=max(args.steps, 10) * 4,
    )
    _, hist = train_loop(
        cfg, mesh, shape, st, steps=args.steps,
        ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 2, 10),
        log_every=max(args.steps // 10, 1),
    )
    print(f"[train_lm] CE {hist[0]['ce']:.3f} -> {hist[-1]['ce']:.3f} "
          f"over {args.steps} steps")


if __name__ == "__main__":
    main()
