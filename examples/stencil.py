"""Distributed stencil (paper §5.4.2): SPMD halo exchange over a 2D grid.

The domain is scattered 2x4 over 8 ranks; every sweep exchanges N/S/E/W
halos through SMI channels and runs the stencil kernel locally; the
assembled result equals the single-rank sweep bit-for-bit.

    PYTHONPATH=src python examples/stencil.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.stencil_bench import run  # noqa: E402


if __name__ == "__main__":
    run()
    print("distributed stencil == single-rank reference on all grids ✓")
