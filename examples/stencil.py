"""Distributed stencil (paper §5.4.2): pipelined halo exchange over a grid.

The domain is scattered 2x4 over 8 ranks; every sweep streams N/S/E/W halo
slabs through the selected SMI transport *while* the interior update runs
(the overlap window), and the assembled result equals the single-rank
sweep — bit-for-bit on exact wires, within the codec bound on the int8
compressed links this example finishes with.

    PYTHONPATH=src python examples/stencil.py [comm_mode ...]
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, os.path.join(
    os.path.dirname(__file__), "..", "src"
))

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.apps import DistributedStencil  # noqa: E402


def main(modes=("smi", "smi:packet", "smi:fused", "smi:compressed")):
    grid, steps = (2, 4), 8
    world = np.random.RandomState(0).randn(256, 256).astype(np.float32)
    want = DistributedStencil.single_rank_reference(world, steps)
    for mode in modes:
        # comm_mode maps onto the halo channel's spec (DESIGN.md §9): the
        # "exchange" ChannelSpec carries the selected transport backend
        app = DistributedStencil.create(grid, comm_mode=mode)
        assert app.halo_schedule.spec.kind == "exchange"
        tiles = jnp.asarray(app.scatter(world))
        ref = app.gather(np.asarray(
            app.jitted(n_steps=steps, overlapped=False)(tiles)
        ))
        ovl = app.gather(np.asarray(
            app.jitted(n_steps=steps, overlapped=True)(tiles)
        ))
        assert np.array_equal(ref, ovl), mode
        err = float(np.max(np.abs(ovl - want)))
        exact = "bit-exact" if err == 0.0 else f"max|err|={err:.2g}"
        nx, ny = world.shape[0] // grid[0], world.shape[1] // grid[1]
        halo_us = app.halo_schedule.predicted_time(
            (nx, ny),
            wire="int8" if mode.startswith("smi:compressed") else "raw",
        ) * 1e6
        print(f"{mode:<16} overlapped == reference ✓  vs single-rank: "
              f"{exact:<18} v5e halo/step: {halo_us:.1f}us")
    print("distributed stencil == single-rank reference on all backends ✓")


if __name__ == "__main__":
    main(tuple(sys.argv[1:]) or ("smi", "smi:packet", "smi:fused",
                                 "smi:compressed"))
