import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: run (dryrun full-step + roofline probe) for a
cell under a named variant and append the assembled roofline row.

    PYTHONPATH=src python scripts/hillclimb.py <arch> <shape> <variant>
variants: base | sg (shared_gather) | sg_ra (+ring_attn) | sg_dnb
          (+remat dots_nb) | ra | sg_ra_dnb
"""

import json
import sys

from repro.launch.dryrun import run_cell
from repro.launch.roofline import analyze_cell

VARIANTS = {
    "base":      dict(shared_gather=False, ring_attn=False, remat="nothing"),
    "sg":        dict(shared_gather=True,  ring_attn=False, remat="nothing"),
    "ra":        dict(shared_gather=False, ring_attn=True,  remat="nothing"),
    "sg_ra":     dict(shared_gather=True,  ring_attn=True,  remat="nothing"),
    "sg_dnb":    dict(shared_gather=True,  ring_attn=False, remat="dots_nb"),
    "sg_ra_dnb": dict(shared_gather=True,  ring_attn=True,  remat="dots_nb"),
}


def main():
    arch, shape, variant = sys.argv[1], sys.argv[2], sys.argv[3]
    opts = VARIANTS[variant]
    rec = run_cell(arch, shape, multi_pod=False, comm_mode="smi",
                   variant=variant, **opts)
    assert rec["ok"], rec.get("error")
    row = analyze_cell(rec, comm_mode="smi",
                       remat=opts["remat"],
                       shared_gather=opts["shared_gather"],
                       ring_attn=opts["ring_attn"])
    row["temp_gb"] = rec["memory"]["temp_gb"]
    with open("hillclimb_results.jsonl", "a") as f:
        f.write(json.dumps(row) + "\n")
    t = row["terms_s"]
    print(f"[hillclimb] {arch} {shape} {variant}: "
          f"comp={t['compute_s']:.4f} mem={t['memory_s']:.4f} "
          f"coll={t['collective_s']:.4f} dom={row['dominant']} "
          f"frac={row['roofline_fraction']:.3f} temp={row['temp_gb']}GB")


if __name__ == "__main__":
    main()
