"""CI lint guard: no deprecated ``stream_*`` collective shims under src/.

Since PR 10 this script is a thin shim over smilint rule **SMI001**
(``repro.analysis.rules.NoStreamShims``) — the generalized AST pass that
also checks close discipline, reserved ports, and raw collectives.  The
entry point survives so existing CI invocations and habits keep working;
new callers should run ``python scripts/smilint.py --ast`` instead.

    python scripts/check_no_stream_shims.py [ROOT]

Stays importable without jax: ``repro.analysis.rules`` is stdlib-only.
"""

from __future__ import annotations

import pathlib
import sys


def main(argv=None) -> int:
    here = pathlib.Path(__file__).resolve().parent.parent
    root = pathlib.Path(argv[0]).resolve() if argv else here
    sys.path.insert(0, str(here / "src"))  # the rules live in THIS repo
    from repro.analysis.rules import NoStreamShims, lint_paths

    hits = lint_paths(str(root), rules=(NoStreamShims(),))
    if hits:
        print("[no-stream-shims] deprecated stream_* shim use under src/ "
              "(use the channels API — repro.channels.open_*_channel):")
        for d in hits:
            print(f"  {d}")
        return 1
    print("[no-stream-shims] ok: no stream_* shim references under src/ "
          f"outside {sorted(NoStreamShims.ALLOWED)}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
