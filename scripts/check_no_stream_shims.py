"""CI lint guard: no deprecated ``stream_*`` collective shims under src/.

The ``stream_bcast`` / ``stream_reduce`` / ``stream_gather`` /
``stream_scatter`` / ``stream_allreduce`` wrappers are deprecated since
PR 8 — the channels API (``repro.channels.open_*_channel`` and
``ChannelSpec``) is the supported surface — and are slated for removal
once external callers migrate (PR 9 bumped the warnings).  This guard
fails CI when any *new* in-tree use appears under ``src/`` outside the
shims' definition site, so the deprecation can only ever move forward.

    python scripts/check_no_stream_shims.py [ROOT]
"""

from __future__ import annotations

import pathlib
import re
import sys

SHIMS = ("stream_bcast", "stream_reduce", "stream_gather",
         "stream_scatter", "stream_allreduce")
PAT = re.compile(r"\b(" + "|".join(SHIMS) + r")\b")

#: the only files allowed to mention the shims: their definition site
#: and the package re-export that keeps them importable until removal
ALLOWED = {
    pathlib.PurePosixPath("src/repro/core/collectives.py"),
    pathlib.PurePosixPath("src/repro/core/__init__.py"),
}


def main(argv=None) -> int:
    root = pathlib.Path(argv[0]) if argv else pathlib.Path(
        __file__).resolve().parent.parent
    hits = []
    for path in sorted((root / "src").rglob("*.py")):
        rel = pathlib.PurePosixPath(path.relative_to(root).as_posix())
        if rel in ALLOWED:
            continue
        for lineno, line in enumerate(
                path.read_text().splitlines(), start=1):
            m = PAT.search(line)
            if m:
                hits.append(f"{rel}:{lineno}: {line.strip()}")
    if hits:
        print("[no-stream-shims] deprecated stream_* shim use under src/ "
              "(use the channels API — repro.channels.open_*_channel):")
        for h in hits:
            print(f"  {h}")
        return 1
    print("[no-stream-shims] ok: no stream_* shim references under src/ "
          f"outside {sorted(str(p) for p in ALLOWED)}")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
