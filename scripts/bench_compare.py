"""Perf-trajectory regression gate: diff two BENCH_*.json files.

Compares a freshly produced ``benchmarks/run.py --json`` file against the
checked-in baseline and fails (exit 1) when any cell regressed by more than
the threshold (default 1.5x):

* **model cells** (the ``v5e_model_us=...`` derived column) are
  deterministic schedule costs — LinkModel-predicted step counts times the
  wire-aware hop time — so they are compared raw: a model regression means
  the *schedule itself* got worse (more steps, more bytes), which no
  runner-speed argument excuses.
* **measured cells** (``us_per_call``) are wall times on whatever machine
  ran the job, so raw cross-machine ratios are meaningless.  They are
  normalised by the median measured ratio across all shared rows first:
  the gate then catches any cell that slowed down *relative to the rest
  of the suite* — a real per-cell regression — while a uniformly slower
  runner shifts every ratio equally and passes.  (A uniform true
  regression of every cell at once is invisible to this normalisation;
  the model columns cover that direction.)  Even median-normalised,
  same-machine re-runs of the CPU suites show *isolated* per-cell jitter
  past 6x (compile cache, host load, the cycle-emulated packet router),
  while a real code regression hits a coherent group of cells — a
  backend's whole column, an op across sizes.  So the measured gate
  fails only when ``--measured-min-cells`` (default 3) or more cells
  exceed ``--measured-threshold`` (default 4x); fewer are printed as
  warnings.  Tighten both for controlled same-machine comparisons.

Usage:
    python scripts/bench_compare.py BASELINE.json FRESH.json \\
        [--threshold 1.5] [--measured-threshold 4.0] \\
        [--measured-min-cells 3] [--raw-measured] [--json deltas.json]

Always prints the per-cell delta table (cell, baseline, current, ratio,
verdict) so CI logs show *which* cells moved; ``--json`` writes the same
table machine-readably.

Rows present in only one file are reported but never fail the gate
(benchmarks get added and retired; the trajectory continues).
"""

from __future__ import annotations

import argparse
import json
import re
import sys

_MODEL_RE = re.compile(r"v5e_model_us=([0-9.eE+-]+)")


def load_rows(path: str) -> dict:
    """{(suite, name, params): row} from a benchmarks/run.py --json file."""
    with open(path) as f:
        data = json.load(f)
    rows = {}
    for row in data.get("rows", []):
        rows[(row.get("suite", ""), row["name"], row.get("params", ""))] = row
    return rows, data


def model_us(row) -> float | None:
    m = _MODEL_RE.search(row.get("derived", "") or "")
    return float(m.group(1)) if m else None


def median(xs):
    xs = sorted(xs)
    n = len(xs)
    if not n:
        return 1.0
    return xs[n // 2] if n % 2 else 0.5 * (xs[n // 2 - 1] + xs[n // 2])


def compare(base_rows, fresh_rows, *, threshold: float,
            measured_threshold: float | None = None,
            measured_min_cells: int = 3,
            raw_measured: bool = False):
    """Returns (regressions, notes, norm, n_shared, cells): regressions is a
    list of human-readable gate violations, notes a list of informational
    lines (row churn and uncorroborated measured spikes), and cells the full
    per-cell delta table (one record per model/measured comparison with its
    verdict) for the summary printout and the JSON report."""
    shared = sorted(set(base_rows) & set(fresh_rows))
    only_base = sorted(set(base_rows) - set(fresh_rows))
    only_fresh = sorted(set(fresh_rows) - set(base_rows))
    notes = [
        *(f"row retired (baseline only): {k}" for k in only_base),
        *(f"row added (fresh only): {k}" for k in only_fresh),
    ]
    regressions = []
    cells = []
    m_thresh = measured_threshold if measured_threshold is not None \
        else threshold

    meas_ratios = {}
    for k in shared:
        b, f = base_rows[k]["us_per_call"], fresh_rows[k]["us_per_call"]
        if b > 0 and f > 0:
            meas_ratios[k] = f / b
    norm = 1.0 if raw_measured else median(list(meas_ratios.values()))

    measured_hits = []
    for k in shared:
        cell_name = ",".join(str(p) for p in k if p)
        # model cells: deterministic, raw-gated
        mb, mf = model_us(base_rows[k]), model_us(fresh_rows[k])
        if mb is not None and mf is not None and mb > 0:
            r = mf / mb
            verdict = "REGRESS" if r > threshold else "OK"
            cells.append({
                "cell": cell_name, "kind": "model", "baseline_us": mb,
                "current_us": mf, "ratio": r, "verdict": verdict,
            })
            if r > threshold:
                regressions.append(
                    f"MODEL {k}: {mb:.1f}us -> {mf:.1f}us ({r:.2f}x > "
                    f"{threshold:.2f}x)"
                )
        # measured cells: machine-speed-normalised
        if k in meas_ratios:
            r = meas_ratios[k] / norm
            b, f = base_rows[k]["us_per_call"], fresh_rows[k]["us_per_call"]
            hit = r > m_thresh
            cells.append({
                "cell": cell_name, "kind": "measured", "baseline_us": b,
                "current_us": f, "ratio": meas_ratios[k], "norm_ratio": r,
                "verdict": "WARN" if hit else "OK",
            })
            if hit:
                measured_hits.append(
                    f"MEASURED {k}: {b:.1f}us -> {f:.1f}us "
                    f"({meas_ratios[k]:.2f}x raw, {r:.2f}x vs suite median "
                    f"{norm:.2f}x > {m_thresh:.2f}x)"
                )
    # a real regression hits a coherent group of cells; isolated wall-time
    # spikes are CI noise — warn, don't fail
    gated = len(measured_hits) >= measured_min_cells
    if gated:
        regressions.extend(measured_hits)
        for c in cells:
            if c["verdict"] == "WARN":
                c["verdict"] = "REGRESS"
    else:
        notes.extend(
            f"isolated measured spike (not gated, "
            f"{len(measured_hits)} < {measured_min_cells} cells): {h}"
            for h in measured_hits
        )
    return regressions, notes, norm, len(shared), cells


def print_cell_table(cells, *, norm: float) -> None:
    """Aligned per-cell delta summary: which cells moved, by how much, and
    what the gate decided — so a red CI log names the culprits directly."""
    if not cells:
        return
    w = max(len(c["cell"]) for c in cells)
    print(f"# {'cell':<{w}} {'kind':<8} {'baseline':>12} {'current':>12} "
          f"{'ratio':>7} {'vs-med':>7}  verdict")
    for c in sorted(cells, key=lambda c: (-c.get("norm_ratio", c["ratio"]))):
        nr = c.get("norm_ratio")
        print(f"# {c['cell']:<{w}} {c['kind']:<8} "
              f"{c['baseline_us']:>10.1f}us {c['current_us']:>10.1f}us "
              f"{c['ratio']:>6.2f}x "
              + (f"{nr:>6.2f}x" if nr is not None else f"{'-':>7}")
              + f"  {c['verdict']}")
    print(f"# (measured vs-med column normalised by suite median "
          f"{norm:.2f}x)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter
    )
    ap.add_argument("baseline", help="checked-in BENCH_*.json")
    ap.add_argument("fresh", help="freshly produced BENCH_*.json")
    ap.add_argument("--threshold", type=float, default=1.5,
                    help="max allowed slowdown of a deterministic "
                         "model-predicted cell (default 1.5)")
    ap.add_argument("--measured-threshold", type=float, default=4.0,
                    help="max allowed median-normalised slowdown of a "
                         "measured wall-time cell (default 4.0 — CPU-CI "
                         "jitter tolerant; tighten for same-machine runs)")
    ap.add_argument("--measured-min-cells", type=int, default=3,
                    help="measured cells past the threshold needed to fail "
                         "the gate (isolated spikes are warnings; "
                         "default 3)")
    ap.add_argument("--raw-measured", action="store_true",
                    help="gate measured cells on raw ratios (same-machine "
                         "comparisons only)")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write the per-cell delta table + verdicts to OUT")
    args = ap.parse_args(argv)

    base_rows, base = load_rows(args.baseline)
    fresh_rows, fresh = load_rows(args.fresh)
    if fresh.get("failures"):
        print(f"[bench-compare] fresh run had failed suites: "
              f"{fresh['failures']} — gate FAILED")
        return 1

    regressions, notes, norm, n_shared, cells = compare(
        base_rows, fresh_rows, threshold=args.threshold,
        measured_threshold=args.measured_threshold,
        measured_min_cells=args.measured_min_cells,
        raw_measured=args.raw_measured,
    )
    for line in notes:
        print(f"[bench-compare] note: {line}")
    print_cell_table(cells, norm=norm)
    print(f"[bench-compare] {n_shared} shared cells; suite-median measured "
          f"ratio {norm:.2f}x; thresholds: model {args.threshold:.2f}x, "
          f"measured {args.measured_threshold:.2f}x")
    if args.json:
        with open(args.json, "w") as f:
            json.dump({
                "cells": cells,
                "norm": norm,
                "regressions": regressions,
                "notes": notes,
                "ok": bool(n_shared) and not regressions,
            }, f, indent=1)
        print(f"[bench-compare] wrote {len(cells)} cell deltas to {args.json}")
    if n_shared == 0:
        # zero overlap means the gate compared nothing: a wrong baseline
        # path or wholesale row-key churn must not read as green
        print("[bench-compare] gate FAILED: no shared cells between "
              "baseline and fresh run — wrong baseline file, or every row "
              "key changed (regenerate and commit the baseline)")
        return 1
    if regressions:
        for line in regressions:
            print(f"[bench-compare] REGRESSION {line}")
        print(f"[bench-compare] gate FAILED: {len(regressions)} regressed "
              "cell(s). If intentional (schedule change, new model), "
              "regenerate the baseline with benchmarks/run.py --json and "
              "commit it alongside the change.")
        return 1
    print("[bench-compare] gate OK: no cell regressed past the threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
