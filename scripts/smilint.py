"""smilint entry point: static + capture-mode SMI channel verifier.

Thin wrapper over ``python -m repro.analysis.lint`` that works from a
fresh checkout (adds ``src/`` to ``sys.path`` and anchors the AST sweep
at the repo root).  See DESIGN.md §14 for the rule catalog.

    python scripts/smilint.py                 # all three passes
    python scripts/smilint.py --ast           # source lints only (no jax)
    python scripts/smilint.py --corpus --json smilint.json
"""

from __future__ import annotations

import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.analysis.lint import main  # noqa: E402

if __name__ == "__main__":
    argv = sys.argv[1:]
    if "--root" not in argv:
        argv = ["--root", str(ROOT)] + argv
    sys.exit(main(argv))
