import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape) cell, lower + compile the production
step (train_step / serve_step / prefill) on the single-pod (16, 16) mesh and
the multi-pod (2, 16, 16) mesh, print ``memory_analysis()`` (proves it fits)
and ``cost_analysis()`` (FLOPs/bytes for the roofline), and parse the
compiled HLO for per-device collective wire bytes.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --out dryrun_results.jsonl
"""

import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import COMM_MODES, ARCHS, SHAPES, cells, get_arch
from ..data.inputs import input_specs
from .mesh import make_production_mesh
from .steps import TrainSettings, build_prefill, build_serve, build_train

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_COLL_RE = re.compile(
    r"=\s+(\w+)\[([\d,]*)\]\S*\s+(all-gather|all-reduce|reduce-scatter"
    r"|all-to-all|collective-permute)"
)


def _shape_bytes(dt: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def collective_bytes(hlo_text: str) -> dict:
    """Per-device wire-byte estimate from compiled HLO.

    Conventions (documented in EXPERIMENTS.md): all-gather counts its result
    bytes, reduce-scatter / all-to-all / collective-permute count operand ≈
    result bytes, all-reduce counts 2x operand (ring RS+AG).  All are the
    O(P-1/P) ring wire cost per device."""
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    for m in _COLL_RE.finditer(hlo_text):
        dt, dims, kind = m.groups()
        b = _shape_bytes(dt, dims)
        if kind == "all-reduce":
            b *= 2
        out[kind] += b
    out["total"] = sum(out.values())
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             comm_mode: str = "smi", settings: TrainSettings | None = None,
             shared_gather: bool = False, ring_attn: bool = False,
             remat: str = "nothing", variant: str = "base",
             verbose: bool = True) -> dict:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec: dict = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "comm_mode": comm_mode, "variant": variant, "ok": False,
    }
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        rec["skipped"] = "pure full-attention arch (DESIGN.md §4)"
        rec["ok"] = True
        return rec

    t0 = time.time()
    try:
        if shape.kind == "train":
            st = settings or TrainSettings(
                comm_mode=comm_mode, shared_gather=shared_gather,
                ring_attn=ring_attn, remat=remat,
            )
            art = build_train(cfg, mesh, shape, st)
            batch_structs = {
                k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                for k, v in art["input_specs"].items()
            }
            lowered = art["step"].lower(art["state_shape"], batch_structs)
        elif shape.kind == "prefill":
            art = build_prefill(cfg, mesh, shape, comm_mode=comm_mode,
                                shared_gather=shared_gather,
                                ring_attn=ring_attn)
            args = [art["params_shape"], art["input_specs"]["tokens"]]
            if "pixel_embeds" in art["input_specs"]:
                args.append(art["input_specs"]["pixel_embeds"])
            lowered = art["step"].lower(*args)
        else:  # decode
            art = build_serve(cfg, mesh, shape, comm_mode=comm_mode)
            lowered = art["step"].lower(
                art["params_shape"], art["cache_shape"],
                art["input_specs"]["token"], art["input_specs"]["pos"],
            )
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_gb": round(mem.argument_size_in_bytes / 2**30, 3),
            "output_gb": round(mem.output_size_in_bytes / 2**30, 3),
            "temp_gb": round(mem.temp_size_in_bytes / 2**30, 3),
            "alias_gb": round(mem.alias_size_in_bytes / 2**30, 3),
        }
        cost = compiled.cost_analysis() or {}
        rec["cost"] = {
            "flops": float(cost.get("flops", 0.0)),
            "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        }
        rec["collectives"] = collective_bytes(compiled.as_text())
        rec["ok"] = True
        if verbose:
            print(f"[dryrun] {arch} {shape_name} mesh={rec['mesh']} "
                  f"mode={comm_mode} OK lower={rec['lower_s']}s "
                  f"compile={rec['compile_s']}s mem(temp)="
                  f"{rec['memory']['temp_gb']}GB flops={rec['cost']['flops']:.3g} "
                  f"coll={rec['collectives']['total']:.3g}B", flush=True)
    except Exception as e:  # noqa: BLE001 — a failing cell is a bug report
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[dryrun] {arch} {shape_name} mesh={rec['mesh']} FAILED: "
                  f"{rec['error']}", flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="both")
    ap.add_argument("--comm-mode", default="smi", choices=list(COMM_MODES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    todo = []
    for arch, shape_name, skip in cells():
        if args.arch and arch != args.arch:
            continue
        if args.shape and shape_name != args.shape:
            continue
        todo.append((arch, shape_name))
    if not todo:
        print("nothing selected", file=sys.stderr)
        return 1

    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    results = []
    for arch, shape_name in todo:
        for mp in meshes:
            rec = run_cell(arch, shape_name, multi_pod=mp,
                           comm_mode=args.comm_mode)
            results.append(rec)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(rec) + "\n")

    n_bad = sum(1 for r in results if not r["ok"])
    print(f"[dryrun] {len(results) - n_bad}/{len(results)} cells OK")
    return 0 if n_bad == 0 else 2


if __name__ == "__main__":
    sys.exit(main())
