"""Step builders: train_step / serve_step / prefill_step for (cfg, mesh).

These are the production entry points shared by the trainer, the serving
engine, the dry-run, and the roofline analysis.  Everything distributed is
explicit: the model runs inside one shard_map over the full mesh with SMI
(or bulk) collectives; the optimizer runs at the jit level where the
FSDP/ZeRO layouts are pure sharding annotations.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from ..data.inputs import input_specs
from ..mesh.api import (
    build_fsdp_plan,
    fsdp_storage_specs,
    grad_sync_fsdp,
    make_ctx,
)
from ..models import (
    init_lm,
    lm_cache_specs,
    lm_caches,
    lm_decode_step,
    lm_loss,
    lm_prefill,
    lm_specs,
)
from ..optim import adamw_init, adamw_update, clip_by_global_norm, cosine_warmup
from .mesh import batch_axes_of


@dataclass
class TrainSettings:
    #: "smi" | "smi:static" | "smi:packet" | "smi:fused" | "bulk" — base
    #: collective mode plus transport backend (repro/transport registry)
    comm_mode: str = "smi"
    remat: str = "nothing"
    loss_chunks: int = 8
    base_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    clip_norm: float = 1.0
    fsdp: bool = True
    compressed_grads: bool = False
    shared_gather: bool = False   # beyond-paper §Perf optimisation
    ring_attn: bool = False       # beyond-paper §Perf optimisation


def globalize_structs(local_tree, spec_tree, mesh):
    """Per-device cache/struct shapes -> global shapes per the spec tree
    (multiply each sharded dim by its axis size)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(l, sp):
        dims = tuple(sp) + (None,) * (len(l.shape) - len(tuple(sp)))
        shape = []
        for d, sz in zip(dims, l.shape):
            mult = 1
            if d is not None:
                for a in (d if isinstance(d, tuple) else (d,)):
                    mult *= sizes[a]
            shape.append(sz * mult)
        return jax.ShapeDtypeStruct(tuple(shape), l.dtype)

    return jax.tree.map(
        one, local_tree, spec_tree,
        is_leaf=lambda x: hasattr(x, "shape"),
    )


def _sh(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _batch_spec(shape_leaf, batch_axes, mesh):
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = 1
    for a in batch_axes:
        dp *= sizes[a]
    if shape_leaf.shape and shape_leaf.shape[0] % dp == 0 and shape_leaf.shape[0] > 0:
        ax = tuple(batch_axes) if len(batch_axes) > 1 else batch_axes[0]
        return P(*((ax,) + (None,) * (len(shape_leaf.shape) - 1)))
    return P(*((None,) * len(shape_leaf.shape)))


def _layer_plan(cfg: ModelConfig, comm_mode: str):
    """The per-tag layer plan a launch selects: the config's ``comm_plan``
    (default "auto") when the comm_mode string doesn't pin a transport
    backend; an explicit ``smi:<backend>`` (or bulk/none) is the escape
    hatch and keeps layers on the pinned backend (plan None)."""
    return cfg.comm_plan if comm_mode == "smi" else None


def build_train(cfg: ModelConfig, mesh, shape: ShapeConfig, st: TrainSettings):
    """Returns dict with jitted ``step``, ``init_state``, shardings, specs."""
    batch_axes = batch_axes_of(mesh)
    ctx = make_ctx(mesh, model_axis="model", batch_axes=batch_axes,
                   comm_mode=st.comm_mode,
                   opt_shared_gather=st.shared_gather,
                   opt_ring_attn=st.ring_attn,
                   plan=_layer_plan(cfg, st.comm_mode))
    pspecs = lm_specs(cfg, ctx)
    key = jax.random.PRNGKey(0)
    pshapes = jax.eval_shape(lambda: init_lm(key, cfg, ctx))
    plan = build_fsdp_plan(pshapes, pspecs, mesh, batch_axes) if st.fsdp else None
    store_specs = fsdp_storage_specs(pspecs, plan, batch_axes) if st.fsdp else pspecs

    ispecs = input_specs(cfg, shape)
    bspecs = {k: _batch_spec(v, batch_axes, mesh) for k, v in ispecs.items()}
    has_pix = "pixel_embeds" in ispecs

    # ---- loss + synced grads, explicit-SPMD region
    def loss_grads(params, tokens, labels, *extra):
        def lf(p):
            loss, (ce, aux) = lm_loss(
                p, tokens, labels, cfg, ctx,
                extra_embeds=extra[0] if extra else None,
                remat=st.remat, loss_chunks=st.loss_chunks, fsdp_plan=plan,
            )
            return loss, (ce, aux)

        (loss, (ce, aux)), grads = jax.value_and_grad(lf, has_aux=True)(params)
        grads = grad_sync_fsdp(grads, plan, ctx, compressed=st.compressed_grads) \
            if plan is not None else grads
        if plan is None and batch_axes:
            grads = jax.tree.map(lambda g: lax.pmean(g, batch_axes), grads)
        loss_s = lax.pmean(loss, batch_axes) if batch_axes else loss
        ce_s = lax.pmean(ce, batch_axes) if batch_axes else ce
        return loss_s, ce_s, grads

    in_specs = (store_specs, bspecs["tokens"], bspecs["labels"])
    if has_pix:
        in_specs = in_specs + (bspecs["pixel_embeds"],)
    smapped = jax.shard_map(
        loss_grads, mesh=mesh,
        in_specs=in_specs,
        out_specs=(P(), P(), store_specs),
        check_vma=False,
    )

    state_specs = {
        "params": store_specs,
        "opt": {"m": store_specs, "v": store_specs, "step": P()},
    }
    state_sh = _sh(mesh, state_specs)
    batch_sh = {k: NamedSharding(mesh, v) for k, v in bspecs.items()}

    def step_fn(state, batch):
        args = (state["params"], batch["tokens"], batch["labels"])
        if has_pix:
            args = args + (batch["pixel_embeds"],)
        loss, ce, grads = smapped(*args)
        grads, gnorm = clip_by_global_norm(grads, st.clip_norm)
        lr = cosine_warmup(
            state["opt"]["step"], base_lr=st.base_lr,
            warmup_steps=st.warmup_steps, total_steps=st.total_steps,
        )
        new_p, new_opt = adamw_update(state["params"], grads, state["opt"], lr=lr)
        return (
            {"params": new_p, "opt": new_opt},
            {"loss": loss, "ce": ce, "gnorm": gnorm, "lr": lr},
        )

    step = jax.jit(
        step_fn,
        in_shardings=(state_sh, batch_sh),
        out_shardings=(state_sh, None),
        donate_argnums=(0,),
    )

    def init_state(seed=0):
        k = jax.random.PRNGKey(seed)
        params = init_lm(k, cfg, ctx)
        return {"params": params, "opt": adamw_init(params)}

    init_jit = jax.jit(init_state, static_argnums=(0,), out_shardings=state_sh)

    state_shape = jax.eval_shape(init_state)
    return dict(
        step=step, init_state=init_jit, state_shape=state_shape,
        state_sharding=state_sh, batch_sharding=batch_sh, ctx=ctx,
        input_specs=ispecs, plan=plan, store_specs=store_specs,
    )


def build_serve(cfg: ModelConfig, mesh, shape: ShapeConfig, *,
                comm_mode: str = "smi", fsdp: str | bool = "auto"):
    """serve_step: one token for the whole batch against a full KV cache."""
    batch_axes = batch_axes_of(mesh)
    ctx = make_ctx(mesh, model_axis="model", batch_axes=batch_axes,
                   comm_mode=comm_mode, plan=_layer_plan(cfg, comm_mode))
    pspecs = lm_specs(cfg, ctx)
    key = jax.random.PRNGKey(0)
    pshapes = jax.eval_shape(lambda: init_lm(key, cfg, ctx))

    if fsdp == "auto":
        # weight-stream (ZeRO-3-style gather per layer) only when a pure
        # TP shard would not fit HBM (bf16 params/device > 10 GB)
        total = sum(
            int(jnp.prod(jnp.asarray(l.shape))) for l in jax.tree.leaves(pshapes)
        )
        fsdp = (total / ctx.tp) * 2 > 10e9
    plan = build_fsdp_plan(pshapes, pspecs, mesh, batch_axes) if fsdp else None
    store_specs = fsdp_storage_specs(pspecs, plan, batch_axes) if fsdp else pspecs

    ispecs = input_specs(cfg, shape)
    bspec_tok = _batch_spec(ispecs["token"], batch_axes, mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = 1
    for a in batch_axes:
        dp *= sizes[a]
    shard_batch = shape.global_batch % dp == 0 and dp > 1
    cspecs = lm_cache_specs(cfg, ctx, shard_batch=shard_batch)
    B_loc = shape.global_batch // dp if shard_batch else shape.global_batch

    def serve_step(params, caches, token, pos):
        logits, caches = lm_decode_step(
            params, caches, token, pos, cfg, ctx,
            gather_logits=False, fsdp_plan=plan,
        )
        return logits, caches

    b0 = bspec_tok[0] if len(tuple(bspec_tok)) else None
    logit_spec = (
        P(b0, "model", None) if cfg.n_codebooks > 1 else P(b0, "model")
    )
    smapped = jax.shard_map(
        serve_step, mesh=mesh,
        in_specs=(store_specs, cspecs, bspec_tok, P()),
        out_specs=(logit_spec, cspecs),
        check_vma=False,
    )
    cache_sh = _sh(mesh, cspecs)
    param_sh = _sh(mesh, store_specs)

    step = jax.jit(
        smapped,
        in_shardings=(param_sh, cache_sh, NamedSharding(mesh, bspec_tok), None),
        out_shardings=(None, cache_sh),
        donate_argnums=(1,),
    )

    capacity = shape.seq_len
    cache_local = jax.eval_shape(
        lambda: lm_caches(cfg, B_loc, capacity=capacity, ctx=ctx)
    )
    cache_shape = globalize_structs(cache_local, cspecs, mesh)

    def params_shape_bf16():
        return jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(
                l.shape, jnp.bfloat16 if l.dtype == jnp.float32 else l.dtype
            ),
            pshapes,
        )

    return dict(
        step=step, ctx=ctx, cache_shape=cache_shape,
        params_shape=params_shape_bf16(), param_sharding=param_sh,
        cache_sharding=cache_sh, input_specs=ispecs, B_loc=B_loc,
        capacity=capacity, store_specs=store_specs, plan=plan,
    )


def build_continuous_serve(cfg: ModelConfig, mesh, *, comm_mode: str = "smi",
                           batch_slots: int = 4, capacity: int = 128,
                           fsdp: str | bool = "auto"):
    """Tensor-parallel runtime for the continuous-batching engine.

    Returns the ``runtime`` dict :class:`~repro.serving.ContinuousEngine`
    consumes: the shard_map'd per-slot decode step (``pos`` is a (B,)
    vector), the slot-invalidation step, the two migration legs on the
    pool's ``serve.migrate`` gather/scatter channels, and the
    :class:`~repro.channels.ChannelPool` whose persistent port claims
    outlive every trace (released only by ``pool.close()`` / engine
    shutdown).  Every layer channel inside the step resolves to ONE
    persistent pool spec per tag, reused across all decode steps.

    Slots are batch rows replicated over the data axes (slot scheduling
    is a global decision); the KV cache stays sequence-sharded over the
    model axis, which is what migration streams across ranks.
    """
    import dataclasses as _dc

    from ..channels import ChannelPool
    from ..serving.continuous import (
        migrate_gather,
        migrate_scatter,
        open_migration,
        reset_slot,
    )

    ctx = make_ctx(mesh, model_axis="model", batch_axes=(),
                   comm_mode=comm_mode, plan=_layer_plan(cfg, comm_mode))
    pool = gspec = sspec = None
    if ctx.is_smi and ctx.model_comm is not None:
        pool = ChannelPool(ctx.model_comm, prefix="serve.")
        ctx = _dc.replace(ctx, channels=pool)
        gspec, sspec = open_migration(pool)
    pspecs = lm_specs(cfg, ctx)
    key = jax.random.PRNGKey(0)
    pshapes = jax.eval_shape(lambda: init_lm(key, cfg, ctx))
    if fsdp == "auto":
        total = sum(
            int(jnp.prod(jnp.asarray(l.shape))) for l in jax.tree.leaves(pshapes)
        )
        fsdp = (total / ctx.tp) * 2 > 10e9
    batch_axes = batch_axes_of(mesh)
    plan = build_fsdp_plan(pshapes, pspecs, mesh, batch_axes) if fsdp else None
    store_specs = fsdp_storage_specs(pspecs, plan, batch_axes) if fsdp else pspecs
    cspecs = lm_cache_specs(cfg, ctx, shard_batch=False)

    def serve_step(params, caches, token, pos):
        return lm_decode_step(params, caches, token, pos, cfg, ctx,
                              gather_logits=False, fsdp_plan=plan)

    tok_spec = P(None, None) if cfg.n_codebooks > 1 else P(None)
    logit_spec = (
        P(None, "model", None) if cfg.n_codebooks > 1 else P(None, "model")
    )
    param_sh = _sh(mesh, store_specs)
    cache_sh = _sh(mesh, cspecs)
    step = jax.jit(
        jax.shard_map(
            serve_step, mesh=mesh,
            in_specs=(store_specs, cspecs, tok_spec, P(None)),
            out_specs=(logit_spec, cspecs), check_vma=False,
        ),
        in_shardings=(param_sh, cache_sh, None, None),
        out_shardings=(None, cache_sh),
        donate_argnums=(1,),
    )

    reset = jax.jit(
        jax.shard_map(reset_slot, mesh=mesh, in_specs=(cspecs, P()),
                      out_specs=cspecs, check_vma=False),
        in_shardings=(cache_sh, None), out_shardings=cache_sh,
        donate_argnums=(0,),
    )

    # migration legs: gather every rank's packed slot image to the root,
    # later scatter it back out into the destination slot.  The in-flight
    # handle is the per-rank (P, N) gather result, stacked over the model
    # axis — opaque to the engine.
    if gspec is not None:
        def mig_start(caches, slot):
            return migrate_gather(caches, slot, gspec)

        def mig_finish(caches, inflight, slot):
            return migrate_scatter(caches, inflight, slot, sspec)
    else:
        # bulk / non-SMI: no channels — the image round-trips locally
        from ..serving.continuous import pack_slot, unpack_slot

        def mig_start(caches, slot):
            return pack_slot(caches, slot)[None]

        def mig_finish(caches, inflight, slot):
            return unpack_slot(caches, inflight[0], slot)

    migrate_start = jax.jit(
        jax.shard_map(mig_start, mesh=mesh, in_specs=(cspecs, P()),
                      out_specs=P("model", None), check_vma=False),
        in_shardings=(cache_sh, None),
    )
    migrate_finish = jax.jit(
        jax.shard_map(mig_finish, mesh=mesh,
                      in_specs=(cspecs, P("model", None), P()),
                      out_specs=cspecs, check_vma=False),
        in_shardings=(cache_sh, None, None), out_shardings=cache_sh,
        donate_argnums=(0,),
    )

    init_caches = jax.jit(
        jax.shard_map(
            lambda: lm_caches(cfg, batch_slots, capacity=capacity, ctx=ctx),
            mesh=mesh, in_specs=(), out_specs=cspecs, check_vma=False,
        ),
        out_shardings=cache_sh,
    )

    return dict(
        ctx=ctx, pool=pool, step=step, reset=reset,
        migrate_start=migrate_start, migrate_finish=migrate_finish,
        init_caches=init_caches, batch_slots=batch_slots, capacity=capacity,
        param_sharding=param_sh, cache_sharding=cache_sh,
        store_specs=store_specs, plan=plan,
    )


def build_prefill(cfg: ModelConfig, mesh, shape: ShapeConfig, *,
                  comm_mode: str = "smi", fsdp: str | bool = "auto",
                  shared_gather: bool = False, ring_attn: bool = False):
    batch_axes = batch_axes_of(mesh)
    ctx = make_ctx(mesh, model_axis="model", batch_axes=batch_axes,
                   comm_mode=comm_mode, opt_shared_gather=shared_gather,
                   opt_ring_attn=ring_attn,
                   plan=_layer_plan(cfg, comm_mode))
    pspecs = lm_specs(cfg, ctx)
    key = jax.random.PRNGKey(0)
    pshapes = jax.eval_shape(lambda: init_lm(key, cfg, ctx))
    if fsdp == "auto":
        total = sum(
            int(jnp.prod(jnp.asarray(l.shape))) for l in jax.tree.leaves(pshapes)
        )
        fsdp = (total / ctx.tp) * 2 > 10e9
    plan = build_fsdp_plan(pshapes, pspecs, mesh, batch_axes) if fsdp else None
    store_specs = fsdp_storage_specs(pspecs, plan, batch_axes) if fsdp else pspecs

    ispecs = input_specs(cfg, shape)
    bspecs = {k: _batch_spec(v, batch_axes, mesh) for k, v in ispecs.items()}
    has_pix = "pixel_embeds" in ispecs

    def prefill(params, tokens, *extra):
        h = lm_prefill(
            params, tokens, cfg, ctx, capacity=shape.seq_len,
            extra_embeds=extra[0] if extra else None, fsdp_plan=plan,
        )
        return h

    in_specs = (store_specs, bspecs["tokens"])
    if has_pix:
        in_specs = in_specs + (bspecs["pixel_embeds"],)
    bspec_tok = bspecs["tokens"]
    out_spec = P(bspec_tok[0] if bspec_tok else None, "model", None)
    smapped = jax.shard_map(
        prefill, mesh=mesh, in_specs=in_specs, out_specs=out_spec,
        check_vma=False,
    )
    param_sh = _sh(mesh, store_specs)
    step = jax.jit(
        smapped,
        in_shardings=(param_sh,) + tuple(
            NamedSharding(mesh, bspecs[k]) for k in (["tokens", "pixel_embeds"] if has_pix else ["tokens"])
        ),
    )

    def params_shape_bf16():
        return jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(
                l.shape, jnp.bfloat16 if l.dtype == jnp.float32 else l.dtype
            ),
            pshapes,
        )

    return dict(
        step=step, ctx=ctx, params_shape=params_shape_bf16(),
        param_sharding=param_sh, input_specs=ispecs, store_specs=store_specs,
        plan=plan,
    )
