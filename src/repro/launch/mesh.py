"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single-pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model) — the "pod" axis
is the DCN/inter-pod dimension; SMI's routed transport treats it as one more
torus dimension with its own link bandwidth.
"""

from __future__ import annotations

from ..compat import make_mesh as _compat_make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _compat_make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/benchmarks/elastic restarts."""
    return _compat_make_mesh(shape, axes)


def batch_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
