"""Production training driver.

Wires together: arch config -> mesh -> SMI train step -> synthetic data
pipeline -> checkpointing -> watchdog + checkpoint/restart.  CLI:

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \\
        --steps 50 --mesh 2,4 --comm-mode smi

``--smoke`` scales the arch to its reduced config so the driver runs on the
host devices; the full configs are exercised via the dry-run.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import Checkpointer
from ..configs import COMM_MODES, SHAPES, get_arch, smoke
from ..configs.base import ShapeConfig
from ..data.pipeline import SyntheticTokenPipeline
from ..ft import StepWatchdog
from .mesh import make_mesh
from .steps import TrainSettings, build_train


def train_loop(
    cfg, mesh, shape, settings: TrainSettings, *,
    steps: int, ckpt_dir: str | None = None, ckpt_every: int = 50,
    log_every: int = 10, seed: int = 0, state=None, start_step: int = 0,
    fail_at: int | None = None,
):
    art = build_train(cfg, mesh, shape, settings)
    if state is None:
        state = art["init_state"](seed)
    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    pipe = SyntheticTokenPipeline(
        cfg.vocab_size, shape.seq_len, shape.global_batch,
        seed=seed, n_codebooks=cfg.n_codebooks,
    )
    wd = StepWatchdog()
    wd.start()
    history = []
    try:
        for step in range(start_step, steps):
            if fail_at is not None and step == fail_at:
                raise RuntimeError("injected node failure")
            hostb = pipe.next()
            batch = {
                "tokens": jnp.asarray(hostb["tokens"]),
                "labels": jnp.asarray(hostb["labels"]),
            }
            if cfg.frontend == "vit_stub":
                rng = np.random.RandomState(seed * 7919 + step)
                batch["pixel_embeds"] = jnp.asarray(
                    rng.randn(shape.global_batch, cfg.n_patches, cfg.d_model)
                    * 0.02,
                    jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32,
                )
            state, metrics = art["step"](state, batch)
            slow = wd.lap(step)
            if step % log_every == 0 or step == steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m.update(step=step, straggler=slow)
                history.append(m)
                print(f"[train] step={step} loss={m['loss']:.4f} "
                      f"ce={m['ce']:.4f} gnorm={m['gnorm']:.3f} lr={m['lr']:.2e}",
                      flush=True)
            if ckpt and step > 0 and step % ckpt_every == 0:
                ckpt.save(state, step, async_=True)
        if ckpt:
            ckpt.save(state, steps)
            ckpt.wait()
    finally:
        pipe.close()
    return state, history


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (host-scale)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--mesh", default="2,4", help="data,model grid")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--comm-mode", default="smi", choices=list(COMM_MODES),
                    help="collective mode; smi:<backend> picks the "
                         "transport (see repro/transport)")
    ap.add_argument("--remat", default="nothing")
    ap.add_argument("--compressed-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke(cfg)
    dims = tuple(int(x) for x in args.mesh.split(","))
    mesh = make_mesh(dims, ("data", "model")[: len(dims)] if len(dims) == 2
                     else ("pod", "data", "model"))
    shape = ShapeConfig("cli", seq_len=args.seq_len, global_batch=args.batch,
                        kind="train")
    st = TrainSettings(
        comm_mode=args.comm_mode, remat=args.remat, base_lr=args.lr,
        loss_chunks=1 if args.smoke else 8,
        compressed_grads=args.compressed_grads,
        total_steps=max(args.steps, 10),
        warmup_steps=max(args.steps // 10, 1),
    )
    t0 = time.time()
    _, history = train_loop(
        cfg, mesh, shape, st, steps=args.steps, ckpt_dir=args.ckpt_dir
    )
    print(f"[train] done in {time.time() - t0:.1f}s; "
          f"first loss {history[0]['loss']:.4f} -> last {history[-1]['loss']:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
