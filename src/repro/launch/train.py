"""Production training driver.

Wires together: arch config -> mesh -> SMI train step -> synthetic data
pipeline -> checkpointing -> watchdog + checkpoint/restart.  CLI:

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke \\
        --steps 50 --mesh 2,4 --comm-mode smi

``--smoke`` scales the arch to its reduced config so the driver runs on the
host devices; the full configs are exercised via the dry-run.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import Checkpointer
from ..configs import COMM_MODES, SHAPES, get_arch, smoke
from ..configs.base import ShapeConfig
from ..data.pipeline import SyntheticTokenPipeline
from ..ft import StepWatchdog
from .mesh import make_mesh
from .steps import TrainSettings, build_train


def train_loop(
    cfg, mesh, shape, settings: TrainSettings, *,
    steps: int, ckpt_dir: str | None = None, ckpt_every: int = 50,
    log_every: int = 10, seed: int = 0, state=None, start_step: int = 0,
    fail_at: int | None = None,
):
    art = build_train(cfg, mesh, shape, settings)
    if state is None:
        state = art["init_state"](seed)
    ckpt = Checkpointer(ckpt_dir) if ckpt_dir else None
    pipe = SyntheticTokenPipeline(
        cfg.vocab_size, shape.seq_len, shape.global_batch,
        seed=seed, n_codebooks=cfg.n_codebooks,
    )
    wd = StepWatchdog()
    wd.start()
    history = []
    try:
        for step in range(start_step, steps):
            if fail_at is not None and step == fail_at:
                raise RuntimeError("injected node failure")
            hostb = pipe.next()
            batch = {
                "tokens": jnp.asarray(hostb["tokens"]),
                "labels": jnp.asarray(hostb["labels"]),
            }
            if cfg.frontend == "vit_stub":
                rng = np.random.RandomState(seed * 7919 + step)
                batch["pixel_embeds"] = jnp.asarray(
                    rng.randn(shape.global_batch, cfg.n_patches, cfg.d_model)
                    * 0.02,
                    jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32,
                )
            state, metrics = art["step"](state, batch)
            slow = wd.lap(step)
            if step % log_every == 0 or step == steps - 1:
                m = {k: float(v) for k, v in metrics.items()}
                m.update(step=step, straggler=slow)
                history.append(m)
                print(f"[train] step={step} loss={m['loss']:.4f} "
                      f"ce={m['ce']:.4f} gnorm={m['gnorm']:.3f} lr={m['lr']:.2e}",
                      flush=True)
            if ckpt and step > 0 and step % ckpt_every == 0:
                ckpt.save(state, step, async_=True)
        if ckpt:
            ckpt.save(state, steps)
            ckpt.wait()
    finally:
        pipe.close()
    return state, history


def validate_comm(cfg, mesh, dims, shape, settings: TrainSettings) -> int:
    """Predicted-vs-measured channel traffic gate (DESIGN.md §12).

    Traces one training step (abstract lowering — no device compute),
    captures every tagged channel's ledger tallies, and diffs them against
    :func:`repro.netsim.predict_train_step_stats` per tag.  The contract is
    byte-exact: any per-tag difference in steps or bytes is a failure."""
    from ..netsim import predict_train_step_stats
    from ..parallel import ledger

    dp = int(np.prod(dims[:-1]))
    tp = dims[-1]
    art = build_train(cfg, mesh, shape, settings)
    batch = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
             for k, v in art["input_specs"].items()}
    with ledger.capture() as led:
        art["step"].lower(art["state_shape"], batch)
    measured = {t: dict(e) for t, e in led.by_tag.items()}
    predicted = predict_train_step_stats(cfg, (dp, tp), shape, settings)

    mesh_s = ",".join(str(d) for d in dims)
    print(f"[validate-comm] arch={cfg.name} mesh={mesh_s} "
          f"comm={settings.comm_mode}")
    print(f"  {'tag':<16} {'pred bytes':>12} {'meas bytes':>12} "
          f"{'pred steps':>11} {'meas steps':>11}")
    failures = 0
    for tag in sorted(set(predicted) | set(measured)):
        p = predicted.get(tag, {"steps": 0, "bytes": 0})
        m = measured.get(tag, {"steps": 0, "bytes": 0})
        ok = p == m
        failures += 0 if ok else 1
        print(f"  {tag:<16} {p['bytes']:>12} {m['bytes']:>12} "
              f"{p['steps']:>11} {m['steps']:>11}  {'ok' if ok else 'FAIL'}")
    if failures:
        print(f"[validate-comm] FAIL: {failures} tag(s) diverge")
        return 1
    print(f"[validate-comm] ok: {len(measured)} tags byte-exact "
          f"({sum(e['bytes'] for e in measured.values())} bytes/step)")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (host-scale)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--mesh", default="2,4", help="data,model grid")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--comm-mode", default="smi", choices=list(COMM_MODES),
                    help="collective mode; smi:<backend> picks the "
                         "transport (see repro/transport)")
    ap.add_argument("--remat", default="nothing")
    ap.add_argument("--compressed-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--validate-comm", action="store_true",
                    help="trace one step and gate the per-tag channel "
                         "ledger against netsim's prediction, byte-exact")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke(cfg)
    dims = tuple(int(x) for x in args.mesh.split(","))
    mesh = make_mesh(dims, ("data", "model")[: len(dims)] if len(dims) == 2
                     else ("pod", "data", "model"))
    shape = ShapeConfig("cli", seq_len=args.seq_len, global_batch=args.batch,
                        kind="train")
    st = TrainSettings(
        comm_mode=args.comm_mode, remat=args.remat, base_lr=args.lr,
        loss_chunks=1 if args.smoke else 8,
        compressed_grads=args.compressed_grads,
        total_steps=max(args.steps, 10),
        warmup_steps=max(args.steps // 10, 1),
    )
    if args.validate_comm:
        return validate_comm(cfg, mesh, dims, shape, st)
    t0 = time.time()
    _, history = train_loop(
        cfg, mesh, shape, st, steps=args.steps, ckpt_dir=args.ckpt_dir
    )
    print(f"[train] done in {time.time() - t0:.1f}s; "
          f"first loss {history[0]['loss']:.4f} -> last {history[-1]['loss']:.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
