import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Roofline analysis (deliverable g).

Per (arch × shape) on the single-pod mesh, derive the three roofline terms
from compiled artifacts:

    compute_t    = HLO_FLOPs / peak_FLOPs
    memory_t     = HLO_bytes / HBM_bw
    collective_t = collective_wire_bytes / ICI_bw          (per device)

XLA's ``cost_analysis`` counts while-loop bodies ONCE, and the layer stack
runs under ``lax.scan`` — so the honest total is assembled as

    total(X) = full_step(X) + (n_periods - 1) * period_probe(X)

where the *period probe* is a separately compiled (value_and_grad of the)
single layer-period body under the identical shard_map/remat/FSDP context.
The full-step numbers come from launch/dryrun.py's JSONL; the probe is
compiled here.  MODEL_FLOPs uses the 6·N_active·D (train) / 2·N_active·D
(inference) convention, N_active including embeddings (stated in
EXPERIMENTS.md).  Fraction-of-roofline = MODEL_FLOPs-time / dominant term.

    PYTHONPATH=src python -m repro.launch.roofline --results dryrun_results.jsonl
"""

import argparse
import json
import sys

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs import ARCHS, SHAPES, get_arch
from ..mesh.api import build_fsdp_plan, fsdp_storage_specs, fsdp_gather, make_ctx
from ..models.transformer import (
    apply_block,
    block_cache_specs,
    block_specs,
    decode_block,
    init_block,
    init_block_cache,
    REMAT_POLICIES,
)
from ..netsim import LinkModel
from .dryrun import collective_bytes
from .mesh import batch_axes_of, make_production_mesh
from .steps import globalize_structs, _sh

PEAK = 197e12     # bf16 FLOP/s per v5e chip
HBM = 819e9       # B/s
# collective term comes from the shared netsim link model (the same one the
# benchmarks' derived columns and the autotuner use), not an ad-hoc constant
NET_MODEL = LinkModel.default_v5e()


def _probe_period(cfg, shape, mesh, *, comm_mode="smi", remat="nothing",
                  fsdp=True, shared_gather=False, ring_attn=False):
    """Compile one layer-period's (train: fwd+bwd) body; return cost dict."""
    batch_axes = batch_axes_of(mesh)
    ctx = make_ctx(mesh, model_axis="model", batch_axes=batch_axes,
                   comm_mode=comm_mode, opt_shared_gather=shared_gather,
                   opt_ring_attn=ring_attn)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = 1
    for a in batch_axes:
        dp *= sizes[a]
    tp = sizes["model"]

    pattern = cfg.pattern
    key = jax.random.PRNGKey(0)
    pshapes = jax.eval_shape(
        lambda: tuple(init_block(key, k, cfg, ctx) for k in pattern)
    )
    pspecs = tuple(block_specs(k, cfg, ctx) for k in pattern)
    plan = build_fsdp_plan(pshapes, pspecs, mesh, batch_axes) if fsdp else None
    store = fsdp_storage_specs(pspecs, plan, batch_axes) if fsdp else pspecs
    pshapes_bf16 = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(
            l.shape, jnp.bfloat16 if l.dtype == jnp.float32 else l.dtype
        ), pshapes,
    )

    B = shape.global_batch
    b_ok = B % dp == 0 and dp > 1
    B_spec = (tuple(batch_axes) if len(batch_axes) > 1 else batch_axes[0]) if b_ok else None

    if shape.kind == "train":
        S = shape.seq_len
        x_struct = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        x_spec = P(B_spec, "model", None)

        def period(pp, x):
            def f(pp_, x_):
                if plan is not None:
                    pp_ = fsdp_gather(pp_, plan, ctx)
                aux = jnp.zeros((), jnp.float32)
                for j, k in enumerate(pattern):
                    x_, a = apply_block(pp_[j], k, x_, cfg, ctx)
                    aux = aux + a
                return jnp.sum(x_.astype(jnp.float32)) + aux

            body = f
            if remat != "none":
                body = jax.checkpoint(f, policy=REMAT_POLICIES[remat]())
            g = jax.grad(body, argnums=(0, 1))(pp, x)
            # collapse grads to one scalar (negligible extra flops) so the
            # probe's out_specs stay trivial
            leaves = jax.tree.leaves(g)
            return sum(jnp.sum(jnp.abs(l.astype(jnp.float32))) for l in leaves)

        sm = jax.shard_map(period, mesh=mesh, in_specs=(store, x_spec),
                           out_specs=P(), check_vma=False)
        lowered = jax.jit(sm).lower(pshapes_bf16, x_struct)
    elif shape.kind == "prefill":
        S = shape.seq_len
        x_struct = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        x_spec = P(B_spec, "model", None)

        def period(pp, x):
            if plan is not None:
                pp = fsdp_gather(pp, plan, ctx)
            for j, k in enumerate(pattern):
                x, _ = apply_block(pp[j], k, x, cfg, ctx)
            return x

        sm = jax.shard_map(period, mesh=mesh, in_specs=(store, x_spec),
                           out_specs=x_spec, check_vma=False)
        lowered = jax.jit(sm).lower(pshapes_bf16, x_struct)
    else:  # decode
        B_loc = B // dp if b_ok else B
        cspecs = tuple(block_cache_specs(k, ctx, b_ok) for k in pattern)
        clocal = jax.eval_shape(
            lambda: tuple(
                init_block_cache(k, cfg, B_loc, shape.seq_len, ctx, jnp.bfloat16)
                for k in pattern
            )
        )
        cglobal = globalize_structs(clocal, cspecs, mesh)
        x_struct = jax.ShapeDtypeStruct((B, 1, cfg.d_model), jnp.bfloat16)
        x_spec = P(B_spec, None, None)

        def period(pp, cc, x):
            if plan is not None:
                pp = fsdp_gather(pp, plan, ctx)
            new_cc = []
            for j, k in enumerate(pattern):
                x, c = decode_block(pp[j], k, x, cc[j], jnp.asarray(123), cfg, ctx)
                new_cc.append(c)
            return x, tuple(new_cc)

        sm = jax.shard_map(period, mesh=mesh, in_specs=(store, cspecs, x_spec),
                           out_specs=(x_spec, cspecs), check_vma=False)
        lowered = jax.jit(sm).lower(pshapes_bf16, cglobal, x_struct)

    compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": collective_bytes(compiled.as_text())["total"],
    }


def useful_bytes_per_device(cfg, shape, n_chips):
    """Minimum HBM traffic per device per step: params (+opt state for
    train) + KV/state caches (decode) + activations in/out, bf16/f32."""
    n = cfg.param_count()
    if shape.kind == "train":
        # fwd+bwd param reads (bf16) + grad write + Adam m/v read/write (f32)
        per_dev = n / n_chips
        return per_dev * (2 * 2 + 4 + 4 * 4)
    if shape.kind == "prefill":
        return (n / n_chips) * 2
    # decode: params (bf16) + full KV/state cache read per token
    cache = 0
    S_eff = shape.seq_len if cfg.local_window is None else min(
        shape.seq_len, cfg.local_window)
    for kind in cfg.layer_pattern:
        if kind in ("attn", "moe"):
            cache += 2 * cfg.n_kv_heads * cfg.hd * S_eff * shape.global_batch * 2
        elif kind == "ssm":
            d_in = cfg.ssm_expand * cfg.d_model
            nh = d_in // cfg.ssm_headdim
            cache += nh * cfg.ssm_state * cfg.ssm_headdim * shape.global_batch * 4
        elif kind == "rec":
            cache += (cfg.lru_width or cfg.d_model) * shape.global_batch * 4
    n_act = cfg.active_param_count()
    return (n_act * 2 + cache) / n_chips


def model_flops_per_device(cfg, shape, n_chips):
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        total = 6 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        total = 2 * n_active * tokens
    else:
        total = 2 * n_active * shape.global_batch  # one new token per seq
    return total / n_chips


def analyze_cell(rec, *, comm_mode="smi", remat="nothing",
                 shared_gather=False, ring_attn=False):
    cfg = get_arch(rec["arch"])
    shape = SHAPES[rec["shape"]]
    mesh = make_production_mesh(multi_pod=False)
    n_chips = 256
    period = len(cfg.pattern)
    n_periods = cfg.n_layers // period

    probe = _probe_period(cfg, shape, mesh, comm_mode=comm_mode,
                          remat=remat if shape.kind == "train" else "none",
                          shared_gather=shared_gather, ring_attn=ring_attn)
    full = {
        "flops": rec["cost"]["flops"],
        "bytes": rec["cost"]["bytes_accessed"],
        "coll": rec["collectives"]["total"],
    }
    total = {
        k: full[k] + max(n_periods - 1, 0) * probe[k] for k in full
    }
    terms = {
        "compute_s": total["flops"] / PEAK,
        "memory_s": total["bytes"] / HBM,
        "collective_s": NET_MODEL.serialization(total["coll"]),
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops_per_device(cfg, shape, n_chips)
    ub = useful_bytes_per_device(cfg, shape, n_chips)
    useful_s = mf / PEAK
    useful_mem_s = ub / HBM
    # compute-roofline fraction for compute kinds; memory-roofline fraction
    # (how close HBM traffic is to the minimum) for decode
    frac = max(useful_s, useful_mem_s if shape.kind == "decode" else 0.0) \
        / max(terms[dominant], 1e-30)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "kind": shape.kind,
        "comm_mode": comm_mode, "variant": rec.get("variant", "base"),
        "period_probe": probe, "full_step": full, "total": total,
        "terms_s": {k: round(v, 6) for k, v in terms.items()},
        "dominant": dominant,
        "model_flops_per_dev": mf,
        "useful_bytes_per_dev": ub,
        "useful_mem_s": round(useful_mem_s, 6),
        "hlo_over_model_flops": total["flops"] / max(mf, 1e-30),
        "roofline_fraction": round(frac, 4),
        "temp_gb": rec["memory"]["temp_gb"],
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", default="dryrun_results.jsonl")
    ap.add_argument("--out", default="roofline_results.jsonl")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--comm-mode", default="smi")
    ap.add_argument("--remat", default="nothing")
    args = ap.parse_args(argv)

    recs = {}
    for line in open(args.results):
        r = json.loads(line)
        if r.get("ok") and not r.get("skipped") and r["mesh"] == "16x16":
            recs[(r["arch"], r["shape"])] = r  # last wins

    rows = []
    for (arch, shape), rec in sorted(recs.items()):
        if args.arch and arch != args.arch:
            continue
        if args.shape and shape != args.shape:
            continue
        try:
            row = analyze_cell(rec, comm_mode=args.comm_mode, remat=args.remat)
            rows.append(row)
            t = row["terms_s"]
            print(f"[roofline] {arch:24s} {shape:12s} "
                  f"comp={t['compute_s']:.4f}s mem={t['memory_s']:.4f}s "
                  f"coll={t['collective_s']:.4f}s dom={row['dominant']:12s} "
                  f"frac={row['roofline_fraction']:.3f} "
                  f"hlo/model={row['hlo_over_model_flops']:.2f}", flush=True)
            if args.out:
                with open(args.out, "a") as f:
                    f.write(json.dumps(row) + "\n")
        except Exception as e:  # noqa: BLE001
            print(f"[roofline] {arch} {shape} FAILED: {type(e).__name__}: {e}",
                  flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
