"""Launch the distributed halo-exchange stencil (paper §5.4.2).

Runs ``repro.apps.DistributedStencil`` over a rank grid on host devices,
streams halos through the selected transport backend, verifies against the
single-rank sweep, and prints measured vs LinkModel-predicted step times.

    PYTHONPATH=src python -m repro.launch.stencil --case torus2x4 \\
        --comm-mode smi:compressed --steps 8
    PYTHONPATH=src python -m repro.launch.stencil --grid 2x4 \\
        --domain 512x512 --no-overlap --json out.json
    PYTHONPATH=src python -m repro.launch.stencil --trace trace.json \\
        --metrics metrics.json

``--trace`` writes a Chrome-trace / Perfetto file with one lane per rank
(measured steps), one lane per directed link (the netsim-predicted halo
flit timeline), and the channel/halo schedule events recorded while
tracing the program — the predicted-vs-measured overlay of DESIGN.md §11.
``--metrics`` snapshots the obs metrics registry (halo transport counters
per tag + the wall-vs-model drift gauge) to JSON.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import argparse
import json
import time

import jax
import numpy as np

from ..configs import COMM_MODES, STENCIL_CASES


def _pair(s: str) -> tuple[int, int]:
    a, _, b = s.partition("x")
    return int(a), int(b)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--case", default=None, choices=sorted(STENCIL_CASES),
                    help="predefined (grid, domain, steps) cell")
    ap.add_argument("--grid", default="2x4", help="rank grid RXxRY")
    ap.add_argument("--domain", default="256x256", help="global domain XxY")
    ap.add_argument("--steps", type=int, default=8)
    ap.add_argument("--comm-mode", default="smi",
                    help=f"one of {COMM_MODES} (smi:<backend> selects the "
                         "transport; 'smi' = static; plan=auto tunes it)")
    ap.add_argument("--plan", default=None, choices=[None, "auto"],
                    help="'auto' lets the netsim tuning table pick the "
                         "halo backend")
    ap.add_argument("--no-overlap", action="store_true",
                    help="run the non-overlapped reference schedule")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write machine-readable results to OUT")
    ap.add_argument("--trace", default=None, metavar="OUT",
                    help="write a Chrome trace (rank lanes + per-link "
                         "netsim-predicted overlay) to OUT")
    ap.add_argument("--metrics", default=None, metavar="OUT",
                    help="write an obs metrics snapshot (transport "
                         "counters + drift gauges) to OUT")
    args = ap.parse_args(argv)

    from ..apps import DistributedStencil

    grid, domain, steps = _pair(args.grid), _pair(args.domain), args.steps
    if args.case:
        c = STENCIL_CASES[args.case]
        grid, domain, steps = c["grid"], c["domain"], c["steps"]

    if args.plan == "auto":
        if args.comm_mode != "smi":
            ap.error("--plan auto lets the tuner pick the backend; it "
                     "cannot be combined with an explicit --comm-mode")
        comm_mode = None
    else:
        comm_mode = args.comm_mode
    app = DistributedStencil.create(
        grid, comm_mode=comm_mode, plan=args.plan
    )
    mode_label = args.comm_mode if args.plan != "auto" else "smi(auto)"
    world = np.random.RandomState(0).randn(*domain).astype(np.float32)
    tiles = app.scatter(world)
    mesh = app.make_mesh()
    overlapped = not args.no_overlap

    if args.trace:
        from ..obs import trace as obs_trace
        obs_trace.enable(capacity=1 << 18)
    # an explicit transport instance (rather than the spec's lazy resolve)
    # lets the metrics registry snapshot the traced per-tag counters;
    # plan=auto must keep resolving per tile size, so it stays lazy
    tp = app.halo_schedule.resolve_transport() if args.plan != "auto" else None
    f = app.jitted(mesh, n_steps=steps, overlapped=overlapped, transport=tp)
    got = np.asarray(jax.block_until_ready(f(tiles)))  # compile + warm
    t0 = time.perf_counter()
    jax.block_until_ready(f(tiles))
    wall = time.perf_counter() - t0

    want = app.single_rank_reference(world, steps)
    err = float(np.max(np.abs(app.gather(got) - want)))
    # lossy only when compressed links actually move the halos (tuned
    # plans are raw-wire by construction, so plan="auto" gates exactly)
    lossy = (comm_mode or "").startswith("smi:compressed")
    ok = err == 0.0 if not lossy else err < 1e-1
    nx, ny = domain[0] // grid[0], domain[1] // grid[1]
    model_s = app.predicted_step_time(
        (nx, ny), wire="int8" if lossy else "raw"
    ) * steps

    sched = "overlapped" if overlapped else "reference"
    print(f"[stencil] grid={grid} domain={domain} steps={steps} "
          f"comm_mode={mode_label} schedule={sched}")
    print(f"[stencil] wall={wall * 1e6:.1f}us  "
          f"v5e_model_halo={model_s * 1e6:.1f}us  max|err|={err:.3g} "
          f"{'OK' if ok else 'MISMATCH'}")

    from ..obs.metrics import REGISTRY
    if tp is not None:
        REGISTRY.track("halo", tp)
    REGISTRY.drift("stencil/wall_vs_model", predicted=model_s, measured=wall)

    if args.trace:
        from ..netsim.schedule import halo_rounds, halo_slab_elems
        from ..netsim.sim import simulate
        from ..obs import trace as obs_trace
        from ..obs.export import sim_report_events, write_chrome_trace

        tracer = obs_trace.disable()
        events = list(tracer.events()) if tracer else []
        # measured rank lanes: SPMD lockstep means every rank ran the same
        # schedule — split the timed wall across steps, one lane per rank
        per_step = wall / max(steps, 1)
        for r in range(app.comm.size):
            for s in range(steps):
                events.append({
                    "ts": s * per_step, "rank": r, "kind": "run.step",
                    "tag": mode_label, "port": None,
                    "attrs": {"dur": per_step, "step": s},
                })
        # predicted overlay: replay the halo rounds through the tick
        # simulator with the move log on, one lane per directed link
        ns_e, ew_e = halo_slab_elems((nx, ny))
        reports = [
            simulate(app.comm.topology, app.comm.route_table, msgs,
                     trace=True)
            for msgs in halo_rounds(grid, ns_e * 4, ew_e * 4)
        ]
        n_ev = write_chrome_trace(args.trace, events + sim_report_events(
            app.comm.topology, reports, wire="int8" if lossy else "raw",
        ))
        print(f"[stencil] wrote {n_ev} trace events to {args.trace}")

    if args.metrics:
        with open(args.metrics, "w") as fm:
            json.dump(REGISTRY.snapshot(), fm, indent=1)
        print(f"[stencil] wrote metrics snapshot to {args.metrics}")
    if args.json:
        with open(args.json, "w") as fjs:
            json.dump({
                "grid": grid, "domain": domain, "steps": steps,
                "comm_mode": mode_label, "schedule": sched,
                "wall_us": wall * 1e6, "v5e_model_halo_us": model_s * 1e6,
                "max_err": err, "ok": bool(ok),
                "metrics": REGISTRY.snapshot(),
            }, fjs, indent=1)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
