"""Serving driver: wave or continuous-batching decode.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \\
        --requests 6 --max-new 12

    # tensor-parallel continuous batching on persistent channels
    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \\
        --mesh 1,8 --comm-mode smi:static

    # predicted-vs-measured channel gate for ONE decode step + migration
    PYTHONPATH=src python -m repro.launch.serve --arch glm4-9b --smoke \\
        --mesh 2,4 --comm-mode smi:static --validate-comm

``--engine wave`` runs the lock-step wave engine (single-device only —
the bit-exactness oracle); the default continuous engine admits into any
free slot and, under a model-parallel mesh, decodes over ONE persistent
channel per layer tag from the serving :class:`~repro.channels.
ChannelPool`, released at shutdown.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import COMM_MODES, get_arch, smoke
from ..mesh.api import ParallelCtx
from ..models import init_lm
from ..serving import ContinuousEngine, Request, ServeEngine
from .mesh import make_mesh
from .steps import build_continuous_serve


def validate_comm(cfg, mesh, dims, args) -> int:
    """Predicted-vs-measured channel traffic gate for the serving step
    (DESIGN.md §12/§13): traces one continuous decode step plus one slot
    migration (abstract lowering), captures the tagged channel ledger,
    and diffs it against :func:`repro.netsim.predict_decode_step_stats`
    per ``serve.*`` tag.  Byte-exact, like the training gate."""
    from ..netsim import predict_decode_step_stats
    from ..parallel import ledger

    if ":" not in args.comm_mode:
        print("[validate-comm] need a pinned backend (smi:<backend>); "
              "bare 'smi' lets the per-tag tuner pick schedules the "
              "predictor cannot see")
        return 2
    dp, tp = int(np.prod(dims[:-1])), dims[-1]
    rt = build_continuous_serve(cfg, mesh, comm_mode=args.comm_mode,
                                batch_slots=args.slots,
                                capacity=args.capacity)
    ctx = rt["ctx"]
    B = rt["batch_slots"]
    pshapes = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg, ctx))
    cshapes = jax.eval_shape(rt["init_caches"])
    tok = jax.ShapeDtypeStruct(
        (B, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B,), jnp.int32
    )
    pos = jax.ShapeDtypeStruct((B,), jnp.int32)
    slot = jax.ShapeDtypeStruct((), jnp.int32)
    migrations = 1 if tp > 1 else 0
    with ledger.capture() as led:
        rt["step"].lower(pshapes, cshapes, tok, pos)
        if migrations:
            infl = jax.eval_shape(rt["migrate_start"], cshapes, slot)
            rt["migrate_start"].lower(cshapes, slot)
            rt["migrate_finish"].lower(cshapes, infl, slot)
    measured = {t: dict(e) for t, e in led.by_tag.items()}
    predicted = predict_decode_step_stats(
        cfg, (dp, tp), B, args, capacity=args.capacity,
        migrations=migrations,
    )
    if rt["pool"] is not None:
        rt["pool"].close()

    mesh_s = ",".join(str(d) for d in dims)
    print(f"[validate-comm] arch={cfg.name} mesh={mesh_s} "
          f"comm={args.comm_mode} slots={B} migrations={migrations}")
    print(f"  {'tag':<22} {'pred bytes':>12} {'meas bytes':>12} "
          f"{'pred steps':>11} {'meas steps':>11}")
    failures = 0
    for tag in sorted(set(predicted) | set(measured)):
        p = predicted.get(tag, {"steps": 0, "bytes": 0})
        m = measured.get(tag, {"steps": 0, "bytes": 0})
        ok = p == m
        failures += 0 if ok else 1
        print(f"  {tag:<22} {p['bytes']:>12} {m['bytes']:>12} "
              f"{p['steps']:>11} {m['steps']:>11}  {'ok' if ok else 'FAIL'}")
    if failures:
        print(f"[validate-comm] FAIL: {failures} tag(s) diverge")
        return 1
    print(f"[validate-comm] ok: {len(measured)} tags byte-exact "
          f"({sum(e['bytes'] for e in measured.values())} bytes/step)")
    return 0


def _submit_all(eng, cfg, n_requests, max_new, seed=0):
    rng = np.random.RandomState(seed)
    for uid in range(n_requests):
        plen = int(rng.randint(3, 9))
        if cfg.n_codebooks > 1:
            prompt = rng.randint(
                0, cfg.vocab_size, (plen, cfg.n_codebooks)
            ).tolist()
        else:
            prompt = rng.randint(0, cfg.vocab_size, (plen,)).tolist()
        eng.submit(Request(uid=uid, prompt=prompt, max_new=max_new))


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--engine", default="continuous",
                    choices=["continuous", "wave"])
    ap.add_argument("--mesh", default="1,1", help="data,model grid")
    ap.add_argument("--comm-mode", default="smi", choices=list(COMM_MODES))
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--capacity", type=int, default=64)
    ap.add_argument("--validate-comm", action="store_true",
                    help="trace one serve step + migration and gate the "
                         "serve.* channel ledger against netsim, byte-exact")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke(cfg)
    dims = tuple(int(x) for x in args.mesh.split(","))
    parallel = int(np.prod(dims)) > 1

    if args.validate_comm:
        mesh = make_mesh(dims, ("data", "model"))
        return validate_comm(cfg, mesh, dims, args)

    if parallel:
        if args.engine == "wave":
            print("[serve] the wave engine is single-device only; use "
                  "--engine continuous for a parallel mesh")
            return 2
        mesh = make_mesh(dims, ("data", "model"))
        rt = build_continuous_serve(cfg, mesh, comm_mode=args.comm_mode,
                                    batch_slots=args.slots,
                                    capacity=args.capacity)
        params = init_lm(jax.random.PRNGKey(0), cfg, rt["ctx"])
        params = jax.device_put(params, rt["param_sharding"])
        eng = ContinuousEngine(cfg, params, runtime=rt)
        if rt["pool"] is not None:
            print(f"[serve] persistent channels: "
                  f"{sorted(rt['pool'].ports().items())}")
    else:
        ctx = ParallelCtx()
        params = init_lm(jax.random.PRNGKey(0), cfg, ctx)
        cls = ServeEngine if args.engine == "wave" else ContinuousEngine
        eng = cls(cfg, params, ctx=ctx, batch_slots=args.slots,
                  capacity=args.capacity)

    _submit_all(eng, cfg, args.requests, args.max_new)
    t0 = time.time()
    done = eng.run(max_steps=1024)
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    print(f"[serve] engine={args.engine} completed {len(done)}/"
          f"{args.requests} requests, {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s)")
    for r in done:
        print(f"  req {r.uid}: {r.out[:8]}{'...' if len(r.out) > 8 else ''}")
    if isinstance(eng, ContinuousEngine):
        eng.shutdown()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
