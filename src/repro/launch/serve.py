"""Serving driver: batched decode with the ServeEngine.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-6b --smoke \\
        --requests 6 --max-new 12
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_arch, smoke
from ..mesh.api import ParallelCtx
from ..models import init_lm
from ..serving import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.smoke:
        cfg = smoke(cfg)
    ctx = ParallelCtx()
    params = init_lm(jax.random.PRNGKey(0), cfg, ctx)
    eng = ServeEngine(cfg, params, ctx=ctx, batch_slots=args.slots, capacity=64)
    rng = np.random.RandomState(0)
    t0 = time.time()
    for uid in range(args.requests):
        plen = int(rng.randint(3, 9))
        if cfg.n_codebooks > 1:
            prompt = rng.randint(0, cfg.vocab_size, (plen, cfg.n_codebooks)).tolist()
        else:
            prompt = rng.randint(0, cfg.vocab_size, (plen,)).tolist()
        eng.submit(Request(uid=uid, prompt=prompt, max_new=args.max_new))
    done = eng.run(max_steps=1024)
    dt = time.time() - t0
    toks = sum(len(r.out) for r in done)
    print(f"[serve] completed {len(done)}/{args.requests} requests, "
          f"{toks} tokens in {dt:.1f}s ({toks / dt:.1f} tok/s)")
    for r in done:
        print(f"  req {r.uid}: {r.out[:8]}{'...' if len(r.out) > 8 else ''}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
