"""First-class SMI channels: the user-facing communication API.

The paper's programming model is *channels all the way down* (§2.2–§2.4):
programs open send/recv channels and transient collective channels
(``SMI_Open_bcast_channel``, ``SMI_Open_reduce_channel``, ...) and
communicate element-by-element with ``SMI_Push`` / ``SMI_Pop``, which is
what lets communication fuse into pipelined kernels.  This package is that
API for the TPU rendering:

* :class:`ChannelSpec` — the single carrier of communication config
  (peer/root, port, transport backend, wire format, stats tag, tuning
  plan), replacing the historic per-call kwarg sprawl;
* :func:`open_channel` — p2p channels with :meth:`~Channel.push` /
  :meth:`~Channel.pop` element pipelining (latency = routed hops) and a
  whole-message :meth:`~Channel.transfer`, all moving through the
  channel's transport backend;
* :func:`open_bcast_channel` / :func:`open_reduce_channel` /
  :func:`open_scatter_channel` / :func:`open_gather_channel` /
  :func:`open_allreduce_channel` — transient collective channels whose
  ``transfer`` lowers onto the streamed collective schedules,
  bit-identical to the direct calls on every transport backend;
* :data:`PORTS` — the default :class:`~repro.core.comm.PortAllocator`
  every ``open_*`` claims its port from; channels are context managers
  and release the port on close/scope exit;
* :class:`ChannelPool` — the *persistent* lifecycle
  (``ChannelSpec(persistent=True)``): one strongly-held port claim per
  layer tag that survives trace exits and is released only on explicit
  close / engine shutdown — the serving engine's channel context;
* :func:`default_channel_spec` — ``comm_mode="smi:<backend>"`` strings
  mapped onto their channel spec.

The legacy ``stream_*`` entry points in :mod:`repro.core` remain as thin
shims that open a transient (anonymous-port) channel internally; see
DESIGN.md §9 for the migration table.
"""

from .spec import KINDS, ChannelSpec, default_channel_spec
from .channel import (
    PORTS,
    Channel,
    channel_transfer,
    open_channel,
    pop,
    push,
)
from .collective import (
    CollectiveChannel,
    open_allreduce_channel,
    open_bcast_channel,
    open_gather_channel,
    open_reduce_channel,
    open_scatter_channel,
)
from .persistent import ChannelPool

__all__ = [
    "KINDS",
    "ChannelSpec",
    "default_channel_spec",
    "PORTS",
    "ChannelPool",
    "Channel",
    "channel_transfer",
    "open_channel",
    "pop",
    "push",
    "CollectiveChannel",
    "open_allreduce_channel",
    "open_bcast_channel",
    "open_gather_channel",
    "open_reduce_channel",
    "open_scatter_channel",
]
