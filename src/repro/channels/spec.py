"""ChannelSpec: the single carrier of communication configuration.

The paper configures every transfer at channel-open time: peer, port,
communicator (§2.2–§2.4).  This repo historically scattered the TPU-side
equivalents — transport backend, wire format, message tag, tuning plan —
over per-call kwargs (``transport=``, ``plan=``, ``tag=``, the deprecated
``quantize=``/``dequantize=``).  :class:`ChannelSpec` folds all of them
into the open-time descriptor, so a channel *is* its communication config:

* ``port`` — the hardware-endpoint id, claimed through the communicator's
  :class:`~repro.core.comm.PortAllocator` at open time (``None`` =
  anonymous: no claim, used by the transient ``stream_*`` shims);
* ``transport`` — a registry key, a live Transport instance, or ``None``
  (the communicator's default backend);
* ``wire`` — ``"raw"`` | ``"int8"``: an int8 wire composes the transport
  with the compressed-link backend, exactly like a tuned
  :class:`~repro.netsim.tune.Plan` does;
* ``tag`` — the :class:`~repro.transport.base.TransportStats` bucket every
  step of this channel is accounted under (default: ``"port<N>"`` for
  claimed ports), which is what lets ``netsim.predict_channel_stats`` be
  asserted against exactly this channel's wire traffic;
* ``plan`` — ``None`` | ``"auto"`` | a Plan: defers backend / chunk-count
  / wire selection to the netsim tuning table at transfer time.

Specs ride in the channel pytree's aux data, so they must stay hashable:
the ``transport`` / ``plan`` / ``op`` fields (possibly live objects or
functions) are excluded from equality and hashing.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

from ..core.comm import Communicator

#: channel kinds -> the netsim tuner op their plans are keyed on
KINDS = ("p2p", "bcast", "reduce", "scatter", "gather", "allreduce",
         "exchange")


@dataclass(frozen=True)
class ChannelSpec:
    """Static descriptor: the SMI_Open_*_channel arguments, TPU-rendered."""

    comm: Communicator
    kind: str = "p2p"
    #: elements the channel will carry (``None`` = unbounded); push/pop
    #: validity gates on ``min(count, pushed)``
    count: int | None = None
    src: int = 0
    dst: int = 0
    root: int = 0
    #: claimed hardware endpoint id; ``None`` = anonymous (no claim)
    port: int | None = 0
    #: persistent lifecycle: the port claim is held by strong reference on
    #: the allocator — it survives trace exits (no weakref lapse) and is
    #: released only on explicit close / pool shutdown.  The serving
    #: engine's per-layer channels use this; transient channels (default)
    #: keep the weakref lifecycle.
    persistent: bool = False
    transport: object = field(default=None, compare=False)
    wire: str = "raw"
    tag: str | None = None
    plan: object = field(default=None, compare=False)
    #: reduction operator for reduce channels (``None`` -> jnp.add)
    op: object = field(default=None, compare=False)
    n_chunks: int = 1
    #: the allocator holding this spec's port claim (set by open_*)
    allocator: object = field(default=None, compare=False, repr=False)

    def __post_init__(self):
        assert self.kind in KINDS, (
            f"unknown channel kind {self.kind!r}; one of {KINDS}"
        )
        assert self.wire in ("raw", "int8"), (
            f"unknown wire format {self.wire!r}; 'raw' or 'int8'"
        )

    # -- route queries (p2p) ------------------------------------------------

    @property
    def path(self) -> list[int]:
        return self.comm.route_table.path(self.src, self.dst)

    @property
    def hops(self) -> int:
        return len(self.path) - 1

    # -- stats tagging -------------------------------------------------------

    @property
    def stats_tag(self) -> str | None:
        """The TransportStats bucket this channel accounts under: an
        explicit ``tag``, else ``"port<N>"`` for claimed ports, else
        ``None`` (untagged — the anonymous stream_* shims)."""
        if self.tag is not None:
            return self.tag
        if self.port is not None:
            return f"port{self.port}"
        return None

    # -- transport resolution ------------------------------------------------

    @property
    def transport_key(self) -> str:
        """Registry key realising this spec's backend + wire (for netsim
        predictions and comm_mode round-trips).  Requires a string-keyed
        spec; a live instance's key is reconstructed from its chain."""
        t = self.transport
        if t is None:
            t = self.comm.transport
        if not isinstance(t, str):
            t = _instance_key(t)
        return _compose_wire(t, self.wire)

    def resolve(self):
        """A Transport instance realising this spec's backend + wire.

        String keys (and ``None``) resolve to a *fresh* instance per call —
        per-trace stats, and the packet backend's cross-trace reuse guard
        stays satisfied; a live Transport instance passes through (wrapped
        in the compressed-link backend when ``wire="int8"``).

        Under :func:`repro.analysis.capture` every resolution — string
        key, ``None`` *and* live instance — yields the abstract accounting
        backend instead: this is the seam that makes capture-mode
        verification run whole programs without moving a byte."""
        import sys

        cap = sys.modules.get("repro.analysis.capture")
        if cap is not None and cap.ACTIVE:
            return cap.AbstractTransport()
        from ..transport.base import Transport
        from ..transport.registry import get_transport

        t = self.transport
        if isinstance(t, Transport):
            if self.wire == "int8" and not getattr(t, "lossy_wire", False):
                from ..transport.compressed import CompressedTransport

                return CompressedTransport(inner=t)
            return t
        key = t if t is not None else self.comm.transport
        return get_transport(_compose_wire(key, self.wire))

    def step_transport(self):
        """The instance the element-level push/pop pipeline drives: resolved
        once per spec (one open = one trace = one backend instance), so
        per-channel counters accumulate in one place.  Capture mode uses a
        separate cache slot, so a spec resolved both inside and outside a
        capture block never hands the wrong backend to either world."""
        import sys

        cap = sys.modules.get("repro.analysis.capture")
        slot = ("_abstract_step_transport"
                if cap is not None and cap.ACTIVE else "_step_transport")
        cached = self.__dict__.get(slot)
        if cached is None:
            cached = self.resolve()
            object.__setattr__(self, slot, cached)
        return cached

    # -- lifecycle -----------------------------------------------------------

    def release_port(self):
        """Release this spec's port claim (idempotent; a stale double
        release never frees a later claimant's port)."""
        if self.allocator is not None and self.port is not None:
            self.allocator.release(self.comm, self.port, owner=self)

    def replace(self, **kw) -> "ChannelSpec":
        return replace(self, **kw)


def _instance_key(t) -> str:
    """Reconstruct the registry key of a live Transport chain
    (``CompressedTransport(inner=PacketTransport)`` -> "compressed:packet")."""
    name = getattr(t, "name", "") or type(t).__name__
    inner = getattr(t, "inner", None)
    if inner is not None and getattr(t, "wraps_inner", False):
        return f"{name}:{_instance_key(inner)}"
    return name


def _compose_wire(key: str, wire: str) -> str:
    """Compose a backend key with a wire format, the same spelling a tuned
    Plan uses: an int8 wire wraps the backend in the compressed link."""
    if wire == "raw" or key.partition(":")[0] == "compressed":
        return key
    return f"compressed:{key}"


def default_channel_spec(
    comm: Communicator, comm_mode: str | None = None, **overrides
) -> ChannelSpec:
    """The ChannelSpec a ``comm_mode`` string denotes: ``"smi:<backend>"``
    maps onto a spec carrying that transport key (``"smi"`` = the
    communicator's default backend) — the launch-layer strings and the
    channel API name the same configuration."""
    if comm_mode is not None:
        from ..transport.registry import resolve_comm_mode

        base, backend = resolve_comm_mode(comm_mode)
        assert base == "smi", (
            f"only smi comm_modes map onto channels; got {comm_mode!r}"
        )
        overrides.setdefault("transport", backend)
    return ChannelSpec(comm=comm, **overrides)
