"""Transient collective channels (paper §2.4: SMI_Open_bcast/reduce/
scatter/gather_channel).

The paper's collectives are *channels*: a program opens a transient
collective channel and pushes/pops elements through it; the root (or every
rank) participates element-by-element, which is what lets a collective
fuse into a pipelined kernel.  Rendered for the TPU schedule world:

* **bcast** — the root pushes; every rank pops.  Fully pipelined chain
  (one hop-step per pop, ii=1): the element pushed first reaches ring
  distance d after d pops, validity travels in-band as an f32 flag so
  pipeline bubbles (pops without pushes) gate cleanly.
* **reduce** — every rank pushes its contribution; the root pops reduced
  elements.  Pipelined chain toward the root with a P-deep contribution
  FIFO per rank (the paper's credit window): the farthest rank injects,
  each rank folds its matching element into the passing stream, the root
  delivers after P-1 hop-steps.
* **scatter / gather / allreduce** — round channels: each pop runs one
  element-sized round of the corresponding streamed schedule (the paper's
  sequentially-coordinated scatter/gather; ring RS+AG for allreduce).
  Pushes are SPMD-lockstep (every rank traces the same push calls), so
  validity gates on the uniform call count.

Every kind also provides the whole-message :meth:`CollectiveChannel.
transfer`, which lowers onto the existing ``stream_*`` schedules (or the
autotuned dispatchers when the spec carries a plan) — bit-identical to
calling them directly, on every transport backend.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..analysis import capture as _capture
from ..core.comm import Communicator, PortAllocator
from ..obs import trace as obs
from .channel import _ChannelBase, _claim, _mask_sel, _pvary, _tagged
from .spec import ChannelSpec


def _i32(pred):
    return jnp.where(pred, 1, 0).astype(jnp.int32)


def _f32flag(pred):
    return jnp.where(pred, 1.0, 0.0).astype(jnp.float32)


def _take(buf, i):
    return jax.lax.dynamic_index_in_dim(buf, i, 0, keepdims=False)


@jax.tree_util.register_pytree_node_class
@dataclass
class CollectiveChannel(_ChannelBase):
    """Traced collective-channel state; layout depends on ``spec.kind``
    (see the module docstring).  ``pushed``/``popped`` count accepted
    pushes / valid deliveries; the spec rides in the pytree aux data so
    collective channels can be loop carries, exactly like p2p channels.
    close / ``with``-scope lifecycle comes from the shared
    :class:`~repro.channels.channel._ChannelBase`."""

    spec: ChannelSpec
    state: tuple
    pushed: jax.Array  # i32: accepted pushes so far at this rank
    popped: jax.Array  # i32: valid deliveries at this rank

    def tree_flatten(self):
        return (self.state, self.pushed, self.popped), self.spec

    @classmethod
    def tree_unflatten(cls, spec, leaves):
        return cls(spec, *leaves)

    def _limit(self):
        """Deliverable-element bound: pushes so far, capped by count."""
        if self.spec.count is None:
            return self.pushed
        return jnp.minimum(jnp.int32(self.spec.count), self.pushed)

    def _op(self):
        return self.spec.op if self.spec.op is not None else jnp.add

    # ------------------------------------------------------------- push

    def push(self, elem: jax.Array) -> "CollectiveChannel":
        """SMI_Push: stage one element into the channel.

        bcast: the root's element is the payload (other ranks' staged
        copies are ignored); reduce: every rank's element is its
        contribution; scatter: the root pushes a (P,)+elem_shape row (one
        element per destination); gather/allreduce: every rank pushes its
        element.  SPMD: every rank traces every push — masking selects the
        live role.

        Non-blocking with a credit window (the paper's §3.3 P-deep FIFO):
        when this rank's window is full — pushes have outrun consumption by
        the buffer depth — the element is *refused* (not staged, not
        counted in ``pushed``), the trace-level rendering of SMI_Push
        backpressure.  A refusal can never silently overwrite an element
        the schedule has not consumed yet.  ``pushed`` therefore counts
        *accepted* pushes at this rank; in the lockstep one-push-one-pop
        loops of the paper's listings the window never fills and the count
        stays uniform.
        """
        kind = self.spec.kind
        if obs.TRACING:
            obs.emit("channel.push", tag=self.spec.stats_tag,
                     port=self.spec.port, channel_kind=kind)
        if _capture.ACTIVE:
            _capture.record("push", self.spec)
        P = self.spec.comm.size
        if kind in ("bcast", "reduce"):
            # consumption pointer of this rank's FIFO: the root/injector
            # reads slots at `sent`; a reduce rank folds slots at `folded`
            # (exactly one of the two advances on any given rank)
            consumed = (self.state[1] if kind == "bcast"
                        else self.state[3] + self.state[4])
            ok = (self.pushed - consumed) < P
            buf = self.state[0]
            staged = jax.lax.dynamic_update_index_in_dim(
                buf, jnp.asarray(elem, buf.dtype), self.pushed % P, 0
            )
            state = (_mask_sel(ok, staged, buf),) + self.state[1:]
        else:  # scatter / gather / allreduce: 1-deep staging, one
            # element-sized schedule round (`state[1]`) consumes it
            ok = (self.pushed - self.state[1]) < 1
            staged = jnp.asarray(elem, self.state[0].dtype)
            state = (_mask_sel(ok, staged, self.state[0]),) + self.state[1:]
        return CollectiveChannel(
            self.spec, state, self.pushed + _i32(ok), self.popped
        )

    # ------------------------------------------------------------- pop

    def pop(self):
        """SMI_Pop: advance the collective pipeline one step and extract.

        Returns ``(chan', value, valid)``.  bcast: ``value`` is the next
        broadcast element (every rank, after its pipeline latency);
        reduce: the next reduced element (root only); scatter: this rank's
        element of the next pushed row; gather: the (P,)-row of pushed
        elements (root only); allreduce: the next reduced element (every
        rank).  ``valid`` gates warm-up, drain and pipeline bubbles.
        """
        if obs.TRACING:
            obs.emit("channel.pop", tag=self.spec.stats_tag,
                     port=self.spec.port, channel_kind=self.spec.kind)
        if _capture.ACTIVE:
            _capture.record("pop", self.spec)
        return getattr(self, f"_pop_{self.spec.kind}")()

    # bcast: pipelined chain, validity in-band ---------------------------

    def _pop_bcast(self):
        spec = self.spec
        comm, root = spec.comm, spec.root
        P, r = comm.size, comm.rank()
        buf, sent, up, up_v, down, down_v = self.state
        t = spec.step_transport()

        from ..core.collectives import _line_perms

        is_line = comm.topology.dims is None
        if is_line:
            up_pairs, down_pairs = _line_perms(comm, root)
        else:
            up_pairs, down_pairs = comm.ring_perm(+1), None

        at_root = r == root
        avail = sent < self._limit()
        inj_ok = jnp.logical_and(at_root, avail)
        inj = _take(buf, sent % P)

        # the root always overwrites its pipe registers (injection or
        # bubble) so stale elements can never recirculate around the wrap
        reg_u = _mask_sel(at_root, _mask_sel(inj_ok, inj, jnp.zeros_like(up)),
                          up)
        reg_uv = jnp.where(at_root, _f32flag(inj_ok), up_v)
        with _tagged(t, spec.stats_tag):
            moved_u, moved_uv = t.permute((reg_u, reg_uv), comm, up_pairs)
            if down_pairs is not None:
                reg_d = _mask_sel(
                    at_root, _mask_sel(inj_ok, inj, jnp.zeros_like(down)),
                    down,
                )
                reg_dv = jnp.where(at_root, _f32flag(inj_ok), down_v)
                moved_d, moved_dv = t.permute((reg_d, reg_dv), comm,
                                              down_pairs)
            else:
                moved_d, moved_dv = down, down_v

        if down_pairs is not None:
            arriving = _mask_sel(r > root, moved_u, moved_d)
            arr_v = jnp.where(r > root, moved_uv, moved_dv)
        else:
            arriving, arr_v = moved_u, moved_uv
        recv_ok = jnp.logical_and(arr_v > 0.5, jnp.logical_not(at_root))

        value = _mask_sel(at_root, inj, arriving)
        valid = jnp.where(at_root, inj_ok, recv_ok)
        new = CollectiveChannel(
            spec,
            (buf, sent + _i32(inj_ok), moved_u, moved_uv, moved_d, moved_dv),
            self.pushed,
            self.popped + _i32(valid),
        )
        return new, value, valid

    # reduce: pipelined chain toward root, contribution FIFO -------------

    def _pop_reduce(self):
        spec = self.spec
        comm, root = spec.comm, spec.root
        P, r = comm.size, comm.rank()
        buf, pipe, pipe_v, sent, folded = self.state
        t = spec.step_transport()
        op = self._op()

        dist = (r - root) % P
        farthest = dist == P - 1
        avail = sent < self._limit()
        inj_ok = jnp.logical_and(farthest, avail)

        # the farthest rank always overwrites its register (injection or
        # bubble), killing the wrap-around recirculation from the root
        reg = _mask_sel(
            farthest,
            _mask_sel(inj_ok, _take(buf, sent % P), jnp.zeros_like(pipe)),
            pipe,
        )
        reg_v = jnp.where(farthest, _f32flag(inj_ok), pipe_v)
        with _tagged(t, spec.stats_tag):
            moved, moved_v = t.permute((reg, reg_v), comm, comm.ring_perm(-1))

        arrived = moved_v > 0.5
        fold_ok = jnp.logical_and(arrived, jnp.logical_not(farthest))
        contrib = _take(buf, folded % P)
        # plain-add folds run on the transport's accumulate datapath (the
        # fused backend's Pallas kernel); the validity mask stays outside
        folded_val = t.accumulate(moved, contrib) if op is jnp.add \
            else op(moved, contrib)
        new_pipe = _mask_sel(fold_ok, folded_val, moved)

        valid = jnp.logical_and(r == root, arrived)
        new = CollectiveChannel(
            spec,
            (buf, new_pipe, moved_v, sent + _i32(inj_ok),
             folded + _i32(fold_ok)),
            self.pushed,
            self.popped + _i32(valid),
        )
        return new, new_pipe, valid

    # scatter / gather / allreduce: one schedule round per pop -----------

    def _round(self):
        """(transport, step, avail) shared by the round channels."""
        step = self.state[1]
        return self.spec.step_transport(), step, step < self._limit()

    def _pop_scatter(self):
        from ..core.collectives import _stream_scatter_impl

        spec = self.spec
        t, step, avail = self._round()
        staged = self.state[0]  # (P,)+elem_shape row, meaningful at root
        with _tagged(t, spec.stats_tag):
            y = _stream_scatter_impl(staged, spec.comm, root=spec.root,
                                     transport=t)
        new = CollectiveChannel(
            spec, (staged, step + 1), self.pushed, self.popped + _i32(avail)
        )
        return new, y[0], avail

    def _pop_gather(self):
        from ..core.collectives import _stream_gather_impl

        spec = self.spec
        t, step, avail = self._round()
        staged = self.state[0]
        with _tagged(t, spec.stats_tag):
            y = _stream_gather_impl(staged[None], spec.comm, root=spec.root,
                                    transport=t)
        valid = jnp.logical_and(spec.comm.rank() == spec.root, avail)
        new = CollectiveChannel(
            spec, (staged, step + 1), self.pushed, self.popped + _i32(valid)
        )
        return new, y, valid

    def _pop_allreduce(self):
        from ..core.collectives import _stream_allreduce_impl

        spec = self.spec
        t, step, avail = self._round()
        staged = self.state[0]
        with _tagged(t, spec.stats_tag):
            y = _stream_allreduce_impl(staged, spec.comm, transport=t)
        new = CollectiveChannel(
            spec, (staged, step + 1), self.pushed, self.popped + _i32(avail)
        )
        return new, y, avail

    # ---------------------------------------------------------- transfer

    def transfer(self, x: jax.Array, n_chunks: int | None = None, **kw):
        """Whole-message collective over this channel: lowers onto the
        corresponding ``stream_*`` schedule (or the autotuned dispatcher
        when the spec carries a plan), through the channel's transport
        backend and stats tag — bit-identical to the direct call on every
        backend.  Extra kwargs forward to the underlying schedule
        (``bidir=``, the reduce ``op`` defaults to the spec's)."""
        spec = self.spec
        if _capture.ACTIVE:
            _capture.record("transfer", spec, dtype=str(x.dtype))
        if obs.TRACING:
            obs.emit("channel.transfer.start", tag=spec.stats_tag,
                     port=spec.port, channel_kind=spec.kind,
                     nbytes=int(x.size) * x.dtype.itemsize)
        y = self._transfer_impl(x, n_chunks, **kw)
        if obs.TRACING:
            obs.emit("channel.transfer.finish", tag=spec.stats_tag,
                     port=spec.port, channel_kind=spec.kind)
        return y

    def _transfer_impl(self, x, n_chunks, **kw):
        from ..core import collectives as C

        spec = self.spec
        kind = spec.kind
        if spec.plan is not None and kind in ("bcast", "reduce", "allreduce"):
            # the autotuned dispatchers own the schedule shape and chunk
            # count, but the channel still owns the backend instance: the
            # spec's transport (explicit wins) or the plan's tuned key,
            # composed with the spec's wire, resolved *here* so the
            # transfer stays accounted under the channel's stats tag —
            # the same contract the non-plan path and p2p transfers keep
            p = C._resolve_plan(spec.plan, kind, spec.comm, x)
            if spec.transport is not None:
                t = spec.resolve()
            else:
                t = spec.replace(transport=p.transport_key).resolve()
            with _tagged(t, spec.stats_tag):
                if kind == "bcast":
                    return C.bcast(x, spec.comm, root=spec.root, plan=p,
                                   transport=t)
                if kind == "reduce":
                    kw.setdefault("op", self._op())
                    return C.reduce(x, spec.comm, root=spec.root, plan=p,
                                    transport=t, **kw)
                return C.allreduce(x, spec.comm, plan=p, transport=t, **kw)

        t = spec.resolve()
        nc = n_chunks if n_chunks is not None else spec.n_chunks
        with _tagged(t, spec.stats_tag):
            if kind == "bcast":
                return C._stream_bcast_impl(x, spec.comm, root=spec.root,
                                            n_chunks=nc, transport=t)
            if kind == "reduce":
                kw.setdefault("op", self._op())
                return C._stream_reduce_impl(x, spec.comm, root=spec.root,
                                             n_chunks=nc, transport=t, **kw)
            if kind == "scatter":
                return C._stream_scatter_impl(x, spec.comm, root=spec.root,
                                              transport=t)
            if kind == "gather":
                return C._stream_gather_impl(x, spec.comm, root=spec.root,
                                             transport=t)
            assert kind == "allreduce", kind
            return C._stream_allreduce_impl(x, spec.comm, transport=t, **kw)


# ---------------------------------------------------------------------------
# open_*_channel: the SMI_Open_*_channel family
# ---------------------------------------------------------------------------


def _open(kind: str, comm: Communicator, *, count, root, port, elem_shape,
          dtype, transport, wire, tag, plan, n_chunks, op, allocator):
    spec = _claim(
        ChannelSpec(
            comm=comm, kind=kind, count=count, root=root, port=port,
            transport=transport, wire=wire, tag=tag, plan=plan,
            n_chunks=n_chunks, op=op,
        ),
        allocator,
    )
    if obs.TRACING:
        obs.emit("channel.open", tag=spec.stats_tag, port=spec.port,
                 channel_kind=kind, root=root, count=count, wire=wire)
    if _capture.ACTIVE:
        _capture.record("open", spec, dtype=str(jnp.dtype(dtype)))
    P = comm.size
    z = jnp.zeros
    if kind == "bcast":
        state = (
            z((P,) + elem_shape, dtype),      # buf: element FIFO
            z((), jnp.int32),                 # sent
            z(elem_shape, dtype),             # pipe up
            z((), jnp.float32),               # pipe up valid
            z(elem_shape, dtype),             # pipe down (line topologies)
            z((), jnp.float32),               # pipe down valid
        )
    elif kind == "reduce":
        state = (
            z((P,) + elem_shape, dtype),      # buf: contribution FIFO
            z(elem_shape, dtype),             # pipe
            z((), jnp.float32),               # pipe valid
            z((), jnp.int32),                 # sent (farthest rank)
            z((), jnp.int32),                 # folded (per rank)
        )
    elif kind == "scatter":
        state = (z((P,) + elem_shape, dtype), z((), jnp.int32))
    else:  # gather / allreduce
        state = (z(elem_shape, dtype), z((), jnp.int32))
    return CollectiveChannel(
        spec=spec,
        state=tuple(_pvary(s, comm) for s in state),
        pushed=_pvary(z((), jnp.int32), comm),
        popped=_pvary(z((), jnp.int32), comm),
    )


def _open_doc(fn, what):
    fn.__doc__ = f"""SMI_Open_{fn.__name__[5:-8]}_channel: open a transient
    {what} channel on ``comm``.

    Opening claims ``port`` on the communicator's allocator (``None`` =
    anonymous) and zeroes the channel state; no communication happens
    until elements flow.  The spec carries the channel's whole comm
    config: ``transport`` (registry key / instance / None = the
    communicator's default), ``wire`` ("raw" | "int8" compressed links),
    ``tag`` (stats bucket), ``plan`` (netsim autotuning) and
    ``n_chunks``."""
    return fn


@lambda f: _open_doc(f, "broadcast")
def open_bcast_channel(comm, *, count=None, root=0, port=0, elem_shape=(),
                       dtype=jnp.float32, transport=None, wire="raw",
                       tag=None, plan=None, n_chunks=1, allocator=None):
    return _open("bcast", comm, count=count, root=root, port=port,
                 elem_shape=elem_shape, dtype=dtype, transport=transport,
                 wire=wire, tag=tag, plan=plan, n_chunks=n_chunks, op=None,
                 allocator=allocator)


@lambda f: _open_doc(f, "rooted-reduction")
def open_reduce_channel(comm, *, count=None, root=0, port=0, elem_shape=(),
                        dtype=jnp.float32, op=None, transport=None,
                        wire="raw", tag=None, plan=None, n_chunks=1,
                        allocator=None):
    return _open("reduce", comm, count=count, root=root, port=port,
                 elem_shape=elem_shape, dtype=dtype, transport=transport,
                 wire=wire, tag=tag, plan=plan, n_chunks=n_chunks, op=op,
                 allocator=allocator)


@lambda f: _open_doc(f, "scatter")
def open_scatter_channel(comm, *, count=None, root=0, port=0, elem_shape=(),
                         dtype=jnp.float32, transport=None, wire="raw",
                         tag=None, plan=None, n_chunks=1, allocator=None):
    return _open("scatter", comm, count=count, root=root, port=port,
                 elem_shape=elem_shape, dtype=dtype, transport=transport,
                 wire=wire, tag=tag, plan=plan, n_chunks=n_chunks, op=None,
                 allocator=allocator)


@lambda f: _open_doc(f, "gather")
def open_gather_channel(comm, *, count=None, root=0, port=0, elem_shape=(),
                        dtype=jnp.float32, transport=None, wire="raw",
                        tag=None, plan=None, n_chunks=1, allocator=None):
    return _open("gather", comm, count=count, root=root, port=port,
                 elem_shape=elem_shape, dtype=dtype, transport=transport,
                 wire=wire, tag=tag, plan=plan, n_chunks=n_chunks, op=None,
                 allocator=allocator)


@lambda f: _open_doc(f, "ring all-reduce")
def open_allreduce_channel(comm, *, count=None, port=0, elem_shape=(),
                           dtype=jnp.float32, transport=None, wire="raw",
                           tag=None, plan=None, n_chunks=1, allocator=None):
    return _open("allreduce", comm, count=count, root=0, port=port,
                 elem_shape=elem_shape, dtype=dtype, transport=transport,
                 wire=wire, tag=tag, plan=plan, n_chunks=n_chunks, op=None,
                 allocator=allocator)
