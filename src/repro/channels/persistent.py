"""Persistent channel pool: open-once, serve-forever SMI channels.

The transient channel lifecycle (open -> claim -> transfer -> close, once
per traced call) is the right rendering of the paper's listings, but the
wrong one for a decode loop that runs millions of steps: re-claiming a
port per step is per-message setup cost the paper's whole design exists
to avoid, and ACCL (PAPERS.md, arxiv 2403.18374) shows latency-sensitive
collectives live or die on pre-established, reusable contexts.

A :class:`ChannelPool` is that context.  It hands out one
``ChannelSpec(persistent=True)`` per layer tag — the port claim is held
*strongly* by the communicator's :class:`~repro.core.comm.PortAllocator`
(see ``claim(persistent=True)``), so it survives trace exits and garbage
collection of every compiled step that used it — and re-tags each layer
under a pool prefix (default ``"serve."``), so the ledger / netsim
taxonomy separates serving traffic from training traffic while the
tag/ledger machinery keeps tallying every step.  Transport *instances*
still resolve fresh per trace from the spec (persistence is the port
claim and the spec identity, not a live backend object), which keeps the
packet router's cross-trace reuse guard satisfied.

Lifecycle: the serving engine creates one pool, threads it through
``ParallelCtx(channels=pool)`` so every ``layer_spec`` call inside the
decode step resolves to the pool's persistent spec for its tag, and
releases every claim at engine shutdown via :meth:`ChannelPool.close`
(or a ``with`` scope).
"""

from __future__ import annotations

from ..analysis import capture as _capture
from ..core.comm import Communicator, PortAllocator
from ..obs import trace as obs
from .channel import PORTS, _claim
from .spec import ChannelSpec


class ChannelPool:
    """Per-tag registry of persistent channel specs on one communicator.

    Ports are assigned sequentially from ``base_port`` in first-request
    order — deterministic for a fixed model architecture, which is what
    makes the claim set reproducible across engine restarts.
    """

    def __init__(self, comm: Communicator, *, prefix: str = "serve.",
                 base_port: int = 100, transport=None, wire: str = "raw",
                 plan=None, allocator: PortAllocator | None = None):
        self.comm = comm
        self.prefix = prefix
        self.transport = transport
        self.wire = wire
        self.plan = plan
        self.allocator = allocator if allocator is not None else PORTS
        self._specs: dict[str, ChannelSpec] = {}
        self._next_port = base_port
        self.closed = False

    # -- tag namespace -------------------------------------------------------

    def retag(self, tag: str) -> str:
        """The pool's stats bucket for a layer tag (idempotent)."""
        return tag if tag.startswith(self.prefix) else self.prefix + tag

    # -- spec registry -------------------------------------------------------

    def spec(self, tag: str, *, kind: str = "allreduce", wire: str | None = None,
             plan=None, transport=None, n_chunks: int = 1,
             op=None, key: str | None = None) -> ChannelSpec:
        """The persistent spec for ``tag``: created (and its port claimed,
        strongly) on first request, returned verbatim afterwards — one
        claim per layer for the lifetime of the pool.  ``key`` overrides
        the registry key (default: the retagged tag) so two channels of
        different kinds can share one stats tag (the migration gather /
        scatter pair)."""
        assert not self.closed, "ChannelPool is closed"
        full = self.retag(tag)
        k = key if key is not None else full
        s = self._specs.get(k)
        if s is None:
            port = self._next_port
            self._next_port += 1
            s = ChannelSpec(
                comm=self.comm, kind=kind, tag=full, port=port,
                persistent=True,
                wire=wire if wire is not None else self.wire,
                plan=plan if plan is not None else self.plan,
                transport=(transport if transport is not None
                           else self.transport),
                n_chunks=n_chunks, op=op,
            )
            s = _claim(s, self.allocator)
            if obs.TRACING:
                obs.emit("channel.open", tag=s.stats_tag, port=s.port,
                         channel_kind=kind, wire=s.wire, persistent=True)
            if _capture.ACTIVE:
                _capture.record("pool.open", s)
            self._specs[k] = s
        return s

    def specs(self) -> dict[str, ChannelSpec]:
        """{retagged tag: persistent spec} opened so far."""
        return dict(self._specs)

    def ports(self) -> dict[str, int]:
        return {tag: s.port for tag, s in self._specs.items()}

    def claims(self) -> tuple[dict, ...]:
        """The pool's live claims as the allocator sees them: the
        :meth:`~repro.core.comm.PortAllocator.claims` rows whose owner is
        one of this pool's specs (port-ordered).  Empty after close."""
        own = {id(s) for s in self._specs.values()}
        return tuple(r for r in self.allocator.claims(self.comm)
                     if id(r["owner"]) in own)

    def __len__(self) -> int:
        return len(self._specs)

    def __contains__(self, tag: str) -> bool:
        return self.retag(tag) in self._specs

    # -- lifecycle -----------------------------------------------------------

    def close(self) -> None:
        """Release every persistent claim (idempotent — a second close is
        a no-op, it can never release a later claimant's ports).  This is
        the ONLY way a persistent port comes back — trace exits never
        lapse it."""
        if self.closed:
            return
        self.closed = True
        for s in self._specs.values():
            if obs.TRACING:
                obs.emit("channel.close", tag=s.stats_tag, port=s.port,
                         channel_kind=s.kind, persistent=True)
            if _capture.ACTIVE:
                _capture.record("pool.close", s)
            s.release_port()
        self._specs.clear()

    def __enter__(self) -> "ChannelPool":
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def __del__(self):
        # a pool garbage-collected with live claims is a leak: nothing can
        # ever release its persistent ports again.  Report it (the ft.*
        # fault-tolerance event family) and recover the ports instead of
        # dying silently — __del__ swallows everything else.
        try:
            if getattr(self, "closed", True) or not self._specs:
                return
            if obs.TRACING:
                obs.emit("ft.leak", tag=self.prefix,
                         ports=sorted(s.port for s in self._specs.values()),
                         n_claims=len(self._specs))
            self.close()
        except Exception:
            pass  # interpreter teardown: modules may already be gone
