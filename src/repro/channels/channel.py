"""Point-to-point transient channels (paper Listing 1, §2.2–§2.3).

A :class:`Channel` is traced state — a 1-deep pipe register per rank on
the route plus progress counters — described by a static
:class:`~repro.channels.spec.ChannelSpec`.  Element-level :meth:`push` /
:meth:`pop` advance the pipeline one hop-step per pop, so arrival latency
equals the routed hop count (paper Tab. 3) and a consumer loop gates its
tail on the returned ``valid`` bit (pipeline bubbles).  Whole-message
:meth:`transfer` hands the payload to the chunk-pipelined transport engine.

Both paths move bytes through the channel's *transport backend* — the
spec's key/instance, or the communicator's default — so packet-routed and
int8-compressed p2p channels exist: a pop over ``transport="packet"`` runs
the dynamic router for every hop-step, and every step is accounted under
the channel's stats tag (``netsim.predict_channel_stats`` matches the
tagged counters to the byte).

Opening claims the spec's port through the communicator's
:class:`~repro.core.comm.PortAllocator` (``port=None`` = anonymous, no
claim); closing — explicitly or by ``with`` scope — releases it.
"""

from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..analysis import capture as _capture
from ..core.comm import Communicator, PortAllocator
from ..obs import trace as obs
from .spec import ChannelSpec

#: the package-level default allocator open_* claims ports from
PORTS = PortAllocator()


@contextmanager
def _tagged(t, tag: str | None):
    """Account the block under ``tag`` (no-op for untagged channels)."""
    if tag is None:
        yield t
    else:
        with t.tagged(tag):
            yield t


def _claim(spec: ChannelSpec, allocator) -> ChannelSpec:
    """Claim the spec's port (owner = the spec, so the claim lapses when
    the opening trace is garbage-collected — unless the spec is
    ``persistent``, in which case the allocator holds the spec strongly and
    the claim survives until explicit close) and remember the allocator."""
    alloc = allocator if allocator is not None else PORTS
    spec = spec.replace(allocator=alloc)
    if spec.port is None:
        # no claim to hold, but the allocator notes the channel so
        # PortAllocator.claims() can report anonymous channels at all
        alloc.note_anonymous(spec.comm, spec)
        return spec
    alloc.claim(spec.comm, spec.port, owner=spec, persistent=spec.persistent)
    return spec


def _mask_sel(pred, a, b):
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def _pvary(x, comm):
    from ..core.streaming import _pvary as f

    return f(x, comm)


class _ChannelBase:
    """close / context-manager plumbing shared by every channel kind."""

    def close(self):
        """Release the channel's port claim (idempotent)."""
        if obs.TRACING:
            obs.emit("channel.close", tag=self.spec.stats_tag,
                     port=self.spec.port, channel_kind=self.spec.kind)
        if _capture.ACTIVE:
            _capture.record("close", self.spec)
        self.spec.release_port()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def _resolve_transfer(self, x, n_chunks, op: str):
        """(transport, n_chunks) for one whole-message transfer, honouring
        the spec's plan exactly as the legacy per-call kwargs did: "auto"
        consults the tuning table; a tuned int8 wire falls back to raw for
        integer payloads; an explicit spec transport always wins over the
        plan's backend."""
        spec = self.spec
        nc = n_chunks if n_chunks is not None else spec.n_chunks
        plan = spec.plan
        if plan is None:
            return spec.resolve(), nc
        import dataclasses

        from ..netsim.tune import Plan

        if not isinstance(plan, Plan):
            assert plan == "auto", (
                f"plan must be 'auto', None or a Plan; got {plan!r}"
            )
            nbytes = x.size * x.dtype.itemsize
            plan = spec.comm.plan(op, int(nbytes))
        if plan.wire != "raw" and not jnp.issubdtype(x.dtype, jnp.floating):
            # integer payloads must move exactly: same plan, raw wire
            plan = dataclasses.replace(plan, wire="raw")
        if spec.transport is None and spec.wire == "raw":
            t = spec.replace(transport=plan.transport_key).resolve()
        else:
            t = spec.resolve()
        return t, plan.clamp_chunks(x.shape[0])


@jax.tree_util.register_pytree_node_class
@dataclass
class Channel(_ChannelBase):
    """Traced p2p channel state: a 1-deep pipe register per rank on the
    route.  ``pushed``/``popped`` count progress; ``pipe`` holds the
    in-flight element at this rank; ``valid`` (f32 0/1 so it rides every
    wire format, including the int8 compressed link, exactly) tags
    pipeline bubbles.  The spec (static) rides in the pytree aux data, so
    channels can be loop carries."""

    spec: ChannelSpec
    pipe: jax.Array
    valid: jax.Array  # f32 scalar 0/1: pipe holds a live element
    pushed: jax.Array  # i32 scalar
    popped: jax.Array  # i32 scalar

    def tree_flatten(self):
        return (self.pipe, self.valid, self.pushed, self.popped), self.spec

    @classmethod
    def tree_unflatten(cls, spec, leaves):
        return cls(spec, *leaves)

    # -- element level -------------------------------------------------------

    def push(self, elem: jax.Array) -> "Channel":
        """SMI_Push: stage ``elem`` into the pipe at the source rank.

        Non-blocking in trace terms; the element starts moving on the next
        :meth:`pop` (the schedule's pipeline advance).  Pipelines to one
        advance per loop iteration — the ii=1 requirement of §3.1.1.
        """
        if obs.TRACING:
            obs.emit("channel.push", tag=self.spec.stats_tag,
                     port=self.spec.port, src=self.spec.src)
        if _capture.ACTIVE:
            _capture.record("push", self.spec)
        r = self.spec.comm.rank()
        at_src = r == self.spec.src
        new_pipe = _mask_sel(
            at_src, jnp.asarray(elem, self.pipe.dtype), self.pipe
        )
        new_valid = jnp.where(at_src, 1.0, self.valid).astype(self.valid.dtype)
        return Channel(
            self.spec,
            new_pipe,
            new_valid,
            self.pushed + jnp.where(at_src, 1, 0).astype(jnp.int32),
            self.popped,
        )

    def pop(self):
        """SMI_Pop: advance the channel pipeline one hop-step and extract.

        Returns ``(chan', value, valid)``: after ``hops`` advances the
        element pushed first arrives, so a consumer loop runs
        ``count + hops - 1`` iterations and gates on ``valid`` — exactly a
        hardware pipeline with latency = network distance (paper Tab. 3).
        The hop-step moves through the channel's transport backend and is
        accounted under its stats tag.  A bounded channel (``count`` not
        None) delivers at most ``count`` valid elements — extra pops gate
        invalid, the documented min(count, pushed) validity cap.
        """
        spec = self.spec
        if obs.TRACING:
            obs.emit("channel.pop", tag=spec.stats_tag, port=spec.port,
                     dst=spec.dst, hops=spec.hops)
        if _capture.ACTIVE:
            _capture.record("pop", spec)
        r = spec.comm.rank()
        pairs = spec.comm.path_perm(spec.path)
        t = spec.step_transport()
        with _tagged(t, spec.stats_tag):
            moved, moved_valid = t.permute(
                (self.pipe, self.valid), spec.comm, pairs
            )
        at_dst = r == spec.dst
        value = moved
        valid = jnp.logical_and(at_dst, moved_valid > 0.5)
        if spec.count is not None:
            valid = jnp.logical_and(
                valid, self.popped < jnp.int32(spec.count)
            )
        new = Channel(
            spec,
            moved,
            moved_valid,
            self.pushed,
            self.popped + jnp.where(valid, 1, 0).astype(jnp.int32),
        )
        return new, value, valid

    # -- transfer level ------------------------------------------------------

    def transfer(self, x: jax.Array, n_chunks: int | None = None) -> jax.Array:
        """Whole-message streamed transfer over this channel: ``x``@src
        delivered to dst along the routed path through the channel's
        transport backend (``n_chunks`` chunks in flight; the spec's plan
        may pick backend and chunk count).  Equivalent to count/chunk
        pushes + pops, dispatched to the pipelined transfer engine."""
        spec = self.spec
        t, nc = self._resolve_transfer(x, n_chunks, "p2p")
        if _capture.ACTIVE:
            _capture.record("transfer", spec, dtype=str(x.dtype))
        if obs.TRACING:
            obs.emit("channel.transfer.start", tag=spec.stats_tag,
                     port=spec.port, src=spec.src, dst=spec.dst,
                     nbytes=int(x.size) * x.dtype.itemsize,
                     n_chunks=int(nc), transport=t.name)
        with _tagged(t, spec.stats_tag):
            y = t.p2p(x, src=spec.src, dst=spec.dst, comm=spec.comm,
                      n_chunks=nc)
        if obs.TRACING:
            obs.emit("channel.transfer.finish", tag=spec.stats_tag,
                     port=spec.port, src=spec.src, dst=spec.dst)
        return y


def open_channel(
    comm: Communicator,
    *,
    count: int | None = None,
    src: int = 0,
    dst: int = 0,
    port: int | None = 0,
    elem_shape=(),
    dtype=jnp.float32,
    transport=None,
    wire: str = "raw",
    tag: str | None = None,
    plan=None,
    n_chunks: int = 1,
    allocator: PortAllocator | None = None,
) -> Channel:
    """SMI_Open_send_channel / SMI_Open_recv_channel.

    Opening claims ``port`` on the communicator's allocator (two open
    channels cannot share a port — the software analogue of two kernels
    contending for one hardware FIFO; ``port=None`` skips the claim) and
    creates the descriptor plus a zeroed pipe register; no communication
    happens until elements flow (paper §3.3 eager protocol).  The spec
    carries the channel's whole comm config — transport backend, wire
    format, stats tag, tuning plan — replacing the legacy per-call kwargs.
    """
    spec = _claim(
        ChannelSpec(
            comm=comm, kind="p2p", count=count, src=src, dst=dst, port=port,
            transport=transport, wire=wire, tag=tag, plan=plan,
            n_chunks=n_chunks,
        ),
        allocator,
    )
    if obs.TRACING:
        obs.emit("channel.open", tag=spec.stats_tag, port=spec.port,
                 channel_kind="p2p", src=src, dst=dst, count=count,
                 wire=wire)
    if _capture.ACTIVE:
        _capture.record("open", spec, dtype=str(jnp.dtype(dtype)))
    return Channel(
        spec=spec,
        pipe=_pvary(jnp.zeros(elem_shape, dtype), comm),
        valid=_pvary(jnp.zeros((), jnp.float32), comm),
        pushed=_pvary(jnp.zeros((), jnp.int32), comm),
        popped=_pvary(jnp.zeros((), jnp.int32), comm),
    )


# -- module-level functional forms (the paper's C-style API; re-exported
# through repro.core for existing call sites) --------------------------------


def push(chan: Channel, elem: jax.Array) -> Channel:
    """SMI_Push (functional form): see :meth:`Channel.push`."""
    return chan.push(elem)


def pop(chan: Channel):
    """SMI_Pop (functional form): see :meth:`Channel.pop`."""
    return chan.pop()


def channel_transfer(chan, x: jax.Array, n_chunks: int | None = None):
    """Whole-message convenience (functional form): see
    :meth:`Channel.transfer`.  Dispatches through the channel's own
    transport backend and stats tag — a channel opened over a packet or
    compressed backend streams over exactly that wire."""
    return chan.transfer(x, n_chunks=n_chunks)
