"""Model zoo: the assigned architectures as composable JAX modules."""

from .model import (
    init_lm,
    lm_specs,
    lm_loss,
    lm_prefill,
    lm_decode_step,
    lm_caches,
    lm_cache_specs,
)

__all__ = [
    "init_lm",
    "lm_specs",
    "lm_loss",
    "lm_prefill",
    "lm_decode_step",
    "lm_caches",
    "lm_cache_specs",
]
