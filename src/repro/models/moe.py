"""Mixture-of-Experts with expert parallelism over the model axis.

Formulation (per device): experts are sharded over the model axis
(E_loc = E/tp per device); the MoE operates on the *replicated* token view
(decode) or the sequence-gathered view (train/prefill, where the residual
stream is sequence-sharded and tokens transit through the same allgather the
attention path uses).  Each device:

  1. routes every token it sees (router weights replicated — tiny),
  2. sort-based capacity dispatch of the tokens choosing *its* experts into
     an (E_loc, C, D) buffer (no (T, E, C) one-hot monster),
  3. local expert GEMMs,
  4. scatter back + weighted combine, then a single reduce over the model
     axis (SMI streamed ring or lax.psum) merges per-expert-group partials —
     the EP "combine" collective, reduce-scattered back into sequence shards.

Capacity follows the paper's buffer-size philosophy: an optimisation
parameter that cannot affect correctness of the *protocol* (overflowing
tokens are dropped, the standard MoE trade-off; aux load-balance loss keeps
the router from overflowing).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..mesh.api import ParallelCtx
from ..parallel import moe_combine, moe_dispatch
from .common import silu, trunc_normal


def _e_loc(E: int, tp: int) -> int:
    assert E % tp == 0 or tp == 1, f"{E} experts not divisible by tp={tp}"
    return E // tp if tp > 1 else E


def init_moe(key, cfg, ctx: ParallelCtx):
    """GLOBAL-shape MoE params (experts sharded over model by the specs)."""
    D = cfg.d_model
    E = cfg.n_experts
    ffe = cfg.d_ff_expert
    assert E % ctx.tp == 0 or ctx.tp == 1
    ks = jax.random.split(key, 5)
    p = {
        "router": trunc_normal(ks[0], (D, E), D ** -0.5),
        "w_gate": trunc_normal(ks[1], (E, D, ffe), D ** -0.5),
        "w_up": trunc_normal(ks[2], (E, D, ffe), D ** -0.5),
        "w_down": trunc_normal(ks[3], (E, ffe, D), ffe ** -0.5),
    }
    if cfg.shared_expert:
        from .mlp import init_mlp

        p["shared"] = init_mlp(ks[4], cfg, ctx, d_ff=cfg.d_ff)
    return p


def moe_specs(cfg, ctx: ParallelCtx):
    from jax.sharding import PartitionSpec as P

    m = ctx.model_axis
    sp = {
        "router": P(None, None),
        "w_gate": P(m, None, None),
        "w_up": P(m, None, None),
        "w_down": P(m, None, None),
    }
    if cfg.shared_expert:
        from .mlp import mlp_specs

        sp["shared"] = mlp_specs(cfg, ctx)
    return sp


def _dispatch_compute(p, xf, cfg, ctx: ParallelCtx):
    """xf: (T, D) full token view on this device.  Returns this device's
    expert-group partial output (T, D) and the aux loss ingredients."""
    T, D = xf.shape
    E = cfg.n_experts
    k = cfg.top_k
    tp = ctx.tp
    E_loc = _e_loc(E, tp)
    r = ctx.rank() if tp > 1 else 0

    logits = (xf @ p["router"]).astype(jnp.float32)        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = lax.top_k(probs, k)              # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )

    # aux load-balance loss (Switch-style): E * sum_e f_e * p_e
    me = probs.mean(axis=0)                                # (E,)
    ce = jnp.zeros((E,)).at[gate_idx.reshape(-1)].add(
        jnp.ones((T * k,)) / (T * k)
    )
    aux = E * jnp.sum(me * ce)

    # flatten assignments, keep only my expert group
    e_flat = gate_idx.reshape(-1)                          # (T*k,)
    w_flat = gate_vals.reshape(-1)
    t_flat = jnp.arange(T * k) // k
    local_e = e_flat - r * E_loc
    mine = jnp.logical_and(local_e >= 0, local_e < E_loc)

    C = int(max(8, round(cfg.capacity_factor * T * k / E)))
    # rank within expert queue via sort by (expert, arrival)
    sort_key = jnp.where(mine, local_e, E_loc).astype(jnp.int32)
    order = jnp.argsort(sort_key, stable=True)
    e_sorted = sort_key[order]
    # position within each expert's run
    idx = jnp.arange(T * k)
    starts = jnp.searchsorted(e_sorted, jnp.arange(E_loc), side="left")
    pos = idx - starts[jnp.clip(e_sorted, 0, E_loc - 1)]
    keep = jnp.logical_and(e_sorted < E_loc, pos < C)

    slot = jnp.where(keep, e_sorted * C + pos, E_loc * C)  # overflow -> dump row
    buf = jnp.zeros((E_loc * C + 1, D), xf.dtype)
    buf = buf.at[slot].add(jnp.where(keep[:, None], xf[t_flat[order]], 0))
    ein = buf[:-1].reshape(E_loc, C, D)

    h = jnp.einsum("ecd,edf->ecf", ein, p["w_gate"])
    h = silu(h) * jnp.einsum("ecd,edf->ecf", ein, p["w_up"])
    eout = jnp.einsum("ecf,efd->ecd", h, p["w_down"]).reshape(E_loc * C, D)
    eout = jnp.concatenate([eout, jnp.zeros((1, D), eout.dtype)], axis=0)

    tok_out = eout[slot] * jnp.where(keep, w_flat[order], 0.0)[:, None]
    y = jnp.zeros((T, D), xf.dtype).at[t_flat[order]].add(tok_out.astype(xf.dtype))
    return y, aux


def apply_moe(p, x, cfg, ctx: ParallelCtx):
    """Train/prefill.  x: (B, S_loc, D) sequence-sharded -> same (+aux)."""
    B, S_loc, D = x.shape
    tp = ctx.tp
    x2d = x.reshape(B * S_loc, D)
    xf = moe_dispatch(x2d, ctx) if tp > 1 else x2d         # (T, D)
    y_part, aux = _dispatch_compute(p, xf, cfg, ctx)
    # merge expert-group partials AND return to sequence shards in one RS
    y = moe_combine(y_part, ctx) if tp > 1 else y_part
    y = y.reshape(B, S_loc, D)
    if cfg.shared_expert:
        from .mlp import apply_mlp

        y = y + apply_mlp(p["shared"], x, cfg, ctx)
    return y, aux


def apply_moe_replicated(p, x, cfg, ctx: ParallelCtx):
    """Decode: x (B, 1, D) replicated -> same (+aux)."""
    from ..parallel import all_reduce

    B, _, D = x.shape
    y_part, aux = _dispatch_compute(p, x.reshape(B, D), cfg, ctx)
    y = all_reduce(y_part, ctx, tag="ep.combine").reshape(B, 1, D)
    if cfg.shared_expert:
        from .mlp import apply_mlp_replicated

        y = y + apply_mlp_replicated(p["shared"], x, cfg, ctx)
    return y, aux
