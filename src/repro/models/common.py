"""Shared model pieces: norms, RoPE, init, embeddings, vocab-parallel CE."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..mesh.api import ParallelCtx, psum_model, psum_max_model


def trunc_normal(key, shape, scale, dtype=jnp.float32):
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def rms_norm(x, weight, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(x.dtype)


def rope(x, pos, theta: float = 10_000.0):
    """Rotate-half RoPE.  x: (..., S, H, D); pos: (S,) absolute positions."""
    D = x.shape[-1]
    half = D // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos.astype(jnp.float32)[:, None] * freqs[None, :]      # (S, half)
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x)


# -------------------------------------------------------- vocab-parallel IO


def embed_lookup(table_local, ids, ctx: ParallelCtx):
    """Vocab-parallel embedding: table (V_local, D), ids any int shape.

    Every device holds vocab rows [r*V_local, (r+1)*V_local); out-of-shard
    ids hit zero and the psum over the model axis assembles the embedding."""
    V_local, D = table_local.shape
    r = ctx.rank()
    local = ids - r * V_local
    ok = jnp.logical_and(local >= 0, local < V_local)
    emb = jnp.take(table_local, jnp.clip(local, 0, V_local - 1), axis=0)
    emb = jnp.where(ok[..., None], emb, 0)
    return psum_model(emb, ctx)


def vocab_parallel_ce(logits_local, labels, ctx: ParallelCtx):
    """Cross entropy with vocab-sharded logits (B, S, V_local), labels (B, S).

    max / sum-exp / label-pick each psum once over the model axis — the
    standard Megatron scheme, with SMI/bulk selection at the psum level."""
    V_local = logits_local.shape[-1]
    r = ctx.rank()
    lf = logits_local.astype(jnp.float32)
    # the max shift is gradient-neutral (d(logZ+m)/dm = 0); pmax has no JVP,
    # so stop the gradient at its *input* (symbolic-zero tangents skip it)
    m = psum_max_model(lax.stop_gradient(lf.max(axis=-1)), ctx)  # (B, S)
    z = psum_model(jnp.exp(lf - m[..., None]).sum(axis=-1), ctx)  # (B, S)
    local = labels - r * V_local
    ok = jnp.logical_and(local >= 0, local < V_local)
    picked = jnp.take_along_axis(
        lf, jnp.clip(local, 0, V_local - 1)[..., None], axis=-1
    )[..., 0]
    picked = psum_model(jnp.where(ok, picked, 0.0), ctx)
    ce = jnp.log(z) + m - picked
    return ce  # (B, S)


def lm_head(x, table_local, ctx: ParallelCtx):
    """Tied LM head: x (B, S, D) @ table (V_local, D)^T -> vocab-sharded
    logits.  Column-parallel (no comm; the loss handles the reduction)."""
    return jnp.einsum("bsd,vd->bsv", x, table_local).astype(jnp.float32)
