"""Shared model pieces: norms, RoPE, init, embeddings, vocab-parallel CE."""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..mesh.api import ParallelCtx
from ..parallel import parallel_embedding, vocab_parallel_cross_entropy


def trunc_normal(key, shape, scale, dtype=jnp.float32):
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


def rms_norm(x, weight, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps) * weight.astype(jnp.float32)
    return out.astype(x.dtype)


def rope(x, pos, theta: float = 10_000.0):
    """Rotate-half RoPE.  x: (..., S, H, D); pos: (S,) absolute positions."""
    D = x.shape[-1]
    half = D // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos.astype(jnp.float32)[:, None] * freqs[None, :]      # (S, half)
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def rope_batched(x, pos, theta: float = 10_000.0):
    """Rotate-half RoPE for single-token decode with a *per-row* position.
    x: (B, 1, H, D); pos: (B,).  Bit-identical to :func:`rope` when every
    row sits at the same position (the wave-decoding case)."""
    D = x.shape[-1]
    half = D // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos.astype(jnp.float32)[:, None] * freqs[None, :]      # (B, half)
    cos = jnp.cos(ang)[:, None, None, :]
    sin = jnp.sin(ang)[:, None, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def silu(x):
    return x * jax.nn.sigmoid(x)


# -------------------------------------------------------- vocab-parallel IO


def embed_lookup(table_local, ids, ctx: ParallelCtx):
    """Vocab-parallel embedding: table (V_local, D), ids any int shape —
    the ``"tp.embed"`` channel (repro/parallel) assembles the shards."""
    return parallel_embedding(table_local, ids, ctx)


def vocab_parallel_ce(logits_local, labels, ctx: ParallelCtx):
    """Cross entropy with vocab-sharded logits (B, S, V_local), labels
    (B, S) — the Megatron scheme over the ``"tp.loss.ce"`` channel
    (repro/parallel)."""
    return vocab_parallel_cross_entropy(logits_local, labels, ctx)


def lm_head(x, table_local, ctx: ParallelCtx):
    """Tied LM head: x (B, S, D) @ table (V_local, D)^T -> vocab-sharded
    logits.  Column-parallel (no comm; the loss handles the reduction)."""
    return jnp.einsum("bsd,vd->bsv", x, table_local).astype(jnp.float32)
