"""RG-LRU recurrent block (RecurrentGemma / Griffin) under TP.

Block: x -> [linear -> causal conv -> RG-LRU] ⊙ gelu(linear) -> out proj.
The RG-LRU gates are per-channel (diagonal) — the Griffin paper's
block-diagonal gate weights are simplified to diagonal here; recorded in
DESIGN.md §Arch-applicability.  The linear recurrence
``h_t = a_t h_{t-1} + sqrt(1 - a_t^2) (i_t ⊙ x_t)`` runs as an associative
scan over the gathered sequence; channels (lru_width) are column-sharded.
Decode carries (conv window, h) — O(1) state, so the hybrid runs long_500k.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..mesh.api import ParallelCtx
from ..parallel import all_reduce, column_parallel_linear, row_parallel_linear
from .common import silu, trunc_normal
from .ssm import _causal_conv

_C_GATE = 8.0  # Griffin's fixed gate sharpness


def _w_loc(cfg, tp: int) -> int:
    w = cfg.lru_width or cfg.d_model
    assert w % tp == 0 or tp == 1
    return w // tp if tp > 1 else w


def init_rglru(key, cfg, ctx: ParallelCtx):
    """GLOBAL-shape RG-LRU params (lru_width sharded by the specs)."""
    D = cfg.d_model
    W = cfg.lru_width or cfg.d_model
    assert W % ctx.tp == 0 or ctx.tp == 1
    K = cfg.ssm_conv
    ks = jax.random.split(key, 6)
    s = D ** -0.5
    return {
        "w_branch": trunc_normal(ks[0], (D, W), s),
        "w_gate": trunc_normal(ks[1], (D, W), s),
        "conv": trunc_normal(ks[2], (K, W), K ** -0.5),
        "lam": jnp.full((W,), 1.0),          # Λ: a = sigmoid ∘ softplus decay
        "wa": jnp.zeros((W,)),               # recurrence-gate diag weight
        "ba": jnp.zeros((W,)),
        "wi": jnp.zeros((W,)),               # input-gate diag weight
        "bi": jnp.zeros((W,)),
        "w_out": trunc_normal(ks[3], (W, D), W ** -0.5),
    }


def rglru_specs(cfg, ctx: ParallelCtx):
    from jax.sharding import PartitionSpec as P

    m = ctx.model_axis
    return {
        "w_branch": P(None, m), "w_gate": P(None, m), "conv": P(None, m),
        "lam": P(m), "wa": P(m), "ba": P(m), "wi": P(m), "bi": P(m),
        "w_out": P(m, None),
    }


def _gates(p, u):
    """u: (..., W_loc) conv output.  Returns (a, b) of h = a h_prev + b."""
    r = jax.nn.sigmoid(p["wa"] * u + p["ba"])
    i = jax.nn.sigmoid(p["wi"] * u + p["bi"])
    log_a = -_C_GATE * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (i * u)
    return a, b


def apply_rglru(p, x, cfg, ctx: ParallelCtx):
    """Train/prefill.  x: (B, S_loc, D) sequence-sharded -> same."""
    B, S_loc, D = x.shape
    tp = ctx.tp
    S = S_loc * tp
    W_loc = _w_loc(cfg, tp)

    x2d = x.reshape(B * S_loc, D)
    if ctx.opt_shared_gather:
        br, xf = column_parallel_linear(
            x2d, p["w_branch"], ctx, tag="ssm.in", return_gathered=True
        )
        gt = xf @ p["w_gate"]           # ring-free
    else:
        br = column_parallel_linear(x2d, p["w_branch"], ctx, tag="ssm.in")
        gt = column_parallel_linear(x2d, p["w_gate"], ctx, tag="ssm.in")

    def to_bsc(t):
        return t.reshape(tp, B, S_loc, W_loc).transpose(1, 0, 2, 3).reshape(B, S, W_loc)

    br = to_bsc(br)
    gt = to_bsc(gt)
    u = _causal_conv(br, p["conv"])
    a, b = _gates(p, u.astype(jnp.float32))

    # associative linear recurrence over the sequence
    def combine(l, r):
        al, bl = l
        ar, br_ = r
        return al * ar, ar * bl + br_

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    y = (h.astype(x.dtype)) * jax.nn.gelu(gt)
    y2d = (
        y.reshape(B, tp, S_loc, W_loc).transpose(1, 0, 2, 3).reshape(tp * B * S_loc, W_loc)
    )
    out = row_parallel_linear(y2d, p["w_out"], ctx, tag="ssm.out")
    return out.reshape(B, S_loc, D)


def init_rglru_cache(cfg, B: int, ctx: ParallelCtx, dtype):
    W_loc = _w_loc(cfg, ctx.tp)
    K = cfg.ssm_conv
    return {
        "conv": jnp.zeros((B, K - 1, W_loc), dtype),
        "h": jnp.zeros((B, W_loc), jnp.float32),
    }


def rglru_cache_specs(ctx: ParallelCtx, shard_batch: bool = True):
    from jax.sharding import PartitionSpec as P

    m = ctx.model_axis
    b = None
    if shard_batch and ctx.batch_axes:
        b = ctx.batch_axes if len(ctx.batch_axes) > 1 else ctx.batch_axes[0]
    return {"conv": P(b, None, m), "h": P(b, m)}


def decode_rglru(p, x, cache, cfg, ctx: ParallelCtx):
    """x: (B, 1, D) replicated -> (y, cache')."""
    B = x.shape[0]
    x2d = x.reshape(B, -1)
    br = x2d @ p["w_branch"]
    gt = x2d @ p["w_gate"]
    cx = jnp.concatenate([cache["conv"], br[:, None]], axis=1)
    u = jnp.einsum("bkc,kc->bc", cx, p["conv"])
    a, b = _gates(p, u.astype(jnp.float32))
    h = a * cache["h"] + b
    y = h.astype(x.dtype) * jax.nn.gelu(gt)
    out = all_reduce(y @ p["w_out"], ctx, tag="ssm.out")
    return out.reshape(B, 1, -1), {"conv": cx[:, 1:], "h": h}
