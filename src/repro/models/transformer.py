"""Block assembly: dense / MoE / SSM / hybrid patterns, scan-over-layers.

Layers are grouped by the config's ``pattern`` period (e.g. RecurrentGemma's
(rec, rec, attn)); parameters for each period position are stacked and the
stack runs under ``lax.scan`` (small HLO, fast compiles at 64 layers) with a
``jax.checkpoint`` remat policy around the period body.  Remainder layers
(n_layers % period) are unrolled.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from ..mesh.api import ParallelCtx
from .attention import (
    apply_attention,
    attention_specs,
    decode_attention,
    init_attention,
    init_kv_cache,
    kv_cache_specs,
)
from .common import rms_norm
from .mlp import apply_mlp, apply_mlp_replicated, init_mlp, mlp_specs
from .moe import apply_moe, apply_moe_replicated, init_moe, moe_specs
from .rglru import (
    apply_rglru,
    decode_rglru,
    init_rglru,
    init_rglru_cache,
    rglru_cache_specs,
    rglru_specs,
)
from .ssm import (
    apply_ssm,
    decode_ssm,
    init_ssm,
    init_ssm_cache,
    ssm_cache_specs,
    ssm_specs,
)

REMAT_POLICIES = {
    "none": None,
    "dots": lambda: jax.checkpoint_policies.checkpoint_dots,
    # dots without batch dims: saves projection outputs but NOT attention
    # score blocks (those carry batch dims) — the memory/compute middle ground
    "dots_nb": lambda: jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
    "nothing": lambda: jax.checkpoint_policies.nothing_saveable,
}


def init_block(key, kind: str, cfg, ctx: ParallelCtx):
    D = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {"norm1": jnp.ones((D,))}
    if kind in ("attn", "moe"):
        p["attn"] = init_attention(ks[0], cfg, ctx)
        p["norm2"] = jnp.ones((D,))
        if kind == "attn":
            p["mlp"] = init_mlp(ks[1], cfg, ctx)
        else:
            p["moe"] = init_moe(ks[1], cfg, ctx)
    elif kind == "ssm":
        p["ssm"] = init_ssm(ks[0], cfg, ctx)
    elif kind == "rec":
        p["rec"] = init_rglru(ks[0], cfg, ctx)
        p["norm2"] = jnp.ones((D,))
        p["mlp"] = init_mlp(ks[1], cfg, ctx)
    else:
        raise ValueError(kind)
    return p


def block_specs(kind: str, cfg, ctx: ParallelCtx):
    from jax.sharding import PartitionSpec as P

    sp = {"norm1": P(None)}
    if kind in ("attn", "moe"):
        sp["attn"] = attention_specs(cfg, ctx)
        sp["norm2"] = P(None)
        if kind == "attn":
            sp["mlp"] = mlp_specs(cfg, ctx)
        else:
            sp["moe"] = moe_specs(cfg, ctx)
    elif kind == "ssm":
        sp["ssm"] = ssm_specs(cfg, ctx)
    elif kind == "rec":
        sp["rec"] = rglru_specs(cfg, ctx)
        sp["norm2"] = P(None)
        sp["mlp"] = mlp_specs(cfg, ctx)
    return sp


def apply_block(p, kind: str, x, cfg, ctx: ParallelCtx, *, interp=False):
    aux = jnp.zeros((), jnp.float32)
    if kind in ("attn", "moe"):
        x = x + apply_attention(
            p["attn"], rms_norm(x, p["norm1"], cfg.norm_eps), cfg, ctx,
            use_kernel_interpret=interp,
        )
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if kind == "attn":
            x = x + apply_mlp(p["mlp"], h, cfg, ctx)
        else:
            y, aux = apply_moe(p["moe"], h, cfg, ctx)
            x = x + y
    elif kind == "ssm":
        x = x + apply_ssm(
            p["ssm"], rms_norm(x, p["norm1"], cfg.norm_eps), cfg, ctx,
            use_kernel_interpret=interp,
        )
    elif kind == "rec":
        x = x + apply_rglru(p["rec"], rms_norm(x, p["norm1"], cfg.norm_eps), cfg, ctx)
        x = x + apply_mlp(p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps), cfg, ctx)
    return x, aux


def init_block_cache(kind: str, cfg, B: int, capacity: int, ctx, dtype):
    if kind in ("attn", "moe"):
        cap = capacity if cfg.local_window is None else min(
            capacity, _pow2_pad(cfg.local_window, ctx.tp)
        )
        return init_kv_cache(cfg, B, cap, ctx, dtype)
    if kind == "ssm":
        return init_ssm_cache(cfg, B, ctx, dtype)
    if kind == "rec":
        return init_rglru_cache(cfg, B, ctx, dtype)
    raise ValueError(kind)


def _pow2_pad(w: int, tp: int) -> int:
    return ((w + tp - 1) // tp) * tp


def block_cache_specs(kind: str, ctx, shard_batch: bool = True):
    if kind in ("attn", "moe"):
        return kv_cache_specs(ctx, shard_batch)
    if kind == "ssm":
        return ssm_cache_specs(ctx, shard_batch)
    if kind == "rec":
        return rglru_cache_specs(ctx, shard_batch)
    raise ValueError(kind)


def decode_block(p, kind: str, x, cache, pos, cfg, ctx: ParallelCtx):
    if kind in ("attn", "moe"):
        y, cache = decode_attention(
            p["attn"], rms_norm(x, p["norm1"], cfg.norm_eps), cache, pos, cfg, ctx
        )
        x = x + y
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if kind == "attn":
            x = x + apply_mlp_replicated(p["mlp"], h, cfg, ctx)
        else:
            y2, _ = apply_moe_replicated(p["moe"], h, cfg, ctx)
            x = x + y2
    elif kind == "ssm":
        y, cache = decode_ssm(p["ssm"], rms_norm(x, p["norm1"], cfg.norm_eps), cache, cfg, ctx)
        x = x + y
    elif kind == "rec":
        y, cache = decode_rglru(p["rec"], rms_norm(x, p["norm1"], cfg.norm_eps), cache, cfg, ctx)
        x = x + y
        x = x + apply_mlp_replicated(p["mlp"], rms_norm(x, p["norm2"], cfg.norm_eps), cfg, ctx)
    return x, cache


# ------------------------------------------------------- stacked (scan) form


def _stack_trees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def init_stack(key, cfg, ctx: ParallelCtx):
    """Returns {"periods": stacked-per-position params, "rem": remainder}."""
    pattern = cfg.pattern
    period = len(pattern)
    n_full = cfg.n_layers // period
    rem = cfg.n_layers % period
    keys = jax.random.split(key, cfg.n_layers)
    periods = []
    for i in range(n_full):
        periods.append(
            tuple(
                init_block(keys[i * period + j], pattern[j], cfg, ctx)
                for j in range(period)
            )
        )
    stacked = _stack_trees(periods) if n_full > 0 else None
    remainder = tuple(
        init_block(keys[n_full * period + j], pattern[j], cfg, ctx)
        for j in range(rem)
    )
    return {"periods": stacked, "rem": remainder}


def stack_specs(cfg, ctx: ParallelCtx):
    from jax.sharding import PartitionSpec as P

    pattern = cfg.pattern
    period = len(pattern)
    n_full = cfg.n_layers // period
    rem = cfg.n_layers % period

    def prepend(spec_tree):
        return jax.tree.map(
            lambda s: P(*((None,) + tuple(s))),
            spec_tree,
            is_leaf=lambda s: isinstance(s, P),
        )

    stacked = (
        tuple(prepend(block_specs(pattern[j], cfg, ctx)) for j in range(period))
        if n_full > 0 else None
    )
    remainder = tuple(block_specs(pattern[j], cfg, ctx) for j in range(rem))
    return {"periods": stacked, "rem": remainder}


def _shift_plan(plan):
    """Stacked-storage FSDP dims -> per-slice dims (scan strips dim 0)."""
    return jax.tree.map(lambda d: d - 1 if d > 0 else -1, plan)


def apply_stack(params, x, cfg, ctx: ParallelCtx, *, interp=False, remat="dots",
                fsdp_plan=None):
    from ..mesh.api import fsdp_gather

    pattern = cfg.pattern
    period = len(pattern)
    period_plan = (
        _shift_plan(fsdp_plan["periods"])
        if fsdp_plan is not None and fsdp_plan["periods"] is not None else None
    )

    def period_fn(x, pp):
        if period_plan is not None:
            # ZeRO-3 weight streaming: gather this period's layer params
            # (AD transposes to the reduce-scatter grad sync)
            pp = fsdp_gather(pp, period_plan, ctx)
        aux = jnp.zeros((), jnp.float32)
        for j in range(period):
            x, a = apply_block(pp[j], pattern[j], x, cfg, ctx, interp=interp)
            aux = aux + a
        return x, aux

    body = period_fn
    if remat != "none":
        policy = REMAT_POLICIES[remat]()
        body = jax.checkpoint(period_fn, policy=policy)

    aux_total = jnp.zeros((), jnp.float32)
    if params["periods"] is not None:
        x, auxs = lax.scan(lambda c, pp: body(c, pp), x, params["periods"])
        aux_total = aux_total + auxs.sum()
    for j, p in enumerate(params["rem"]):
        if fsdp_plan is not None:
            p = fsdp_gather(p, fsdp_plan["rem"][j], ctx)
        x, a = apply_block(p, pattern[j], x, cfg, ctx, interp=interp)
        aux_total = aux_total + a
    return x, aux_total


def init_stack_cache(cfg, B: int, capacity: int, ctx, dtype):
    pattern = cfg.pattern
    period = len(pattern)
    n_full = cfg.n_layers // period
    rem = cfg.n_layers % period
    stacked = (
        _stack_trees(
            [
                tuple(
                    init_block_cache(pattern[j], cfg, B, capacity, ctx, dtype)
                    for j in range(period)
                )
                for _ in range(n_full)
            ]
        )
        if n_full > 0 else None
    )
    remainder = tuple(
        init_block_cache(pattern[j], cfg, B, capacity, ctx, dtype)
        for j in range(rem)
    )
    return {"periods": stacked, "rem": remainder}


def stack_cache_specs(cfg, ctx, shard_batch: bool = True):
    from jax.sharding import PartitionSpec as P

    pattern = cfg.pattern
    period = len(pattern)
    n_full = cfg.n_layers // period
    rem = cfg.n_layers % period

    def prepend(spec_tree):
        return jax.tree.map(
            lambda s: P(*((None,) + tuple(s))),
            spec_tree,
            is_leaf=lambda s: isinstance(s, P),
        )

    stacked = (
        tuple(prepend(block_cache_specs(pattern[j], ctx, shard_batch))
              for j in range(period))
        if n_full > 0 else None
    )
    remainder = tuple(
        block_cache_specs(pattern[j], ctx, shard_batch) for j in range(rem)
    )
    return {"periods": stacked, "rem": remainder}


def decode_stack(params, caches, x, pos, cfg, ctx: ParallelCtx, *, fsdp_plan=None):
    from ..mesh.api import fsdp_gather

    pattern = cfg.pattern
    period = len(pattern)
    period_plan = (
        _shift_plan(fsdp_plan["periods"])
        if fsdp_plan is not None and fsdp_plan["periods"] is not None else None
    )

    def period_fn(x, pp_cc):
        pp, cc = pp_cc
        if period_plan is not None:
            pp = fsdp_gather(pp, period_plan, ctx)
        new_cc = []
        for j in range(period):
            x, c = decode_block(pp[j], pattern[j], x, cc[j], pos, cfg, ctx)
            new_cc.append(c)
        return x, tuple(new_cc)

    if params["periods"] is not None:
        x, new_stacked = lax.scan(
            period_fn, x, (params["periods"], caches["periods"])
        )
    else:
        new_stacked = None
    new_rem = []
    for j, p in enumerate(params["rem"]):
        if fsdp_plan is not None:
            p = fsdp_gather(p, fsdp_plan["rem"][j], ctx)
        x, c = decode_block(p, pattern[j], x, caches["rem"][j], pos, cfg, ctx)
        new_rem.append(c)
    return x, {"periods": new_stacked, "rem": tuple(new_rem)}
