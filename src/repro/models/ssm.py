"""Mamba2 (SSD) mixer block under TP, backed by the ssd Pallas kernel.

TP layout: the inner width (expand*D) and its heads are column-sharded;
B/C projections (shared across heads, ngroups=1 simplification — recorded in
DESIGN.md) are replicated.  Train/prefill runs on the sequence-gathered view
(the same streamed allgather the attention path uses) because the causal
conv and the scan need contiguous sequences; output returns to sequence
shards through the streamed matmul-reduce-scatter.  Decode carries a
(conv window, SSD state) cache — O(1) in sequence length, which is why this
arch runs the long_500k shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels import ssd_scan, ssd_decode_step
from ..mesh.api import ParallelCtx
from ..parallel import (
    all_reduce,
    column_parallel_linear,
    gather_sequence,
    row_parallel_linear,
)
from .common import rms_norm, silu, trunc_normal


def _dims(cfg, tp: int):
    d_in = cfg.ssm_expand * cfg.d_model
    nh = d_in // cfg.ssm_headdim
    assert nh % tp == 0 or tp == 1, f"{nh} ssm heads vs tp={tp}"
    nh_loc = nh // tp if tp > 1 else nh
    return d_in, nh, nh_loc, nh_loc * cfg.ssm_headdim


def init_ssm(key, cfg, ctx: ParallelCtx):
    """GLOBAL-shape SSM params (inner width/heads sharded by the specs)."""
    D = cfg.d_model
    tp = ctx.tp
    d_in, nh, nh_loc, d_in_loc = _dims(cfg, tp)
    Dst = cfg.ssm_state
    K = cfg.ssm_conv
    ks = jax.random.split(key, 8)
    s = D ** -0.5
    return {
        "w_z": trunc_normal(ks[0], (D, d_in), s),
        "w_x": trunc_normal(ks[1], (D, d_in), s),
        "w_bc": trunc_normal(ks[2], (D, 2 * Dst), s),
        "w_dt": trunc_normal(ks[3], (D, nh), s),
        "dt_bias": jnp.zeros((nh,)),
        "A_log": jnp.zeros((nh,)),                # A = -exp(A_log) -> -1
        "D_skip": jnp.ones((nh,)),
        "conv_x": trunc_normal(ks[4], (K, d_in), K ** -0.5),
        "conv_bc": trunc_normal(ks[5], (K, 2 * Dst), K ** -0.5),
        "gn": jnp.ones((cfg.ssm_headdim,)),       # grouped (per-head) norm
        "w_out": trunc_normal(ks[6], (d_in, D), d_in ** -0.5),
    }


def ssm_specs(cfg, ctx: ParallelCtx):
    from jax.sharding import PartitionSpec as P

    m = ctx.model_axis
    return {
        "w_z": P(None, m), "w_x": P(None, m), "w_bc": P(None, None),
        "w_dt": P(None, m), "dt_bias": P(m), "A_log": P(m), "D_skip": P(m),
        "conv_x": P(None, m), "conv_bc": P(None, None), "gn": P(None),
        "w_out": P(m, None),
    }


def _loc_cols(w, ctx):
    """Inside shard_map the column-sharded weight is already local."""
    return w


def _causal_conv(x, w):
    """Depthwise causal conv.  x: (B, S, C), w: (K, C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(K))
    return out


def apply_ssm(p, x, cfg, ctx: ParallelCtx, *, use_kernel_interpret=False):
    """Train/prefill.  x: (B, S_loc, D) sequence-sharded -> same."""
    B, S_loc, D = x.shape
    tp = ctx.tp
    S = S_loc * tp
    d_in, nh, nh_loc, d_in_loc = _dims(cfg, tp)
    hd = cfg.ssm_headdim
    Dst = cfg.ssm_state

    x2d = x.reshape(B * S_loc, D)
    if ctx.opt_shared_gather:
        # one ring for the whole mixer: z overlapped, x/B/C/dt from the copy
        z, xf = column_parallel_linear(
            x2d, p["w_z"], ctx, tag="ssm.in", return_gathered=True
        )
        xin = xf @ _loc_cols(p["w_x"], ctx)
    else:
        z = column_parallel_linear(
            x2d, p["w_z"], ctx, tag="ssm.in"
        )                                               # (tp*B*S_loc, d_in_loc)
        xin = column_parallel_linear(x2d, p["w_x"], ctx, tag="ssm.in")
        xf = gather_sequence(x2d, ctx, tag="ssm.gather") if tp > 1 else x2d
    bc = xf @ p["w_bc"]                                  # (T, 2*Dst)
    dt_raw = xf @ p["w_dt"]                              # (T, nh_loc)

    def to_bsc(t, C):
        return (
            t.reshape(tp, B, S_loc, C).transpose(1, 0, 2, 3).reshape(B, S, C)
        )

    z = to_bsc(z, d_in_loc)
    xin = to_bsc(xin, d_in_loc)
    bc = to_bsc(bc, 2 * Dst)
    dt_raw = to_bsc(dt_raw, nh_loc)

    xin = silu(_causal_conv(xin, p["conv_x"]))
    bc = silu(_causal_conv(bc, p["conv_bc"]))
    Bm, Cm = bc[..., :Dst], bc[..., Dst:]
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])          # (B, S, nh_loc)

    # per-head SSD scan via the kernel
    xh = xin.reshape(B, S, nh_loc, hd).transpose(0, 2, 1, 3).reshape(B * nh_loc, S, hd)
    dth = dt.transpose(0, 2, 1).reshape(B * nh_loc, S)
    Bh = jnp.broadcast_to(Bm[:, None], (B, nh_loc, S, Dst)).reshape(B * nh_loc, S, Dst)
    Ch = jnp.broadcast_to(Cm[:, None], (B, nh_loc, S, Dst)).reshape(B * nh_loc, S, Dst)
    A = -jnp.exp(p["A_log"])                             # (nh_loc,)
    Ah = jnp.broadcast_to(A[None, :], (B, nh_loc)).reshape(B * nh_loc, 1)
    y = ssd_scan(xh, dth, Bh, Ch, Ah, interpret=use_kernel_interpret)
    # per-head skip connection
    d_sk = jnp.broadcast_to(p["D_skip"][None, :], (B, nh_loc)).reshape(B * nh_loc, 1, 1)
    y = y + d_sk * xh
    y = y.reshape(B, nh_loc, S, hd)
    y = rms_norm(y, p["gn"], cfg.norm_eps)               # grouped norm per head
    y = y.transpose(0, 2, 1, 3).reshape(B, S, d_in_loc)
    y = y * silu(z)
    # row-parallel out proj, back to sequence shards
    y2d = (
        y.reshape(B, tp, S_loc, d_in_loc)
        .transpose(1, 0, 2, 3)
        .reshape(tp * B * S_loc, d_in_loc)
    )
    out = row_parallel_linear(y2d, p["w_out"], ctx, tag="ssm.out")
    return out.reshape(B, S_loc, D)


# ------------------------------------------------------------------ decode


def init_ssm_cache(cfg, B: int, ctx: ParallelCtx, dtype):
    tp = ctx.tp
    d_in, nh, nh_loc, d_in_loc = _dims(cfg, tp)
    K = cfg.ssm_conv
    return {
        "conv_x": jnp.zeros((B, K - 1, d_in_loc), dtype),
        "conv_bc": jnp.zeros((B, K - 1, 2 * cfg.ssm_state), dtype),
        "state": jnp.zeros((B, nh_loc, cfg.ssm_state, cfg.ssm_headdim), jnp.float32),
    }


def ssm_cache_specs(ctx: ParallelCtx, shard_batch: bool = True):
    from jax.sharding import PartitionSpec as P

    m = ctx.model_axis
    b = _bax(ctx) if shard_batch else None
    return {"conv_x": P(b, None, m), "conv_bc": P(b, None, None),
            "state": P(b, m, None, None)}


def _bax(ctx: ParallelCtx):
    if not ctx.batch_axes:
        return None
    return ctx.batch_axes if len(ctx.batch_axes) > 1 else ctx.batch_axes[0]


def decode_ssm(p, x, cache, cfg, ctx: ParallelCtx):
    """x: (B, 1, D) replicated -> (y, cache')."""
    B = x.shape[0]
    tp = ctx.tp
    d_in, nh, nh_loc, d_in_loc = _dims(cfg, tp)
    hd = cfg.ssm_headdim
    Dst = cfg.ssm_state
    K = cfg.ssm_conv

    x2d = x.reshape(B, -1)
    z = x2d @ p["w_z"]
    xin = x2d @ p["w_x"]
    bc = x2d @ p["w_bc"]
    dt_raw = x2d @ p["w_dt"]

    cx = jnp.concatenate([cache["conv_x"], xin[:, None]], axis=1)  # (B, K, C)
    cb = jnp.concatenate([cache["conv_bc"], bc[:, None]], axis=1)
    xin_c = silu(jnp.einsum("bkc,kc->bc", cx, p["conv_x"]))
    bc_c = silu(jnp.einsum("bkc,kc->bc", cb, p["conv_bc"]))
    Bm, Cm = bc_c[..., :Dst], bc_c[..., Dst:]
    dt = jax.nn.softplus(dt_raw + p["dt_bias"])                    # (B, nh_loc)

    xh = xin_c.reshape(B * nh_loc, hd)
    dth = dt.reshape(B * nh_loc)
    Bh = jnp.broadcast_to(Bm[:, None], (B, nh_loc, Dst)).reshape(B * nh_loc, Dst)
    Ch = jnp.broadcast_to(Cm[:, None], (B, nh_loc, Dst)).reshape(B * nh_loc, Dst)
    A = -jnp.exp(p["A_log"])
    Ah = jnp.broadcast_to(A[None, :], (B, nh_loc)).reshape(B * nh_loc, 1)
    st_flat = cache["state"].reshape(B * nh_loc, Dst, hd)
    state, y = ssd_decode_step(st_flat, xh, dth, Bh, Ch, Ah)
    state = state.reshape(B, nh_loc, Dst, hd)
    y = y + jnp.broadcast_to(p["D_skip"][None, :], (B, nh_loc)).reshape(
        B * nh_loc, 1
    ) * xh
    y = rms_norm(y.reshape(B, nh_loc, 1, hd), p["gn"], cfg.norm_eps)
    y = y.reshape(B, d_in_loc) * silu(z)
    out = all_reduce(y @ p["w_out"], ctx, tag="ssm.out")
    cache = {"conv_x": cx[:, 1:], "conv_bc": cb[:, 1:], "state": state}
    return out.reshape(B, 1, -1), cache
