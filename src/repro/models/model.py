"""LM wrapper: embeddings -> block stack -> head/loss; prefill + decode.

Sequence-parallel residual stream end-to-end:
  * vocab-parallel embedding with the psum fused into a reduce-scatter onto
    sequence shards (Megatron-SP style; SMI or bulk collectives, with the
    SMI wire path selected by the ctx transport backend — comm_mode
    "smi:static" | "smi:packet" | "smi:fused", see repro/transport),
  * vocab-parallel cross-entropy, chunked over the sequence so (B, S, V/tp)
    logits never materialise at once,
  * modality frontends per the assignment: VLM patch embeddings and
    EnCodec codebook streams arrive precomputed via input_specs() stubs.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from ..mesh.api import ParallelCtx
from ..parallel import (
    gather_sequence,
    parallel_embedding_partial,
    psum_tagged,
    reduce_scatter_sequence,
    vocab_parallel_cross_entropy,
)
from .common import lm_head, rms_norm, trunc_normal
from .transformer import (
    apply_stack,
    decode_stack,
    init_stack,
    init_stack_cache,
    stack_cache_specs,
    stack_specs,
)


def _v_loc(cfg, tp: int) -> int:
    assert cfg.padded_vocab % tp == 0 or tp == 1
    return cfg.padded_vocab // tp if tp > 1 else cfg.padded_vocab


def init_lm(key, cfg, ctx: ParallelCtx):
    """GLOBAL-shape LM params (vocab padded; sharded by lm_specs)."""
    D = cfg.d_model
    V = cfg.padded_vocab
    assert V % ctx.tp == 0 or ctx.tp == 1
    ks = jax.random.split(key, 4)
    p = {"final_norm": jnp.ones((D,)), "stack": init_stack(ks[1], cfg, ctx)}
    if cfg.n_codebooks > 1:
        p["embed_cb"] = trunc_normal(ks[0], (cfg.n_codebooks, V, D), 0.02)
        p["head_cb"] = trunc_normal(ks[2], (cfg.n_codebooks, D, V), D ** -0.5)
    else:
        p["embed"] = trunc_normal(ks[0], (V, D), 0.02)
        if not cfg.tie_embeddings:
            p["head"] = trunc_normal(ks[2], (D, V), D ** -0.5)
    return p


def lm_specs(cfg, ctx: ParallelCtx):
    from jax.sharding import PartitionSpec as P

    m = ctx.model_axis
    sp = {"final_norm": P(None), "stack": stack_specs(cfg, ctx)}
    if cfg.n_codebooks > 1:
        sp["embed_cb"] = P(None, m, None)
        sp["head_cb"] = P(None, None, m)
    else:
        sp["embed"] = P(m, None)
        if not cfg.tie_embeddings:
            sp["head"] = P(None, m)
    return sp


def _cast(p, dtype):
    return jax.tree.map(
        lambda v: v.astype(dtype) if v.dtype == jnp.float32 else v, p
    )


# --------------------------------------------------------------- embedding


def _embed_partial(table_local, ids, ctx: ParallelCtx):
    """Local-vocab-shard partial embedding, NO reduction (caller picks
    psum for decode or reduce-scatter for the SP residual stream)."""
    return parallel_embedding_partial(table_local, ids, ctx)


def embed_tokens_sp(params, tokens, cfg, ctx: ParallelCtx, extra_embeds=None):
    """tokens: (B, S) (or (B, S, n_cb)) replicated -> (B, S_loc, D) shards."""
    tp = ctx.tp
    if cfg.n_codebooks > 1:
        emb = sum(
            _embed_partial(params["embed_cb"][cb], tokens[..., cb], ctx)
            for cb in range(cfg.n_codebooks)
        )
    else:
        emb = _embed_partial(params["embed"], tokens, ctx)
    B, S = emb.shape[0], emb.shape[1]
    if extra_embeds is not None:
        # VLM stub: first n_patches positions are precomputed patch embeds.
        npch = extra_embeds.shape[1]
        # zero the partial for patch positions; add them post-reduction so
        # only one vocab shard (rank 0) contributes the full value
        pos = jnp.arange(S)[None, :, None]
        emb = jnp.where(pos < npch, 0.0, emb)
        pad = jnp.zeros((B, S - npch, emb.shape[-1]), extra_embeds.dtype)
        full = jnp.concatenate([extra_embeds, pad], axis=1)
        emb = emb + jnp.where(
            jnp.logical_and(pos < npch, ctx.rank() == 0), full, 0.0
        )
    if tp > 1:
        # fused vocab-psum + seq-scatter: reduce_scatter over blocks laid out
        # shard-major: (tp, B, S_loc, D) flattened on rows
        S_loc = S // tp
        blocks = (
            emb.reshape(B, tp, S_loc, -1).transpose(1, 0, 2, 3)
            .reshape(tp * B * S_loc, -1)
        )
        out = reduce_scatter_sequence(blocks, ctx, tag="tp.embed")
        return out.reshape(B, S_loc, -1).astype(_dt(cfg))
    return emb.astype(_dt(cfg))


def _dt(cfg):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ------------------------------------------------------------ train / loss


def lm_loss(
    params,
    tokens,      # (B, S) int32 (or (B, S, n_cb))
    labels,      # same shape; -100 = ignore
    cfg,
    ctx: ParallelCtx,
    *,
    extra_embeds=None,
    interp=False,
    remat="dots",
    loss_chunks: int = 1,
    aux_weight: float = 1e-2,
    fsdp_plan=None,
):
    """Causal-LM loss (mean CE over valid labels) + MoE aux loss."""
    tp = ctx.tp
    pf = _cast(params, _dt(cfg))
    if fsdp_plan is not None:
        from ..mesh.api import fsdp_gather

        for key in ("embed", "head", "embed_cb", "head_cb", "final_norm"):
            if key in pf:
                pf[key] = fsdp_gather(pf[key], fsdp_plan[key], ctx)
    x = embed_tokens_sp(pf, tokens, cfg, ctx, extra_embeds=extra_embeds)
    x, aux = apply_stack(pf["stack"], x, cfg, ctx, interp=interp, remat=remat,
                         fsdp_plan=None if fsdp_plan is None else fsdp_plan["stack"])
    x = rms_norm(x, pf["final_norm"], cfg.norm_eps)      # (B, S_loc, D)

    B, S_loc, D = x.shape
    S = S_loc * tp

    if cfg.n_codebooks > 1:
        tables = [pf["head_cb"][cb] for cb in range(cfg.n_codebooks)]
    elif cfg.tie_embeddings:
        tables = [pf["embed"].T]
    else:
        tables = [pf["head"]]

    assert S_loc % loss_chunks == 0
    csz = S_loc // loss_chunks
    total = jnp.zeros((), jnp.float32)
    count = jnp.zeros((), jnp.float32)

    def chunk_ce(xc, labc):
        """xc: (B, csz, D) shard chunk; labc: (B, tp*csz[, n_cb]) aligned."""
        if tp > 1:
            xg = gather_sequence(xc.reshape(B * csz, D), ctx,
                                 tag="tp.loss.gather")
            xg = xg.reshape(tp, B, csz, D).transpose(1, 0, 2, 3).reshape(B, tp * csz, D)
        else:
            xg = xc
        t = jnp.zeros((), jnp.float32)
        c = jnp.zeros((), jnp.float32)
        for cb, table in enumerate(tables):
            logits = jnp.einsum("bsd,dv->bsv", xg, table).astype(jnp.float32)
            lab = labc[..., cb] if cfg.n_codebooks > 1 else labc
            valid = lab >= 0
            ce = vocab_parallel_cross_entropy(logits, jnp.maximum(lab, 0), ctx)
            t = t + jnp.sum(jnp.where(valid, ce, 0.0))
            c = c + jnp.sum(valid.astype(jnp.float32))
        return t, c

    chunk_ce_r = jax.checkpoint(chunk_ce) if loss_chunks > 1 else chunk_ce

    for ci in range(loss_chunks):
        xc = lax.dynamic_slice_in_dim(x, ci * csz, csz, axis=1)
        if tp > 1:
            # labels for the gathered chunk: (B, tp, csz) -> (B, tp*csz),
            # r-major blocks matching the all-gathered x layout
            lb = labels.reshape((B, tp, S_loc) + labels.shape[2:])
            lb = lax.dynamic_slice_in_dim(lb, ci * csz, csz, axis=2)
            lb = lb.reshape((B, tp * csz) + labels.shape[2:])
        else:
            lb = lax.dynamic_slice_in_dim(labels, ci * csz, csz, axis=1)
        t, c = chunk_ce_r(xc, lb)
        total = total + t
        count = count + c

    loss = total / jnp.maximum(count, 1.0)
    return loss + aux_weight * aux, (loss, aux)


# ---------------------------------------------------------------- serving


def lm_prefill(params, tokens, cfg, ctx: ParallelCtx, *, capacity: int,
               extra_embeds=None, interp=False, fsdp_plan=None):
    """Prefill: full forward (no caches materialised — SMI streaming keeps
    attention block-wise); returns final hidden states, sequence-sharded.

    NOTE: serving-grade prefill would also populate the KV cache; the
    serve engine replays prefill through decode steps for cache build at
    small scale, while the 32k prefill shape benchmarks this compute path.
    """
    pf = _cast(params, _dt(cfg))
    if fsdp_plan is not None:
        from ..mesh.api import fsdp_gather

        for key in ("embed", "head", "embed_cb", "head_cb", "final_norm"):
            if key in pf:
                pf[key] = fsdp_gather(pf[key], fsdp_plan[key], ctx)
    x = embed_tokens_sp(pf, tokens, cfg, ctx, extra_embeds=extra_embeds)
    x, _ = apply_stack(pf["stack"], x, cfg, ctx, interp=interp, remat="none",
                       fsdp_plan=None if fsdp_plan is None else fsdp_plan["stack"])
    return rms_norm(x, pf["final_norm"], cfg.norm_eps)


def lm_decode_step(params, caches, token, pos, cfg, ctx: ParallelCtx,
                   *, gather_logits: bool = True, fsdp_plan=None):
    """One decode step.  token: (B,) int32 (or (B, n_cb)); pos: scalar.

    Returns (logits, caches'): full (B, V[, n_cb]) when ``gather_logits``,
    else the local vocab shard (B, V_loc[, n_cb]) for shard_map out_specs
    to assemble (avoids the in-region gather)."""
    pf = _cast(params, _dt(cfg))
    if fsdp_plan is not None:
        from ..mesh.api import fsdp_gather

        for key in ("embed", "head", "embed_cb", "head_cb", "final_norm"):
            if key in pf:
                pf[key] = fsdp_gather(pf[key], fsdp_plan[key], ctx)
    if cfg.n_codebooks > 1:
        emb = sum(
            _embed_partial(pf["embed_cb"][cb], token[:, cb], ctx)
            for cb in range(cfg.n_codebooks)
        )
    else:
        emb = _embed_partial(pf["embed"], token, ctx)
    x = psum_tagged(emb, ctx, "tp.embed")[:, None, :].astype(_dt(cfg))  # (B, 1, D)
    x, caches = decode_stack(pf["stack"], caches, x, pos, cfg, ctx,
                             fsdp_plan=None if fsdp_plan is None else fsdp_plan["stack"])
    x = rms_norm(x, pf["final_norm"], cfg.norm_eps)[:, 0]   # (B, D)

    if cfg.n_codebooks > 1:
        logit_loc = jnp.stack(
            [x @ pf["head_cb"][cb] for cb in range(cfg.n_codebooks)], axis=-1
        )  # (B, V_loc, n_cb)
    elif cfg.tie_embeddings:
        logit_loc = x @ pf["embed"].T
    else:
        logit_loc = x @ pf["head"]
    if not gather_logits:
        return logit_loc.astype(jnp.float32), caches
    # gather the vocab shards: (V_loc, ...) -> (V, ...)
    logits = gather_sequence(jnp.moveaxis(logit_loc, 1, 0), ctx,
                             tag="tp.loss.gather")
    logits = jnp.moveaxis(logits, 0, 1)                     # (B, V[, n_cb])
    return logits.astype(jnp.float32), caches


def lm_caches(cfg, B: int, capacity: int, ctx: ParallelCtx):
    return init_stack_cache(cfg, B, capacity, ctx, _dt(cfg))


def lm_cache_specs(cfg, ctx: ParallelCtx, shard_batch: bool = True):
    return stack_cache_specs(cfg, ctx, shard_batch)
