"""Feed-forward blocks: SwiGLU / GELU, column->row parallel with streamed
collective-matmul (the paper's communication-during-computation applied to
the MLP pair)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..mesh.api import ParallelCtx
from ..parallel import column_parallel_linear, row_parallel_linear
from .common import silu, trunc_normal


def init_mlp(key, cfg, ctx: ParallelCtx, d_ff: int | None = None):
    """GLOBAL-shape MLP params; d_ff must divide the TP degree (all assigned
    archs do — asserted so a bad config fails loudly)."""
    D = cfg.d_model
    ff = d_ff or cfg.d_ff
    assert ff % ctx.tp == 0, f"d_ff={ff} not divisible by tp={ctx.tp}"
    ks = jax.random.split(key, 3)
    p = {}
    if cfg.mlp_type == "swiglu":
        p["w_gate"] = trunc_normal(ks[0], (D, ff), D ** -0.5)
        p["w_up"] = trunc_normal(ks[1], (D, ff), D ** -0.5)
    else:
        p["w_up"] = trunc_normal(ks[1], (D, ff), D ** -0.5)
    p["w_down"] = trunc_normal(ks[2], (ff, D), ff ** -0.5)
    return p


def mlp_specs(cfg, ctx: ParallelCtx):
    from jax.sharding import PartitionSpec as P

    m = ctx.model_axis
    sp = {"w_up": P(None, m), "w_down": P(m, None)}
    if cfg.mlp_type == "swiglu":
        sp["w_gate"] = P(None, m)
    return sp


def apply_mlp(p, x, cfg, ctx: ParallelCtx):
    """x: (B, S_loc, D) sequence-sharded -> same."""
    B, S_loc, D = x.shape
    x2d = x.reshape(B * S_loc, D)
    if cfg.mlp_type == "swiglu":
        if ctx.opt_shared_gather:
            g, xf = column_parallel_linear(
                x2d, p["w_gate"], ctx, tag="tp.mlp.up", return_gathered=True
            )
            u = xf @ p["w_up"]          # ring-free: reuse the gathered input
        else:
            g = column_parallel_linear(x2d, p["w_gate"], ctx, tag="tp.mlp.up")
            u = column_parallel_linear(x2d, p["w_up"], ctx, tag="tp.mlp.up")
        h = silu(g) * u
    else:
        u = column_parallel_linear(x2d, p["w_up"], ctx, tag="tp.mlp.up")
        h = jax.nn.gelu(u)
    y = row_parallel_linear(h, p["w_down"], ctx, tag="tp.mlp.down")
    return y.reshape(B, S_loc, D)


def apply_mlp_replicated(p, x, cfg, ctx: ParallelCtx):
    """Decode path: x (B, 1, D) replicated; partial-sum via psum."""
    from ..parallel import all_reduce

    B = x.shape[0]
    x2d = x.reshape(B, -1)
    if cfg.mlp_type == "swiglu":
        h = silu(x2d @ p["w_gate"]) * (x2d @ p["w_up"])
    else:
        h = jax.nn.gelu(x2d @ p["w_up"])
    y = all_reduce(h @ p["w_down"], ctx, tag="tp.mlp.down")
    return y.reshape(B, 1, -1)
