"""GQA attention: TP/SP train + prefill, distributed flash-decode.

TP layout at tp-way model parallelism (all derived from the assignment's
head counts, which are never divisible by 16 in the KV dimension):

* wq, wo — head-sharded; the head count is padded up to a multiple of tp
  and padded heads are hard-masked (zero output, zero gradient).
* wk, wv — **replicated** (every arch here has n_kv <= 24 < 2*tp; this is
  the standard GQA-under-TP arrangement: KV is cheap, queries are not).
* prefill/train: sequence-parallel residual stream; column-parallel QKV via
  streamed allgather-matmul, row-parallel output via streamed
  matmul-reduce-scatter (the SMI overlap engine).
* decode: KV cache sharded over the model axis on the *sequence* dim
  (uniform regardless of kv head count); queries all-gathered (tiny) and
  flash-decoding LSE-combine psum'd over the model axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels import flash_attention
from ..mesh.api import ParallelCtx
from ..parallel import (
    column_parallel_linear,
    gather_sequence,
    pmax_tagged,
    psum_tagged,
    ring_attention,
    row_parallel_linear,
)
from .common import rms_norm, rope, rope_batched, trunc_normal


def _pad_heads(H: int, tp: int) -> int:
    return ((H + tp - 1) // tp) * tp


def init_attention(key, cfg, ctx: ParallelCtx):
    """GLOBAL-shape attention params (sharded onto devices by the specs;
    head count padded to the TP degree, padded heads hard-masked)."""
    D, hd = cfg.d_model, cfg.hd
    tp = ctx.tp
    Hp = _pad_heads(cfg.n_heads, tp)
    ks = jax.random.split(key, 6)
    s_in = D ** -0.5
    p = {
        "wq": trunc_normal(ks[0], (D, Hp * hd), s_in),
        "wk": trunc_normal(ks[1], (D, cfg.n_kv_heads * hd), s_in),
        "wv": trunc_normal(ks[2], (D, cfg.n_kv_heads * hd), s_in),
        "wo": trunc_normal(ks[3], (Hp * hd, D), (Hp * hd) ** -0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((Hp * hd,))
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,))
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,))
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,))
        p["k_norm"] = jnp.ones((hd,))
    return p


def attention_specs(cfg, ctx: ParallelCtx):
    from jax.sharding import PartitionSpec as P

    m = ctx.model_axis
    sp = {
        "wq": P(None, m),
        "wk": P(None, None),
        "wv": P(None, None),
        "wo": P(m, None),
    }
    if cfg.qkv_bias:
        sp["bq"] = P(m)
        sp["bk"] = P(None)
        sp["bv"] = P(None)
    if cfg.qk_norm:
        sp["q_norm"] = P(None)
        sp["k_norm"] = P(None)
    return sp


def _head_mask_and_kv_map(cfg, ctx: ParallelCtx):
    """(H_loc,) mask of real heads + (H_loc,) kv-head index per local head."""
    tp = ctx.tp
    Hp = _pad_heads(cfg.n_heads, tp)
    H_loc = Hp // tp
    g = max(cfg.n_heads // cfg.n_kv_heads, 1)
    r = ctx.rank()
    gh = r * H_loc + jnp.arange(H_loc)            # global head ids
    mask = (gh < cfg.n_heads).astype(jnp.float32)
    kv_idx = jnp.clip(gh // g, 0, cfg.n_kv_heads - 1)
    return mask, kv_idx


def apply_attention_ring(p, x, cfg, ctx: ParallelCtx):
    """Ring-attention block (beyond-paper §Perf): the sequence stays sharded
    and the (small, GQA) K/V blocks stream around the ring instead of the
    (large) activations — per-layer attention wire bytes drop by
    D / (2 * n_kv * hd) (= 4x for yi-6b, 8x for glm4-9b).

    The head-sharded wq/wo are all-gathered over the model axis first (a
    few 10s of MB — amortised against the saved activation rings); each
    device then computes ALL heads for ITS sequence shard, so compute stays
    balanced and no reduce-scatter is needed at the output.
    """
    B, S_loc, D = x.shape
    tp = ctx.tp
    hd = cfg.hd
    H_loc = p["wq"].shape[1] // hd
    Hp = H_loc * tp
    r = ctx.rank()

    # gather the head-sharded weights (small) over the model ring
    if tp > 1:
        wq = gather_sequence(jnp.moveaxis(p["wq"], 1, 0), ctx, tag="tp.attn.qkv")
        wq = jnp.moveaxis(wq, 0, 1)                  # (D, Hp*hd)
        wo = gather_sequence(p["wo"], ctx, tag="tp.attn.out")  # (Hp*hd, D)
        bq = (gather_sequence(p["bq"], ctx, tag="tp.attn.qkv")
              if cfg.qkv_bias else None)
    else:
        wq, wo = p["wq"], p["wo"]
        bq = p.get("bq")

    x2d = x.reshape(B * S_loc, D)
    q = x2d @ wq
    k = x2d @ p["wk"]
    v = x2d @ p["wv"]
    if cfg.qkv_bias:
        q = q + bq
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S_loc, Hp, hd)
    k = k.reshape(B, S_loc, cfg.n_kv_heads, hd)
    v = v.reshape(B, S_loc, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    pos = r * S_loc + jnp.arange(S_loc)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)

    if tp > 1:
        o = ring_attention(
            q, k, v, ctx, tag="tp.attn.ring", causal=True,
            local_window=cfg.local_window,
        )                                             # (B, S_loc, Hp, hd)
    else:
        from ..kernels import flash_attention

        g = max(cfg.n_heads // cfg.n_kv_heads, 1)
        kv_idx = jnp.clip(jnp.arange(Hp) // g, 0, cfg.n_kv_heads - 1)
        o = flash_attention(q, jnp.take(k, kv_idx, 2), jnp.take(v, kv_idx, 2),
                            causal=True, window=cfg.local_window)
    head_ok = (jnp.arange(Hp) < cfg.n_heads).astype(o.dtype)
    o = o * head_ok[None, None, :, None]
    y = o.reshape(B * S_loc, Hp * hd) @ wo            # local rows: no RS
    return y.reshape(B, S_loc, D)


def apply_attention(p, x, cfg, ctx: ParallelCtx, *, use_kernel_interpret=False):
    """Train/prefill.  x: (B, S_loc, D) sequence-sharded; returns same."""
    if getattr(ctx, "opt_ring_attn", False):
        return apply_attention_ring(p, x, cfg, ctx)
    B, S_loc, D = x.shape
    tp = ctx.tp
    S = S_loc * tp
    hd = cfg.hd
    H_loc = p["wq"].shape[1] // hd
    mask, kv_idx = _head_mask_and_kv_map(cfg, ctx)

    x2d = x.reshape(B * S_loc, D)
    # column-parallel Q (head-sharded); replicated KV
    if ctx.opt_shared_gather:
        # one ring: Q overlapped with the gather; KV from the free copy
        q, xf = column_parallel_linear(
            x2d, p["wq"], ctx, tag="tp.attn.qkv", return_gathered=True
        )
    else:
        q = column_parallel_linear(
            x2d, p["wq"], ctx, tag="tp.attn.qkv"
        )                                             # (tp*B*S_loc, H_loc*hd)
        xf = gather_sequence(x2d, ctx, tag="tp.attn.kv") if tp > 1 else x2d
    k = xf @ p["wk"]
    v = xf @ p["wv"]
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]

    def to_bshd(t, H):
        return (
            t.reshape(tp, B, S_loc, H, hd)
            .transpose(1, 0, 2, 3, 4)
            .reshape(B, S, H, hd)
        )

    q = to_bshd(q, H_loc)
    k = to_bshd(k, cfg.n_kv_heads)
    v = to_bshd(v, cfg.n_kv_heads)

    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)

    pos = jnp.arange(S)
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)

    # local q heads attend their mapped kv head (gather once; GQA under TP)
    k_sel = jnp.take(k, kv_idx, axis=2)               # (B, S, H_loc, hd)
    v_sel = jnp.take(v, kv_idx, axis=2)
    o = flash_attention(
        q, k_sel, v_sel,
        causal=True, window=cfg.local_window,
        interpret=use_kernel_interpret,
    )                                                  # (B, S, H_loc, hd)
    o = o * mask[None, None, :, None].astype(o.dtype)
    # row-parallel out projection, reduce-scatter back to sequence shards
    o2d = (
        o.reshape(B, tp, S_loc, H_loc, hd)
        .transpose(1, 0, 2, 3, 4)
        .reshape(tp * B * S_loc, H_loc * hd)
    )
    y = row_parallel_linear(o2d, p["wo"], ctx, tag="tp.attn.out")  # (B*S_loc, D)
    return y.reshape(B, S_loc, D)


# ------------------------------------------------------------------ decode


def init_kv_cache(cfg, B_loc: int, capacity: int, ctx: ParallelCtx, dtype):
    """Sequence-sharded ring cache: (B, cap/tp, Hkv, hd) + slot positions."""
    tp = ctx.tp
    cap_loc = capacity // tp
    return {
        "k": jnp.zeros((B_loc, cap_loc, cfg.n_kv_heads, cfg.hd), dtype),
        "v": jnp.zeros((B_loc, cap_loc, cfg.n_kv_heads, cfg.hd), dtype),
        "slot_pos": jnp.full((B_loc, cap_loc), -1, jnp.int32),
    }


def kv_cache_specs(ctx: ParallelCtx, shard_batch: bool = True):
    from jax.sharding import PartitionSpec as P

    m = ctx.model_axis
    b = None
    if shard_batch and ctx.batch_axes:
        b = ctx.batch_axes if len(ctx.batch_axes) > 1 else ctx.batch_axes[0]
    return {"k": P(b, m, None, None), "v": P(b, m, None, None),
            "slot_pos": P(b, m)}


def decode_attention(p, x, cache, pos, cfg, ctx: ParallelCtx):
    """One decode step.  x: (B, 1, D) replicated over model; ``pos`` is the
    absolute position of the new token — a scalar (wave decoding: every
    row at the same position) or a (B,) int array (continuous batching:
    one position per slot).  Returns (y (B, 1, D), cache')."""
    B = x.shape[0]
    hd = cfg.hd
    tp = ctx.tp
    H_loc = p["wq"].shape[1] // hd
    Hp = H_loc * tp
    mask, kv_idx = _head_mask_and_kv_map(cfg, ctx)
    r = ctx.rank()
    cap_loc = cache["k"].shape[1]
    capacity = cap_loc * tp
    pos_b = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (B,))

    x2d = x.reshape(B, -1)
    q_loc = (x2d @ p["wq"])
    k_new = (x2d @ p["wk"])
    v_new = (x2d @ p["wv"])
    if cfg.qkv_bias:
        q_loc = q_loc + p["bq"]
        k_new = k_new + p["bk"]
        v_new = v_new + p["bv"]
    q_loc = q_loc.reshape(B, 1, H_loc, hd)
    k_new = k_new.reshape(B, 1, cfg.n_kv_heads, hd)
    v_new = v_new.reshape(B, 1, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q_loc = rms_norm(q_loc, p["q_norm"], cfg.norm_eps)
        k_new = rms_norm(k_new, p["k_norm"], cfg.norm_eps)
    q_loc = rope_batched(q_loc, pos_b, cfg.rope_theta)
    k_new = rope_batched(k_new, pos_b, cfg.rope_theta)

    # gather all query heads (tiny) so every device scans its cache slice
    if tp > 1:
        q = gather_sequence(q_loc.reshape(B, H_loc * hd)[None], ctx,
                            tag="tp.attn.qkv")
        q = q.reshape(tp, B, H_loc, hd).transpose(1, 0, 2, 3).reshape(B, Hp, hd)
    else:
        q = q_loc.reshape(B, Hp, hd)

    # ring-buffer write, per batch row: global slot = pos % capacity;
    # shard r owns slots [r*cap_loc, (r+1)*cap_loc)
    g_slot_b = pos_b % capacity
    my_b = jnp.logical_and(g_slot_b >= r * cap_loc, g_slot_b < (r + 1) * cap_loc)
    l_slot_b = jnp.clip(g_slot_b - r * cap_loc, 0, cap_loc - 1)
    write = jnp.logical_and(
        my_b[:, None], jnp.arange(cap_loc)[None, :] == l_slot_b[:, None]
    )                                                        # (B, cap_loc)
    k_cache = jnp.where(
        write[:, :, None, None], k_new.astype(cache["k"].dtype), cache["k"]
    )
    v_cache = jnp.where(
        write[:, :, None, None], v_new.astype(cache["v"].dtype), cache["v"]
    )
    slot_pos = jnp.where(write, pos_b[:, None], cache["slot_pos"])

    # partial attention over the local cache slice, all heads
    kv_sel_k = jnp.take(k_cache, kv_idx_full(cfg, Hp), axis=2)  # (B, cap_loc, Hp, hd)
    kv_sel_v = jnp.take(v_cache, kv_idx_full(cfg, Hp), axis=2)
    s = jnp.einsum(
        "bhd,bkhd->bhk", q.astype(jnp.float32) * hd ** -0.5,
        kv_sel_k.astype(jnp.float32),
    )
    valid = slot_pos >= 0                                    # (B, cap_loc)
    valid = jnp.logical_and(valid, slot_pos <= pos_b[:, None])
    if cfg.local_window is not None:
        valid = jnp.logical_and(
            valid, slot_pos > pos_b[:, None] - cfg.local_window
        )
    s = jnp.where(valid[:, None, :], s, -1e30)
    m_loc = s.max(axis=-1)                                   # (B, Hp)
    m_g = pmax_tagged(m_loc, ctx, "tp.attn.out")
    pexp = jnp.exp(s - m_g[..., None])
    pexp = jnp.where(valid[:, None, :], pexp, 0.0)
    l_loc = pexp.sum(axis=-1)
    o_loc = jnp.einsum("bhk,bkhd->bhd", pexp, kv_sel_v.astype(jnp.float32))
    l_g = psum_tagged(l_loc, ctx, "tp.attn.out")
    o_g = psum_tagged(o_loc, ctx, "tp.attn.out")
    o = o_g / jnp.maximum(l_g, 1e-30)[..., None]             # (B, Hp, hd)
    o = o * mask_full(cfg, Hp)[None, :, None].astype(o.dtype)

    # row-parallel out proj: my head slice only, then psum
    o_my = lax.dynamic_slice_in_dim(o, r * H_loc, H_loc, axis=1)
    y = (o_my.reshape(B, H_loc * hd).astype(x.dtype)) @ p["wo"]
    y = psum_tagged(y, ctx, "tp.attn.out")
    cache = {"k": k_cache, "v": v_cache, "slot_pos": slot_pos}
    return y.reshape(B, 1, -1), cache


def kv_idx_full(cfg, Hp: int):
    g = max(cfg.n_heads // cfg.n_kv_heads, 1)
    gh = jnp.arange(Hp)
    return jnp.clip(gh // g, 0, cfg.n_kv_heads - 1)


def mask_full(cfg, Hp: int):
    return (jnp.arange(Hp) < cfg.n_heads).astype(jnp.float32)
