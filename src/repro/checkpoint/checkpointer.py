"""Sharded checkpointing: atomic, manifest-verified, async.

Layout:  <dir>/step_<N>/
            manifest.json   — tree structure, shapes, dtypes, step, extra
            arrays.npz      — flattened leaves (key = leaf index)
Write protocol: stage into ``step_<N>.tmp`` then ``os.rename`` (atomic on
POSIX), so a crash mid-save never corrupts the restore point — the
checkpoint/restart contract the fault-tolerance layer builds on.  An async
mode hands the (already host-fetched) arrays to a writer thread so the train
loop overlaps the disk write with the next step, mirroring the paper's
communication/computation overlap on the host side.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


class Checkpointer:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._pending: threading.Thread | None = None

    # ----------------------------------------------------------------- save

    def save(self, state, step: int, *, extra: dict | None = None,
             async_: bool = False):
        """Snapshot ``state`` (any pytree of arrays) at ``step``."""
        leaves, treedef = jax.tree.flatten(state)
        host = [np.asarray(jax.device_get(l)) for l in leaves]
        if async_:
            self.wait()
            t = threading.Thread(
                target=self._write, args=(host, treedef, step, extra), daemon=True
            )
            t.start()
            self._pending = t
        else:
            self._write(host, treedef, step, extra)

    def _write(self, host, treedef, step, extra):
        final = os.path.join(self.dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"),
                 **{f"leaf_{i}": a for i, a in enumerate(host)})
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "n_leaves": len(host),
            "shapes": [list(a.shape) for a in host],
            "dtypes": [str(a.dtype) for a in host],
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._gc()

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self):
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # -------------------------------------------------------------- restore

    def steps(self):
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self):
        s = self.steps()
        return s[-1] if s else None

    def restore(self, state_like, step: int | None = None):
        """Restore into the structure of ``state_like`` (shapes verified).
        Returns a host-numpy pytree; caller device_puts with its shardings
        (which may belong to a *different* mesh — elastic restart)."""
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        leaves = [data[f"leaf_{i}"] for i in range(manifest["n_leaves"])]
        ref_leaves, treedef = jax.tree.flatten(state_like)
        assert len(ref_leaves) == len(leaves), (
            f"checkpoint has {len(leaves)} leaves, expected {len(ref_leaves)}"
        )
        for i, (a, r) in enumerate(zip(leaves, ref_leaves)):
            assert tuple(a.shape) == tuple(r.shape), (
                f"leaf {i}: checkpoint {a.shape} vs expected {r.shape}"
            )
        return jax.tree.unflatten(treedef, leaves), manifest
