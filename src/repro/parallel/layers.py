"""Channel-native parallel layers: every layer's comm is a tagged SMI
channel (DESIGN.md §12).

The model stack's communication — column/row-parallel projections, the
parallel embedding, the vocab-sharded cross-entropy, MoE dispatch/combine,
the KV ring of ring attention, sequence gathers/scatters — routes through
here.  Each layer call owns a :class:`~repro.channels.ChannelSpec`
(:func:`layer_spec`: communicator, transport backend, wire format, stats
tag, tuning plan) and drives the exact streamed schedule the repo already
proves bit-identical across backends (core/overlap.py,
core/collectives.py) through a *fresh* transport resolved from that spec,
with every wire byte accounted under the spec's tag.

Two properties fall out:

* **bit-identity** — the schedules, the per-call fresh-instance transport
  resolution, and the raw ``lax.psum`` reductions (kept where the model
  always used them) are unchanged; only tagging and accounting are added,
  neither of which touches traced values.
* **predictability** — the tags partition a training step's wire traffic
  into the taxonomy ``netsim.predict_train_step_stats`` prices, and a
  :func:`~repro.parallel.ledger.capture` of a traced step must match it
  to the byte (``launch/train --validate-comm``).

Tag taxonomy (one bucket per layer comm; see DESIGN.md §12):
``tp.embed`` ``tp.attn.qkv`` ``tp.attn.kv`` ``tp.attn.out``
``tp.attn.ring`` ``tp.mlp.up`` ``tp.mlp.down`` ``tp.loss.gather``
``tp.loss.ce`` ``ep.dispatch`` ``ep.combine`` ``ssm.in`` ``ssm.gather``
``ssm.out`` ``fsdp.gather`` ``grad`` ``pp.stage``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax

from ..channels import ChannelSpec
from ..channels.channel import _tagged
from ..core.collectives import (
    _stream_allreduce_impl,
    stream_allgather,
    stream_reduce_scatter,
)
from ..core.comm import Communicator
from ..core.overlap import (
    stream_allgather_matmul,
    stream_matmul_reducescatter,
    stream_ring_attention,
)
from ..transport.base import tree_bytes
from . import ledger

#: the layer tag taxonomy (asserted stable by tests/test_parallel_layers.py)
LAYER_TAGS = (
    "tp.embed", "tp.attn.qkv", "tp.attn.kv", "tp.attn.out", "tp.attn.ring",
    "tp.mlp.up", "tp.mlp.down", "tp.loss.gather", "tp.loss.ce",
    "ep.dispatch", "ep.combine", "ssm.in", "ssm.gather", "ssm.out",
    "fsdp.gather", "grad", "pp.stage",
)

#: channel kind -> the netsim tuner op a ``plan="auto"`` consults (the
#: tuner prices rooted/ring collectives; ring AG/RS cost like the ring
#: all-reduce phases they compose into)
_PLAN_OPS = {"bcast": "bcast", "reduce": "allreduce", "gather": "allreduce",
             "scatter": "allreduce", "allreduce": "allreduce",
             "exchange": "allreduce", "p2p": "p2p"}


def _matmul(ctx):
    return ctx.matmul_fn or (
        lambda a, b: jnp.dot(
            a, b, preferred_element_type=jnp.float32
        ).astype(a.dtype)
    )


def layer_spec(ctx, tag: str, *, kind: str = "allreduce", wire: str = "raw",
               plan=None, transport=None, port: int | None = None,
               n_chunks: int = 1, op=None) -> ChannelSpec:
    """The ChannelSpec a parallel layer owns: the context's TP communicator
    and launch-selected backend, the layer's stats tag, and the call's
    wire/plan overrides.  ``transport=None`` inherits ``ctx.transport``
    unless a ``plan`` is given (then the tuned plan picks the backend;
    pass ``transport`` explicitly to pin it).

    When the context carries a persistent :class:`~repro.channels.
    ChannelPool` (``ctx.channels``, the serving engine), the layer's spec
    comes from the pool instead: same config, but the tag is pool-prefixed
    (``"serve.tp.attn.qkv"``), the port claim is persistent, and repeat
    calls across decode steps reuse ONE spec per tag."""
    if plan is None:
        plan = ctx.plan
    if transport is None and plan is None:
        transport = ctx.transport
    pool = ctx.channels
    if pool is not None:
        return pool.spec(tag, kind=kind, wire=wire, plan=plan,
                         transport=transport, n_chunks=n_chunks, op=op)
    return ChannelSpec(
        comm=ctx.model_comm, kind=kind, tag=tag, wire=wire, plan=plan,
        transport=transport, port=port, n_chunks=n_chunks, op=op,
    )


def _open(spec: ChannelSpec, x):
    """Fresh transport realising ``spec`` for one traced layer call,
    mirrored into the active capture ledger.  A ``plan`` ("auto" or a
    netsim Plan) selects backend + wire from the tuning table unless the
    spec pins a transport — the tuner's choice is recorded in the active
    ledger's ``plans`` per tag, so a capture shows *which* backend each
    auto-planned layer actually ran; an int8-wire plan falls back to the
    raw wire for non-floating payloads (exactness over the tuner's cost
    hint)."""
    if spec.plan is not None and spec.transport is None:
        from ..netsim.tune import Plan

        p = spec.plan
        if not isinstance(p, Plan):
            assert p == "auto", \
                f"plan must be 'auto', None or a Plan; got {p!r}"
            p = spec.comm.plan(
                _PLAN_OPS.get(spec.kind, "allreduce"), tree_bytes(x)
            )
        if p.wire != "raw" and not all(
            jnp.issubdtype(l.dtype, jnp.floating)
            for l in jax.tree.leaves(x)
        ):
            p = dataclasses.replace(p, wire="raw")
        spec = spec.replace(transport=p.transport_key)
        if spec.tag is not None:
            ledger.record_plan(spec.tag, p.transport_key)
    return ledger.attach(spec.resolve())


# ------------------------------------------------------------ tagged psums
#
# Sites the model always reduced with a raw lax.psum/pmax (flash-decode
# LSE combine, the vocab-parallel CE) keep it — bit-identity — but the
# wire cost is still a channel's worth of traffic: one logical step moving
# the reduced pytree, tallied under the layer tag so the step prediction
# covers every byte the forward trace moves.


def psum_tagged(x, ctx, tag: str):
    if ctx.tp == 1:
        return x
    if ctx.channels is not None:
        tag = ctx.channels.retag(tag)
    ledger.tally(tag, 1, tree_bytes(x))
    return lax.psum(x, ctx.model_axis)


def pmax_tagged(x, ctx, tag: str):
    if ctx.tp == 1:
        return x
    if ctx.channels is not None:
        tag = ctx.channels.retag(tag)
    ledger.tally(tag, 1, tree_bytes(x))
    return lax.pmax(x, ctx.model_axis)


# ------------------------------------------------------- linear projections


def column_parallel_linear(x2d, w, ctx, *, tag: str = "tp.col", spec=None,
                           plan=None, transport=None, wire: str = "raw",
                           return_gathered: bool = False):
    """y = AG_seq(x) @ w_colshard through a tagged channel.

    ``x2d``: (t_local, K) sequence-sharded rows; ``w``: (K, N_local).
    Returns (t_local * tp, N_local) — full rows, local columns — with the
    all-gather streamed through the per-chunk GEMM (core/overlap.py).
    ``return_gathered=True`` also returns the gathered input (free on the
    ring: every shard transits each device)."""
    mm = _matmul(ctx)
    if ctx.tp == 1:
        y = mm(x2d, w)
        return (y, x2d) if return_gathered else y
    if not ctx.is_smi:
        xf = lax.all_gather(x2d, ctx.model_axis, axis=0, tiled=True)
        y = mm(xf, w)
        return (y, xf) if return_gathered else y
    if spec is None:
        spec = layer_spec(ctx, tag, kind="gather", wire=wire, plan=plan,
                          transport=transport)
    t = _open(spec, x2d)
    with _tagged(t, spec.stats_tag):
        return stream_allgather_matmul(
            x2d, w, spec.comm, matmul=mm, transport=t,
            return_gathered=return_gathered,
        )


def row_parallel_linear(x2d, w, ctx, *, tag: str = "tp.row", spec=None,
                        plan=None, transport=None, wire: str = "raw"):
    """y = RS_seq(x @ w_rowshard) through a tagged channel.

    ``x2d``: (t_full, K_local) full rows, local contraction; ``w``:
    (K_local, N).  Returns (t_full / tp, N) sequence shards, with the
    reduce-scatter streamed through the per-chunk GEMM."""
    mm = _matmul(ctx)
    if ctx.tp == 1:
        return mm(x2d, w)
    if not ctx.is_smi:
        y = mm(x2d, w)
        return lax.psum_scatter(y, ctx.model_axis, scatter_dimension=0,
                                tiled=True)
    if spec is None:
        spec = layer_spec(ctx, tag, kind="reduce", wire=wire, plan=plan,
                          transport=transport)
    t = _open(spec, x2d)
    with _tagged(t, spec.stats_tag):
        return stream_matmul_reducescatter(
            x2d, w, spec.comm, matmul=mm, transport=t
        )


# --------------------------------------------------- sequence redistributes


def gather_sequence(x, ctx, axis: int = 0, *, tag: str = "tp.gather",
                    spec=None, plan=None, transport=None, wire: str = "raw"):
    """Plain sequence all-gather along ``axis`` through a tagged channel
    (non-GEMM consumers: MoE token dispatch, conv/scan inputs, decode
    logit assembly)."""
    if ctx.tp == 1:
        return x
    if not ctx.is_smi:
        return lax.all_gather(x, ctx.model_axis, axis=axis, tiled=True)
    if spec is None:
        spec = layer_spec(ctx, tag, kind="gather", wire=wire, plan=plan,
                          transport=transport)
    t = _open(spec, x)
    with _tagged(t, spec.stats_tag):
        if axis != 0:
            x = jnp.moveaxis(x, axis, 0)
        g = stream_allgather(x, spec.comm, transport=t)
        if axis != 0:
            g = jnp.moveaxis(g, 0, axis)
        return g


def reduce_scatter_sequence(x, ctx, axis: int = 0, *, tag: str = "tp.scatter",
                            spec=None, plan=None, transport=None,
                            wire: str = "raw"):
    """Sequence reduce-scatter along ``axis`` through a tagged channel
    (MoE combine, the embedding's fused vocab-psum + seq-scatter)."""
    if ctx.tp == 1:
        return x
    if not ctx.is_smi:
        return lax.psum_scatter(x, ctx.model_axis, scatter_dimension=axis,
                                tiled=True)
    if spec is None:
        spec = layer_spec(ctx, tag, kind="reduce", wire=wire, plan=plan,
                          transport=transport)
    t = _open(spec, x)
    with _tagged(t, spec.stats_tag):
        if axis != 0:
            x = jnp.moveaxis(x, axis, 0)
        y = stream_reduce_scatter(x, spec.comm, transport=t)
        if axis != 0:
            y = jnp.moveaxis(y, 0, axis)
        return y


def all_reduce(x, ctx, *, tag: str = "tp.allreduce", spec=None, plan=None,
               transport=None, wire: str = "raw"):
    """Full all-reduce over the model axis through a tagged channel (MoE
    decode combine, replicated-MLP decode)."""
    if ctx.tp == 1:
        return x
    if not ctx.is_smi:
        return lax.psum(x, ctx.model_axis)
    if spec is None:
        spec = layer_spec(ctx, tag, kind="allreduce", wire=wire, plan=plan,
                          transport=transport)
    t = _open(spec, x)
    with _tagged(t, spec.stats_tag):
        return _stream_allreduce_impl(x, spec.comm, transport=t)


# -------------------------------------------------------- embedding / loss


def parallel_embedding(table_local, ids, ctx, *, tag: str = "tp.embed"):
    """Vocab-parallel embedding lookup -> replicated (B, ..., D).

    Every device holds vocab rows [r*V_local, (r+1)*V_local); out-of-shard
    ids hit zero and one tagged psum over the model axis assembles the
    embedding.  (The SP residual stream instead keeps the partial and
    fuses the reduction into :func:`reduce_scatter_sequence` — see
    models/model.py ``embed_tokens_sp``.)"""
    emb = parallel_embedding_partial(table_local, ids, ctx)
    return psum_tagged(emb, ctx, tag)


def parallel_embedding_partial(table_local, ids, ctx):
    """This vocab shard's partial embedding, NO reduction (caller picks
    the tagged psum for decode or the reduce-scatter for SP)."""
    V_local = table_local.shape[0]
    r = ctx.rank()
    local = ids - r * V_local
    ok = jnp.logical_and(local >= 0, local < V_local)
    emb = jnp.take(table_local, jnp.clip(local, 0, V_local - 1), axis=0)
    return jnp.where(ok[..., None], emb, 0)


def vocab_parallel_cross_entropy(logits_local, labels, ctx,
                                 *, tag: str = "tp.loss.ce"):
    """Cross entropy with vocab-sharded logits (B, S, V_local), labels
    (B, S).  max / sum-exp / label-pick each cross the model axis once —
    the standard Megatron scheme — as tagged reductions."""
    V_local = logits_local.shape[-1]
    r = ctx.rank()
    lf = logits_local.astype(jnp.float32)
    # the max shift is gradient-neutral (d(logZ+m)/dm = 0); pmax has no
    # JVP, so stop the gradient at its *input*
    m = pmax_tagged(lax.stop_gradient(lf.max(axis=-1)), ctx, tag)  # (B, S)
    z = psum_tagged(jnp.exp(lf - m[..., None]).sum(axis=-1), ctx, tag)
    local = labels - r * V_local
    ok = jnp.logical_and(local >= 0, local < V_local)
    picked = jnp.take_along_axis(
        lf, jnp.clip(local, 0, V_local - 1)[..., None], axis=-1
    )[..., 0]
    picked = psum_tagged(jnp.where(ok, picked, 0.0), ctx, tag)
    return jnp.log(z) + m - picked  # (B, S)


# --------------------------------------------------------------- attention


def ring_attention(q, k, v, ctx, *, tag: str = "tp.attn.ring", spec=None,
                   plan=None, transport=None, **kw):
    """Sequence-parallel ring attention: the (small, GQA) K/V blocks
    stream around a tagged channel ring while every device computes its
    sequence shard's attention (core/overlap.py)."""
    assert ctx.tp > 1 and ctx.is_smi
    if spec is None:
        spec = layer_spec(ctx, tag, kind="exchange", plan=plan,
                          transport=transport)
    t = _open(spec, (k, v))
    with _tagged(t, spec.stats_tag):
        return stream_ring_attention(q, k, v, spec.comm, transport=t, **kw)


# --------------------------------------------------------------------- MoE


def moe_dispatch(x2d, ctx, *, tag: str = "ep.dispatch", **kw):
    """Expert dispatch: gather the sequence-sharded token stream to the
    full token view every expert group routes over (the EP all-gather)."""
    return gather_sequence(x2d, ctx, tag=tag, **kw)


def moe_combine(y_partial, ctx, *, tag: str = "ep.combine", **kw):
    """Expert combine: merge per-expert-group partials AND return to
    sequence shards in one reduce-scatter (the EP combine collective)."""
    return reduce_scatter_sequence(y_partial, ctx, tag=tag, **kw)


# ----------------------------------------------------- gradient sync (DP)


def grad_allreduce(g, comm: Communicator, *, tag: str = "grad",
                   transport=None, wire: str = "raw"):
    """One tensor's DP ring all-reduce over a tagged ``"grad"`` channel.

    ``wire="int8"`` composes the compressed-link transport (blockwise
    scales + per-hop error feedback) exactly like a tuned plan would.
    Resolution is fresh per call — per-tensor error-feedback residuals
    must not bleed between tensors of one sync — unless a live transport
    instance is passed (callers tracking stats across a sync own that
    trade)."""
    spec = ChannelSpec(comm=comm, kind="allreduce", tag=tag, wire=wire,
                       transport=transport, port=None)
    t = _open(spec, g)
    with _tagged(t, spec.stats_tag):
        return _stream_allreduce_impl(g, comm, transport=t)


def fsdp_allgather(p, comm: Communicator, dim: int, *,
                   tag: str = "fsdp.gather", transport=None):
    """All-gather one FSDP-sharded leaf along ``dim`` over a tagged
    channel (AD transposes it to the reduce-scatter gradient sync)."""
    spec = ChannelSpec(comm=comm, kind="gather", tag=tag,
                       transport=transport, port=None)
    t = _open(spec, p)
    with _tagged(t, spec.stats_tag):
        moved = jnp.moveaxis(p, dim, 0)
        g = stream_allgather(moved, spec.comm, transport=t)
        return jnp.moveaxis(g, 0, dim)


# ------------------------------------------------------------ pipeline hop


def stage_transport(comm: Communicator, *, tag: str = "pp.stage",
                    transport=None):
    """The persistent chain channel's transport for a pipeline schedule:
    resolved once per traced schedule (the paper's open-once channel), to
    be driven once per tick inside the scan body.

    Runtime-stats backends (the packet router) must not run inside
    ``lax.scan`` bodies, and a lossy wire would corrupt the activations a
    stage hop must deliver exactly — both fall back to the static
    schedule-equivalent wire, which moves bit-identical values (the
    transport contract).  Returns ``(spec, transport)``; the caller
    tallies the schedule's full step count via
    :func:`repro.parallel.ledger.tally` (a scan body traces once, so
    per-call accounting would undercount)."""
    spec = ChannelSpec(comm=comm, kind="exchange", tag=tag,
                       transport=transport, port=None)
    t = spec.resolve()
    if getattr(t, "runtime_stats", False) or getattr(t, "lossy_wire", False):
        from ..transport.registry import get_transport

        t = get_transport("static")
    return spec, t
