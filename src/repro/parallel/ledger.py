"""CommLedger: whole-step, per-tag trace-time comm accounting.

The parallel layers (repro/parallel/layers.py) resolve a *fresh* transport
instance per call — per-trace stats, packet-reuse guards, compressed
error-feedback freshness all depend on it — which means no single
``TransportStats`` object survives a traced training step.  The ledger is
the aggregation point that does survive: while a :func:`capture` block is
active, every transport the layers open mirrors its trace-time tallies
(steps, bytes, honouring the active message tag) into one process-level
:class:`CommLedger`, and sites that communicate without a transport at all
(the raw ``lax.psum`` reductions kept for bit-identity) tally into it
directly.

``launch/train --validate-comm`` lowers the jitted train step inside a
capture and asserts the ledger's per-tag bytes equal
``netsim.predict_train_step_stats`` to the byte (DESIGN.md §12).

Mirroring hooks the :meth:`~repro.transport.base.Transport.tally` funnel
(the single accounting entry point shared by every backend, including the
packet router's explicit step-count formula).  Traced *runtime* counters —
the packet overflow sum — deliberately stay per-instance: they are keyed
to their jax trace and aggregating them across scan-body and top-level
traces would leak tracers.  The rolled ``_schedule_loop`` path in
core/collectives.py scales stats post-hoc without re-entering ``tally``;
it only drives the rooted chain collectives (bcast/reduce), which are not
on the training-step path — callers capturing those should unroll or
account explicitly.
"""

from __future__ import annotations

from contextlib import contextmanager
from dataclasses import dataclass, field

from ..transport.base import Transport

#: the ledger (if any) currently mirroring transport tallies
_ACTIVE: "CommLedger | None" = None

#: bucket for tallies arriving outside any message tag
UNTAGGED = "untagged"


@dataclass
class CommLedger:
    """Per-tag (steps, bytes) totals across one traced step."""

    steps: int = 0
    bytes_moved: int = 0
    #: tag -> {"steps": int, "bytes": int}
    by_tag: dict = field(default_factory=dict)
    #: tag -> tuner-chosen transport key for ``plan="auto"`` layers (the
    #: record the plan-auto config test asserts against)
    plans: dict = field(default_factory=dict)
    _attached: set = field(default_factory=set, repr=False)

    def tally(self, tag: str | None, steps: int, nbytes: int):
        self.steps += steps
        self.bytes_moved += nbytes
        e = self.by_tag.setdefault(tag or UNTAGGED, {"steps": 0, "bytes": 0})
        e["steps"] += steps
        e["bytes"] += nbytes

    def record_plan(self, tag: str, transport_key: str):
        self.plans[tag] = transport_key

    def tag_counts(self, tag: str) -> tuple[int, int]:
        e = self.by_tag.get(tag, {"steps": 0, "bytes": 0})
        return e["steps"], e["bytes"]

    def tag_bytes(self) -> dict:
        """{tag: bytes} — the quantity the validate-comm gate compares."""
        return {tag: e["bytes"] for tag, e in sorted(self.by_tag.items())}

    def attach(self, t: Transport) -> Transport:
        """Mirror every future ``tally`` of ``t`` (and its ``inner`` chain)
        into this ledger, each under the transport's tag active at tally
        time.  Idempotent per instance; returns ``t`` for chaining."""
        x = t
        while isinstance(x, Transport):
            if id(x) not in self._attached:
                self._attached.add(id(x))
                orig = x.tally  # bound method (class funnel)

                def mirrored(steps, nbytes, _x=x, _orig=orig):
                    _orig(steps, nbytes)
                    self.tally(_x._tag, steps, nbytes)

                x.tally = mirrored
            x = getattr(x, "inner", None)
        return t


def active() -> CommLedger | None:
    return _ACTIVE


def attach(t: Transport) -> Transport:
    """Attach ``t`` to the active ledger (no-op outside a capture)."""
    if _ACTIVE is not None:
        _ACTIVE.attach(t)
    return t


def tally(tag: str | None, steps: int, nbytes: int):
    """Direct tally for transport-less comm sites (the raw psum
    reductions); no-op outside a capture."""
    if _ACTIVE is not None:
        _ACTIVE.tally(tag, steps, nbytes)


def record_plan(tag: str, transport_key: str):
    """Record the tuner's backend choice for a ``plan="auto"`` layer tag;
    no-op outside a capture."""
    if _ACTIVE is not None:
        _ACTIVE.record_plan(tag, transport_key)


@contextmanager
def capture():
    """Activate a fresh ledger for the block; trace the step inside it
    (``jit(...).lower(...)`` runs the Python accounting) and read the
    per-tag totals off the yielded :class:`CommLedger`."""
    global _ACTIVE
    prev = _ACTIVE
    led = CommLedger()
    _ACTIVE = led
    try:
        yield led
    finally:
        _ACTIVE = prev
