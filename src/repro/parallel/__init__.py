"""repro.parallel — channel-native parallel layers (DESIGN.md §12).

The model stack's parallelism, expressed as layers that each own a
:class:`~repro.channels.ChannelSpec`: tensor-parallel linear projections,
the vocab-parallel embedding and cross-entropy, MoE dispatch/combine, the
ring-attention KV ring, DP/FSDP gradient channels, and the pipeline stage
hop — plus the :mod:`~repro.parallel.ledger` that accounts every traced
wire byte per tag for the ``--validate-comm`` contract.
"""

from . import ledger
from .layers import (
    LAYER_TAGS,
    all_reduce,
    column_parallel_linear,
    fsdp_allgather,
    gather_sequence,
    grad_allreduce,
    layer_spec,
    moe_combine,
    moe_dispatch,
    parallel_embedding,
    parallel_embedding_partial,
    pmax_tagged,
    psum_tagged,
    reduce_scatter_sequence,
    ring_attention,
    row_parallel_linear,
    stage_transport,
    vocab_parallel_cross_entropy,
)

__all__ = [
    "LAYER_TAGS",
    "all_reduce",
    "column_parallel_linear",
    "fsdp_allgather",
    "gather_sequence",
    "grad_allreduce",
    "layer_spec",
    "ledger",
    "moe_combine",
    "moe_dispatch",
    "parallel_embedding",
    "parallel_embedding_partial",
    "pmax_tagged",
    "psum_tagged",
    "reduce_scatter_sequence",
    "ring_attention",
    "row_parallel_linear",
    "stage_transport",
    "vocab_parallel_cross_entropy",
]
