"""Calibration + prediction-vs-measurement validation.

The calibration flow (DESIGN.md §6): a benchmark runs a schedule whose
trace-time cost is known exactly — either read from a live
:class:`~repro.transport.base.TransportStats` instance or predicted by
:func:`~repro.netsim.schedule.predict_transport_stats` — and measures wall
seconds.  Each (steps, bytes, seconds) record is one calibration point;
:meth:`LinkModel.fit` turns a set of them into a fitted model, and
:func:`validate` asserts the fitted model predicts every measurement within
a tolerance factor (the ``--validate-sim`` drift gate: if the simulator's
schedule structure stopped matching what actually executes, the fit resid-
uals blow past the gate).
"""

from __future__ import annotations

from .model import LinkModel


def drift_ratio(predicted: float, measured: float) -> float:
    """Symmetric prediction-vs-measurement ratio (1.0 = perfect).

    The single formula behind the ``--validate-sim`` gate AND the
    ``drift/*`` gauges of :mod:`repro.obs.metrics` — factored out so the
    continuously-sampled metric can never disagree with the bench gate.
    """
    pred = max(float(predicted), 1e-12)
    meas = max(float(measured), 1e-12)
    return max(pred / meas, meas / pred)


def record(steps: int, nbytes: float, seconds: float, name: str = ""):
    """One calibration point, in TransportStats' schedule-cost convention."""
    return {
        "steps": int(steps),
        "bytes": float(nbytes),
        "seconds": float(seconds),
        "name": name,
    }


def record_from_stats(stats, seconds: float, name: str = ""):
    """Calibration point straight from a backend's tallied counters
    (delegates to :meth:`TransportStats.record`, the transport-side hook)."""
    return stats.record(seconds, name)


def fit(records, *, base: LinkModel | None = None) -> LinkModel:
    return LinkModel.fit(records, base=base)


def validate(records, *, tol: float = 2.0, label: str = "netsim",
             model: LinkModel | None = None):
    """Fit (unless ``model`` is given) and assert every prediction is within
    ``tol``x of its measurement.  Returns (model, worst_ratio)."""
    records = list(records)
    m = model if model is not None else fit(records)
    worst = 1.0
    lines = []
    for r in records:
        pred = max(m.predict(r), 1e-12)
        meas = max(r["seconds"], 1e-12)
        ratio = drift_ratio(pred, meas)
        worst = max(worst, ratio)
        lines.append(
            f"  {r.get('name', '?'):<32} measured={meas * 1e6:9.1f}us "
            f"predicted={pred * 1e6:9.1f}us ratio={ratio:5.2f}"
        )
    report = "\n".join(lines)
    assert worst <= tol, (
        f"[{label}] simulator/measurement drift: worst ratio {worst:.2f} "
        f"exceeds {tol:.1f}x\n{report}"
    )
    print(f"# [{label}] validate-sim OK: worst prediction ratio "
          f"{worst:.2f}x (<= {tol:.1f}x)\n{report}")
    return m, worst
