"""Cost-model autotuner: sweep the simulator, cache the winning plans.

For each (operation, message size) on a topology the tuner scores every
candidate :class:`Plan` — transport backend x chunk count x collective
algorithm — by replaying its schedule through the link simulator under a
:class:`~repro.netsim.model.LinkModel`, and records the argmin in a
:class:`TuningTable`.  ``Communicator.plan()`` and the ``bcast``/``reduce``
/``allreduce`` dispatchers in ``core/collectives.py`` consult the table by
default, which is what finally turns PR 1's cost counters into decisions.

The "static default" plan (static transport, 1 chunk, ring/chain schedule —
exactly what the un-tuned code paths run) is always in the candidate set,
so the tuner can never select a plan the simulator scores worse than it
(asserted by ``tests/test_netsim.py``).

Tables are cheap to build (pure-python simulation; milliseconds per cell)
and cached per topology signature in-process; :meth:`TuningTable.save` /
:meth:`TuningTable.load` persist them as JSON for offline reuse.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

from ..obs import trace as obs
from .model import LinkModel
from .schedule import (
    HALO_DIRECTIONS,
    collective_rounds,
    compressed_reduce_scatter_rounds,
    halo_pairs,
    halo_rounds,
    p2p_messages,
    packet_bounds,
    packet_n_packets,
    ring_perm_round,
)
from .sim import simulate, simulate_rounds

#: the paper-evaluation sweep grid: 1 KiB .. 16 MiB
SIZE_GRID = tuple(1 << p for p in range(10, 25, 2))

N_CHUNKS_GRID = (1, 2, 4, 8, 16, 32)

#: ``halo`` is the repro/apps stencil's exchange: ``nbytes`` is one halo
#: slab; the schedule shape is fixed (one neighbour permute per direction)
#: so the tuner's decision is which backend moves the slabs
OPS = ("p2p", "bcast", "reduce", "allreduce", "halo")

ALGOS = {
    "p2p": ("routed",),
    "bcast": ("ring", "tree", "staged"),
    "reduce": ("ring", "tree", "staged"),
    "allreduce": ("ring",),
    # one schedule shape; "ring" labels the neighbour-permute rounds and
    # keeps the static default plan inside the candidate set
    "halo": ("ring",),
}

PACKET_ELEMS = 32
PACKET_R = 8


#: wire formats the tuner sweeps: raw f32 links vs int8 compressed links
WIRES = ("raw", "int8")


@dataclass(frozen=True)
class Plan:
    """One tuned decision: which backend moves the bytes, how many chunks
    ride the pipeline, which schedule shape the collective uses, and the
    wire format (``"raw"`` | ``"int8"`` — the compressed-link backend)."""

    transport: str = "static"
    n_chunks: int = 1
    algo: str = "ring"
    wire: str = "raw"

    @property
    def transport_key(self) -> str:
        """Registry key realising this plan's wire format: an ``"int8"``
        wire wraps the inner backend in the compressed-link transport."""
        if self.wire == "raw":
            return self.transport
        return f"compressed:{self.transport}"

    def clamp_chunks(self, leading_dim: int) -> int:
        """Largest divisor of ``leading_dim`` <= the tuned chunk count (the
        collectives require n_chunks | leading dim; the tuned value is a
        hint, never a correctness constraint)."""
        from .model import clamp_chunks

        return clamp_chunks(self.n_chunks, leading_dim)

    def to_dict(self):
        return {"transport": self.transport, "n_chunks": self.n_chunks,
                "algo": self.algo, "wire": self.wire}


DEFAULT_PLAN = Plan("static", 1, "ring")


def score_plan(topo, rt, op: str, nbytes: int, plan: Plan,
               model: LinkModel) -> float:
    """Predicted seconds for ``op`` of ``nbytes`` under ``plan``.

    Static/fused plans replay their schedule through the tick simulator;
    packet plans use the router's static schedule bound (the same
    ``_bounds`` the device path computes) times the per-packet cycle cost
    including the R-stickiness arbitration factor (Tab. 4).  An ``int8``
    wire keeps the tick structure (same schedule, compressed flits) but
    converts ticks through :meth:`LinkModel.hop_time_wire` — serialising
    the compressed bytes and paying the per-hop codec pass, which is what
    keeps compression off the latency-bound cells.
    """
    P = topo.n_ranks
    if P == 1 or nbytes <= 0:
        return 0.0
    # score p2p at the topology's worst case: the farthest rank from 0
    far = max(range(P), key=lambda d: rt.n_hops(0, d))

    if op == "halo":
        # ``nbytes`` = one halo slab; the decomposition grid is the 2D
        # torus's own dims, else a 1 x P line over the linearised ranks
        grid = topo.dims if topo.dims is not None and len(topo.dims) == 2 \
            else (1, P)
        if plan.transport == "packet":
            pkt_bytes = PACKET_ELEMS * 4
            K = packet_n_packets(max(int(nbytes // 4), 1), PACKET_ELEMS)
            total = 0
            for drx, dry, _axis in HALO_DIRECTIONS:
                pairs = halo_pairs(grid, drx, dry)
                if not pairs:
                    continue
                n_steps, _ = packet_bounds(rt, pairs, K,
                                           pkt_elems=PACKET_ELEMS)
                total += n_steps
            return total * model.hop_time(pkt_bytes) * \
                model.injection_cycles(PACKET_R)
        _, _, reports = simulate_rounds(
            topo, rt, halo_rounds(grid, nbytes, nbytes)
        )
        return sum(
            r.ticks * model.hop_time_wire(r.flit_bytes_max, plan.wire)
            for r in reports
        )

    if plan.transport == "packet":
        pkt_bytes = PACKET_ELEMS * 4
        if op in ("p2p", "bcast", "reduce"):
            # the packet backend drives the same logical schedule; cost it
            # as the chain's per-link serialisation of the full message
            pairs, n_rounds = [(0, far)], 1
            per_sender = nbytes
        else:  # allreduce: 2(P-1) identical ring permutes of nbytes/P
            pairs, n_rounds = [(i, (i + 1) % P) for i in range(P)], 2 * (P - 1)
            per_sender = nbytes / P
        K = packet_n_packets(max(int(per_sender // 4), 1), PACKET_ELEMS)
        n_steps, _ = packet_bounds(rt, pairs, K, pkt_elems=PACKET_ELEMS)
        return n_rounds * n_steps * model.hop_time(pkt_bytes) * \
            model.injection_cycles(PACKET_R)

    # static / fused: replay the exact schedule; tick period set by the
    # flit's wire bytes under the plan's wire format
    if op == "p2p":
        rep = simulate(topo, rt, p2p_messages(rt, 0, far, nbytes,
                                              plan.n_chunks))
        return rep.ticks * model.hop_time_wire(rep.flit_bytes_max, plan.wire)
    if op == "allreduce" and plan.wire == "int8":
        # the compressed wire runs the once-quantised-contribution RS
        # (distance-s permutes, real multi-hop cost) + a compressed AG
        rounds = compressed_reduce_scatter_rounds(P, nbytes / P) + [
            ring_perm_round(P, nbytes / P) for _ in range(P - 1)
        ]
    else:
        rounds = collective_rounds(topo, rt, op, plan.algo, nbytes,
                                   n_chunks=plan.n_chunks)
    _, _, reports = simulate_rounds(topo, rt, rounds)
    wire_s = sum(
        r.ticks * model.hop_time_wire(r.flit_bytes_max, plan.wire)
        for r in reports
    )
    # reducing ops fold an accumulate into every schedule tick; the unfused
    # static backend pays the HBM round-trip between permute and add on each
    # of them, the fused backend's receive+accumulate kernel does not
    # (transport/fused.py).  An upper-estimate tick count (every round
    # charged) is fine: it shifts all unfused plans of one schedule equally.
    if op in ("reduce", "allreduce") and plan.transport != "fused":
        wire_s += model.unfused_add_latency * sum(r.ticks for r in reports)
    return wire_s


@dataclass
class TuningTable:
    """op x size -> (best plan, its score, the static default's score)."""

    topo_sig: str
    model: LinkModel
    entries: dict = field(default_factory=dict)  # (op, size) -> dict

    def lookup(self, op: str, nbytes: int) -> Plan:
        """Best plan for the nearest swept size (log-distance)."""
        sizes = sorted({s for (o, s) in self.entries if o == op})
        if not sizes:
            return DEFAULT_PLAN
        nbytes = max(int(nbytes), 1)
        best = min(sizes, key=lambda s: abs(s.bit_length() - nbytes.bit_length()))
        e = self.entries[(op, best)]
        return Plan(e["transport"], e["n_chunks"], e["algo"],
                    e.get("wire", "raw"))

    def score(self, op: str, nbytes: int) -> float:
        e = self.entries[(op, nbytes)]
        return e["score"]

    # -- persistence (the cached tuning-table format of DESIGN.md §6) ------

    def to_json(self) -> str:
        return json.dumps({
            "topo_sig": self.topo_sig,
            "model": {
                "hop_latency": self.model.hop_latency,
                "link_bw": self.model.link_bw,
                "injection_base": self.model.injection_base,
                "switch_cycles": self.model.switch_cycles,
                "quant_latency": self.model.quant_latency,
                "unfused_add_latency": self.model.unfused_add_latency,
            },
            "entries": [
                {"op": op, "nbytes": size, **e}
                for (op, size), e in sorted(self.entries.items())
            ],
        }, indent=1)

    @staticmethod
    def from_json(s: str) -> "TuningTable":
        spec = json.loads(s)
        t = TuningTable(spec["topo_sig"], LinkModel(**spec["model"]))
        for e in spec["entries"]:
            e = dict(e)
            t.entries[(e.pop("op"), e.pop("nbytes"))] = e
        return t

    def save(self, path: str):
        with open(path, "w") as f:
            f.write(self.to_json())

    @staticmethod
    def load(path: str) -> "TuningTable":
        with open(path) as f:
            return TuningTable.from_json(f.read())


def topo_signature(topo, rt=None) -> str:
    """Cache key: the connection graph AND the route table — one topology
    admits different route sets (DOR vs BFS tie-breaks), and plans scored
    against one must not be served to a communicator using the other."""
    sig = topo.to_json()
    if rt is not None:
        sig += "|" + rt.next_hop.tobytes().hex()
    return sig


def autotune(
    topo, rt=None, *,
    ops=OPS, sizes=SIZE_GRID, model: LinkModel | None = None,
    transports=("static", "packet", "fused"), n_chunks_grid=N_CHUNKS_GRID,
    wires=WIRES,
) -> TuningTable:
    """Sweep plans over the (op x size) grid and record the winners.

    The wire dimension (``wires``) is swept for static-schedule plans:
    an ``"int8"`` wire is the compressed-link backend wrapping the same
    schedule.  The raw static default remains in every candidate set, so
    a compressed plan is only ever recorded when the simulator scores it
    strictly better — compression can win bandwidth-bound cells but never
    displaces the default on latency-bound ones.  The fused backend runs
    the identical static schedules but skips the per-tick unfused-add cost
    on reducing ops; ties (ops with no accumulate) keep the static default
    via the strict-< argmin.
    """
    from ..core.routing import compute_route_table  # lazy: keep import light

    if rt is None:
        rt = compute_route_table(topo)
    model = model or LinkModel.default_v5e()
    table = TuningTable(topo_signature(topo, rt), model)
    for op in ops:
        algos = ALGOS[op]
        for size in sizes:
            best = None
            default_score = None
            for tname in transports:
                # wire formats ride static schedules; the packet cost
                # model is packetisation-based, so it scores raw only.
                # The rooted "reduce" op is also excluded: its chain/tree/
                # staged schedules re-quantise the travelling partial sum
                # every hop (no once-quantised form exists for it yet), so
                # an int8 plan there would compound error with P — the
                # exact failure the compressed reduce-scatter schedule
                # avoids (DESIGN.md §7).  "halo" is excluded too: the apps
                # layer diffs distributed against single-rank results
                # exactly, so a lossy wire there is an explicit user
                # choice (comm_mode="smi:compressed"), never a tuned one
                wire_grid = wires if tname == "static" \
                    and op not in ("reduce", "halo") else ("raw",)
                for wire in wire_grid:
                    for algo in algos:
                        chunk_grid = n_chunks_grid
                        if tname == "packet" or algo in ("tree", "staged") \
                                or op in ("allreduce", "halo"):
                            # whole-message rounds / router packetisation /
                            # ring RS+AG / single-hop halo permutes:
                            # chunking cannot change the schedule
                            chunk_grid = (1,)
                        for nc in chunk_grid:
                            plan = Plan(tname, nc, algo, wire)
                            s = score_plan(topo, rt, op, size, plan, model)
                            if plan == DEFAULT_PLAN or (
                                op == "p2p"
                                and plan == Plan("static", 1, "routed")
                            ):
                                default_score = s
                            if best is None or s < best[1]:
                                best = (plan, s)
            plan, s = best
            assert default_score is not None, "default plan must be swept"
            # invariant: argmin over a set containing the default
            assert s <= default_score + 1e-18
            table.entries[(op, size)] = {
                **plan.to_dict(), "score": s, "static_score": default_score,
            }
            if obs.TRACING:
                obs.emit("tuner.plan", tag=op, nbytes=int(size),
                         topology=topo.name, score=s,
                         static_score=default_score, **plan.to_dict())
    return table


# ---------------------------------------------------------------------------
# in-process table cache — what Communicator / the dispatchers consult
# ---------------------------------------------------------------------------

_TABLES: dict = {}


def tuning_table_for(topo, rt=None, model: LinkModel | None = None) -> TuningTable:
    sig = topo_signature(topo, rt)
    if sig not in _TABLES:
        _TABLES[sig] = autotune(topo, rt, model=model)
    return _TABLES[sig]


def tuned_plan(op: str, comm, nbytes: int) -> Plan:
    """The table-backed decision point used by the core dispatchers."""
    table = tuning_table_for(comm.topology, comm.route_table)
    return table.lookup(op, nbytes)


def clear_cache():
    _TABLES.clear()
