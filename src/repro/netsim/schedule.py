"""Message schedules for the simulator, mirroring the real transports.

Two jobs:

1. **Schedule builders** — turn a logical operation (routed p2p, ring
   shift, a collective under a given algorithm) into the :class:`~repro.
   netsim.sim.Message` rounds the simulator replays.  The builders encode
   the *same* schedules ``transport/static.py`` and ``core/collectives.py``
   trace, so simulated tick counts are the schedule's step counts, not an
   approximation of them.

2. **predict_transport_stats** — the exact trace-time accounting a backend
   would tally into :class:`~repro.transport.base.TransportStats` for an
   operation (steps and wire bytes, per rank).  For the static backend this
   is the simulator's tick count; for the packet backend it is the router's
   static worst-case schedule bound, obtained from the *same*
   ``PacketTransport._bounds`` code the device path runs (no parallel
   formula to drift).  ``tests/test_netsim.py`` asserts equality against
   real traced runs on ring, torus and snake-bus.
"""

from __future__ import annotations

import math
from types import SimpleNamespace

from .sim import Message, simulate, simulate_rounds


def _dtype_size(dtype) -> int:
    import numpy as np

    return np.dtype(dtype).itemsize


# ---------------------------------------------------------------------------
# schedule builders
# ---------------------------------------------------------------------------


def p2p_messages(rt, src: int, dst: int, nbytes: float, n_chunks: int = 1):
    """The static transport's chunk-pipelined routed transfer."""
    n_chunks = max(int(n_chunks), 1)
    return [
        Message(
            src, dst, n_flits=n_chunks, flit_bytes=nbytes / n_chunks,
            pipelined=True,
        )
    ]


def ring_perm_round(n_ranks: int, nbytes: float, step: int = 1):
    """One logical ring-permute round: every rank forwards its buffer to
    the rank ``step`` positions along the linearised order.  Routed through
    the route table, so a logical hop that is not a physical link (the
    wrap-around edge on a bus, a distance-``s`` shift anywhere) costs its
    real multi-hop path — exactly what the physical fabric pays."""
    return [
        Message(i, (i + step) % n_ranks, n_flits=1, flit_bytes=nbytes)
        for i in range(n_ranks)
    ]


def compressed_reduce_scatter_rounds(n_ranks: int, nbytes: float):
    """The once-quantised contribution schedule the compressed wire's ring
    reduce-scatter executes (DESIGN.md §7): round ``s`` ships every rank's
    block contribution a logical distance ``s`` — charged its real routed
    multi-hop cost, which is how the tuner sees that this schedule trades
    byte-hops for P-independent quantisation error."""
    return [
        ring_perm_round(n_ranks, nbytes, step=s) for s in range(1, n_ranks)
    ]


def _expand_chain(rt, order):
    """Route-expand a logical chain: each consecutive pair of the rank
    ``order`` is replaced by its full routed path, so a logical hop that is
    not a physical link costs its real multi-hop traversal (e.g. the wrap
    edge of a linearised ring on a bus, or rank-order chains on a snake)."""
    path = [order[0]]
    for a, b in zip(order[:-1], order[1:]):
        path.extend(rt.path(a, b)[1:])
    return path


def _chain_paths(topo, rt, root: int):
    """Chain path(s) for the pipelined rooted collectives: one wrap-around
    ring chain on tori, an up+down pair on line topologies (the schedule
    ``core/collectives.py`` runs), route-expanded onto physical links."""
    P = topo.n_ranks
    if topo.dims is not None:
        order = [[(root + i) % P for i in range(P)]]
    else:
        order = [p for p in (list(range(root, P)), list(range(root, -1, -1)))
                 if len(p) >= 2]
    return [_expand_chain(rt, o) for o in order]


def collective_rounds(
    topo, rt, op: str, algo: str, nbytes: float, *,
    n_chunks: int = 1, root: int = 0,
):
    """Barrier-separated message rounds for ``op`` under ``algo``.

    ops: ``bcast`` / ``reduce`` (rooted), ``allgather``, ``allreduce``.
    algos: ``ring`` (the pipelined chain / ring schedule — the repo's
    default), ``tree`` (binomial rounds), ``staged`` (serial whole-message
    sends, the host-staged baseline).
    """
    P = topo.n_ranks
    n_chunks = max(int(n_chunks), 1)
    if P == 1:
        return []

    if op in ("bcast", "reduce"):
        if algo == "ring":
            # pipelined chain: n_chunks flits streamed along the chain(s);
            # reduce runs the same schedule in reverse (same cost)
            rounds = [[]]
            for path in _chain_paths(topo, rt, root):
                p = path if op == "bcast" else list(reversed(path))
                rounds[0].append(
                    Message(p[0], p[-1], n_flits=n_chunks,
                            flit_bytes=nbytes / n_chunks, path=p)
                )
            return rounds
        if algo == "tree":
            rounds = []
            h = 1
            while h < P:
                msgs = []
                for i in range(h):
                    if i + h >= P:
                        continue
                    a, b = (root + i) % P, (root + i + h) % P
                    if op == "reduce":
                        a, b = b, a
                    msgs.append(Message(a, b, n_flits=1, flit_bytes=nbytes))
                rounds.append(msgs)
                h <<= 1
            return rounds if op == "bcast" else list(reversed(rounds))
        if algo == "staged":
            # serial whole-message sends, one destination at a time
            rounds = []
            for d in range(1, P):
                peer = (root + d) % P
                a, b = (root, peer) if op == "bcast" else (peer, root)
                rounds.append(
                    [Message(a, b, n_flits=1, flit_bytes=nbytes,
                             pipelined=False)]
                )
            return rounds
        raise ValueError(f"unknown {op} algorithm {algo!r}")

    if op == "allgather":
        return [ring_perm_round(P, nbytes) for _ in range(P - 1)]
    if op == "reduce_scatter":
        return [ring_perm_round(P, nbytes / P) for _ in range(P - 1)]
    if op == "allreduce":
        # ring RS + AG of nbytes/P blocks — the streaming all-reduce schedule
        return [ring_perm_round(P, nbytes / P) for _ in range(2 * (P - 1))]
    raise ValueError(f"unknown collective op {op!r}")


# ---------------------------------------------------------------------------
# halo-exchange schedules (the repro/apps stencil's communication phase)
# ---------------------------------------------------------------------------


def halo_pairs(grid, drx: int, dry: int):
    """(src, dst) pairs of one halo direction: the fixed neighbour wiring
    both the traced exchange (``core/overlap.py`` re-exports this as
    ``halo_perm``) and the simulator replay.  Lives here, jax-free, so
    netsim stays importable before jax initialises and the two sides can
    never drift."""
    RX, RY = grid
    pairs = []
    for s in range(RX * RY):
        sx, sy = s // RY, s % RY
        tx, ty = sx + drx, sy + dry
        if 0 <= tx < RX and 0 <= ty < RY:
            pairs.append((s, tx * RY + ty))
    return pairs


#: the four halo directions in trace order: (drx, dry, slab_axis) where
#: slab_axis 0 = an N/S row slab, 1 = an E/W column slab
HALO_DIRECTIONS = ((-1, 0, 0), (+1, 0, 0), (0, -1, 1), (0, +1, 1))


def halo_slab_elems(shape, halo=(1, 1)) -> tuple[int, int]:
    """(ns_elems, ew_elems): element counts of one N/S row slab and one E/W
    column slab of a per-rank tile ``shape`` = (Nx, Ny, ...)."""
    import numpy as np

    hx, hy = halo
    trail = int(np.prod(shape[2:])) if len(shape) > 2 else 1
    return hx * shape[1] * trail, shape[0] * hy * trail


def halo_rounds(grid, ns_bytes: float, ew_bytes: float):
    """Barrier-separated message rounds of the 2D halo exchange: one round
    per non-empty direction (N, S, W, E), each a neighbour permute carrying
    the direction's slab.  The simulator route-expands each pair through
    the route table, so a grid laid over a non-matching topology pays its
    real multi-hop cost."""
    rounds = []
    for drx, dry, axis in HALO_DIRECTIONS:
        pairs = halo_pairs(grid, drx, dry)
        if not pairs:
            continue
        nbytes = ns_bytes if axis == 0 else ew_bytes
        rounds.append(
            [Message(s, d, n_flits=1, flit_bytes=nbytes) for s, d in pairs]
        )
    return rounds


def predict_halo_stats(
    comm, *, grid, shape, dtype="float32", halo=(1, 1),
    transport: str = "static", pkt_elems: int = 32, slack_steps: int = 4,
    axis_elems: int | None = None,
):
    """Exact (steps, bytes) a fresh backend tallies for one halo exchange
    (``repro.core.overlap.halo_exchange_2d_start``): one permute per
    non-empty direction; the compressed wire carries the int8 payload +
    scale sidecar; the packet backend pays its static router bound per
    direction.  Asserted against traced ``stats.by_tag["halo"]`` counters
    in tests/test_apps.py."""
    from .model import WIRE_AXIS_ELEMS, int8_wire_nbytes

    ns_elems, ew_elems = halo_slab_elems(shape, halo)
    esz = _dtype_size(dtype)
    rt = comm.route_table
    steps = 0
    nbytes = 0
    for drx, dry, axis in HALO_DIRECTIONS:
        pairs = halo_pairs(grid, drx, dry)
        if not pairs:
            continue
        elems = ns_elems if axis == 0 else ew_elems
        if transport in ("compressed", "compressed:static"):
            wire = int8_wire_nbytes(
                elems, WIRE_AXIS_ELEMS if axis_elems is None else axis_elems
            )
            steps += 1
            nbytes += wire
        elif transport in ("static", "fused"):
            steps += 1
            nbytes += elems * esz
        elif transport == "packet":
            K = packet_n_packets(elems, pkt_elems)
            n_steps, _ = packet_bounds(
                rt, pairs, K, pkt_elems=pkt_elems, slack_steps=slack_steps
            )
            steps += n_steps
            nbytes += elems * esz
        else:
            raise ValueError(f"no halo stats model for transport {transport!r}")
    return steps, nbytes


def predict_halo_time(
    comm, *, grid, shape, dtype="float32", halo=(1, 1), model=None,
    wire: str = "raw",
):
    """Predicted seconds of one halo exchange under a
    :class:`~repro.netsim.model.LinkModel`: replay the direction rounds
    through the tick simulator and convert ticks through the wire-aware
    hop time — the model column of benchmarks/stencil_bench.py."""
    from .model import LinkModel

    model = model or LinkModel.default_v5e()
    ns_elems, ew_elems = halo_slab_elems(shape, halo)
    esz = _dtype_size(dtype)
    rounds = halo_rounds(grid, ns_elems * esz, ew_elems * esz)
    _, _, reports = simulate_rounds(comm.topology, comm.route_table, rounds)
    return sum(
        r.ticks * model.hop_time_wire(r.flit_bytes_max, wire) for r in reports
    )


# ---------------------------------------------------------------------------
# packet-backend schedule bounds (shared with the device path)
# ---------------------------------------------------------------------------


def packet_bounds(rt, pairs, n_packets: int, *, pkt_elems: int = 32,
                  slack_steps: int = 4, transit_cap: int | None = None):
    """(n_steps, transit_cap) for a packet-routed permutation — computed by
    ``PacketTransport._bounds`` itself so the simulator can never drift from
    the schedule the device actually runs."""
    from ..transport.packet import PacketTransport  # lazy: imports jax

    tp = PacketTransport(
        pkt_elems=pkt_elems, slack_steps=slack_steps, transit_cap=transit_cap
    )
    shim = SimpleNamespace(route_table=rt, size=rt.topo.n_ranks)
    active = [(s, d) for s, d in pairs if s != d]
    return tp._bounds(shim, active, n_packets)


def packet_n_packets(n_elems: int, pkt_elems: int = 32) -> int:
    """Packets per sender for an ``n_elems``-element wire vector (the f32
    wire format of ``transport/packet.py``)."""
    return -(-int(n_elems) // int(pkt_elems))


# ---------------------------------------------------------------------------
# exact TransportStats prediction
# ---------------------------------------------------------------------------


def predict_transport_stats(
    comm, op: str, *, shape, dtype="float32", transport: str = "static",
    src: int = 0, dst: int = 0, n_chunks: int = 1,
    pkt_elems: int = 32, slack_steps: int = 4, axis_elems: int | None = None,
):
    """Exact (steps, bytes_moved) a fresh backend instance tallies for one
    operation — the numbers ``Transport.stats`` holds after tracing.

    ops: ``p2p`` (uses src/dst/n_chunks), ``shift`` (one ring step),
    ``allgather`` (P-1 shifts of the local shard).  ``shape`` is the
    per-rank array shape.  ``transport="compressed"`` (static inner)
    predicts the int8 wire's exact byte count — payload plus the bitcast
    scale sidecar of ``axis_elems``-sized blocks (None = the transport's
    default), the same :func:`repro.netsim.model.int8_wire_nbytes` figure
    the traced backend accounts.
    """
    import numpy as np

    from .model import WIRE_AXIS_ELEMS, clamp_chunks, int8_wire_nbytes

    elems = int(np.prod(shape)) if shape else 1
    nbytes = elems * _dtype_size(dtype)
    topo, rt = comm.topology, comm.route_table

    if transport in ("compressed", "compressed:static"):
        # the compressed wire is one flat int8 vector per leaf; the static
        # inner backend then moves (and accounts) exactly those bytes
        W = int8_wire_nbytes(
            elems, WIRE_AXIS_ELEMS if axis_elems is None else axis_elems
        )
        if op == "p2p":
            if src == dst:
                return 0, 0
            nc = clamp_chunks(n_chunks, W)
            rep = simulate(topo, rt, p2p_messages(rt, src, dst, W, nc))
            return rep.ticks, (W // nc) * rep.ticks
        if op == "shift":
            rep = simulate(topo, rt, ring_perm_round(comm.size, W))
            return rep.ticks, W * rep.ticks
        if op == "allgather":
            ticks, _, _ = simulate_rounds(
                topo, rt, collective_rounds(topo, rt, "allgather", "ring", W)
            )
            return ticks, W * ticks
        raise ValueError(f"unknown op {op!r}")

    if transport == "static":
        if op == "p2p":
            if src == dst:
                return 0, 0
            rep = simulate(topo, rt, p2p_messages(rt, src, dst, nbytes, n_chunks))
            # the backend accounts chunk_bytes per tick (wire bytes per rank
            # per step, the schedule-cost convention of TransportStats)
            csz_bytes = nbytes // max(int(n_chunks), 1)
            return rep.ticks, csz_bytes * rep.ticks
        if op == "shift":
            rep = simulate(topo, rt, ring_perm_round(comm.size, nbytes))
            return rep.ticks, nbytes * rep.ticks
        if op == "allgather":
            ticks, _, _ = simulate_rounds(
                topo, rt, collective_rounds(topo, rt, "allgather", "ring", nbytes)
            )
            return ticks, nbytes * ticks
        raise ValueError(f"unknown op {op!r}")

    if transport == "packet":
        if op == "p2p":
            if src == dst:
                return 0, 0
            K = packet_n_packets(elems, pkt_elems)
            n_steps, _ = packet_bounds(
                rt, [(src, dst)], K,
                pkt_elems=pkt_elems, slack_steps=slack_steps,
            )
            return n_steps, nbytes
        if op == "shift":
            K = packet_n_packets(elems, pkt_elems)
            pairs = [(i, (i + 1) % comm.size) for i in range(comm.size)]
            n_steps, _ = packet_bounds(
                rt, pairs, K, pkt_elems=pkt_elems, slack_steps=slack_steps
            )
            return n_steps, nbytes
        raise ValueError(f"unknown op {op!r}")

    raise ValueError(f"no stats model for transport {transport!r}")


# ---------------------------------------------------------------------------
# whole-training-step prediction (per channel tag)
# ---------------------------------------------------------------------------


def _shift_cost(leaves, key, *, pkt_elems=32, slack_steps=4):
    """Exact (steps, wire_bytes) ONE ring shift (hop distance 1) of a pytree
    payload tallies, per backend family.  ``leaves``: [(elems, itemsize,
    is_float)].  Mirrors the transports' trace accounting: static/fused move
    the raw bytes in one step; the compressed link re-wires float leaves as
    int8 + scale sidecar; the packet router's schedule bound is
    ``hops + n_packets + slack`` over the flattened f32 wire."""
    from .model import WIRE_AXIS_ELEMS, int8_wire_nbytes

    raw = sum(n * sz for n, sz, _ in leaves)
    fam, _, inner = key.partition(":")
    if fam == "compressed":
        wire = sum(
            int8_wire_nbytes(n, WIRE_AXIS_ELEMS) if fl else n * sz
            for n, sz, fl in leaves
        )
        if inner == "packet":
            k = packet_n_packets(-(-wire // 4), pkt_elems)
            return 1 + k + slack_steps, wire
        return 1, wire
    if fam == "packet":
        k = packet_n_packets(-(-raw // 4), pkt_elems)
        return 1 + k + slack_steps, raw
    return 1, raw


def predict_train_step_stats(cfg, mesh_shape, shape, settings, *,
                             pkt_elems=32, slack_steps=4):
    """Per-tag predicted channel traffic of ONE traced training step —
    forward + backward + FSDP gather + gradient sync — as the channel
    ledger (:mod:`repro.parallel.ledger`) measures it.

    ``cfg`` is the arch config, ``mesh_shape`` is ``(dp, tp)``, ``shape``
    a ShapeConfig (seq_len / global_batch), ``settings`` duck-types
    TrainSettings (comm_mode, fsdp, loss_chunks, shared_gather, ring_attn,
    compressed_grads).  Returns ``{tag: {"steps": int, "bytes": int}}``.

    The contract (DESIGN.md §12) is byte-exactness against a traced
    ``launch/train --validate-comm`` run: the sum of per-tag channel
    predictions here must equal the ledger's ``tag_bytes()`` to the byte.
    Counts are therefore *trace* counts — a ``lax.scan`` over layer periods
    traces its body once, so per-block channels count once per traced
    period position (the ledger's documented rolled-loop semantics), not
    once per layer.  AD-transposed collectives mirror their forward
    channel and are accounted there by both sides."""
    from ..transport import resolve_comm_mode

    dp, tp = int(mesh_shape[0]), int(mesh_shape[1])
    base_mode, key = resolve_comm_mode(settings.comm_mode)
    if base_mode != "smi":
        raise ValueError(
            f"predict_train_step_stats models smi comm modes; got "
            f"{settings.comm_mode!r}"
        )
    esz = 2 if cfg.dtype == "bfloat16" else 4
    B = shape.global_batch // dp
    S = shape.seq_len
    S_loc = S // tp if tp > 1 else S
    rows = B * S_loc
    D = cfg.d_model
    shared = bool(getattr(settings, "shared_gather", False))

    acc: dict = {}

    def add(tag, steps, nbytes):
        e = acc.setdefault(tag, {"steps": 0, "bytes": 0})
        e["steps"] += int(steps)
        e["bytes"] += int(nbytes)

    def ring(tag, leaves, P, n_shifts=None, tkey=key):
        if P <= 1:
            return
        ns = (P - 1) if n_shifts is None else n_shifts
        s, b = _shift_cost(leaves, tkey, pkt_elems=pkt_elems,
                           slack_steps=slack_steps)
        add(tag, s * ns, b * ns)

    def psum(tag, nbytes, n=1):
        if tp > 1:
            add(tag, n, nbytes * n)

    act = lambda elems: [(int(elems), esz, True)]  # noqa: E731

    # ---- forward activations: embed -> traced block positions -> loss
    if tp > 1:
        ring("tp.embed", act(rows * D), tp)

    period = len(cfg.pattern)
    n_full = cfg.n_layers // period
    rem = cfg.n_layers % period
    traced = (list(cfg.pattern) if n_full > 0 else []) + list(cfg.pattern[:rem])

    for kind in traced:
        if tp <= 1:
            break
        if kind in ("attn", "moe"):
            if getattr(settings, "ring_attn", False):
                hd = cfg.hd
                Hp = -(-cfg.n_heads // tp) * tp
                ring("tp.attn.qkv", act(D * Hp * hd // tp), tp)
                if cfg.qkv_bias:
                    ring("tp.attn.qkv", act(Hp * hd // tp), tp)
                ring("tp.attn.out", act(Hp * hd // tp * D), tp)
                kv = B * S_loc * cfg.n_kv_heads * hd
                ring("tp.attn.ring", act(kv) + act(kv), tp)
            else:
                ring("tp.attn.qkv", act(rows * D), tp)
                if not shared:
                    ring("tp.attn.kv", act(rows * D), tp)
                ring("tp.attn.out", act(rows * D), tp)
        if kind == "attn" or (kind == "moe" and cfg.shared_expert):
            n_up = 1 if (cfg.mlp_type != "swiglu" or shared) else 2
            ring("tp.mlp.up", act(rows * D), tp, n_shifts=n_up * (tp - 1))
            ring("tp.mlp.down", act(rows * D), tp)
        if kind == "moe":
            ring("ep.dispatch", act(rows * D), tp)
            ring("ep.combine", act(rows * D), tp)
        if kind == "ssm":
            n_in = 1 if shared else 2
            ring("ssm.in", act(rows * D), tp, n_shifts=n_in * (tp - 1))
            if not shared:
                ring("ssm.gather", act(rows * D), tp)
            ring("ssm.out", act(rows * D), tp)
        if kind == "rec":
            n_in = 1 if shared else 2
            ring("ssm.in", act(rows * D), tp, n_shifts=n_in * (tp - 1))
            ring("ssm.out", act(rows * D), tp)

    lc = int(getattr(settings, "loss_chunks", 1))
    csz = S_loc // lc
    n_tables = cfg.n_codebooks if cfg.n_codebooks > 1 else 1
    if tp > 1:
        for _ in range(lc):
            ring("tp.loss.gather", act(B * csz * D), tp)
            psum("tp.loss.ce", B * tp * csz * 4, n=3 * n_tables)

    # ---- FSDP param gather + gradient sync over the data ring
    if getattr(settings, "fsdp", False) and dp > 1:
        gathered, grad_rings = _fsdp_leaf_walk(cfg, dp, tp, n_full)
        for loc_elems in gathered:
            ring("fsdp.gather", act(loc_elems), dp)
        gkey = key if not getattr(settings, "compressed_grads", False) else (
            key if key.partition(":")[0] == "compressed"
            else f"compressed:{key}"
        )
        for loc_elems in grad_rings:
            m = -(-loc_elems // dp)  # padded ring chunk
            ring("grad", [(m, 4, True)], dp, n_shifts=2 * (dp - 1), tkey=gkey)

    return {t: acc[t] for t in sorted(acc)}


def predict_decode_step_stats(cfg, mesh_shape, batch_slots, settings, *,
                              capacity=128, migrations=0, prefix="serve.",
                              pkt_elems=32, slack_steps=4):
    """Per-tag predicted channel traffic of ONE traced serving decode step
    (``lm_decode_step`` with ``gather_logits=False``, as lowered by
    ``launch.steps.build_continuous_serve`` / ``build_serve``), plus
    ``migrations`` optional slot migrations, as the channel ledger
    measures it.

    Same contract as :func:`predict_train_step_stats` (DESIGN.md §12):
    byte-exact against a traced ``launch/serve --validate-comm`` run.
    Tags carry the serving pool's ``prefix`` (default ``"serve."``).
    ``settings`` duck-types comm_mode; ``mesh_shape`` is ``(dp, tp)`` —
    serving replicates slots over the data axes, so only ``tp`` moves
    bytes.  Migration always rides the static schedule on a raw wire
    (the slot image is reinterpreted bytes), whatever the layer backend.
    """
    from ..transport import resolve_comm_mode

    tp = int(mesh_shape[1])
    base_mode, key = resolve_comm_mode(settings.comm_mode)
    if base_mode != "smi":
        raise ValueError(
            f"predict_decode_step_stats models smi comm modes; got "
            f"{settings.comm_mode!r}"
        )
    esz = 2 if cfg.dtype == "bfloat16" else 4
    B = int(batch_slots)
    D = cfg.d_model

    acc: dict = {}

    def add(tag, steps, nbytes):
        e = acc.setdefault(prefix + tag, {"steps": 0, "bytes": 0})
        e["steps"] += int(steps)
        e["bytes"] += int(nbytes)

    def ring(tag, leaves, P, n_shifts=None, tkey=key):
        if P <= 1:
            return
        ns = (P - 1) if n_shifts is None else n_shifts
        s, b = _shift_cost(leaves, tkey, pkt_elems=pkt_elems,
                           slack_steps=slack_steps)
        add(tag, s * ns, b * ns)

    def psum(tag, nbytes, n=1):
        if tp > 1:
            add(tag, n, nbytes * n)

    def allreduce(tag, elems, itemsize=None):
        # _stream_allreduce_impl: pad to a tp multiple, RS + AG =
        # 2*(tp-1) shifts of the padded ring chunk
        m = -(-int(elems) // tp)
        ring(tag, [(m, esz if itemsize is None else itemsize, True)], tp,
             n_shifts=2 * (tp - 1))

    act = lambda elems: [(int(elems), esz, True)]  # noqa: E731

    # ---- embed: one partial-sum tally of the (B, D) embedding
    psum("tp.embed", B * D * esz)

    period = len(cfg.pattern)
    n_full = cfg.n_layers // period
    rem = cfg.n_layers % period
    traced = (list(cfg.pattern) if n_full > 0 else []) + list(cfg.pattern[:rem])

    hd = cfg.hd
    Hp = -(-cfg.n_heads // tp) * tp

    for kind in traced:
        if tp <= 1:
            break
        if kind in ("attn", "moe"):
            # query-head gather (1, B, H_loc*hd) + the four softmax /
            # out-proj partial-sum tallies (m, l f32; o f32; y act-dtype)
            ring("tp.attn.qkv", act(B * Hp * hd // tp), tp)
            psum("tp.attn.out", B * Hp * 4)
            psum("tp.attn.out", B * Hp * 4)
            psum("tp.attn.out", B * Hp * hd * 4)
            psum("tp.attn.out", B * D * esz)
        if kind == "attn" or (kind == "moe" and cfg.shared_expert):
            allreduce("tp.mlp.down", B * D)
        if kind == "moe":
            allreduce("ep.combine", B * D)
        if kind == "ssm":
            allreduce("ssm.out", B * D)
        if kind == "rec":
            allreduce("ssm.out", B * D)
            allreduce("tp.mlp.down", B * D)

    # ---- slot migrations: gather + scatter leg, (1, N) uint8 image per
    # shift, static/raw pinned (lossless, backend-insensitive)
    if migrations and tp > 1:
        import jax

        from ..core.comm import Communicator
        from ..mesh.api import ParallelCtx
        from ..models import lm_caches
        from ..serving.continuous import slot_nbytes

        comm = Communicator.create("model", (tp,), name="tp_model")
        ctx = ParallelCtx(model_axis="model", batch_axes=(),
                          model_comm=comm, comm_mode="smi")
        shapes = jax.eval_shape(
            lambda: lm_caches(cfg, B, capacity=capacity, ctx=ctx)
        )
        n = slot_nbytes(shapes)
        ring("migrate", [(n, 1, False)], tp,
             n_shifts=2 * (tp - 1) * int(migrations), tkey="static")

    return {t: acc[t] for t in sorted(acc)}


def _fsdp_leaf_walk(cfg, dp, tp, n_full):
    """Local element counts for the FSDP plan's leaves: (gathered, rings).

    ``gathered`` lists, once per traced gather site, the per-shift payload
    elems of every dim>=0 leaf (model-sharded, /n_full for scan-sliced
    period leaves, /dp for the FSDP shard).  ``rings`` lists the full local
    elems of dim<0 leaves, which the gradient sync all-reduces over a
    tagged ``"grad"`` channel."""
    import jax
    import numpy as np
    from jax.tree_util import tree_flatten_with_path

    from ..core.comm import Communicator
    from ..mesh.api import ParallelCtx, fsdp_dim_for
    from ..models.model import init_lm, lm_specs

    comm = (
        Communicator.create("model", (tp,), name="tp_model")
        if tp > 1 else None
    )
    ctx = ParallelCtx(model_axis="model", batch_axes=("data",),
                      model_comm=comm, comm_mode="smi")
    shapes = jax.eval_shape(lambda: init_lm(jax.random.PRNGKey(0), cfg, ctx))
    specs = lm_specs(cfg, ctx)
    sh_leaves, _ = tree_flatten_with_path(shapes)
    sp_leaves, _ = tree_flatten_with_path(specs)

    gathered, rings = [], []
    for (path, sh), (_, sp) in zip(sh_leaves, sp_leaves):
        stacked = any(getattr(k, "key", None) == "periods" for k in path)
        dim = fsdp_dim_for(sh.shape, sp, dp, skip_dim0=stacked)
        tp_div = 1
        for d in tuple(sp):
            if d is not None:
                tp_div *= tp
        loc = int(np.prod(sh.shape)) // tp_div
        if dim < 0:
            rings.append(loc)
        else:
            gathered.append(loc // (n_full if stacked else 1) // dp)
    return gathered, rings


def predict_channel_stats(spec, *, shape, dtype="float32", n_chunks=None,
                          **kw):
    """Exact (steps, bytes_moved) one whole-message ``transfer`` of
    ``shape`` over a p2p channel tallies into its backend's stats —
    and, because every channel step is accounted under the channel's
    :attr:`~repro.channels.ChannelSpec.stats_tag`, the numbers
    ``stats.tag_counts(spec.stats_tag)`` holds after tracing.

    ``spec`` is a :class:`~repro.channels.ChannelSpec` (duck-typed: any
    object with ``comm`` / ``kind`` / ``src`` / ``dst`` / ``transport_key``
    / ``n_chunks`` attributes works, keeping this module jax-free).  The
    channel's transport key selects the stats model — ``"static"`` /
    ``"fused"`` (same wire), ``"packet"`` (router schedule bounds), or the
    int8 compressed link (``"compressed"`` over a static inner) — exactly
    the backends :func:`predict_transport_stats` covers.
    """
    assert spec.kind == "p2p", (
        f"channel-stats prediction covers p2p channels; got {spec.kind!r}"
    )
    key = spec.transport_key
    if key == "fused":
        key = "static"  # identical permute schedule and wire accounting
    elif key == "compressed:fused":
        key = "compressed:static"  # same aliasing under the int8 wire
    nc = n_chunks if n_chunks is not None else spec.n_chunks
    return predict_transport_stats(
        spec.comm, "p2p", shape=shape, dtype=dtype, transport=key,
        src=spec.src, dst=spec.dst, n_chunks=nc, **kw,
    )
