"""netsim: link-level network simulation + cost-model autotuning.

The paper's evaluation is a performance model made measurable — latency vs
hops (Tab. 3), injection rate vs polling stickiness R (Tab. 4), bandwidth
vs frame size (Fig. 9).  This subsystem is that model made *executable*:

* :mod:`~repro.netsim.model` — :class:`LinkModel`, the analytic per-link
  cost model every benchmark/roofline column derives from;
* :mod:`~repro.netsim.sim` — a tick-based link-level simulator replaying
  message schedules over any Topology + RouteTable with FIFO depths,
  R-sticky arbitration and backpressure;
* :mod:`~repro.netsim.schedule` — schedule builders mirroring the real
  transports, plus exact :class:`TransportStats` prediction;
* :mod:`~repro.netsim.calibrate` — fit a LinkModel from measured runs and
  gate simulator/measurement drift (``benchmarks/run.py --validate-sim``);
* :mod:`~repro.netsim.tune` — the autotuner producing cached
  :class:`TuningTable` s that ``Communicator``/collectives consult.

See DESIGN.md §6 for the subsystem contract.
"""

from .model import LinkModel, WIRE_AXIS_ELEMS, int8_wire_nbytes
from .sim import Message, SimReport, simulate, simulate_rounds
from .schedule import (
    HALO_DIRECTIONS,
    collective_rounds,
    compressed_reduce_scatter_rounds,
    halo_pairs,
    halo_rounds,
    halo_slab_elems,
    p2p_messages,
    packet_bounds,
    packet_n_packets,
    predict_channel_stats,
    predict_decode_step_stats,
    predict_halo_stats,
    predict_halo_time,
    predict_train_step_stats,
    predict_transport_stats,
    ring_perm_round,
)
from .calibrate import fit, record, record_from_stats, validate
from .tune import (
    DEFAULT_PLAN,
    Plan,
    SIZE_GRID,
    WIRES,
    TuningTable,
    autotune,
    score_plan,
    tuned_plan,
    tuning_table_for,
)

__all__ = [
    "LinkModel",
    "WIRE_AXIS_ELEMS",
    "int8_wire_nbytes",
    "Message",
    "SimReport",
    "simulate",
    "simulate_rounds",
    "HALO_DIRECTIONS",
    "collective_rounds",
    "compressed_reduce_scatter_rounds",
    "halo_pairs",
    "halo_rounds",
    "halo_slab_elems",
    "p2p_messages",
    "packet_bounds",
    "packet_n_packets",
    "predict_channel_stats",
    "predict_decode_step_stats",
    "predict_halo_stats",
    "predict_halo_time",
    "predict_train_step_stats",
    "predict_transport_stats",
    "ring_perm_round",
    "fit",
    "record",
    "record_from_stats",
    "validate",
    "DEFAULT_PLAN",
    "Plan",
    "SIZE_GRID",
    "WIRES",
    "TuningTable",
    "autotune",
    "score_plan",
    "tuned_plan",
    "tuning_table_for",
]
