"""Link-level discrete-event simulator for message schedules.

Replays a set of :class:`Message` flows over a :class:`~repro.core.topology.
Topology` + :class:`~repro.core.routing.RouteTable` under the same structural
rules the transports implement on the device mesh:

* one flit (chunk / packet) per directed link per tick — the fixed link
  schedule of the compiled executable;
* store-and-forward: a flit arriving at an intermediate rank departs on its
  next link no earlier than the following tick;
* per-link arbitration among the input FIFOs wanting that link, with the
  router's transit-priority, R-sticky polling and optional switch-bubble
  semantics (``core/router.py`` §4.3);
* bounded transit FIFOs with backpressure: a flit only traverses a link
  when the downstream queue has room (stalls are counted, never dropped —
  the schedule bound provers in ``transport/packet.py`` handle the lossy
  regime).

The simulator works in abstract *ticks*; :class:`SimReport` converts to
seconds through a :class:`~repro.netsim.model.LinkModel`.  For an
uncontended routed transfer the tick count reproduces the static
transport's schedule exactly (``n_chunks + hops - 1``), which is what lets
``tests/test_netsim.py`` assert simulator == ``TransportStats`` to the tick.

jax-free by design: schedules are replayed in plain python/numpy so tuning
sweeps run in milliseconds, not compile times.
"""

from __future__ import annotations

from dataclasses import dataclass, field

MAX_TICKS_FACTOR = 64  # runaway guard: ticks <= factor * total flit-hops


@dataclass
class Message:
    """One logical flow: ``n_flits`` equal flits from ``src`` along a route.

    ``path`` overrides route-table lookup with an explicit rank list (used
    for chain collectives, where the stream multicast-taps every rank on the
    way and delivery time is the last rank's last flit).  ``pipelined``
    messages inject at most one flit per tick (the static chunk pipeline);
    staged messages have every flit FIFO-ready at ``t_start`` (the packet
    router's pre-staged input queues).
    """

    src: int
    dst: int
    n_flits: int = 1
    flit_bytes: float = 0.0
    t_start: int = 0
    port: int = 0
    pipelined: bool = True
    path: list | None = None


@dataclass
class SimReport:
    """What one simulation run produced."""

    ticks: int
    flit_bytes_max: float
    msg_done: list            # per-message delivery tick (inclusive)
    link_busy: dict           # (a, b) -> flits carried
    link_max_queue: dict      # (a, b) -> peak transit-queue depth
    stalls: int               # link-tick slots lost to full downstream FIFOs
    flit_hops: int            # total flits x hops moved
    byte_hops: float          # total payload bytes x hops moved
    dropped: int = 0          # flits past a full (rank, port) delivery buffer
    #: per-tick move log, only filled by ``simulate(..., trace=True)``:
    #: (tick, src, dst, msg index, delivered) per link traversal — the raw
    #: material repro.obs.export renders into the predicted timeline lanes
    moves: list = field(default_factory=list)

    def occupancy(self, link) -> float:
        """Fraction of ticks the directed ``link`` carried a flit."""
        return self.link_busy.get(tuple(link), 0) / max(self.ticks, 1)

    def congestion(self) -> int:
        """Peak transit-queue depth across all links (0 == contention-free)."""
        return max(self.link_max_queue.values(), default=0)

    def time(self, model) -> float:
        """Seconds under ``model``: every tick forwards at most one flit per
        link, so the tick period is one max-size-flit hop."""
        return self.ticks * model.hop_time(self.flit_bytes_max)


@dataclass
class _Flit:
    msg: int
    idx: int
    route: tuple
    leg: int = 0  # next edge index into route


def _route_of(msg: Message, rt) -> tuple:
    if msg.path is not None:
        return tuple(msg.path)
    return tuple(rt.path(msg.src, msg.dst))


def simulate(
    topo,
    rt,
    messages,
    *,
    fifo_depth: int | None = None,
    R: int | None = None,
    switch_bubble: bool = False,
    out_cap: int | None = None,
    trace: bool = False,
) -> SimReport:
    """Run the schedule to completion and report.

    ``fifo_depth`` bounds every transit FIFO (None = unbounded); ``R`` is
    the arbiter's polling stickiness (None = pure round-robin with free
    switching); ``switch_bubble`` burns the link's cycle whenever the
    arbiter acquires a new input FIFO (the paper's Tab. 4 cost);
    ``out_cap`` bounds every (rank, port) delivery buffer — a flit past it
    is dropped on arrival and counted in :attr:`SimReport.dropped`, the
    device router's delivery-overrun semantics (it still counts toward
    message completion so an undersized buffer can't hang the schedule).
    ``trace=True`` additionally records every link traversal into
    :attr:`SimReport.moves` (off by default: tuner sweeps replay thousands
    of schedules and must not pay the log).
    """
    messages = list(messages)
    routes = [_route_of(m, rt) for m in messages]
    for m, route in zip(messages, routes):
        assert len(route) >= 1, "empty route"
        for a, b in zip(route[:-1], route[1:]):
            assert b in topo.links[a], (
                f"route edge {a}->{b} is not a topology link"
            )

    # Per directed link: transit FIFO + the injection FIFOs (one per message
    # originating on it, in port order — the router's input queues).
    transit: dict = {}
    inject: dict = {}
    for li, (m, route) in enumerate(zip(messages, routes)):
        if len(route) < 2:
            continue
        edge = (route[0], route[1])
        inject.setdefault(edge, []).append(li)
    for edge in inject:
        inject[edge].sort(key=lambda li: (messages[li].port, li))

    sent = [0 for _ in messages]        # flits that left the source FIFO
    done_flits = [0 for _ in messages]
    msg_done = [-1 for _ in messages]
    n_live = sum(1 for m, r in zip(messages, routes) if len(r) >= 2)
    for li, route in enumerate(routes):
        if len(route) < 2:  # src == dst: delivered at t_start for free
            msg_done[li] = messages[li].t_start
            done_flits[li] = messages[li].n_flits

    edges = sorted(
        set(inject) | {
            (a, b)
            for route in routes
            for a, b in zip(route[:-1], route[1:])
        }
    )
    last_src: dict = {e: -1 for e in edges}   # arbiter state per link
    stick: dict = {e: 0 for e in edges}
    link_busy: dict = {}
    link_max_queue: dict = {}
    stalls = 0
    flit_hops = 0
    byte_hops = 0.0
    dropped = 0
    moves_log: list | None = [] if trace else None
    out_fill: dict = {}  # (rank, port) -> delivered flits held

    total_work = sum(
        m.n_flits * (len(r) - 1) for m, r in zip(messages, routes)
    )
    max_ticks = max(16, MAX_TICKS_FACTOR * max(total_work, 1))

    def _ready(li: int, t: int) -> bool:
        m = messages[li]
        if sent[li] >= m.n_flits or t < m.t_start:
            return False
        if m.pipelined and sent[li] > (t - m.t_start):
            return False  # the pipeline injects one chunk per tick
        return True

    t = 0
    pending = n_live
    while pending > 0:
        assert t < max_ticks, "simulator failed to converge (routing loop?)"
        moves = []  # (edge, flit, from_transit)
        reserved: dict = {}  # downstream edge -> flits already bound this tick
        for edge in edges:
            tq = transit.get(edge, [])
            link_max_queue[edge] = max(link_max_queue.get(edge, 0), len(tq))
            # candidate sources: injection FIFOs in port order, transit last
            # (mirrors core/router.py's source indexing)
            srcs = inject.get(edge, [])
            S = len(srcs) + 1
            avail = [ _ready(li, t) for li in srcs ] + [bool(tq)]

            def _flit_of(s):
                if s == S - 1:
                    return tq[0]
                li = srcs[s]
                return _Flit(li, sent[li], routes[li], 0)

            def _has_room(fl: _Flit) -> bool:
                route, leg = fl.route, fl.leg
                if leg + 1 == len(route) - 1:
                    return True  # delivery, no queue
                if fifo_depth is None:
                    return True
                down = (route[leg + 1], route[leg + 2])
                q = len(transit.get(down, [])) + reserved.get(down, 0)
                return q < fifo_depth

            # transit priority, then R-sticky polling (core/router.py step 1)
            chosen = -1
            if avail[S - 1]:
                chosen = S - 1
            elif any(avail):
                last = last_src[edge]
                # R-sticky: keep draining the latched FIFO up to R flits;
                # R=None means pure round-robin (free switching)
                keep = (
                    R is not None
                    and 0 <= last < S
                    and stick[edge] < R
                    and avail[last]
                )
                if keep:
                    chosen = last
                else:
                    start = (last + 1) % S if last >= 0 else 0
                    for off in range(S):
                        cand = (start + off) % S
                        if avail[cand]:
                            chosen = cand
                            break
            if chosen < 0:
                continue
            s_is_transit = chosen == S - 1
            fl = _flit_of(chosen)
            if not _has_room(fl):
                stalls += 1
                continue
            if switch_bubble and chosen != last_src[edge]:
                # acquiring a new FIFO burns the cycle; the arbiter latches
                last_src[edge] = chosen
                stick[edge] = 0
                continue
            stick[edge] = stick[edge] + 1 if chosen == last_src[edge] else 0
            last_src[edge] = chosen
            if s_is_transit:
                tq.pop(0)
            else:
                sent[fl.msg] += 1
                fl.leg = 0
            if fl.leg + 1 < len(fl.route) - 1:
                down = (fl.route[fl.leg + 1], fl.route[fl.leg + 2])
                reserved[down] = reserved.get(down, 0) + 1
            moves.append((edge, fl))

        for edge, fl in moves:
            link_busy[edge] = link_busy.get(edge, 0) + 1
            flit_hops += 1
            byte_hops += messages[fl.msg].flit_bytes
            fl.leg += 1
            route = fl.route
            if moves_log is not None:
                moves_log.append(
                    (t, edge[0], edge[1], fl.msg, fl.leg == len(route) - 1)
                )
            # delivery is by path position, not rank value: route-expanded
            # logical chains may revisit a rank before terminating there
            if fl.leg == len(route) - 1:
                if out_cap is not None:
                    slot = (route[-1], messages[fl.msg].port)
                    if out_fill.get(slot, 0) >= out_cap:
                        dropped += 1
                    else:
                        out_fill[slot] = out_fill.get(slot, 0) + 1
                done_flits[fl.msg] += 1
                if done_flits[fl.msg] == messages[fl.msg].n_flits:
                    msg_done[fl.msg] = t
                    pending -= 1
            else:
                down = (route[fl.leg], route[fl.leg + 1])
                transit.setdefault(down, []).append(fl)
        t += 1

    flit_max = max((m.flit_bytes for m in messages), default=0.0)
    return SimReport(
        ticks=t,
        flit_bytes_max=flit_max,
        msg_done=msg_done,
        link_busy=link_busy,
        link_max_queue=link_max_queue,
        stalls=stalls,
        flit_hops=flit_hops,
        byte_hops=byte_hops,
        dropped=dropped,
        moves=moves_log if moves_log is not None else [],
    )


def simulate_rounds(topo, rt, rounds, model=None, **kw):
    """Run barrier-separated schedule rounds (tree collectives, ring shifts).

    Each round starts when the previous one fully completes.  Returns
    ``(total_ticks, total_seconds, reports)`` — seconds is None without a
    ``model``.
    """
    total_ticks = 0
    total_s = 0.0 if model is not None else None
    reports = []
    for msgs in rounds:
        rep = simulate(topo, rt, msgs, **kw)
        reports.append(rep)
        total_ticks += rep.ticks
        if model is not None:
            total_s += rep.time(model)
    return total_ticks, total_s, reports
