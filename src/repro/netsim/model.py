"""The analytic link cost model (paper Tab. 3 / Tab. 4 / Fig. 9 quantities).

One :class:`LinkModel` instance answers every "how long does this schedule
take" question in the repo — benchmarks derive their model columns from it,
the discrete-event simulator (:mod:`repro.netsim.sim`) converts ticks to
seconds through it, the autotuner (:mod:`repro.netsim.tune`) scores
candidate plans with it, and ``launch/roofline.py`` uses it for the
collective roofline term.  Before this module existed those four call sites
each hard-coded their own constants and could silently drift apart.

Quantities, mapped to the paper:

* ``hop_latency`` — per-hop forwarding cost (Tab. 3: latency = hops x
  per-hop cost; ~1 us per ICI hop on a v5e-class part).
* ``link_bw`` — per-link per-direction serialization bandwidth (Fig. 9's
  plateau; 50 GB/s on v5e ICI).
* ``injection_base`` — fixed per-transfer overhead (dispatch / rendezvous;
  the host-staged path pays a large one, the streamed path a small one).
* ``switch_cycles`` — the router's polling-stickiness cost (Tab. 4): with
  stickiness R the arbiter burns ~``switch_cycles / R`` extra cycles per
  packet acquiring a new input FIFO (paper: 5 cycles/packet at R=1 falling
  to 1.69 at R=16).
* ``quant_latency`` — per-hop quantise+dequantise pipeline cost of a
  compressed link (``transport/compressed.py``): a fixed vector-unit pass
  at each edge of every hop.  This is what keeps compressed links off the
  latency-bound cells — the wire carries 4x fewer bytes but every hop pays
  the codec, so compression only wins once serialization dominates.

The module is deliberately jax-free (pure python + numpy) so it can be
imported before jax initialises (benchmarks set XLA_FLAGS first) and used
from offline tooling.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

import numpy as np

#: scale-block size of the int8 compressed wire format: one f32 scale per
#: ``WIRE_AXIS_ELEMS`` payload elements (transport/compressed.py's default)
WIRE_AXIS_ELEMS = 256


def int8_wire_nbytes(n_elems: int, axis_elems: int = WIRE_AXIS_ELEMS) -> int:
    """Exact wire bytes of ``n_elems`` f32 payload elements on an int8
    compressed link: 1 byte per element + a 4-byte f32 scale per block.
    Single source for the traced transport's accounting and the
    simulator's prediction (they are asserted equal to the byte)."""
    n_elems = int(n_elems)
    axis_elems = max(int(axis_elems), 1)
    n_blocks = -(-n_elems // axis_elems) if n_elems else 0
    return n_elems + 4 * n_blocks


def clamp_chunks(n_chunks: int, leading_dim: int) -> int:
    """Largest divisor of ``leading_dim`` <= the chunk-count hint (the
    pipelined transports require n_chunks | leading dim; hints are never a
    correctness constraint)."""
    n = max(1, min(int(n_chunks), int(leading_dim)))
    while leading_dim % n:
        n -= 1
    return n


@dataclass(frozen=True)
class LinkModel:
    """Per-link cost parameters; all times in seconds, sizes in bytes."""

    hop_latency: float = 1e-6     # s per hop (v5e ICI class)
    link_bw: float = 50e9         # B/s per link per direction
    injection_base: float = 0.0   # s fixed overhead per transfer
    switch_cycles: float = 4.0    # extra arbiter cycles at R=1 (Tab. 4)
    quant_latency: float = 1.5e-6  # s per hop: compressed-link codec pass
    #: s per reduction tick the *unfused* static backend pays for the HBM
    #: round-trip between the collective-permute and the add; the fused
    #: backend's receive+accumulate kernel elides it (DESIGN.md §3.3/§10)
    unfused_add_latency: float = 2.5e-7

    # -- primitive costs ---------------------------------------------------

    def serialization(self, nbytes: float) -> float:
        """Wire time of ``nbytes`` through one link (Fig. 9 plateau)."""
        return nbytes / self.link_bw

    def hop_time(self, flit_bytes: float) -> float:
        """One pipeline tick: forward a ``flit_bytes`` chunk one hop."""
        return self.hop_latency + self.serialization(flit_bytes)

    # -- wire formats (compressed links, transport/compressed.py) ----------

    def wire_bytes(self, nbytes: float, wire: str = "raw") -> float:
        """Bytes actually serialized for an ``nbytes`` f32 payload under
        the given wire format (``"raw"`` | ``"int8"``)."""
        if wire == "raw":
            return float(nbytes)
        if wire == "int8":
            return float(int8_wire_nbytes(max(int(round(nbytes / 4.0)), 1)))
        raise ValueError(f"unknown wire format {wire!r}")

    def hop_time_wire(self, flit_bytes: float, wire: str = "raw") -> float:
        """One pipeline tick under a wire format: a raw link is
        :meth:`hop_time`; a compressed link serializes the compressed
        bytes but pays the per-hop codec pass on top."""
        if wire == "raw":
            return self.hop_time(flit_bytes)
        return (self.hop_latency + self.quant_latency
                + self.serialization(self.wire_bytes(flit_bytes, wire)))

    def injection_cycles(self, R: int) -> float:
        """Router cycles per packet as a function of polling stickiness R
        (Tab. 4: 5 cycles at R=1, approaching 1 as R grows)."""
        return 1.0 + self.switch_cycles / max(int(R), 1)

    # -- transfer-level costs (the quantities the benchmarks print) --------

    def p2p_time(self, nbytes: float, hops: int, n_chunks: int = 1) -> float:
        """Chunk-pipelined routed transfer: ``n_chunks + hops - 1`` ticks of
        one chunk each (paper Fig. 9 / Tab. 3 by construction)."""
        n_chunks = max(int(n_chunks), 1)
        ticks = n_chunks + max(int(hops), 0) - 1 if hops else 0
        if hops == 0:
            return 0.0
        return self.injection_base + ticks * self.hop_time(nbytes / n_chunks)

    def staged_time(self, nbytes: float, hops: int) -> float:
        """Store-and-forward whole-message transfer: the full message
        completes each hop before the next (the paper's host-staged path)."""
        return self.injection_base + hops * self.hop_time(nbytes)

    def bandwidth(self, nbytes: float, hops: int, n_chunks: int = 1) -> float:
        """Effective p2p bandwidth in B/s."""
        t = self.p2p_time(nbytes, hops, n_chunks)
        return nbytes / t if t > 0 else float("inf")

    # -- overlap window (the apps layer's pipelined steps, paper §5.4.2) ---

    def overlapped_step_time(self, compute_s: float, comm_s: float) -> float:
        """One pipelined application step: communication streams during the
        compute pipeline, so the step costs the *longer* of the two — the
        paper's compute/communication-overlap inequality.  This is the
        model column of the overlapped stencil."""
        return max(compute_s, comm_s)

    def serial_step_time(self, compute_s: float, comm_s: float) -> float:
        """The non-overlapped reference: exchange completes before compute
        starts, so the step pays the sum."""
        return compute_s + comm_s

    # -- construction ------------------------------------------------------

    @staticmethod
    def default_v5e() -> "LinkModel":
        """The TPU-v5e ICI figures the benchmarks' derived columns use."""
        return LinkModel()

    def with_params(self, **kw) -> "LinkModel":
        return replace(self, **kw)

    # -- calibration -------------------------------------------------------

    @staticmethod
    def fit(records, *, base: "LinkModel | None" = None):
        """Least-squares fit of (hop_latency, link_bw, injection_base) from
        schedule-cost records.

        ``records``: iterable of dicts with keys ``steps`` (schedule ticks,
        the :class:`repro.transport.base.TransportStats` convention),
        ``bytes`` (wire bytes, same convention) and ``seconds`` (measured).
        Solves ``t = injection_base + steps * hop_latency + bytes / bw``
        weighted by 1/t (relative error: a 4 MB transfer must not drown the
        8-byte latency probes); negative coefficients are clamped to the
        ``base`` model's values (measurement noise must not produce an
        unphysical model).

        Returns the fitted :class:`LinkModel`.
        """
        base = base or LinkModel.default_v5e()
        recs = list(records)
        if not recs:
            return base
        A = np.array([[1.0, r["steps"], r["bytes"]] for r in recs], float)
        t = np.array([r["seconds"] for r in recs], float)
        w = 1.0 / np.maximum(t, 1e-12)
        coef, *_ = np.linalg.lstsq(A * w[:, None], t * w, rcond=None)
        inj, hop, inv_bw = (float(c) for c in coef)
        if inj < 0:
            inj = 0.0
        if hop <= 0:
            hop = base.hop_latency
        bw = 1.0 / inv_bw if inv_bw > 0 else base.link_bw
        return base.with_params(
            injection_base=inj, hop_latency=hop, link_bw=bw
        )

    def predict(self, record) -> float:
        """Predicted seconds for one schedule-cost record (same keys as
        :meth:`fit`)."""
        return (
            self.injection_base
            + record["steps"] * self.hop_latency
            + self.serialization(record["bytes"])
        )
