"""Pure single-tick datapath of the store-and-forward router.

One tick of ``core/router.py`` as whole-state array ops — no per-link
Python loop, no per-arrival scalar scan.  Both the lax "vector"
implementation and the Pallas kernel execute exactly this function; the
seed's per-link scalar loop is kept in ``core/router.py`` as the reference
the equivalence tests diff against.

Why one-shot arbitration is exact: the routing table maps each candidate
source (its head packet's destination) to exactly *one* link id, so the
per-link availability sets are disjoint across links — the sequential
``taken`` mask of the scalar reference can never exclude a candidate a
later link would otherwise have selected.  Arbitrating every link with one
masked argmax over the (NL, S) availability matrix is therefore
tick-for-tick identical to the scalar loop, R-stickiness, switch-bubble
and all.

Sequential-absorb equivalence: the scalar reference delivers/parks
arrivals one link at a time, each seeing the counters the previous arrival
updated.  The vectorized form reproduces that with exclusive prefix sums
in link order: arrival ``li``'s delivery slot is ``out_cnt[port] + (number
of earlier arrivals this tick delivering to the same port)``, and its
transit-tail offset is the count of earlier parked arrivals — the same
slots, computed in one shot and written with masked scatters
(out-of-bounds index + ``mode="drop"`` realises the capacity drop).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp


@dataclass(frozen=True)
class TickSpec:
    """Static shape/config of one router tick (hashable, trace-stable)."""

    n: int                    # ranks
    n_ports: int
    fifo_cap: int
    transit_cap: int
    out_cap: int
    pkt_elems: int
    R: int
    switch_bubble: bool
    link_ids: tuple[int, ...]  # physical id of each link, in link order

    @property
    def n_links(self) -> int:
        return len(self.link_ids)

    @property
    def n_srcs(self) -> int:
        """Arbitration candidates per link: the input FIFOs + transit."""
        return self.n_ports + 1


def tick_spec_of(cfg, n: int, link_ids) -> TickSpec:
    """Build a TickSpec from a ``core.router.RouterConfig``."""
    return TickSpec(
        n=n, n_ports=cfg.n_ports, fifo_cap=cfg.fifo_cap,
        transit_cap=cfg.transit_cap, out_cap=cfg.out_cap,
        pkt_elems=cfg.pkt_elems, R=cfg.R,
        switch_bubble=cfg.switch_bubble, link_ids=tuple(link_ids),
    )


def _i32(x):
    return x.astype(jnp.int32)


def router_absorb(spec: TickSpec, st, arr_pay, arr_dst, arr_prt, arr_val,
                  r, t):
    """Absorb one tick's arrivals: deliver (dst == me) or park in transit.

    ``arr_*`` are the NL link arrivals in link order; ``t`` labels the tick
    the arrivals completed (the ``t_done`` stamp).  A delivery past
    ``out_cap`` and a park past ``transit_cap`` both drop the packet and
    count it in ``overflow``.
    """
    NP, NL = spec.n_ports, spec.n_links
    if NL == 0:
        return st
    mine = jnp.logical_and(arr_val, arr_dst == r)            # (NL,)
    fwd = jnp.logical_and(arr_val, arr_dst != r)
    prt = jnp.clip(arr_prt, 0, NP - 1)

    # -- deliveries: per-port slots via exclusive prefix sums in link order
    hot = jnp.logical_and(mine[:, None],
                          prt[:, None] == jnp.arange(NP)[None, :])  # (NL,NP)
    hot_i = _i32(hot)
    prior = jnp.cumsum(hot_i, axis=0) - hot_i
    slot = st["out_cnt"][prt] + \
        jnp.take_along_axis(prior, prt[:, None], axis=1)[:, 0]
    ok_del = jnp.logical_and(mine, slot < spec.out_cap)
    row = jnp.where(ok_del, prt, NP)              # OOB row/col => dropped
    col = jnp.where(ok_del, slot, spec.out_cap)
    st["out_pay"] = st["out_pay"].at[row, col].set(arr_pay, mode="drop")
    st["out_cnt"] = st["out_cnt"] + \
        jnp.sum(_i32(jnp.logical_and(hot, ok_del[:, None])), axis=0)
    st["overflow"] = st["overflow"] + \
        jnp.sum(_i32(jnp.logical_and(mine, ~ok_del)))
    st["t_done"] = jnp.where(ok_del.any(), _i32(t), st["t_done"])

    # -- transit parking: ring-buffer tails via exclusive prefix sum
    fwd_i = _i32(fwd)
    off = jnp.cumsum(fwd_i) - fwd_i                          # (NL,)
    room = (st["tr_cnt"] + off) < spec.transit_cap
    ok_park = jnp.logical_and(fwd, room)
    tail = (st["tr_head"] + st["tr_cnt"] + off) % spec.transit_cap
    idx = jnp.where(ok_park, tail, spec.transit_cap)
    st["tr_pay"] = st["tr_pay"].at[idx].set(arr_pay, mode="drop")
    st["tr_dst"] = st["tr_dst"].at[idx].set(arr_dst, mode="drop")
    st["tr_port"] = st["tr_port"].at[idx].set(arr_prt, mode="drop")
    st["tr_cnt"] = st["tr_cnt"] + jnp.sum(_i32(ok_park))
    st["overflow"] = st["overflow"] + \
        jnp.sum(_i32(jnp.logical_and(fwd, ~room)))
    return st


def router_arbitrate(spec: TickSpec, my_tbl, inq_pay, inq_dst, inq_len,
                     st, r, link_ids=None):
    """Arbitrate all links in one shot and pop the selected sources.

    Returns ``(st, snd_pay, snd_dst, snd_prt, snd_val, pending)`` —
    the NL outgoing link rows plus the rank's remaining-work count
    (staged + parked + in flight) for the early-exit ticker.
    ``link_ids`` defaults to ``spec.link_ids`` as an array; the Pallas
    kernel passes it explicitly (a closure constant can't enter a kernel).
    """
    NP, NL, S = spec.n_ports, spec.n_links, spec.n_srcs
    n = spec.n
    if link_ids is None:
        link_ids = jnp.asarray(spec.link_ids, jnp.int32)

    # candidate heads: sources 0..NP-1 = input FIFOs, S-1 = transit
    hclip = jnp.minimum(st["inq_head"], spec.fifo_cap - 1)
    fifo_pay = jnp.take_along_axis(
        inq_pay, hclip[:, None, None], axis=1)[:, 0]         # (NP, E)
    fifo_dst = jnp.take_along_axis(inq_dst, hclip[:, None], axis=1)[:, 0]
    fifo_has = st["inq_head"] < inq_len
    th = st["tr_head"] % spec.transit_cap
    cand_pay = jnp.concatenate([fifo_pay, st["tr_pay"][th][None]], axis=0)
    cand_dst = jnp.concatenate([fifo_dst, st["tr_dst"][th][None]])
    cand_prt = jnp.concatenate(
        [jnp.arange(NP, dtype=jnp.int32), st["tr_port"][th][None]])
    cand_has = jnp.concatenate([fifo_has, (st["tr_cnt"] > 0)[None]])

    want = jnp.where(cand_dst == r, -2,
                     my_tbl[jnp.clip(cand_dst, 0, n - 1)])   # (S,)
    A = jnp.logical_and(cand_has[None, :],
                        want[None, :] == link_ids[:, None])  # (NL, S)

    last = st["last_src"]
    tr_want = A[:, S - 1]
    keep = jnp.logical_and(
        st["stick"] < spec.R,
        jnp.take_along_axis(
            A, jnp.clip(last, 0, S - 1)[:, None], axis=1)[:, 0],
    )
    idxs = (last[:, None] + 1 + jnp.arange(S)[None, :]) % S  # (NL, S)
    rot = jnp.take_along_axis(A, idxs, axis=1)
    off = jnp.argmax(rot, axis=1)
    rr = jnp.take_along_axis(idxs, off[:, None], axis=1)[:, 0]
    chosen = jnp.where(tr_want, S - 1, jnp.where(keep, last, rr))
    any_avail = A.any(axis=1)
    if spec.switch_bubble:
        switching = jnp.logical_and(any_avail, chosen != last)
        send = jnp.logical_and(any_avail, ~switching)
    else:
        send = any_avail
    st["last_src"] = jnp.where(any_avail, chosen, last)
    st["stick"] = jnp.where(
        jnp.logical_and(send, chosen == last), st["stick"] + 1, 0)
    sel = jnp.where(send, chosen, -1)                        # (NL,)

    # pops (availability sets are disjoint: each source selected at most once)
    pop_fifo = jnp.sum(
        _i32(sel[:, None] == jnp.arange(NP)[None, :]), axis=0)
    st["inq_head"] = st["inq_head"] + pop_fifo
    tr_pops = jnp.sum(_i32(sel == S - 1))
    st["tr_head"] = st["tr_head"] + tr_pops
    st["tr_cnt"] = st["tr_cnt"] - tr_pops

    # outgoing rows (invalid selections ride as bubbles)
    cs = jnp.clip(sel, 0, S - 1)
    snd_val = sel >= 0
    snd_pay = cand_pay[cs]                                   # (NL, E)
    snd_dst = jnp.where(snd_val, cand_dst[cs], -1)
    snd_prt = jnp.where(snd_val, cand_prt[cs], 0)

    pending = jnp.sum(inq_len - st["inq_head"]) + st["tr_cnt"] + \
        jnp.sum(_i32(snd_val))
    return st, snd_pay, snd_dst, snd_prt, snd_val, _i32(pending)


def router_tick(spec: TickSpec, my_tbl, inq_pay, inq_dst, inq_len, st,
                arr_pay, arr_dst, arr_prt, arr_val, r, t, link_ids=None):
    """One full tick: absorb the previous tick's arrivals (labelled
    ``t - 1``), then arbitrate/pop the outgoing rows for tick ``t``."""
    st = router_absorb(spec, st, arr_pay, arr_dst, arr_prt, arr_val,
                       r, t - 1)
    return router_arbitrate(spec, my_tbl, inq_pay, inq_dst, inq_len, st, r,
                            link_ids)
