"""Vectorized router datapath kernels (DESIGN.md §10).

``ref`` holds the pure single-tick datapath (absorb + arbitrate) shared by
the lax "vector" implementation and the Pallas kernel; ``kernel`` wraps it
in a ``pallas_call`` whose FIFO/arbiter state stays aliased in place
(VMEM-resident on TPU) across ticks.
"""

from .kernel import router_tick_pallas  # noqa: F401
from .ref import TickSpec, router_absorb, router_tick, tick_spec_of  # noqa: F401
