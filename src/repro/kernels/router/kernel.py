"""Pallas tick kernel for the packet router (DESIGN.md §10).

One ``pallas_call`` executes a full router tick — absorb the previous
tick's arrivals, arbitrate all links, pop the selected FIFO heads — over
the *same* pure datapath as the lax implementation (``ref.router_tick``).
Every piece of mutable router state (input-FIFO heads, transit ring
buffer, delivery buffers, arbiter latch/stickiness, counters) is passed in
and aliased onto the corresponding output via ``input_output_aliases``, so
on TPU the state tensors live in VMEM and are updated in place tick after
tick instead of round-tripping HBM between loop iterations.  Off TPU the
kernel runs under the Pallas interpreter (``interpret=True``) and lowers
to the identical XLA ops as the vector path — bit-for-bit equal, which is
what the equivalence tests assert.

Scalars ride as (1, 1) tiles and 1-D state as (1, k) rows (TPU refs want
>= 2D); the wrapper reshapes at the boundary so callers keep the reference
implementation's shapes.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .ref import TickSpec, router_tick

#: state-dict keys in the fixed ref-argument order of the kernel
STATE_KEYS = (
    "inq_head", "tr_pay", "tr_dst", "tr_port", "tr_head", "tr_cnt",
    "out_pay", "out_cnt", "overflow", "last_src", "stick", "t_done",
)

#: keys whose carried shape is 0-D / 1-D and rides as (1, k) in the kernel
_FLAT = {"inq_head", "tr_dst", "tr_port", "tr_head", "tr_cnt", "out_cnt",
         "overflow", "last_src", "stick", "t_done"}


def _widen(k, v):
    return v.reshape(1, -1) if k in _FLAT else v


def _narrow(k, v, like):
    return v.reshape(like.shape) if k in _FLAT else v


def _make_kernel(spec: TickSpec):
    def kernel(my_tbl_ref, link_ids_ref, inq_pay_ref, inq_dst_ref,
               inq_len_ref, meta_ref, arr_pay_ref, arr_meta_ref,
               *state_refs):
        in_refs = state_refs[:len(STATE_KEYS)]
        out_refs = state_refs[len(STATE_KEYS):len(STATE_KEYS) * 2]
        snd_pay_ref, snd_meta_ref, pend_ref = state_refs[len(STATE_KEYS) * 2:]

        st = {}
        for k, ref in zip(STATE_KEYS, in_refs):
            v = ref[...]
            if k in ("tr_head", "tr_cnt", "overflow", "t_done"):
                v = v[0, 0]
            elif k in _FLAT:
                v = v[0, :]
            st[k] = v
        r = meta_ref[0, 0]
        t = meta_ref[0, 1]
        st, snd_pay, snd_dst, snd_prt, snd_val, pending = router_tick(
            spec, my_tbl_ref[0, :], inq_pay_ref[...], inq_dst_ref[...],
            inq_len_ref[0, :], st,
            arr_pay_ref[...], arr_meta_ref[0, :], arr_meta_ref[1, :],
            arr_meta_ref[2, :] > 0, r, t, link_ids_ref[0, :],
        )
        for k, ref in zip(STATE_KEYS, out_refs):
            ref[...] = st[k].reshape(ref.shape)
        snd_pay_ref[...] = snd_pay
        snd_meta_ref[...] = jnp.stack(
            [snd_dst, snd_prt, snd_val.astype(jnp.int32)])
        pend_ref[...] = pending.reshape(1, 1)

    return kernel


@partial(jax.jit, static_argnames=("spec", "interpret"))
def router_tick_pallas(spec: TickSpec, my_tbl, inq_pay, inq_dst, inq_len,
                       st, arr_pay, arr_dst, arr_prt, arr_val, r, t, *,
                       interpret: bool = True):
    """``ref.router_tick`` as one Pallas kernel with in-place state.

    Same signature/returns as the reference; ``interpret=True`` (the
    CPU/GPU fallback) runs the kernel through the Pallas interpreter.
    """
    from jax.experimental import pallas as pl

    NL, E = spec.n_links, spec.pkt_elems
    i32 = jnp.int32
    meta = jnp.stack([r, t]).astype(i32).reshape(1, 2)
    arr_meta = jnp.stack(
        [arr_dst.astype(i32), arr_prt.astype(i32), arr_val.astype(i32)])
    state_in = [_widen(k, st[k]) for k in STATE_KEYS]
    out_shape = [jax.ShapeDtypeStruct(v.shape, v.dtype) for v in state_in]
    out_shape += [
        jax.ShapeDtypeStruct((NL, E), inq_pay.dtype),
        jax.ShapeDtypeStruct((3, NL), i32),
        jax.ShapeDtypeStruct((1, 1), i32),
    ]
    # my_tbl, link_ids, inq_pay, inq_dst, inq_len, meta, arr_pay, arr_meta
    n_fixed = 8
    aliases = {n_fixed + i: i for i in range(len(STATE_KEYS))}
    link_ids = jnp.asarray(spec.link_ids, i32).reshape(1, -1)
    outs = pl.pallas_call(
        _make_kernel(spec),
        out_shape=out_shape,
        input_output_aliases=aliases,
        interpret=interpret,
    )(
        my_tbl.reshape(1, -1), link_ids, inq_pay, inq_dst,
        inq_len.reshape(1, -1), meta, arr_pay, arr_meta, *state_in,
    )
    new_st = {
        k: _narrow(k, v, st[k])
        for k, v in zip(STATE_KEYS, outs[:len(STATE_KEYS)])
    }
    snd_pay, snd_meta, pending = outs[len(STATE_KEYS):]
    return (new_st, snd_pay, snd_meta[0], snd_meta[1], snd_meta[2] > 0,
            pending[0, 0])
