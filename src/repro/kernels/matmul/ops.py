"""jit'd public wrapper for the MXU matmul kernel: padding + dispatch."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..common import pad_to, resolve_use_pallas
from .kernel import matmul_pallas
from .ref import matmul_ref


@partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "use_pallas", "interpret", "out_dtype"),
)
def matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=None,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jax.Array:
    """(M, K) @ (K, N) -> (M, N); arbitrary sizes (zero-padded to blocks)."""
    if not resolve_use_pallas(use_pallas) and not interpret:
        return matmul_ref(x, w, out_dtype=out_dtype)
    M, N = x.shape[0], w.shape[1]
    xp, _ = pad_to(x, block_m, 0)
    xp, _ = pad_to(xp, block_k, 1)
    wp, _ = pad_to(w, block_k, 0)
    wp, _ = pad_to(wp, block_n, 1)
    out = matmul_pallas(
        xp, wp,
        block_m=block_m, block_n=block_n, block_k=block_k,
        out_dtype=out_dtype, interpret=interpret,
    )
    return out[:M, :N]
