"""MXU-tiled matmul Pallas kernel.

The per-chunk GEMM of the SMI overlap engine (core/overlap.py): each ring
step multiplies one streamed chunk on the MXU while the next chunk rides the
ICI.  Block sizes default to (128, 128, 128) — MXU-native tiles; the K grid
dim is innermost ("arbitrary": sequential) and accumulates into an f32 VMEM
scratch so low-precision inputs keep full-precision partials.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...compat import tpu_compiler_params


def _matmul_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == nk - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(
    x: jax.Array,
    w: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """x: (M, K) @ w: (K, N) -> (M, N).  Dims must divide the block sizes
    (ops.py pads).  Grid: (M/bm, N/bn, K/bk), K innermost sequential."""
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, f"contraction mismatch {K} vs {K2}"
    assert M % block_m == 0 and N % block_n == 0 and K % block_k == 0
    out_dtype = out_dtype or x.dtype
    grid = (M // block_m, N // block_n, K // block_k)
    kernel = partial(_matmul_kernel, nk=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w)
