"""Pure-jnp oracle for the matmul kernel."""

import jax.numpy as jnp


def matmul_ref(x, w, out_dtype=None):
    out_dtype = out_dtype or x.dtype
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(out_dtype)
