"""jit'd wrapper for the stencil sweep: padding + dispatch + time loop."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..common import pad_to, resolve_use_pallas
from .kernel import stencil_pallas
from .ref import stencil_ref


@partial(jax.jit, static_argnames=("block_m", "use_pallas", "interpret"))
def stencil_step(
    x: jax.Array,
    *,
    block_m: int = 128,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jax.Array:
    """One sweep of the 4-point stencil with zero (Dirichlet) boundaries."""
    if not resolve_use_pallas(use_pallas) and not interpret:
        return stencil_ref(x)
    M = x.shape[0]
    xp, _ = pad_to(x, block_m, 0)
    out = stencil_pallas(xp, block_m=block_m, interpret=interpret)
    # Zero-padded rows double as the zero Dirichlet boundary: row M-1's south
    # neighbour is xp[M] == 0, exactly the oracle's condition; rows >= M are
    # garbage and sliced off.
    return out[:M]


def stencil_run(x, n_steps: int, **kw):
    """n_steps sweeps (the paper's T timesteps)."""
    def body(_, v):
        return stencil_step(v, **kw)

    return jax.lax.fori_loop(0, n_steps, body, x)


def stencil_interior(x: jax.Array, **kw) -> jax.Array:
    """Interior output points of one sweep: rows/cols ``1..-2`` of
    :func:`stencil_step`, which depend only on values already resident in
    the local tile — no halo reads.  This is the compute the ``repro/apps``
    distributed stencil runs *while* its halo slabs are in flight (the
    overlap window); the boundary ring is finished after the exchange
    lands.  Same kwargs as :func:`stencil_step` (``use_pallas`` /
    ``interpret`` select the Pallas kernel), and bit-identical to the
    corresponding interior of the halo'd reference sweep: every point is
    the same ``0.25 * (n + s + w + e)`` f32 expression.
    """
    return stencil_step(x, **kw)[1:-1, 1:-1]
