"""Pure-jnp oracle: one 4-point stencil sweep, zero boundary."""

import jax.numpy as jnp


def stencil_ref(x):
    xp = jnp.pad(x.astype(jnp.float32), 1)
    out = 0.25 * (xp[:-2, 1:-1] + xp[2:, 1:-1] + xp[1:-1, :-2] + xp[1:-1, 2:])
    return out.astype(x.dtype)
