from .ops import stencil_step, stencil_run, stencil_interior
from .ref import stencil_ref

__all__ = ["stencil_step", "stencil_run", "stencil_interior", "stencil_ref"]
