from .ops import stencil_step, stencil_run
from .ref import stencil_ref

__all__ = ["stencil_step", "stencil_run", "stencil_ref"]
