"""4-point stencil sweep Pallas kernel (the paper's §5.4.2 application).

Hardware adaptation: the FPGA implementation streams the domain through a
shift-register pipeline with perfect on-chip reuse.  The TPU analogue is
row-block streaming: each grid step holds a (bm × N) row slab in VMEM, the
north/south boundary rows come from neighbouring blocks via clamped
index_maps (double-buffered by the pipeline), and the east/west shifts are
VREG lane rotations — the shift register becomes the vector register file.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...compat import tpu_compiler_params


def _stencil_kernel(up_ref, c_ref, dn_ref, o_ref, *, bm: int, n_blocks: int):
    i = pl.program_id(0)
    c = c_ref[...].astype(jnp.float32)             # (bm, N)
    up = up_ref[...].astype(jnp.float32)
    dn = dn_ref[...].astype(jnp.float32)

    north = jnp.concatenate([up[-1:], c[:-1]], axis=0)      # x[r-1, :]
    south = jnp.concatenate([c[1:], dn[:1]], axis=0)        # x[r+1, :]
    row = jax.lax.broadcasted_iota(jnp.int32, c.shape, 0)
    north = jnp.where(jnp.logical_and(i == 0, row == 0), 0.0, north)
    south = jnp.where(
        jnp.logical_and(i == n_blocks - 1, row == bm - 1), 0.0, south
    )

    west = jnp.pad(c[:, :-1], ((0, 0), (1, 0)))             # x[:, c-1]
    east = jnp.pad(c[:, 1:], ((0, 0), (0, 1)))              # x[:, c+1]

    o_ref[...] = (0.25 * (north + south + west + east)).astype(o_ref.dtype)


def stencil_pallas(
    x: jax.Array,  # (M, N)
    *,
    block_m: int = 128,
    interpret: bool = False,
) -> jax.Array:
    M, N = x.shape
    assert M % block_m == 0
    nb = M // block_m
    kern = partial(_stencil_kernel, bm=block_m, n_blocks=nb)
    return pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_m, N), lambda i: (jnp.maximum(i - 1, 0), 0)),
            pl.BlockSpec((block_m, N), lambda i: (i, 0)),
            pl.BlockSpec((block_m, N), lambda i: (jnp.minimum(i + 1, nb - 1), 0)),
        ],
        out_specs=pl.BlockSpec((block_m, N), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(x, x, x)
