"""Shared kernel utilities: padding, backend dispatch.

Kernels TARGET TPU (MXU/VMEM tiling via BlockSpec); on this CPU container
they are validated with ``interpret=True`` against the pure-jnp ``ref.py``
oracles.  ``use_pallas(None)`` auto-selects: real kernels on TPU backends,
jnp reference elsewhere (models stay fast on CPU; tests force interpret)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_use_pallas(use_pallas: bool | None) -> bool:
    return on_tpu() if use_pallas is None else use_pallas


def pad_to(x: jax.Array, multiple: int, axis: int):
    """Zero-pad ``axis`` up to a multiple; returns (padded, original_size)."""
    size = x.shape[axis]
    pad = (-size) % multiple
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def cdiv(a: int, b: int) -> int:
    return (a + b - 1) // b


def match_vma(x, ref):
    """Promote ``x``'s varying-manual-axes to match ``ref`` (no-op outside
    shard_map and on pre-VMA runtimes).  Needed for scan carries created
    inside shard_map bodies."""
    from ..compat import pvary_missing, vma_of

    return pvary_missing(x, tuple(vma_of(ref)))
