"""Pallas TPU kernels for the compute hot-spots of the SMI framework.

Each subpackage: kernel.py (pl.pallas_call + explicit BlockSpec VMEM
tiling), ops.py (jit'd wrapper with padding/dispatch), ref.py (pure-jnp
oracle).  Validated with interpret=True on CPU; real Mosaic lowering on TPU.

* matmul           — MXU-tiled GEMM; the per-chunk compute of the SMI
                     collective-matmul overlap engine.
* flash_attention  — online-softmax attention (causal/local, GQA).
* stencil          — 4-point stencil sweep (the paper's application).
* ssd              — Mamba2 state-space chunked scan.
"""

from .matmul import matmul, matmul_ref
from .flash_attention import flash_attention, attention_ref, attention_chunked_ref
from .stencil import stencil_step, stencil_run, stencil_interior, stencil_ref
from .ssd import ssd_scan, ssd_decode_step, ssd_ref

__all__ = [
    "matmul", "matmul_ref",
    "flash_attention", "attention_ref", "attention_chunked_ref",
    "stencil_step", "stencil_run", "stencil_interior", "stencil_ref",
    "ssd_scan", "ssd_decode_step", "ssd_ref",
]
