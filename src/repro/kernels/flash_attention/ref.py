"""Pure-jnp oracles: dense masked softmax attention (small S) and a
scan-based chunked flash attention (same math as the kernel; bounded
memory — the non-TPU dispatch path for long sequences and the dry-run)."""

import jax
import jax.numpy as jnp


def attention_ref(
    q,  # (B, Sq, H, D)
    k,  # (B, Skv, Hkv, D)
    v,  # (B, Skv, Hkv, D)
    *,
    scale=None,
    causal=True,
    window=None,
):
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    kf = jnp.repeat(k, g, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v, g, axis=2).astype(jnp.float32)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32) * scale, kf)
    Skv = k.shape[1]
    qpos = jnp.arange(Sq)[:, None] + (Skv - Sq)  # right-aligned (decode-safe)
    kpos = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask = qpos >= kpos
    if window is not None:
        mask = jnp.logical_and(mask, qpos - kpos < window)
    s = jnp.where(mask[None, None], s, -jnp.inf)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vf)
    return out.astype(q.dtype)


def attention_chunked_ref(
    q,  # (B, Sq, H, D)
    k,  # (B, Skv, Hkv, D)
    v,
    *,
    scale=None,
    causal=True,
    window=None,
    block_k: int = 512,
):
    """Online-softmax attention, lax.scan over KV blocks.

    Peak live memory is O(Sq * block_k) scores instead of O(Sq * Skv) —
    required for the 32k prefill / 500k shapes, and the model-layer default
    beyond 2k tokens.  Matches the Pallas kernel's math exactly.
    """
    B, Sq, H, D = q.shape
    Skv, Hkv = k.shape[1], k.shape[2]
    g = H // Hkv
    scale = scale if scale is not None else D ** -0.5
    pad = (-Skv) % block_k
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nkb = k.shape[1] // block_k

    qf = q.astype(jnp.float32) * scale
    q_pos = jnp.arange(Sq) + (Skv - Sq)  # right-aligned

    kb = k.reshape(B, nkb, block_k, Hkv, D).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, nkb, block_k, Hkv, D).transpose(1, 0, 2, 3, 4)

    def step(carry, blk):
        m_i, l_i, acc = carry
        kcb, vcb, j = blk
        kf = jnp.repeat(kcb.astype(jnp.float32), g, axis=2)
        vf = jnp.repeat(vcb.astype(jnp.float32), g, axis=2)
        s = jnp.einsum("bqhd,bkhd->bhqk", qf, kf)
        kv_pos = j * block_k + jnp.arange(block_k)
        mask = kv_pos[None, :] < Skv
        if causal:
            mask = jnp.logical_and(mask, q_pos[:, None] >= kv_pos[None, :])
        if window is not None:
            mask = jnp.logical_and(mask, q_pos[:, None] - kv_pos[None, :] < window)
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m_i, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask[None, None], p, 0.0)
        corr = jnp.exp(m_i - m_new)
        l_new = l_i * corr + p.sum(axis=-1)
        acc = acc * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vf)
        return (m_new, l_new, acc), None

    m0 = jnp.full((B, H, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    from ..common import match_vma

    carry0 = jax.tree.map(lambda t: match_vma(t, q), (m0, l0, a0))
    (m_i, l_i, acc), _ = jax.lax.scan(step, carry0, (kb, vb, jnp.arange(nkb)))
    out = acc / jnp.maximum(l_i, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)
