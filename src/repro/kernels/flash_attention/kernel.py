"""Flash-attention forward Pallas kernel (online softmax, causal/local).

TPU adaptation notes: the FPGA notion of a fully-pipelined attention datapath
becomes MXU-tiled block processing — (bq × d) query tiles resident in VMEM,
K/V streamed block-by-block through the innermost sequential grid dim with
running max/normaliser in VMEM scratch.  GQA is handled in the BlockSpec
index maps (query head -> shared KV head), so no repeated KV materialisation
ever touches HBM.  Supports causal masking and a sliding local window
(RecurrentGemma's 1:2 local-attention layers).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...compat import tpu_compiler_params

NEG_INF = -1e30


def _fa_kernel(
    q_ref, k_ref, v_ref, o_ref,
    m_ref, l_ref, acc_ref,
    *, nk: int, bq: int, bk: int, scale: float,
    causal: bool, window: int | None, skv: int,
):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # blocks that are entirely in the causal/window shadow are skipped
    # (the @pl.when guard keeps the schedule static but elides the FLOPs)
    q_first = iq * bq
    q_last = iq * bq + bq - 1
    k_first = ik * bk
    needed = True
    if causal:
        needed = k_first <= q_last
    if window is not None:
        k_last = ik * bk + bk - 1
        needed = jnp.logical_and(needed, k_last > q_first - window)

    @pl.when(needed)
    def _update():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, d)
        k = k_ref[0].astype(jnp.float32)                  # (bk, d)
        v = v_ref[0].astype(jnp.float32)                  # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )                                                  # (bq, bk)
        qpos = q_first + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_first + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < skv  # ignore zero-padded keys
        if causal:
            mask = jnp.logical_and(mask, qpos >= kpos)
        if window is not None:
            mask = jnp.logical_and(mask, qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                                # (bq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(m_prev - m_new)                     # (bq, 1)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,      # (B*H, Sq, D)
    k: jax.Array,      # (B*Hkv, Skv, D)
    v: jax.Array,      # (B*Hkv, Skv, D)
    *,
    n_q_heads: int,
    n_kv_heads: int,
    scale: float,
    causal: bool = True,
    window: int | None = None,
    skv_actual: int | None = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    BH, Sq, D = q.shape
    BKV, Skv, _ = k.shape
    H, Hkv = n_q_heads, n_kv_heads
    g = H // Hkv
    assert Sq % block_q == 0 and Skv % block_k == 0
    grid = (BH, Sq // block_q, Skv // block_k)
    skv = skv_actual if skv_actual is not None else Skv

    def kv_idx(bh, iq, ik):
        return ((bh // H) * Hkv + (bh % H) // g, ik, 0)

    kern = partial(
        _fa_kernel, nk=grid[2], bq=block_q, bk=block_k,
        scale=scale, causal=causal, window=window, skv=skv,
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, D), kv_idx),
            pl.BlockSpec((1, block_k, D), kv_idx),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
