"""jit'd public wrapper: layout handling, padding, dispatch."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..common import pad_to, resolve_use_pallas
from .kernel import flash_attention_pallas
from .ref import attention_ref, attention_chunked_ref


@partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "scale", "block_q", "block_k",
        "use_pallas", "interpret",
    ),
)
def flash_attention(
    q: jax.Array,  # (B, Sq, H, D)
    k: jax.Array,  # (B, Skv, Hkv, D)
    v: jax.Array,  # (B, Skv, Hkv, D)
    *,
    causal: bool = True,
    window: int | None = None,
    scale: float | None = None,
    block_q: int = 128,
    block_k: int = 128,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Multi-head attention with GQA; (B, S, H, D) layouts throughout."""
    if not resolve_use_pallas(use_pallas) and not interpret:
        if q.shape[1] * k.shape[1] > 2048 * 2048:
            return attention_chunked_ref(
                q, k, v, scale=scale, causal=causal, window=window
            )
        return attention_ref(q, k, v, scale=scale, causal=causal, window=window)

    B, Sq, H, D = q.shape
    _, Skv, Hkv, _ = k.shape
    scale = scale if scale is not None else D ** -0.5

    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, Skv, D)
    qf, _ = pad_to(qf, block_q, 1)
    kf, _ = pad_to(kf, block_k, 1)
    vf, _ = pad_to(vf, block_k, 1)

    out = flash_attention_pallas(
        qf, kf, vf,
        n_q_heads=H, n_kv_heads=Hkv, scale=scale,
        causal=causal, window=window, skv_actual=Skv,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    out = out[:, :Sq].reshape(B, H, Sq, D).transpose(0, 2, 1, 3)
    return out
