"""Pure-jnp oracle: sequential SSD recurrence (the definition)."""

import jax
import jax.numpy as jnp


def ssd_ref(x, dt, B, C, A):
    """x: (BH, S, Dh), dt: (BH, S), B/C: (BH, S, Dst), A: (BH, 1).

    h_t = exp(dt_t A) h_{t-1} + B_t (dt_t x_t);  y_t = C_t · h_t.
    """
    BH, S, Dh = x.shape
    Dst = B.shape[-1]

    def per_head(xh, dth, Bh, Ch, Ah):
        def step(h, inputs):
            xt, dtt, Bt, Ct = inputs
            h = jnp.exp(dtt * Ah[0]) * h + jnp.outer(Bt, dtt * xt)
            return h, Ct @ h

        h0 = jnp.zeros((Dst, Dh), jnp.float32)
        _, y = jax.lax.scan(
            step, h0,
            (xh.astype(jnp.float32), dth.astype(jnp.float32),
             Bh.astype(jnp.float32), Ch.astype(jnp.float32)),
        )
        return y

    y = jax.vmap(per_head)(x, dt, B, C, A)
    return y.astype(x.dtype)
