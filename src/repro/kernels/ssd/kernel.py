"""Mamba2 SSD (state-space duality) chunked-scan Pallas kernel.

The SSD recurrence  h_t = exp(dt_t·A) h_{t-1} + B_t (dt_t·x_t),
                    y_t = C_t · h_t
is computed chunk-by-chunk (arXiv:2405.21060): within a chunk the output is a
masked, decay-weighted quadratic form (MXU work — "attention duality"), and
the chunk boundary state is carried through the innermost sequential grid
dimension in VMEM scratch — the same carry pattern the matmul kernel uses
for K blocks.  Numerically safe for A < 0, dt > 0 (all exponents ≤ 0).

Grid: (B*H, n_chunks); one (L × Dh) x-tile and (L × Dst) B/C tiles per step.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ...compat import tpu_compiler_params


def _ssd_kernel(
    x_ref,      # (1, L, Dh)
    dt_ref,     # (1, L)
    b_ref,      # (1, L, Dst)
    c_ref,      # (1, L, Dst)
    a_ref,      # (1, 1)  A (negative) for this head
    y_ref,      # (1, L, Dh)
    state_ref,  # VMEM (Dst, Dh) carry
    *, n_chunks: int, L: int,
):
    c_i = pl.program_id(1)

    @pl.when(c_i == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0].astype(jnp.float32)          # (L, Dh)
    dt = dt_ref[0].astype(jnp.float32)[:, None]   # (L, 1)
    B = b_ref[0].astype(jnp.float32)          # (L, Dst)
    C = c_ref[0].astype(jnp.float32)          # (L, Dst)
    A = a_ref[0, 0].astype(jnp.float32)       # scalar

    a = dt * A                                # (L, 1) decay logs (<= 0)
    cum = jnp.cumsum(a, axis=0)               # (L, 1)
    xd = x * dt                               # dt-weighted input

    # intra-chunk: y1[i] = sum_{j<=i} (C_i·B_j) exp(cum_i - cum_j) xd_j
    G = jax.lax.dot_general(C, B, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (L, L)
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    decay = jnp.exp(cum - cum.T)              # (L, L)
    scores = jnp.where(ii >= jj, G * decay, 0.0)
    y1 = jax.lax.dot_general(scores, xd, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (L, Dh)

    # inter-chunk: y2[i] = exp(cum_i) C_i · h_in
    h_in = state_ref[...]                      # (Dst, Dh)
    y2 = jnp.exp(cum) * jax.lax.dot_general(
        C, h_in, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                          # (L, Dh)

    y_ref[0] = (y1 + y2).astype(y_ref.dtype)

    # state out: h = exp(cum_L) h_in + sum_j exp(cum_L - cum_j) B_j ⊗ xd_j
    last = cum[L - 1]                          # (1,)
    w = jnp.exp(last[None, :] - cum)           # (L, 1)
    state_ref[...] = jnp.exp(last)[:, None] * h_in + jax.lax.dot_general(
        B * w, xd, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def ssd_pallas(
    x: jax.Array,    # (BH, S, Dh)
    dt: jax.Array,   # (BH, S)
    B: jax.Array,    # (BH, S, Dst)
    C: jax.Array,    # (BH, S, Dst)
    A: jax.Array,    # (BH, 1)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    BH, S, Dh = x.shape
    Dst = B.shape[-1]
    assert S % chunk == 0
    n_chunks = S // chunk
    kern = partial(_ssd_kernel, n_chunks=n_chunks, L=chunk)
    return pl.pallas_call(
        kern,
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, Dh), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk), lambda b, c: (b, c)),
            pl.BlockSpec((1, chunk, Dst), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, Dst), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1), lambda b, c: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, Dh), lambda b, c: (b, c, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, Dh), x.dtype),
        scratch_shapes=[pltpu.VMEM((Dst, Dh), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, dt, B, C, A)
