"""jit'd wrapper for SSD: padding + dispatch + single-step decode path."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..common import resolve_use_pallas
from .kernel import ssd_pallas
from .ref import ssd_ref


@partial(jax.jit, static_argnames=("chunk", "use_pallas", "interpret"))
def ssd_scan(
    x: jax.Array,    # (BH, S, Dh)
    dt: jax.Array,   # (BH, S)
    B: jax.Array,    # (BH, S, Dst)
    C: jax.Array,    # (BH, S, Dst)
    A: jax.Array,    # (BH, 1)
    *,
    chunk: int = 128,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Full-sequence SSD scan (training / prefill)."""
    if not resolve_use_pallas(use_pallas) and not interpret:
        return _ssd_chunked_jnp(x, dt, B, C, A, chunk=chunk)
    S = x.shape[1]
    pad = (-S) % chunk
    if pad:
        widths3 = ((0, 0), (0, pad), (0, 0))
        x = jnp.pad(x, widths3)
        dt = jnp.pad(dt, ((0, 0), (0, pad)))
        B = jnp.pad(B, widths3)
        C = jnp.pad(C, widths3)
    out = ssd_pallas(x, dt, B, C, A, chunk=chunk, interpret=interpret)
    return out[:, : S]


def _ssd_chunked_jnp(x, dt, B, C, A, *, chunk=128):
    """Chunked SSD in pure jnp (same math as the kernel; fast on CPU via
    lax.scan over chunks).  Used as the non-TPU dispatch path so models keep
    identical numerics to the kernel."""
    BH, S, Dh = x.shape
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    Sp = x.shape[1]
    n = Sp // chunk
    Dst = B.shape[-1]

    xc = x.reshape(BH, n, chunk, Dh).astype(jnp.float32)
    dtc = dt.reshape(BH, n, chunk, 1).astype(jnp.float32)
    Bc = B.reshape(BH, n, chunk, Dst).astype(jnp.float32)
    Cc = C.reshape(BH, n, chunk, Dst).astype(jnp.float32)
    Af = A.astype(jnp.float32)  # (BH, 1)

    a = dtc * Af[:, None, :, None][..., 0:1]          # (BH, n, L, 1)
    cum = jnp.cumsum(a, axis=2)
    xd = xc * dtc

    ii = jnp.arange(chunk)[:, None]
    jj = jnp.arange(chunk)[None, :]
    tri = ii >= jj

    G = jnp.einsum("bnld,bnmd->bnlm", Cc, Bc)
    decay = jnp.exp(cum - jnp.swapaxes(cum, 2, 3))
    scores = jnp.where(tri[None, None], G * decay, 0.0)
    y1 = jnp.einsum("bnlm,bnmd->bnld", scores, xd)

    last = cum[:, :, -1:]                              # (BH, n, 1, 1)
    w = jnp.exp(last - cum)                            # (BH, n, L, 1)
    chunk_state = jnp.einsum("bnls,bnld->bnsd", Bc * w, xd)  # (BH,n,Dst,Dh)
    chunk_decay = jnp.exp(last[..., 0, 0])             # (BH, n)

    def boundary(h, inp):
        st, dec = inp
        h_new = dec[:, None, None] * h + st
        return h_new, h

    from ..common import match_vma

    h0 = match_vma(jnp.zeros((BH, Dst, Dh), jnp.float32), chunk_state)
    _, h_in = jax.lax.scan(
        boundary, h0,
        (chunk_state.transpose(1, 0, 2, 3), chunk_decay.T),
    )
    h_in = h_in.transpose(1, 0, 2, 3)                  # (BH, n, Dst, Dh)

    y2 = jnp.exp(cum) * jnp.einsum("bnls,bnsd->bnld", Cc, h_in)
    y = (y1 + y2).reshape(BH, Sp, Dh)[:, :S]
    return y.astype(x.dtype)


@jax.jit
def ssd_decode_step(h, xt, dtt, Bt, Ct, A):
    """Single-token decode: h (BH, Dst, Dh), xt (BH, Dh), dtt (BH,),
    Bt/Ct (BH, Dst) -> (h', y (BH, Dh)).  O(1) state — the reason mamba2
    runs the long_500k shape."""
    hf = h.astype(jnp.float32)
    dec = jnp.exp(dtt[:, None] * A[:, 0:1])            # (BH, 1)
    upd = jnp.einsum("bs,bd->bsd", Bt.astype(jnp.float32),
                     (dtt[:, None] * xt).astype(jnp.float32))
    h_new = dec[..., None] * hf + upd
    y = jnp.einsum("bs,bsd->bd", Ct.astype(jnp.float32), h_new)
    return h_new.astype(h.dtype), y.astype(xt.dtype)
