"""Fused transport: static schedule + Pallas shift-accumulate (DESIGN.md §3.3).

The ring collectives' hot path is ``acc = shift(acc) + partial`` repeated
P-1 times.  On TPU the add runs on the VPU while the *next* ppermute's ICI
transfer is already in flight; fusing the receive-side add into one Pallas
VMEM kernel removes the extra HBM round-trip XLA would otherwise emit
between the collective-permute done and the add.  Off TPU (CPU/GPU tests)
the step falls back to ``lax.ppermute`` + ``jnp`` add — bit-identical, so
backend equivalence tests cover this path too.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from ..kernels.common import on_tpu
from .registry import register_transport
from .static import StaticTransport

# VPU-native tile: 8 sublanes x 128 lanes (f32).
_LANES = 128
_SUBLANES = 8
_BLOCK_ROWS = 512


def _accum_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] + b_ref[...]


@partial(jax.jit, static_argnames=("interpret",))
def fused_accumulate(a: jax.Array, b: jax.Array, *, interpret: bool = False):
    """``a + b`` as a single VMEM-tiled Pallas kernel (any shape/dtype).

    Flattens to (rows, 128) f32-tile-aligned blocks; the padding rows are
    zeros on both sides so the result slice is exact.
    """
    from jax.experimental import pallas as pl

    assert a.shape == b.shape and a.dtype == b.dtype
    n = a.size
    tile = _SUBLANES * _LANES
    rows = max((n + _LANES - 1) // _LANES, _SUBLANES)
    rows = ((rows + _SUBLANES - 1) // _SUBLANES) * _SUBLANES
    pad = rows * _LANES - n
    af = jnp.pad(a.reshape(-1), (0, pad)).reshape(rows, _LANES)
    bf = jnp.pad(b.reshape(-1), (0, pad)).reshape(rows, _LANES)
    block = min(_BLOCK_ROWS, rows)
    # grid rows must divide evenly; fall back to one whole-array block
    if rows % block:
        block = rows
    out = pl.pallas_call(
        _accum_kernel,
        grid=(rows // block,),
        in_specs=[
            pl.BlockSpec((block, _LANES), lambda i: (i, 0)),
            pl.BlockSpec((block, _LANES), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block, _LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, _LANES), a.dtype),
        interpret=interpret,
    )(af, bf)
    return out.reshape(-1)[:n].reshape(a.shape)


@register_transport("fused")
@dataclass
class FusedTransport(StaticTransport):
    """Static schedules with the receive+accumulate step fused on TPU.

    ``use_pallas=None`` auto-selects (TPU: kernel, elsewhere: jnp);
    ``interpret=True`` forces the kernel through the Pallas interpreter for
    CPU validation.
    """

    use_pallas: bool | None = None
    interpret: bool = False

    def _fuse(self) -> bool:
        return on_tpu() if self.use_pallas is None else self.use_pallas

    def accumulate(self, a, b):
        """Tiled-VMEM add: every reduction-combine the collective layer
        routes through :meth:`Transport.accumulate` lands on the kernel,
        not just the shift-adjacent one."""
        if not (self._fuse() or self.interpret):
            return jax.tree.map(lambda x, y: x + y, a, b)
        return jax.tree.map(
            lambda x, y: fused_accumulate(x, y, interpret=self.interpret),
            a, b,
        )

    def shift_accumulate(self, x, addend, comm, step: int = 1):
        return self.accumulate(self.shift(x, comm, step), addend)
