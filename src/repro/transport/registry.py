"""String-keyed transport registry + comm_mode parsing.

The registry is the runtime-reconfigurability seam: call sites name their
backend with a string (``"static"``, ``"packet"``, ``"fused"``,
``"compressed"``), carried in ``Communicator.transport`` or a ``comm_mode``
like ``"smi:packet"``, and the same compiled collective call site runs over
whichever backend the string selects — the TPU rendering of the paper's
"upload new routing tables, keep the bitstream".

Wrapper backends compose by key: a class registered with a true
``wraps_inner`` attribute (``CompressedTransport``) accepts
``"<wrapper>:<inner>"`` keys — ``"compressed:packet"`` is the int8
compressed wire over the dynamic router; bare ``"compressed"`` wraps the
default static backend.  comm_mode grows the same spelling:
``"smi:compressed"`` / ``"smi:compressed:packet"``.
"""

from __future__ import annotations

from typing import Union

_REGISTRY: dict[str, type] = {}

#: transport key used when a comm_mode / Communicator doesn't name one
DEFAULT_TRANSPORT = "static"


def register_transport(name: str):
    """Class decorator: register a Transport subclass under ``name``."""

    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def _ensure_builtins():
    if "static" not in _REGISTRY:
        from . import compressed, fused, packet, static  # noqa: F401


def available_transports() -> tuple[str, ...]:
    _ensure_builtins()
    return tuple(sorted(_REGISTRY))


def _split_wrapper(key: str):
    """``"compressed:packet"`` -> (wrapper_cls, "packet"); None otherwise."""
    outer, _, inner = key.partition(":")
    cls = _REGISTRY.get(outer)
    if inner and cls is not None and getattr(cls, "wraps_inner", False) \
            and inner in _REGISTRY:
        return cls, inner
    return None


def is_transport_key(key: str) -> bool:
    """True when ``key`` names a registered backend, including composed
    ``"<wrapper>:<inner>"`` forms."""
    _ensure_builtins()
    return key in _REGISTRY or _split_wrapper(key) is not None


def get_transport(name: str | None = None, **kw):
    """New Transport instance for ``name`` (None -> DEFAULT_TRANSPORT).

    Under :func:`repro.analysis.capture` every key resolves to the
    abstract accounting backend — the registry is the second seam (after
    ``ChannelSpec.resolve``) that keeps capture-mode verification from
    moving a single byte, covering call sites that name backends by
    string (``resolve_transport``, the ``stream_*`` schedules)."""
    import sys

    cap = sys.modules.get("repro.analysis.capture")
    if cap is not None and cap.ACTIVE:
        return cap.AbstractTransport()
    _ensure_builtins()
    key = name or DEFAULT_TRANSPORT
    if key in _REGISTRY:
        return _REGISTRY[key](**kw)
    wrapped = _split_wrapper(key)
    if wrapped is not None:
        cls, inner = wrapped
        return cls(inner=inner, **kw)
    raise KeyError(
        f"unknown transport {key!r}; available: {available_transports()} "
        "(wrapper backends compose as '<wrapper>:<inner>', "
        "e.g. 'compressed:packet')"
    )


def resolve_transport(transport, comm=None):
    """Per-call resolution: explicit object > explicit key > communicator's
    key > default.  Accepts a Transport instance, a string key, or None."""
    from .base import Transport

    if isinstance(transport, Transport):
        return transport
    if transport is None and comm is not None:
        transport = getattr(comm, "transport", None)
    return get_transport(transport)


def resolve_comm_mode(mode: Union[str, None]) -> tuple[str, str]:
    """Split a comm_mode string into (base_mode, transport_key).

    ``"smi"`` -> ("smi", "static"); ``"smi:packet"`` -> ("smi", "packet");
    ``"smi:compressed:packet"`` -> ("smi", "compressed:packet");
    ``"bulk"`` / ``"none"`` pass through with the default transport key
    (unused there).  Unknown bases or transports raise.
    """
    if not mode:
        return "none", DEFAULT_TRANSPORT
    base, _, backend = mode.partition(":")
    if base not in ("smi", "bulk", "none"):
        raise ValueError(f"unknown comm_mode base {base!r} in {mode!r}")
    if not backend:
        return base, DEFAULT_TRANSPORT
    if base != "smi":
        raise ValueError(
            f"comm_mode {mode!r}: only 'smi' takes a transport backend"
        )
    if not is_transport_key(backend):
        raise ValueError(
            f"comm_mode {mode!r}: unknown transport {backend!r}; "
            f"available: {available_transports()}"
        )
    return base, backend
