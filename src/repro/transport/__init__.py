"""Pluggable transport backends (DESIGN.md §3).

The paper's central flexibility claim is that *routing is data, not
program*: the compiled design (bitstream / XLA executable) is fixed, and
what moves messages — static schedules, a packet-switched router, a fused
hot path — is a swappable layer underneath one interface.  This package is
that layer:

* :class:`~repro.transport.base.Transport` — the protocol every backend
  implements: ring ``shift``, explicit-pairs ``permute``, the fused
  ``shift_accumulate`` hot-path hook, routed ``p2p``, and per-step
  cost/overflow counters.
* :func:`~repro.transport.registry.get_transport` /
  :func:`~repro.transport.registry.register_transport` — the string-keyed
  registry.  Built-ins: ``"static"`` (trace-time routed ppermute
  schedules), ``"packet"`` (the dynamic store-and-forward router run end
  to end), ``"fused"`` (static schedules with a Pallas shift+accumulate
  step on TPU), ``"compressed"`` / ``"compressed:<inner>"`` (int8 wire
  compression with blockwise scales and error feedback over any inner
  backend, DESIGN.md §7).
* :func:`~repro.transport.registry.resolve_comm_mode` — parses the
  ``comm_mode`` strings used across launch/configs/benchmarks
  (``"smi:packet"`` → SMI collectives over the packet backend).

Every collective in :mod:`repro.core.collectives` and every overlap engine
in :mod:`repro.core.overlap` dispatches through a Transport, so one call
site runs unchanged over all backends — selected per
:class:`~repro.core.comm.Communicator` (its ``transport=`` field) or per
call (the ``transport=`` keyword).
"""

from .base import Transport, TransportStats
from .registry import (
    available_transports,
    get_transport,
    is_transport_key,
    register_transport,
    resolve_comm_mode,
    resolve_transport,
)

__all__ = [
    "Transport",
    "TransportStats",
    "available_transports",
    "get_transport",
    "is_transport_key",
    "register_transport",
    "resolve_comm_mode",
    "resolve_transport",
]
