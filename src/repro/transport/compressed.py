"""Compressed-link transport: int8 wire format over any inner backend
(DESIGN.md §7).

The int8 codec that used to live as ad-hoc ``quantize=``/``dequantize=``
kwargs on the ring collectives, generalised to the transport the layer was
built for: :class:`CompressedTransport` wraps an *inner* backend (static /
packet / fused) and quantises payloads at the send edge of every logical
step — ``shift`` / ``permute`` / ``shift_accumulate`` / ``p2p`` — and
dequantises at the receive edge.  Registry keys: ``"compressed"`` (static
inner) and ``"compressed:<inner>"``; comm_mode ``"smi:compressed"``.

Wire format (per pytree leaf): the payload flattens to f32, splits into
``axis_elems``-sized blocks, and each block carries one f32 scale
(``max|block| / 127``) beside its int8 values.  On the wire the int8
payload and the bitcast scale sidecar travel as one flat int8 vector, so
every inner backend moves it unchanged (the packet router's f32 wire
carries int8 values exactly) and ``TransportStats`` counts the true wire
bytes — ``n + 4 * ceil(n / axis_elems)`` per leaf, the exact figure
:func:`repro.netsim.model.int8_wire_nbytes` predicts — because the inner
backend accounts the wire pytree it actually moves.

Requantisation of an already-quantised block is exact (the block max maps
back to +/-127, reproducing the same scale and codes), so multi-hop chains
(bcast, staged, allgather) pay quantisation error once, not once per hop.

The ring reduce-scatter fix: re-rounding a travelling partial sum once per
hop compounds quantisation error with the ring size P (the quantisation
grid is proportional to the growing accumulator), and no per-hop trick can
undo that — so the compressed wire does not transmit accumulators at all.
:meth:`CompressedTransport.send_contribution` quantises each hop's
*transmitted contribution* exactly once (with per-instance error-feedback
residuals: transmit ``Q(c + e)``, carry ``e' = (c + e) - dq(Q(c + e))``),
and ``stream_reduce_scatter`` ships it straight to its home rank with a
distance-s ring permute, summing dequantised contributions in f32.  Each
value on the wire is rounded once on its own (P-independent) grid, so the
reduced blocks' error is bounded independent of P — regression-tested in
tests/test_compressed.py.  The residual is a traced value, so it is keyed
to the live jax trace and silently resets to zero when the instance is
reused in a new trace (resetting is always correct: error feedback is an
accuracy aid, never a correctness dependency).
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax

from ..netsim.model import WIRE_AXIS_ELEMS, clamp_chunks
from .base import Transport
from .registry import register_transport


# ------------------------------------------------------------------ codec


def _n_blocks(n: int, axis_elems: int) -> int:
    return -(-n // axis_elems) if n else 0


def _block_elems(n: int, axis_elems: int | None) -> int:
    """Effective block size: ``None`` means one scale for the whole tensor
    (the legacy per-tensor codec); otherwise clamp to the element count."""
    if axis_elems is None:
        return max(n, 1)
    return max(1, min(int(axis_elems), max(n, 1)))


def quantize_int8(v, axis_elems: int | None = WIRE_AXIS_ELEMS):
    """``v`` (any shape, floating) -> ``(q, scales)``: int8 codes shaped
    like ``v`` plus one f32 scale per ``axis_elems``-sized block of the
    flattened payload (``None`` = a single per-tensor scale)."""
    if not jnp.issubdtype(v.dtype, jnp.floating):
        raise TypeError(
            f"int8 wire compression carries floating payloads; got {v.dtype} "
            "(a lossy wire on integer data would silently corrupt it)"
        )
    flat = v.reshape(-1).astype(jnp.float32)
    n = flat.size
    ae = _block_elems(n, axis_elems)
    nb = _n_blocks(n, ae)
    blocks = jnp.pad(flat, (0, nb * ae - n)).reshape(nb, ae)
    scales = jnp.maximum(jnp.max(jnp.abs(blocks), axis=1), 1e-8) / 127.0
    q = jnp.clip(jnp.round(blocks / scales[:, None]), -127, 127)
    q = q.astype(jnp.int8).reshape(-1)[:n].reshape(v.shape)
    return q, scales


def dequantize_int8(wire, axis_elems: int | None = WIRE_AXIS_ELEMS):
    """Inverse of :func:`quantize_int8` (f32 result, shaped like ``q``)."""
    q, scales = wire
    n = q.size
    ae = _block_elems(n, axis_elems)
    per_elem = jnp.repeat(scales, ae)[:n].reshape(q.shape)
    return q.astype(jnp.float32) * per_elem


def _pack_wire(q, scales):
    """(q int8, scales f32) -> one flat int8 vector: payload then the
    scales bitcast byte-by-byte.  A single sub-32-bit leaf rides every
    inner backend (incl. the packet router's f32 wire) exactly."""
    sb = lax.bitcast_convert_type(scales, jnp.int8).reshape(-1)
    return jnp.concatenate([q.reshape(-1), sb])


def _unpack_wire(wire, shape, n_blocks: int):
    n = 1
    for d in shape:
        n *= int(d)
    q = wire[:n].reshape(shape)
    scales = lax.bitcast_convert_type(
        wire[n:].reshape(n_blocks, 4), jnp.float32
    )
    return q, scales


def _trace_token(v):
    return getattr(v, "_trace", None)


# -------------------------------------------------------------- transport


@register_transport("compressed")
@dataclass
class CompressedTransport(Transport):
    """int8 compressed links over any inner backend.

    ``inner`` is a registry key or Transport instance (the wrapper adopts
    its stats object, so steps/bytes tally in one place and the byte count
    is automatically the wire's — int8 payload + scale sidecar, not f32).
    ``axis_elems`` is the scale-block size (``None`` = per-tensor scale);
    ``error_feedback`` enables the residual-carrying ``send_contribution``
    hot path; ``codec`` overrides the built-in int8 codec with a legacy
    ``(quantize, dequantize)`` pair (the deprecated-kwargs shim — shift
    paths only, arbitrary wire pytrees, no packed accounting guarantees).
    """

    inner: object = "static"
    axis_elems: int | None = WIRE_AXIS_ELEMS
    error_feedback: bool = True
    codec: tuple | None = None

    #: results differ from the raw wire within the codec error bound —
    #: callers needing exactness (integer payloads) must check this
    lossy_wire = True
    #: registry marker: "compressed:<inner>" keys construct this class
    wraps_inner = True

    def __post_init__(self):
        from .registry import get_transport

        if not isinstance(self.inner, Transport):
            self.inner = get_transport(self.inner or "static")
        # one shared counter object; adopt the inner's so an instance
        # passed in with prior tallies keeps accumulating into them
        self.stats = self.inner.stats
        self.runtime_stats = self.inner.runtime_stats
        self._ef = None  # error-feedback residuals (traced; trace-keyed)

    # ------------------------------------------------------------- wire

    def _encode(self, v):
        if self.codec is not None:
            return self.codec[0](v)
        q, scales = quantize_int8(v, self.axis_elems)
        return _pack_wire(q, scales)

    def _decode_f32(self, wire, ref):
        """Wire -> f32 payload shaped like ``ref`` (no dtype cast)."""
        if self.codec is not None:
            return self.codec[1](wire)
        nb = _n_blocks(ref.size, _block_elems(ref.size, self.axis_elems))
        q, scales = _unpack_wire(wire, ref.shape, nb)
        return dequantize_int8((q, scales), self.axis_elems)

    def _decode(self, wire, ref):
        return self._decode_f32(wire, ref).astype(ref.dtype)

    # ------------------------------------------------------------- steps

    def permute(self, x, comm, pairs):
        leaves, treedef = jax.tree.flatten(x)
        moved = self.inner.permute([self._encode(l) for l in leaves],
                                   comm, pairs)
        return jax.tree.unflatten(
            treedef, [self._decode(w, l) for w, l in zip(moved, leaves)]
        )

    def shift_accumulate(self, x, addend, comm, step: int = 1):
        """Generic lossy hot path: ``dq(shift(Q(x))) + addend`` in f32.

        This re-rounds a travelling accumulator and therefore compounds
        error with the hop count — ``stream_reduce_scatter`` does NOT use
        it on lossy wires (it dispatches to :meth:`send_contribution`'s
        once-quantised schedule instead); it exists so generic callers of
        the Transport protocol keep working over a compressed link.
        """
        moved = self.shift(x, comm, step)
        return jax.tree.map(
            lambda a, b: a.astype(jnp.float32) + b.astype(jnp.float32),
            moved, addend,
        )

    def send_contribution(self, c, comm, step: int = 1):
        """Quantise ``c`` exactly once (with error feedback) and ship it a
        logical ring distance ``step``; returns the dequantised f32 arrival.

        The compressed ring reduce-scatter's inner step: the wire carries
        each hop's *transmitted contribution* — never a partial sum — so
        every value is rounded once on its own (P-independent) grid.  The
        per-instance residual ``e`` feeds this rank's rounding error into
        its next transmission (EF-SGD semantics across hops and across
        repeated syncs on one instance).
        """
        leaves, treedef = jax.tree.flatten(c)
        if self.error_feedback:
            ef = self._ef_residuals(leaves)
            sends = [l.astype(jnp.float32) + e for l, e in zip(leaves, ef)]
        else:
            sends = [l.astype(jnp.float32) for l in leaves]
        wires = [self._encode(u) for u in sends]
        if self.error_feedback:
            # residual against the *local* wire: the permute moves the
            # int8 codes bit-exactly, so this equals what the destination
            # rank dequantises
            self._ef = [
                u - self._decode_f32(w, u) for u, w in zip(sends, wires)
            ]
        moved = self.inner.permute(wires, comm, comm.ring_perm(step))
        return jax.tree.unflatten(
            treedef,
            [self._decode_f32(w, u) for w, u in zip(moved, sends)],
        )

    def p2p(self, x, *, src, dst, comm, n_chunks: int = 1):
        if self.codec is not None:
            raise NotImplementedError(
                "custom-codec CompressedTransport supports shift/permute "
                "paths only; use the built-in int8 codec for p2p"
            )
        if src == dst:
            return x
        q, scales = quantize_int8(x, self.axis_elems)
        wire = _pack_wire(q, scales)
        nc = clamp_chunks(n_chunks, wire.shape[0])
        got = self.inner.p2p(wire, src=src, dst=dst, comm=comm, n_chunks=nc)
        nb = _n_blocks(x.size, _block_elems(x.size, self.axis_elems))
        gq, gs = _unpack_wire(got, x.shape, nb)
        return dequantize_int8((gq, gs), self.axis_elems).astype(x.dtype)

    # ---------------------------------------------------- EF state mgmt

    def _ef_residuals(self, leaves):
        """Current residuals, or zeros when absent/stale.  Staleness =
        shape mismatch or a residual traced in a different (dead) jax
        trace; resetting to zero is always correct."""
        prev = self._ef
        if (
            prev is not None
            and len(prev) == len(leaves)
            and all(p.shape == l.shape for p, l in zip(prev, leaves))
            and all(
                _trace_token(p) is _trace_token(l)
                for p, l in zip(prev, leaves)
            )
        ):
            return prev
        return [jnp.zeros(l.shape, jnp.float32) for l in leaves]

    def reset_state(self):
        """Drop error-feedback residuals (fresh collective / new trace)."""
        self._ef = None

    def reset_stats(self):
        super().reset_stats()
        self.inner.stats = self.stats
        self.reset_state()
