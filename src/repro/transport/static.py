"""Static transport: trace-time routed ppermute schedules (DESIGN.md §3.1).

The fast path.  Every logical step lowers to exactly one ``lax.ppermute``
on the communicator's axes, so XLA sees a fixed link schedule it can
software-pipeline; routing decisions were already burnt into the schedule
at trace time from the communicator's route table.  This is the code that
used to live inline in ``core/streaming.py`` — moved here so the packet
and fused backends can slot in under the same call sites.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax

from .base import Transport
from .registry import register_transport


@register_transport("static")
@dataclass
class StaticTransport(Transport):
    """One ppermute per step; the collectives' trace-time default."""

    def permute(self, x, comm, pairs):
        self.account(x)
        return jax.tree.map(lambda v: lax.ppermute(v, comm.axis, pairs), x)

    def p2p(self, x, *, src, dst, comm, n_chunks: int = 1):
        """Chunk-pipelined multi-hop transfer (paper §3.1 / Fig. 9).

        The message splits along axis 0 into ``n_chunks`` chunks that move
        through the routed pipe one hop per step, all hops advancing in
        parallel — one ppermute per step carrying every in-flight chunk
        (asynchronicity degree k of §3.3 = path length)."""
        from ..core.streaming import _mask_sel, _pvary

        if src == dst:
            return x
        path = comm.route_table.path(src, dst)
        hops = len(path) - 1
        pairs = comm.path_perm(path)

        S = x.shape[0]
        assert S % n_chunks == 0, (
            f"leading dim {S} not divisible by n_chunks={n_chunks}"
        )
        csz = S // n_chunks
        r = comm.rank()
        steps = n_chunks + hops - 1

        def body(t, carry):
            y, pipe = carry
            # Source loads chunk t (clamped; masked to src and t < n_chunks).
            load_idx = jnp.minimum(t, n_chunks - 1) * csz
            inj = lax.dynamic_slice_in_dim(x, load_idx, csz, axis=0)
            use_inj = jnp.logical_and(r == path[0], t < n_chunks)
            pipe = _mask_sel(use_inj, inj, pipe)
            # One pipeline shift: every hop advances.
            pipe = jax.tree.map(
                lambda v: lax.ppermute(v, comm.axis, pairs), pipe
            )
            # Destination stores chunk (t - hops + 1) when it arrives.
            c_out = t - (hops - 1)
            store = jnp.logical_and(r == path[-1], c_out >= 0)
            upd = lax.dynamic_update_slice_in_dim(
                y, pipe, jnp.maximum(c_out, 0) * csz, axis=0
            )
            y = _mask_sel(store, upd, y)
            return y, pipe

        y0 = _pvary(jnp.zeros_like(x), comm)
        pipe0 = _pvary(jnp.zeros((csz,) + x.shape[1:], x.dtype), comm)
        self.account(
            jax.eval_shape(lambda: jnp.zeros((csz,) + x.shape[1:], x.dtype)),
            steps=steps,
        )
        y, _ = lax.fori_loop(0, steps, body, (y0, pipe0))
        return y
