"""Packet transport: collectives over the dynamic router (DESIGN.md §3.2).

The flexibility path.  Every logical step — ring shift, explicit
permutation, routed p2p — is executed *end-to-end* by the store-and-forward
packet router of :mod:`repro.core.router`: payloads are packetised
(``pkt_elems`` f32 per packet + dst header), staged into the input FIFOs,
and the router runs enough cycles over the fixed physical link schedule to
deliver everything; arrivals are reassembled into the same arrays the
static backend would have produced.  Routing tables are runtime data, so
swapping the communicator's logical topology (torus → snake bus) re-routes
the exact same compiled collective — the paper's §5.3.1 experiment at the
collective level, not just for raw packets.

Delivery guarantees relied on for reassembly:

* each ``permute`` is a partial permutation (unique sources and unique
  destinations), so a receiver drains exactly one stream;
* packets of one stream follow one fixed route through FIFO queues, so
  they arrive in order;
* ``n_steps`` is a static worst-case bound (max hops + serialisation on
  the most contended link), so a lossless run delivers everything — the
  router's overflow counter *plus any delivery shortfall at the schedule's
  end* is accumulated into :attr:`Transport.stats` and equals 0 for every
  in-capacity run (asserted by tests).
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..obs import trace as obs
from .base import Transport, tree_bytes
from .registry import register_transport

# ------------------------------------------------------------------ wire


def _encode(leaf: jax.Array) -> jax.Array:
    """Leaf -> flat f32 wire vector, bit-exactly invertible for <=32-bit
    types (floats widen exactly; 32-bit ints ride as raw bits)."""
    assert leaf.dtype.itemsize <= 4, (
        f"packet wire format carries <=32-bit elements; got {leaf.dtype} "
        "(a 64-bit payload would silently truncate through the f32 wire)"
    )
    flat = leaf.reshape(-1)
    if leaf.dtype == jnp.float32:
        return flat
    if leaf.dtype in (jnp.int32, jnp.uint32):
        return lax.bitcast_convert_type(flat, jnp.float32)
    return flat.astype(jnp.float32)


def _decode(vec: jax.Array, shape, dtype) -> jax.Array:
    if dtype == jnp.float32:
        return vec.reshape(shape)
    if dtype in (jnp.int32, jnp.uint32):
        return lax.bitcast_convert_type(vec, dtype).reshape(shape)
    return vec.astype(dtype).reshape(shape)


# ------------------------------------------------------------- transport

#: router-table cache bound: the key includes the route table's bytes, so a
#: long-lived transport sweeping topologies would otherwise grow without
#: limit.  8 comfortably covers a working set of fabrics in flight.
TBL_CACHE_MAX = 8


def lru_get(cache: dict, key, make, cap: int = TBL_CACHE_MAX):
    """Tiny LRU on a plain (insertion-ordered) dict: hit moves the entry to
    the back; a miss past ``cap`` evicts the front (least recent)."""
    if key in cache:
        cache[key] = cache.pop(key)  # refresh recency
        return cache[key]
    while len(cache) >= max(int(cap), 1):
        cache.pop(next(iter(cache)))
    val = cache[key] = make()
    return val


@register_transport("packet")
@dataclass
class PacketTransport(Transport):
    """Store-and-forward packet router as a Transport backend.

    ``pkt_elems`` scales the paper's 28 B network packet to a TPU-friendly
    payload; ``slack_steps`` pads the static delivery-time bound (left at
    the default it simply costs a few bubble cycles).  ``router_impl``
    picks the router datapath (``core/router.py``: "scalar" | "vector" |
    "pallas"; None auto-selects pallas on TPU, vector elsewhere).
    """

    pkt_elems: int = 32
    slack_steps: int = 4
    #: override the computed worst-case transit queue depth (tests use a
    #: deliberately undersized queue to prove the overflow counter fires)
    transit_cap: int | None = None
    runtime_stats: bool = True
    router_impl: str | None = None
    _tbl_cache: dict = field(default_factory=dict, repr=False)

    # -- routing-table + schedule bounds (static, per communicator) ------

    def _phys_dims(self, comm) -> tuple[int, ...]:
        # The physical fabric is the torus implied by the mesh axes.
        return tuple(comm.axis_sizes)

    def _route_table(self, comm) -> jax.Array:
        from ..core.router import make_router_tables

        # key on the actual connection lists AND the route-table bytes —
        # two `from_edges` topologies share name="custom", and one link set
        # admits different route tables (DOR vs BFS tie-breaks)
        key = (
            comm.axis_sizes,
            comm.topology.links,
            comm.route_table.next_hop.tobytes(),
        )
        # derive from the communicator's own route table so the router
        # follows exactly the paths _bounds() analysed (a comm created
        # with routing_scheme="bfs" must not get fresh DOR routes)
        tbl = lru_get(self._tbl_cache, key, lambda: np.asarray(
            make_router_tables(
                comm.topology, self._phys_dims(comm), rt=comm.route_table
            )
        ))
        return jnp.asarray(tbl)

    def _bounds(self, comm, active_pairs, n_packets: int):
        """(n_steps, transit_cap): static worst-case delivery bounds.

        n_steps: longest route + full serialisation of the most contended
        directed link (each link moves one packet per cycle).
        transit_cap: most packets that can ever be parked at one rank.
        """
        edge_load: dict[tuple[int, int], int] = {}
        transit_load = np.zeros(comm.size, np.int64)
        max_hops = 1
        for s, d in active_pairs:
            path = comm.route_table.path(s, d)
            max_hops = max(max_hops, len(path) - 1)
            for a, b in zip(path[:-1], path[1:]):
                edge_load[(a, b)] = edge_load.get((a, b), 0) + 1
            for mid in path[1:-1]:
                transit_load[mid] += 1
        max_edge = max(edge_load.values(), default=1)
        n_steps = max_hops + n_packets * max_edge + self.slack_steps
        transit_cap = self.transit_cap
        if transit_cap is None:
            transit_cap = max(4, n_packets * int(transit_load.max()) + 2)
        return n_steps, transit_cap

    # ------------------------------------------------------------- steps

    def permute(self, x, comm, pairs):
        from ..core.router import RouterConfig, run_router

        n = comm.size
        pairs = [(int(s), int(d)) for s, d in pairs]
        active = [(s, d) for s, d in pairs if s != d]
        if not active:
            return x
        srcs = [s for s, _ in active]
        dsts = [d for _, d in active]
        assert len(set(srcs)) == len(srcs) and len(set(dsts)) == len(dsts), (
            "packet transport moves partial permutations: unique srcs/dsts "
            f"required, got {pairs}"
        )

        leaves, treedef = jax.tree.flatten(x)
        if not leaves:
            return x
        parts = [_encode(l) for l in leaves]
        vec = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
        T = vec.size
        if T == 0:
            return x
        E = self.pkt_elems
        K = -(-T // E)  # packets per sender

        # Per-rank roles from the static pair list (SPMD: same trace
        # everywhere; the rank lookup selects the live role).
        r = comm.rank()
        dst_arr = np.full(n, -1, np.int32)
        for s, d in active:
            dst_arr[s] = d
        keep_arr = np.zeros(n, bool)  # (r, r) self-pairs: local delivery
        for s, d in pairs:
            if s == d:
                keep_arr[s] = True
        recv_arr = np.zeros(n, bool)
        for _, d in active:
            recv_arr[d] = True

        dst_r = jnp.asarray(dst_arr)[r]
        sends = dst_r >= 0
        pay = jnp.pad(vec, (0, K * E - T)).reshape(1, K, E)
        inq_dst = jnp.broadcast_to(
            jnp.clip(dst_r, 0, n - 1), (1, K)
        ).astype(jnp.int32)
        inq_len = jnp.where(sends, K, 0).astype(jnp.int32)[None]

        n_steps, transit_cap = self._bounds(comm, active, K)
        cfg = RouterConfig(
            dims=self._phys_dims(comm), n_ports=1, fifo_cap=K,
            transit_cap=transit_cap, out_cap=K, pkt_elems=E,
        )
        out_pay, out_cnt, ovf, _ = run_router(
            cfg, comm, self._route_table(comm), pay, inq_dst, inq_len,
            n_steps, impl=self.router_impl,
        )
        self._guard_runtime_reuse(ovf)
        self.tally(n_steps, tree_bytes(x))
        is_recv = jnp.asarray(recv_arr)[r]
        # Undelivered packets (an under-provisioned n_steps bound) would
        # silently back-fill zeros below — fold the delivery shortfall into
        # the loss counter so the tests' "overflow == 0" oracle catches it.
        shortfall = jnp.where(is_recv, K - out_cnt[0], 0).astype(jnp.int32)
        self.stats.add_overflow(ovf + shortfall)
        if obs.TRACING:
            # the counter itself is a traced runtime value; the event marks
            # where it accrues and carries the static schedule bounds
            obs.emit("router.overflow", tag=self._tag, n_steps=int(n_steps),
                     packets=int(K), transit_cap=int(transit_cap),
                     counter="stats.overflow")

        got = out_pay[0].reshape(K * E)[:T]
        keeps = jnp.asarray(keep_arr)[r]
        wire = jnp.where(is_recv, got, jnp.where(keeps, vec, 0.0))

        out_leaves, off = [], 0
        for l in leaves:
            out_leaves.append(_decode(wire[off:off + l.size], l.shape, l.dtype))
            off += l.size
        return jax.tree.unflatten(treedef, out_leaves)

    def p2p(self, x, *, src, dst, comm, n_chunks: int = 1):
        """Whole message as one packet train src -> dst through the router
        (``n_chunks`` is a scheduling hint other backends use; the router's
        chunking is its packet size)."""
        del n_chunks
        if src == dst:
            return x
        return self.permute(x, comm, [(src, dst)])


@register_transport("packet:pallas")
@dataclass
class PallasPacketTransport(PacketTransport):
    """The packet backend pinned to the Pallas tick kernel
    (``kernels/router``): the router's FIFO/arbiter state is updated in
    place inside one ``pallas_call`` per tick (VMEM-resident on TPU;
    interpreter fallback elsewhere).  The bare ``"packet"`` key already
    auto-selects this datapath on TPU — this key forces it everywhere,
    which is how the equivalence tests drive the kernel on CPU."""

    router_impl: str | None = "pallas"
