"""The Transport protocol: what a message-moving backend must provide.

A backend turns *logical* communication steps — a ring shift, an explicit
permutation, a routed point-to-point transfer — into wire traffic.  The
collectives and the overlap engine are written once against this interface;
the backend decides whether a step is a trace-time ppermute (static), a run
of the packet-switched router (packet), or a ppermute fused with its
consumer accumulate (fused).

All methods must be callable inside ``jax.shard_map`` over the
communicator's axes, and all are *schedule-preserving*: for a fixed
communicator and arguments every backend moves exactly the same values to
the same ranks, so collective results are bit-identical across backends
(tests/test_transport.py proves it).

Cost accounting: backends tally trace-time step/byte counters per instance
(:class:`TransportStats`); the packet backend additionally accumulates the
router's runtime overflow counter so lossless runs are assertable.
"""

from __future__ import annotations

import abc
from contextlib import contextmanager
from dataclasses import dataclass, field

import jax


@dataclass
class TransportStats:
    """Per-instance accounting, reset with :meth:`Transport.reset_stats`.

    ``steps``/``bytes_moved`` are trace-time counts (schedule cost per rank:
    one "step" = one link-schedule tick; bytes = payload carried per rank
    per tick, summed).  ``overflow`` is a traced runtime counter summed over
    router runs (``None`` for backends that cannot drop traffic).

    ``by_tag`` splits the same counters per message *tag* (set with
    :meth:`Transport.tagged`): an application phase that shares one backend
    instance with other traffic — the halo exchange of ``repro/apps`` riding
    a communicator that also moves collectives — still gets its own
    steps/bytes line, which is what lets the netsim halo predictions be
    asserted against exactly the halo's wire traffic.
    """

    steps: int = 0
    bytes_moved: int = 0
    overflow: object | None = None  # jax scalar i32 once a router has run
    #: identity of the jax trace whose runtime counters live here (set by
    #: Transport._guard_runtime_reuse; None until a traced value is stored)
    trace_token: object | None = None
    #: tag -> {"steps": int, "bytes": int} sub-accounting (see class doc)
    by_tag: dict = field(default_factory=dict)

    def tag_counts(self, tag: str) -> tuple[int, int]:
        """(steps, bytes) tallied under ``tag`` (0, 0 when never tagged)."""
        e = self.by_tag.get(tag, {"steps": 0, "bytes": 0})
        return e["steps"], e["bytes"]

    def add_overflow(self, ovf):
        self.overflow = ovf if self.overflow is None else self.overflow + ovf

    def record(self, seconds: float, name: str = "") -> dict:
        """One netsim calibration point: this schedule's trace-time cost
        paired with its measured wall time (consumed by
        :mod:`repro.netsim.calibrate`; the model fit reads only
        steps/bytes/seconds).  ``by_tag`` and ``overflow`` ride along so
        saved calibration runs stay auditable per message tag — overflow
        is ``None`` when the counter holds a traced value from a dead
        jit trace (only a concrete runtime sum is recordable)."""
        try:
            ovf = None if self.overflow is None else int(self.overflow)
        except Exception:  # a traced counter outside its trace
            ovf = None
        return {
            "steps": int(self.steps),
            "bytes": float(self.bytes_moved),
            "seconds": float(seconds),
            "name": name,
            "overflow": ovf,
            "by_tag": {
                tag: {"steps": int(e["steps"]), "bytes": int(e["bytes"])}
                for tag, e in self.by_tag.items()
            },
        }


def tree_bytes(x) -> int:
    """Static wire-byte count of a pytree (per rank, one step)."""
    total = 0
    for leaf in jax.tree.leaves(x):
        size = 1
        for d in leaf.shape:
            size *= int(d)
        total += size * leaf.dtype.itemsize
    return total


@dataclass
class Transport(abc.ABC):
    """One message-moving backend.  Instances are cheap, stateful only in
    their counters; create one per logical phase when separate accounting
    is wanted."""

    stats: TransportStats = field(default_factory=TransportStats)

    # registry key; a plain class attribute (NOT a dataclass field) so
    # @register_transport's assignment reaches every instance
    name = ""

    #: active message tag (see :meth:`tagged`)
    _tag: str | None = None

    #: True when step methods thread *traced* values into ``stats`` (the
    #: packet backend's overflow counter).  Such a backend must not be
    #: driven from inside ``lax.fori_loop``/``scan`` bodies — the schedule
    #: loops in core/collectives.py unroll instead — and one instance must
    #: not be reused across separately-traced functions.
    runtime_stats: bool = False

    # ------------------------------------------------------------- steps

    @abc.abstractmethod
    def permute(self, x, comm, pairs):
        """Move pytree ``x`` along explicit (src, dst) rank pairs — one link
        step of the schedule.  Ranks absent as a destination receive the
        backend's bubble value (zeros / stale register, matching ppermute
        semantics)."""

    def shift(self, x, comm, step: int = 1):
        """Ring shift of ``x`` by ``step`` along the linearised ranks."""
        return self.permute(x, comm, comm.ring_perm(step))

    def accumulate(self, a, b):
        """Elementwise ``a + b`` over a pytree — the reduction-combine hook.
        The fused backend overrides this with its tiled Pallas add so
        collective fold steps run on the fused datapath even when the shift
        and the add are not adjacent (the channel layer's pop-reduce).
        Must equal plain ``+`` bit-for-bit in f32."""
        return jax.tree.map(lambda x, y: x + y, a, b)

    def shift_accumulate(self, x, addend, comm, step: int = 1):
        """Hot-path hook for the ring-reduce inner loop:
        ``shift(x) + addend`` — backends may fuse the add into the
        receive (the fused backend's Pallas kernel).  Must equal the
        unfused composition bit-for-bit in f32."""
        return self.accumulate(self.shift(x, comm, step), addend)

    def send_contribution(self, c, comm, step: int = 1):
        """Ship one rank-local contribution a logical ring distance
        ``step`` (the lossy reduce-scatter's inner step).  On exact wires
        this is just :meth:`shift`; lossy backends override it to quantise
        the transmitted contribution exactly once, with error feedback
        (``transport/compressed.py``)."""
        return self.shift(c, comm, step)

    @abc.abstractmethod
    def p2p(self, x, *, src, dst, comm, n_chunks: int = 1):
        """Routed whole-message transfer: ``x``@src delivered to ``dst``
        along the communicator's route table; zeros elsewhere (SPMD)."""

    # ---------------------------------------------------------- counters

    @contextmanager
    def tagged(self, tag: str):
        """Tag every step accounted inside the block (halo message tagging).

        The tag buckets the same trace-time counters into
        ``stats.by_tag[tag]`` so one backend instance can serve several
        application phases — interior collectives and halo slabs — with
        separately assertable wire costs.  Wrapper backends (the compressed
        link) propagate the tag down their ``inner`` chain, since the inner
        backend is the one that accounts the wire it actually moves.
        """
        chain = [self]
        inner = getattr(self, "inner", None)
        while isinstance(inner, Transport):
            chain.append(inner)
            inner = getattr(inner, "inner", None)
        prev = [t._tag for t in chain]
        for t in chain:
            t._tag = tag
        try:
            yield self
        finally:
            for t, p in zip(chain, prev):
                t._tag = p

    def tally(self, steps: int, nbytes: int):
        """Add raw (steps, bytes) to the counters, honouring the active tag
        (the single accounting funnel; backends with their own step-count
        formulae — the packet router — call this directly)."""
        self.stats.steps += steps
        self.stats.bytes_moved += nbytes
        if self._tag is not None:
            e = self.stats.by_tag.setdefault(
                self._tag, {"steps": 0, "bytes": 0}
            )
            e["steps"] += steps
            e["bytes"] += nbytes

    def account(self, x, steps: int = 1):
        self.tally(steps, tree_bytes(x) * steps)

    def _guard_runtime_reuse(self, traced):
        """Refuse to mix traced counters from two different traces.

        A ``runtime_stats`` backend accumulates *traced* values (the packet
        router's overflow counter) into ``stats``.  Reusing one instance
        across separately-traced functions would silently corrupt them —
        summing a tracer from a dead trace either leaks it or bakes in a
        stale constant (the DESIGN.md §3.2 footgun).  Called with the new
        traced value before each accumulation; raises on cross-trace reuse.
        """
        token = getattr(traced, "_trace", None)
        prev = self.stats.trace_token
        if prev is not None and token is not None and prev is not token:
            raise RuntimeError(
                f"{type(self).__name__} instance reused across separately-"
                "traced functions: its runtime stats (runtime_stats=True) "
                "hold traced values from an earlier trace and would be "
                "silently corrupted (DESIGN.md §3.2). Create a fresh "
                "transport instance per traced function, or call "
                "reset_stats() between traces."
            )
        self.stats.trace_token = token

    def reset_stats(self):
        self.stats = TransportStats()
