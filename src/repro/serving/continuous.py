"""Continuous-batching serve loop on persistent SMI channels.

The wave engine (serving/engine.py) admits requests only at wave
boundaries because the batch shares one cache position — correct, but a
request arriving mid-wave waits for the whole wave to drain.  This module
is the production loop:

* **per-slot positions** — ``pos`` is a (B,) vector (decode_attention
  generalises bit-identically from the scalar wave case), so every slot
  advances independently;
* **per-slot admission/invalidation** — a request lands in *any* free
  slot; :func:`reset_slot` invalidates exactly that slot's rows across
  every cache leaf (``slot_pos`` rows back to -1, state to 0) without
  touching its batch-mates, so nothing ever leaks between requests;
* **prefill/decode overlap** — newly admitted slots replay their prompts
  through the same decode step their batch-mates are generating in (the
  per-slot cursor), so there is no prefill barrier;
* **persistent channels** — under tensor parallelism the decode step's
  layer channels come from a :class:`~repro.channels.ChannelPool`
  threaded through ``ParallelCtx(channels=pool)``: one
  ``ChannelSpec(persistent=True)`` per layer tag, claimed once, reused
  every step, released only at :meth:`ContinuousEngine.shutdown`;
* **streaming migration** — a slot's cache rows (an opaque byte image
  across every leaf) stream to the root over a persistent gather channel
  and back out over a scatter channel, both tallying under
  ``"serve.migrate"``, with the apps-layer start/finish split
  (apps/halo.py): decode ticks for the other slots run between the two
  legs while the migrating slot's image is in flight.

Migration always rides the lossless static schedule on a raw wire: the
image is reinterpreted bytes (bf16 KV, int32 positions, f32 recurrent
state) and a lossy or reordering wire would corrupt it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..mesh.api import ParallelCtx
from ..models import lm_caches, lm_decode_step
from ..parallel import ledger
from .engine import Request

#: the stats tag migration traffic tallies under (pool-prefixed ->
#: "serve.migrate"); gather and scatter legs share it
MIGRATE_TAG = "migrate"

#: sentinel occupying a slot whose cache image is in flight (migration):
#: not decodable, not admittable
_MIGRATING = object()


# ------------------------------------------------------------- cache rows
#
# Cache trees are {"periods": tuple-of-stacked-block-trees, "rem":
# tuple-of-block-trees} (models/transformer.py): leaves under "periods"
# carry a leading layer dim, so their batch dim is 1; everything else is
# batch-dim 0.  ``slot_pos`` leaves hold -1 for "no entry".


def _batch_dim(path) -> int:
    return 1 if any(getattr(k, "key", None) == "periods" for k in path) else 0


def _is_slot_pos(path) -> bool:
    return any(getattr(k, "key", None) == "slot_pos" for k in path)


def reset_slot(caches, slot):
    """Invalidate one batch slot across every cache leaf: its ``slot_pos``
    rows go to -1 (no valid entry) and all other state to 0.  The other
    slots' rows are untouched — this is the per-slot cache invalidation
    continuous admission relies on."""
    def one(path, leaf):
        bdim = _batch_dim(path)
        fill = -1 if _is_slot_pos(path) else 0
        row = jnp.full(
            leaf.shape[:bdim] + (1,) + leaf.shape[bdim + 1:], fill, leaf.dtype
        )
        return lax.dynamic_update_slice_in_dim(leaf, row, slot, bdim)

    return jax.tree_util.tree_map_with_path(one, caches)


def copy_slot(caches, src, dst):
    """Local slot-to-slot row copy — the exactness oracle for the
    streamed migration path."""
    def one(path, leaf):
        bdim = _batch_dim(path)
        row = lax.dynamic_slice_in_dim(leaf, src, 1, bdim)
        return lax.dynamic_update_slice_in_dim(leaf, row, dst, bdim)

    return jax.tree_util.tree_map_with_path(one, caches)


def pack_slot(caches, slot):
    """One slot's rows across every (local) cache leaf as a flat (N,)
    uint8 image, leaves in tree-flatten order.  Reinterpreted bytes
    (bitcast), so the image is exact for every leaf dtype."""
    bufs = []
    for path, leaf in jax.tree_util.tree_leaves_with_path(caches):
        row = lax.dynamic_slice_in_dim(leaf, slot, 1, _batch_dim(path))
        flat = row.reshape(-1)
        if flat.dtype != jnp.uint8:
            flat = lax.bitcast_convert_type(flat, jnp.uint8)
        bufs.append(flat.reshape(-1))
    return jnp.concatenate(bufs)


def unpack_slot(caches, image, slot):
    """Inverse of :func:`pack_slot`: write the (N,) uint8 image back into
    ``slot``'s rows across every cache leaf."""
    leaves = jax.tree_util.tree_leaves_with_path(caches)
    out, off = [], 0
    for path, leaf in leaves:
        bdim = _batch_dim(path)
        row_shape = leaf.shape[:bdim] + (1,) + leaf.shape[bdim + 1:]
        n = int(np.prod(row_shape))
        nbytes = n * leaf.dtype.itemsize
        piece = lax.slice_in_dim(image, off, off + nbytes, axis=0)
        off += nbytes
        if leaf.dtype != jnp.uint8:
            piece = lax.bitcast_convert_type(
                piece.reshape(n, leaf.dtype.itemsize), leaf.dtype
            )
        out.append(lax.dynamic_update_slice_in_dim(
            leaf, piece.reshape(row_shape), slot, bdim
        ))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(caches), out
    )


def slot_nbytes(cache_shapes) -> int:
    """Bytes of one slot's packed image (for the migration channel's
    predicted cost)."""
    total = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(cache_shapes):
        bdim = _batch_dim(path)
        shape = leaf.shape[:bdim] + (1,) + leaf.shape[bdim + 1:]
        total += int(np.prod(shape)) * np.dtype(leaf.dtype).itemsize
    return total


# --------------------------------------------------------- migration legs


def open_migration(pool):
    """The persistent gather/scatter channel pair one engine's migrations
    ride — both tagged ``serve.migrate``, both pinned to the lossless
    static schedule on a raw wire (the image is reinterpreted bytes)."""
    g = pool.spec(MIGRATE_TAG, kind="gather", transport="static",
                  wire="raw", key=pool.retag(MIGRATE_TAG) + "#gather")
    s = pool.spec(MIGRATE_TAG, kind="scatter", transport="static",
                  wire="raw", key=pool.retag(MIGRATE_TAG) + "#scatter")
    return g, s


def migrate_gather(caches, slot, gspec):
    """Start leg: pack ``slot``'s local rows and stream every rank's image
    to the root over the persistent gather channel.  Returns the in-flight
    (P, N) buffer (meaningful at the root)."""
    from ..channels.channel import _tagged
    from ..core.collectives import _stream_gather_impl

    image = pack_slot(caches, slot)
    t = ledger.attach(gspec.resolve())
    with _tagged(t, gspec.stats_tag):
        return _stream_gather_impl(image[None], gspec.comm, root=gspec.root,
                                   transport=t)


def migrate_scatter(caches, inflight, slot, sspec):
    """Finish leg: stream each rank's image back out of the root over the
    persistent scatter channel and write it into ``slot``'s rows."""
    from ..channels.channel import _tagged
    from ..core.collectives import _stream_scatter_impl

    t = ledger.attach(sspec.resolve())
    with _tagged(t, sspec.stats_tag):
        image = _stream_scatter_impl(inflight, sspec.comm, root=sspec.root,
                                     transport=t)
    return unpack_slot(caches, image[0], slot)


# ------------------------------------------------------------- the engine


class ContinuousEngine:
    """Continuous-batching serve loop; greedy sampling, deterministic.

    Single-device by default (``ctx=None``); pass the ``runtime`` dict
    from :func:`repro.launch.steps.build_continuous_serve` to run the
    tensor-parallel decode step on persistent channels.

    A request's greedy output is bit-identical to the wave engine's for
    the same params: each slot's computation depends only on its own row
    (per-slot positions, per-row cache masking), so batch-mates — and
    when they were admitted — cannot perturb it.
    """

    def __init__(self, cfg, params, *, ctx: ParallelCtx | None = None,
                 batch_slots: int = 4, capacity: int = 128,
                 eos: int | None = None, runtime: dict | None = None):
        self.cfg = cfg
        self.params = params
        self.eos = eos
        if runtime is not None:
            self.ctx = runtime["ctx"]
            self.pool = runtime.get("pool")
            self.B = runtime["batch_slots"]
            self.capacity = runtime["capacity"]
            self.caches = runtime["init_caches"]()
            self._step = runtime["step"]
            self._reset = runtime["reset"]
            self._mig_start = runtime["migrate_start"]
            self._mig_finish = runtime["migrate_finish"]
        else:
            self.ctx = ctx or ParallelCtx()
            self.pool = None
            self.B = batch_slots
            self.capacity = capacity
            self.caches = lm_caches(cfg, batch_slots, capacity=capacity,
                                    ctx=self.ctx)
            self._step = jax.jit(
                lambda p, c, t, pos: lm_decode_step(p, c, t, pos, cfg,
                                                    self.ctx)
            )
            self._reset = jax.jit(reset_slot, donate_argnums=(0,))
            # single-device "migration": the packed image round-trips
            # locally (the comm legs need a TP runtime)
            self._mig_start = jax.jit(pack_slot)
            self._mig_finish = jax.jit(unpack_slot, donate_argnums=(0,))
        B = self.B
        self.slot_req: list = [None] * B
        self.queue: list[Request] = []
        self.pos = np.zeros(B, dtype=np.int32)      # per-slot next position
        self.cursor = np.zeros(B, dtype=np.int64)   # per-slot prompt cursor
        tok_shape = (B, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B,)
        self._cur = np.zeros(tok_shape, dtype=np.int32)
        self.steps_done = 0
        self.admit_step: dict[int, int] = {}   # uid -> tick admitted
        self.finish_step: dict[int, int] = {}  # uid -> tick completed

    # -- queue / admission ---------------------------------------------------

    def submit(self, req: Request):
        self.queue.append(req)

    @staticmethod
    def _active(r) -> bool:
        return r is not None and r is not _MIGRATING

    def _admit(self) -> int:
        """Admit waiting requests into free slots — any free slot, any
        time; only that slot's cache rows are invalidated."""
        n = 0
        for i in range(self.B):
            if self.slot_req[i] is None and self.queue:
                req = self.queue.pop(0)
                self.caches = self._reset(self.caches, np.int32(i))
                self.slot_req[i] = req
                self.pos[i] = 0
                self.cursor[i] = 0
                self._cur[i] = 0
                self.admit_step[req.uid] = self.steps_done
                n += 1
        return n

    # -- the decode tick -----------------------------------------------------

    def tick(self) -> list[Request]:
        """Admit, run ONE decode step for every occupied slot (prompt
        replay and generation overlap in the same step), harvest
        completions.  Returns the requests completed this tick."""
        self._admit()
        if not any(self._active(r) for r in self.slot_req):
            return []
        for i, req in enumerate(self.slot_req):
            if not self._active(req):
                self._cur[i] = 0
            elif self.cursor[i] < len(req.prompt):
                self._cur[i] = req.prompt[int(self.cursor[i])]
            # else: keep the sampled token from the last tick
        logits, self.caches = self._step(
            self.params, self.caches, jnp.asarray(self._cur),
            jnp.asarray(self.pos),
        )
        nxt = np.asarray(jnp.argmax(logits, axis=1))  # (B[, n_cb])
        done: list[Request] = []
        for i, req in enumerate(self.slot_req):
            if not self._active(req):
                continue
            self.pos[i] += 1
            self.cursor[i] += 1
            if self.cursor[i] >= len(req.prompt):
                tok = nxt[i]
                req.out.append(tok.tolist() if tok.ndim else int(tok))
                self._cur[i] = tok
                hit_eos = (self.eos is not None and np.ndim(tok) == 0
                           and int(tok) == self.eos)
                if len(req.out) >= req.max_new or hit_eos:
                    req.done = True
                    self.finish_step[req.uid] = self.steps_done + 1
                    done.append(req)
                    self.slot_req[i] = None   # freed NOW: no wave barrier
        self.steps_done += 1
        return done

    def run(self, *, max_steps: int = 256, arrivals=None) -> list[Request]:
        """Drain the queue; returns completed requests.

        ``arrivals`` is an optional ``[(tick, Request), ...]`` schedule
        keyed on the engine's global tick clock (``steps_done``), so
        latency benchmarks can replay a Poisson trace against continuous
        admission."""
        completed: list[Request] = []
        pending = sorted(arrivals, key=lambda a: a[0]) if arrivals else []
        steps = 0
        while (pending or any(r is not None for r in self.slot_req)
               or self.queue) and steps < max_steps:
            while pending and pending[0][0] <= self.steps_done:
                self.queue.append(pending.pop(0)[1])
            if not self.queue and \
                    not any(self._active(r) for r in self.slot_req):
                self.steps_done += 1  # idle tick: waiting on arrivals
                steps += 1
                continue
            completed.extend(self.tick())
            steps += 1
        return completed

    # -- migration -----------------------------------------------------------

    def migrate(self, src: int, dst: int, *, overlap_ticks: int = 0):
        """Move the request in slot ``src`` into free slot ``dst`` by
        streaming its cache image over the migration channels
        (start/finish split): ``overlap_ticks`` decode ticks for the
        other slots run between the gather and scatter legs while the
        image is in flight.  Both slots are held out of decoding (and
        admission) for the duration."""
        req = self.slot_req[src]
        assert self._active(req), "source slot must hold a request"
        assert self.slot_req[dst] is None, "destination slot must be free"
        inflight = self._mig_start(self.caches, np.int32(src))
        self.slot_req[src] = _MIGRATING
        self.slot_req[dst] = _MIGRATING
        state = (self.pos[src], self.cursor[src], self._cur[src].copy())
        for _ in range(overlap_ticks):
            self.tick()
        self.caches = self._mig_finish(self.caches, inflight, np.int32(dst))
        self.slot_req[src] = None
        self.slot_req[dst] = req
        self.pos[dst], self.cursor[dst], self._cur[dst] = state
        return req

    # -- lifecycle -----------------------------------------------------------

    def shutdown(self):
        """Release the pool's persistent port claims (the ONLY point a
        persistent channel's port returns to the allocator)."""
        if self.pool is not None and not self.pool.closed:
            self.pool.close()

    def __enter__(self) -> "ContinuousEngine":
        return self

    def __exit__(self, *exc):
        self.shutdown()
        return False
