"""Batched serving engine: prefill-as-decode + wave batching.

A fixed-width batch of slots decodes in lock-step through the compiled
``serve_step``; when a wave of requests completes, the caches are reset and
the next wave is admitted.  Wave batching shares ONE cache position across
the batch (the ``pos`` local in :meth:`ServeEngine.run`) — the per-slot
positions, per-slot admission, and per-slot cache invalidation that lift
this restriction live in :class:`~repro.serving.continuous.
ContinuousEngine`, which this engine remains the bit-exactness oracle
for.  Prompts are replayed through decode steps (exact at small scale; the
32k-prefill *shape* exercises the dedicated prefill path).  Greedy
sampling; deterministic.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..mesh.api import ParallelCtx
from ..models import lm_caches, lm_decode_step


@dataclass
class Request:
    uid: int
    prompt: list
    max_new: int = 16
    out: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, *, ctx: ParallelCtx | None = None,
                 batch_slots: int = 4, capacity: int = 128, eos: int | None = None):
        self.cfg = cfg
        self.ctx = ctx or ParallelCtx()
        self.params = params
        self.B = batch_slots
        self.capacity = capacity
        self.eos = eos
        self.caches = lm_caches(cfg, batch_slots, capacity=capacity, ctx=self.ctx)
        self.slot_req: list[Request | None] = [None] * batch_slots
        self.queue: list[Request] = []
        self.admit_step: dict[int, int] = {}   # uid -> tick admitted
        self.finish_step: dict[int, int] = {}  # uid -> tick completed
        self._step = jax.jit(
            lambda p, c, t, pos: lm_decode_step(p, c, t, pos, cfg, self.ctx)
        )

    def submit(self, req: Request):
        self.queue.append(req)

    def _fill_wave(self):
        """Admit a new wave only when every slot is free (cache reset keeps
        per-slot histories from leaking across requests)."""
        if any(r is not None for r in self.slot_req):
            return 0
        n = 0
        for i in range(self.B):
            if self.queue:
                self.slot_req[i] = self.queue.pop(0)
                n += 1
        if n:
            self.caches = lm_caches(
                self.cfg, self.B, capacity=self.capacity, ctx=self.ctx
            )
        return n

    def run(self, *, max_steps: int = 256, arrivals=None) -> list[Request]:
        """Drain the queue; returns completed requests.

        ``arrivals`` is an optional ``[(tick, Request), ...]`` schedule: each
        request joins the queue at its tick (idle ticks pass when nothing is
        resident yet), so latency benchmarks can replay a Poisson trace.
        ``admit_step`` / ``finish_step`` record per-uid admission/completion
        ticks either way."""
        completed: list[Request] = []
        pending = sorted(arrivals, key=lambda a: a[0]) if arrivals else []
        tok_shape = (self.B, self.cfg.n_codebooks) if self.cfg.n_codebooks > 1 else (self.B,)
        cur = np.zeros(tok_shape, dtype=np.int32)
        cursor = np.zeros(self.B, dtype=np.int64)  # prompt read positions
        pos = 0
        steps = 0
        while (pending or any(r is not None for r in self.slot_req)
               or self.queue) and steps < max_steps:
            while pending and pending[0][0] <= steps:
                self.queue.append(pending.pop(0)[1])
            if all(r is None for r in self.slot_req):
                if self._fill_wave():
                    pos = 0
                    cur[:] = 0
                    cursor[:] = 0
                    for r in self.slot_req:
                        if r is not None:
                            self.admit_step[r.uid] = steps
                else:
                    steps += 1  # idle tick: waiting on arrivals
                    continue
            # choose the input token per slot: prompt replay or last sample
            for i, req in enumerate(self.slot_req):
                if req is None:
                    cur[i] = 0
                elif cursor[i] < len(req.prompt):
                    cur[i] = req.prompt[int(cursor[i])]
                # else: keep the sampled token from last iteration
            logits, self.caches = self._step(
                self.params, self.caches, jnp.asarray(cur), jnp.asarray(pos)
            )
            nxt = np.asarray(jnp.argmax(logits, axis=1))  # (B[, n_cb])
            for i, req in enumerate(self.slot_req):
                if req is None:
                    continue
                cursor[i] += 1
                if cursor[i] >= len(req.prompt):
                    tok = nxt[i]
                    req.out.append(tok.tolist() if tok.ndim else int(tok))
                    cur[i] = tok
                    hit_eos = (
                        self.eos is not None and np.ndim(tok) == 0 and int(tok) == self.eos
                    )
                    if len(req.out) >= req.max_new or hit_eos:
                        req.done = True
                        self.finish_step[req.uid] = steps + 1
                        completed.append(req)
                        self.slot_req[i] = None
                        cursor[i] = 0
            pos += 1
            steps += 1
        return completed
