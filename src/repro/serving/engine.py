"""Batched serving engine: prefill-as-decode + wave batching.

A fixed-width batch of slots decodes in lock-step through the compiled
``serve_step``; when a wave of requests completes, the caches are reset and
the next wave is admitted (wave batching — the correct scale-down of
continuous batching given a batch-shared cache position; per-slot cache
invalidation is the production extension and is what the decode shapes
exercise in the dry-run).  Prompts are replayed through decode steps (exact
at small scale; the 32k-prefill *shape* exercises the dedicated prefill
path).  Greedy sampling; deterministic.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from ..mesh.api import ParallelCtx
from ..models import lm_caches, lm_decode_step


@dataclass
class Request:
    uid: int
    prompt: list
    max_new: int = 16
    out: list = field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg, params, *, ctx: ParallelCtx | None = None,
                 batch_slots: int = 4, capacity: int = 128, eos: int | None = None):
        self.cfg = cfg
        self.ctx = ctx or ParallelCtx()
        self.params = params
        self.B = batch_slots
        self.capacity = capacity
        self.eos = eos
        self.caches = lm_caches(cfg, batch_slots, capacity=capacity, ctx=self.ctx)
        self.pos = np.zeros(batch_slots, dtype=np.int64)  # per-slot next pos
        self.slot_req: list[Request | None] = [None] * batch_slots
        self.queue: list[Request] = []
        self._step = jax.jit(
            lambda p, c, t, pos: lm_decode_step(p, c, t, pos, cfg, self.ctx)
        )

    def submit(self, req: Request):
        self.queue.append(req)

    def _fill_wave(self):
        """Admit a new wave only when every slot is free (cache reset keeps
        per-slot histories from leaking across requests)."""
        if any(r is not None for r in self.slot_req):
            return 0
        n = 0
        for i in range(self.B):
            if self.queue:
                self.slot_req[i] = self.queue.pop(0)
                n += 1
        if n:
            self.caches = lm_caches(
                self.cfg, self.B, capacity=self.capacity, ctx=self.ctx
            )
        return n

    def run(self, *, max_steps: int = 256) -> list[Request]:
        """Drain the queue; returns completed requests."""
        completed: list[Request] = []
        self._fill_wave()
        tok_shape = (self.B, self.cfg.n_codebooks) if self.cfg.n_codebooks > 1 else (self.B,)
        cur = np.zeros(tok_shape, dtype=np.int32)
        cursor = np.zeros(self.B, dtype=np.int64)  # prompt read positions
        pos = 0
        steps = 0
        while (any(r is not None for r in self.slot_req) or self.queue) and steps < max_steps:
            # choose the input token per slot: prompt replay or last sample
            for i, req in enumerate(self.slot_req):
                if req is None:
                    cur[i] = 0
                elif cursor[i] < len(req.prompt):
                    cur[i] = req.prompt[int(cursor[i])]
                # else: keep the sampled token from last iteration
            logits, self.caches = self._step(
                self.params, self.caches, jnp.asarray(cur), jnp.asarray(pos)
            )
            nxt = np.asarray(jnp.argmax(logits, axis=1))  # (B[, n_cb])
            for i, req in enumerate(self.slot_req):
                if req is None:
                    continue
                cursor[i] += 1
                if cursor[i] >= len(req.prompt):
                    tok = nxt[i]
                    req.out.append(tok.tolist() if tok.ndim else int(tok))
                    cur[i] = tok
                    hit_eos = (
                        self.eos is not None and np.ndim(tok) == 0 and int(tok) == self.eos
                    )
                    if len(req.out) >= req.max_new or hit_eos:
                        req.done = True
                        completed.append(req)
                        self.slot_req[i] = None
                        cursor[i] = 0
            pos += 1
            steps += 1
            if all(r is None for r in self.slot_req) and self.queue:
                if self._fill_wave():
                    pos = 0
                    cur[:] = 0
                    cursor[:] = 0
        return completed
