from .engine import ServeEngine, Request
from .continuous import (
    ContinuousEngine,
    copy_slot,
    open_migration,
    pack_slot,
    reset_slot,
    slot_nbytes,
    unpack_slot,
)

__all__ = [
    "ServeEngine", "Request", "ContinuousEngine", "reset_slot", "copy_slot",
    "pack_slot", "unpack_slot", "slot_nbytes", "open_migration",
]
