from .engine import ServeEngine, Request

__all__ = ["ServeEngine", "Request"]
