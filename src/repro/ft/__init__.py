from .watchdog import StepWatchdog, run_with_restarts
from .elastic import best_mesh_shape, elastic_restart_plan

__all__ = ["StepWatchdog", "run_with_restarts", "best_mesh_shape", "elastic_restart_plan"]
