"""Fault tolerance: straggler watchdog + checkpoint/restart driver."""

from __future__ import annotations

import time
from dataclasses import dataclass, field

from ..obs import trace as obs


@dataclass
class StepWatchdog:
    """EMA step-time monitor: flags stragglers (a step slower than
    ``threshold`` × the running mean) and stalls (no heartbeat).  At pod
    scale the flagged step triggers the restart path; here it feeds tests
    and the trainer's log."""

    threshold: float = 3.0
    alpha: float = 0.1
    ema: float | None = None
    events: list = field(default_factory=list)
    _last: float | None = None

    def start(self):
        self._last = time.monotonic()

    def lap(self, step: int) -> bool:
        now = time.monotonic()
        if self._last is None:
            # lap() before start(): no real interval exists yet — arm the
            # timer and skip both the straggler check and EMA seeding (a
            # dt = now - now = 0 seed would make every later step satisfy
            # dt > threshold * 0 and flag as a straggler forever)
            self._last = now
            return False
        dt = now - self._last
        self._last = now
        slow = False
        if self.ema is not None and dt > self.threshold * self.ema:
            slow = True
            self.events.append({"step": step, "dt": dt, "ema": self.ema})
            if obs.TRACING:
                obs.emit("ft.straggler", tag="ft", step=step, dt=dt,
                         ema=self.ema, threshold=self.threshold)
        self.ema = dt if self.ema is None else (1 - self.alpha) * self.ema + self.alpha * dt
        return slow


def run_with_restarts(
    make_loop,
    checkpointer,
    state_like,
    *,
    max_restarts: int = 2,
):
    """Run ``make_loop(start_state, start_step) -> final_state`` with
    checkpoint/restart on failure.

    ``make_loop`` raising is treated as a node failure: the driver reloads
    the latest checkpoint and resumes.  Returns (final_state, n_restarts).
    """
    restarts = 0
    state = state_like
    step = 0
    while True:
        try:
            return make_loop(state, step), restarts
        except Exception:  # noqa: BLE001 — any failure triggers restart
            restarts += 1
            if restarts > max_restarts:
                raise
            restored, manifest = checkpointer.restore(state_like)
            state = restored
            step = manifest["step"]
            if obs.TRACING:
                obs.emit("ft.restart", tag="ft", restart=restarts,
                         resume_step=step, max_restarts=max_restarts)
