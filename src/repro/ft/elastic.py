"""Elastic scaling: re-mesh to the surviving device set.

Paper §2.2: "ranks involved in communication and the total number of ranks
can be dynamically altered without recompiling the program, by simply
updating the routing configuration at each rank."  On the SMI dynamic-router
path that holds verbatim (core/router.py: same executable, new tables).  For
the XLA-compiled model step, a mesh resize necessarily recompiles; what this
module preserves is the *state*: the checkpoint re-shards onto the new mesh
(host numpy -> device_put with new NamedShardings) and the route generator
re-emits tables for the surviving topology.
"""

from __future__ import annotations

import jax
import numpy as np

from ..core import Topology, compute_route_table


def best_mesh_shape(n_devices: int, *, prefer_model: int = 4) -> tuple[int, int]:
    """Largest usable (data, model) grid for the surviving device count."""
    model = min(prefer_model, n_devices)
    while n_devices % model:
        model -= 1
    return (n_devices // model, model)


def elastic_restart_plan(old_n: int, new_n: int, *, prefer_model: int = 4):
    """Returns the new mesh shape and fresh routing tables for the new world
    (the paper's route regeneration step)."""
    shape = best_mesh_shape(new_n, prefer_model=prefer_model)
    topo = Topology.torus(shape)
    rt = compute_route_table(topo)
    return {"mesh_shape": shape, "topology": topo, "route_table": rt}


def reshard_state(host_state, shardings):
    """device_put a host checkpoint onto (possibly different) shardings."""
    return jax.tree.map(
        lambda a, s: jax.device_put(np.asarray(a), s), host_state, shardings
    )
