"""The in-repo programs smilint's capture pass sweeps (DESIGN.md §14).

Each entry traces one real program of the repo — the training step, the
continuous-serving decode step + slot migration, the distributed stencil,
and channel-API programs in the shape of the benchmarks and the
quickstart example — under :func:`repro.analysis.capture`, then verifies
the recorded ledger.  CI gates every entry on **zero diagnostics** and
**zero real transport steps** (abstract interpretation moved no bytes).

Imports the launch stack, so this module (unlike the package root) needs
jax and 8 host devices; the CLI sets ``XLA_FLAGS`` before importing it.
"""

from __future__ import annotations

from . import capture as _capture
from .verify import verify_ledger


def _mesh(dims, axes=("data", "model")):
    from ..launch.mesh import make_mesh

    return make_mesh(dims, axes[: len(dims)])


def capture_train(dims=(2, 4), comm_mode: str = "smi:static"):
    """One smoke training step (the validate-comm recipe, captured)."""
    import jax

    from ..configs import get_arch, smoke
    from ..configs.base import ShapeConfig
    from ..launch.steps import TrainSettings, build_train

    cfg = smoke(get_arch("yi-6b"))
    shape = ShapeConfig("smilint", seq_len=128, global_batch=8, kind="train")
    settings = TrainSettings(comm_mode=comm_mode, remat="nothing",
                             base_lr=3e-4, loss_chunks=1, total_steps=10,
                             warmup_steps=1)
    mesh = _mesh(dims)
    with _capture.capture() as led:
        art = build_train(cfg, mesh, shape, settings)
        batch = {k: jax.ShapeDtypeStruct(v.shape, v.dtype)
                 for k, v in art["input_specs"].items()}
        art["step"].lower(art["state_shape"], batch)
    return led


def capture_serve(dims=(2, 4), comm_mode: str = "smi:static"):
    """One continuous decode step + one slot migration over the
    persistent serve.* channel pool, captured; the pool closes inside the
    block so its claims balance (no SMI105)."""
    import jax
    import jax.numpy as jnp

    from ..configs import get_arch, smoke
    from ..launch.steps import build_continuous_serve
    from ..models import init_lm

    cfg = smoke(get_arch("glm4-9b"))
    mesh = _mesh(dims)
    tp = dims[-1]
    with _capture.capture() as led:
        rt = build_continuous_serve(cfg, mesh, comm_mode=comm_mode,
                                    batch_slots=2, capacity=64)
        ctx = rt["ctx"]
        B = rt["batch_slots"]
        pshapes = jax.eval_shape(
            lambda: init_lm(jax.random.PRNGKey(0), cfg, ctx))
        cshapes = jax.eval_shape(rt["init_caches"])
        tok = jax.ShapeDtypeStruct(
            (B, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B,), jnp.int32)
        pos = jax.ShapeDtypeStruct((B,), jnp.int32)
        slot = jax.ShapeDtypeStruct((), jnp.int32)
        rt["step"].lower(pshapes, cshapes, tok, pos)
        if tp > 1:
            infl = jax.eval_shape(rt["migrate_start"], cshapes, slot)
            rt["migrate_start"].lower(cshapes, slot)
            rt["migrate_finish"].lower(cshapes, infl, slot)
        if rt["pool"] is not None:
            rt["pool"].close()
    return led


def capture_stencil(grid=(2, 4), domain=(32, 32), comm_mode: str = "smi"):
    """One distributed halo-exchange stencil step, captured."""
    import jax
    import numpy as np

    from ..apps import DistributedStencil

    app = DistributedStencil.create(grid, comm_mode=comm_mode)
    tiles = app.scatter(np.zeros(domain, np.float32))
    mesh = app.make_mesh()
    with _capture.capture() as led:
        f = app.jitted(mesh, n_steps=1)
        f.lower(jax.ShapeDtypeStruct(tiles.shape, tiles.dtype))
    return led


def capture_bench_collectives(size: int = 8):
    """The collective-benchmark program shape (benchmarks/ and the
    channels acceptance suite): all five collective channel kinds opened
    anonymously and driven by one whole-message transfer each."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from ..channels import (
        open_allreduce_channel,
        open_bcast_channel,
        open_gather_channel,
        open_reduce_channel,
        open_scatter_channel,
    )
    from ..compat import shard_map
    from ..core import Communicator, make_test_mesh

    mesh = make_test_mesh((size,), ("x",))
    comm = Communicator.create("x", (size,))

    def body(v, gv, fv):
        b = open_bcast_channel(comm, root=1, port=None,
                               n_chunks=2).transfer(v[0])
        r = open_reduce_channel(comm, root=0, port=None,
                                n_chunks=2).transfer(v[0])
        gt = open_gather_channel(comm, root=0, port=None).transfer(gv[0])
        s = open_scatter_channel(comm, root=0, port=None).transfer(fv)
        a = open_allreduce_channel(comm, port=None).transfer(v[0])
        return b[None], r[None], gt[None], s[None], a[None]

    f = shard_map(body, mesh=mesh, in_specs=(P("x"), P("x"), P(None)),
                  out_specs=(P("x"),) * 5)
    with _capture.capture() as led:
        jax.jit(f).lower(
            jax.ShapeDtypeStruct((size, 4, 3), jnp.float32),
            jax.ShapeDtypeStruct((size, 2, 3), jnp.float32),
            jax.ShapeDtypeStruct((size * 2, 3), jnp.float32))
    return led


def capture_quickstart(size: int = 8, count: int = 12):
    """The quickstart example's element pipeline: a claimed p2p channel
    pushed/popped through the warm-up/drain loop (paper Listing 1), then
    a whole-message transfer + broadcast over anonymous ports."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from ..channels import open_bcast_channel, open_channel
    from ..compat import shard_map
    from ..core import Communicator, Topology, make_test_mesh, pvary

    mesh = make_test_mesh((size,), ("x",))
    comm = Communicator.create("x", (size,), topology=Topology.bus(size))
    src, dst = 0, 3
    hops = comm.route_table.n_hops(src, dst)

    def spmd(dummy):
        with open_channel(comm, count=count, src=src, dst=dst, port=0,
                          elem_shape=(), dtype=jnp.float32) as chan:
            acc = pvary(jnp.zeros((count,), jnp.float32), comm)

            # capture sees the traced loop body once — one push, one pop
            # in the ledger — which is exactly the per-iteration pattern
            # the credit-window walk checks (DESIGN.md §14)
            def body(i, carry):
                chan, acc = carry
                chan = chan.push(jnp.sin(i.astype(jnp.float32)))
                chan, val, valid = chan.pop()
                slot = jnp.maximum(i - (hops - 1), 0)
                acc = jnp.where(valid, acc.at[slot].set(val), acc)
                return chan, acc

            chan, acc = jax.lax.fori_loop(0, count + hops - 1, body,
                                          (chan, acc))
        y = open_channel(comm, src=src, dst=dst, port=None,
                         n_chunks=4).transfer(acc)
        y = open_bcast_channel(comm, root=dst, port=None,
                               n_chunks=2).transfer(y)
        return y[None] + 0 * dummy[:, :1]

    f = shard_map(spmd, mesh=mesh, in_specs=P("x"), out_specs=P("x"))
    with _capture.capture() as led:
        jax.jit(f).lower(jax.ShapeDtypeStruct((size, 1), jnp.float32))
    return led


#: name -> zero-argument capture entry; the CLI/CI sweep
PROGRAMS = {
    "launch.train": capture_train,
    "launch.serve": capture_serve,
    "launch.stencil": capture_stencil,
    "bench.collectives": capture_bench_collectives,
    "examples.quickstart": capture_quickstart,
}


def run_programs(names=None) -> tuple[list, bool]:
    """Capture + verify each named program.  ``(rows, all_ok)``: a row
    carries the op counts, the real-step counter (must be 0) and the
    diagnostics (must be empty)."""
    rows = []
    ok = True
    for name in names or sorted(PROGRAMS):
        led = PROGRAMS[name]()
        diags = verify_ledger(led, name=name)
        clean = not diags and led.real_steps == 0
        ok = ok and clean
        rows.append({
            "program": name,
            "ops": led.counts(),
            "size": led.size,
            "real_steps": led.real_steps,
            "transport_steps": led.transport_steps,
            "ok": clean,
            "diagnostics": [d.to_dict() for d in diags],
        })
    return rows, ok
