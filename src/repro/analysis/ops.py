"""The channel-op model `smilint` verifies over (DESIGN.md §14).

A *channel program* is, per rank, an ordered list of :class:`ChannelOp`
records — the abstract trace of every ``open_*_channel`` / ``push`` /
``pop`` / ``transfer`` / ``close`` / :class:`~repro.channels.ChannelPool`
claim the program performs.  Two producers exist:

* **capture mode** (:mod:`repro.analysis.capture`): the real channel API
  records ops while a program *traces* (``jit(...).lower``) with every
  transport replaced by an abstract backend — one SPMD op stream, expanded
  per rank by :func:`as_program`;
* **explicit MPMD programs** (:class:`ProgramBuilder`): per-rank op lists
  written directly, the paper's one-kernel-per-FPGA world — this is how
  the known-bad corpus seeds cross-rank defects (endpoint mismatches,
  deadlock cycles) an SPMD trace cannot express.

This module is deliberately jax-free so the verifier and the corpus run
anywhere the AST lints do.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field

#: ops a channel program is made of
OPS = ("open", "close", "push", "pop", "transfer", "pool.open", "pool.close")


@dataclass
class ChannelOp:
    """One abstract channel operation at one rank.

    ``rank=None`` marks an SPMD op (every rank performs it, with the roles
    its ``src``/``dst``/``root`` fields imply).  ``chan`` identifies the
    rank-local channel *instance* the op belongs to (capture assigns it
    from the opening spec); the cross-rank identity of a channel is its
    ``(comm, port)`` — SMI ports name hardware endpoints (paper §2.2), so
    anonymous (``port=None``) channels are rank-local only.
    """

    op: str
    rank: int | None = None
    chan: int | None = None
    kind: str = "p2p"
    port: int | None = None
    tag: str | None = None
    comm: str = "world"
    size: int = 0
    src: int = 0
    dst: int = 0
    root: int = 0
    count: int | None = None
    dtype: str | None = None
    wire: str = "raw"
    transport: str | None = None
    persistent: bool = False
    location: str | None = None

    def __post_init__(self):
        assert self.op in OPS, f"unknown channel op {self.op!r}; one of {OPS}"

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def replace(self, **kw) -> "ChannelOp":
        return dataclasses.replace(self, **kw)


@dataclass
class CaptureLedger:
    """What one capture run accumulates: the SPMD op stream, per-tag
    abstract-transport step/byte totals, and the count of *real* transport
    steps — which capture mode exists to keep at zero (the no-comm-executed
    contract ``tests/test_analysis.py`` asserts for ``launch/train`` and
    ``launch/serve``)."""

    ops: list = field(default_factory=list)
    #: tag -> {"steps": int, "bytes": int} tallied by the abstract backend
    transport_steps: dict = field(default_factory=dict)
    #: steps tallied by any REAL (non-abstract) transport during capture;
    #: must stay 0 — capture is abstract interpretation, not execution
    real_steps: int = 0
    size: int = 0
    _chan_ids: dict = field(default_factory=dict, repr=False)
    _chan_refs: list = field(default_factory=list, repr=False)

    def chan_id(self, spec) -> int:
        """Stable rank-local channel id for an opened spec (capture keeps
        the spec alive for the ledger's lifetime so ids cannot alias)."""
        key = id(spec)
        cid = self._chan_ids.get(key)
        if cid is None:
            cid = len(self._chan_refs)
            self._chan_ids[key] = cid
            self._chan_refs.append(spec)
        return cid

    def add(self, op: ChannelOp):
        self.ops.append(op)
        if op.size > self.size:
            self.size = op.size

    def tally_abstract(self, tag: str | None, steps: int, nbytes: int):
        e = self.transport_steps.setdefault(
            tag or "untagged", {"steps": 0, "bytes": 0}
        )
        e["steps"] += steps
        e["bytes"] += nbytes

    def counts(self) -> dict:
        by_op: dict[str, int] = {}
        for o in self.ops:
            by_op[o.op] = by_op.get(o.op, 0) + 1
        return by_op


@dataclass
class Program:
    """A per-rank channel program: what the verifier checks.

    ``spmd=True`` marks programs expanded from one SPMD op stream — every
    rank runs the same sequence, which licenses the aligned prefix walk the
    credit-window check uses (an MPMD program only gets the
    interleaving-independent totals rule)."""

    ranks: dict  # rank -> list[ChannelOp]
    size: int
    spmd: bool = False
    name: str = "program"

    def all_ops(self):
        for r in sorted(self.ranks):
            yield from self.ranks[r]


def as_program(src, size: int | None = None, name: str = "program") -> Program:
    """Normalise a capture ledger / flat op list into a :class:`Program`.

    SPMD ops (``rank=None``) are expanded to every rank; ops that already
    carry a rank stay where they are.  ``size`` defaults to the largest
    communicator size any op saw."""
    ops = src.ops if isinstance(src, CaptureLedger) else list(src)
    if size is None:
        size = max(
            [getattr(src, "size", 0)] + [o.size for o in ops] + [1]
        )
    ranks: dict[int, list] = {r: [] for r in range(size)}
    spmd = True
    for o in ops:
        if o.rank is None:
            for r in range(size):
                ranks[r].append(o.replace(rank=r))
        else:
            spmd = False
            assert 0 <= o.rank < size, (o.rank, size)
            ranks[o.rank].append(o)
    return Program(ranks=ranks, size=size, spmd=spmd, name=name)


class _RankOps:
    """Fluent per-rank op appender (see :class:`ProgramBuilder`)."""

    def __init__(self, builder: "ProgramBuilder", rank: int):
        self._b = builder
        self._rank = rank

    def _add(self, op: str, **kw):
        kw.setdefault("size", self._b.size)
        kw.setdefault("comm", self._b.comm)
        self._b.ops.append(ChannelOp(op=op, rank=self._rank, **kw))
        return self

    def open(self, **kw):
        return self._add("open", **kw)

    def close(self, **kw):
        return self._add("close", **kw)

    def push(self, **kw):
        return self._add("push", **kw)

    def pop(self, **kw):
        return self._add("pop", **kw)

    def transfer(self, **kw):
        return self._add("transfer", **kw)

    def pool_open(self, **kw):
        kw.setdefault("persistent", True)
        return self._add("pool.open", **kw)

    def pool_close(self, **kw):
        kw.setdefault("persistent", True)
        return self._add("pool.close", **kw)


class ProgramBuilder:
    """Hand-build an MPMD channel program (the known-bad corpus' tool).

    >>> b = ProgramBuilder(size=2)
    >>> b.rank(0).open(kind="p2p", port=0, src=0, dst=1).push(port=0)
    >>> b.rank(1).open(kind="p2p", port=0, src=0, dst=1).pop(port=0)
    >>> prog = b.build()
    """

    def __init__(self, size: int, comm: str = "world"):
        self.size = int(size)
        self.comm = comm
        self.ops: list[ChannelOp] = []

    def rank(self, r: int) -> _RankOps:
        assert 0 <= r < self.size, (r, self.size)
        return _RankOps(self, r)

    def spmd(self) -> _RankOps:
        """Appender for SPMD ops (every rank performs them)."""
        ops = _RankOps(self, 0)
        ops._rank = None  # type: ignore[assignment]
        return ops

    def build(self, name: str = "program") -> Program:
        return as_program(self.ops, size=self.size, name=name)
