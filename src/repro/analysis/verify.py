"""smilint capture-mode verifier: static checks over channel programs.

Implements the semantic half of the rule catalog (DESIGN.md §14) over a
:class:`~repro.analysis.ops.Program`:

* **SMI101 port-claim collision** — two live claims of one ``(comm, port)``
  at a rank (the PortAllocator raises at runtime; here it is a diagnostic
  with a source location *before* anything runs).
* **SMI102 endpoint mismatch** — the ranks of one port's channel disagree
  on kind/dtype/wire/transport/count/peers, or a required peer never opens
  the port at all (the paper's §4 matched-signature rule).
* **SMI103 push/pop imbalance** — elements pushed that the consumer side
  can never pop (or pushes beyond a bounded channel's ``count``).
* **SMI104 credit-window overrun** — more outstanding pushes than the
  channel's statically-known window (1-deep p2p pipe register, P-deep
  bcast/reduce FIFO, 1-deep round channels): the push the runtime would
  refuse or silently overwrite.
* **SMI105 persistent-claim leak** — a persistent (pool) claim never
  released; trace exits never lapse it, so it is gone for good.
* **SMI106 deadlock cycle** — a Kahn-style topological run of the per-rank
  op orders over the inter-rank wait-for relation gets stuck: blocked pops
  whose producers are themselves blocked, reported as the cycle.

Deliberately jax-free: the verifier runs over captured ledgers and over
hand-built MPMD corpus programs identically.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from .ops import CaptureLedger, ChannelOp, Program, as_program

#: rule id -> (severity, one-line summary).  The single catalog both passes
#: share; ids below 100 are AST source lints (repro/analysis/rules.py).
CATALOG = {
    "SMI001": ("error", "deprecated stream_* shim call"),
    "SMI002": ("error", "channel opened outside with/close discipline"),
    "SMI003": ("error", "hardcoded port/tag collides with a reserved range"),
    "SMI004": ("error", "raw lax collective bypasses the tagged channel layer"),
    "SMI101": ("error", "port-claim collision"),
    "SMI102": ("error", "cross-rank endpoint mismatch"),
    "SMI103": ("error", "push/pop count imbalance"),
    "SMI104": ("error", "credit-window overrun"),
    "SMI105": ("error", "persistent claim leaked (never released)"),
    "SMI106": ("error", "deadlock cycle in the channel wait-for graph"),
}


@dataclass
class Diagnostic:
    """One machine-readable smilint finding (rule id, rank, port, tag,
    source location — the schema the CI artifact carries)."""

    rule: str
    message: str
    rank: int | None = None
    port: int | None = None
    tag: str | None = None
    location: str | None = None
    severity: str = field(default="")

    def __post_init__(self):
        if not self.severity:
            self.severity = CATALOG.get(self.rule, ("error", ""))[0]

    def to_dict(self) -> dict:
        return {
            "rule": self.rule,
            "severity": self.severity,
            "message": self.message,
            "rank": self.rank,
            "port": self.port,
            "tag": self.tag,
            "location": self.location,
        }

    def __str__(self):
        where = f" @{self.location}" if self.location else ""
        rank = "all-ranks" if self.rank is None else f"rank {self.rank}"
        port = "" if self.port is None else f" port {self.port}"
        tag = "" if self.tag is None else f" tag {self.tag!r}"
        return (f"{self.rule} [{self.severity}] {rank}{port}{tag}: "
                f"{self.message}{where}")


# -- channel identity --------------------------------------------------------


def _ckey(op: ChannelOp):
    """Cross-rank channel identity: the claimed port; anonymous channels
    fall back to the rank-local instance id (no cross-rank identity)."""
    if op.port is not None:
        return ("port", op.comm, op.port)
    return ("anon", op.comm, op.chan)


def _participants(d: ChannelOp) -> set:
    """Ranks required to open a channel with descriptor ``d``."""
    if d.kind == "p2p":
        return {d.src, d.dst}
    return set(range(d.size))


def _producers(d: ChannelOp) -> set:
    """Ranks whose pushes feed the channel."""
    if d.kind == "p2p":
        return {d.src}
    if d.kind in ("bcast", "scatter"):
        return {d.root}
    return set(range(d.size))  # reduce / gather / allreduce: everyone


def _consumers(d: ChannelOp) -> set:
    """Ranks whose pops deliver valid elements."""
    if d.kind == "p2p":
        return {d.dst}
    if d.kind in ("reduce", "gather"):
        return {d.root}
    return set(range(d.size))  # bcast / scatter / allreduce: everyone


def _window(d: ChannelOp) -> int:
    """Statically-known credit window per producing rank: the 1-deep p2p
    pipe register, the P-deep bcast/reduce contribution FIFO (paper §3.3),
    the 1-deep staging slot of the round channels."""
    if d.kind in ("bcast", "reduce"):
        return max(d.size, 1)
    return 1


# -- SMI101: port-claim collisions -------------------------------------------


def _check_collisions(prog: Program) -> list:
    diags = []
    for r in sorted(prog.ranks):
        live: dict = {}
        for op in prog.ranks[r]:
            if op.port is None:
                continue
            key = (op.comm, op.port)
            if op.op in ("open", "pool.open"):
                if key in live:
                    first = live[key]
                    diags.append(Diagnostic(
                        "SMI101", rank=r, port=op.port, tag=op.tag,
                        location=op.location,
                        message=(
                            f"port {op.port} on comm {op.comm!r} is already "
                            f"claimed by a live {first.kind} channel"
                            + (f" (opened at {first.location})"
                               if first.location else "")
                            + "; SMI ports identify distinct hardware "
                              "endpoints and cannot be shared"),
                    ))
                else:
                    live[key] = op
            elif op.op in ("close", "pool.close"):
                live.pop(key, None)
    return diags


# -- SMI102: cross-rank endpoint matching ------------------------------------

#: open-descriptor fields every endpoint of a channel must agree on
_MATCH_FIELDS = ("kind", "dtype", "wire", "transport", "count",
                 "src", "dst", "root", "persistent")


def _check_endpoints(prog: Program) -> list:
    diags = []
    # per cross-rank channel key: rank -> ordered list of opens
    opens: dict = {}
    for op in prog.all_ops():
        if op.op in ("open", "pool.open") and op.port is not None:
            opens.setdefault(("port", op.comm, op.port), {}) \
                 .setdefault(op.rank, []).append(op)
    for (_, comm, port), per_rank in sorted(opens.items()):
        n_gen = max(len(v) for v in per_rank.values())
        for gen in range(n_gen):
            gen_opens = {r: v[gen] for r, v in per_rank.items()
                         if len(v) > gen}
            ref_rank = min(gen_opens)
            ref = gen_opens[ref_rank]
            # every required participant must open this generation
            for r in sorted(_participants(ref)):
                if r not in gen_opens:
                    diags.append(Diagnostic(
                        "SMI102", rank=r, port=port, tag=ref.tag,
                        location=ref.location,
                        message=(
                            f"rank {r} never opens port {port} on comm "
                            f"{comm!r}, but the {ref.kind} channel rank "
                            f"{ref_rank} opened there names it as an "
                            "endpoint (unmatched peer)"),
                    ))
            # and every rank that did open must agree with the reference
            for r, d in sorted(gen_opens.items()):
                if r == ref_rank:
                    continue
                bad = [f for f in _MATCH_FIELDS
                       if getattr(d, f) != getattr(ref, f)]
                if bad:
                    detail = ", ".join(
                        f"{f}: {getattr(ref, f)!r} (rank {ref_rank}) != "
                        f"{getattr(d, f)!r} (rank {r})" for f in bad
                    )
                    diags.append(Diagnostic(
                        "SMI102", rank=r, port=port, tag=d.tag,
                        location=d.location,
                        message=(f"endpoints of port {port} disagree on "
                                 f"{detail}"),
                    ))
    return diags


# -- SMI105: persistent-claim leaks ------------------------------------------


def _check_leaks(prog: Program) -> list:
    diags = []
    for r in sorted(prog.ranks):
        live: dict = {}
        for op in prog.ranks[r]:
            key = _ckey(op)
            if op.op in ("open", "pool.open") and op.persistent:
                live[key] = op
            elif op.op in ("close", "pool.close"):
                live.pop(key, None)
        for key, op in sorted(live.items(), key=lambda kv: str(kv[0])):
            diags.append(Diagnostic(
                "SMI105", rank=r, port=op.port, tag=op.tag,
                location=op.location,
                message=(
                    f"persistent claim of port {op.port} (tag {op.tag!r}) "
                    "is never released; persistent claims survive trace "
                    "exits and garbage collection — only an explicit "
                    "close()/pool.close() frees the port"),
            ))
    return diags


# -- SMI104: credit windows (SPMD lockstep walk) -----------------------------


def _check_windows(prog: Program) -> list:
    """Credit-window overrun on the aligned SPMD walk.

    Every rank of an SPMD program executes the same op sequence in
    lockstep, so pushes and the pops that drain them interleave in exactly
    the recorded order — the outstanding count is exact.  An MPMD program
    has no such alignment (any interleaving may drain between two pushes),
    so only SPMD programs get this check; MPMD over-production still
    surfaces as SMI103.
    """
    if not prog.spmd:
        return []
    diags = []
    # per channel: opening descriptor, pushes accepted, pops consumed
    desc: dict = {}
    pushed: dict = {}
    popped: dict = {}
    for op in prog.ranks.get(0, []):
        key = _ckey(op)
        if op.op in ("open", "pool.open"):
            desc[key] = op
            pushed[key] = popped[key] = 0
        elif op.op == "push":
            d = desc.get(key, op)
            pushed.setdefault(key, 0)
            popped.setdefault(key, 0)
            if pushed[key] - popped[key] >= _window(d):
                verb = ("silently overwrites the in-flight element"
                        if d.kind == "p2p" else "is refused")
                diags.append(Diagnostic(
                    "SMI104", rank=None, port=d.port, tag=d.tag or op.tag,
                    location=op.location,
                    message=(
                        f"push #{pushed[key] + 1} on {d.kind} channel "
                        f"(port {d.port}) exceeds the {_window(d)}-deep "
                        f"credit window and {verb}; pop before pushing "
                        "again"),
                ))
            else:
                pushed[key] += 1
        elif op.op == "pop":
            pushed.setdefault(key, 0)
            popped.setdefault(key, 0)
            # a drain-phase bubble pop consumes nothing and banks no credit
            popped[key] = min(popped[key] + 1, pushed[key])
    return diags


# -- the abstract scheduler: SMI103 + SMI106 ---------------------------------


class _ChanState:
    """Abstract runtime state of one channel during the Kahn run."""

    __slots__ = ("desc", "pushed", "popped", "future_pushes")

    def __init__(self, desc: ChannelOp, size: int):
        self.desc = desc
        self.pushed = dict.fromkeys(range(size), 0)   # pushes, per rank
        self.popped = dict.fromkeys(range(size), 0)   # pop attempts, per rank
        self.future_pushes = dict.fromkeys(range(size), 0)

    def available(self, rank: int) -> bool:
        """Can a pop at ``rank`` deliver one more element right now?"""
        d = self.desc
        if rank not in _consumers(d):
            return True  # bubble pop at a non-consumer: completes, invalid
        produced = min(self.pushed[p] for p in _producers(d))
        if d.count is not None:
            produced = min(produced, d.count)
        return self.popped[rank] < produced

    def producers_pending(self, rank: int) -> set:
        """Producer ranks that still owe this channel future pushes."""
        return {p for p in _producers(self.desc)
                if self.future_pushes.get(p, 0) > 0 and p != rank}


def _run_schedule(prog: Program):
    """Kahn-style topological execution of the per-rank op orders.

    Pushes never block (SMI refusal semantics — a full window refuses or
    overwrites, it does not stall, so it cannot deadlock; over-production
    is SMI103/SMI104's business).  A pop is ready when data is available
    *or* its producers have no future pushes left (the warm-up/drain
    bubble pop).  Returns ``(states, deadlock_diags)``: the final channel
    states for the balance check and — if the run gets stuck — the
    wait-for cycle."""
    size = prog.size
    # channel states, keyed by cross-rank identity; opened lazily so corpus
    # programs that push without opening still verify
    states: dict = {}

    def state(op: ChannelOp) -> _ChanState:
        key = _ckey(op)
        st = states.get(key)
        if st is None:
            st = states[key] = _ChanState(op, size)
        elif op.op in ("open", "pool.open"):
            st.desc = op  # refresh descriptor on (re)open
        return st

    # register descriptors first, then pre-scan future pushes per rank
    for op in prog.all_ops():
        if op.op in ("open", "pool.open"):
            state(op)
    for op in prog.all_ops():
        if op.op == "push":
            st = state(op)
            if op.rank in _producers(st.desc):
                st.future_pushes[op.rank] += 1

    pc = {r: 0 for r in range(size)}
    seqs = {r: prog.ranks.get(r, []) for r in range(size)}

    def try_step(r: int) -> bool:
        seq = seqs[r]
        if pc[r] >= len(seq):
            return False
        op = seq[pc[r]]
        if op.op in ("open", "close", "transfer", "pool.open", "pool.close"):
            state(op)  # ensure descriptor exists
            pc[r] += 1
            return True
        st = state(op)
        if op.op == "push":
            if r in _producers(st.desc):
                st.future_pushes[r] -= 1
                st.pushed[r] += 1
            pc[r] += 1
            return True
        assert op.op == "pop", op.op
        if st.available(r) or not st.producers_pending(r):
            st.popped[r] += 1
            pc[r] += 1
            return True
        return False  # blocked on data

    remaining = sum(len(s) for s in seqs.values())
    while remaining:
        progressed = False
        for r in range(size):
            while try_step(r):
                progressed = True
        remaining = sum(len(seqs[r]) - pc[r] for r in range(size))
        if not progressed:
            break

    deadlocks: list = []
    if remaining:
        # every stuck rank is blocked on a pop; walk the wait-for edges
        # (blocked rank -> producers it waits on) to present the cycle
        blocked = {}
        for r in range(size):
            if pc[r] < len(seqs[r]):
                op = seqs[r][pc[r]]
                if op.op == "pop":
                    st = state(op)
                    blocked[r] = (op, st.producers_pending(r))
        chain = []
        for r, (op, waits_on) in sorted(blocked.items()):
            others = sorted(w for w in waits_on if w in blocked) or \
                sorted(waits_on)
            chain.append(f"rank {r} waits on port {op.port} "
                         f"(producer rank{'s' if len(others) != 1 else ''} "
                         f"{', '.join(map(str, others))})")
        first = sorted(blocked)[0] if blocked else None
        op0 = blocked[first][0] if blocked else None
        deadlocks.append(Diagnostic(
            "SMI106",
            rank=first,
            port=op0.port if op0 is not None else None,
            tag=op0.tag if op0 is not None else None,
            location=op0.location if op0 is not None else None,
            message=("channel wait-for graph has a cycle; no rank can make "
                     "progress: " + "; ".join(chain)),
        ))
    return states, deadlocks


def _check_balance(states: dict) -> list:
    diags = []
    for key, st in sorted(states.items(), key=lambda kv: str(kv[0])):
        d = st.desc
        producers, consumers = _producers(d), _consumers(d)
        counts = {st.pushed[p] for p in producers}
        if len(counts) > 1 and d.kind in ("reduce", "gather", "allreduce"):
            detail = ", ".join(f"rank {p}: {st.pushed[p]}"
                               for p in sorted(producers))
            diags.append(Diagnostic(
                "SMI103", rank=min(producers, key=lambda p: st.pushed[p]),
                port=d.port, tag=d.tag, location=d.location,
                message=(f"{d.kind} channel contributions are unbalanced "
                         f"({detail}); every rank must push equally"),
            ))
        produced = min(st.pushed[p] for p in producers) if producers else 0
        deliverable = produced
        if d.count is not None:
            deliverable = min(deliverable, d.count)
            excess = max(st.pushed[p] for p in producers) - d.count
            if excess > 0:
                diags.append(Diagnostic(
                    "SMI103", rank=max(producers,
                                       key=lambda p: st.pushed[p]),
                    port=d.port, tag=d.tag, location=d.location,
                    message=(f"{excess} push(es) beyond the channel's "
                             f"count={d.count} can never be delivered"),
                ))
        for c in sorted(consumers):
            if st.popped[c] < deliverable:
                diags.append(Diagnostic(
                    "SMI103", rank=c, port=d.port, tag=d.tag,
                    location=d.location,
                    message=(f"{deliverable - st.popped[c]} element(s) "
                             f"pushed on the {d.kind} channel are never "
                             f"popped at rank {c} "
                             f"({st.popped[c]}/{deliverable} pops)"),
                ))
    return diags


# -- entry points ------------------------------------------------------------


def verify_program(prog: Program) -> list:
    """Run every capture-mode rule over ``prog``; diagnostics sorted by
    rule id, then rank."""
    diags = []
    diags += _check_collisions(prog)
    diags += _check_endpoints(prog)
    diags += _check_leaks(prog)
    diags += _check_windows(prog)
    states, deadlocks = _run_schedule(prog)
    diags += deadlocks
    # a deadlocked program never finished its pops; the balance counts are
    # partial and would double-report every blocked element
    if not deadlocks:
        diags += _check_balance(states)
    return sorted(diags, key=lambda d: (d.rule, d.rank if d.rank is not None
                                        else -1, d.port or 0))


def verify_ledger(led: CaptureLedger, size: int | None = None,
                  name: str = "capture") -> list:
    """Expand a captured SPMD op stream per rank and verify it."""
    return verify_program(as_program(led, size=size, name=name))
