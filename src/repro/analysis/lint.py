"""smilint CLI: both verifier passes over the repo, one exit code.

    PYTHONPATH=src python -m repro.analysis.lint            # everything
    python -m repro.analysis.lint --ast                     # source lints only
    python -m repro.analysis.lint --capture --programs launch.train
    python -m repro.analysis.lint --corpus --json report.json

Three gates, all of which must hold for exit 0 (the CI contract):

1. **AST pass** — every source file under src/scripts/benchmarks/examples
   is clean under the SMI00x rules (``--ast``).
2. **Capture pass** — every in-repo channel program traces under
   :func:`repro.analysis.capture` with zero diagnostics and zero *real*
   transport steps (``--capture``; abstract interpretation must move no
   bytes).
3. **Corpus pass** — every seeded defect reports exactly its golden rule
   ids (``--corpus``; a verifier that goes quiet fails the same gate as
   a program that goes bad).

``--json`` writes the full machine-readable report (rule id, severity,
rank, port, tag, source location per diagnostic) for the CI artifact.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

# the capture pass traces 8-rank SPMD programs on the host platform; set
# before anything imports jax (the launch/stencil.py pattern)
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")


def _ast_pass(root: str) -> tuple[dict, bool]:
    from .rules import lint_paths

    diags = lint_paths(root)
    for d in diags:
        print(f"  {d}")
    ok = not diags
    return {"diagnostics": [d.to_dict() for d in diags]}, ok


def _capture_pass(names) -> tuple[dict, bool]:
    from .programs import PROGRAMS, run_programs

    unknown = [n for n in names or [] if n not in PROGRAMS]
    if unknown:
        raise SystemExit(
            f"unknown program(s) {unknown}; have {sorted(PROGRAMS)}")
    rows, ok = run_programs(names or None)
    for row in rows:
        mark = "ok" if row["ok"] else "FAIL"
        n_ops = sum(row["ops"].values())
        print(f"  [{mark}] {row['program']}: {n_ops} ops over "
              f"{len(row['transport_steps'])} channels, "
              f"real_steps={row['real_steps']}, "
              f"{len(row['diagnostics'])} diagnostics")
        for d in row["diagnostics"]:
            print(f"      {d['rule']} {d['message']}")
    return {"programs": rows}, ok


def _corpus_pass() -> tuple[dict, bool]:
    from .corpus import run_corpus

    rows, ok = run_corpus()
    for row in rows:
        mark = "ok" if row["ok"] else "FAIL"
        print(f"  [{mark}] {row['case']}: golden={row['golden']} "
              f"reported={row['reported']}")
    return {"corpus": rows}, ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="smilint",
        description="static + capture-mode verifier for SMI channel "
                    "programs (DESIGN.md §14)")
    ap.add_argument("--ast", action="store_true",
                    help="AST source lints over the repo")
    ap.add_argument("--capture", action="store_true",
                    help="capture-mode verification of in-repo programs")
    ap.add_argument("--corpus", action="store_true",
                    help="golden-rule check over the seeded defect corpus")
    ap.add_argument("--programs", nargs="*", default=None, metavar="NAME",
                    help="capture only these programs (default: all)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the machine-readable report here")
    ap.add_argument("--root", default=None,
                    help="repo root for the AST sweep (default: cwd)")
    args = ap.parse_args(argv)

    # no pass selected = every pass (the CI invocation)
    run_all = not (args.ast or args.capture or args.corpus)
    report: dict = {}
    ok = True

    if run_all or args.ast:
        root = args.root or os.getcwd()
        print(f"smilint: AST pass over {root}")
        part, good = _ast_pass(root)
        report["ast"] = part
        ok = ok and good
        print(f"  -> {'clean' if good else 'DIAGNOSTICS'}")
    if run_all or args.capture:
        print("smilint: capture pass (abstract interpretation, no comm)")
        part, good = _capture_pass(args.programs)
        report["capture"] = part
        ok = ok and good
        print(f"  -> {'clean' if good else 'FAILED'}")
    if run_all or args.corpus:
        print("smilint: corpus pass (seeded defects vs golden rules)")
        part, good = _corpus_pass()
        report["corpus"] = part
        ok = ok and good
        print(f"  -> {'all matched' if good else 'MISMATCH'}")

    report["ok"] = ok
    if args.json:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"smilint: report -> {args.json}")
    print(f"smilint: {'PASS' if ok else 'FAIL'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
