"""Capture mode: record channel programs without moving a byte.

Inside :func:`capture`, the channel API becomes an abstract interpreter of
itself (DESIGN.md §14):

* every ``ChannelSpec.resolve()`` / ``get_transport()`` hands back an
  :class:`AbstractTransport` — a backend whose steps account into the
  capture ledger and return zeros, so ``jit(...).lower()`` traces the whole
  program (channel opens, pushes, pops, transfers, pool claims) while **no
  collective executes**;
* every channel op records a :class:`~repro.analysis.ops.ChannelOp` into
  the active :class:`~repro.analysis.ops.CaptureLedger` (the ``if
  _capture.ACTIVE:`` guards in ``repro/channels`` mirror the zero-overhead
  ``if obs.TRACING:`` tracing hooks);
* ``Transport.tally`` — the single accounting funnel every *real* backend
  reports through — is class-patched to count into ``ledger.real_steps``,
  which must stay 0: the assertable no-comm-executed contract.

The guards make capture strictly opt-in: when ``ACTIVE`` is False (always,
unless a :func:`capture` block is running) the channel layer pays one
module-attribute check per op and nothing else.
"""

from __future__ import annotations

import os
import sys
from contextlib import contextmanager
from dataclasses import dataclass

from ..transport.base import Transport, tree_bytes
from .ops import CaptureLedger, ChannelOp

#: True while a :func:`capture` block is running (the channel layer's guard)
ACTIVE = False

#: the ledger the running capture records into (None outside capture)
LEDGER: CaptureLedger | None = None

#: the unpatched accounting funnel (bound at import, before any patching)
_REAL_TALLY = Transport.tally

#: directories whose frames are skipped when attributing a source location
#: (the channel machinery itself is never the interesting line)
_SKIP_DIRS = (
    os.sep + os.path.join("repro", "analysis") + os.sep,
    os.sep + os.path.join("repro", "channels") + os.sep,
)


def source_location(skip: int = 1) -> str | None:
    """``file.py:line`` of the nearest caller outside the channel machinery
    (repo-relative when under the working tree)."""
    f = sys._getframe(skip)
    while f is not None:
        fn = f.f_code.co_filename
        if not any(d in fn for d in _SKIP_DIRS):
            rel = os.path.relpath(fn)
            if not rel.startswith(".."):
                fn = rel
            return f"{fn}:{f.f_lineno}"
        f = f.f_back
    return None


def _comm_name(comm) -> str:
    """Cross-rank channel identity needs the communicator's identity; the
    name plus the instance id separates two comms that share a name."""
    return f"{getattr(comm, 'name', 'world')}#{id(comm):x}"


def record(op: str, spec=None, **over):
    """Record one channel op against the active ledger (no-op when no
    capture is running — callers guard on ``ACTIVE`` anyway)."""
    led = LEDGER
    if led is None:
        return
    kw: dict = {}
    if spec is not None:
        comm = spec.comm
        try:
            tkey = spec.transport_key
        except Exception:
            tkey = None
        kw = dict(
            chan=led.chan_id(spec),
            kind=spec.kind,
            port=spec.port,
            tag=spec.stats_tag,
            comm=_comm_name(comm),
            size=comm.size,
            src=spec.src,
            dst=spec.dst,
            root=spec.root,
            count=spec.count,
            wire=spec.wire,
            transport=tkey,
            persistent=spec.persistent,
        )
    kw.update(over)
    kw.setdefault("location", source_location(skip=2))
    led.add(ChannelOp(op=op, **kw))


@dataclass
class AbstractTransport(Transport):
    """The no-op backend capture substitutes for every real one.

    Schedule-shaped: ``permute`` accounts one link step, ``p2p`` accounts
    the chunk-pipelined ``n_chunks + hops - 1`` steps of the routed pipe —
    the same trace-time cost formulae the real backends use — but every
    step returns zeros instead of issuing a ``ppermute``.  Tallies land in
    ``ledger.transport_steps`` (per tag), never in ``real_steps``.
    """

    name = "abstract"

    def permute(self, x, comm, pairs):
        import jax
        import jax.numpy as jnp

        self.account(x)
        return jax.tree.map(jnp.zeros_like, x)

    def p2p(self, x, *, src, dst, comm, n_chunks: int = 1):
        import jax
        import jax.numpy as jnp

        if src == dst:
            return x
        hops = len(comm.route_table.path(src, dst)) - 1
        self.tally(n_chunks + hops - 1, tree_bytes(x))
        return jax.tree.map(jnp.zeros_like, x)

    def tally(self, steps: int, nbytes: int):
        led = LEDGER
        if led is not None:
            led.tally_abstract(self._tag, steps, nbytes)
        _REAL_TALLY(self, steps, nbytes)  # per-instance stats stay coherent


def _counting_tally(self, steps: int, nbytes: int):
    """The :func:`capture`-time ``Transport.tally``: any *real* backend
    stepping during capture is exactly what capture exists to prevent, so
    it is counted (and asserted zero by the acceptance tests)."""
    led = LEDGER
    if led is not None and not isinstance(self, AbstractTransport):
        led.real_steps += steps
    _REAL_TALLY(self, steps, nbytes)


@contextmanager
def capture(size: int | None = None):
    """Record every channel op under the block into a fresh ledger.

    Trace the program (``jax.jit(...).lower(shapes...)``) inside the block;
    nothing executes.  Not reentrant — the ledger is process-global, like
    the obs tracer it mirrors.

    >>> with capture() as led:
    ...     jax.jit(step).lower(state_shape, batch_shape)
    >>> assert led.real_steps == 0
    >>> diags = verify_ledger(led)
    """
    global ACTIVE, LEDGER
    assert not ACTIVE, "capture() blocks do not nest"
    led = CaptureLedger()
    if size is not None:
        led.size = int(size)
    prev_tally = Transport.tally
    Transport.tally = _counting_tally
    ACTIVE, LEDGER = True, led
    try:
        yield led
    finally:
        ACTIVE = False
        LEDGER = None
        Transport.tally = prev_tally
