"""The seeded known-bad corpus: one program per rule, with golden ids.

CI runs smilint in both directions (DESIGN.md §14): every in-repo program
must be *clean*, and every corpus entry must report **exactly** its golden
rule set — a verifier that goes quiet (or noisy) fails the gate either
way.  Capture-mode defects are hand-built MPMD/SPMD channel programs
(:class:`~repro.analysis.ops.ProgramBuilder` — endpoint mismatches and
deadlock cycles cannot even be expressed by an SPMD trace); AST defects
are seeded source snippets run through :func:`~repro.analysis.rules.
lint_source`.
"""

from __future__ import annotations

from dataclasses import dataclass

from .ops import Program, ProgramBuilder
from .rules import lint_source
from .verify import verify_program


@dataclass
class CorpusCase:
    """One seeded defect: a program or source snippet plus its golden
    rule-id set (what the verifier MUST report, and nothing else)."""

    name: str
    golden: frozenset
    program: Program | None = None
    source: str | None = None
    #: repo path the AST seed pretends to live at (path-scoped rules)
    relpath: str | None = None
    note: str = ""

    def run(self) -> list:
        """The diagnostics smilint reports for this case."""
        if self.program is not None:
            return verify_program(self.program)
        rel = self.relpath or f"src/repro/seeded/{self.name}.py"
        return lint_source(self.source, relpath=rel)

    def reported(self) -> frozenset:
        return frozenset(d.rule for d in self.run())

    def ok(self) -> bool:
        return self.reported() == self.golden


# -- capture-mode defects -----------------------------------------------------


def _port_collision() -> CorpusCase:
    """SMI101: every rank claims port 3 twice without closing — the
    second open collides with the live first claim."""
    b = ProgramBuilder(size=4)
    s = b.spmd()
    s.open(kind="p2p", port=3, src=0, dst=1, count=2, dtype="float32")
    s.open(kind="p2p", port=3, src=0, dst=1, count=2, dtype="float32")
    s.push(port=3, src=0, dst=1, count=2)
    s.pop(port=3, src=0, dst=1, count=2)
    s.push(port=3, src=0, dst=1, count=2)
    s.pop(port=3, src=0, dst=1, count=2)
    s.close(port=3, src=0, dst=1)
    s.close(port=3, src=0, dst=1)
    return CorpusCase(
        name="port_collision", golden=frozenset({"SMI101"}),
        program=b.build("port_collision"),
        note="double claim of one live (comm, port)",
    )


def _endpoint_mismatch() -> CorpusCase:
    """SMI102: sender opens port 0 as float32/raw/static; receiver opens
    the same port as int8 over the compressed wire — the paper's matched
    signature rule (§4) broken in dtype and wire."""
    b = ProgramBuilder(size=2)
    b.rank(0) \
        .open(kind="p2p", port=0, src=0, dst=1, count=1, dtype="float32",
              wire="raw", transport="static") \
        .push(port=0, src=0, dst=1, count=1) \
        .close(port=0, src=0, dst=1)
    b.rank(1) \
        .open(kind="p2p", port=0, src=0, dst=1, count=1, dtype="int8",
              wire="int8", transport="compressed:static") \
        .pop(port=0, src=0, dst=1, count=1) \
        .close(port=0, src=0, dst=1)
    return CorpusCase(
        name="endpoint_mismatch", golden=frozenset({"SMI102"}),
        program=b.build("endpoint_mismatch"),
        note="dtype/wire/transport disagree across the port's endpoints",
    )


def _unmatched_peer() -> CorpusCase:
    """SMI102 (unmatched flavour): the sender opens a p2p channel to rank
    1, which never opens the port — a message with no receiver.  The
    sender's unpoppable push co-reports as SMI103."""
    b = ProgramBuilder(size=2)
    b.rank(0) \
        .open(kind="p2p", port=7, src=0, dst=1, count=1, dtype="float32") \
        .push(port=7, src=0, dst=1, count=1) \
        .close(port=7, src=0, dst=1)
    return CorpusCase(
        name="unmatched_peer", golden=frozenset({"SMI102", "SMI103"}),
        program=b.build("unmatched_peer"),
        note="peer rank never opens the port",
    )


def _push_pop_imbalance() -> CorpusCase:
    """SMI103: the producer pushes four elements; the consumer pops one
    — three elements the program provably never delivers."""
    b = ProgramBuilder(size=2)
    r0 = b.rank(0).open(kind="p2p", port=0, src=0, dst=1, count=4,
                        dtype="float32")
    for _ in range(4):
        r0.push(port=0, src=0, dst=1, count=4)
    r0.close(port=0, src=0, dst=1)
    b.rank(1).open(kind="p2p", port=0, src=0, dst=1, count=4,
                   dtype="float32") \
        .pop(port=0, src=0, dst=1, count=4) \
        .close(port=0, src=0, dst=1)
    return CorpusCase(
        name="push_pop_imbalance", golden=frozenset({"SMI103"}),
        program=b.build("push_pop_imbalance"),
        note="4 pushes vs 1 pop on a bounded channel",
    )


def _credit_overrun() -> CorpusCase:
    """SMI104: an SPMD program pushes twice into the 1-deep p2p pipe
    before any pop — the second push silently overwrites the in-flight
    element (Channel.push has no backpressure on the pipe register)."""
    b = ProgramBuilder(size=2)
    s = b.spmd()
    s.open(kind="p2p", port=0, src=0, dst=1, count=2, dtype="float32")
    s.push(port=0, src=0, dst=1, count=2)
    s.push(port=0, src=0, dst=1, count=2)
    s.pop(port=0, src=0, dst=1, count=2)
    s.pop(port=0, src=0, dst=1, count=2)
    s.close(port=0, src=0, dst=1)
    return CorpusCase(
        name="credit_overrun", golden=frozenset({"SMI104"}),
        program=b.build("credit_overrun"),
        note="2 outstanding pushes vs the 1-deep p2p credit window",
    )


def _claim_leak() -> CorpusCase:
    """SMI105: a persistent pool claim with no matching pool.close —
    persistent claims survive trace exits and GC, so the port is gone
    for good."""
    b = ProgramBuilder(size=4)
    s = b.spmd()
    s.pool_open(kind="allreduce", port=100, tag="serve.decode.mlp",
                dtype="float32")
    s.pool_open(kind="allreduce", port=101, tag="serve.decode.attn",
                dtype="float32")
    s.pool_close(kind="allreduce", port=101, tag="serve.decode.attn")
    return CorpusCase(
        name="claim_leak", golden=frozenset({"SMI105"}),
        program=b.build("claim_leak"),
        note="persistent claim on port 100 never released",
    )


def _deadlock_cycle() -> CorpusCase:
    """SMI106: rank 0 pops from rank 1 before pushing to it; rank 1 pops
    from rank 0 before pushing to it — a two-rank wait-for cycle no
    schedule can break."""
    b = ProgramBuilder(size=2)
    b.rank(0) \
        .open(kind="p2p", port=0, src=1, dst=0, count=1, dtype="float32") \
        .open(kind="p2p", port=1, src=0, dst=1, count=1, dtype="float32") \
        .pop(port=0, src=1, dst=0, count=1) \
        .push(port=1, src=0, dst=1, count=1) \
        .close(port=0, src=1, dst=0).close(port=1, src=0, dst=1)
    b.rank(1) \
        .open(kind="p2p", port=0, src=1, dst=0, count=1, dtype="float32") \
        .open(kind="p2p", port=1, src=0, dst=1, count=1, dtype="float32") \
        .pop(port=1, src=0, dst=1, count=1) \
        .push(port=0, src=1, dst=0, count=1) \
        .close(port=0, src=1, dst=0).close(port=1, src=0, dst=1)
    return CorpusCase(
        name="deadlock_cycle", golden=frozenset({"SMI106"}),
        program=b.build("deadlock_cycle"),
        note="mutual pop-before-push across two ports",
    )


# -- AST defects --------------------------------------------------------------

_AST_CASES = (
    CorpusCase(
        name="stream_shim", golden=frozenset({"SMI001"}),
        source="y = stream_bcast(x, comm, root=0)\n",
        note="deprecated stream_* shim under src/",
    ),
    CorpusCase(
        name="undisciplined_open", golden=frozenset({"SMI002"}),
        source=(
            "def step(comm, x):\n"
            "    ch = open_channel(comm, count=4, src=0, dst=1, port=0)\n"
            "    ch = ch.push(x)\n"
            "    return x\n"
        ),
        note="port-claiming open: no with, no close, no escape",
    ),
    CorpusCase(
        name="reserved_port", golden=frozenset({"SMI003"}),
        source=(
            "def step(comm, x):\n"
            "    with open_allreduce_channel(comm, port=150,\n"
            "                                elem_shape=()) as ch:\n"
            "        return ch.transfer(x)\n"
        ),
        note="hardcoded port inside the serving pool's reserved range",
    ),
    CorpusCase(
        name="raw_collective", golden=frozenset({"SMI004"}),
        source="def fwd(x):\n    return lax.psum(x, 'model')\n",
        relpath="src/repro/models/seeded.py",
        note="raw lax collective bypassing the tagged channel layer",
    ),
)


def corpus() -> tuple:
    """Every seeded case, capture-mode first, AST last."""
    return (
        _port_collision(),
        _endpoint_mismatch(),
        _unmatched_peer(),
        _push_pop_imbalance(),
        _credit_overrun(),
        _claim_leak(),
        _deadlock_cycle(),
    ) + _AST_CASES


def run_corpus() -> tuple[list, bool]:
    """``(report_rows, all_ok)``: per-case golden-vs-reported rows for
    the CLI / CI artifact."""
    rows = []
    ok = True
    for case in corpus():
        reported = case.reported()
        match = reported == case.golden
        ok = ok and match
        rows.append({
            "case": case.name,
            "golden": sorted(case.golden),
            "reported": sorted(reported),
            "ok": match,
            "note": case.note,
            "diagnostics": [d.to_dict() for d in case.run()],
        })
    return rows, ok
