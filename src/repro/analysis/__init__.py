"""smilint: static analysis for SMI channel programs (DESIGN.md §14).

Two passes over two program sources:

* **capture mode** (:mod:`repro.analysis.capture` + :mod:`.verify`) —
  abstract interpretation: trace a program with every transport replaced
  by a no-op accounting backend, then verify the recorded channel-op
  ledger (port collisions, endpoint matching, push/pop balance, credit
  windows, claim leaks, deadlock cycles);
* **AST lints** (:mod:`repro.analysis.rules`) — source-level rules over
  the tree (deprecated shims, close discipline, reserved ports, raw lax
  collectives), with ``# smilint: ignore[RULE]`` suppression.

CLI: ``python -m repro.analysis.lint`` / ``scripts/smilint.py``.

This package root is jax-free: the AST pass (and the CI lint job, which
has no jax) imports it freely.  ``capture`` / ``AbstractTransport`` pull
in the transport stack and resolve lazily on first attribute access;
``.programs`` and ``.lint`` pull in the launch stack and are imported
explicitly by the CLI only.
"""

from .ops import (  # noqa: F401
    CaptureLedger,
    ChannelOp,
    Program,
    ProgramBuilder,
    as_program,
)
from .verify import (  # noqa: F401
    CATALOG,
    Diagnostic,
    verify_ledger,
    verify_program,
)

#: lazy (jax-touching) exports -> defining submodule
_LAZY = {"capture": "capture", "record": "capture",
         "AbstractTransport": "capture", "source_location": "capture"}


def __getattr__(name):
    mod = _LAZY.get(name)
    if mod is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    return getattr(importlib.import_module(f".{mod}", __name__), name)
