"""smilint AST rules: source-level lints over the tree (DESIGN.md §14).

The static half of the rule catalog — no capture, no jax, just ``ast``
over the files the CLI sweeps:

* **SMI001** — deprecated ``stream_*`` collective shims under ``src/``
  (the generalisation of ``scripts/check_no_stream_shims.py``, which is
  now a thin shim over this rule);
* **SMI002** — a port-claiming ``open_*_channel`` call outside the
  ``with``/close discipline: the claim leaks until the opening trace is
  garbage-collected (anonymous ``port=None`` opens hold no claim and are
  exempt);
* **SMI003** — a hardcoded port literal inside the serving pool's
  reserved range (``ChannelPool`` assigns 100+ sequentially), or a
  ``"serve."``-prefixed tag literal, outside the serving/channels layer:
  the next engine start collides with it;
* **SMI004** — a raw ``lax`` collective (``psum``/``ppermute``/...) in
  ``models``/``parallel``/``serving``, bypassing the tagged channel layer
  (``parallel/layers.py`` is the one allowed site: it *is* the layer).

Suppression: a ``# smilint: ignore[RULE]`` (or ``ignore[RULE1,RULE2]``)
comment on the flagged line silences exactly those rules there.
"""

from __future__ import annotations

import ast
import pathlib
import re
from dataclasses import dataclass, field

from .verify import Diagnostic

#: ``# smilint: ignore[SMI001]`` / ``ignore[SMI001,SMI104]``
_SUPPRESS = re.compile(r"#\s*smilint:\s*ignore\[([A-Za-z0-9_,\s]+)\]")

#: the serving pool's reserved port range: ``ChannelPool(base_port=100)``
#: claims sequentially upward; DESIGN.md §13 budgets it one hundred ports
RESERVED_PORTS = range(100, 200)

#: the serving pool's tag namespace (``ChannelPool(prefix="serve.")``)
RESERVED_TAG_PREFIX = "serve."

#: the port-claiming channel-open family SMI002/SMI003 watch
OPEN_CALLS = ("open_channel", "open_bcast_channel", "open_reduce_channel",
              "open_scatter_channel", "open_gather_channel",
              "open_allreduce_channel")


@dataclass
class SourceFile:
    """One file under lint: text, parse tree, suppression map."""

    path: pathlib.Path
    relpath: str  # posix, relative to the sweep root
    text: str
    _tree: object = field(default=None, repr=False)
    _suppressed: dict | None = field(default=None, repr=False)

    @classmethod
    def load(cls, path: pathlib.Path, root: pathlib.Path) -> "SourceFile":
        rel = pathlib.PurePosixPath(path.relative_to(root).as_posix())
        return cls(path=path, relpath=str(rel), text=path.read_text())

    @property
    def lines(self) -> list:
        return self.text.splitlines()

    @property
    def tree(self):
        if self._tree is None:
            self._tree = ast.parse(self.text, filename=str(self.path))
            for node in ast.walk(self._tree):
                for child in ast.iter_child_nodes(node):
                    child._smilint_parent = node
        return self._tree

    def suppressed(self, lineno: int, rule: str) -> bool:
        if self._suppressed is None:
            sup: dict[int, set] = {}
            for i, line in enumerate(self.lines, start=1):
                m = _SUPPRESS.search(line)
                if m:
                    sup[i] = {r.strip() for r in m.group(1).split(",")}
            self._suppressed = sup
        return rule in self._suppressed.get(lineno, ())

    def diag(self, rule: str, lineno: int, message: str, **kw):
        return Diagnostic(rule=rule, message=message,
                          location=f"{self.relpath}:{lineno}", **kw)


class Rule:
    """One AST/source rule.  Subclasses set ``rule_id`` and implement
    :meth:`check`; :meth:`applies` scopes the rule by repo path."""

    rule_id = ""

    def applies(self, relpath: str) -> bool:
        return True

    def check(self, src: SourceFile) -> list:
        raise NotImplementedError


# -- SMI001: deprecated stream_* shims ---------------------------------------


class NoStreamShims(Rule):
    """The ``stream_*`` wrappers are deprecated since PR 8; the channels
    API is the supported surface.  Same contract as the original
    ``scripts/check_no_stream_shims.py``: any reference under ``src/``
    outside the shims' definition site and re-export is a regression."""

    rule_id = "SMI001"

    SHIMS = ("stream_bcast", "stream_reduce", "stream_gather",
             "stream_scatter", "stream_allreduce")
    PAT = re.compile(r"\b(" + "|".join(SHIMS) + r")\b")

    #: definition site + the package re-export keeping the shims importable
    #: + this rule catalog and its seeded-defect corpus, which must be able
    #: to *name* the shims they hunt
    ALLOWED = ("src/repro/core/collectives.py", "src/repro/core/__init__.py",
               "src/repro/analysis/rules.py", "src/repro/analysis/corpus.py")

    def applies(self, relpath: str) -> bool:
        return relpath.startswith("src/") and relpath not in self.ALLOWED

    def check(self, src: SourceFile) -> list:
        diags = []
        for lineno, line in enumerate(src.lines, start=1):
            m = self.PAT.search(line)
            if m:
                diags.append(src.diag(
                    self.rule_id, lineno,
                    f"deprecated shim {m.group(1)}() — use the channels "
                    "API (repro.channels.open_*_channel / ChannelSpec)",
                ))
        return diags


# -- SMI002: open outside with/close discipline ------------------------------


def _kwarg(call: ast.Call, name: str):
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def _is_none(node) -> bool:
    return isinstance(node, ast.Constant) and node.value is None


def _enclosing_scope(node):
    cur = getattr(node, "_smilint_parent", None)
    while cur is not None and not isinstance(
            cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Module)):
        cur = getattr(cur, "_smilint_parent", None)
    return cur


class CloseDiscipline(Rule):
    """A port-claiming open must be scoped: a ``with`` block, an explicit
    ``.close()`` on the bound name, or an escape (returned / yielded /
    passed on / stored on an object) that hands the obligation to the
    caller.  A bare open leaves the claim to the garbage collector —
    exactly the non-determinism the PortAllocator's weakref lifecycle
    exists to paper over, and persistent claims never lapse at all."""

    rule_id = "SMI002"

    def applies(self, relpath: str) -> bool:
        # the channels layer itself constructs channels it hands out
        return not relpath.startswith("src/repro/channels/")

    def check(self, src: SourceFile) -> list:
        diags = []
        for node in ast.walk(src.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Name)
                    and node.func.id in OPEN_CALLS):
                continue
            if _is_none(_kwarg(node, "port")):
                continue  # anonymous: no claim, nothing to leak
            if self._disciplined(node):
                continue
            diags.append(src.diag(
                self.rule_id, node.lineno,
                f"{node.func.id}(...) claims a port outside the "
                "with/close discipline — wrap it in `with`, call "
                ".close(), or open with port=None",
            ))
        return diags

    def _disciplined(self, call: ast.Call) -> bool:
        parent = getattr(call, "_smilint_parent", None)
        # with open_*(...) as ch: — the canonical form
        if isinstance(parent, ast.withitem):
            return True
        # escapes: return/yield it, pass it on, store it on an object —
        # the claim's lifetime is the caller's / owner's business
        if isinstance(parent, (ast.Return, ast.Yield, ast.YieldFrom,
                               ast.Call, ast.keyword, ast.Tuple, ast.List,
                               ast.Dict, ast.Starred)):
            return True
        # ch = open_*(...): look for ch.close() / an escape of ch in the
        # enclosing scope
        if isinstance(parent, ast.Assign) and len(parent.targets) == 1:
            target = parent.targets[0]
            if isinstance(target, (ast.Attribute, ast.Subscript)):
                return True  # stored on an object: ownership transferred
            if isinstance(target, ast.Name):
                return self._name_released(call, target.id)
        if isinstance(parent, (ast.AnnAssign, ast.NamedExpr)) and \
                isinstance(getattr(parent, "target", None), ast.Name):
            return self._name_released(call, parent.target.id)
        return False

    def _name_released(self, call: ast.Call, name: str) -> bool:
        scope = _enclosing_scope(call)
        if scope is None:
            return False
        for node in ast.walk(scope):
            # ch.close() — possibly rebound through loop carries first,
            # so any .close() on the name counts
            if (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "close"
                    and isinstance(node.func.value, ast.Name)
                    and node.func.value.id == name):
                return True
            # return ch / yield ch (alone or inside a tuple)
            if isinstance(node, (ast.Return, ast.Yield)) \
                    and node.value is not None:
                for sub in ast.walk(node.value):
                    if isinstance(sub, ast.Name) and sub.id == name:
                        return True
        return False


# -- SMI003: reserved ports / tags -------------------------------------------


class ReservedPorts(Rule):
    """Ports 100–199 belong to the serving pool (``ChannelPool`` claims
    from ``base_port=100`` upward) and ``"serve."`` is its tag namespace;
    a literal in either, outside the serving/channels layer, collides
    with the next engine start."""

    rule_id = "SMI003"

    #: layers that legitimately speak the reserved namespace
    ALLOWED_PREFIXES = ("src/repro/serving/", "src/repro/channels/",
                        "src/repro/launch/")

    def applies(self, relpath: str) -> bool:
        return not relpath.startswith(self.ALLOWED_PREFIXES)

    def check(self, src: SourceFile) -> list:
        diags = []
        for node in ast.walk(src.tree):
            if not isinstance(node, ast.Call):
                continue
            callee = node.func
            name = callee.id if isinstance(callee, ast.Name) else (
                callee.attr if isinstance(callee, ast.Attribute) else None)
            if name in OPEN_CALLS + ("ChannelSpec", "claim"):
                port = _kwarg(node, "port")
                if isinstance(port, ast.Constant) \
                        and isinstance(port.value, int) \
                        and port.value in RESERVED_PORTS:
                    diags.append(src.diag(
                        self.rule_id, port.lineno,
                        f"hardcoded port {port.value} lies in the serving "
                        f"pool's reserved range "
                        f"[{RESERVED_PORTS.start}, {RESERVED_PORTS.stop}) "
                        "— the pool claims these sequentially at engine "
                        "start", port=port.value,
                    ))
            if name in OPEN_CALLS + ("ChannelSpec", "layer_spec"):
                tag = _kwarg(node, "tag")
                if isinstance(tag, ast.Constant) \
                        and isinstance(tag.value, str) \
                        and tag.value.startswith(RESERVED_TAG_PREFIX):
                    diags.append(src.diag(
                        self.rule_id, tag.lineno,
                        f"tag {tag.value!r} uses the serving pool's "
                        f"reserved {RESERVED_TAG_PREFIX!r} namespace "
                        "outside the serving layer", tag=tag.value,
                    ))
        return diags


# -- SMI004: raw lax collectives ---------------------------------------------


class NoRawCollectives(Rule):
    """Model/parallel/serving code must move bytes through the tagged
    channel layer (``layer_spec`` / ``psum_tagged`` / channel transfers)
    so the ledger, netsim predictions and smilint capture see them; a raw
    ``lax`` collective is invisible traffic."""

    rule_id = "SMI004"

    COLLECTIVES = ("psum", "psum_scatter", "pmax", "pmin", "pmean",
                   "ppermute", "all_gather", "all_to_all")
    SCOPES = ("src/repro/models/", "src/repro/parallel/",
              "src/repro/serving/")
    #: the tagged channel layer itself: the one place raw lax collectives
    #: are the implementation, not a bypass
    ALLOWED = ("src/repro/parallel/layers.py",)

    def applies(self, relpath: str) -> bool:
        return relpath.startswith(self.SCOPES) \
            and relpath not in self.ALLOWED

    def check(self, src: SourceFile) -> list:
        diags = []
        for node in ast.walk(src.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr in self.COLLECTIVES):
                continue
            base = node.func.value
            base_name = base.id if isinstance(base, ast.Name) else (
                base.attr if isinstance(base, ast.Attribute) else None)
            if base_name != "lax":
                continue
            diags.append(src.diag(
                self.rule_id, node.lineno,
                f"raw lax.{node.func.attr}(...) bypasses the tagged "
                "channel layer — use repro.parallel.layers "
                "(layer_spec/psum_tagged) or a channel transfer",
            ))
        return diags


#: the registered rule set, catalog order
ALL_RULES = (NoStreamShims(), CloseDiscipline(), ReservedPorts(),
             NoRawCollectives())

#: directories the default sweep ignores entirely
_SKIP_PARTS = {".git", "__pycache__", ".ruff_cache", "build", "dist"}


def lint_paths(root, paths=None, rules=ALL_RULES) -> list:
    """Run the AST rules over ``paths`` (default: every ``*.py`` under
    ``root``'s ``src``, ``scripts``, ``benchmarks`` and ``examples``),
    returning suppression-filtered diagnostics sorted by location."""
    root = pathlib.Path(root).resolve()
    if paths is None:
        paths = []
        for sub in ("src", "scripts", "benchmarks", "examples"):
            d = root / sub
            if d.is_dir():
                paths.extend(sorted(d.rglob("*.py")))
    diags = []
    for path in paths:
        path = pathlib.Path(path).resolve()
        if _SKIP_PARTS.intersection(path.parts):
            continue
        src = SourceFile.load(path, root)
        for rule in rules:
            if not rule.applies(src.relpath):
                continue
            for d in rule.check(src):
                lineno = int(d.location.rsplit(":", 1)[1])
                if not src.suppressed(lineno, d.rule):
                    diags.append(d)
    return sorted(diags, key=lambda d: (d.location or "", d.rule))


def lint_source(text: str, relpath: str = "src/seeded.py",
                rules=ALL_RULES) -> list:
    """Rule run over an in-memory source string (the corpus' AST seeds)."""
    src = SourceFile(path=pathlib.Path(relpath), relpath=relpath, text=text)
    diags = []
    for rule in rules:
        if rule.applies(relpath):
            for d in rule.check(src):
                lineno = int(d.location.rsplit(":", 1)[1])
                if not src.suppressed(lineno, d.rule):
                    diags.append(d)
    return sorted(diags, key=lambda d: (d.location or "", d.rule))
