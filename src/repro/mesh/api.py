"""Parallelism context: how model code talks to the mesh.

Models are written once against these helpers; the context selects

* ``comm_mode="smi"``  — the paper's streaming collectives (ring schedules
  overlapped with per-chunk GEMMs, core/overlap.py).  An optional suffix
  picks the transport backend moving the bytes (see repro/transport):
  ``"smi:static"`` (trace-time ppermute schedules, the default),
  ``"smi:packet"`` (the dynamic packet-switched router end to end),
  ``"smi:fused"`` (Pallas-fused shift+accumulate on TPU),
  ``"smi:compressed"`` (int8 compressed links with blockwise scales and
  per-hop error feedback; ``"smi:compressed:<inner>"`` picks the wrapped
  backend),
* ``comm_mode="bulk"`` — XLA bulk collectives (lax.all_gather / psum_scatter)
  — the "host-orchestrated bulk transfer" baseline of the paper's
  comparisons, and the fallback fast path,
* ``comm_mode="none"`` — single-device (smoke tests).

Sharding layout (TP over the ``model`` axis, Megatron-style + SP):
activations in the residual stream are *sequence-sharded*; column-parallel
projections consume an all-gather streamed through the GEMM; row-parallel
projections emit a reduce-scatter streamed through the GEMM.  DP gradient
sync runs over the (pod, data) axes.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from ..core import Communicator
from ..transport import resolve_comm_mode


@dataclass(frozen=True)
class ParallelCtx:
    """Everything model code needs to know about the mesh."""

    model_axis: str | None = None          # TP/SP/EP axis name
    batch_axes: tuple[str, ...] = ()       # DP axes ("pod", "data")
    model_comm: Communicator | None = None
    comm_mode: str = "none"                # smi | bulk | none (base mode)
    transport: str = "static"              # smi backend: static|packet|fused
    matmul_fn: Callable | None = None      # Pallas kernel injection
    mesh: object | None = None
    opt_shared_gather: bool = False        # beyond-paper: one seq ring/block
    opt_ring_attn: bool = False            # beyond-paper: KV-streaming attn
    #: persistent ChannelPool (serving): layer_spec resolves every layer
    #: tag to the pool's persistent, pool-prefixed spec instead of a
    #: transient per-call spec; None = transient lifecycle (training)
    channels: object = field(default=None, compare=False)
    #: default tuning plan for layer channels (None | "auto" | netsim
    #: Plan): the model config's ``comm_plan`` when the launch string
    #: doesn't pin a backend; an explicit ``smi:<backend>`` comm_mode is
    #: the escape hatch that keeps this None
    plan: object = field(default=None, compare=False)

    @property
    def is_smi(self) -> bool:
        return self.comm_mode == "smi"

    def channel_spec(self, **overrides):
        """The :class:`~repro.channels.ChannelSpec` this context's
        comm_mode denotes: model code opens channels on the TP communicator
        carrying the launch-selected transport backend (DESIGN.md §9)."""
        from ..channels import default_channel_spec

        assert self.model_comm is not None, (
            "channel_spec needs a model communicator (comm_mode != 'none')"
        )
        overrides.setdefault("transport", self.transport)
        return default_channel_spec(self.model_comm, None, **overrides)

    @property
    def tp(self) -> int:
        return self.model_comm.size if self.model_comm is not None else 1

    def rank(self):
        return self.model_comm.rank() if self.model_comm is not None else 0


def make_ctx(
    mesh=None,
    *,
    model_axis: str | None = "model",
    batch_axes: tuple[str, ...] = ("data",),
    comm_mode: str = "bulk",
    matmul_fn=None,
    opt_shared_gather: bool = False,
    opt_ring_attn: bool = False,
    plan=None,
) -> ParallelCtx:
    base_mode, transport = resolve_comm_mode(comm_mode)
    if mesh is None or model_axis is None:
        return ParallelCtx(comm_mode="none", transport=transport, mesh=mesh,
                           opt_shared_gather=opt_shared_gather,
                           opt_ring_attn=opt_ring_attn, plan=plan)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    comm = Communicator.create(
        model_axis, (sizes[model_axis],), name=f"tp_{model_axis}",
        transport=transport,
    )
    return ParallelCtx(
        model_axis=model_axis,
        batch_axes=tuple(a for a in batch_axes if a in sizes),
        model_comm=comm,
        comm_mode=base_mode,
        transport=transport,
        matmul_fn=matmul_fn,
        mesh=mesh,
        opt_shared_gather=opt_shared_gather,
        opt_ring_attn=opt_ring_attn,
        plan=plan,
    )


def _mm(ctx: ParallelCtx):
    return ctx.matmul_fn or (
        lambda a, b: jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)
    )


# ------------------------------------------------------------------ basics
#
# These wrappers are kept as the mesh-level vocabulary model code built on
# PRs 1-5 used; each now delegates to the channel-native layer in
# repro/parallel (fresh tagged transport per call, ledger-accounted).  New
# call sites should import repro.parallel directly and pick a layer tag.


def psum_model(x, ctx: ParallelCtx, *, tag: str = "tp.psum"):
    from ..parallel import psum_tagged

    return psum_tagged(x, ctx, tag)


def psum_max_model(x, ctx: ParallelCtx, *, tag: str = "tp.psum"):
    from ..parallel import pmax_tagged

    return pmax_tagged(x, ctx, tag)


def allreduce_model(x, ctx: ParallelCtx, *, tag: str = "tp.allreduce"):
    """Full all-reduce over the model axis (MoE combine, bulk decode)."""
    from ..parallel import all_reduce

    return all_reduce(x, ctx, tag=tag)


# ----------------------------------------------------- fused linear comms


def colparallel_matmul(x2d: jax.Array, w: jax.Array, ctx: ParallelCtx,
                       *, tag: str = "tp.col"):
    """y = AG_seq(x) @ w_colshard.  x2d: (t_local, K) sequence-sharded rows;
    w: (K, N_local).  Returns (t_local * tp, N_local): full rows, local cols."""
    from ..parallel import column_parallel_linear

    return column_parallel_linear(x2d, w, ctx, tag=tag)


def colparallel_matmul_gathered(x2d: jax.Array, w: jax.Array, ctx: ParallelCtx,
                                *, tag: str = "tp.col"):
    """Like colparallel_matmul but ALSO returns the gathered input (free on
    the smi ring — every shard transits each device; one lax.all_gather in
    bulk mode).  Enables the shared-gather block layout: later projections
    of the same input become ring-free local GEMMs."""
    from ..parallel import column_parallel_linear

    return column_parallel_linear(x2d, w, ctx, tag=tag, return_gathered=True)


def rowparallel_matmul(x2d: jax.Array, w: jax.Array, ctx: ParallelCtx,
                       *, tag: str = "tp.row"):
    """y = RS_seq(x @ w_rowshard).  x2d: (t_full, K_local) full rows, local
    contraction; w: (K_local, N).  Returns (t_full / tp, N): seq-sharded."""
    from ..parallel import row_parallel_linear

    return row_parallel_linear(x2d, w, ctx, tag=tag)


def allgather_seq(x, ctx: ParallelCtx, axis: int = 0, *,
                  tag: str = "tp.gather"):
    """Plain sequence all-gather (for non-GEMM consumers, e.g. conv)."""
    from ..parallel import gather_sequence

    return gather_sequence(x, ctx, axis, tag=tag)


def reduce_scatter_seq(x, ctx: ParallelCtx, axis: int = 0, *,
                       tag: str = "tp.scatter"):
    from ..parallel import reduce_scatter_sequence

    return reduce_scatter_sequence(x, ctx, axis, tag=tag)


def ring_attention(q, k, v, ctx: ParallelCtx, **kw):
    """Sequence-parallel attention (prefill hillclimb path)."""
    from ..parallel import ring_attention as _ring

    return _ring(q, k, v, ctx, **kw)


# ----------------------------------------------------------- grad sync (DP)


def _compressed_key(ctx: ParallelCtx) -> str:
    """Transport key for int8-compressed gradient rings: wrap the context's
    backend in the compressed-link transport (idempotent when the context
    already names a compressed backend)."""
    t = ctx.transport
    return t if t.partition(":")[0] == "compressed" else f"compressed:{t}"


def grad_sync(grads, ctx: ParallelCtx, *, compressed: bool = False,
              tag: str = "grad", transport=None):
    """Data-parallel gradient mean over the batch axes.

    smi mode: streamed ring all-reduce per tensor, each over a tagged
    ``"grad"`` channel so metrics/trace can attribute gradient traffic;
    ``compressed=True`` selects the int8 wire — the channel composes the
    compressed-link transport (blockwise scales + per-hop error feedback
    inside the reduce-scatter; end-to-end residual feedback stays with
    the optimizer's :class:`~repro.optim.grad.ErrorFeedback`).  Channels
    resolve fresh per tensor: error-feedback residuals must not bleed
    between tensors of one sync.  bulk mode: lax.psum.
    """
    if not ctx.batch_axes:
        return grads
    n = 1
    if ctx.mesh is not None:
        sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
        for a in ctx.batch_axes:
            n *= sizes[a]
    if ctx.is_smi:
        from ..parallel import grad_allreduce

        comm = _dp_comm(ctx)
        wire = "int8" if compressed else "raw"
        return jax.tree.map(
            lambda g: grad_allreduce(
                g, comm, tag=tag, wire=wire, transport=transport) / n,
            grads,
        )
    return jax.tree.map(lambda g: lax.pmean(g, ctx.batch_axes), grads)


# ------------------------------------------------------------------- FSDP


def fsdp_dim_for(shape, model_spec, dp: int, *, skip_dim0: bool = False):
    """Deterministic FSDP rule: first dim the model spec leaves unsharded
    whose size divides the DP degree.  Returns -1 for "store replicated"
    (None leaves would vanish from pytrees)."""
    dims = tuple(model_spec) + (None,) * (len(shape) - len(tuple(model_spec)))
    for i, (d, s) in enumerate(zip(dims, shape)):
        if skip_dim0 and i == 0:
            continue  # never shard a scan (layer-stack) dimension
        if d is None and s % dp == 0 and s >= dp and dp > 1:
            return i
    return -1


def build_fsdp_plan(param_shapes, param_specs, mesh, batch_axes):
    """Pytree of FSDP dims (int; -1 = replicated) mirroring the params.
    Leaves under a "periods" path are layer-stacked: their dim 0 is the scan
    dimension and is never sharded."""
    from jax.tree_util import tree_map_with_path

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = 1
    for a in batch_axes:
        dp *= sizes.get(a, 1)

    def one(path, sh, sp):
        stacked = any(getattr(k, "key", None) == "periods" for k in path)
        return fsdp_dim_for(sh.shape, sp, dp, skip_dim0=stacked)

    return tree_map_with_path(
        one, param_shapes, param_specs,
        is_leaf=lambda x: hasattr(x, "shape"),
    )


def fsdp_storage_specs(param_specs, fsdp_plan, batch_axes):
    """Storage layout: model spec + batch axes inserted at the FSDP dim."""
    from jax.sharding import PartitionSpec as P

    ax = tuple(batch_axes) if len(batch_axes) > 1 else batch_axes[0]

    def one(sp, dim):
        if dim < 0:
            return sp
        dims = list(tuple(sp)) + [None] * (dim + 1 - len(tuple(sp)))
        dims[dim] = ax
        return P(*dims)

    return jax.tree.map(
        one, param_specs, fsdp_plan, is_leaf=lambda x: isinstance(x, P)
    )


def fsdp_gather(params, fsdp_plan, ctx: ParallelCtx, *,
                tag: str = "fsdp.gather"):
    """All-gather FSDP-sharded leaves over the batch axes (inside shard_map).
    AD transposes this to the reduce-scatter gradient sync — ZeRO-3 dataflow
    for free.  smi mode streams each leaf's ring over a tagged channel."""
    if not ctx.batch_axes:
        return params
    comm = _dp_comm(ctx) if ctx.is_smi else None

    def one(p, dim):
        if dim < 0:
            return p
        if ctx.is_smi:
            from ..parallel import fsdp_allgather

            return fsdp_allgather(p, comm, dim, tag=tag)
        return lax.all_gather(p, ctx.batch_axes, axis=dim, tiled=True)

    return jax.tree.map(one, params, fsdp_plan)


def _dp_comm(ctx: ParallelCtx) -> Communicator:
    sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
    return Communicator.create(
        ctx.batch_axes, tuple(sizes[a] for a in ctx.batch_axes), name="dp",
        transport=ctx.transport,
    )


def grad_sync_fsdp(grads, fsdp_plan, ctx: ParallelCtx, *, compressed=False,
                   tag: str = "grad"):
    """DP gradient mean: FSDP leaves arrive already reduce-scattered (the
    gather transpose), so they only need /dp; replicated leaves ring over a
    tagged ``"grad"`` channel (int8 wire when ``compressed``)."""
    if not ctx.batch_axes:
        return grads
    sizes = dict(zip(ctx.mesh.axis_names, ctx.mesh.devices.shape))
    dp = 1
    for a in ctx.batch_axes:
        dp *= sizes[a]
    comm = _dp_comm(ctx) if ctx.is_smi else None
    wire = "int8" if compressed else "raw"

    def one(g, dim):
        if dim >= 0:
            return g / dp
        if ctx.is_smi:
            from ..parallel import grad_allreduce

            return grad_allreduce(g, comm, tag=tag, wire=wire) / dp
        return lax.pmean(g, ctx.batch_axes)

    return jax.tree.map(one, grads, fsdp_plan)
