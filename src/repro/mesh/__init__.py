from .api import ParallelCtx, make_ctx
from . import api

__all__ = ["ParallelCtx", "make_ctx", "api"]
