"""Model inputs per (arch, shape): real arrays (tests/examples) and
ShapeDtypeStruct stand-ins (dry-run; no allocation).

Modality frontends are STUBS per the assignment: the VLM's InternViT and
MusicGen's EnCodec are not modelled; ``input_specs`` hands the backbone the
precomputed patch/frame embeddings (vlm) or codebook token streams (audio).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig


def _tok_shape(cfg: ModelConfig, B: int, S: int):
    if cfg.n_codebooks > 1:
        return (B, S, cfg.n_codebooks)
    return (B, S)


def input_specs(cfg: ModelConfig, shape: ShapeConfig, *, batch_override=None):
    """ShapeDtypeStructs for every model input of this (arch, shape) cell."""
    B = batch_override or shape.global_batch
    S = shape.seq_len
    i32 = jnp.int32
    emb_dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if shape.kind == "train":
        spec = {
            "tokens": jax.ShapeDtypeStruct(_tok_shape(cfg, B, S), i32),
            "labels": jax.ShapeDtypeStruct(_tok_shape(cfg, B, S), i32),
        }
        if cfg.frontend == "vit_stub":
            spec["pixel_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_patches, cfg.d_model), emb_dt
            )
        return spec
    if shape.kind == "prefill":
        spec = {"tokens": jax.ShapeDtypeStruct(_tok_shape(cfg, B, S), i32)}
        if cfg.frontend == "vit_stub":
            spec["pixel_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.n_patches, cfg.d_model), emb_dt
            )
        return spec
    if shape.kind == "decode":
        tok = (B, cfg.n_codebooks) if cfg.n_codebooks > 1 else (B,)
        return {
            "token": jax.ShapeDtypeStruct(tok, i32),
            "pos": jax.ShapeDtypeStruct((), i32),
        }
    raise ValueError(shape.kind)


def make_inputs(cfg: ModelConfig, shape: ShapeConfig, seed=0, *, batch_override=None):
    """Concrete deterministic arrays matching input_specs."""
    rng = np.random.RandomState(seed)
    out = {}
    for k, sd in input_specs(cfg, shape, batch_override=batch_override).items():
        if sd.dtype == jnp.int32:
            if k == "pos":
                out[k] = jnp.asarray(shape.seq_len // 2, jnp.int32)
            else:
                out[k] = jnp.asarray(
                    rng.randint(0, cfg.vocab_size, sd.shape), jnp.int32
                )
        else:
            out[k] = jnp.asarray(rng.randn(*sd.shape) * 0.02, sd.dtype)
    if "labels" in out and cfg.frontend == "vit_stub":
        # patch positions carry no LM loss
        lab = np.array(out["labels"], copy=True)
        lab[:, : cfg.n_patches] = -100
        out["labels"] = jnp.asarray(lab)
    return out
