"""Deterministic synthetic token pipeline with host-side prefetch.

Scaled-down but honest data path: documents are generated from a seeded
Markov-ish process (so loss curves are reproducible and non-trivial), packed
into fixed-length sequences with next-token labels, sharded per data rank,
and prefetched on a background thread so step N+1's batch is ready while
step N computes — the host-side mirror of the paper's overlap philosophy.
"""

from __future__ import annotations

import queue
import threading

import numpy as np


class SyntheticTokenPipeline:
    def __init__(
        self,
        vocab_size: int,
        seq_len: int,
        batch: int,
        *,
        seed: int = 0,
        n_codebooks: int = 1,
        prefetch: int = 2,
    ):
        self.vocab = vocab_size
        self.seq = seq_len
        self.batch = batch
        self.n_codebooks = n_codebooks
        self.seed = seed
        self._step = 0
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    def _gen(self, step: int):
        rng = np.random.RandomState(self.seed * 1_000_003 + step)
        shape = (self.batch, self.seq + 1)
        if self.n_codebooks > 1:
            shape = shape + (self.n_codebooks,)
        # order-1 structure: next token correlated with current
        base = rng.randint(0, self.vocab, shape)
        drift = rng.randint(0, 17, shape)
        toks = (base + np.cumsum(drift, axis=1)) % self.vocab
        tokens = toks[:, :-1].astype(np.int32)
        labels = toks[:, 1:].astype(np.int32)
        return {"tokens": tokens, "labels": labels}

    def _producer(self):
        step = 0
        while not self._stop.is_set():
            batch = self._gen(step)
            while not self._stop.is_set():
                try:
                    self._q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def next(self):
        self._step += 1
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)
