from .inputs import make_inputs, input_specs
from .pipeline import SyntheticTokenPipeline

__all__ = ["make_inputs", "input_specs", "SyntheticTokenPipeline"]
