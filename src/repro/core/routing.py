"""Deadlock-free static routing (paper §4.3).

The paper computes routes offline with a deadlock-free scheme (citing Domke
et al.) and uploads routing tables to each rank at runtime, *without
rebuilding the bitstream*.  We reproduce the split exactly:

* :func:`compute_route_table` — the "route generator".  Dimension-order
  routing (DOR) on tori (provably deadlock-free on a fixed-direction link
  schedule), breadth-first shortest paths with deterministic tie-breaking on
  arbitrary graphs.
* :class:`RouteTable` — ``next_hop[src, dst]`` and ``out_port[src, dst]``
  numpy tables.  The *static* streaming engine consumes them at trace time
  (fast path); the *dynamic* packet router (``core/router.py``) consumes them
  as runtime device arrays — the compiled executable is the "bitstream" and
  these tables are what gets re-uploaded when the topology changes.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .topology import Topology


def bfs_dists(topo: Topology, src: int) -> np.ndarray:
    dist = np.full(topo.n_ranks, -1, dtype=np.int32)
    dist[src] = 0
    frontier = [src]
    while frontier:
        nxt = []
        for r in frontier:
            for n in topo.links[r]:
                if dist[n] < 0:
                    dist[n] = dist[r] + 1
                    nxt.append(n)
        frontier = nxt
    return dist


def _dor_next_hop(topo: Topology, src: int, dst: int) -> int:
    """Dimension-order next hop on a torus: correct dimension 0 first, then 1,
    ..., choosing the shorter wrap direction (ties go +)."""
    dims = topo.dims
    assert dims is not None
    strides = []
    s = 1
    for d in reversed(dims):
        strides.append(s)
        s *= d
    strides = list(reversed(strides))
    cs = [(src // strides[i]) % dims[i] for i in range(len(dims))]
    cd = [(dst // strides[i]) % dims[i] for i in range(len(dims))]
    for i in range(len(dims)):
        if cs[i] == cd[i]:
            continue
        d = dims[i]
        fwd = (cd[i] - cs[i]) % d
        bwd = (cs[i] - cd[i]) % d
        step = +1 if fwd <= bwd else -1
        cc = list(cs)
        cc[i] = (cs[i] + step) % d
        return sum(cc[j] * strides[j] for j in range(len(dims)))
    return dst


@dataclass(frozen=True)
class RouteTable:
    """Static routing tables for one topology.

    next_hop[s, d] = neighbour of s on the route to d (s itself when s == d).
    out_port[s, d] = index of that neighbour in topo.links[s] (-1 when s == d).
    """

    topo: Topology
    next_hop: np.ndarray
    out_port: np.ndarray

    def path(self, src: int, dst: int) -> list[int]:
        """Full route src -> dst as a rank list (inclusive)."""
        p = [src]
        guard = 0
        while p[-1] != dst:
            p.append(int(self.next_hop[p[-1], dst]))
            guard += 1
            assert guard <= self.topo.n_ranks, f"routing loop {src}->{dst}"
        return p

    def n_hops(self, src: int, dst: int) -> int:
        return len(self.path(src, dst)) - 1


def compute_route_table(topo: Topology, scheme: str = "auto") -> RouteTable:
    """The paper's "route generator": topology in, per-rank tables out."""
    n = topo.n_ranks
    next_hop = np.zeros((n, n), dtype=np.int32)
    if scheme == "auto":
        scheme = "dor" if topo.dims is not None else "bfs"

    if scheme == "dor":
        assert topo.dims is not None, "DOR needs torus coordinates"
        for s in range(n):
            for d in range(n):
                next_hop[s, d] = s if s == d else _dor_next_hop(topo, s, d)
    elif scheme == "bfs":
        # Shortest paths; tie-break by smallest-index predecessor so tables
        # are deterministic (the paper requires static, reproducible routes).
        for d in range(n):
            dist = bfs_dists(topo, d)
            assert (dist >= 0).all(), f"topology {topo.name} is disconnected"
            for s in range(n):
                if s == d:
                    next_hop[s, d] = s
                    continue
                best = min(
                    (x for x in topo.links[s] if dist[x] == dist[s] - 1),
                )
                next_hop[s, d] = best
    else:
        raise ValueError(f"unknown routing scheme {scheme!r}")

    out_port = np.full((n, n), -1, dtype=np.int32)
    for s in range(n):
        for d in range(n):
            if s != d:
                out_port[s, d] = topo.port_of(s, int(next_hop[s, d]))
    return RouteTable(topo, next_hop, out_port)


def channel_dependency_acyclic(rt: RouteTable) -> bool:
    """Deadlock-freedom check: build the channel-dependency graph (CDG) over
    directed links induced by all (src, dst) routes and test acyclicity.
    Dally & Seitz: wormhole/credit routing is deadlock-free iff the CDG is
    acyclic.  Used by property tests on DOR tables."""
    edges: set[tuple[tuple[int, int], tuple[int, int]]] = set()
    n = rt.topo.n_ranks
    for s in range(n):
        for d in range(n):
            if s == d:
                continue
            p = rt.path(s, d)
            chans = list(zip(p[:-1], p[1:]))
            for a, b in zip(chans[:-1], chans[1:]):
                edges.add((a, b))
    # Kahn toposort over channel nodes.
    nodes = {c for e in edges for c in e}
    indeg = {c: 0 for c in nodes}
    for _, b in edges:
        indeg[b] += 1
    from collections import deque

    q = deque([c for c in nodes if indeg[c] == 0])
    seen = 0
    adj: dict[tuple[int, int], list[tuple[int, int]]] = {c: [] for c in nodes}
    for a, b in edges:
        adj[a].append(b)
    while q:
        c = q.popleft()
        seen += 1
        for b in adj[c]:
            indeg[b] -= 1
            if indeg[b] == 0:
                q.append(b)
    return seen == len(nodes)


def physical_link_map(dims: tuple[int, ...]) -> dict[tuple[int, int], int]:
    """Map each directed torus edge to its physical link id.

    Link ids: 2*i   = +1 step in dim i,
              2*i+1 = -1 step in dim i.
    This is the TPU analogue of the paper's fixed QSFP wiring: the dynamic
    router executes one ppermute per link id per step, and the runtime routing
    table selects which packets ride which link.
    """
    topo = Topology.torus(dims)
    strides = []
    s = 1
    for d in reversed(dims):
        strides.append(s)
        s *= d
    strides = list(reversed(strides))
    out: dict[tuple[int, int], int] = {}
    n = topo.n_ranks
    for r in range(n):
        c = [(r // strides[i]) % dims[i] for i in range(len(dims))]
        for i, d in enumerate(dims):
            if d == 1:
                continue
            for sidx, step in ((0, +1), (1, -1)):
                cc = list(c)
                cc[i] = (cc[i] + step) % d
                nb = sum(cc[j] * strides[j] for j in range(len(dims)))
                if nb != r:
                    out[(r, nb)] = 2 * i + sidx
    return out
