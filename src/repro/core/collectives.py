"""Streamed collectives (paper §3.2, §4.4) as static ppermute schedules.

The reference implementation in the paper uses a *linear* scheme with
per-rank rendezvous (root coordinates who streams when) and credit-based flow
control for Reduce; tree-based collectives are explicitly left as future
work.  Here:

* the paper-faithful *linear/ring pipelined* schedules are implemented for
  Bcast / Scatter / Gather / Reduce (chunks flow hop-by-hop through the ring,
  every rank taps/accumulates the passing stream — communication fully
  overlapped with the pipeline, zero bulk buffering beyond one chunk),
* bandwidth-optimal ring AllGather / ReduceScatter / AllReduce / AllToAll are
  provided for the compute layers (TP/DP/EP),
* **beyond-paper**: binomial-tree Bcast/Reduce (the paper's future work) and
  bidirectional rings (halved step count), plus int8-compressed rings for
  gradient sync.

All functions run inside ``jax.shard_map`` over the communicator's axes.
Chunk counts, like the paper's buffer sizes, are optimisation parameters
that never affect correctness.

Every function takes a ``transport=`` keyword (a key into
:mod:`repro.transport` or a Transport instance; default: the
communicator's ``transport`` field).  The schedule — who sends what when —
is backend-independent, so the same call produces bit-identical results
over the static ppermute path, the dynamic packet router, and the fused
Pallas path.
"""

from __future__ import annotations

import warnings
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from .comm import Communicator
from .streaming import _mask_sel, _pvary


def _resolve(transport, comm: Communicator):
    from ..transport.registry import resolve_transport

    return resolve_transport(transport, comm)


def _codec_shim(t, quantize, dequantize):
    """Deprecated-kwargs migration: wrap the resolved transport in a
    :class:`~repro.transport.compressed.CompressedTransport` carrying the
    caller's codec, so the legacy ``quantize=``/``dequantize=`` path runs
    the same error-feedback wire as ``transport="compressed"``."""
    warnings.warn(
        "quantize=/dequantize= kwargs are deprecated; pass "
        "transport='compressed' (or 'compressed:<inner>') instead — the "
        "compressed transport carries blockwise int8 scales, per-hop error "
        "feedback and byte-accurate wire stats (DESIGN.md §7)",
        DeprecationWarning,
        stacklevel=3,
    )
    from ..transport.compressed import CompressedTransport

    return CompressedTransport(inner=t, codec=(quantize, dequantize))


def _is_lossy(t) -> bool:
    return bool(getattr(t, "lossy_wire", False))


def _shift(x, comm: Communicator, step: int = 1, transport=None):
    return _resolve(transport, comm).shift(x, comm, step)


def _schedule_loop(tp, steps: int, body, carry):
    """Run a static schedule loop: rolled (fori_loop) on trace-time
    backends, unrolled when the backend threads runtime counters through
    ``stats`` (a traced value may not escape a fori_loop body).

    Rolled tracing executes ``body`` once, so the backend's trace-time
    step/byte counters would record a single iteration; the per-iteration
    delta is scaled to the full step count afterwards.  That scaling is
    exact only because every schedule here moves the *same wire bytes each
    step*: the chain pipelines carry one fixed-size chunk per tick
    (``csz`` never varies with ``t``), and wire formats (the compressed
    backend's int8 payload + sidecar) are a pure function of that chunk
    shape.  A future schedule with per-step-varying payloads must not use
    the rolled path — unroll it (or account explicitly, as
    ``static.p2p`` does).  ``tests/test_compressed.py`` asserts rolled ==
    unrolled stats for the chunked chain on both raw and compressed wires.
    """
    if getattr(tp, "runtime_stats", False):
        for t in range(steps):
            carry = body(jnp.asarray(t, jnp.int32), carry)
        return carry
    steps0, bytes0 = tp.stats.steps, tp.stats.bytes_moved
    tags0 = {k: dict(v) for k, v in tp.stats.by_tag.items()}
    carry = lax.fori_loop(0, steps, body, carry)
    tp.stats.steps = steps0 + (tp.stats.steps - steps0) * steps
    tp.stats.bytes_moved = bytes0 + (tp.stats.bytes_moved - bytes0) * steps
    for k, e in tp.stats.by_tag.items():
        p = tags0.get(k, {"steps": 0, "bytes": 0})
        e["steps"] = p["steps"] + (e["steps"] - p["steps"]) * steps
        e["bytes"] = p["bytes"] + (e["bytes"] - p["bytes"]) * steps
    return carry


def _line_perms(comm: Communicator, root: int):
    """Up/down chain permutations for bus (no-wrap) topologies."""
    P = comm.size
    up = [(i, i + 1) for i in range(root, P - 1)]
    down = [(i, i - 1) for i in range(1, root + 1)]
    return up, down


# ---------------------------------------------------------------------------
# Ring AllGather / ReduceScatter / AllReduce / AllToAll (compute-layer cores)
# ---------------------------------------------------------------------------


def stream_allgather(
    x: jax.Array,
    comm: Communicator,
    *,
    on_chunk: Callable | None = None,
    bidir: bool = False,
    transport=None,
):
    """Ring all-gather of the local shard ``x`` -> (P*m, ...).

    ``on_chunk(block, slot)`` fires the moment each remote shard arrives —
    the SMI Pop-inside-the-pipeline pattern; the overlap engine passes the
    per-chunk GEMM here.  ``bidir`` streams both ring directions
    (beyond-paper; ~halves the number of steps for even P).
    """
    P = comm.size
    r = comm.rank()
    t = _resolve(transport, comm)
    out = jnp.zeros((P,) + x.shape, x.dtype)
    out = jax.lax.dynamic_update_index_in_dim(out, x, r, 0)
    if on_chunk is not None:
        on_chunk(x, r)
    if P == 1:
        return out.reshape((P * x.shape[0],) + x.shape[1:])

    if not bidir:
        buf = x
        for s in range(1, P):
            buf = t.shift(buf, comm, +1)  # buf now originated at rank r - s
            slot = (r - s) % P
            out = jax.lax.dynamic_update_index_in_dim(out, buf, slot, 0)
            if on_chunk is not None:
                on_chunk(buf, slot)
    else:
        up = x
        down = x
        n_up = (P - 1 + 1) // 2  # ceil((P-1)/2)
        n_down = (P - 1) // 2
        for s in range(1, n_up + 1):
            up = t.shift(up, comm, +1)
            slot = (r - s) % P
            out = jax.lax.dynamic_update_index_in_dim(out, up, slot, 0)
            if on_chunk is not None:
                on_chunk(up, slot)
            if s <= n_down:
                down = t.shift(down, comm, -1)
                slot2 = (r + s) % P
                out = jax.lax.dynamic_update_index_in_dim(out, down, slot2, 0)
                if on_chunk is not None:
                    on_chunk(down, slot2)
    return out.reshape((P * x.shape[0],) + x.shape[1:])


def stream_reduce_scatter(
    x: jax.Array | None,
    comm: Communicator,
    *,
    compute_chunk: Callable | None = None,
    block_shape=None,
    dtype=None,
    quantize: Callable | None = None,
    dequantize: Callable | None = None,
    transport=None,
):
    """Ring reduce-scatter.  ``x``: (P*m, ...) local partials -> (m, ...)
    fully-reduced block ``r``.

    ``compute_chunk(blk_idx)`` produces partial block ``blk_idx``
    *just-in-time*, one ring step before it is needed — this is the streamed
    matmul+reduce-scatter fusion (communication during computation, the
    paper's core idea applied to a collective).

    Wire compression is a transport concern: pass
    ``transport="compressed"`` (or a :class:`~repro.transport.compressed.
    CompressedTransport` instance).  A lossy wire switches the schedule to
    the *once-quantised contribution* form (DESIGN.md §7): round ``s``
    quantises each rank's contribution for block ``(r+s) % P`` exactly
    once — with the transport's error-feedback residual — and ships it
    straight to its home rank with a distance-``s`` ring permute; partial
    sums accumulate in f32 and never re-round, so quantisation error is
    bounded independent of P (the old quantize-the-accumulator branch
    compounded error once per hop).  The legacy ``quantize``/
    ``dequantize`` kwargs are deprecated shims that wrap the resolved
    transport in exactly that backend.

    The uncompressed inner step is the transport's ``shift_accumulate``
    hot path (Pallas-fused on the ``fused`` backend).
    """
    P = comm.size
    r = comm.rank()
    t = _resolve(transport, comm)
    if quantize is not None:
        t = _codec_shim(t, quantize, dequantize)
    if compute_chunk is None:
        m = x.shape[0] // P
        xb = x.reshape((P, m) + x.shape[1:])

        def compute_chunk(i):
            return jax.lax.dynamic_index_in_dim(xb, i, 0, keepdims=False)

    if _is_lossy(t):
        own = compute_chunk(r)
        if P == 1:
            return own
        acc = own.astype(jnp.float32)
        for s in range(1, P):
            # contribution for block (r+s)%P, arriving at its home rank
            acc = acc + t.send_contribution(
                compute_chunk((r + s) % P), comm, +s
            )
        return acc.astype(own.dtype)

    acc = compute_chunk((r - 1) % P)
    if P == 1:
        return acc
    for s in range(1, P):
        blk = (r - s - 1) % P
        acc = t.shift_accumulate(acc, compute_chunk(blk), comm, +1)
    return acc


def _stream_allreduce_impl(
    x: jax.Array,
    comm: Communicator,
    *,
    quantize=None,
    dequantize=None,
    bidir: bool = False,
    transport=None,
):
    """Ring all-reduce (RS + AG) of an arbitrary-shaped array.

    A lossy wire (``transport="compressed"`` or the deprecated
    ``quantize=`` kwargs) requires a floating dtype: the quantized path
    produces approximate floats, and the trailing restore-cast to the
    input dtype would silently truncate integer payloads (the old code
    did exactly that).
    """
    P = comm.size
    if P == 1:
        return x
    shape, dtype = x.shape, x.dtype
    t = _resolve(transport, comm)
    rs_t = t if quantize is None else _codec_shim(t, quantize, dequantize)
    if (_is_lossy(rs_t)) and not jnp.issubdtype(dtype, jnp.floating):
        raise TypeError(
            f"compressed/quantized all-reduce of {dtype} payload: the lossy "
            "wire yields approximate floats and casting back would silently "
            "corrupt integer data; use a raw transport for integer reduces"
        )
    flat = x.reshape(-1)
    orig = flat.shape[0]
    pad = (-orig) % P
    if pad:
        flat = jnp.pad(flat, (0, pad))
    # legacy shim semantics: compress the reduce-scatter wire only (the
    # allgather phase ran raw before); transport="compressed" proper
    # compresses both phases
    red = stream_reduce_scatter(flat, comm, transport=rs_t)
    full = stream_allgather(red, comm, bidir=bidir, transport=t)
    if pad:
        full = full[:orig]
    return full.reshape(shape).astype(dtype)


def stream_alltoall(x: jax.Array, comm: Communicator, *, transport=None):
    """All-to-all: ``x``(P, m, ...) block d goes to rank d; returns (P, m, ...)
    where slot s holds the block sent by rank s.  P-1 direct permutes (each
    lowered by XLA to its own route on the physical torus)."""
    P = comm.size
    r = comm.rank()
    t = _resolve(transport, comm)
    out = jnp.zeros_like(x)
    own = jax.lax.dynamic_index_in_dim(x, r, 0, keepdims=False)
    out = jax.lax.dynamic_update_index_in_dim(out, own, r, 0)
    for s in range(1, P):
        # Send the block destined to rank (r+s); it arrives from rank (r-s).
        blk = jax.lax.dynamic_index_in_dim(x, (r + s) % P, 0, keepdims=False)
        got = t.shift(blk, comm, +s)
        out = jax.lax.dynamic_update_index_in_dim(out, got, (r - s) % P, 0)
    return out


# ---------------------------------------------------------------------------
# Rooted streaming collectives (paper-faithful linear pipelined schemes)
# ---------------------------------------------------------------------------


def _stream_bcast_impl(
    x: jax.Array,
    comm: Communicator,
    *,
    root: int = 0,
    n_chunks: int = 1,
    transport=None,
):
    """Pipelined chain broadcast (paper §4.4 linear scheme).

    Chunks leave the root every step and ripple through the chain; every rank
    taps the passing stream.  Steps = n_chunks + P - 2: for large messages the
    cost approaches one link-bandwidth pass independent of topology diameter —
    the paper's Fig. 10 behaviour.
    """
    P = comm.size
    if P == 1:
        return x
    S = x.shape[0]
    assert S % n_chunks == 0
    csz = S // n_chunks
    r = comm.rank()
    tp = _resolve(transport, comm)
    is_line = comm.topology.dims is None  # bus et al: chain both directions

    if is_line:
        up_pairs, down_pairs = _line_perms(comm, root)
        dist = jnp.abs(r - root)
    else:
        up_pairs, down_pairs = comm.ring_perm(+1), None
        dist = (r - root) % P

    def body(t, carry):
        out, pipe_u, pipe_d = carry
        idx = jnp.minimum(t, n_chunks - 1) * csz
        inj = lax.dynamic_slice_in_dim(x, idx, csz, axis=0)
        at_root_live = jnp.logical_and(r == root, t < n_chunks)
        pipe_u = _mask_sel(at_root_live, inj, pipe_u)
        pipe_u = tp.permute(pipe_u, comm, up_pairs)
        if down_pairs is not None:
            pipe_d = _mask_sel(at_root_live, inj, pipe_d)
            pipe_d = tp.permute(pipe_d, comm, down_pairs)
            arriving = jnp.where(r > root, pipe_u, pipe_d)
        else:
            arriving = pipe_u
        c = t - dist + 1
        ok = jnp.logical_and(jnp.logical_and(c >= 0, c < n_chunks), dist > 0)
        upd = lax.dynamic_update_slice_in_dim(out, arriving, jnp.maximum(c, 0) * csz, axis=0)
        out = _mask_sel(ok, upd, out)
        return out, pipe_u, pipe_d

    out0 = _pvary(jnp.zeros_like(x), comm)
    pipe0 = _pvary(jnp.zeros((csz,) + x.shape[1:], x.dtype), comm)
    steps = n_chunks + P - 2
    out, _, _ = _schedule_loop(tp, steps, body, (out0, pipe0, pipe0))
    return _mask_sel(r == root, x, out)


def _stream_reduce_impl(
    x: jax.Array,
    comm: Communicator,
    *,
    root: int = 0,
    n_chunks: int = 1,
    op=jnp.add,
    transport=None,
):
    """Pipelined chain reduction to ``root`` (credit/tile-based, paper §4.4).

    Tiles stream down the chain toward the root, each rank folding in its
    local contribution as the tile passes — the number of in-flight tiles is
    the paper's credit count C.  Steps = n_chunks + P - 1.
    """
    P = comm.size
    if P == 1:
        return x
    S = x.shape[0]
    assert S % n_chunks == 0
    csz = S // n_chunks
    r = comm.rank()
    tp = _resolve(transport, comm)
    dist = (r - root) % P  # ring distance (chain order: farthest = P-1)
    down_pairs = comm.ring_perm(-1)

    def chunk_at(c):
        return lax.dynamic_slice_in_dim(x, jnp.maximum(c, 0) * csz, csz, axis=0)

    def body(t, carry):
        out, pipe = carry
        # Farthest rank injects chunk t.
        inj_ok = jnp.logical_and(dist == P - 1, t < n_chunks)
        pipe = _mask_sel(inj_ok, chunk_at(jnp.minimum(t, n_chunks - 1)), pipe)
        pipe = tp.permute(pipe, comm, down_pairs)
        # After the shift at step t, rank at ring-distance d holds chunk
        # c = t - (P - 2 - d): injected at step c, it has moved t - c + 1 hops.
        c = t - (P - 2 - dist)
        live = jnp.logical_and(c >= 0, c < n_chunks)
        add_ok = jnp.logical_and(live, dist < P - 1)
        # Plain-add folds route through the transport's accumulate hook so
        # the fused backend runs them on its tiled Pallas datapath; the
        # mask stays outside the hook (a masked lane must keep `pipe`
        # bit-exactly, not `pipe + 0`).
        folded = tp.accumulate(pipe, chunk_at(c)) if op is jnp.add \
            else op(pipe, chunk_at(c))
        pipe = _mask_sel(add_ok, folded, pipe)
        # Root delivers.
        store = jnp.logical_and(r == root, live)
        upd = lax.dynamic_update_slice_in_dim(out, pipe, jnp.maximum(c, 0) * csz, axis=0)
        out = _mask_sel(store, upd, out)
        return out, pipe

    out0 = _pvary(jnp.zeros_like(x), comm)
    pipe0 = _pvary(jnp.zeros((csz,) + x.shape[1:], x.dtype), comm)
    out, _ = _schedule_loop(tp, n_chunks + P - 2, body, (out0, pipe0))
    return _mask_sel(r == root, out, jnp.zeros_like(x))


def _stream_gather_impl(x: jax.Array, comm: Communicator, *, root: int = 0, transport=None):
    """Convoy gather: every shard shifts one hop toward the root per step;
    the root receives nearest-first, one shard per step (root-link bandwidth
    optimal, the paper's sequentially-coordinated Gather)."""
    P = comm.size
    r = comm.rank()
    tp = _resolve(transport, comm)
    out = jnp.zeros((P,) + x.shape, x.dtype)
    out = jax.lax.dynamic_update_index_in_dim(out, x, r, 0)
    if P == 1:
        return out.reshape((P * x.shape[0],) + x.shape[1:])
    pipe = x
    for t in range(P - 1):
        pipe = tp.shift(pipe, comm, -1)  # toward root (ring -1 = decreasing dist)
        src = (r + t + 1) % P
        upd = jax.lax.dynamic_update_index_in_dim(out, pipe, src, 0)
        out = _mask_sel(r == root, upd, out)
    out = _mask_sel(r == root, out, jnp.zeros_like(out))
    return out.reshape((P * x.shape[0],) + x.shape[1:])


def _stream_scatter_impl(x: jax.Array, comm: Communicator, *, root: int = 0, transport=None):
    """Convoy scatter: the root injects blocks farthest-first; after P-1
    shifts every rank's pipe register holds its own block."""
    P = comm.size
    r = comm.rank()
    tp = _resolve(transport, comm)
    m = x.shape[0] // P
    xb = x.reshape((P, m) + x.shape[1:])
    if P == 1:
        return xb[0]
    pipe = jnp.zeros((m,) + x.shape[1:], x.dtype)
    for t in range(P - 1):
        d = P - 1 - t  # inject block for ring-distance d
        blk = jax.lax.dynamic_index_in_dim(xb, (root + d) % P, 0, keepdims=False)
        pipe = _mask_sel(r == root, blk, pipe)
        pipe = tp.shift(pipe, comm, +1)
    own = jax.lax.dynamic_index_in_dim(xb, r, 0, keepdims=False)
    return _mask_sel(r == root, own, pipe)


# ---------------------------------------------------------------------------
# Public streamed collectives: thin shims over transient channels
#
# The channel API (repro/channels) is the primary surface: each stream_*
# entry point opens a transient anonymous-port collective channel carrying
# the call's config and streams the whole message through it — the channel's
# transfer() lowers back onto the _stream_*_impl schedule above, so results
# and stats are bit-identical to the pre-channel code on every backend.
#
# DEPRECATED since PR 8: model/optimizer code routes through the tagged
# layer API in repro/parallel (which drives the same _stream_*_impl
# schedules through per-layer ChannelSpecs).  PR 9 retired the last
# in-repo call sites (scripts/check_no_stream_shims.py keeps it that way
# under src/); the shims survive only for external callers, the
# shim-equivalence test and the deprecation-warning sweep, and will be
# removed in a future PR.
# ---------------------------------------------------------------------------


def _deprecated_shim(name: str, alt: str):
    warnings.warn(
        f"{name} is a deprecated transient-channel shim: untagged, untuned "
        f"comm invisible to the per-tag step accounting.  PR 9 retired the "
        f"last in-repo call sites; this wrapper is slated for removal.  Use "
        f"{alt} (see repro/parallel, DESIGN.md §12), or open a tagged "
        "channel via repro.channels.",
        DeprecationWarning,
        stacklevel=3,
    )


def stream_bcast(
    x: jax.Array,
    comm: Communicator,
    *,
    root: int = 0,
    n_chunks: int = 1,
    transport=None,
):
    """Pipelined chain broadcast (paper §4.4 linear scheme); see
    :func:`_stream_bcast_impl` for the schedule.  Thin shim: opens a
    transient broadcast channel (``repro.channels.open_bcast_channel``)
    and transfers through it."""
    _deprecated_shim("stream_bcast", "a tagged bcast channel")
    from ..channels import open_bcast_channel

    return open_bcast_channel(
        comm, root=root, port=None, transport=transport, n_chunks=n_chunks
    ).transfer(x)


def stream_reduce(
    x: jax.Array,
    comm: Communicator,
    *,
    root: int = 0,
    n_chunks: int = 1,
    op=jnp.add,
    transport=None,
):
    """Pipelined chain reduction to ``root`` (paper §4.4); see
    :func:`_stream_reduce_impl` for the schedule.  Thin shim over a
    transient reduce channel."""
    _deprecated_shim("stream_reduce", "a tagged reduce channel")
    from ..channels import open_reduce_channel

    return open_reduce_channel(
        comm, root=root, port=None, op=op, transport=transport,
        n_chunks=n_chunks,
    ).transfer(x)


def stream_gather(x: jax.Array, comm: Communicator, *, root: int = 0,
                  transport=None):
    """Convoy gather (root-link bandwidth optimal); see
    :func:`_stream_gather_impl`.  Thin shim over a transient gather
    channel."""
    _deprecated_shim("stream_gather", "repro.parallel.gather_sequence")
    from ..channels import open_gather_channel

    return open_gather_channel(
        comm, root=root, port=None, transport=transport
    ).transfer(x)


def stream_scatter(x: jax.Array, comm: Communicator, *, root: int = 0,
                   transport=None):
    """Convoy scatter (root injects farthest-first); see
    :func:`_stream_scatter_impl`.  Thin shim over a transient scatter
    channel."""
    _deprecated_shim("stream_scatter", "repro.parallel.reduce_scatter_sequence")
    from ..channels import open_scatter_channel

    return open_scatter_channel(
        comm, root=root, port=None, transport=transport
    ).transfer(x)


def stream_allreduce(
    x: jax.Array,
    comm: Communicator,
    *,
    quantize=None,
    dequantize=None,
    bidir: bool = False,
    transport=None,
):
    """Ring all-reduce (RS + AG); see :func:`_stream_allreduce_impl` for
    the schedule and the lossy-wire rules.  Thin shim over a transient
    all-reduce channel; the deprecated ``quantize=``/``dequantize=``
    kwargs forward to the schedule's codec shim unchanged."""
    _deprecated_shim(
        "stream_allreduce",
        "repro.parallel.all_reduce / repro.parallel.grad_allreduce",
    )
    from ..channels import open_allreduce_channel

    return open_allreduce_channel(
        comm, port=None, transport=transport
    ).transfer(x, quantize=quantize, dequantize=dequantize, bidir=bidir)


# ---------------------------------------------------------------------------
# Beyond-paper: binomial trees (the paper's explicit future work)
# ---------------------------------------------------------------------------


def _tree_rounds(P: int):
    k = 0
    while (1 << k) < P:
        yield 1 << k
        k += 1


def tree_bcast(x: jax.Array, comm: Communicator, *, root: int = 0, transport=None):
    """Binomial-tree broadcast: O(log P) rounds of whole-message sends.
    Better than the chain for small messages / large P (latency-bound)."""
    P = comm.size
    r = comm.rank()
    tp = _resolve(transport, comm)
    rel = (r - root) % P
    have = (rel == 0)
    buf = _mask_sel(r == root, x, jnp.zeros_like(x))
    for h in _tree_rounds(P):
        pairs = [
            ((root + i) % P, (root + i + h) % P) for i in range(h) if i + h < P
        ]
        moved = tp.permute(buf, comm, pairs)
        recv = jnp.logical_and(rel >= h, rel < 2 * h)
        buf = _mask_sel(recv, moved, buf)
        have = jnp.logical_or(have, recv)
    return buf


def tree_reduce(
    x: jax.Array, comm: Communicator, *, root: int = 0, op=jnp.add, transport=None
):
    """Binomial-tree reduction to root: O(log P) rounds."""
    P = comm.size
    r = comm.rank()
    tp = _resolve(transport, comm)
    rel = (r - root) % P
    buf = x
    rounds = list(_tree_rounds(P))
    for h in reversed(rounds):
        pairs = [
            ((root + i + h) % P, (root + i) % P) for i in range(h) if i + h < P
        ]
        moved = tp.permute(buf, comm, pairs)
        recv = rel < h
        # ranks in [h, 2h) sent; ranks in [0, h) fold the arrival in.
        sent_exists = jnp.logical_and(recv, rel + h < P)
        folded = tp.accumulate(buf, moved) if op is jnp.add \
            else op(buf, moved)
        buf = _mask_sel(sent_exists, folded, buf)
    return _mask_sel(r == root, buf, jnp.zeros_like(buf))


# ---------------------------------------------------------------------------
# Autotuned dispatchers (netsim tuning table -> schedule selection)
# ---------------------------------------------------------------------------


def _resolve_plan(plan, op: str, comm: Communicator, x):
    """Turn a plan argument into a concrete netsim Plan.

    ``"auto"`` consults the communicator's cached tuning table for the
    message's byte size; ``None`` is the static default; a
    :class:`repro.netsim.tune.Plan` passes through.  A tuned ``int8``-wire
    plan only applies to floating payloads — integer data must move
    exactly, so it silently falls back to the same plan on the raw wire
    (the tuner's wire choice is a cost hint, never a correctness gate)."""
    import dataclasses

    from ..netsim.tune import DEFAULT_PLAN, Plan

    if plan is None:
        return DEFAULT_PLAN
    if isinstance(plan, Plan):
        p = plan
    else:
        assert plan == "auto", \
            f"plan must be 'auto', None or a Plan; got {plan!r}"
        p = comm.plan(op, int(x.size) * x.dtype.itemsize)
    if p.wire != "raw" and not jnp.issubdtype(x.dtype, jnp.floating):
        p = dataclasses.replace(p, wire="raw")
    return p


def bcast(x: jax.Array, comm: Communicator, *, root: int = 0,
          plan="auto", transport=None):
    """Autotuned broadcast: the netsim tuning table picks the schedule
    (pipelined chain / binomial tree / staged), the chunk count, the
    transport backend and the wire format (a bandwidth-bound plan may
    select a compressed link — results then match within the codec error
    bound) for this topology and message size.  ``transport`` overrides
    the tuned backend; ``plan=None`` forces the static default."""
    p = _resolve_plan(plan, "bcast", comm, x)
    tp = transport if transport is not None else p.transport_key
    if p.algo == "tree":
        return tree_bcast(x, comm, root=root, transport=tp)
    if p.algo == "staged":
        return staged_bcast(x, comm, root=root, transport=tp)
    return _stream_bcast_impl(x, comm, root=root,
                              n_chunks=p.clamp_chunks(x.shape[0]),
                              transport=tp)


def reduce(x: jax.Array, comm: Communicator, *, root: int = 0, op=jnp.add,
           plan="auto", transport=None):
    """Autotuned rooted reduction (same dispatch rules as :func:`bcast`)."""
    p = _resolve_plan(plan, "reduce", comm, x)
    tp = transport if transport is not None else p.transport_key
    if p.algo == "tree":
        return tree_reduce(x, comm, root=root, op=op, transport=tp)
    if p.algo == "staged":
        return staged_reduce(x, comm, root=root, op=op, transport=tp)
    return _stream_reduce_impl(x, comm, root=root, op=op,
                               n_chunks=p.clamp_chunks(x.shape[0]),
                               transport=tp)


def allreduce(x: jax.Array, comm: Communicator, *, plan="auto",
              transport=None, **kw):
    """Autotuned ring all-reduce.  Only the plan's transport applies here:
    the RS+AG schedule fixes its own chunking (nbytes/P blocks), so the
    tuner sweeps no chunk grid for this op and ``plan.n_chunks`` is moot."""
    p = _resolve_plan(plan, "allreduce", comm, x)
    tp = transport if transport is not None else p.transport_key
    return _stream_allreduce_impl(x, comm, transport=tp, **kw)


# ---------------------------------------------------------------------------
# Host-staged baseline (the paper's MPI+OpenCL comparison point)
# ---------------------------------------------------------------------------


def staged_bcast(x, comm: Communicator, *, root: int = 0, transport=None):
    """Unpipelined baseline: root sends the whole message to each rank in
    turn (models the paper's host-staged path: serialized bulk transfers,
    no streaming overlap)."""
    P = comm.size
    r = comm.rank()
    tp = _resolve(transport, comm)
    out = _mask_sel(r == root, x, jnp.zeros_like(x))
    for d in range(1, P):
        dst = (root + d) % P
        path = comm.route_table.path(root, dst)
        buf = _mask_sel(r == root, x, jnp.zeros_like(x))
        for a, b in zip(path[:-1], path[1:]):
            buf = tp.permute(buf, comm, [(a, b)])
        out = _mask_sel(r == dst, buf, out)
    return out


def staged_reduce(x, comm: Communicator, *, root: int = 0, op=jnp.add, transport=None):
    """Unpipelined baseline reduce: each rank's full buffer travels to the
    root sequentially."""
    P = comm.size
    r = comm.rank()
    tp = _resolve(transport, comm)
    acc = _mask_sel(r == root, x, jnp.zeros_like(x))
    for d in range(1, P):
        src = (root + d) % P
        path = comm.route_table.path(src, root)
        buf = _mask_sel(r == src, x, jnp.zeros_like(x))
        for a, b in zip(path[:-1], path[1:]):
            buf = tp.permute(buf, comm, [(a, b)])
        folded = tp.accumulate(acc, buf) if op is jnp.add else op(acc, buf)
        acc = _mask_sel(r == root, folded, acc)
    return acc


# ---------------------------------------------------------------------------
# int8 wire compression (gradient sync; pairs with optim error feedback)
# ---------------------------------------------------------------------------


def make_int8_codec(axis_elems: int | None = None):
    """int8 quantization codec for compressed rings.

    ``axis_elems`` sets the scale-block size: one f32 scale per
    ``axis_elems`` flattened payload elements (``None`` = a single
    per-tensor scale — the historic behaviour, which used to be the *only*
    behaviour because the parameter was silently ignored).  Blockwise
    scales localise the quantisation step to each block's own magnitude,
    which is what makes heterogeneous-magnitude tensors (gradients)
    survive int8 wires.

    Prefer ``transport="compressed"`` for new code — same codec, plus
    per-hop error feedback and byte-accurate wire stats; this factory
    remains the explicit-codec hook for the deprecated kwargs path.
    """
    from ..transport.compressed import dequantize_int8, quantize_int8

    def quantize(v):
        return quantize_int8(v, axis_elems)

    def dequantize(wire):
        return dequantize_int8(wire, axis_elems)

    return quantize, dequantize
