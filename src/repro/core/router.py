"""Dynamic packet-switched transport (paper §4.2–§4.3).

The paper's transport layer: CK_S/CK_R kernels connected to the physical
QSFP links, forwarding fixed-size network packets according to routing
tables that are *uploaded at runtime* — topology or rank-count changes
never rebuild the bitstream.

TPU rendering (DESIGN.md §2): the compiled XLA executable is the bitstream.
It executes a **fixed** per-step link schedule — one ppermute per physical
link id (±1 along each mesh dim, the ICI torus wiring) — and the routing
table is a runtime ``(n, n)`` int32 array mapping (rank, dst) -> link id.
Swapping tables re-routes the same compiled program, reproducing the paper's
flexibility experiment (torus vs. bus without rebuild) exactly.

Per router step (one "clock cycle"):
  1. per link: arbitrate a packet whose table entry routes it out that link
     — transit traffic first (drain the network), then input-FIFO traffic
     with the paper's R-stickiness polling (§4.3: keep reading the same
     FIFO up to R times before moving on);
  2. all links fire their ppermute (invalid packets ride as bubbles);
  3. arrivals are delivered (dst == me: pushed to the port's output buffer)
     or parked in the transit FIFO for the next hop.

Store-and-forward with a bounded transit FIFO; an overflow counter is
returned so tests/benchmarks can assert lossless runs (the paper's links
provide backpressure; we provide provable-capacity schedules instead).
A delivery buffer past ``out_cap`` and a transit queue past ``transit_cap``
both *drop* the packet and count it in ``overflow``.

Packets: payload (PKT_ELEMS f32) + header (dst rank, port) — the 28 B + 4 B
network packet of §4.2, scaled to a TPU-friendly chunk.

Three implementations of the identical tick semantics (DESIGN.md §10):

* ``impl="scalar"`` — the per-link Python-unrolled reference loop;
* ``impl="vector"`` — whole-state array ops (one masked argmax arbitrates
  all links per tick, prefix-sum absorb), ONE packed ``all_to_all``
  exchange per tick instead of a ppermute per link, and an early-exit
  batched tick loop (a scan of cond'd batches — reverse-differentiable)
  that goes idle as soon as the network drains;
* ``impl="pallas"`` — the vector tick as a Pallas kernel
  (``kernels/router``) whose FIFO/arbiter state is aliased in place
  (VMEM-resident on TPU); interpret-mode fallback elsewhere.

``impl=None`` auto-selects: pallas on TPU, vector otherwise.  All three
produce bit-identical ``(out_pay, out_cnt, overflow, t_done)`` — asserted
by the equivalence tests in ``tests/test_router.py``.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..obs import trace as obs
from .comm import Communicator
from .routing import compute_route_table, physical_link_map
from .streaming import _pvary
from .topology import Topology

LOCAL = -1  # routing-table value for "deliver here" (never looked up)


def make_links(dims: tuple[int, ...]):
    """Physical link list for a torus mesh: (link_id, perm pairs).

    link 2*i   = +1 along dim i; link 2*i+1 = -1 along dim i (omitted when
    the dim has size <= 2, where -1 == +1)."""
    topo = Topology.torus(dims)
    n = topo.n_ranks
    strides = []
    s = 1
    for d in reversed(dims):
        strides.append(s)
        s *= d
    strides = list(reversed(strides))

    def coords(r):
        return [(r // strides[i]) % dims[i] for i in range(len(dims))]

    def rank_of(c):
        return sum(c[j] * strides[j] for j in range(len(dims)))

    links = []
    for i, d in enumerate(dims):
        if d == 1:
            continue
        steps = (+1,) if d == 2 else (+1, -1)
        for sidx, step in enumerate(steps):
            pairs = []
            for r in range(n):
                c = coords(r)
                c[i] = (c[i] + step) % d
                pairs.append((r, rank_of(c)))
            links.append((2 * i + sidx, pairs))
    return links


def make_router_tables(
    topology: Topology, dims: tuple[int, ...], rt=None
) -> np.ndarray:
    """The route generator for the dynamic router: (n, n) int32 of link ids.

    Every edge of ``topology`` must be a physical neighbour pair on the
    ``dims`` torus (the paper's constraint: logical connections are real
    wires).  Entry [r, d] = physical link id of the first hop r -> d.
    Pass ``rt`` (a precomputed RouteTable, e.g. a communicator's) to make
    the router follow exactly those paths instead of recomputing with the
    default scheme."""
    if rt is None:
        rt = compute_route_table(topology)
    phys = physical_link_map(dims)
    # remap ids for size-2 dims where only the +1 link exists
    links = make_links(dims)
    live_ids = {lid for lid, _ in links}

    def canon(lid):
        return lid if lid in live_ids else lid - 1  # -1 of a size-2 dim -> +1

    n = topology.n_ranks
    tbl = np.full((n, n), LOCAL, dtype=np.int32)
    for r in range(n):
        for d in range(n):
            if r == d:
                continue
            nh = int(rt.next_hop[r, d])
            assert (r, nh) in phys, (
                f"logical edge {r}->{nh} of {topology.name} is not a physical "
                f"link on torus{dims}; embed the topology first (e.g. snake_bus)"
            )
            tbl[r, d] = canon(phys[(r, nh)])
    return tbl


def snake_bus(dims: tuple[int, int]) -> Topology:
    """A linear bus embedded in the torus along a boustrophedon path — the
    paper's 'treat the 8 FPGAs as a linear bus by editing the connection
    list' experiment (§5.3.1)."""
    X, Y = dims
    order = []
    for x in range(X):
        ys = range(Y) if x % 2 == 0 else range(Y - 1, -1, -1)
        order += [x * Y + y for y in ys]
    edges = list(zip(order[:-1], order[1:]))
    t = Topology.from_edges(X * Y, edges, name=f"snake_bus{dims}")
    return t


@dataclass(frozen=True)
class RouterConfig:
    dims: tuple[int, ...]
    n_ports: int = 2          # application endpoints per rank
    fifo_cap: int = 8         # input FIFO depth (paper: compile-time buffer)
    transit_cap: int = 16     # CK transit queue depth
    out_cap: int = 16         # delivery buffer per port
    pkt_elems: int = 32       # payload elements (the 28 B packet, scaled)
    R: int = 8                # polling stickiness (paper §4.3)
    switch_bubble: bool = False  # model the FPGA CK's sequential polling
    # cost: switching input FIFOs costs one dead cycle on the link (the
    # paper's Tab. 4 effect; our combinational arbiter has no such cost
    # physically, so it is opt-in for the reproduction benchmark)
    tick_batch: int | None = None  # ticks advanced per loop body in the
    # vector/pallas datapath; the drain check runs once per batch, so
    # up to tick_batch - 1 idle (identity) ticks run past the drain point.
    # None = adaptive: 2 on the packed exchange (its drain check is free —
    # the pending count rides in the packet's lane), 4 on the psum
    # fallback, where deeper batches amortize the extra collective


def _default_impl() -> str:
    from ..kernels.common import on_tpu

    return "pallas" if on_tpu() else "vector"


def _exchange_tables(links, n: int):
    """Static per-rank exchange tables for the packed all_to_all tick.

    ``nbr[r, li]`` = the rank link ``li`` delivers to from ``r``;
    ``src[r, li]`` = the rank whose link-``li`` packet lands on ``r``.
    ``packed_ok`` is True when every rank's link destinations are distinct
    (always the case for torus links), so one (n, F) row buffer carries at
    most one packet per destination and a single tiled ``all_to_all``
    replaces the per-link ppermutes."""
    NL = len(links)
    nbr = np.zeros((n, NL), np.int32)
    src = np.zeros((n, NL), np.int32)
    for li, (_lid, pairs) in enumerate(links):
        for s, d in pairs:
            nbr[s, li] = d
            src[d, li] = s
    packed_ok = all(len(set(nbr[q])) == NL for q in range(n))
    return nbr, src, packed_ok


def run_router(
    cfg: RouterConfig,
    comm: Communicator,
    route_tbl: jax.Array,      # (n, n) int32 link ids — RUNTIME data
    inq_pay: jax.Array,        # (n_ports, fifo_cap, E) staged messages
    inq_dst: jax.Array,        # (n_ports, fifo_cap) destination ranks
    inq_len: jax.Array,        # (n_ports,) packets staged per FIFO
    n_steps: int,
    *,
    impl: str | None = None,
    interpret: bool | None = None,
):
    """Execute up to ``n_steps`` router cycles.  Must run inside shard_map.

    Returns (out_pay, out_cnt, overflow, t_done): per-port delivery
    buffers, their fill counts, the loss counter (0 == lossless run) and
    the last delivery tick.  ``impl`` picks the datapath ("scalar" |
    "vector" | "pallas"; None auto-selects — see module docstring); the
    vector/pallas datapaths may stop early once the network drains, which
    never changes the returned values.  ``interpret`` forces the Pallas
    tick kernel through the interpreter (None: interpret off TPU).
    """
    links = make_links(cfg.dims)
    if impl is None:
        impl = _default_impl()
    if impl != "scalar" and (not links or inq_pay.dtype != jnp.float32):
        # degenerate fabrics (no links) and exotic wire dtypes keep the
        # reference path; the packetised wire is always f32
        impl = "scalar"
    if obs.TRACING:
        obs.emit("router.run", impl=impl, n_steps=int(n_steps),
                 n_links=len(links), n_ports=int(cfg.n_ports),
                 dims=list(cfg.dims))
    if impl == "scalar":
        return _run_router_scalar(
            cfg, comm, route_tbl, inq_pay, inq_dst, inq_len, n_steps, links)
    assert impl in ("vector", "pallas"), impl
    return _run_router_vector(
        cfg, comm, route_tbl, inq_pay, inq_dst, inq_len, n_steps, links,
        use_pallas=impl == "pallas", interpret=interpret)


def _run_router_scalar(
    cfg, comm, route_tbl, inq_pay, inq_dst, inq_len, n_steps, links
):
    """The per-link scalar reference loop (the equivalence-test oracle)."""
    n = comm.size
    r = comm.rank()
    E = cfg.pkt_elems
    NP = cfg.n_ports
    NL = len(links)
    my_tbl = route_tbl[jnp.minimum(r, n - 1)]  # (n,) link id per dst

    def init():
        z = lambda *sh_dt: _pvary(jnp.zeros(*sh_dt), comm)
        return dict(
            inq_head=z((NP,), jnp.int32),
            inq_len=_pvary(inq_len.astype(jnp.int32), comm),
            tr_pay=z((cfg.transit_cap, E), inq_pay.dtype),
            tr_dst=z((cfg.transit_cap,), jnp.int32),
            tr_port=z((cfg.transit_cap,), jnp.int32),
            tr_head=z((), jnp.int32),
            tr_cnt=z((), jnp.int32),
            out_pay=z((NP, cfg.out_cap, E), inq_pay.dtype),
            out_cnt=z((NP,), jnp.int32),
            overflow=z((), jnp.int32),
            last_src=z((NL,), jnp.int32),
            stick=z((NL,), jnp.int32),
            t_done=z((), jnp.int32),
        )

    def fifo_head(st, p):
        """Head packet of input FIFO p: (pay, dst, port, has)."""
        h = st["inq_head"][p]
        pay = inq_pay[p, jnp.minimum(h, cfg.fifo_cap - 1)]
        dst = inq_dst[p, jnp.minimum(h, cfg.fifo_cap - 1)]
        has = h < st["inq_len"][p]
        return pay, dst, p, has

    def transit_head(st):
        h = st["tr_head"] % cfg.transit_cap
        return st["tr_pay"][h], st["tr_dst"][h], st["tr_port"][h], st["tr_cnt"] > 0

    def step(t, st):
        # ---- gather candidate heads: sources 0..NP-1 = FIFOs, NP = transit
        pays, dsts, ports, has_l = [], [], [], []
        for p in range(NP):
            pay, dst, port, has = fifo_head(st, p)
            pays.append(pay); dsts.append(dst); ports.append(jnp.asarray(port)); has_l.append(has)
        tpay, tdst, tport, thas = transit_head(st)
        pays.append(tpay); dsts.append(tdst); ports.append(tport); has_l.append(thas)
        pays = jnp.stack(pays)               # (S, E)
        dsts = jnp.stack(dsts)               # (S,)
        ports = jnp.stack([jnp.asarray(p, jnp.int32) for p in ports])
        has = jnp.stack(has_l)                  # (S,)
        S = NP + 1
        want_link = jnp.where(dsts == r, -2, my_tbl[jnp.clip(dsts, 0, n - 1)])  # (S,)

        taken = jnp.zeros((S,), bool)
        sel_src = []
        for li, (lid, _) in enumerate(links):
            avail = jnp.logical_and(has, jnp.logical_and(want_link == lid, ~taken))
            # transit priority: if transit wants this link, take it.
            tr_want = avail[S - 1]
            # R-stickiness round-robin over FIFO sources
            last = st["last_src"][li]
            stickok = st["stick"][li] < cfg.R
            keep = jnp.logical_and(stickok, avail[jnp.clip(last, 0, S - 1)])
            # next available after `last` (rotate & argmax)
            idxs = (last + 1 + jnp.arange(S)) % S
            rot = avail[idxs]
            off = jnp.argmax(rot)
            rr = idxs[off]
            chosen = jnp.where(tr_want, S - 1, jnp.where(keep, last, rr))
            any_avail = avail.any()
            if cfg.switch_bubble:
                # sequential-polling model: acquiring a new FIFO burns the
                # cycle (the link sends nothing) but the arbiter latches on
                switching = jnp.logical_and(any_avail, chosen != last)
                send = jnp.logical_and(any_avail, ~switching)
            else:
                send = any_avail
            new_last = jnp.where(any_avail, chosen, last)
            new_stick = jnp.where(
                jnp.logical_and(send, chosen == last), st["stick"][li] + 1, 0
            )
            st["last_src"] = st["last_src"].at[li].set(new_last)
            st["stick"] = st["stick"].at[li].set(new_stick)
            chosen = jnp.where(send, chosen, -1)
            taken = jnp.where(send, taken.at[jnp.clip(chosen, 0, S - 1)].set(True), taken)
            sel_src.append(chosen)

        # ---- pop selected sources
        for li in range(NL):
            c = sel_src[li]
            for p in range(NP):
                hit = c == p
                st["inq_head"] = st["inq_head"].at[p].add(jnp.where(hit, 1, 0))
            hit_tr = c == S - 1
            st["tr_head"] = st["tr_head"] + jnp.where(hit_tr, 1, 0)
            st["tr_cnt"] = st["tr_cnt"] - jnp.where(hit_tr, 1, 0)

        # ---- fire all links (fixed wiring; bubbles ride as invalid)
        arrivals = []
        for li, (lid, pairs) in enumerate(links):
            c = sel_src[li]
            val = c >= 0
            cs = jnp.clip(c, 0, S - 1)
            pay = pays[cs]
            dst = jnp.where(val, dsts[cs], -1)
            prt = jnp.where(val, ports[cs], 0)
            pay, dst, prt, val = jax.tree.map(
                lambda v: lax.ppermute(v, comm.axis, pairs), (pay, dst, prt, val)
            )
            arrivals.append((pay, dst, prt, val))

        # ---- absorb arrivals: deliver or park in transit
        for pay, dst, prt, val in arrivals:
            mine = jnp.logical_and(val, dst == r)
            fwd = jnp.logical_and(val, dst != r)
            # deliver to port buffer; a full buffer drops the packet and
            # counts it in overflow, like a transit overrun (it must not
            # silently overwrite the last delivered packet)
            fits = st["out_cnt"][jnp.clip(prt, 0, NP - 1)] < cfg.out_cap
            delivered = jnp.logical_and(mine, fits)
            for p in range(NP):
                hit = jnp.logical_and(delivered, prt == p)
                slot = jnp.clip(st["out_cnt"][p], 0, cfg.out_cap - 1)
                newbuf = st["out_pay"].at[p, slot].set(pay)
                st["out_pay"] = jnp.where(hit, newbuf, st["out_pay"])
                st["out_cnt"] = st["out_cnt"].at[p].add(jnp.where(hit, 1, 0))
            st["overflow"] = st["overflow"] + jnp.where(
                jnp.logical_and(mine, ~fits), 1, 0
            )
            st["t_done"] = jnp.where(delivered, t.astype(jnp.int32), st["t_done"])
            # park in transit ring buffer
            room = st["tr_cnt"] < cfg.transit_cap
            ok = jnp.logical_and(fwd, room)
            tail = (st["tr_head"] + st["tr_cnt"]) % cfg.transit_cap
            st["tr_pay"] = jnp.where(ok, st["tr_pay"].at[tail].set(pay), st["tr_pay"])
            st["tr_dst"] = jnp.where(ok, st["tr_dst"].at[tail].set(dst), st["tr_dst"])
            st["tr_port"] = jnp.where(ok, st["tr_port"].at[tail].set(prt), st["tr_port"])
            st["tr_cnt"] = st["tr_cnt"] + jnp.where(ok, 1, 0)
            st["overflow"] = st["overflow"] + jnp.where(
                jnp.logical_and(fwd, ~room), 1, 0
            )
        return st

    st = lax.fori_loop(0, n_steps, step, init())
    return st["out_pay"], st["out_cnt"], st["overflow"], st["t_done"]


def _run_router_vector(
    cfg, comm, route_tbl, inq_pay, inq_dst, inq_len, n_steps, links, *,
    use_pallas: bool, interpret: bool | None,
):
    """Vectorized batched-tick datapath (DESIGN.md §10).

    Per tick: ``router_tick`` (absorb + one-shot arbitration, pure array
    ops — or the Pallas kernel wrapping the same function) followed by ONE
    packed ``all_to_all`` moving every link's packet row plus a global
    pending lane.  The tick loop is a ``scan`` of ``cond``'d batches
    advancing ``cfg.tick_batch`` ticks each that go idle as soon as the
    pending lane reports the network drained — idle ticks are identity
    on every returned value, so the early out is output-invariant with
    the scalar reference running all ``n_steps`` cycles, and scan+cond
    keep the datapath reverse-differentiable for the training path.
    """
    from ..compat import HAS_VMA
    from ..kernels.common import on_tpu
    from ..kernels.router import router_absorb, router_tick, \
        router_tick_pallas, tick_spec_of

    n = comm.size
    r = comm.rank()
    E = cfg.pkt_elems
    NL = len(links)
    F = E + 4  # lanes: dst, port, valid, pending + payload
    spec = tick_spec_of(cfg, n, [lid for lid, _ in links])
    my_tbl = route_tbl[jnp.minimum(r, n - 1)]
    inq_len = inq_len.astype(jnp.int32)
    nbr, src, packed_ok = _exchange_tables(links, n)
    nbr_r = jnp.asarray(nbr)[jnp.minimum(r, n - 1)]
    src_r = jnp.asarray(src)[jnp.minimum(r, n - 1)]
    if interpret is None:
        interpret = not on_tpu()
    # the drain predicate must be replicated: on VMA runtimes that is a
    # psum of the local pending count; pre-VMA runtimes read the packed
    # exchange's own pending lane (same value, no extra collective)
    lane_live = packed_ok and not HAS_VMA

    def init():
        z = lambda *sh_dt: _pvary(jnp.zeros(*sh_dt), comm)
        st = dict(
            inq_head=z((cfg.n_ports,), jnp.int32),
            tr_pay=z((cfg.transit_cap, E), inq_pay.dtype),
            tr_dst=z((cfg.transit_cap,), jnp.int32),
            tr_port=z((cfg.transit_cap,), jnp.int32),
            tr_head=z((), jnp.int32),
            tr_cnt=z((), jnp.int32),
            out_pay=z((cfg.n_ports, cfg.out_cap, E), inq_pay.dtype),
            out_cnt=z((cfg.n_ports,), jnp.int32),
            overflow=z((), jnp.int32),
            last_src=z((NL,), jnp.int32),
            stick=z((NL,), jnp.int32),
            t_done=z((), jnp.int32),
        )
        arr = (z((NL, E), inq_pay.dtype), z((NL,), jnp.int32),
               z((NL,), jnp.int32), z((NL,), bool))
        return st, arr

    def tick(st, arr, t):
        if use_pallas:
            return router_tick_pallas(
                spec, my_tbl, inq_pay, inq_dst, inq_len, st, *arr, r, t,
                interpret=interpret)
        return router_tick(
            spec, my_tbl, inq_pay, inq_dst, inq_len, st, *arr, r, t)

    def exchange(snd_pay, snd_dst, snd_prt, snd_val, pending):
        pend_f = pending.astype(jnp.float32)
        row = jnp.concatenate([
            snd_dst.astype(jnp.float32)[:, None],
            snd_prt.astype(jnp.float32)[:, None],
            snd_val.astype(jnp.float32)[:, None],
            jnp.broadcast_to(pend_f, (NL,))[:, None],
            snd_pay,
        ], axis=1)                                           # (NL, F)
        if packed_ok:
            # one collective for the whole fabric: row li rides at the
            # destination's index, every row carries the pending lane
            buf = _pvary(jnp.zeros((n, F), jnp.float32), comm)
            buf = buf.at[:, 3].set(pend_f)
            buf = buf.at[nbr_r].set(row)
            got = lax.all_to_all(buf, comm.axis, 0, 0, tiled=True)
            rows = got[src_r]                                # (NL, F)
            live = got[:, 3].sum().astype(jnp.int32)
        else:
            rows = jnp.stack([
                lax.ppermute(row[li], comm.axis, pairs)
                for li, (_lid, pairs) in enumerate(links)
            ])
            live = jnp.asarray(0, jnp.int32)
        if not lane_live:
            live = lax.psum(pending, comm.axis)
        arr = (rows[:, 4:], rows[:, 0].astype(jnp.int32),
               rows[:, 1].astype(jnp.int32), rows[:, 2] > 0.5)
        return arr, live

    # batch size must divide n_steps: the drain check only runs between
    # batches, and a batch straddling the n_steps bound would tick a
    # still-live network past the cycle budget the scalar reference stops
    # at (idle ticks are identity, over-budget *live* ticks are not)
    req = cfg.tick_batch if cfg.tick_batch is not None \
        else (2 if lane_live else 4)
    B = max(1, min(int(req), int(n_steps)))
    while n_steps % B:
        B -= 1
    if obs.TRACING:
        obs.emit("router.tick_batch", batch=int(B),
                 n_batches=int(n_steps) // int(B), lane_live=bool(lane_live))
        obs.emit("router.drain", mode="lane" if lane_live else "psum")

    # early exit without while_loop: a scan over n_steps // B batches
    # whose body is a cond — once the pending lane reports the network
    # drained, the remaining batches take the identity branch (the taken
    # branch is all XLA executes, so drained batches cost ~nothing).
    # cond + scan both carry transpose rules, which keeps the packet
    # datapath reverse-differentiable end to end (the training path
    # differentiates straight through the router, like the scalar
    # reference's concrete-bound fori_loop); while_loop does not.
    def batch(carry):
        st, arr, t, live = carry
        for _ in range(B):
            st, sp, sd, sq, sv, pend = tick(st, arr, t)
            arr, live = exchange(sp, sd, sq, sv, pend)
            t = t + 1
        return st, arr, t, live

    def body(carry, _):
        return lax.cond(carry[3] > 0, batch, lambda c: c, carry), None

    st0, arr0 = init()
    (st, arr, t, _live), _ = lax.scan(
        body,
        (st0, arr0, jnp.asarray(0, jnp.int32), jnp.asarray(1, jnp.int32)),
        None, length=n_steps // B,
    )
    # the final exchange's arrivals are still in flight at loop exit
    st = router_absorb(spec, st, *arr, r, t - 1)
    return st["out_pay"], st["out_cnt"], st["overflow"], st["t_done"]
