"""Dynamic packet-switched transport (paper §4.2–§4.3).

The paper's transport layer: CK_S/CK_R kernels connected to the physical
QSFP links, forwarding fixed-size network packets according to routing
tables that are *uploaded at runtime* — topology or rank-count changes
never rebuild the bitstream.

TPU rendering (DESIGN.md §2): the compiled XLA executable is the bitstream.
It executes a **fixed** per-step link schedule — one ppermute per physical
link id (±1 along each mesh dim, the ICI torus wiring) — and the routing
table is a runtime ``(n, n)`` int32 array mapping (rank, dst) -> link id.
Swapping tables re-routes the same compiled program, reproducing the paper's
flexibility experiment (torus vs. bus without rebuild) exactly.

Per router step (one "clock cycle"):
  1. per link: arbitrate a packet whose table entry routes it out that link
     — transit traffic first (drain the network), then input-FIFO traffic
     with the paper's R-stickiness polling (§4.3: keep reading the same
     FIFO up to R times before moving on);
  2. all links fire their ppermute (invalid packets ride as bubbles);
  3. arrivals are delivered (dst == me: pushed to the port's output buffer)
     or parked in the transit FIFO for the next hop.

Store-and-forward with a bounded transit FIFO; an overflow counter is
returned so tests/benchmarks can assert lossless runs (the paper's links
provide backpressure; we provide provable-capacity schedules instead).

Packets: payload (PKT_ELEMS f32) + header (dst rank, port) — the 28 B + 4 B
network packet of §4.2, scaled to a TPU-friendly chunk.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .comm import Communicator
from .routing import compute_route_table, physical_link_map
from .streaming import _pvary
from .topology import Topology

LOCAL = -1  # routing-table value for "deliver here" (never looked up)


def make_links(dims: tuple[int, ...]):
    """Physical link list for a torus mesh: (link_id, perm pairs).

    link 2*i   = +1 along dim i; link 2*i+1 = -1 along dim i (omitted when
    the dim has size <= 2, where -1 == +1)."""
    topo = Topology.torus(dims)
    n = topo.n_ranks
    strides = []
    s = 1
    for d in reversed(dims):
        strides.append(s)
        s *= d
    strides = list(reversed(strides))

    def coords(r):
        return [(r // strides[i]) % dims[i] for i in range(len(dims))]

    def rank_of(c):
        return sum(c[j] * strides[j] for j in range(len(dims)))

    links = []
    for i, d in enumerate(dims):
        if d == 1:
            continue
        steps = (+1,) if d == 2 else (+1, -1)
        for sidx, step in enumerate(steps):
            pairs = []
            for r in range(n):
                c = coords(r)
                c[i] = (c[i] + step) % d
                pairs.append((r, rank_of(c)))
            links.append((2 * i + sidx, pairs))
    return links


def make_router_tables(
    topology: Topology, dims: tuple[int, ...], rt=None
) -> np.ndarray:
    """The route generator for the dynamic router: (n, n) int32 of link ids.

    Every edge of ``topology`` must be a physical neighbour pair on the
    ``dims`` torus (the paper's constraint: logical connections are real
    wires).  Entry [r, d] = physical link id of the first hop r -> d.
    Pass ``rt`` (a precomputed RouteTable, e.g. a communicator's) to make
    the router follow exactly those paths instead of recomputing with the
    default scheme."""
    if rt is None:
        rt = compute_route_table(topology)
    phys = physical_link_map(dims)
    # remap ids for size-2 dims where only the +1 link exists
    links = make_links(dims)
    live_ids = {lid for lid, _ in links}

    def canon(lid):
        return lid if lid in live_ids else lid - 1  # -1 of a size-2 dim -> +1

    n = topology.n_ranks
    tbl = np.full((n, n), LOCAL, dtype=np.int32)
    for r in range(n):
        for d in range(n):
            if r == d:
                continue
            nh = int(rt.next_hop[r, d])
            assert (r, nh) in phys, (
                f"logical edge {r}->{nh} of {topology.name} is not a physical "
                f"link on torus{dims}; embed the topology first (e.g. snake_bus)"
            )
            tbl[r, d] = canon(phys[(r, nh)])
    return tbl


def snake_bus(dims: tuple[int, int]) -> Topology:
    """A linear bus embedded in the torus along a boustrophedon path — the
    paper's 'treat the 8 FPGAs as a linear bus by editing the connection
    list' experiment (§5.3.1)."""
    X, Y = dims
    order = []
    for x in range(X):
        ys = range(Y) if x % 2 == 0 else range(Y - 1, -1, -1)
        order += [x * Y + y for y in ys]
    edges = list(zip(order[:-1], order[1:]))
    t = Topology.from_edges(X * Y, edges, name=f"snake_bus{dims}")
    return t


@dataclass(frozen=True)
class RouterConfig:
    dims: tuple[int, ...]
    n_ports: int = 2          # application endpoints per rank
    fifo_cap: int = 8         # input FIFO depth (paper: compile-time buffer)
    transit_cap: int = 16     # CK transit queue depth
    out_cap: int = 16         # delivery buffer per port
    pkt_elems: int = 32       # payload elements (the 28 B packet, scaled)
    R: int = 8                # polling stickiness (paper §4.3)
    switch_bubble: bool = False  # model the FPGA CK's sequential polling
    # cost: switching input FIFOs costs one dead cycle on the link (the
    # paper's Tab. 4 effect; our combinational arbiter has no such cost
    # physically, so it is opt-in for the reproduction benchmark)


def run_router(
    cfg: RouterConfig,
    comm: Communicator,
    route_tbl: jax.Array,      # (n, n) int32 link ids — RUNTIME data
    inq_pay: jax.Array,        # (n_ports, fifo_cap, E) staged messages
    inq_dst: jax.Array,        # (n_ports, fifo_cap) destination ranks
    inq_len: jax.Array,        # (n_ports,) packets staged per FIFO
    n_steps: int,
):
    """Execute ``n_steps`` router cycles.  Must run inside shard_map.

    Returns (out_pay, out_cnt, overflow): per-port delivery buffers, their
    fill counts, and the transit-overflow counter (0 == lossless run).
    """
    n = comm.size
    r = comm.rank()
    E = cfg.pkt_elems
    NP = cfg.n_ports
    links = make_links(cfg.dims)
    NL = len(links)
    my_tbl = route_tbl[jnp.minimum(r, n - 1)]  # (n,) link id per dst

    def init():
        z = lambda *sh_dt: _pvary(jnp.zeros(*sh_dt), comm)
        return dict(
            inq_head=z((NP,), jnp.int32),
            inq_len=_pvary(inq_len.astype(jnp.int32), comm),
            tr_pay=z((cfg.transit_cap, E), inq_pay.dtype),
            tr_dst=z((cfg.transit_cap,), jnp.int32),
            tr_port=z((cfg.transit_cap,), jnp.int32),
            tr_head=z((), jnp.int32),
            tr_cnt=z((), jnp.int32),
            out_pay=z((NP, cfg.out_cap, E), inq_pay.dtype),
            out_cnt=z((NP,), jnp.int32),
            overflow=z((), jnp.int32),
            last_src=z((NL,), jnp.int32),
            stick=z((NL,), jnp.int32),
            t_done=z((), jnp.int32),
        )

    def fifo_head(st, p):
        """Head packet of input FIFO p: (pay, dst, port, has)."""
        h = st["inq_head"][p]
        pay = inq_pay[p, jnp.minimum(h, cfg.fifo_cap - 1)]
        dst = inq_dst[p, jnp.minimum(h, cfg.fifo_cap - 1)]
        has = h < st["inq_len"][p]
        return pay, dst, p, has

    def transit_head(st):
        h = st["tr_head"] % cfg.transit_cap
        return st["tr_pay"][h], st["tr_dst"][h], st["tr_port"][h], st["tr_cnt"] > 0

    def step(t, st):
        # ---- gather candidate heads: sources 0..NP-1 = FIFOs, NP = transit
        pays, dsts, ports, has_l = [], [], [], []
        for p in range(NP):
            pay, dst, port, has = fifo_head(st, p)
            pays.append(pay); dsts.append(dst); ports.append(jnp.asarray(port)); has_l.append(has)
        tpay, tdst, tport, thas = transit_head(st)
        pays.append(tpay); dsts.append(tdst); ports.append(tport); has_l.append(thas)
        pays = jnp.stack(pays)               # (S, E)
        dsts = jnp.stack(dsts)               # (S,)
        ports = jnp.stack([jnp.asarray(p, jnp.int32) for p in ports])
        has = jnp.stack(has_l)                  # (S,)
        S = NP + 1
        want_link = jnp.where(dsts == r, -2, my_tbl[jnp.clip(dsts, 0, n - 1)])  # (S,)

        taken = jnp.zeros((S,), bool)
        sel_src = []
        for li, (lid, _) in enumerate(links):
            avail = jnp.logical_and(has, jnp.logical_and(want_link == lid, ~taken))
            # transit priority: if transit wants this link, take it.
            tr_want = avail[S - 1]
            # R-stickiness round-robin over FIFO sources
            last = st["last_src"][li]
            stickok = st["stick"][li] < cfg.R
            keep = jnp.logical_and(stickok, avail[jnp.clip(last, 0, S - 1)])
            # next available after `last` (rotate & argmax)
            idxs = (last + 1 + jnp.arange(S)) % S
            rot = avail[idxs]
            off = jnp.argmax(rot)
            rr = idxs[off]
            chosen = jnp.where(tr_want, S - 1, jnp.where(keep, last, rr))
            any_avail = avail.any()
            if cfg.switch_bubble:
                # sequential-polling model: acquiring a new FIFO burns the
                # cycle (the link sends nothing) but the arbiter latches on
                switching = jnp.logical_and(any_avail, chosen != last)
                send = jnp.logical_and(any_avail, ~switching)
            else:
                send = any_avail
            new_last = jnp.where(any_avail, chosen, last)
            new_stick = jnp.where(
                jnp.logical_and(send, chosen == last), st["stick"][li] + 1, 0
            )
            st["last_src"] = st["last_src"].at[li].set(new_last)
            st["stick"] = st["stick"].at[li].set(new_stick)
            chosen = jnp.where(send, chosen, -1)
            taken = jnp.where(send, taken.at[jnp.clip(chosen, 0, S - 1)].set(True), taken)
            sel_src.append(chosen)

        # ---- pop selected sources
        for li in range(NL):
            c = sel_src[li]
            for p in range(NP):
                hit = c == p
                st["inq_head"] = st["inq_head"].at[p].add(jnp.where(hit, 1, 0))
            hit_tr = c == S - 1
            st["tr_head"] = st["tr_head"] + jnp.where(hit_tr, 1, 0)
            st["tr_cnt"] = st["tr_cnt"] - jnp.where(hit_tr, 1, 0)

        # ---- fire all links (fixed wiring; bubbles ride as invalid)
        arrivals = []
        for li, (lid, pairs) in enumerate(links):
            c = sel_src[li]
            val = c >= 0
            cs = jnp.clip(c, 0, S - 1)
            pay = pays[cs]
            dst = jnp.where(val, dsts[cs], -1)
            prt = jnp.where(val, ports[cs], 0)
            pay, dst, prt, val = jax.tree.map(
                lambda v: lax.ppermute(v, comm.axis, pairs), (pay, dst, prt, val)
            )
            arrivals.append((pay, dst, prt, val))

        # ---- absorb arrivals: deliver or park in transit
        for pay, dst, prt, val in arrivals:
            mine = jnp.logical_and(val, dst == r)
            fwd = jnp.logical_and(val, dst != r)
            # deliver to port buffer
            for p in range(NP):
                hit = jnp.logical_and(mine, prt == p)
                slot = jnp.clip(st["out_cnt"][p], 0, cfg.out_cap - 1)
                newbuf = st["out_pay"].at[p, slot].set(pay)
                st["out_pay"] = jnp.where(hit, newbuf, st["out_pay"])
                st["out_cnt"] = st["out_cnt"].at[p].add(jnp.where(hit, 1, 0))
            st["t_done"] = jnp.where(mine, t.astype(jnp.int32), st["t_done"])
            # park in transit ring buffer
            room = st["tr_cnt"] < cfg.transit_cap
            ok = jnp.logical_and(fwd, room)
            tail = (st["tr_head"] + st["tr_cnt"]) % cfg.transit_cap
            st["tr_pay"] = jnp.where(ok, st["tr_pay"].at[tail].set(pay), st["tr_pay"])
            st["tr_dst"] = jnp.where(ok, st["tr_dst"].at[tail].set(dst), st["tr_dst"])
            st["tr_port"] = jnp.where(ok, st["tr_port"].at[tail].set(prt), st["tr_port"])
            st["tr_cnt"] = st["tr_cnt"] + jnp.where(ok, 1, 0)
            st["overflow"] = st["overflow"] + jnp.where(
                jnp.logical_and(fwd, ~room), 1, 0
            )
        return st

    st = lax.fori_loop(0, n_steps, step, init())
    return st["out_pay"], st["out_cnt"], st["overflow"], st["t_done"]
