"""Communicators (paper §3.1: ranks, ports, communicators).

A :class:`Communicator` binds SMI rank semantics to JAX mesh axes:

* its *ranks* are the devices along one or more named mesh axes, linearised
  row-major (matching ``lax.axis_index((ax0, ax1, ...))``),
* its *topology* is the logical connection graph handed to the route
  generator (defaults to the torus implied by the axis sizes — the physical
  ICI fabric),
* *ports* provide independent parallel streams, exactly as the paper's
  hardware port endpoints; a :class:`PortAllocator` enforces the paper's
  compile-time-known-ports rule.

All collective / streaming functions in ``core`` take a communicator and must
be called inside ``jax.shard_map`` over (at least) the communicator's axes.
"""

from __future__ import annotations

import weakref
from dataclasses import dataclass, field, replace

from jax import lax

from .routing import RouteTable, compute_route_table
from .topology import Topology


@dataclass(frozen=True)
class Communicator:
    """SMI_Comm: a set of ranks over mesh axes with a routed topology.

    ``transport`` names the message-moving backend (see
    :mod:`repro.transport`) every collective over this communicator uses by
    default; a per-call ``transport=`` keyword overrides it.
    """

    axis_names: tuple[str, ...]
    axis_sizes: tuple[int, ...]
    topology: Topology
    route_table: RouteTable
    name: str = "world"
    transport: str = "static"

    # -- construction ------------------------------------------------------

    @staticmethod
    def create(
        axis_names,
        axis_sizes,
        topology: Topology | None = None,
        routing_scheme: str = "auto",
        name: str = "world",
        transport: str = "static",
    ) -> "Communicator":
        if isinstance(axis_names, str):
            axis_names = (axis_names,)
        axis_names = tuple(axis_names)
        axis_sizes = tuple(int(s) for s in axis_sizes)
        n = 1
        for s in axis_sizes:
            n *= s
        if topology is None:
            topology = Topology.torus(axis_sizes)
        assert topology.n_ranks == n, (
            f"topology has {topology.n_ranks} ranks but axes {axis_names} give {n}"
        )
        rt = compute_route_table(topology, scheme=routing_scheme)
        return Communicator(
            axis_names, axis_sizes, topology, rt, name=name, transport=transport
        )

    def with_topology(self, topology: Topology, routing_scheme: str = "auto") -> "Communicator":
        """Re-route over a new logical topology *without* changing the program
        structure — the paper's 'recompute routes, keep the bitstream'."""
        rt = compute_route_table(topology, scheme=routing_scheme)
        return replace(self, topology=topology, route_table=rt)

    def with_transport(self, transport: str) -> "Communicator":
        """Same ranks/routes, different message-moving backend."""
        return replace(self, transport=transport)

    def plan(self, op: str, nbytes: int):
        """The netsim autotuner's decision for ``op`` at ``nbytes`` on this
        communicator's topology (cached per topology signature).  This is
        what the ``bcast``/``reduce``/``allreduce`` dispatchers,
        ``stream_p2p(plan="auto")`` and the apps layer's halo exchange
        (``op="halo"``, ``nbytes`` = one slab) consult by default."""
        from ..netsim.tune import tuned_plan

        return tuned_plan(op, self, nbytes)

    # -- rank queries (trace-time inside shard_map) --------------------------

    @property
    def size(self) -> int:
        return self.topology.n_ranks

    @property
    def axis(self):
        """Axis-name argument for lax collectives: str for 1 axis, tuple else."""
        return self.axis_names[0] if len(self.axis_names) == 1 else self.axis_names

    def rank(self):
        """Traced linearised rank of the executing device (SMI_Comm_rank)."""
        return lax.axis_index(self.axis_names)

    # ring helpers over the linearised rank order -----------------------------

    def ring_perm(self, step: int = 1) -> list[tuple[int, int]]:
        """Ring permutation (+step along linearised ranks, wrap-around)."""
        n = self.size
        return [(i, (i + step) % n) for i in range(n)]

    def path_perm(self, path: list[int]) -> list[tuple[int, int]]:
        """Pipeline permutation along a routed path (each hop advances)."""
        return list(zip(path[:-1], path[1:]))


@dataclass
class PortAllocator:
    """Ports must be known at compile time (paper §2.2); this allocator hands
    out unique port ids per communicator and raises on reuse, which is the
    software analogue of two kernels contending for one hardware FIFO.

    ``repro.channels.open_channel`` enforces this at open time through the
    package-level default allocator (``repro.channels.PORTS``): opening a
    channel claims its port, closing the channel (or leaving its ``with``
    scope) releases it.  A claim may carry an *owner* — the opening
    :class:`~repro.channels.ChannelSpec` — held by weak reference: when
    every channel of a spec is garbage-collected (the trace that opened it
    is gone), the claim lapses and the port becomes reclaimable, so
    re-traced functions that open channels without closing them do not
    poison the allocator.  Ownerless claims (the bare ``claim(comm, port)``
    form) persist until released, as before.

    A *persistent* claim (``claim(..., persistent=True)`` — the
    ``ChannelSpec(persistent=True)`` lifecycle) holds its owner by strong
    reference instead: the claim survives trace exits and garbage
    collection of every channel that used it, and is released only by an
    explicit owner release (channel/pool close, engine shutdown) or
    ``release_all``.  This is the serving-engine lifecycle — one port
    endpoint reused across millions of decode steps.

    Claims are keyed per communicator *instance*: two distinct
    communicators may both use port 0 — they are different route fabrics —
    but one communicator's port 0 is a single hardware endpoint.
    """

    #: id(comm) -> {port: owner weakref (transient) | owner object
    #: (persistent) | None (ownerless / permanent)}
    used: dict[int, dict] = field(default_factory=dict)
    #: id(comm) -> [weakref to anonymous (port=None) channel specs]; no
    #: claim is held, but :meth:`claims` reports them so diagnostics can
    #: see anonymous channels at all (they lapse with their spec)
    anonymous: dict[int, list] = field(default_factory=dict)

    def _ports(self, comm: Communicator) -> dict:
        key = id(comm)
        if key not in self.used:
            self.used[key] = {}
            # drop the bucket when the communicator itself is collected
            weakref.finalize(comm, self.used.pop, key, None)
        return self.used[key]

    @staticmethod
    def _owner_of(entry):
        """(live, owner) of a claim entry: ownerless entries are live with
        no owner; weakref entries are live while the referent is; strong
        (persistent) entries are always live."""
        if entry is None:
            return True, None
        if isinstance(entry, weakref.ref):
            cur = entry()
            return cur is not None, cur
        return True, entry

    def claim(self, comm: Communicator, port: int, owner=None,
              persistent: bool = False) -> int:
        ports = self._ports(comm)
        if port in ports:
            live, _ = self._owner_of(ports[port])
            if live:
                raise ValueError(
                    f"port {port} already claimed on communicator "
                    f"{comm.name!r}; SMI ports identify distinct hardware "
                    "endpoints and cannot be shared — close the other "
                    "channel (or pick another port) first"
                )
        if owner is None:
            ports[port] = None
        else:
            ports[port] = owner if persistent else weakref.ref(owner)
        return port

    def release(self, comm: Communicator, port: int, owner=None) -> None:
        """Release ``port`` — only the claim ``owner`` holds (or any claim
        when ``owner`` is None and the claim is ownerless/dead).  A stale
        release — a double ``close()`` racing a re-claimed port — must not
        silently free another live channel's claim."""
        ports = self.used.get(id(comm), {})
        if port not in ports:
            return
        entry = ports[port]
        _, cur = self._owner_of(entry)
        if owner is not None:
            if entry is None or (cur is not None and cur is not owner):
                return  # ownerless or another live owner holds the port now
        elif cur is not None:
            return  # bare release frees only ownerless/dead claims
        ports.pop(port, None)

    def release_all(self, comm: Communicator) -> None:
        self.used.pop(id(comm), None)

    def in_use(self, comm: Communicator) -> tuple[int, ...]:
        """Ports currently claimed (live owners / ownerless) on ``comm``."""
        ports = self.used.get(id(comm), {})
        return tuple(
            sorted(p for p, entry in ports.items()
                   if self._owner_of(entry)[0])
        )

    def note_anonymous(self, comm: Communicator, owner) -> None:
        """Register an anonymous (``port=None``) channel owner, weakly.

        Anonymous channels hold no claim — nothing to collide with — but
        were invisible to every diagnostic surface; :meth:`claims` lists
        them while their owning spec is alive."""
        key = id(comm)
        refs = self.anonymous.get(key)
        if refs is None:
            refs = self.anonymous[key] = []
            weakref.finalize(comm, self.anonymous.pop, key, None)
        refs[:] = [r for r in refs if r() is not None]  # prune the dead
        refs.append(weakref.ref(owner))

    def claims(self, comm: Communicator) -> tuple[dict, ...]:
        """Structured snapshot of every live claim on ``comm`` — what the
        smilint capture verifier and the pool introspection read.

        One row per live claim, port-ordered, plus one trailing row per
        live anonymous (``port=None``) channel:
        ``{"port", "persistent", "anonymous", "tag", "kind", "owner"}``
        (``tag``/``kind`` come off the owning ChannelSpec when there is
        one; ownerless ``claim(comm, port)`` rows carry ``owner=None``)."""

        def row(port, entry_persistent, anonymous, owner):
            return {
                "port": port,
                "persistent": entry_persistent,
                "anonymous": anonymous,
                "tag": getattr(owner, "stats_tag",
                               getattr(owner, "tag", None)),
                "kind": getattr(owner, "kind", None),
                "owner": owner,
            }

        rows = []
        for port, entry in sorted(self.used.get(id(comm), {}).items()):
            live, owner = self._owner_of(entry)
            if not live:
                continue
            persistent = entry is not None and \
                not isinstance(entry, weakref.ref)
            rows.append(row(port, persistent, False, owner))
        for ref in self.anonymous.get(id(comm), []):
            owner = ref()
            if owner is not None:
                rows.append(row(None, False, True, owner))
        return tuple(rows)
