"""Collective–compute overlap (the paper's core idea, applied to TPU GEMMs).

SMI's streaming messages exist so that communication happens *during*
pipelined computation rather than before/after it.  On a TPU the pipelined
computation is a GEMM/attention on MXU tiles, so the faithful adaptation is
the *collective matmul* family: each ring step's ppermute is interleaved with
the per-chunk compute, so the ICI transfer of chunk i+1 overlaps the MXU work
on chunk i.  (XLA can then software-pipeline the loop; on real TPUs the
async collective-permute start/done pair brackets the GEMM.)

These are the building blocks the model layers use in ``comm_mode="smi"``:

* :func:`stream_allgather_matmul`   — column-parallel linear after sequence
  sharding: ``AG(x) @ W`` with the AG streamed through the GEMM.
* :func:`stream_matmul_reducescatter` — row-parallel linear:
  ``RS(x @ W)`` with each row-block's partial GEMM computed just-in-time.
* :func:`stream_ring_attention`     — ring attention: K/V blocks stream
  around the ring while flash-style online-softmax accumulation runs.
* :func:`halo_exchange_2d`          — the paper's stencil halo pattern.

``matmul`` is injectable so the Pallas MXU kernel (kernels/matmul) replaces
``jnp.dot`` on TPU; the default keeps everything traceable on CPU.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from .comm import Communicator
from .collectives import _resolve, stream_reduce_scatter
from .streaming import _pvary


def _default_mm(a, b):
    return jnp.dot(a, b, preferred_element_type=jnp.float32).astype(a.dtype)


def stream_allgather_matmul(
    x: jax.Array,
    w: jax.Array,
    comm: Communicator,
    *,
    matmul: Callable | None = None,
    bidir: bool = False,
    return_gathered: bool = False,
    transport=None,
):
    """``concat_p(x) @ w`` with the all-gather streamed through the GEMM.

    x: (m, K) — this rank's row block (e.g. its sequence shard).
    w: (K, N) — resident weight (a column shard of the global weight).
    returns (P*m, N): full rows, local columns.

    Per ring step: ppermute the next row block while the MXU multiplies the
    block that just arrived — communication during computation.

    ``return_gathered``: every shard passes through this device anyway, so
    the full gathered input can be emitted for FREE (zero extra wire bytes)
    — downstream same-input projections (KV, MLP-up, SSM gates) then run as
    local GEMMs instead of paying their own ring (beyond-paper
    shared-gather optimisation; see EXPERIMENTS.md §Perf).
    """
    mm = matmul or _default_mm
    P = comm.size
    r = comm.rank()
    t = _resolve(transport, comm)
    m = x.shape[0]
    out = jnp.zeros((P, m, w.shape[1]), x.dtype)
    out = lax.dynamic_update_index_in_dim(out, mm(x, w), r, 0)
    gat = None
    if return_gathered:
        gat = jnp.zeros((P, m, x.shape[1]), x.dtype)
        gat = lax.dynamic_update_index_in_dim(gat, x, r, 0)
    if P == 1:
        y = out.reshape(P * m, w.shape[1])
        return (y, gat.reshape(P * m, -1)) if return_gathered else y
    if not bidir:
        buf = x
        for s in range(1, P):
            buf = t.shift(buf, comm, +1)  # originated at rank r - s
            out = lax.dynamic_update_index_in_dim(out, mm(buf, w), (r - s) % P, 0)
            if return_gathered:
                gat = lax.dynamic_update_index_in_dim(gat, buf, (r - s) % P, 0)
    else:
        up = x
        down = x
        n_up = P // 2
        n_down = (P - 1) // 2
        for s in range(1, n_up + 1):
            up = t.shift(up, comm, +1)
            out = lax.dynamic_update_index_in_dim(out, mm(up, w), (r - s) % P, 0)
            if return_gathered:
                gat = lax.dynamic_update_index_in_dim(gat, up, (r - s) % P, 0)
            if s <= n_down:
                down = t.shift(down, comm, -1)
                out = lax.dynamic_update_index_in_dim(out, mm(down, w), (r + s) % P, 0)
                if return_gathered:
                    gat = lax.dynamic_update_index_in_dim(gat, down, (r + s) % P, 0)
    y = out.reshape(P * m, w.shape[1])
    if return_gathered:
        return y, gat.reshape(P * m, x.shape[1])
    return y


def stream_matmul_reducescatter(
    x: jax.Array,
    w: jax.Array,
    comm: Communicator,
    *,
    matmul: Callable | None = None,
    transport=None,
):
    """``reduce_scatter(x @ w)`` with per-block partial GEMMs just-in-time.

    x: (P*m, K_local) — full rows, contraction-sharded columns.
    w: (K_local, N)   — the matching row shard of the global weight.
    returns (m, N): this rank's fully-reduced row block.
    """
    mm = matmul or _default_mm
    P = comm.size
    m = x.shape[0] // P

    def compute_chunk(i):
        rows = lax.dynamic_slice_in_dim(x, i * m, m, axis=0)
        return mm(rows, w)

    return stream_reduce_scatter(None, comm, compute_chunk=compute_chunk, transport=transport)


# ---------------------------------------------------------------------------
# Ring attention (sequence-parallel prefill)
# ---------------------------------------------------------------------------


def stream_ring_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    comm: Communicator,
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    local_window: int | None = None,
    transport=None,
):
    """Ring attention: K/V blocks stream around the ring during flash-style
    online-softmax accumulation (SMI streaming applied to attention).

    q: (B, Sq, H, D) — this rank's query block (global position r*Sq..).
    k, v: (B, Skv, Hkv, D) — this rank's K/V block; Hkv may divide H (GQA).
    returns (B, Sq, H, D).

    ``local_window`` (tokens) implements sliding-window attention
    (RecurrentGemma): blocks wholly outside the window are masked (the
    ppermute still runs — uniform SPMD schedule).
    """
    P = comm.size
    r = comm.rank()
    t = _resolve(transport, comm)
    B, Sq, H, D = q.shape
    Hkv = k.shape[2]
    g = H // Hkv
    scale = sm_scale if sm_scale is not None else D ** -0.5

    from ..kernels.common import match_vma

    qf = q.astype(jnp.float32) * scale
    # accumulators (vma matched to the inputs: they are scan carries fed by
    # ppermute'd KV blocks)
    m_i = match_vma(jnp.full((B, H, Sq), -1e30, jnp.float32), q)
    l_i = match_vma(jnp.zeros((B, H, Sq), jnp.float32), q)
    acc = match_vma(jnp.zeros((B, H, Sq, D), jnp.float32), q)

    q_pos = r * Sq + jnp.arange(Sq)  # (Sq,)
    blk = min(512, k.shape[1])       # inner flash block (VMEM-sized on TPU)

    def block_update(carry, kv, owner):
        """Online-softmax update for one arriving KV ring block, processed
        in flash-sized chunks (lax.scan) so peak live scores stay
        O(Sq x blk) — identical blocking to the baseline attention path."""
        kb, vb = kv
        Skv = kb.shape[1]
        nkb = Skv // blk
        kc = kb.reshape(B, nkb, blk, Hkv, D).transpose(1, 0, 2, 3, 4)
        vc = vb.reshape(B, nkb, blk, Hkv, D).transpose(1, 0, 2, 3, 4)

        def inner(c, xs):
            m_i, l_i, acc = c
            kcb, vcb, j = xs
            kv_pos = owner * Skv + j * blk + jnp.arange(blk)
            kbe = jnp.repeat(kcb.astype(jnp.float32), g, axis=2)
            vbe = jnp.repeat(vcb.astype(jnp.float32), g, axis=2)
            s = jnp.einsum("bqhd,bkhd->bhqk", qf, kbe)
            mask = jnp.ones((Sq, blk), bool)
            if causal:
                mask = q_pos[:, None] >= kv_pos[None, :]
            if local_window is not None:
                mask = jnp.logical_and(
                    mask, q_pos[:, None] - kv_pos[None, :] < local_window
                )
            s = jnp.where(mask[None, None], s, -1e30)
            m_new = jnp.maximum(m_i, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            p = jnp.where(mask[None, None], p, 0.0)
            corr = jnp.exp(m_i - m_new)
            l_new = l_i * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum("bhqk,bkhd->bhqd", p, vbe)
            return (m_new, l_new, acc_new), None

        c, _ = jax.lax.scan(inner, carry, (kc, vc, jnp.arange(nkb)))
        return c

    carry = block_update((m_i, l_i, acc), (k, v), r)
    kv = (k, v)
    for s_ in range(1, P):
        kv = t.shift(kv, comm, +1)
        owner = (r - s_) % P
        carry = block_update(carry, kv, owner)
    m_i, l_i, acc = carry
    l_safe = jnp.maximum(l_i, 1e-30)
    out = (acc / l_safe[..., None]).transpose(0, 2, 1, 3)  # (B, Sq, H, D)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Halo exchange (the paper's stencil application, §5.4.2)
# ---------------------------------------------------------------------------


# (src, dst) pairs shifting the row-major rank grid by (drx, dry) — the
# fixed neighbour wiring of one halo direction (no wrap: channels to absent
# neighbours "simply remain unused").  The single implementation lives in
# jax-free netsim.schedule so the simulator and the traced schedule can
# never disagree on the wiring.
from ..netsim.schedule import halo_pairs as halo_perm  # noqa: E402


def halo_exchange_2d_start(
    x: jax.Array,
    comm: Communicator,
    *,
    grid: tuple[int, int],
    halo: tuple[int, int] = (1, 1),
    transport=None,
    tag: str = "halo",
):
    """Launch the four neighbour permutes of a 2D halo exchange and return
    the in-flight halo slabs — the *send edge* of the overlap window.

    Issuing the permutes before any dependent compute is traced is what
    lets XLA overlap the ICI transfers with the interior update that the
    ``repro/apps`` stencil runs between :func:`halo_exchange_2d_start` and
    :func:`halo_exchange_2d_finish` (the paper's pipelined halo pattern).
    Steps are accounted under ``tag`` so halo wire traffic stays separable
    from any collectives sharing the backend instance.
    """
    RX, RY = grid
    hx, hy = halo
    assert comm.size == RX * RY
    t = _resolve(transport, comm)

    with t.tagged(tag):
        def shift(buf, drx, dry):
            pairs = halo_perm(grid, drx, dry)
            if not pairs:
                # a 1-row/1-column grid has no neighbours this direction:
                # no wire step at all (and none accounted) — the paper's
                # unused channels; every rank's halo is the bubble value
                return _pvary(jnp.zeros_like(buf), comm)
            return t.permute(buf, comm, pairs)

        # x[:hx] are my north boundary rows; the north neighbour (rx-1)
        # needs them as its south halo.  Receiving side of the same permute:
        # the slab from (rx+1) is my south halo — and so on per direction.
        south_halo = shift(x[:hx], -1, 0)   # from rx+1: their north rows
        north_halo = shift(x[-hx:], +1, 0)  # from rx-1: their south rows
        east_halo = shift(x[:, :hy], 0, -1)  # from ry+1: their west cols
        west_halo = shift(x[:, -hy:], 0, +1)  # from ry-1: their east cols
    return south_halo, north_halo, east_halo, west_halo


def halo_exchange_2d_finish(
    x: jax.Array,
    inflight,
    comm: Communicator,
    *,
    grid: tuple[int, int],
    halo: tuple[int, int] = (1, 1),
):
    """Assemble the padded tile from ``x`` and the slabs returned by
    :func:`halo_exchange_2d_start` — the *receive edge* of the overlap
    window.  Physical-boundary halos are zeroed (Dirichlet)."""
    RX, RY = grid
    hx, hy = halo
    south_halo, north_halo, east_halo, west_halo = inflight
    r = comm.rank()
    rx, ry = r // RY, r % RY
    Nx, Ny = x.shape[0], x.shape[1]
    out = jnp.zeros((Nx + 2 * hx, Ny + 2 * hy) + x.shape[2:], x.dtype)
    out = out.at[hx:-hx, hy:-hy].set(x)
    out = out.at[:hx, hy:-hy].set(jnp.where(rx > 0, north_halo, 0))
    out = out.at[-hx:, hy:-hy].set(jnp.where(rx < RX - 1, south_halo, 0))
    out = out.at[hx:-hx, :hy].set(jnp.where(ry > 0, west_halo, 0))
    out = out.at[hx:-hx, -hy:].set(jnp.where(ry < RY - 1, east_halo, 0))
    return out


def halo_exchange_2d(
    x: jax.Array,
    comm: Communicator,
    *,
    grid: tuple[int, int],
    halo: tuple[int, int] = (1, 1),
    transport=None,
):
    """Exchange N/S/E/W halo slabs of a 2D-decomposed domain (paper Fig. 14).

    x: (Nx_local, Ny_local, ...) local tile of the global domain; ranks are
    laid out row-major on ``grid`` = (RX, RY) over the communicator.  Returns
    the tile padded with received halos (zero at physical boundaries).

    This is the non-overlapped composition; the ``repro/apps`` stencil uses
    the start/finish split to hide the exchange behind interior compute.
    """
    inflight = halo_exchange_2d_start(
        x, comm, grid=grid, halo=halo, transport=transport
    )
    return halo_exchange_2d_finish(x, inflight, comm, grid=grid, halo=halo)
