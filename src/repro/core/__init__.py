"""SMI core: streaming messages for JAX meshes.

The paper's primary contribution — transient channels, a routed transport
layer with runtime-uploadable tables, and streamed collectives — rendered as
static ppermute schedules (fast path) plus a dynamic packet router
(flexibility path) for TPU pods.  See DESIGN.md §2 for the adaptation map.
"""

from .comm import Communicator, PortAllocator
from .topology import Topology
from .routing import (
    RouteTable,
    compute_route_table,
    channel_dependency_acyclic,
    physical_link_map,
)
from .streaming import (
    stream_p2p,
    stream_exchange,
    run_spmd,
    make_test_mesh,
    pvary,
)

#: channel API names served lazily from repro.channels (PEP 562): the
#: channels package imports core.comm, so an eager import here would cycle
_CHANNEL_EXPORTS = (
    "Channel",
    "ChannelSpec",
    "open_channel",
    "push",
    "pop",
    "channel_transfer",
    "open_bcast_channel",
    "open_reduce_channel",
    "open_scatter_channel",
    "open_gather_channel",
    "open_allreduce_channel",
)


def __getattr__(name):
    if name in _CHANNEL_EXPORTS:
        from .. import channels

        return getattr(channels, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
from .collectives import (
    allreduce,
    bcast,
    reduce,
    stream_allgather,
    stream_reduce_scatter,
    stream_allreduce,
    stream_alltoall,
    stream_bcast,
    stream_reduce,
    stream_gather,
    stream_scatter,
    tree_bcast,
    tree_reduce,
    staged_bcast,
    staged_reduce,
    make_int8_codec,
)

__all__ = [
    "Communicator",
    "PortAllocator",
    "Topology",
    "RouteTable",
    "compute_route_table",
    "channel_dependency_acyclic",
    "physical_link_map",
    "Channel",
    "ChannelSpec",
    "open_channel",
    "push",
    "pop",
    "channel_transfer",
    "open_bcast_channel",
    "open_reduce_channel",
    "open_scatter_channel",
    "open_gather_channel",
    "open_allreduce_channel",
    "stream_p2p",
    "stream_exchange",
    "run_spmd",
    "make_test_mesh",
    "pvary",
    "allreduce",
    "bcast",
    "reduce",
    "stream_allgather",
    "stream_reduce_scatter",
    "stream_allreduce",
    "stream_alltoall",
    "stream_bcast",
    "stream_reduce",
    "stream_gather",
    "stream_scatter",
    "tree_bcast",
    "tree_reduce",
    "staged_bcast",
    "staged_reduce",
    "make_int8_codec",
]
