"""Logical interconnect topologies (paper §4.3 / §5.1).

The paper describes FPGA clusters whose QSFP ports are wired point-to-point
(8 FPGAs in a 2D torus for the evaluation; a linear bus variant is obtained by
*reconfiguring the routing tables only*).  Here a :class:`Topology` is the
logical connection graph used by the route generator.  On TPU the physical
links are the ICI torus implied by the mesh axes; logical topologies must be
embeddable in it (every logical edge maps to a physical neighbour hop), which
mirrors the paper's constraint that logical connections are realised by
physical QSFP wiring.
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Sequence


@dataclass(frozen=True)
class Topology:
    """An undirected connection graph over ``n_ranks`` endpoints.

    ``links[r]`` is the ordered tuple of neighbours of rank ``r`` — the order
    is meaningful: position ``i`` is rank ``r``'s *port i* (the paper's QSFP
    port index), used by the routing tables to name output links.
    """

    n_ranks: int
    links: tuple[tuple[int, ...], ...]
    name: str = "custom"
    dims: tuple[int, ...] | None = None  # set for tori; enables DOR routing

    def __post_init__(self):
        assert len(self.links) == self.n_ranks, "links must cover every rank"
        for r, nbrs in enumerate(self.links):
            for n in nbrs:
                assert 0 <= n < self.n_ranks, f"bad neighbour {n} of {r}"
                assert n != r, f"self-link at {r}"
                assert r in self.links[n], f"link {r}->{n} not symmetric"

    # -- constructors -----------------------------------------------------

    @staticmethod
    def torus(dims: Sequence[int]) -> "Topology":
        """K-ary n-cube.  Rank layout is row-major (last dim fastest), which
        matches ``lax.axis_index((ax0, ax1, ...))`` flattening order."""
        dims = tuple(int(d) for d in dims)
        n = 1
        for d in dims:
            n *= d
        strides = []
        s = 1
        for d in reversed(dims):
            strides.append(s)
            s *= d
        strides = list(reversed(strides))

        def coords(r):
            return tuple((r // strides[i]) % dims[i] for i in range(len(dims)))

        def rank_of(c):
            return sum(ci * strides[i] for i, ci in enumerate(c))

        links = []
        for r in range(n):
            c = coords(r)
            nbrs = []
            for i, d in enumerate(dims):
                if d == 1:
                    continue
                for step in (+1, -1):
                    cc = list(c)
                    cc[i] = (cc[i] + step) % d
                    nb = rank_of(tuple(cc))
                    if nb != r and nb not in nbrs:
                        nbrs.append(nb)
            links.append(tuple(nbrs))
        return Topology(n, tuple(links), name=f"torus{dims}", dims=dims)

    @staticmethod
    def ring(n: int) -> "Topology":
        return Topology.torus((n,))._replace_name(f"ring{n}")

    @staticmethod
    def bus(n: int) -> "Topology":
        """Linear bus (no wrap-around) — the paper's reduced-connectivity
        benchmark topology."""
        links = []
        for r in range(n):
            nbrs = []
            if r + 1 < n:
                nbrs.append(r + 1)
            if r - 1 >= 0:
                nbrs.append(r - 1)
            links.append(tuple(nbrs))
        return Topology(n, tuple(links), name=f"bus{n}")

    @staticmethod
    def from_edges(n: int, edges: Sequence[tuple[int, int]], name="custom") -> "Topology":
        nbrs: list[list[int]] = [[] for _ in range(n)]
        for a, b in edges:
            if b not in nbrs[a]:
                nbrs[a].append(b)
            if a not in nbrs[b]:
                nbrs[b].append(a)
        return Topology(n, tuple(tuple(x) for x in nbrs), name=name)

    @staticmethod
    def from_json(path_or_str: str) -> "Topology":
        """The paper's route generator consumes a JSON topology description;
        we accept ``{"n_ranks": N, "edges": [[a, b], ...], "name": ...}``."""
        try:
            spec = json.loads(path_or_str)
        except json.JSONDecodeError:
            with open(path_or_str) as f:
                spec = json.load(f)
        return Topology.from_edges(
            int(spec["n_ranks"]),
            [tuple(e) for e in spec["edges"]],
            name=spec.get("name", "json"),
        )

    def to_json(self) -> str:
        edges = sorted({(min(a, b), max(a, b)) for a in range(self.n_ranks) for b in self.links[a]})
        return json.dumps(
            {"n_ranks": self.n_ranks, "edges": [list(e) for e in edges], "name": self.name}
        )

    # -- queries ----------------------------------------------------------

    def _replace_name(self, name: str) -> "Topology":
        return Topology(self.n_ranks, self.links, name=name, dims=self.dims)

    def neighbors(self, r: int) -> tuple[int, ...]:
        return self.links[r]

    def port_of(self, r: int, neighbor: int) -> int:
        """Output-link ("QSFP port") index of the edge r -> neighbor."""
        return self.links[r].index(neighbor)

    def degree(self, r: int) -> int:
        return len(self.links[r])

    def is_connected(self) -> bool:
        if self.n_ranks == 0:
            return True
        seen = {0}
        stack = [0]
        while stack:
            r = stack.pop()
            for n in self.links[r]:
                if n not in seen:
                    seen.add(n)
                    stack.append(n)
        return len(seen) == self.n_ranks

    def diameter(self) -> int:
        from .routing import bfs_dists

        return max(int(bfs_dists(self, s).max()) for s in range(self.n_ranks))
