"""Transient channels and the chunk-pipelined point-to-point engine (paper §3.1).

The paper's key primitive is the *transient channel*: open(count, dtype, peer,
port, comm) then Push/Pop one element per clock cycle inside the pipelined
loop, with the transport layer forwarding packets hop-by-hop.

TPU adaptation (see DESIGN.md §2): the streaming unit is a *chunk* (a
hardware-tile-aligned slab) instead of a 28-byte packet payload, and one
"clock cycle" is one step of a static ppermute schedule.  Two API levels:

* :func:`stream_p2p` — transfer-level: a whole message streamed through the
  routed multi-hop pipeline, ``n_chunks`` in flight; this is what the
  collectives and the overlap engine build on.  Bandwidth is
  hop-independent (pipelining), latency grows linearly with hops — the
  paper's Fig. 9 / Tab. 3 behaviour by construction.
* :class:`Channel` with :func:`push` / :func:`pop` — element-level, faithful
  to Listing 1 of the paper: ``push`` stages an element into the pipe
  (masked to the source rank), ``pop`` advances the global pipeline by one
  hop-step and extracts at the destination.  Under SPMD both calls appear in
  every rank's trace; masks select the active role, which is the JAX
  rendering of the paper's MPMD ranks.

Everything here must execute *inside* ``jax.shard_map`` spanning the
communicator's mesh axes.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax

from ..compat import make_mesh as _compat_make_mesh
from ..compat import pvary_missing
from ..compat import shard_map as _compat_shard_map
from .comm import Communicator


def _mask_sel(pred, a, b):
    """where() with scalar pred broadcast over pytrees of equal shape."""
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def _pvary(x, comm: "Communicator"):
    """Mark freshly-created constants as device-varying over the comm axes.

    shard_map's varying-manual-axes type system requires loop carries that
    flow through ppermute to be 'varying'; zeros created inside the region
    start out 'invariant'.  (jax >= 0.8 VMA typing; identity on pre-VMA
    runtimes via the compat layer.)"""
    names = tuple(comm.axis_names)
    return jax.tree.map(lambda v: pvary_missing(v, names), x)


pvary = _pvary  # public: mark user loop-carry state varying over comm axes


# ---------------------------------------------------------------------------
# Transfer-level streaming p2p
# ---------------------------------------------------------------------------


def stream_p2p(
    x: jax.Array,
    *,
    src: int,
    dst: int,
    comm: Communicator,
    n_chunks: int = 1,
    transport=None,
    plan=None,
) -> jax.Array:
    """Stream ``x`` (resident on ``src``) to ``dst`` along the routed path.

    Every rank passes a same-shaped ``x`` (SPMD); only the source's content
    is transmitted.  Returns a buffer that equals ``x``@src on ``dst`` and is
    zeros elsewhere.  Dispatches to the selected transport backend: the
    static/fused backends run the chunk-pipelined multi-hop ppermute
    schedule (``n_chunks`` chunks in flight, the asynchronicity degree k of
    §3.3); the packet backend stages the message into the dynamic router.

    ``plan="auto"`` (or an explicit :class:`repro.netsim.tune.Plan`) lets
    the netsim tuning table choose the backend and chunk count for this
    topology and message size; explicit ``transport``/``n_chunks`` keep
    their meaning when no plan is given.
    """
    from ..transport.registry import resolve_transport

    if plan is not None:
        from ..netsim.tune import Plan

        if not isinstance(plan, Plan):
            assert plan == "auto", (
                f"plan must be 'auto', None or a Plan; got {plan!r}"
            )
            nbytes = x.size * x.dtype.itemsize
            plan = comm.plan("p2p", int(nbytes))
        if plan.wire != "raw" and not jnp.issubdtype(x.dtype, jnp.floating):
            # integer payloads must move exactly: same plan, raw wire
            import dataclasses

            plan = dataclasses.replace(plan, wire="raw")
        if transport is None:
            transport = plan.transport_key
        n_chunks = plan.clamp_chunks(x.shape[0])

    return resolve_transport(transport, comm).p2p(
        x, src=src, dst=dst, comm=comm, n_chunks=n_chunks
    )


def stream_exchange(
    x: jax.Array,
    *,
    pairs: list[tuple[int, int]],
    comm: Communicator,
    transport=None,
    tag: str | None = None,
) -> jax.Array:
    """Single-hop bulk exchange over explicit (src, dst) pairs — the
    "fixed wiring" streaming model of paper Fig. 3, for benchmarks and halo
    exchanges between mesh neighbours (one physical link per pair).

    ``tag`` buckets the step's wire accounting under a message tag
    (:meth:`repro.transport.base.Transport.tagged`), so application phases
    sharing a backend instance keep separable cost counters."""
    from ..transport.registry import resolve_transport

    t = resolve_transport(transport, comm)
    if tag is None:
        return t.permute(x, comm, pairs)
    with t.tagged(tag):
        return t.permute(x, comm, pairs)


# ---------------------------------------------------------------------------
# Element-level transient channels (paper Listing 1)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ChannelSpec:
    """Static descriptor: SMI_Open_*_channel arguments."""

    count: int
    src: int
    dst: int
    port: int
    comm: Communicator

    @property
    def path(self) -> list[int]:
        return self.comm.route_table.path(self.src, self.dst)

    @property
    def hops(self) -> int:
        return len(self.path) - 1


@jax.tree_util.register_pytree_node_class
@dataclass
class Channel:
    """Traced channel state: a 1-deep pipe register per rank on the route.

    ``pushed``/``popped`` count progress; ``pipe`` holds the in-flight element
    at this rank; ``valid`` tags pipeline bubbles.  The spec (static) rides in
    the pytree aux data, so channels can be loop carries.
    """

    spec: ChannelSpec
    pipe: jax.Array
    valid: jax.Array  # bool scalar: pipe holds a live element
    pushed: jax.Array  # i32 scalar
    popped: jax.Array  # i32 scalar

    def tree_flatten(self):
        return (self.pipe, self.valid, self.pushed, self.popped), self.spec

    @classmethod
    def tree_unflatten(cls, spec, leaves):
        return cls(spec, *leaves)


def open_channel(
    comm: Communicator,
    *,
    count: int,
    src: int,
    dst: int,
    port: int = 0,
    elem_shape=(),
    dtype=jnp.float32,
) -> Channel:
    """SMI_Open_send_channel / SMI_Open_recv_channel.

    Opening is a zero-cost operation (paper §3.3 eager protocol): it only
    creates the descriptor and a zeroed pipe register; no communication
    happens until elements flow.
    """
    spec = ChannelSpec(count=count, src=src, dst=dst, port=port, comm=comm)
    return Channel(
        spec=spec,
        pipe=_pvary(jnp.zeros(elem_shape, dtype), comm),
        valid=_pvary(jnp.zeros((), jnp.bool_), comm),
        pushed=_pvary(jnp.zeros((), jnp.int32), comm),
        popped=_pvary(jnp.zeros((), jnp.int32), comm),
    )


def push(chan: Channel, elem: jax.Array) -> Channel:
    """SMI_Push: stage ``elem`` into the pipe at the source rank.

    Non-blocking in trace terms; the element starts moving on the next
    :func:`pop` (the schedule's pipeline advance).  Pipelines to one advance
    per loop iteration — the ii=1 requirement of §3.1.1.
    """
    r = chan.spec.comm.rank()
    at_src = r == chan.spec.src
    new_pipe = _mask_sel(at_src, jnp.asarray(elem, chan.pipe.dtype), chan.pipe)
    new_valid = jnp.where(at_src, True, chan.valid)
    return Channel(
        chan.spec,
        new_pipe,
        new_valid,
        chan.pushed + jnp.where(at_src, 1, 0).astype(jnp.int32),
        chan.popped,
    )


def pop(chan: Channel):
    """SMI_Pop: advance the channel pipeline one hop-step and extract.

    Returns ``(chan', value, valid)``: after ``hops`` advances the element
    pushed first arrives, so a consumer loop runs ``count + hops - 1``
    iterations and gates on ``valid`` — exactly a hardware pipeline with
    latency = network distance (paper Tab. 3).
    """
    spec = chan.spec
    r = spec.comm.rank()
    pairs = spec.comm.path_perm(spec.path)
    moved = lax.ppermute(chan.pipe, spec.comm.axis, pairs)
    moved_valid = lax.ppermute(chan.valid, spec.comm.axis, pairs)
    at_dst = r == spec.dst
    value = moved
    valid = jnp.logical_and(at_dst, moved_valid)
    new = Channel(
        spec,
        moved,
        moved_valid,
        chan.pushed,
        chan.popped + jnp.where(valid, 1, 0).astype(jnp.int32),
    )
    return new, value, valid


def channel_transfer(chan: Channel, x: jax.Array, n_chunks: int = 1) -> jax.Array:
    """Whole-message convenience: stream ``x`` over an open channel (chunked),
    equivalent to count/chunk pushes + pops.  Dispatches to the pipelined
    transfer engine."""
    return stream_p2p(
        x, src=chan.spec.src, dst=chan.spec.dst, comm=chan.spec.comm, n_chunks=n_chunks
    )


# ---------------------------------------------------------------------------
# shard_map harness helpers (used by tests/examples/benchmarks)
# ---------------------------------------------------------------------------


def run_spmd(fn, mesh, in_specs, out_specs, *args):
    """jit(shard_map(fn)) one-liner used across tests and benchmarks."""
    return jax.jit(
        _compat_shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    )(*args)


def make_test_mesh(shape, names):
    """Host-device mesh with Auto axis types (tests / benchmarks)."""
    return _compat_make_mesh(shape, names)
