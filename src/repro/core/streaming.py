"""Transient channels and the chunk-pipelined point-to-point engine (paper §3.1).

The paper's key primitive is the *transient channel*: open(count, dtype, peer,
port, comm) then Push/Pop one element per clock cycle inside the pipelined
loop, with the transport layer forwarding packets hop-by-hop.

The channel API itself lives in :mod:`repro.channels` — ``open_channel`` /
``push`` / ``pop`` / ``Channel.transfer`` plus the transient collective
channels — and is re-exported here for the historic import paths.  What
remains in this module:

* :func:`stream_p2p` — the legacy transfer-level entry point, now a thin
  shim that opens a transient (anonymous-port) p2p channel and streams the
  message through it.  Its ``transport=`` / ``plan=`` kwargs keep working
  but are deprecated: open a channel carrying the config instead
  (DESIGN.md §9 has the migration table).
* :func:`stream_exchange` — single-hop bulk exchange over explicit pairs
  (the halo-exchange wire; `repro.apps` drives it through a ChannelSpec).
* the shard_map harness helpers used across tests and benchmarks.

Everything here must execute *inside* ``jax.shard_map`` spanning the
communicator's mesh axes.
"""

from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from ..compat import make_mesh as _compat_make_mesh
from ..compat import pvary_missing
from ..compat import shard_map as _compat_shard_map
from .comm import Communicator


def _mask_sel(pred, a, b):
    """where() with scalar pred broadcast over pytrees of equal shape."""
    return jax.tree.map(lambda x, y: jnp.where(pred, x, y), a, b)


def _pvary(x, comm: "Communicator"):
    """Mark freshly-created constants as device-varying over the comm axes.

    shard_map's varying-manual-axes type system requires loop carries that
    flow through ppermute to be 'varying'; zeros created inside the region
    start out 'invariant'.  (jax >= 0.8 VMA typing; identity on pre-VMA
    runtimes via the compat layer.)"""
    names = tuple(comm.axis_names)
    return jax.tree.map(lambda v: pvary_missing(v, names), x)


pvary = _pvary  # public: mark user loop-carry state varying over comm axes


# ---------------------------------------------------------------------------
# Transfer-level streaming p2p (transient-channel shim)
# ---------------------------------------------------------------------------


def stream_p2p(
    x: jax.Array,
    *,
    src: int,
    dst: int,
    comm: Communicator,
    n_chunks: int = 1,
    transport=None,
    plan=None,
) -> jax.Array:
    """Stream ``x`` (resident on ``src``) to ``dst`` along the routed path.

    Every rank passes a same-shaped ``x`` (SPMD); only the source's content
    is transmitted.  Returns a buffer that equals ``x``@src on ``dst`` and is
    zeros elsewhere.

    This is a compatibility shim over the channel API: it opens a transient
    anonymous-port p2p channel carrying the call's config and streams the
    message with :meth:`~repro.channels.Channel.transfer` — the static/fused
    backends run the chunk-pipelined multi-hop ppermute schedule
    (``n_chunks`` chunks in flight, the asynchronicity degree k of §3.3);
    the packet backend stages the message into the dynamic router.

    ``transport=`` and ``plan=`` are deprecated here: carry them on the
    channel instead (``open_channel(comm, src=..., dst=...,
    transport=..., plan=...)``), where they configure *every* transfer and
    push/pop of the channel, not one call.
    """
    from ..channels import open_channel

    if transport is not None or plan is not None:
        warnings.warn(
            "stream_p2p(transport=..., plan=...) is deprecated; open a "
            "channel carrying the config instead: open_channel(comm, "
            "src=..., dst=..., transport=..., plan=...).transfer(x) "
            "(DESIGN.md §9)",
            DeprecationWarning,
            stacklevel=2,
        )
    ch = open_channel(
        comm, src=src, dst=dst, port=None, transport=transport, plan=plan
    )
    return ch.transfer(x, n_chunks=n_chunks)


def stream_exchange(
    x: jax.Array,
    *,
    pairs: list[tuple[int, int]],
    comm: Communicator,
    transport=None,
    tag: str | None = None,
) -> jax.Array:
    """Single-hop bulk exchange over explicit (src, dst) pairs — the
    "fixed wiring" streaming model of paper Fig. 3, for benchmarks and halo
    exchanges between mesh neighbours (one physical link per pair).

    ``tag`` buckets the step's wire accounting under a message tag
    (:meth:`repro.transport.base.Transport.tagged`), so application phases
    sharing a backend instance keep separable cost counters."""
    from ..transport.registry import resolve_transport

    t = resolve_transport(transport, comm)
    if tag is None:
        return t.permute(x, comm, pairs)
    with t.tagged(tag):
        return t.permute(x, comm, pairs)


# ---------------------------------------------------------------------------
# Element-level transient channels: re-exported from repro.channels
# ---------------------------------------------------------------------------

#: names served lazily from repro.channels (PEP 562) — a top-level import
#: here would cycle (channels -> core.comm -> core package -> this module)
_CHANNEL_EXPORTS = (
    "Channel",
    "ChannelSpec",
    "channel_transfer",
    "open_channel",
    "pop",
    "push",
)


def __getattr__(name):
    if name in _CHANNEL_EXPORTS:
        from .. import channels

        return getattr(channels, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "Channel",
    "ChannelSpec",
    "channel_transfer",
    "open_channel",
    "pop",
    "push",
    "pvary",
    "stream_exchange",
    "stream_p2p",
    "run_spmd",
    "make_test_mesh",
]


# ---------------------------------------------------------------------------
# shard_map harness helpers (used by tests/examples/benchmarks)
# ---------------------------------------------------------------------------


def run_spmd(fn, mesh, in_specs, out_specs, *args):
    """jit(shard_map(fn)) one-liner used across tests and benchmarks."""
    return jax.jit(
        _compat_shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    )(*args)


def make_test_mesh(shape, names):
    """Host-device mesh with Auto axis types (tests / benchmarks)."""
    return _compat_make_mesh(shape, names)
