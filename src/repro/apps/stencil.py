"""Distributed 2D heat-diffusion stencil with compute/communication overlap.

The paper's flagship application (§5.4.2): a 4-point stencil over a domain
sharded on a (RX, RY) rank grid, halo slabs streamed through SMI channels
every timestep.  Two step schedules, numerically identical by construction:

* :meth:`DistributedStencil.step_reference` — the non-overlapped baseline:
  the halo exchange completes, then the full sweep runs on the padded tile.
* :meth:`DistributedStencil.step_overlapped` — the pipelined schedule: the
  four neighbour permutes launch first, the *interior* update (which reads
  no halo values) runs while the slabs are in flight — on TPU through the
  Pallas row-streaming kernel (``kernels/stencil``) — and only the
  boundary ring waits for :meth:`HaloExchange.finish`.  XLA sees the
  ppermute starts before the interior compute, so the ICI transfer hides
  behind the VPU sweep — the paper's "communication during computation",
  at application scope.

Bit-exactness: every output point is the same ``0.25 * (n + s + w + e)``
f32 expression in both schedules (the interior from resident values, the
ring from the padded tile), so overlapped == reference to the bit on every
transport backend — including the int8 compressed wire, where both
schedules quantise identical slabs (tests/test_apps.py).  Distributed ==
single-rank holds exactly on exact wires and within the codec error bound
on ``smi:compressed``.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..compat import shard_map as _shard_map
from ..core.collectives import _schedule_loop
from ..core.comm import Communicator
from ..core.streaming import make_test_mesh
from ..kernels.stencil import stencil_interior, stencil_ref
from .halo import HaloExchange


def _sweep(padded):
    """One 4-point sweep of a halo-padded tile: (M, N) -> (M-2, N-2).

    The single numeric expression both step schedules are built from —
    identical operand order everywhere, which is what makes the
    overlapped/reference diff exact."""
    q = padded.astype(jnp.float32)
    out = 0.25 * (q[:-2, 1:-1] + q[2:, 1:-1] + q[1:-1, :-2] + q[1:-1, 2:])
    return out.astype(padded.dtype)


@dataclass(frozen=True)
class DistributedStencil:
    """A sharded heat-diffusion run over ``grid`` = (RX, RY) ranks.

    ``use_pallas``/``interpret`` select the interior-update kernel exactly
    as ``kernels/stencil/ops.py`` does (None = TPU auto); ``transport`` /
    ``plan`` configure the halo schedule (see :class:`HaloExchange`).
    """

    comm: Communicator
    grid: tuple[int, int]
    transport: object = None
    plan: object = None
    use_pallas: bool | None = None
    interpret: bool = False

    @staticmethod
    def create(grid, *, axis_names=None, comm=None, comm_mode=None,
               transport=None, plan=None, use_pallas=None, interpret=False):
        """Build the app over a fresh communicator (row-major torus over
        ``axis_names``) unless one is passed.  ``comm_mode`` accepts the
        launch-layer strings (``"smi:compressed"`` etc.), mapped onto the
        halo channel's spec through
        :func:`repro.channels.default_channel_spec`."""
        RX, RY = grid
        if comm is None:
            if axis_names is None:
                axis_names = ("gx", "gy") if RX > 1 and RY > 1 else ("gx",)
            sizes = grid if len(axis_names) == 2 else (RX * RY,)
            comm = Communicator.create(axis_names, sizes)
        if comm_mode is not None:
            from ..channels import default_channel_spec
            from .halo import HALO_TAG

            assert transport is None, "pass comm_mode or transport, not both"
            spec = default_channel_spec(
                comm, comm_mode, kind="exchange", port=None, tag=HALO_TAG,
            )
            transport = spec.transport
        return DistributedStencil(
            comm=comm, grid=(RX, RY), transport=transport, plan=plan,
            use_pallas=use_pallas, interpret=interpret,
        )

    @property
    def halo_schedule(self) -> HaloExchange:
        return HaloExchange(
            comm=self.comm, grid=self.grid, halo=(1, 1),
            transport=self.transport, plan=self.plan,
        )

    def make_mesh(self):
        """Host-device mesh matching the communicator's axes."""
        return make_test_mesh(self.comm.axis_sizes, self.comm.axis_names)

    # -- one timestep ------------------------------------------------------

    def step_reference(self, x, transport=None):
        """Non-overlapped: exchange completes, then the full padded sweep."""
        padded = self.halo_schedule.exchange(x, transport)
        return _sweep(padded)

    def step_overlapped(self, x, transport=None):
        """Pipelined: interior update runs while the halo slabs fly.

        The interior (rows/cols ``1..-2``) reads no halo values, so it is
        traced between :meth:`HaloExchange.start` and ``finish`` — the
        overlap window; only the one-point boundary ring consumes the
        received slabs.  Every point is the same f32 expression as
        :meth:`step_reference` computes, so the two schedules agree bit
        for bit on every backend.
        """
        he = self.halo_schedule
        inflight = he.start(x, transport)
        inner = stencil_interior(
            x, use_pallas=self.use_pallas, interpret=self.interpret
        )
        padded = he.finish(x, inflight)
        out = jnp.zeros_like(x)
        out = out.at[1:-1, 1:-1].set(inner)
        out = out.at[0, :].set(_sweep(padded[:3, :])[0])
        out = out.at[-1, :].set(_sweep(padded[-3:, :])[0])
        out = out.at[:, 0].set(_sweep(padded[:, :3])[:, 0])
        out = out.at[:, -1].set(_sweep(padded[:, -3:])[:, 0])
        return out

    # -- multi-step runs ---------------------------------------------------

    def run(self, x, n_steps: int, *, overlapped: bool = True,
            transport=None):
        """``n_steps`` timesteps of the local tile ``x`` (inside shard_map).

        Rolled (fori_loop) on trace-time backends with the per-iteration
        stats delta scaled to the full step count; unrolled when the
        backend threads runtime counters (the packet router) — the same
        dispatch the streamed collectives use.
        """
        t = self.halo_schedule.resolve_transport(x, transport)
        step = self.step_overlapped if overlapped else self.step_reference

        def body(_, v):
            return step(v, transport=t)

        return _schedule_loop(t, n_steps, body, x)

    def jitted(self, mesh=None, *, n_steps: int = 1, overlapped: bool = True,
               transport=None):
        """jit(shard_map) callable: (n, nx, ny) stacked tiles -> same."""
        mesh = mesh or self.make_mesh()
        names = self.comm.axis_names
        spec = P(names[0]) if len(names) == 1 else P(names)

        def fn(tiles):
            return self.run(
                tiles[0], n_steps, overlapped=overlapped, transport=transport
            )[None]

        return jax.jit(
            _shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec)
        )

    # -- host-side domain plumbing ----------------------------------------

    def scatter(self, world: np.ndarray) -> np.ndarray:
        """(X, Y) domain -> (n_ranks, nx, ny) row-major tile stack."""
        RX, RY = self.grid
        X, Y = world.shape
        assert X % RX == 0 and Y % RY == 0, (
            f"domain {world.shape} not divisible by grid {self.grid}"
        )
        nx, ny = X // RX, Y // RY
        tiles = np.zeros((RX * RY, nx, ny), world.dtype)
        for rx in range(RX):
            for ry in range(RY):
                tiles[rx * RY + ry] = world[rx * nx:(rx + 1) * nx,
                                            ry * ny:(ry + 1) * ny]
        return tiles

    def gather(self, tiles: np.ndarray) -> np.ndarray:
        """(n_ranks, nx, ny) tile stack -> reassembled (X, Y) domain."""
        RX, RY = self.grid
        tiles = np.asarray(tiles)
        _, nx, ny = tiles.shape
        world = np.zeros((RX * nx, RY * ny), tiles.dtype)
        for rx in range(RX):
            for ry in range(RY):
                world[rx * nx:(rx + 1) * nx, ry * ny:(ry + 1) * ny] = \
                    tiles[rx * RY + ry]
        return world

    @staticmethod
    def single_rank_reference(world, n_steps: int):
        """The undistributed oracle: ``n_steps`` zero-boundary sweeps."""
        out = jnp.asarray(world)
        for _ in range(n_steps):
            out = stencil_ref(out)
        return np.asarray(out)

    # -- costing -----------------------------------------------------------

    def predicted_step_time(self, tile_shape, dtype="float32", model=None,
                            *, overlapped: bool = True,
                            compute_seconds: float | None = None,
                            wire: str = "raw") -> float:
        """LinkModel prediction of one timestep: the halo-exchange time
        combined with ``compute_seconds`` through the overlap window
        (max on the pipelined schedule, sum on the reference)."""
        from ..netsim.model import LinkModel

        model = model or LinkModel.default_v5e()
        comm_s = self.halo_schedule.predicted_time(
            tile_shape, dtype, model=model, wire=wire
        )
        if compute_seconds is None:
            return comm_s
        if overlapped:
            return model.overlapped_step_time(compute_seconds, comm_s)
        return model.serial_step_time(compute_seconds, comm_s)

    def with_transport(self, transport) -> "DistributedStencil":
        return replace(self, transport=transport)
