"""repro.apps: distributed application workloads over the SMI stack.

The paper's evaluation is a suite of distributed benchmarks whose
communication is *streamed through* the compute pipeline rather than
bracketing it.  This package hosts those application kernels, built on the
``core`` streaming layer, the pluggable ``transport`` backends and the
``netsim`` cost model:

* :class:`~repro.apps.halo.HaloExchange` — the N/S/E/W halo schedule of a
  2D rank grid: backend-agnostic, start/finish-split for overlap, costed
  and autotuned through netsim.
* :class:`~repro.apps.stencil.DistributedStencil` — 2D heat diffusion
  (paper §5.4.2): a pipelined step that hides the halo exchange behind the
  Pallas interior update, plus the non-overlapped reference it matches bit
  for bit.

See DESIGN.md §8 for the layer contract.
"""

from .halo import HALO_TAG, HaloExchange
from .stencil import DistributedStencil

__all__ = ["HALO_TAG", "HaloExchange", "DistributedStencil"]
