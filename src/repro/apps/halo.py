"""HaloExchange: the distributed-application communication schedule.

The paper's stencil benchmark (§5.4.2, Fig. 14) decomposes a 2D domain over
a rank grid and streams boundary slabs to the four neighbours each
timestep.  :class:`HaloExchange` packages that schedule as an object the
``repro/apps`` workloads share:

* **backend-agnostic** — the slabs move through whichever transport the
  communicator (or an explicit ``transport=`` / ``comm_mode="smi:<b>"``)
  selects: static ppermutes, the packet router, the fused path, or int8
  compressed links;
* **split for overlap** — :meth:`start` launches the neighbour permutes
  and :meth:`finish` assembles the padded tile, so an application can run
  its interior compute between the two (``core/overlap.py``'s
  start/finish pair);
* **costed** — :meth:`predicted_stats` is the netsim-exact (steps, bytes)
  the backend will tally (asserted against ``stats.by_tag["halo"]``), and
  :meth:`predicted_time` is the :class:`~repro.netsim.model.LinkModel`
  step-time prediction the benchmarks print;
* **tunable** — ``plan="auto"`` asks the communicator's netsim tuning
  table which backend should move a slab of this size on this topology
  (``Communicator.plan("halo", nbytes)``; always a raw wire — lossy halos
  are an explicit user choice, never a tuned one).

The schedule's communication configuration rides in a
:class:`~repro.channels.ChannelSpec` of kind ``"exchange"`` (:attr:`spec`):
the same open-time descriptor the channel API uses everywhere else carries
the halo wire's transport backend, tuning plan, and the ``"halo"`` stats
tag — one exchange is one anonymous-port transient channel over the
neighbour links.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..channels.spec import ChannelSpec
from ..core.comm import Communicator
from ..core.overlap import (
    halo_exchange_2d_finish,
    halo_exchange_2d_start,
)
from ..obs import trace as obs

#: the tag halo wire traffic is accounted under (TransportStats.by_tag)
HALO_TAG = "halo"


@dataclass(frozen=True)
class HaloExchange:
    """The N/S/E/W halo-exchange schedule of a (RX, RY) rank grid.

    ``transport`` is a registry key / Transport instance / None (the
    communicator's default); ``plan="auto"`` defers the choice to the
    netsim tuning table per tile size.  A per-call ``transport=`` always
    wins — benchmarks pass fresh instances so traced stats stay per-run.
    """

    comm: Communicator
    grid: tuple[int, int]
    halo: tuple[int, int] = (1, 1)
    transport: object = None
    plan: object = None

    def __post_init__(self):
        RX, RY = self.grid
        assert self.comm.size == RX * RY, (
            f"grid {self.grid} needs {RX * RY} ranks; communicator has "
            f"{self.comm.size}"
        )

    # -- transport resolution ---------------------------------------------

    @property
    def spec(self) -> ChannelSpec:
        """This schedule's communication config as a transient-channel
        descriptor: an anonymous-port ``"exchange"`` channel tagged
        ``"halo"`` over the schedule's transport/plan."""
        return ChannelSpec(
            comm=self.comm, kind="exchange", port=None,
            transport=self.transport, plan=self.plan, tag=HALO_TAG,
        )

    def slab_nbytes(self, tile_shape, dtype=np.float32) -> int:
        """Bytes of the largest halo slab of a ``tile_shape`` tile (the
        message size the tuner's ``halo`` cells are keyed on)."""
        from ..netsim.schedule import halo_slab_elems

        ns, ew = halo_slab_elems(tuple(tile_shape), self.halo)
        return max(ns, ew) * np.dtype(dtype).itemsize

    def resolve_transport(self, tile=None, transport=None):
        """The Transport instance one exchange of ``tile`` uses: explicit
        argument > the spec's ``transport`` > the tuned ``halo`` plan
        (``plan="auto"``) > the communicator's default backend."""
        from ..transport.registry import resolve_transport

        spec = self.spec
        if transport is not None:
            return resolve_transport(transport, self.comm)
        if spec.transport is None and spec.plan == "auto" and tile is not None:
            p = self.comm.plan(
                "halo", self.slab_nbytes(tile.shape, tile.dtype)
            )
            return spec.replace(transport=p.transport_key).resolve()
        return spec.resolve()

    # -- the exchange ------------------------------------------------------

    def start(self, x, transport=None):
        """Launch the four neighbour permutes; returns the in-flight slabs
        (tagged ``"halo"`` in the backend's stats)."""
        t = self.resolve_transport(x, transport)
        if obs.TRACING:
            obs.emit("halo.start", tag=self.spec.stats_tag,
                     grid=list(self.grid), tile=list(x.shape),
                     transport=t.name)
        return halo_exchange_2d_start(
            x, self.comm, grid=self.grid, halo=self.halo,
            transport=t, tag=self.spec.stats_tag,
        )

    def finish(self, x, inflight):
        """Assemble the halo-padded tile from ``x`` + the in-flight slabs."""
        if obs.TRACING:
            obs.emit("halo.finish", tag=self.spec.stats_tag,
                     grid=list(self.grid))
        return halo_exchange_2d_finish(
            x, inflight, self.comm, grid=self.grid, halo=self.halo
        )

    def exchange(self, x, transport=None):
        """Non-overlapped exchange: start and immediately finish."""
        return self.finish(x, self.start(x, transport))

    # -- costing (netsim) --------------------------------------------------

    def predicted_stats(self, tile_shape, dtype="float32",
                        transport: str = "static", **kw):
        """Exact (steps, bytes) one exchange tallies under ``transport`` —
        the numbers ``stats.by_tag["halo"]`` holds after tracing.  Extra
        kwargs (``pkt_elems`` etc.) forward to
        :func:`repro.netsim.schedule.predict_halo_stats`."""
        from ..netsim.schedule import predict_halo_stats

        return predict_halo_stats(
            self.comm, grid=self.grid, shape=tuple(tile_shape), dtype=dtype,
            halo=self.halo, transport=transport, **kw,
        )

    def predicted_time(self, tile_shape, dtype="float32", model=None,
                       wire: str = "raw") -> float:
        """LinkModel-predicted seconds of one exchange (the benchmark's
        model column)."""
        from ..netsim.schedule import predict_halo_time

        return predict_halo_time(
            self.comm, grid=self.grid, shape=tuple(tile_shape), dtype=dtype,
            halo=self.halo, model=model, wire=wire,
        )
