"""JAX version-compat layer.

The codebase is written against the modern explicit-sharding JAX API
(``jax.shard_map``, ``jax.sharding.AxisType``, ``lax.pcast`` / the
varying-manual-axes type system).  Older runtimes (jax < 0.6) expose the
same functionality under different names — or not at all, in which case the
feature is a semantic no-op (pre-VMA shard_map never tracked varyingness).

This module gives every call site one stable surface:

* :func:`shard_map` — ``jax.shard_map`` when present, else the
  ``jax.experimental.shard_map`` fallback.  The modern ``check_vma=``
  kwarg is translated to the legacy ``check_rep=`` (both are pure
  validation toggles; replication checking on legacy jax rejects valid
  masked-ppermute programs, so the fallback disables it).
* :func:`make_mesh` — ``jax.make_mesh`` with ``axis_types=Auto`` when the
  runtime knows about axis types, plain ``jax.make_mesh`` otherwise.
* :func:`pvary_missing` / :func:`vma_of` — ``lax.pcast``-based VMA casts on
  runtimes with the VMA type system, identity elsewhere.

Importing :mod:`repro` installs :func:`shard_map` as ``jax.shard_map`` when
the attribute is missing, so tests/benchmarks/examples written against the
modern spelling run unchanged on legacy runtimes.
"""

from __future__ import annotations

import functools

import jax
from jax import lax

HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")
HAS_VMA = hasattr(lax, "pcast") and hasattr(jax, "typeof")

try:
    from jax.sharding import AxisType  # jax >= 0.6

    HAS_AXIS_TYPES = True
except ImportError:  # legacy: meshes have no axis types
    AxisType = None
    HAS_AXIS_TYPES = False


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True, **kw):
    """Version-stable ``jax.shard_map``.

    Accepts the modern keyword surface; on legacy runtimes dispatches to
    ``jax.experimental.shard_map.shard_map`` with replication checking off
    (the legacy checker predates masked collectives and rejects valid SMI
    schedules — it is validation only, never semantics).
    """
    if HAS_NATIVE_SHARD_MAP:
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma, **kw,
        )
    from jax.experimental.shard_map import shard_map as _legacy

    return _legacy(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False, **kw,
    )


def make_mesh(shape, names):
    """``jax.make_mesh`` with Auto axis types when the runtime has them."""
    shape, names = tuple(shape), tuple(names)
    if HAS_AXIS_TYPES:
        return jax.make_mesh(shape, names, axis_types=(AxisType.Auto,) * len(shape))
    return jax.make_mesh(shape, names)


def vma_of(x) -> frozenset:
    """Varying-manual-axes of ``x`` (empty set on pre-VMA runtimes)."""
    if not HAS_VMA:
        return frozenset()
    return getattr(jax.typeof(x), "vma", frozenset())


def pvary_missing(v, names):
    """Cast ``v`` varying over every axis in ``names`` it is not already
    varying over.  Identity on runtimes without the VMA type system (there,
    constants created inside shard_map are implicitly device-varying)."""
    if not HAS_VMA:
        return v
    missing = tuple(n for n in names if n not in vma_of(v))
    return lax.pcast(v, missing, to="varying") if missing else v


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _psum_rep(x, axis_name):
    return lax.psum(x, axis_name)


def _psum_rep_fwd(x, axis_name):
    return lax.psum(x, axis_name), None


def _psum_rep_bwd(_axis_name, _res, g):
    return (g,)


_psum_rep.defvjp(_psum_rep_fwd, _psum_rep_bwd)


def psum_replicated(x, axis_name):
    """``lax.psum`` whose result is *replicated* over ``axis_name`` and whose
    AD transpose is therefore the identity.

    Modern jax derives this from the VMA type system.  Legacy shard_map with
    replication checking off transposes psum back to psum, over-counting
    replicated cotangents by the axis size; the custom_vjp restores the
    correct identity transpose there.
    """
    if HAS_VMA:
        return lax.psum(x, axis_name)
    return _psum_rep(x, axis_name)


def tpu_compiler_params(**kw):
    """``pltpu.CompilerParams`` across its rename (legacy: TPUCompilerParams)."""
    from jax.experimental.pallas import tpu as pltpu

    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kw)


def install():
    """Install shims onto the ``jax`` namespace (idempotent).

    Only fills gaps — never overrides a native attribute — so running on a
    modern jax leaves the runtime untouched.
    """
    if not HAS_NATIVE_SHARD_MAP:

        @functools.wraps(shard_map)
        def _jax_shard_map(f, mesh, in_specs, out_specs, **kw):
            return shard_map(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw
            )

        jax.shard_map = _jax_shard_map
