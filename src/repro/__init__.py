"""repro: SMI (Streaming Message Interface) rendered for JAX TPU meshes.

Importing the package installs the JAX version-compat shims (see
:mod:`repro.compat`) so the modern API surface (``jax.shard_map`` et al.)
is available on every supported runtime before any submodule uses it.
"""

from . import compat as _compat

_compat.install()
