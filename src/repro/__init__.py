"""repro: SMI (Streaming Message Interface) rendered for JAX TPU meshes.

Importing the package installs the JAX version-compat shims (see
:mod:`repro.compat`) so the modern API surface (``jax.shard_map`` et al.)
is available on every supported runtime before any submodule uses it.

When jax is absent the install is skipped instead of failing the import:
the stdlib-only analysis layer (``repro.analysis`` — the smilint AST
rules and ledger verifier, DESIGN.md §14) must stay importable in
jax-free environments (the CI lint job); everything that actually uses
jax still fails at ITS import, with the real ImportError.
"""

import importlib.util as _ilu

if _ilu.find_spec("jax") is not None:
    from . import compat as _compat

    _compat.install()
