"""AdamW with ZeRO-1-style state sharding.

Parameters live model-sharded / data-replicated (the forward layout);
optimizer moments additionally shard over the data axes wherever a tensor
dimension divides (``opt_specs``).  The update is elementwise, so under jit
XLA turns the layout difference into: slice grads (free — they're replicated
post-sync), update the local moment shard, all-gather fresh params — exactly
the ZeRO-1 dataflow, derived from shardings rather than hand-written."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def adamw_init(params):
    zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def adamw_update(
    params,
    grads,
    opt,
    *,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
):
    step = opt["step"] + 1
    t = step.astype(jnp.float32)
    c1 = 1.0 - b1 ** t
    c2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / c1
        vh = v / c2
        new_p = p - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p)
        return new_p.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(opt["m"])
    flat_v = jax.tree.leaves(opt["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}


def opt_specs(param_specs, mesh, params_shape, data_axes=("data",)):
    """ZeRO-1: shard moments over the data axes on the first dimension whose
    size divides and which the param spec leaves unsharded."""
    from jax.sharding import PartitionSpec as P

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = 1
    for a in data_axes:
        dp *= sizes.get(a, 1)

    def spec_for(ps, shape_leaf):
        dims = tuple(ps) + (None,) * (len(shape_leaf.shape) - len(tuple(ps)))
        for i, (d, s) in enumerate(zip(dims, shape_leaf.shape)):
            if d is None and s % dp == 0 and s > 0 and dp > 1:
                new = list(dims)
                new[i] = tuple(data_axes) if len(data_axes) > 1 else data_axes[0]
                return P(*new)
        return P(*dims)

    moment_specs = jax.tree.map(
        spec_for, param_specs, params_shape,
        is_leaf=lambda x: isinstance(x, P),
    )
    return {"m": moment_specs, "v": moment_specs, "step": P()}
