"""LR schedules."""

import jax.numpy as jnp


def cosine_warmup(step, *, base_lr, warmup_steps, total_steps, min_ratio=0.1):
    t = step.astype(jnp.float32)
    warm = base_lr * jnp.minimum(t / jnp.maximum(warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (t - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0
    )
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(t < warmup_steps, warm, base_lr * cos)
