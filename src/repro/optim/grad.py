"""Gradient utilities: global-norm clipping; error feedback for int8
compressed gradient rings.

Two error-feedback levels cooperate (DESIGN.md §7):

* **per-hop** — inside :class:`repro.transport.compressed.
  CompressedTransport`'s ``send_contribution``: the compressed ring
  reduce-scatter transmits each hop's *contribution* as ``Q(c + e)``
  (never a partial sum — re-rounding a travelling accumulator compounds
  error with the ring size P) and carries the residual forward.  This
  lives in the transport and needs nothing from the optimizer.
* **end-to-end** — :class:`ErrorFeedback` here: the residual between the
  gradients a step *wanted* to sync and what the lossy ring delivered is
  re-injected into the next step's gradients (EF-SGD).  This is optimizer
  state, threaded through the train step.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def clip_by_global_norm(grads, max_norm: float):
    """Scale ``grads`` so their global L2 norm is at most ``max_norm``.

    Each leaf keeps its own dtype (the scale is applied in f32 and cast
    back — no silent upcast of bf16 grads), and an empty pytree is a
    no-op with norm 0 rather than a ``jax.tree.reduce`` crash."""
    sq = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), grads),
        jnp.zeros((), jnp.float32),
    )
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return (
        jax.tree.map(
            lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads
        ),
        norm,
    )


class ErrorFeedback:
    """End-to-end residual accumulator for lossy (int8) gradient sync.

    usage: g_corrected = ef.add(grads); <compressed all-reduce of
    g_corrected -> g_synced (e.g. ``mesh.api.grad_sync(...,
    compressed=True)``, which runs the ``compressed`` transport)>;
    ef.update(g_corrected, g_synced) — or the one-call :meth:`sync` hook.
    State is a pytree like grads; functional (returns new state)."""

    @staticmethod
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    @staticmethod
    def add(ef_state, grads):
        return jax.tree.map(lambda e, g: g.astype(jnp.float32) + e, ef_state, grads)

    @staticmethod
    def update(corrected, synced):
        # residual = what we wanted to send minus what the lossy ring delivered
        return jax.tree.map(lambda c, s: c - s.astype(jnp.float32), corrected, synced)

    @classmethod
    def sync(cls, ef_state, grads, sync_fn=None, *, comm=None, tag="grad",
             wire="int8"):
        """One-call hook: correct, sync, and roll the residual.  Returns
        ``(synced_grads, new_ef_state)``.

        Pass ``sync_fn`` (any lossy all-reduce, e.g. a compressed-transport
        ``grad_sync``) — or pass ``comm`` and the sync opens a tagged
        ``"grad"`` channel per tensor itself (int8 wire by default: the
        compressed-link transport composes under the channel spec, so the
        per-hop and end-to-end feedback levels stack)."""
        if sync_fn is None:
            assert comm is not None, "ErrorFeedback.sync needs sync_fn or comm"
            from ..parallel import grad_allreduce

            def sync_fn(tree):
                return jax.tree.map(
                    lambda g: grad_allreduce(g, comm, tag=tag, wire=wire),
                    tree,
                )

        corrected = cls.add(ef_state, grads)
        synced = sync_fn(corrected)
        return synced, cls.update(corrected, synced)
