"""Gradient utilities: global-norm clipping; error feedback for the int8
compressed gradient rings (core/collectives.make_int8_codec)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def clip_by_global_norm(grads, max_norm: float):
    sq = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda g: jnp.sum(g.astype(jnp.float32) ** 2), grads),
    )
    norm = jnp.sqrt(sq)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale, grads), norm


class ErrorFeedback:
    """Residual accumulator for lossy (int8) gradient sync.

    usage: g_corrected = ef.add(grads); <compressed all-reduce of
    g_corrected -> g_synced>; ef.update(g_corrected, g_synced).
    State is a pytree like grads; functional (returns new state)."""

    @staticmethod
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    @staticmethod
    def add(ef_state, grads):
        return jax.tree.map(lambda e, g: g.astype(jnp.float32) + e, ef_state, grads)

    @staticmethod
    def update(corrected, synced):
        # residual = what we wanted to send minus what the lossy ring delivered
        return jax.tree.map(lambda c, s: c - s.astype(jnp.float32), corrected, synced)
