from .adamw import adamw_init, adamw_update, opt_specs
from .schedule import cosine_warmup
from .grad import clip_by_global_norm, ErrorFeedback

__all__ = [
    "adamw_init", "adamw_update", "opt_specs",
    "cosine_warmup", "clip_by_global_norm", "ErrorFeedback",
]
