"""Per-process ring-buffer event tracer (DESIGN.md §11).

One process holds at most one active :class:`Tracer`; producers all over the
stack — channel open/close/push/pop/transfer, the packet router's schedule
facts, the netsim autotuner's chosen plans, the fault-tolerance watchdog —
emit through the module-level :func:`emit` behind the :data:`TRACING` flag.

The disabled path is the design constraint: tracing is off by default and
instrumentation sits on trace-time hot paths (every channel push/pop call
site), so a disabled call site must cost one module-attribute load plus a
bool test and allocate *nothing*.  That is why call sites are written

    if trace.TRACING:
        trace.emit("channel.push", tag=..., port=...)

— the kwargs dict is only ever built when a tracer is live (asserted by
``tests/test_obs.py`` with tracemalloc).

Event schema (stable; the exporter embeds it verbatim):

    {"ts": float seconds since the tracer epoch,
     "rank": int | None          # None = host / SPMD trace-time event,
     "kind": str                 # dotted producer.verb, e.g. "channel.push",
     "tag":  str | None          # the ChannelSpec / TransportStats tag,
     "port": int | None          # the channel's claimed port,
     "attrs": dict}              # producer-specific payload (JSON-safe)

Timestamps are host ``perf_counter`` times.  SPMD producers emit once per
*python trace*, not per runtime step — a channel push event marks where the
schedule staged an element, not a runtime packet (runtime counters live in
``TransportStats`` and the metrics snapshot).  jax-free by design, so the
netsim/tuner side can import it before jax initialises.
"""

from __future__ import annotations

import time
from collections import deque
from contextlib import contextmanager

#: the stable event schema's keys, in canonical order
EVENT_KEYS = ("ts", "rank", "kind", "tag", "port", "attrs")

#: fast-path flag mirroring ``_TRACER is not None``; call sites test this
#: before building any kwargs so the disabled path allocates nothing
TRACING = False

_TRACER: "Tracer | None" = None


class Tracer:
    """Bounded event recorder: a deque ring buffer of schema events.

    ``capacity`` bounds memory on long runs (oldest events fall off);
    ``clock`` is injectable for deterministic tests.  All timestamps are
    relative to the tracer's construction (``t0``), so exported traces
    start near zero.
    """

    __slots__ = ("capacity", "clock", "t0", "_events")

    def __init__(self, capacity: int = 65536, clock=time.perf_counter):
        self.capacity = int(capacity)
        self.clock = clock
        self.t0 = clock()
        self._events = deque(maxlen=self.capacity)

    def now(self) -> float:
        """Seconds since the tracer epoch (the event ``ts`` base)."""
        return self.clock() - self.t0

    def event(self, kind: str, *, rank=None, tag=None, port=None,
              ts=None, **attrs):
        """Record one schema event.  ``ts=None`` stamps :meth:`now`;
        extra keyword arguments become the event's ``attrs`` payload."""
        self._events.append({
            "ts": self.now() if ts is None else float(ts),
            "rank": rank,
            "kind": kind,
            "tag": tag,
            "port": port,
            "attrs": attrs,
        })

    def events(self) -> list:
        """Snapshot of the buffer, oldest first."""
        return list(self._events)

    def kinds(self) -> set:
        return {e["kind"] for e in self._events}

    def clear(self):
        self._events.clear()

    def __len__(self) -> int:
        return len(self._events)


def enable(capacity: int = 65536, clock=time.perf_counter) -> Tracer:
    """Install (and return) a fresh process-wide tracer."""
    global _TRACER, TRACING
    _TRACER = Tracer(capacity, clock)
    TRACING = True
    return _TRACER


def disable() -> "Tracer | None":
    """Remove the active tracer (returns it, with its events intact)."""
    global _TRACER, TRACING
    t, _TRACER, TRACING = _TRACER, None, False
    return t


def get() -> "Tracer | None":
    return _TRACER


def emit(kind: str, **kw):
    """Record an event on the active tracer; no-op when tracing is off.

    Hot call sites must still guard with ``if trace.TRACING:`` *before*
    building ``kw`` — this function is the slow-path funnel, the flag test
    is the fast path."""
    t = _TRACER
    if t is not None:
        t.event(kind, **kw)


@contextmanager
def enabled(capacity: int = 65536, clock=time.perf_counter):
    """Scoped tracing: install a fresh tracer, restore the previous one
    (usually none) on exit.  Yields the tracer — its events stay readable
    after the block."""
    global _TRACER, TRACING
    prev = _TRACER
    t = enable(capacity, clock)
    try:
        yield t
    finally:
        _TRACER = prev
        TRACING = prev is not None
