"""Chrome-trace / Perfetto export of tracer events (DESIGN.md §11).

Renders the stable event schema of :mod:`repro.obs.trace` into the Chrome
trace-event JSON format (``{"traceEvents": [...]}``, loadable in Perfetto
or chrome://tracing) with a fixed lane layout:

* ``pid 1`` — **measured: ranks**: one thread lane per rank (events with a
  ``rank``), plus a ``host`` lane for rank-less schedule events (SPMD
  producers emit once per python trace, on the host);
* ``pid 2`` — **measured: links**: one lane per directed link, fed by
  events carrying ``attrs["link"] = [a, b]``;
* ``pid 3`` / ``pid 4`` — the same two groups for **netsim (predicted)**
  events (``kind`` prefixed ``sim.``), so a predicted timeline rendered by
  :func:`sim_report_events` overlays the measured one in a single viewer —
  the paper's §5.4.2 overlap window, made visible.

Events with ``attrs["dur"]`` (seconds) become complete ("X") slices; the
rest become instants ("i").  Every viewer event embeds the source schema
event verbatim under ``args["event"]``, which is what makes
:func:`parse_chrome_trace` lossless (export → parse → identical, asserted
by ``tests/test_obs.py``).
"""

from __future__ import annotations

import json

#: fixed process ids of the lane groups (stable across exports)
PID_RANKS = 1
PID_LINKS = 2
PID_SIM_RANKS = 3
PID_SIM_LINKS = 4

#: tid of the host lane inside a rank group (after any real rank tid)
HOST_TID = 10**6

_GROUP_NAMES = {
    PID_RANKS: "measured: ranks",
    PID_LINKS: "measured: links",
    PID_SIM_RANKS: "netsim (predicted): ranks",
    PID_SIM_LINKS: "netsim (predicted): links",
}


def _is_sim(ev) -> bool:
    return str(ev.get("kind", "")).startswith("sim.")


def _lane_of(ev, link_tids: dict):
    """(pid, tid) of one schema event under the fixed lane layout."""
    link = ev.get("attrs", {}).get("link")
    sim = _is_sim(ev)
    if link is not None:
        key = (int(link[0]), int(link[1]))
        if key not in link_tids:
            link_tids[key] = len(link_tids)
        return (PID_SIM_LINKS if sim else PID_LINKS), link_tids[key]
    if ev.get("rank") is not None:
        return (PID_SIM_RANKS if sim else PID_RANKS), int(ev["rank"])
    return (PID_SIM_RANKS if sim else PID_RANKS), HOST_TID


def _meta(pid, tid, what, name):
    return {"ph": "M", "pid": pid, "tid": tid, "name": what,
            "args": {"name": name}}


def chrome_events(events) -> list:
    """Viewer events (no metadata) for a list of schema events."""
    link_tids: dict = {}
    out = []
    for ev in events:
        pid, tid = _lane_of(ev, link_tids)
        attrs = ev.get("attrs", {})
        dur = attrs.get("dur")
        rec = {
            "name": ev["kind"],
            "cat": ev.get("tag") or "event",
            "pid": pid,
            "tid": tid,
            "ts": float(ev["ts"]) * 1e6,  # chrome trace time unit: us
            "args": {"event": ev},
        }
        if dur is not None:
            rec["ph"] = "X"
            rec["dur"] = float(dur) * 1e6
        else:
            rec["ph"] = "i"
            rec["s"] = "t"
        out.append(rec)
    return out


def to_chrome_trace(events) -> dict:
    """Full Chrome-trace document: viewer events + lane-naming metadata."""
    body = chrome_events(events)
    lanes = {}  # (pid, tid) -> label
    link_tids: dict = {}
    for ev in events:
        pid, tid = _lane_of(ev, link_tids)
        if (pid, tid) not in lanes:
            link = ev.get("attrs", {}).get("link")
            if link is not None:
                lanes[(pid, tid)] = f"link {int(link[0])}->{int(link[1])}"
            elif ev.get("rank") is not None:
                lanes[(pid, tid)] = f"rank {int(ev['rank'])}"
            else:
                lanes[(pid, tid)] = "host"
    meta = [
        _meta(pid, 0, "process_name", name)
        for pid, name in _GROUP_NAMES.items()
        if any(p == pid for p, _ in lanes)
    ]
    meta.extend(
        _meta(pid, tid, "thread_name", label)
        for (pid, tid), label in sorted(lanes.items())
    )
    return {"traceEvents": meta + body, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, events) -> int:
    """Write the trace document to ``path``; returns the event count."""
    events = list(events)
    with open(path, "w") as f:
        json.dump(to_chrome_trace(events), f, indent=1)
    return len(events)


def parse_chrome_trace(doc) -> list:
    """Recover the schema events from an exported document (lossless:
    every viewer event carries its source event under ``args["event"]``)."""
    if isinstance(doc, str):
        doc = json.loads(doc)
    return [
        rec["args"]["event"]
        for rec in doc.get("traceEvents", [])
        if rec.get("ph") != "M"
    ]


def lane_count(doc, pid) -> int:
    """Distinct thread lanes of one process group in a trace document."""
    if isinstance(doc, str):
        doc = json.loads(doc)
    return len({
        rec["tid"] for rec in doc.get("traceEvents", [])
        if rec.get("pid") == pid and rec.get("ph") != "M"
    })


# ---------------------------------------------------------------------------
# netsim adapter: SimReport -> schema events (the predicted overlay)
# ---------------------------------------------------------------------------


def directed_links(topo) -> list:
    """Every directed link of a topology, sorted (the link-lane universe)."""
    return sorted(
        (a, int(b)) for a, nbrs in enumerate(topo.links) for b in nbrs
    )


def sim_report_events(topo, reports, *, model=None, wire: str = "raw",
                      t0: float = 0.0) -> list:
    """Render barrier-separated :class:`~repro.netsim.sim.SimReport` rounds
    (run with ``simulate(..., trace=True)``) into schema events.

    One ``sim.lane`` declaration per directed topology link anchors a lane
    for *every* link — idle links included, so the viewer's link-lane count
    always equals the topology's directed link count (asserted by
    ``tests/test_obs.py``).  Each recorded move becomes one ``sim.flit``
    slice whose duration is the round's tick period under ``model`` (the
    same :meth:`~repro.netsim.model.LinkModel.hop_time_wire` conversion
    every predicted time in the repo uses); deliveries additionally emit a
    ``sim.deliver`` instant on the destination rank's lane.  Rounds are
    laid out back to back starting at ``t0`` seconds.
    """
    from ..netsim.model import LinkModel

    model = model or LinkModel.default_v5e()
    events = [
        {"ts": float(t0), "rank": None, "kind": "sim.lane", "tag": None,
         "port": None, "attrs": {"link": [a, b]}}
        for a, b in directed_links(topo)
    ]
    base = float(t0)
    for rep in reports:
        dt = model.hop_time_wire(rep.flit_bytes_max, wire)
        for tick, a, b, msg, delivered in rep.moves:
            ts = base + tick * dt
            events.append({
                "ts": ts, "rank": None, "kind": "sim.flit", "tag": None,
                "port": None,
                "attrs": {"link": [int(a), int(b)], "dur": dt,
                          "msg": int(msg)},
            })
            if delivered:
                events.append({
                    "ts": ts + dt, "rank": int(b), "kind": "sim.deliver",
                    "tag": None, "port": None, "attrs": {"msg": int(msg)},
                })
        base += rep.ticks * dt
    return events
