"""Counter/gauge registry + live TransportStats snapshots (DESIGN.md §11).

The benchmark drivers register every live transport backend under a name
(:meth:`MetricsRegistry.track`); :meth:`MetricsRegistry.snapshot` then
renders the registry into one JSON-safe dict — counters, gauges, and the
full :class:`~repro.transport.base.TransportStats` of each tracked backend
including its ``by_tag`` splits and the packet router's overflow counter.
Snapshots read the *live* stats objects, so the numbers are exactly the
trace-time counters the netsim predictions are asserted against
(``tests/test_obs.py`` checks equality to the byte).

Drift gauges turn the bench-only ``--validate-sim`` 2x gate into a
continuously-sampled metric: :meth:`MetricsRegistry.drift` records the
symmetric prediction ratio ``max(pred/meas, meas/pred)`` — computed by the
same :func:`repro.netsim.calibrate.drift_ratio` helper ``validate`` gates
on, so the gauge and the gate can never disagree — and
:meth:`MetricsRegistry.drift_from_records` samples a whole calibration-
record set, returning the worst ratio (== ``validate``'s).
"""

from __future__ import annotations


def _num(x):
    """Best-effort concrete number for a counter that may hold a traced
    jax value (the packet router's overflow inside an open trace): int
    when concrete, None when unavailable."""
    if x is None:
        return None
    try:
        return int(x)
    except Exception:  # a (dead) tracer from a jitted run: not concrete
        return None


class MetricsRegistry:
    """Process-level metric store: monotonic counters, point-in-time
    gauges, and live transport references snapshotted on demand."""

    def __init__(self):
        self.counters: dict = {}
        self.gauges: dict = {}
        self._transports: dict = {}  # name -> live Transport

    # ---------------------------------------------------------- writers

    def inc(self, name: str, delta=1):
        self.counters[name] = self.counters.get(name, 0) + delta

    def gauge(self, name: str, value: float):
        self.gauges[name] = float(value)

    def track(self, name: str, transport):
        """Register a live transport; its stats are read at snapshot time
        (re-tracking a name replaces the previous instance)."""
        self._transports[name] = transport

    # ------------------------------------------------------------ drift

    def drift(self, name: str, *, predicted: float, measured: float) -> float:
        """Record ``drift/<name>`` = the symmetric prediction ratio (the
        ``--validate-sim`` gate's quantity; 1.0 = perfect)."""
        from ..netsim.calibrate import drift_ratio

        ratio = drift_ratio(predicted, measured)
        self.gauge(f"drift/{name}", ratio)
        return ratio

    def drift_from_records(self, label: str, records, *, model) -> float:
        """Sample drift gauges from netsim calibration records under a
        fitted :class:`~repro.netsim.model.LinkModel`: one gauge per
        record (``drift/<label>/<name>``) plus the worst ratio under
        ``drift/<label>`` — by construction the exact worst ratio
        :func:`repro.netsim.calibrate.validate` computes for the same
        records and model."""
        worst = 1.0
        for i, r in enumerate(records):
            ratio = self.drift(
                f"{label}/{r.get('name') or i}",
                predicted=model.predict(r), measured=r["seconds"],
            )
            worst = max(worst, ratio)
        self.gauge(f"drift/{label}", worst)
        return worst

    # --------------------------------------------------------- snapshot

    @staticmethod
    def stats_dict(stats) -> dict:
        """One TransportStats as a JSON-safe dict (the snapshot's per-
        transport payload; by_tag is copied, overflow concretised when
        possible — a traced counter from a jitted run reads as None)."""
        return {
            "steps": int(stats.steps),
            "bytes": int(stats.bytes_moved),
            "overflow": _num(stats.overflow),
            "by_tag": {
                tag: {"steps": int(e["steps"]), "bytes": int(e["bytes"])}
                for tag, e in stats.by_tag.items()
            },
        }

    def snapshot(self) -> dict:
        """The whole registry as one JSON-safe dict."""
        return {
            "counters": dict(self.counters),
            "gauges": dict(self.gauges),
            "transports": {
                name: {"name": getattr(t, "name", "") or type(t).__name__,
                       **self.stats_dict(t.stats)}
                for name, t in self._transports.items()
            },
        }

    def clear(self):
        self.counters.clear()
        self.gauges.clear()
        self._transports.clear()


#: the process-default registry the benchmark drivers write into
REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return REGISTRY
