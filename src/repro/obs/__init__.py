"""repro.obs — tracing, metrics, and predicted-vs-measured drift
monitoring (DESIGN.md §11).

Three parts: :mod:`~repro.obs.trace` (the per-process ring-buffer event
tracer every layer emits into), :mod:`~repro.obs.export` (Chrome-trace /
Perfetto rendering with a netsim-predicted overlay), and
:mod:`~repro.obs.metrics` (counter/gauge registry snapshotting live
``TransportStats`` plus drift gauges against ``netsim.predict_*``).
"""

from . import trace
from .export import (
    parse_chrome_trace,
    sim_report_events,
    to_chrome_trace,
    write_chrome_trace,
)
from .metrics import REGISTRY, MetricsRegistry, get_registry
from .trace import Tracer

__all__ = [
    "trace",
    "Tracer",
    "to_chrome_trace",
    "parse_chrome_trace",
    "write_chrome_trace",
    "sim_report_events",
    "MetricsRegistry",
    "REGISTRY",
    "get_registry",
]
