from .base import ModelConfig, ShapeConfig, SHAPES
from .registry import ARCHS, COMM_MODES, TRANSPORT_BACKENDS, get_arch, smoke, cells

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "ARCHS", "COMM_MODES",
           "TRANSPORT_BACKENDS", "get_arch", "smoke", "cells"]
