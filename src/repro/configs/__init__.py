from .base import ModelConfig, ShapeConfig, SHAPES
from .registry import (
    APP_WORKLOADS,
    ARCHS,
    COMM_MODES,
    STENCIL_CASES,
    TRANSPORT_BACKENDS,
    cells,
    get_arch,
    smoke,
)

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "APP_WORKLOADS", "ARCHS",
           "COMM_MODES", "STENCIL_CASES", "TRANSPORT_BACKENDS", "get_arch",
           "smoke", "cells"]
