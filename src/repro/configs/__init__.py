from .base import ModelConfig, ShapeConfig, SHAPES
from .registry import ARCHS, get_arch, smoke, cells

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "ARCHS", "get_arch", "smoke", "cells"]
