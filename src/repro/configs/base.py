"""Architecture configuration schema + input shapes.

Every assigned architecture is an instance of :class:`ModelConfig`; the four
input shapes of the assignment are :data:`SHAPES`.  Configs are exact to the
assignment table; derived fields (padded vocab, head counts) are computed
here so the dry-run, smoke tests, and roofline all agree.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace


def pad_vocab(v: int, multiple: int = 256) -> int:
    return ((v + multiple - 1) // multiple) * multiple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    # block pattern, one entry per layer within a period
    pattern: tuple[str, ...] = ("attn",)
    mlp_type: str = "swiglu"       # swiglu | gelu
    # attention details
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    qkv_bias: bool = False
    local_window: int | None = None
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    shared_expert: bool = False
    capacity_factor: float = 1.25
    # SSM (mamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    # RG-LRU (recurrentgemma)
    lru_width: int = 0
    # modality frontends (stubs per assignment)
    n_codebooks: int = 1           # musicgen: EnCodec streams
    frontend: str | None = None    # vit_stub | encodec_stub
    n_patches: int = 0             # vlm: image tokens prepended
    # numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # comm: default tuning plan for the model's layer channels.  "auto"
    # (the default for every arch) hands backend/wire/chunk selection per
    # tag to the netsim tuner whenever the launch comm_mode doesn't pin a
    # backend (bare "smi"); an explicit "smi:<backend>" comm_mode — or
    # cfg.scaled(comm_plan=None) — is the escape hatch that pins it.
    comm_plan: str | None = "auto"
    source: str = ""               # provenance tag from the assignment

    # ------------------------------------------------------------- derived
    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return pad_vocab(self.vocab_size)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: no unbounded full-attention layer.
        NOTE: "moe" blocks contain attention too."""
        attn_kinds = {"attn", "moe"} & set(self.pattern)
        if not attn_kinds:
            return True  # pure ssm/rec
        # hybrids qualify if every attention layer has a bounded window
        return self.local_window is not None

    @property
    def layer_pattern(self) -> tuple[str, ...]:
        """Full per-layer kinds, pattern tiled to n_layers."""
        p = self.pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def scaled(self, **kw) -> "ModelConfig":
        """Reduced-config variant (smoke tests)."""
        return replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for 6ND roofline."""
        D, V = self.d_model, self.padded_vocab
        hd = self.hd
        n = V * D  # embedding
        if not self.tie_embeddings:
            n += V * D * (0 if self.n_codebooks > 1 else 1)
        if self.n_codebooks > 1:
            n += self.n_codebooks * V * D      # codebook embeds
            n += self.n_codebooks * V * D      # codebook heads
        for kind in self.layer_pattern:
            if kind in ("attn", "moe"):
                qkv = D * (self.n_heads * hd) + 2 * D * (self.n_kv_heads * hd)
                o = (self.n_heads * hd) * D
                n += qkv + o
                if self.qkv_bias:
                    n += (self.n_heads + 2 * self.n_kv_heads) * hd
            if kind == "attn":
                if self.mlp_type == "swiglu":
                    n += 3 * D * self.d_ff
                else:
                    n += 2 * D * self.d_ff
            elif kind == "moe":
                n += D * self.n_experts  # router
                n += self.n_experts * 3 * D * self.d_ff_expert
                if self.shared_expert:
                    n += 3 * D * self.d_ff
            elif kind == "ssm":
                d_in = self.ssm_expand * D
                nh = d_in // self.ssm_headdim
                g = self.ssm_state
                # in_proj: z, x, B, C, dt ; out_proj
                n += D * (2 * d_in + 2 * g + nh) + d_in * D
                n += self.ssm_conv * (d_in + 2 * g)  # conv
                n += 2 * nh  # A, D per head
            elif kind == "rec":
                w = self.lru_width or D
                n += D * w * 2       # in proj (branch + gate)
                n += self.ssm_conv * w
                n += 3 * w           # lru gates (a, input gate) diag params
                n += 2 * w * D // 1  # rg-lru input/rec gates (low-rank-ish, approx)
                n += w * D           # out proj
                if self.mlp_type == "swiglu":
                    n += 3 * D * self.d_ff
                else:
                    n += 2 * D * self.d_ff
            n += 2 * D  # norms
        n += D  # final norm
        return n

    def active_param_count(self) -> int:
        """Active (per-token) params — MoE counts top_k + shared experts."""
        if self.n_experts == 0:
            return self.param_count()
        dense_like = self.param_count()
        dense_like -= self.n_experts * 3 * self.d_model * self.d_ff_expert * \
            self.layer_pattern.count("moe")
        dense_like += self.top_k * 3 * self.d_model * self.d_ff_expert * \
            self.layer_pattern.count("moe")
        return dense_like


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
