"""The 10 assigned architectures, exact to the assignment table.

Each entry records its provenance tag.  ``smoke()`` returns the reduced
config used by per-arch smoke tests (same family, tiny dims).
"""

from __future__ import annotations

from .base import ModelConfig, SHAPES, ShapeConfig

GLM4_9B = ModelConfig(
    name="glm4-9b", family="dense", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=2, d_ff=13696, vocab_size=151_552,
    head_dim=128, qkv_bias=True, rope_theta=10_000.0,
    source="hf:THUDM/glm-4-9b; hf",
)

YI_6B = ModelConfig(
    name="yi-6b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=4, d_ff=11008, vocab_size=64_000,
    head_dim=128, rope_theta=5_000_000.0,
    source="arXiv:2403.04652; hf",
)

MINITRON_4B = ModelConfig(
    name="minitron-4b", family="dense", n_layers=32, d_model=3072,
    n_heads=24, n_kv_heads=8, d_ff=9216, vocab_size=256_000,
    head_dim=128, mlp_type="gelu",  # nemotron squared-relu family; gelu proxy
    source="arXiv:2407.14679; hf",
)

COMMAND_R_PLUS_104B = ModelConfig(
    name="command-r-plus-104b", family="dense", n_layers=64, d_model=12288,
    n_heads=96, n_kv_heads=8, d_ff=33792, vocab_size=256_000,
    head_dim=128, rope_theta=75_000_000.0, tie_embeddings=True,
    source="hf:CohereForAI/c4ai-command-r-v01; unverified",
)

MAMBA2_2P7B = ModelConfig(
    name="mamba2-2.7b", family="ssm", n_layers=64, d_model=2560,
    n_heads=1, n_kv_heads=1, d_ff=0, vocab_size=50_280,
    pattern=("ssm",), ssm_state=128, ssm_expand=2, ssm_headdim=64,
    source="arXiv:2405.21060; unverified",
)

RECURRENTGEMMA_9B = ModelConfig(
    name="recurrentgemma-9b", family="hybrid", n_layers=38, d_model=4096,
    n_heads=16, n_kv_heads=1, d_ff=12288, vocab_size=256_000,
    head_dim=256, pattern=("rec", "rec", "attn"), local_window=2048,
    lru_width=4096,
    source="arXiv:2402.19427; unverified",
)

QWEN3_MOE_30B_A3B = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe", n_layers=48, d_model=2048,
    n_heads=32, n_kv_heads=4, d_ff=768, vocab_size=151_936,
    head_dim=128, pattern=("moe",), n_experts=128, top_k=8,
    d_ff_expert=768, qk_norm=True, rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen3-30B-A3B; hf",
)

LLAMA4_SCOUT_17B_A16E = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe", n_layers=48, d_model=5120,
    n_heads=40, n_kv_heads=8, d_ff=8192, vocab_size=202_048,
    head_dim=128, pattern=("moe",), n_experts=16, top_k=1,
    d_ff_expert=8192, shared_expert=True, rope_theta=500_000.0,
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
)

INTERNVL2_1B = ModelConfig(
    name="internvl2-1b", family="vlm", n_layers=24, d_model=896,
    n_heads=14, n_kv_heads=2, d_ff=4864, vocab_size=151_655,
    head_dim=64, qkv_bias=True, rope_theta=1_000_000.0,
    frontend="vit_stub", n_patches=256, tie_embeddings=True,
    source="arXiv:2404.16821; hf",
)

MUSICGEN_MEDIUM = ModelConfig(
    name="musicgen-medium", family="audio", n_layers=48, d_model=1536,
    n_heads=24, n_kv_heads=24, d_ff=6144, vocab_size=2048,
    head_dim=64, mlp_type="gelu", n_codebooks=4,
    frontend="encodec_stub",
    source="arXiv:2306.05284; hf",
)

ARCHS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        GLM4_9B, YI_6B, MINITRON_4B, COMMAND_R_PLUS_104B, MAMBA2_2P7B,
        RECURRENTGEMMA_9B, QWEN3_MOE_30B_A3B, LLAMA4_SCOUT_17B_A16E,
        INTERNVL2_1B, MUSICGEN_MEDIUM,
    ]
}


#: valid ``comm_mode`` strings across launch/dry-run/benchmarks.  The
#: ``smi:<backend>`` forms select the transport backend moving the bytes
#: (repro/transport registry); bare ``"smi"`` means ``smi:static``;
#: ``"smi:compressed"`` runs int8 compressed links over the static
#: schedules (``compressed:<inner>`` composes with any backend).
TRANSPORT_BACKENDS: tuple[str, ...] = ("static", "packet", "fused",
                                       "compressed")
COMM_MODES: tuple[str, ...] = (
    "smi",
    *(f"smi:{b}" for b in TRANSPORT_BACKENDS),
    "bulk",
)

#: distributed application workloads of ``repro/apps`` (launchable via
#: ``python -m repro.launch.stencil`` etc.); each streams its communication
#: through any of the TRANSPORT_BACKENDS via ``comm_mode="smi:<backend>"``
APP_WORKLOADS: tuple[str, ...] = ("stencil",)

#: default (grid, domain, steps) cells the stencil launcher/benchmark runs:
#: strong scaling over the paper's 8-rank testbed shape plus the 1D ring
STENCIL_CASES: dict[str, dict] = {
    "ring8": {"grid": (1, 8), "domain": (256, 256), "steps": 8},
    "torus2x4": {"grid": (2, 4), "domain": (256, 256), "steps": 8},
    "torus2x2": {"grid": (2, 2), "domain": (256, 256), "steps": 8},
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]


def smoke(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=max(2, len(cfg.pattern)),
        d_model=64,
        n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128,
        vocab_size=512,
        dtype="float32",
    )
    if cfg.family == "moe":
        # capacity 4.0: no token drops at init => dispatch order-independent
        # (exact single-device vs TP comparisons in tests)
        kw.update(n_experts=4, top_k=min(2, cfg.top_k or 1), d_ff_expert=64,
                  capacity_factor=4.0)
    if cfg.family == "ssm":
        kw.update(ssm_state=16, ssm_headdim=16, ssm_expand=2,
                  n_heads=1, n_kv_heads=1)
    if cfg.family == "hybrid":
        kw.update(n_layers=3, lru_width=64, local_window=16,
                  n_heads=4, n_kv_heads=1)
    if cfg.family == "vlm":
        kw.update(n_patches=8)
    if cfg.family == "audio":
        kw.update(vocab_size=256)
    return cfg.scaled(**kw)


def cells():
    """All (arch, shape) dry-run cells with skip annotations."""
    out = []
    for name, cfg in ARCHS.items():
        for sname, sh in SHAPES.items():
            skip = None
            if sname == "long_500k" and not cfg.sub_quadratic:
                skip = "pure full-attention arch: 512k dense KV excluded (DESIGN.md §4)"
            out.append((name, sname, skip))
    return out
