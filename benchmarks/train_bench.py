"""Train-step benchmark: the channel-native model stack end to end.

One smoke-scale training step (fwd + bwd + FSDP grad sync) per transport
backend on the 2x4 data-x-model mesh, measured as compiled wall time and
modelled from :func:`repro.netsim.predict_train_step_stats` — the same
per-tag step/byte prediction ``launch/train --validate-comm`` gates
byte-exactly against the traced channel ledger.

Rows:

* ``train_step,<backend>`` — measured us/step plus the aggregate model
  comm time (``v5e_model_us``): every tagged channel's logical steps
  costed at the LinkModel wire-aware hop time.  Deterministic — any
  schedule regression (more steps, more bytes, a tag gone missing) moves
  it regardless of runner speed.
* ``train_comm,<backend>,<tag>`` — the per-tag model cost, so the
  regression gate pins down *which* channel's schedule changed.
"""

import time

import jax

from repro.configs import get_arch, smoke
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_mesh
from repro.launch.steps import TrainSettings, build_train
from repro.netsim import predict_train_step_stats

from .common import V5E_MODEL, csv_row

BACKENDS = ["static", "packet", "fused", "compressed"]
MESH = (2, 4)
SEQ_LEN, GLOBAL_BATCH = 64, 4


def _wire_of(backend: str) -> str:
    return "int8" if backend.startswith("compressed") else "raw"


def tag_model_us(entry: dict, wire: str) -> float:
    """LinkModel cost of one tag's schedule: its logical steps serialized
    at the wire-aware hop time of the mean per-step payload."""
    steps = entry["steps"]
    if steps <= 0:
        return 0.0
    return steps * V5E_MODEL.hop_time_wire(entry["bytes"] / steps, wire) * 1e6


def run():
    cfg = smoke(get_arch("yi-6b"))
    shape = ShapeConfig("bench", seq_len=SEQ_LEN, global_batch=GLOBAL_BATCH,
                        kind="train")
    mesh = make_mesh(MESH, ("data", "model"))
    out = []
    for backend in BACKENDS:
        st = TrainSettings(comm_mode=f"smi:{backend}", remat="nothing",
                           loss_chunks=1, total_steps=10, warmup_steps=1)
        art = build_train(cfg, mesh, shape, st)
        state = art["init_state"](0)
        rng = jax.random.PRNGKey(1)
        tok = jax.random.randint(
            rng, (GLOBAL_BATCH, SEQ_LEN), 0, cfg.vocab_size)
        batch = {"tokens": tok, "labels": tok}

        state, _ = jax.block_until_ready(art["step"](state, batch))  # compile
        ts = []
        for _ in range(3):
            t0 = time.perf_counter()
            state, _ = jax.block_until_ready(art["step"](state, batch))
            ts.append(time.perf_counter() - t0)
        t = sorted(ts)[1]

        predicted = predict_train_step_stats(cfg, MESH, shape, st)
        wire = _wire_of(backend)
        model_total = 0.0
        for tag in sorted(predicted):
            us = tag_model_us(predicted[tag], wire)
            model_total += us
            csv_row(f"train_comm,{backend},{tag}", us,
                    f"v5e_model_us={us:.1f}")
        csv_row(f"train_step,{backend}", t * 1e6,
                f"v5e_model_us={model_total:.1f}")
        out.append((backend, t, model_total))
    return out
