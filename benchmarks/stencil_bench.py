"""Fig. 15 / Fig. 16: SPMD distributed stencil with SMI halo exchange.

Strong scaling of a 4-point stencil over a fixed domain on 1 / 4 / 8 ranks
(2D decomposition, N/S/E/W halo channels per paper Fig. 14), plus a weak-
scaling row.  The distributed result is asserted equal to the single-rank
sweep — communication correctness included in the benchmark.

Domain reduced from the paper's 4096^2 x 32 steps to CPU-friendly sizes;
the v5e model column scales per the paper's inequality (§5.4.2).
"""

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import Communicator, make_test_mesh
from repro.core.overlap import halo_exchange_2d
from repro.kernels import stencil_ref

from .common import HBM_BW, ICI_BW, csv_row, timeit


def _sweep_tile(tile_with_halo):
    """One local sweep given a halo'd tile (paper's shift-register kernel)."""
    xp = tile_with_halo.astype(jnp.float32)
    out = 0.25 * (xp[:-2, 1:-1] + xp[2:, 1:-1] + xp[1:-1, :-2] + xp[1:-1, 2:])
    return out


def _dist_stencil(grid, domain, steps):
    RX, RY = grid
    n = RX * RY
    names = ("gx", "gy")
    mesh = make_test_mesh(grid, names)
    comm = Communicator.create(names, grid)
    nx, ny = domain[0] // RX, domain[1] // RY

    def fn(tiles):
        def body(_, t):
            padded = halo_exchange_2d(t, comm, grid=grid, halo=(1, 1))
            return _sweep_tile(padded).astype(t.dtype)

        return jax.lax.fori_loop(0, steps, body, tiles[0])[None]

    f = jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=P(names), out_specs=P(names)))
    return f, (n, nx, ny)


def run():
    domain = (512, 512)
    steps = 8
    rng = np.random.RandomState(0)
    world = rng.randn(*domain).astype(np.float32)

    # single-rank reference
    f1 = jax.jit(lambda x: jax.lax.fori_loop(0, steps, lambda _, v: stencil_ref(v), x))
    t1 = timeit(f1, jnp.asarray(world))
    want = np.asarray(f1(jnp.asarray(world)))

    out = [("1rank", domain, t1)]
    csv_row(f"stencil_fig15,{domain[0]}x{domain[1]},ranks=1", t1 * 1e6, "")

    for grid in [(2, 2), (2, 4)]:
        RX, RY = grid
        n = RX * RY
        f, (n_, nx, ny) = _dist_stencil(grid, domain, steps)
        tiles = np.zeros((n, nx, ny), np.float32)
        for rx in range(RX):
            for ry in range(RY):
                tiles[rx * RY + ry] = world[rx * nx:(rx + 1) * nx,
                                            ry * ny:(ry + 1) * ny]
        tj = jnp.asarray(tiles)
        t = timeit(f, tj)
        got = np.asarray(f(tj))
        # reassemble + verify against the single-rank sweep
        re = np.zeros_like(world)
        for rx in range(RX):
            for ry in range(RY):
                re[rx * nx:(rx + 1) * nx, ry * ny:(ry + 1) * ny] = got[rx * RY + ry]
        np.testing.assert_allclose(re, want, rtol=1e-5, atol=1e-5)
        # v5e model: compute/mem per rank shrinks by n; halo comm per rank
        mem_t = domain[0] * domain[1] * 4 * 2 / n / HBM_BW
        halo_t = 2 * (nx + ny) * 4 * 2 / ICI_BW
        model = steps * max(mem_t, halo_t)
        csv_row(f"stencil_fig15,{domain[0]}x{domain[1]},ranks={n}", t * 1e6,
                f"v5e_model_us={model * 1e6:.1f}")
        out.append((f"{n}rank", domain, t))

    # weak scaling (fig 16): fixed per-rank tile
    for grid in [(2, 2), (2, 4)]:
        n = grid[0] * grid[1]
        dom = (256 * grid[0], 256 * grid[1])
        wrld = rng.randn(*dom).astype(np.float32)
        f, (_, nx, ny) = _dist_stencil(grid, dom, steps)
        tiles = np.stack([
            wrld[rx * nx:(rx + 1) * nx, ry * ny:(ry + 1) * ny]
            for rx in range(grid[0]) for ry in range(grid[1])
        ])
        t = timeit(f, jnp.asarray(tiles))
        per_pt = t / (dom[0] * dom[1] * steps) * 1e9
        csv_row(f"stencil_fig16_weak,ranks={n}", t * 1e6,
                f"ns_per_point={per_pt:.3f}")
        out.append((f"weak{n}", dom, t))
    return out


if __name__ == "__main__":
    run()
