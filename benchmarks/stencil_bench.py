"""Fig. 15 / Fig. 16: SPMD distributed stencil with SMI halo exchange.

Built on the ``repro/apps`` layer: strong scaling of a 4-point stencil over
a fixed domain on 1 / 4 / 8 ranks, a weak-scaling row, and — the paper's
headline — the *pipelined* schedule sweep: overlapped vs non-overlapped
step under every transport backend (``static`` / ``packet`` / ``fused`` /
``compressed``), asserted bit-identical to each other and to the
single-rank sweep (exact wires) before any timing is reported.

Model columns come from the shared netsim :class:`LinkModel`: the halo
exchange's predicted time and the overlap window (max vs sum of
compute/comm).  ``--validate-sim`` (benchmarks/run.py) asserts the halo
schedule's *exact* traced step/byte counters equal the netsim prediction
and gates fitted time predictions within 2x of measurement — the same
drift gate the latency/injection suites run.

Domain reduced from the paper's 4096^2 x 32 steps to CPU-friendly sizes.
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.apps import DistributedStencil
from repro.netsim import calibrate
from repro.obs.metrics import REGISTRY

from .common import (
    HBM_BW,
    ICI_BW,
    V5E_MODEL,
    csv_row,
    make_bench_transport,
    timeit,
    wire_of,
)

OVERLAP_GRID = (2, 4)
OVERLAP_DOMAIN = (256, 256)
OVERLAP_STEPS = 2


def _strong_weak_scaling(world, domain, steps):
    """The original Fig. 15 / Fig. 16 rows, through the apps layer."""
    app1 = DistributedStencil.create((1, 1), axis_names=("gx",))
    f1 = app1.jitted(app1.make_mesh(), n_steps=steps, overlapped=False)
    t1 = timeit(f1, jnp.asarray(world[None]))
    want = app1.single_rank_reference(world, steps)
    csv_row(f"stencil_fig15,{domain[0]}x{domain[1]},ranks=1", t1 * 1e6, "")

    for grid in [(2, 2), (2, 4)]:
        n = grid[0] * grid[1]
        app = DistributedStencil.create(grid)
        tiles = jnp.asarray(app.scatter(world))
        f = app.jitted(app.make_mesh(), n_steps=steps, overlapped=True)
        t = timeit(f, tiles)
        got = app.gather(np.asarray(f(tiles)))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
        nx, ny = domain[0] // grid[0], domain[1] // grid[1]
        # v5e model: compute/mem per rank shrinks by n; halo comm per rank
        mem_t = domain[0] * domain[1] * 4 * 2 / n / HBM_BW
        halo_t = app.halo_schedule.predicted_time((nx, ny), model=V5E_MODEL)
        model = steps * V5E_MODEL.overlapped_step_time(mem_t, halo_t)
        csv_row(f"stencil_fig15,{domain[0]}x{domain[1]},ranks={n}", t * 1e6,
                f"v5e_model_us={model * 1e6:.1f}")

    # weak scaling (fig 16): fixed per-rank tile
    rng = np.random.RandomState(1)
    for grid in [(2, 2), (2, 4)]:
        n = grid[0] * grid[1]
        dom = (256 * grid[0], 256 * grid[1])
        wrld = rng.randn(*dom).astype(np.float32)
        app = DistributedStencil.create(grid)
        tiles = jnp.asarray(app.scatter(wrld))
        f = app.jitted(app.make_mesh(), n_steps=steps, overlapped=True)
        t = timeit(f, tiles)
        per_pt = t / (dom[0] * dom[1] * steps) * 1e9
        csv_row(f"stencil_fig16_weak,ranks={n}", t * 1e6,
                f"ns_per_point={per_pt:.3f}")


def _overlap_sweep(transports, validate_sim):
    """Overlapped vs reference schedule under every transport backend."""
    grid, domain, steps = OVERLAP_GRID, OVERLAP_DOMAIN, OVERLAP_STEPS
    nx, ny = domain[0] // grid[0], domain[1] // grid[1]
    rng = np.random.RandomState(2)
    world = rng.randn(*domain).astype(np.float32)
    app = DistributedStencil.create(grid)
    mesh = app.make_mesh()
    tiles = jnp.asarray(app.scatter(world))
    want = app.single_rank_reference(world, steps)
    records = []

    for tname in transports:
        wire = wire_of(tname)
        halo_t = app.halo_schedule.predicted_time(
            (nx, ny), model=V5E_MODEL, wire=wire
        )
        mem_t = nx * ny * 4 * 2 / HBM_BW
        results = {}
        for sched, overlapped in (("ref", False), ("ovl", True)):
            tp = make_bench_transport(tname)
            f = app.jitted(mesh, n_steps=steps, overlapped=overlapped,
                           transport=tp)
            t = timeit(f, tiles)
            results[sched] = np.asarray(f(tiles))
            window = (V5E_MODEL.overlapped_step_time(mem_t, halo_t)
                      if overlapped else
                      V5E_MODEL.serial_step_time(mem_t, halo_t))
            csv_row(
                f"stencil_overlap,{domain[0]}x{domain[1]},{tname},{sched}",
                t * 1e6, f"v5e_model_us={window * steps * 1e6:.1f}",
            )
            if sched == "ovl":
                REGISTRY.track(f"stencil/{tname}", tp)
            if validate_sim and sched == "ovl":
                # exactness gate: traced halo counters == netsim prediction
                kw = {"pkt_elems": tp.pkt_elems} if tname == "packet" else {}
                pred = app.halo_schedule.predicted_stats(
                    (nx, ny), transport=tname, **kw
                )
                got = tp.stats.tag_counts("halo")
                got = (got[0] // steps, got[1] // steps)
                REGISTRY.drift(f"stencil/{tname}/halo_bytes",
                               predicted=pred[1], measured=got[1])
                assert got == pred, (
                    f"halo stats drift[{tname}]: traced/step {got} != "
                    f"predicted {pred}"
                )
        # correctness before the numbers mean anything: the two schedules
        # are bit-identical on every backend; exact wires also match the
        # single-rank sweep to the bit
        np.testing.assert_array_equal(results["ref"], results["ovl"])
        got = app.gather(results["ovl"])
        if wire == "raw":
            np.testing.assert_array_equal(got, want)
        else:
            np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)

    # halo-exchange-only calibration records (the --validate-sim gate)
    for size in (64, 128, 256):
        capp = DistributedStencil.create(grid)
        ctiles = jnp.asarray(capp.scatter(
            rng.randn(size * grid[0], size * grid[1]).astype(np.float32)
        ))

        def fn(ts):
            he = capp.halo_schedule
            return he.exchange(ts[0])[None]

        from jax.sharding import PartitionSpec as P

        from repro.compat import shard_map

        f = jax.jit(shard_map(
            fn, mesh=mesh, in_specs=P(("gx", "gy")),
            out_specs=P(("gx", "gy")),
        ))
        t = timeit(f, ctiles, iters=9 if validate_sim else 5)
        steps_p, bytes_p = capp.halo_schedule.predicted_stats((size, size))
        records.append(
            calibrate.record(steps_p, bytes_p, t, f"halo_{size}x{size}")
        )
        csv_row(f"stencil_halo_exchange,{size}x{size}", t * 1e6,
                f"v5e_model_us={capp.halo_schedule.predicted_time((size, size)) * 1e6:.2f}")
    if validate_sim:
        m, _worst = calibrate.validate(records, tol=2.0, label="stencil_halo")
        # the drift gauges recompute validate's ratios through the same
        # drift_ratio formula, so the snapshot can never disagree with the
        # gate that just passed
        REGISTRY.drift_from_records("stencil_halo", records, model=m)


def run(transports=("static", "packet", "fused", "compressed"),
        validate_sim=False):
    domain, steps = (512, 512), 8
    world = np.random.RandomState(0).randn(*domain).astype(np.float32)
    _strong_weak_scaling(world, domain, steps)
    _overlap_sweep(transports, validate_sim)


if __name__ == "__main__":
    run()
