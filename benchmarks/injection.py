"""Tab. 4: injection rate vs the router's polling stickiness R.

The paper: with R=1 the CK polls a different port every cycle (5-cycle
injection latency); higher R lets a busy FIFO keep the link (1.69 cycles at
R=16) at the cost of per-connection fairness.  We run the dynamic packet
router with all FIFOs saturated and count delivered packets per router step
as R varies — the same trade-off, measured on the same transport logic that
serves the routed messaging path.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core import Communicator, Topology, make_test_mesh
from repro.core.router import RouterConfig, make_router_tables, run_router
from repro.netsim import Message, simulate

from .common import csv_row, timeit

DIMS = (2, 4)
N = 8


def _sim_drain(comm, R, n_pkts=8):
    """Replay the bench's contention workload in the netsim link simulator:
    per rank, two staged FIFOs (1-hop and 2-hop +y destinations) competing
    for the same link under R-sticky arbitration with the switch bubble —
    predicted drain steps for the measured run."""
    msgs = []
    for r in range(N):
        row, col = divmod(r, 4)
        for port, delta in [(0, 1), (1, 2)]:
            dst = row * 4 + (col + delta) % 4
            msgs.append(Message(r, dst, n_flits=n_pkts, flit_bytes=32 * 4,
                                port=port, pipelined=False))
    rep = simulate(comm.topology, comm.route_table, msgs,
                   R=R, switch_bubble=True)
    return rep.ticks


def run(validate_sim=False):
    mesh = make_test_mesh(DIMS, ("x", "y"))
    comm = Communicator.create(("x", "y"), DIMS)
    tbl = jnp.asarray(make_router_tables(Topology.torus(DIMS), DIMS))
    out = []
    for R in [1, 4, 8, 16]:
        cfg = RouterConfig(dims=DIMS, n_ports=2, fifo_cap=8, out_cap=32,
                           transit_cap=32, R=R, switch_bubble=True)
        n_steps = 96

        def fn(t, pay, dst, ln):
            op, oc, ov, td = run_router(cfg, comm, t, pay[0], dst[0], ln[0], n_steps)
            return oc[None], ov[None], td[None]

        spec = P(("x", "y"))
        f = jax.jit(jax.shard_map(
            fn, mesh=mesh, in_specs=(P(), spec, spec, spec),
            out_specs=(spec, spec, spec)))

        # saturate with CONTENTION: both FIFOs want the same +y link (one
        # 1-hop, one 2-hop destination), so arbitration (R) decides who
        # keeps the link and transit traffic competes with injection —
        # the paper's multi-connection scenario.
        pay = np.zeros((N, 2, 8, cfg.pkt_elems), np.float32)
        dst = np.zeros((N, 2, 8), np.int32)
        ln = np.full((N, 2), 8, np.int32)
        for r in range(N):
            row, col = divmod(r, 4)
            dst[r, 0, :] = row * 4 + (col + 1) % 4   # +y, 1 hop
            dst[r, 1, :] = row * 4 + (col + 2) % 4   # +y then +y, 2 hops
        args = (tbl, jnp.asarray(pay), jnp.asarray(dst), jnp.asarray(ln))
        oc, ov, td = f(*args)
        delivered = int(np.asarray(oc).sum())
        lost = int(np.asarray(ov).sum())
        drain = int(np.asarray(td).max()) + 1  # steps until last delivery
        t = timeit(f, *args)
        cyc_per_pkt = drain / (delivered / N)  # per-rank steps per packet
        sim_drain = _sim_drain(comm, R)
        csv_row(f"injection_tab4,R={R}", t * 1e6,
                f"delivered={delivered},drain_steps={drain},"
                f"sim_drain={sim_drain},"
                f"steps_per_pkt={cyc_per_pkt:.2f},overflow={lost}")
        out.append((R, delivered, cyc_per_pkt, drain, sim_drain))
    if validate_sim:
        worst = 1.0
        for R, _d, _c, drain, sim_drain in out:
            ratio = max(drain / sim_drain, sim_drain / drain)
            worst = max(worst, ratio)
            assert ratio <= 2.0, (
                f"injection_tab4 R={R}: simulated drain {sim_drain} vs "
                f"measured {drain} steps drifted past 2x"
            )
        print(f"# [injection_tab4] validate-sim OK: worst drain ratio "
              f"{worst:.2f}x (<= 2.0x)")
    return out


if __name__ == "__main__":
    run()
