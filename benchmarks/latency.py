"""Tab. 3: single-element message latency vs hop count (SMI-1/-4/-7).

The paper measures half round-trip of a ping-pong.  Structurally, SMI
latency = hops x per-hop cost; the host-staged path pays the full
PCIe+MPI+PCIe stack once regardless of distance (36.61 us measured there).
We time a 1-chunk channel across 1/4/7 bus hops and report the shared
netsim :class:`~repro.netsim.LinkModel`'s v5e figure next to it (hop cost
≈ 1 us ICI + chunk serialisation) — the same model the simulator and the
autotuner use, so the derived column cannot drift from them.

``--validate-sim`` fits a CPU-calibrated LinkModel to the measurements
(schedule steps/bytes from netsim's exact stats prediction) and asserts
every prediction lands within 2x of its measurement — the drift gate
between the simulator's schedule structure and what actually executes.
"""

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.channels import open_channel
from repro.core import Communicator, Topology, make_test_mesh
from repro.netsim import calibrate, predict_transport_stats

from .common import V5E_MODEL, csv_row, timeit


def run(validate_sim=False):
    mesh = make_test_mesh((8,), ("x",))
    comm = Communicator.create("x", (8,), topology=Topology.bus(8))
    elems = 8  # one tiny packet
    x = jnp.ones((8, elems), jnp.float32)
    out = []
    records = []
    for dst, hops in [(1, 1), (4, 4), (7, 7)]:
        f = jax.jit(jax.shard_map(
            lambda v: open_channel(
                comm, src=0, dst=dst, port=None, n_chunks=1
            ).transfer(v[0])[None],
            mesh=mesh, in_specs=P("x"), out_specs=P("x")))
        t = timeit(f, x, iters=9 if validate_sim else 5)
        model = V5E_MODEL.p2p_time(elems * 4, hops, n_chunks=1)
        steps, nbytes = predict_transport_stats(
            comm, "p2p", shape=(elems,), src=0, dst=dst, n_chunks=1,
        )
        records.append(calibrate.record(steps, nbytes, t, f"hops={hops}"))
        csv_row(f"latency_tab3,hops={hops}", t * 1e6,
                f"v5e_model_us={model * 1e6:.2f}")
        out.append((hops, t, model))
    if validate_sim:
        calibrate.validate(records, tol=2.0, label="latency_tab3")
    return out


if __name__ == "__main__":
    run()
