"""Tab. 3: single-element message latency vs hop count (SMI-1/-4/-7).

The paper measures half round-trip of a ping-pong.  Structurally, SMI
latency = hops x per-hop cost; the host-staged path pays the full
PCIe+MPI+PCIe stack once regardless of distance (36.61 us measured there).
We time a 1-chunk channel across 1/4/7 bus hops and report the v5e model
(hop cost ≈ 1 us ICI + chunk serialisation).
"""

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import Communicator, Topology, make_test_mesh, stream_p2p

from .common import ICI_BW, csv_row, timeit

HOP_LAT = 1e-6  # ~1us per ICI hop (v5e-class)


def run():
    mesh = make_test_mesh((8,), ("x",))
    comm = Communicator.create("x", (8,), topology=Topology.bus(8))
    elems = 8  # one tiny packet
    x = jnp.ones((8, elems), jnp.float32)
    out = []
    for dst, hops in [(1, 1), (4, 4), (7, 7)]:
        f = jax.jit(jax.shard_map(
            lambda v: stream_p2p(v[0], src=0, dst=dst, comm=comm, n_chunks=1)[None],
            mesh=mesh, in_specs=P("x"), out_specs=P("x")))
        t = timeit(f, x)
        model = hops * (HOP_LAT + elems * 4 / ICI_BW)
        csv_row(f"latency_tab3,hops={hops}", t * 1e6,
                f"v5e_model_us={model * 1e6:.2f}")
        out.append((hops, t, model))
    return out


if __name__ == "__main__":
    run()
