"""Fig. 10 / Fig. 11: Bcast and Reduce vs message size, torus vs bus.

Compared: SMI streamed (pipelined chain, the paper's linear scheme),
host-staged (serial bulk sends — the MPI+OpenCL analogue), and the
beyond-paper binomial tree.  The paper's observations to reproduce:
streamed collectives beat staged for all sizes; topology (torus vs bus)
barely matters for the streamed version; trees win at small sizes.
"""

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import (
    Communicator,
    Topology,
    make_test_mesh,
    staged_bcast,
    staged_reduce,
    stream_bcast,
    stream_reduce,
    tree_bcast,
    tree_reduce,
)

from .common import ICI_BW, csv_row, timeit

PP = 8


def run():
    mesh = make_test_mesh((PP,), ("x",))
    comms = {
        "torus": Communicator.create("x", (PP,)),
        "bus": Communicator.create("x", (PP,), topology=Topology.bus(PP)),
    }
    out = []
    for log2_kb in [4, 8, 11]:
        elems = (1 << log2_kb) * 256
        x = jnp.ones((PP, elems), jnp.float32)
        n_chunks = 16
        mb = elems * 4 / 2**20
        for topo, comm in comms.items():
            variants = {
                "smi": lambda v, c=comm: stream_bcast(
                    v[0].reshape(n_chunks, -1), c, root=0, n_chunks=n_chunks
                ).reshape(1, -1),
                "staged": lambda v, c=comm: staged_bcast(v[0], c, root=0)[None],
                "tree": lambda v, c=comm: tree_bcast(v[0], c, root=0)[None],
            }
            for name, fn in variants.items():
                f = jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=P("x"),
                                          out_specs=P("x")))
                t = timeit(f, x)
                if name == "smi":
                    steps = n_chunks + PP - 2
                    model = steps * (elems * 4 / n_chunks) / ICI_BW
                elif name == "staged":
                    model = sum(
                        comm.route_table.n_hops(0, d) for d in range(1, PP)
                    ) * elems * 4 / ICI_BW
                else:
                    model = 3 * elems * 4 / ICI_BW  # log2(8) rounds
                csv_row(f"bcast_fig10,{mb:.2f}MB,{topo},{name}", t * 1e6,
                        f"v5e_model_us={model * 1e6:.1f}")
                out.append(("bcast", mb, topo, name, t, model))

            rvariants = {
                "smi": lambda v, c=comm: stream_reduce(
                    v[0].reshape(n_chunks, -1), c, root=0, n_chunks=n_chunks
                ).reshape(1, -1),
                "staged": lambda v, c=comm: staged_reduce(v[0], c, root=0)[None],
                "tree": lambda v, c=comm: tree_reduce(v[0], c, root=0)[None],
            }
            for name, fn in rvariants.items():
                f = jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=P("x"),
                                          out_specs=P("x")))
                t = timeit(f, x)
                csv_row(f"reduce_fig11,{mb:.2f}MB,{topo},{name}", t * 1e6, "")
                out.append(("reduce", mb, topo, name, t, None))
    return out


if __name__ == "__main__":
    run()
